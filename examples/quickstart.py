"""Quickstart: reproduce the paper's Group 1 experiment (Fig 8a/8b).

Runs the same sweep through the sequential paper-faithful oracle and the
declarative ``SweepPlan`` API (DESIGN.md §4), prints the dependent
variables side by side, and checks Table IV's network-cost column.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import engine, paper_scenario, refsim
from repro.core.sweep import axis, product


def main():
    print("IOTSim-JAX quickstart — paper §5.4 Group 1 (Small job, Small VM, "
          "3 VMs)\n")
    hdr = (f"{'MR':>6} {'avg_exec':>10} {'max_exec':>10} {'min_exec':>10} "
           f"{'makespan':>10} {'delay':>9} {'net_cost':>9} {'vm_cost':>9}")
    print(hdr)
    for m in range(1, 21):
        r = refsim.simulate(paper_scenario(n_maps=m)).job()
        print(f"M{m:<2}R1 {r.avg_exec:10.2f} {r.max_exec:10.2f} "
              f"{r.min_exec:10.2f} {r.makespan:10.2f} {r.delay_time:9.2f} "
              f"{r.network_cost:9.2f} {r.vm_cost:9.2f}")

    # the same sweep, one declarative plan + one vmapped engine call
    plan = product(axis("n_maps", range(1, 21)),
                   axis("network_delay", (True, False)))
    res = plan.run()
    delayed = res.select(network_delay=True)
    ref = [refsim.simulate(paper_scenario(n_maps=m)).job().makespan
           for m in range(1, 21)]
    ok = np.allclose(delayed["makespan"], ref, rtol=1e-4)
    print(f"\nvectorized engine == sequential oracle: {ok}")

    expected = 4250.0 / (np.arange(1, 21) + 1)
    got = delayed["network_cost"]
    print(f"Table IV exact (4250/(M+1)): {np.allclose(got, expected, rtol=1e-4)}")

    # labeled point lookup replaces positional row bookkeeping
    with_delay = res.select(n_maps=20, network_delay=True).to_dict()
    without = res.select(n_maps=20, network_delay=False).to_dict()
    print(f"\nwithout network delay, M20R1 makespan: "
          f"{without['makespan']:.2f}s (with: {with_delay['makespan']:.2f}s)")

    single = engine.simulate(paper_scenario(n_maps=20, network_delay=False))
    assert np.isclose(float(single.makespan[0]), without["makespan"],
                      rtol=1e-6)


if __name__ == "__main__":
    main()
