"""Quickstart: reproduce the paper's Group 1 experiment (Fig 8a/8b).

Runs the same sweep through the sequential paper-faithful oracle and the
vectorized JAX engine, prints the dependent variables side by side, and
checks Table IV's network-cost column.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import engine, paper_scenario, refsim, sweep


def main():
    print("IOTSim-JAX quickstart — paper §5.4 Group 1 (Small job, Small VM, "
          "3 VMs)\n")
    hdr = (f"{'MR':>6} {'avg_exec':>10} {'max_exec':>10} {'min_exec':>10} "
           f"{'makespan':>10} {'delay':>9} {'net_cost':>9} {'vm_cost':>9}")
    print(hdr)
    for m in range(1, 21):
        r = refsim.simulate(paper_scenario(n_maps=m)).job()
        print(f"M{m:<2}R1 {r.avg_exec:10.2f} {r.max_exec:10.2f} "
              f"{r.min_exec:10.2f} {r.makespan:10.2f} {r.delay_time:9.2f} "
              f"{r.network_cost:9.2f} {r.vm_cost:9.2f}")

    # the same sweep, one vmapped engine call
    batch = sweep.paper_grid(m_range=range(1, 21))
    out = sweep.simulate_batch(batch)
    ref = [refsim.simulate(paper_scenario(n_maps=m)).job().makespan
           for m in range(1, 21)]
    ok = np.allclose(np.asarray(out.makespan[:, 0]), ref, rtol=1e-4)
    print(f"\nvectorized engine == sequential oracle: {ok}")

    expected = 4250.0 / (np.arange(1, 21) + 1)
    got = np.asarray(out.network_cost[:, 0])
    print(f"Table IV exact (4250/(M+1)): {np.allclose(got, expected, rtol=1e-4)}")

    single = engine.simulate(paper_scenario(n_maps=20, network_delay=False))
    print(f"\nwithout network delay, M20R1 makespan: "
          f"{float(single.makespan[0]):.2f}s "
          f"(with: {float(out.makespan[19, 0]):.2f}s)")


if __name__ == "__main__":
    main()
