"""Batched serving driver: prefill a prompt batch, decode with KV caches,
report per-phase throughput; then use the simulator to predict pod-scale
serving under stragglers (the IOTSim methodology applied to serving).

    PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax
import numpy as np

from repro.core import ChipSpec, StepCost, workload
from repro.models import (ArchConfig, decode_step, init_model, prefill)


def main():
    cfg = ArchConfig(name="serve-demo", family="dense", n_layers=4,
                     d_model=128, n_heads=8, n_kv_heads=4, d_ff=512,
                     vocab=2048, vocab_pad_to=8, dtype="float32")
    B, S, DEC = 8, 64, 32
    params = init_model(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)

    pf = jax.jit(lambda p, x: prefill(p, cfg, x, S + DEC))
    dec = jax.jit(lambda p, tok, st, t: decode_step(p, cfg, tok, st, t))

    t0 = time.perf_counter()
    logits, state = pf(params, prompts)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    toks = jax.numpy.argmax(logits, -1)
    out = [toks]
    t0 = time.perf_counter()
    for t in range(S, S + DEC):
        logits, state = dec(params, toks, state, t)
        toks = jax.numpy.argmax(logits, -1)
        out.append(toks)
    jax.block_until_ready(toks)
    t_decode = time.perf_counter() - t0

    print(f"batch={B} prompt={S} decode={DEC}")
    print(f"prefill: {t_prefill*1e3:8.1f} ms  "
          f"({B*S/t_prefill:,.0f} tok/s incl. compile)")
    print(f"decode:  {t_decode*1e3:8.1f} ms  "
          f"({B*DEC/t_decode:,.0f} tok/s)")
    seqs = np.asarray(jax.numpy.stack(out, 1))
    print(f"sample continuation ids: {seqs[0][:10].tolist()}")

    # What the paper's methodology adds: predict pod-scale decode serving.
    chip = ChipSpec()
    cost = StepCost(flops=2e9, hbm_bytes=3e9, collective_bytes=2e8)
    pred = workload.simulate_training(     # one decode step == one "job"
        cost, chip, n_devices=256, n_steps=1000, straggler_sigma=0.08,
        checkpoint_secs=0.0)                # serving: no checkpoints
    print(f"\npod-scale decode prediction (256 chips, lognormal "
          f"sigma=0.08 stragglers):")
    print(f"  ideal step {pred['ideal_step_seconds']*1e3:.2f} ms -> "
          f"straggled {pred['step_seconds']*1e3:.2f} ms "
          f"(x{pred['straggler_slowdown']:.3f}), goodput "
          f"{pred['goodput']:.1%}")


if __name__ == "__main__":
    main()
