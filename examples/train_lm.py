"""End-to-end training driver: data pipeline -> sharded train loop ->
checkpoints, with fault tolerance on.

Presets:
  smoke  —   ~6M-param model,  60 steps: finishes in minutes on CPU
             (what the integration test runs);
  100m   — ~100M-param dense model, 300 steps: the assignment's
             reference driver (hours on 1 CPU core; minutes on a TPU
             host — the loop, sharding and checkpoint logic are
             identical, only the config differs).

    PYTHONPATH=src python examples/train_lm.py --preset smoke
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
"""
import argparse

import jax

from repro.models import ArchConfig, init_model
from repro.train import OptConfig, TrainConfig, train

PRESETS = {
    "smoke": dict(
        cfg=ArchConfig(name="lm-smoke", family="dense", n_layers=4,
                       d_model=128, n_heads=8, n_kv_heads=4, d_ff=512,
                       vocab=2048, vocab_pad_to=8, dtype="float32"),
        steps=60, seq_len=128, global_batch=8, lr=1e-3),
    "100m": dict(
        cfg=ArchConfig(name="lm-100m", family="dense", n_layers=12,
                       d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
                       vocab=32768, vocab_pad_to=128, dtype="float32"),
        steps=300, seq_len=512, global_batch=16, lr=6e-4),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=PRESETS, default="smoke")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    p = PRESETS[args.preset]
    cfg = p["cfg"]
    n_params = sum(
        x.size for x in jax.tree.leaves(
            jax.eval_shape(lambda: init_model(jax.random.PRNGKey(0), cfg))))
    tc = TrainConfig(
        steps=args.steps or p["steps"], seq_len=p["seq_len"],
        global_batch=p["global_batch"],
        opt=OptConfig(lr=p["lr"], warmup_steps=20),
        ckpt_dir=f"{args.ckpt_dir}/{cfg.name}", ckpt_every=50, log_every=10)

    print(f"training {cfg.name}: {n_params/1e6:.1f}M params, "
          f"{tc.steps} steps, batch {tc.global_batch}x{tc.seq_len}, "
          f"{len(jax.devices())} device(s)")
    hist = train(cfg, tc)
    losses = hist["loss"]
    print(f"resumed_at={hist['resumed_at']} restarts={hist['restarts']} "
          f"stragglers={hist['straggler_steps']}")
    print(f"loss: first5={sum(losses[:5])/5:.4f} "
          f"last5={sum(losses[-5:])/5:.4f} final={hist['final_loss']:.4f}")
    assert losses[-1] < losses[0], "loss should decrease"
    print("checkpoints committed under", tc.ckpt_dir)


if __name__ == "__main__":
    main()
