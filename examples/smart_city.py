"""Smart-city case study (paper §5.1) + a pod-scale what-if sweep.

A city council sizes the cloud deployment for its MapReduce road-network
analytics: three IoT feeds (road sensors, traffic cams, commuter apps)
arrive as jobs of different sizes.  Part 1 simulates the mixed workload on
a candidate datacentre (sequential oracle — the paper's workflow).
Part 2 asks the question the paper's CloudSim architecture cannot: sweep
*every* provisioning candidate (VM type × VM count × MR split) at once
with the vectorized engine and pick the cheapest config meeting an SLA.

    PYTHONPATH=src python examples/smart_city.py
"""
import dataclasses
import time

import numpy as np

from repro.core import (JOB_BIG, JOB_MEDIUM, JOB_SMALL, VM_TYPES, Scenario,
                        refsim, sweep)


def part1_mixed_workload():
    print("== Part 1: mixed smart-city workload on 6 medium VMs ==")
    jobs = (
        dataclasses.replace(JOB_BIG, name="road-network", n_maps=12),
        dataclasses.replace(JOB_MEDIUM, name="traffic-cams", n_maps=8,
                            submit_time=600.0),
        dataclasses.replace(JOB_SMALL, name="commuter-apps", n_maps=4,
                            submit_time=1200.0),
    )
    sc = Scenario(vms=(VM_TYPES["medium"],) * 6, jobs=jobs)
    res = refsim.simulate(sc)
    for job, jr in zip(jobs, res.jobs):
        print(f"  {job.name:14s} makespan={jr.makespan:9.1f}s "
              f"avg_exec={jr.avg_exec:8.1f}s vm_cost=${jr.vm_cost:10.1f} "
              f"net_cost=${jr.network_cost:8.1f}")
    print(f"  cluster busy until t={res.finish_time:.1f}s, "
          f"{res.n_events} DES epochs\n")


def part2_provisioning_sweep(sla_makespan=4000.0):
    print("== Part 2: provisioning sweep (engine, one vmapped call) ==")
    cells = []
    for vm_name, vm in VM_TYPES.items():
        for n_vms in range(2, 17, 2):
            for m in (4, 8, 16, 20):
                cells.append((vm_name, vm, n_vms, m))
    params = dict(
        n_maps=np.array([c[3] for c in cells], np.int32),
        n_reduces=np.ones(len(cells), np.int32),
        n_vms=np.array([c[2] for c in cells], np.int32),
        vm_mips=np.array([c[1].mips for c in cells], np.float32),
        vm_pes=np.array([float(c[1].pes) for c in cells], np.float32),
        vm_cost=np.array([c[1].cost_per_sec for c in cells], np.float32),
        job_length=np.full(len(cells), JOB_BIG.length_mi, np.float32),
        job_data=np.full(len(cells), JOB_BIG.data_mb, np.float32),
    )
    batch = sweep.grid_arrays(params, pad_tasks=21, pad_vms=16)
    t0 = time.perf_counter()
    out = sweep.simulate_batch(batch)
    out.makespan.block_until_ready()
    dt = time.perf_counter() - t0
    makespan = np.asarray(out.makespan[:, 0])
    cost = np.asarray(out.vm_cost[:, 0]) + np.asarray(out.network_cost[:, 0])
    print(f"  simulated {len(cells)} provisioning candidates in "
          f"{dt*1e3:.1f} ms ({len(cells)/dt:.0f} scenarios/s)")

    feasible = makespan <= sla_makespan
    if feasible.any():
        best = int(np.argmin(np.where(feasible, cost, np.inf)))
        vm_name, _, n_vms, m = cells[best]
        print(f"  SLA: makespan <= {sla_makespan:.0f}s")
        print(f"  cheapest feasible: {n_vms}x {vm_name} VM, M{m}R1 -> "
              f"makespan={makespan[best]:.0f}s total_cost=${cost[best]:.0f}")
    infeasible = (~feasible).sum()
    print(f"  ({infeasible}/{len(cells)} candidates miss the SLA)\n")


if __name__ == "__main__":
    part1_mixed_workload()
    part2_provisioning_sweep()
