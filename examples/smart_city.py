"""Smart-city case study (paper §5.1) + a pod-scale what-if sweep.

A city council sizes the cloud deployment for its MapReduce road-network
analytics: three IoT feeds (road sensors, traffic cams, commuter apps)
arrive as jobs of different sizes.  Part 1 simulates the mixed workload on
a candidate datacentre (sequential oracle — the paper's workflow).
Part 2 asks the question the paper's CloudSim architecture cannot: sweep
*every* provisioning candidate (VM type × VM count × MR split) at once
with the vectorized engine and pick the cheapest config meeting an SLA.
Part 3 turns on the storage subsystem (DESIGN.md §7) and sweeps block
replication × binding policy over a skewed placement to find where
data-local (LOCALITY) dispatch beats load balancing.
Part 4 right-sizes a *pay-as-you-go* fleet (DESIGN.md §8): lease length ×
VM count × Poisson arrival rate, picking the cheapest `billed_cost`
configuration whose worst arrival still meets the makespan target.
Part 5 stress-tests the winner with the closed-loop control subsystem
(DESIGN.md §10): a disaster surge — burst arrivals while the gateway-zone
VMs fail — comparing a reactive fleet (reserves opened by autoscaling,
failed tasks re-dispatched against block replicas) to a static
over-provisioned one on `recovered_fraction` and `billed_cost`.
Part 6 reruns the same surge with decision-window deadlines (DESIGN.md
§11): analytics that finish after the window are wasted, so the council
compares running everything late (the Part-5 posture) against shedding
doomed work and preempting for the critical feed — same recovery, far
fewer missed windows.
Part 7 runs one surge scenario with the in-loop trace recorder on
(DESIGN.md §12) and exports the event timeline as Chrome trace-event
JSON for chrome://tracing / Perfetto.

    PYTHONPATH=src python examples/smart_city.py
"""
import dataclasses
import time

import numpy as np

from repro.core import (JOB_BIG, JOB_MEDIUM, JOB_SMALL, VM_TYPES,
                        BindingPolicy, ControlPolicy, ControlSpec,
                        DeadlinePolicy, Scenario, SchedPolicy, elasticity,
                        refsim, sweep, telemetry)


def part1_mixed_workload():
    print("== Part 1: mixed smart-city workload on 6 medium VMs ==")
    jobs = (
        dataclasses.replace(JOB_BIG, name="road-network", n_maps=12),
        dataclasses.replace(JOB_MEDIUM, name="traffic-cams", n_maps=8,
                            submit_time=600.0),
        dataclasses.replace(JOB_SMALL, name="commuter-apps", n_maps=4,
                            submit_time=1200.0),
    )
    sc = Scenario(vms=(VM_TYPES["medium"],) * 6, jobs=jobs)
    res = refsim.simulate(sc)
    for job, jr in zip(jobs, res.jobs):
        print(f"  {job.name:14s} makespan={jr.makespan:9.1f}s "
              f"avg_exec={jr.avg_exec:8.1f}s vm_cost=${jr.vm_cost:10.1f} "
              f"net_cost=${jr.network_cost:8.1f}")
    print(f"  cluster busy until t={res.finish_time:.1f}s, "
          f"{res.n_events} DES epochs\n")


def part2_provisioning_sweep(sla_makespan=4000.0):
    print("== Part 2: provisioning sweep (one declarative SweepPlan) ==")
    plan = sweep.product(
        sweep.axis("vm_type", list(VM_TYPES)),
        sweep.axis("n_vms", range(2, 17, 2)),
        sweep.axis("n_maps", (4, 8, 16, 20)),
        job_type="big",
    )
    t0 = time.perf_counter()
    res = plan.run()
    dt = time.perf_counter() - t0
    makespan = res["makespan"]
    cost = res["vm_cost"] + res["network_cost"]
    print(f"  simulated {plan.size} provisioning candidates in "
          f"{dt*1e3:.1f} ms ({plan.size/dt:.0f} scenarios/s)")

    feasible = makespan <= sla_makespan
    if feasible.any():
        best = np.unravel_index(np.argmin(np.where(feasible, cost, np.inf)),
                                cost.shape)
        c = res.coord(best)
        print(f"  SLA: makespan <= {sla_makespan:.0f}s")
        print(f"  cheapest feasible: {c['n_vms']}x {c['vm_type']} VM, "
              f"M{c['n_maps']}R1 -> makespan={makespan[best]:.0f}s "
              f"total_cost=${cost[best]:.0f}")
    infeasible = int((~feasible).sum())
    print(f"  ({infeasible}/{plan.size} candidates miss the SLA)\n")


def part3_locality_sweep():
    """Storage subsystem (DESIGN.md §7): where the road-network feed's
    blocks live now matters.  One replication x binding grid over the
    skewed (hot-spot) placement answers the sizing question Locality Sim
    poses: how much HDFS replication does the council need before
    data-local dispatch stops being a trade-off?"""
    print("== Part 3: block replication x binding locality sweep ==")
    plan = sweep.product(
        sweep.axis("binding_policy", [BindingPolicy.ROUND_ROBIN,
                                      BindingPolicy.LEAST_LOADED,
                                      BindingPolicy.LOCALITY]),
        sweep.axis("replication", (1, 2, 3, 4, 6, 8)),
        storage=True, placement="skewed", block_size_mb=32768.0,
        n_vms=8, n_maps=24, n_reduces=2, job_type="small",
    )
    res = plan.run()
    print(f"  {plan.size} cells; skewed placement, 8 VMs, M24R2 "
          "(block = 32 GB)")
    print(f"  {'replication':>11s}  " + "  ".join(
        f"{bp.name:>17s}" for bp in (BindingPolicy.ROUND_ROBIN,
                                     BindingPolicy.LEAST_LOADED,
                                     BindingPolicy.LOCALITY)))
    for i, r in enumerate((1, 2, 3, 4, 6, 8)):
        row = []
        for bp in (BindingPolicy.ROUND_ROBIN, BindingPolicy.LEAST_LOADED,
                   BindingPolicy.LOCALITY):
            c = res.select(binding_policy=bp, replication=r)
            row.append(f"{float(c['makespan']):7.0f}s "
                       f"lf={float(c['locality_fraction']):4.2f}")
        print(f"  {r:>11d}  " + "  ".join(f"{x:>17s}" for x in row))
    loc = res.select(binding_policy=BindingPolicy.LOCALITY)["makespan"]
    ll = res.select(binding_policy=BindingPolicy.LEAST_LOADED)["makespan"]
    wins = [r for i, r in enumerate((1, 2, 3, 4, 6, 8)) if loc[i] < ll[i]]
    print(f"  LOCALITY beats LEAST_LOADED at replication {wins} "
          "(converges bit-for-bit at replication = n_vms)\n")


def part4_lease_rightsizing(makespan_target=6000.0):
    """Elasticity (DESIGN.md §8): the council leases VMs by the hour
    instead of owning a static cluster.  One grid over lease length × VM
    count × offered load answers the pay-as-you-go question the paper
    poses but CloudSim cannot sweep: the *cheapest billed fleet* that
    still meets the makespan target for every arrival in the stream."""
    print("== Part 4: right-size the pay-as-you-go fleet ==")
    n_arrivals = 12
    lease_hours = (2, 4, 8, 24)
    plan = sweep.product(
        sweep.axis("n_vms", (2, 4, 6, 8)),
        sweep.axis("vm_stop", [h * 3600.0 for h in lease_hours]),
        sweep.arrivals(n_arrivals, rate=[1 / 1800.0, 1 / 600.0],
                       process="poisson", seed=7),
        vm_type="medium", n_maps=12, n_reduces=2, job_type="medium",
        spinup_delay=120.0, billing_granularity=3600.0,
    )
    res = plan.run()
    print(f"  {plan.size} cells: {len(lease_hours)} lease lengths x 4 "
          f"fleet sizes x 2 arrival rates x {n_arrivals} arrivals "
          "(billing: hourly, 120 s spin-up)")
    print(f"  target: every arrival's makespan <= {makespan_target:.0f}s")
    for rate_name, rate in (("1/30 min", 1 / 1800.0),
                            ("1/10 min", 1 / 600.0)):
        best = None
        for n_vms in (2, 4, 6, 8):
            for h in lease_hours:
                cell = res.select(arrival_rate=rate, n_vms=n_vms,
                                  vm_stop=h * 3600.0)
                worst = float(cell["makespan"].max())
                cost = float(cell["billed_cost"].max())
                busy = float(cell["vm_busy_fraction"].mean())
                if worst <= makespan_target and (best is None
                                                 or cost < best[0]):
                    best = (cost, n_vms, h, worst, busy)
        if best:
            cost, n_vms, h, worst, busy = best
            print(f"  {rate_name} arrivals -> cheapest feasible: "
                  f"{n_vms}x medium on a {h}h lease "
                  f"(billed ${cost:.0f}, worst makespan {worst:.0f}s, "
                  f"busy {busy:.2f})")
        else:
            print(f"  {rate_name} arrivals -> no leased fleet meets the "
                  "target; lengthen the lease or add VMs")
    stranded = int((res["makespan"] > 1e20).sum())
    print(f"  ({stranded} cells strand work: the lease closes before "
          "the arrival — automatically infeasible)\n")


def part5_disaster_surge():
    """Closed-loop control (DESIGN.md §10): an earthquake cuts the
    gateway-zone uplink at t=900 s (its two VMs fail; repaired 30 min
    later) just as re-routed sensor traffic surges in.  The council
    compares two postures over the same seeded surge:

    * **reactive** — 4 always-on VMs + 4 autoscale reserves the control
      hook opens only while the queue backs up; failed tasks re-dispatch
      to their block-replica holders after a 30 s detection delay;
    * **static** — 8 VMs leased around the clock, same failures.

    Same physics, same recovery — the closed loop just stops paying for
    the reserves once the surge drains."""
    print("== Part 5: disaster surge — reactive vs over-provisioned ==")
    n_arrivals = 6
    big = 1e30
    # the disaster: gateway-zone VMs (fleet slots 0-1) down 900s..2700s
    vm_fail = np.array([900.0, 900.0] + [big] * 6, np.float32)
    vm_restore = np.array([2700.0, 2700.0] + [big] * 6, np.float32)
    base = dict(vm_type="medium", n_vms=8, n_maps=8, n_reduces=2,
                job_type="medium", vm_fail=vm_fail, vm_restore=vm_restore,
                redispatch_delay=30.0, spinup_delay=120.0,
                billing_granularity=900.0)
    surge = sweep.arrivals(n_arrivals, rate=1 / 300.0, process="poisson",
                           seed=11)
    reactive = sweep.product(
        surge, vm_auto=np.array([0.0] * 4 + [1.0] * 4, np.float32),
        control_policy="autoscale", ctl_queue=0.0, ctl_busy=0.0, **base)
    static = sweep.product(surge, control_policy="none", **base)
    r, s = reactive.run(), static.run()
    print(f"  {n_arrivals} seeded surge arrivals; gateway zone (2/8 VMs) "
          "down 900s-2700s, redispatch after 30s")
    for name, res in (("reactive", r), ("static ", s)):
        rec = float(np.asarray(res["recovered_fraction"]).min())
        inj = int(np.asarray(res["failures_injected"]).sum())
        red = int(np.asarray(res["tasks_redispatched"]).sum())
        scale = int(np.asarray(res["scale_events"]).max())
        billed = float(np.asarray(res["billed_cost"]).max())
        mk = float(np.asarray(res["makespan"]).max())
        print(f"  {name}: {inj} failures, {red} tasks re-dispatched, "
              f"min recovered={rec:.2f}, scale events={scale}, "
              f"worst makespan={mk:.0f}s, billed ${billed:.0f}")
    saving = 1.0 - (float(np.asarray(r['billed_cost']).max())
                    / float(np.asarray(s['billed_cost']).max()))
    print(f"  same recovery, {saving:.0%} cheaper: the control hook only "
          "bills the reserves while the surge queue is deep\n")


def part6_deadline_surge():
    """Graceful degradation (DESIGN.md §11): the Part-5 surge again, but
    now the analytics only matter inside a decision window — a road
    closure computed after the evacuation window is wasted work.  Same
    seeded arrivals, same reactive fleet (4 always-on + 4 autoscale
    reserves), the gateway VM down 900s-2700s; each surge job now mixes
    one long critical road-network map (rank 2, 60 min window), four
    straggler maps stuck re-reading a flooded sensor archive (8x work —
    hopeless inside their 40 min window), and bulk camera maps on a
    45 min window.  The council compares two postures:

    * **run-everything** — the PR-7 fleet: deadlines recorded
      (`DeadlinePolicy.NONE`) but every task runs to completion, however
      late — the stragglers hog half the fleet for the whole surge;
    * **shed+preempt** — doomed tasks (earliest possible finish already
      past the window) are shed at admission, and the critical map
      preempts bulk work when the gateway failure re-queues it
      (`preempt_resume=1`: the evicted task keeps its progress).

    Failure physics are identical — degradation only changes *which*
    work the fleet spends the surge on."""
    print("== Part 6: the same surge under decision-window deadlines ==")
    n_arrivals = 6
    big = 1e30
    n_maps, n_red = 16, 2
    arr = np.asarray(elasticity.arrival_times(n_arrivals, rate=1 / 300.0,
                                              seed=11), np.float32)
    # task layout (round-robin bound, task i -> VM i % 8): map 0 the
    # critical feed, maps 2-5 the stragglers, the rest bulk; reduces
    # carry the _BIG sentinel (the job close-out is unconstrained, so
    # orphan-shed reduces don't count as missed windows)
    prio = np.array([2.0] + [0.0] * (n_maps - 1) + [1.0] * n_red,
                    np.float32)
    mult = np.full(n_maps + n_red, 2.0, np.float32)
    mult[0] = 3.0                       # critical: long analysis
    mult[2:6] = 8.0                     # stragglers: flooded archive
    mult[n_maps:] = 1.0
    window = np.full(n_maps + n_red, 2700.0, np.float32)
    window[0] = 3600.0                  # critical decision window
    window[2:6] = 2400.0                # stragglers cannot make this
    window[8] = 4200.0                  # late-tier partition, loose
    deadlines = (arr[:, None] + window[None, :]).astype(np.float32)
    deadlines[:, n_maps:] = big
    surge = sweep.zip_(sweep.axis("job_submit", arr),
                       sweep.axis("task_deadline", deadlines))
    base = dict(vm_type="small", n_vms=8, n_maps=n_maps, n_reduces=n_red,
                job_type="big", sched_policy=SchedPolicy.SPACE_SHARED,
                task_prio=prio, task_mult=mult,
                vm_fail=np.array([900.0] + [big] * 7, np.float32),
                vm_restore=np.array([2700.0] + [big] * 7, np.float32),
                redispatch_delay=30.0, spinup_delay=120.0,
                billing_granularity=900.0,
                vm_auto=np.array([0.0] * 4 + [1.0] * 4, np.float32),
                control_policy="autoscale", ctl_queue=0.0, ctl_busy=0.0)
    run_all = sweep.product(surge, deadline_policy="none", **base)
    degrade = sweep.product(surge, deadline_policy="shed", preempt=1,
                            preempt_resume=1, **base)
    ra, dg = run_all.run(), degrade.run()
    print(f"  {n_arrivals} seeded surge arrivals; 40-70 min task windows; "
          "gateway VM down 900s-2700s; 4 straggler maps per job")
    for name, res in (("run-everything", ra), ("shed+preempt  ", dg)):
        rec = float(np.asarray(res["recovered_fraction"]).min())
        miss = float(np.asarray(res["deadline_miss_fraction"]).mean())
        shed = int(np.asarray(res["shed_tasks"]).sum())
        pre = int(np.asarray(res["preemptions"]).sum())
        waste = float(np.asarray(res["wasted_work_frac"]).mean())
        billed = float(np.asarray(res["billed_cost"]).sum())
        print(f"  {name}: miss fraction={miss:.2f}, "
              f"min recovered={rec:.2f}, shed={shed}, "
              f"preemptions={pre}, wasted work={waste:.2f}, "
              f"billed ${billed:.0f}")
    cut = 1.0 - (float(np.asarray(dg["deadline_miss_fraction"]).mean())
                 / float(np.asarray(ra["deadline_miss_fraction"]).mean()))
    save = 1.0 - (float(np.asarray(dg["billed_cost"]).sum())
                  / float(np.asarray(ra["billed_cost"]).sum()))
    print(f"  {cut:.0%} fewer missed windows at {save:.0%} lower cost: "
          "shedding the doomed archive re-reads frees the fleet for "
          "maps that can still make their window, and the critical feed "
          "preempts its way back after the failure.  Every kill the "
          "degraded fleet keeps is recovered — the only unrecovered "
          "re-dispatches are ones the policy itself shed, work the "
          "outage had already pushed past its window (run-everything "
          "resurrects them, and that work lands in its 0.67 wasted "
          "fraction)\n")


def part7_surge_trace(path="smart_city_trace.json"):
    """Observability (DESIGN.md §12): the council's post-mortem.  Parts
    5-6 said *how much* was recovered; the trace says *when the queue
    built up, which VM each kill landed on, and when the reserves
    opened*.  One surge-like scenario — failures striking the gateway
    zone, autoscale reserves, decision-window shedding and preemption —
    runs with the in-loop trace recorder on (bitwise the same schedule),
    and the event log exports as Chrome trace-event JSON: load it at
    chrome://tracing or https://ui.perfetto.dev to scrub the timeline
    of task spans per VM track."""
    print("== Part 7: exporting the surge timeline for chrome://tracing ==")
    jobs = tuple(
        dataclasses.replace(JOB_BIG, name=f"feed{i}", n_maps=10,
                            n_reduces=2, submit_time=300.0 * i,
                            priority=float(2 - i),
                            deadline=3600.0 + 600.0 * i)
        for i in range(3))
    vms = tuple(dataclasses.replace(VM_TYPES["small"],
                                    autoscale=(i >= 4)) for i in range(6))
    sc = Scenario(vms=vms, jobs=jobs,
                  sched_policy=SchedPolicy.SPACE_SHARED,
                  control=ControlSpec(policy=ControlPolicy.AUTOSCALE,
                                      queue_threshold=2.0,
                                      busy_threshold=0.5,
                                      failure_rate=0.0005, failure_seed=3,
                                      repair_delay=600.0,
                                      redispatch_delay=30.0,
                                      deadline_policy=DeadlinePolicy.SHED,
                                      preempt=1, preempt_resume=1))
    out, tr = telemetry.trace_scenario(sc, label="smart-city surge")
    counts = {k: v for k, v in tr.counts_by_kind(0).items() if v}
    doc = tr.to_chrome_trace(path)
    spans = sum(e["ph"] == "X" for e in doc["traceEvents"])
    print(f"  events by kind: {counts}")
    print(f"  wrote {path}: {spans} task spans over "
          f"{tr.ts[0][:, 4].sum():.0f} realized epochs, "
          f"{doc['otherData']['dropped_events']} dropped events")
    print("  -> open chrome://tracing (or https://ui.perfetto.dev) and "
          "load the file: lanes are processes, VM tracks are threads; "
          "kills, redispatches, sheds and scale events are instants\n")


if __name__ == "__main__":
    part1_mixed_workload()
    part2_provisioning_sweep()
    part3_locality_sweep()
    part4_lease_rightsizing()
    part5_disaster_surge()
    part6_deadline_surge()
    part7_surge_trace()
