"""Group 5: scheduling & binding policy comparison (beyond the paper).

The paper inherits CloudSim's scheduler family but only ever runs
CloudletSchedulerTimeShared with round-robin binding; comparing policies
means swapping Java classes and re-running the JVM per cell.  Here policy
is *data*: one vmapped call simulates every (SchedPolicy x BindingPolicy)
combination of the paper's Group-1 sweep at once, and a second part shows
least-loaded binding rescuing a heterogeneous cluster.

    PYTHONPATH=src python examples/policy_compare.py
"""
import dataclasses
import time

import numpy as np

from repro.core import (JOB_MEDIUM, VM_MEDIUM, VM_SMALL, BindingPolicy,
                        Scenario, SchedPolicy, refsim, sweep)

M_SWEEP = range(1, 21)


def part1_policy_grid():
    print("== Part 1: M-sweep x all 6 policy combos, one vmapped call ==")
    batch, combos = sweep.policy_grid(m_range=M_SWEEP, n_vms=3,
                                      vm_type="medium")
    t0 = time.perf_counter()
    out = sweep.simulate_batch(batch)
    out.makespan.block_until_ready()
    dt = time.perf_counter() - t0
    n_m = len(M_SWEEP)
    print(f"  {len(combos) * n_m} scenarios in {dt * 1e3:.1f} ms")
    print(f"  {'policy':34s} makespan@M1  makespan@M20")
    for i, (sp, bp) in enumerate(combos):
        mk = np.asarray(out.makespan[i * n_m:(i + 1) * n_m, 0])
        print(f"  {sp.name:13s} + {bp.name:12s}     {mk[0]:9.1f}     "
              f"{mk[-1]:9.1f}")
    print()


def part2_heterogeneous_binding():
    print("== Part 2: binding policy on a heterogeneous cluster (oracle) ==")
    # 2 fast + 4 slow VMs: round-robin overloads the slow ones; least-loaded
    # weighs placement by each VM's capacity (mips x PEs).
    vms = (VM_MEDIUM,) * 2 + (VM_SMALL,) * 4
    job = dataclasses.replace(JOB_MEDIUM, n_maps=12, n_reduces=2)
    for bp in BindingPolicy:
        sc = Scenario(vms=vms, jobs=(job,),
                      sched_policy=SchedPolicy.SPACE_SHARED,
                      binding_policy=bp)
        r = refsim.simulate(sc).job()
        print(f"  {bp.name:12s} makespan={r.makespan:9.1f}s "
              f"avg_exec={r.avg_exec:8.1f}s vm_cost=${r.vm_cost:9.1f}")
    print()


if __name__ == "__main__":
    part1_policy_grid()
    part2_heterogeneous_binding()
