"""Group 5: scheduling & binding policy comparison (beyond the paper).

The paper inherits CloudSim's scheduler family but only ever runs
CloudletSchedulerTimeShared with round-robin binding; comparing policies
means swapping Java classes and re-running the JVM per cell.  Here policy
is an *axis* of a declarative ``SweepPlan`` (DESIGN.md §4): one vmapped
call simulates every (SchedPolicy x BindingPolicy) combination of the
paper's Group-1 sweep at once, and a second plan shows least-loaded
binding rescuing a heterogeneous cluster — now encoded *device-side*
through per-VM mips/pes/cost vectors (no host-side scenario objects).

    PYTHONPATH=src python examples/policy_compare.py
"""
import time

from repro.core import BindingPolicy, SchedPolicy
from repro.core.sweep import axis, product

M_SWEEP = range(1, 21)
# The three bindings that differ without a storage model — LOCALITY is
# bit-identical to LEAST_LOADED when the block store is off (DESIGN.md
# §7.3); see examples/smart_city.py Part 3 for the storage-on comparison.
BINDINGS = [BindingPolicy.ROUND_ROBIN, BindingPolicy.LEAST_LOADED,
            BindingPolicy.PACKED]


def part1_policy_grid():
    print(f"== Part 1: M-sweep x all {2 * len(BINDINGS)} distinct policy "
          "combos, one vmapped call ==")
    plan = product(axis("sched_policy", list(SchedPolicy)),
                   axis("binding_policy", BINDINGS),
                   axis("n_maps", M_SWEEP),
                   vm_type="medium")
    t0 = time.perf_counter()
    res = plan.run()
    dt = time.perf_counter() - t0
    print(f"  {plan.size} scenarios in {dt * 1e3:.1f} ms")
    print(f"  {'policy':34s} makespan@M1  makespan@M20")
    for sp in SchedPolicy:
        for bp in BINDINGS:
            mk = res.select(sched_policy=sp, binding_policy=bp)["makespan"]
            print(f"  {sp.name:13s} + {bp.name:12s}     {mk[0]:9.1f}     "
                  f"{mk[-1]:9.1f}")
    print()


def part2_heterogeneous_binding():
    print("== Part 2: binding policy on a heterogeneous cluster "
          "(device-side cell) ==")
    # 2 fast + 4 slow VMs: round-robin overloads the slow ones; least-loaded
    # weighs placement by each VM's capacity (mips x PEs).  The mixed cluster
    # is one per-VM-encoded cell — the sweep never leaves the device.
    plan = product(axis("binding_policy", BINDINGS),
                   vms=("medium",) * 2 + ("small",) * 4,
                   sched_policy=SchedPolicy.SPACE_SHARED,
                   n_maps=12, n_reduces=2, job_type="medium")
    res = plan.run()
    for bp in BINDINGS:
        r = res.select(binding_policy=bp).to_dict()
        print(f"  {bp.name:12s} makespan={r['makespan']:9.1f}s "
              f"avg_exec={r['avg_exec']:8.1f}s vm_cost=${r['vm_cost']:9.1f} "
              f"util={r['utilization']:.2f}")
    print()


if __name__ == "__main__":
    part1_policy_grid()
    part2_heterogeneous_binding()
