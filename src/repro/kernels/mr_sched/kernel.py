"""Batched MapReduce-schedule kernel: the IOTSim event loop on a TensorCore.

One grid step simulates a *tile* of scenarios entirely in VMEM: the
(tasks × scenarios) fluid state (remaining MI, readiness, processor-sharing
rates) is advanced through a statically-bounded ``fori_loop`` of event
epochs — every epoch fires at least one arrival or completion, so
``2·T + 2`` epochs suffice for T tasks.  The XLA while-loop engine
(``repro.core.engine``) round-trips this state through HBM every epoch;
here a whole sweep tile stays resident, which is the same
locality transformation flash attention applies to softmax state.

Scope: one job per scenario (the paper's §5 experiment cells — exactly
what ``repro.core.sweep.encode_cell`` produces), arbitrary M/R/VM mix,
both scheduling policies (time-shared fluid PS and space-shared PE slots;
the per-scenario i32 ``sched_policy`` gate mirrors the engine's, so one
tile may mix policies).  Semantics oracle:
``repro.core.engine.simulate_arrays`` (ref.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BIG = 1e30


def _kernel(task_len_ref, task_vm_ref, ready0_ref, is_red_ref, valid_ref,
            shuffle_ref, vm_mips_ref, vm_pes_ref, sched_ref,
            start_ref, finish_ref, *, T: int, V: int, n_epochs: int):
    task_len = task_len_ref[...]                 # (tile, T) f32
    task_vm = task_vm_ref[...]                   # (tile, T) i32
    is_red = is_red_ref[...] != 0                # (tile, T)
    valid = valid_ref[...] != 0
    shuffle = shuffle_ref[...]                   # (tile, 1) f32
    vm_mips = vm_mips_ref[...]                   # (tile, V)
    vm_pes = vm_pes_ref[...]                     # (tile, V)
    is_space = sched_ref[...] != 0               # (tile, 1) policy gate
    vm_onehot = (task_vm[..., None]
                 == jax.lax.broadcasted_iota(jnp.int32,
                                             (1, 1, V), 2))  # (tile,T,V)
    vm_onehot = vm_onehot.astype(jnp.float32)
    task_pes = jnp.einsum("stv,sv->st", vm_onehot, vm_pes)
    # Loop-invariant pieces of the space-shared admission priority.
    same_vm = jnp.einsum("siv,sjv->sij", vm_onehot, vm_onehot)  # (tile,T,T)
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (1, T, T), 1)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (1, T, T), 2)
    idx_earlier = iota_j < iota_i

    tile = task_len.shape[0]
    state = (
        jnp.zeros((tile,), jnp.float32),                 # time
        task_len,                                        # rem
        jnp.zeros((tile, T), jnp.bool_),                 # running
        jnp.full((tile, T), _BIG, jnp.float32),          # start
        jnp.full((tile, T), _BIG, jnp.float32),          # finish
        ready0_ref[...],                                 # ready
    )

    def epoch(_, st):
        time, rem, running, start, finish, ready = st
        runf = running.astype(jnp.float32)
        n_on_vm = jnp.einsum("stv,st->sv", vm_onehot, runf)
        # space-shared admission keeps n <= pes, so the time-shared fluid
        # share degenerates to full mips there: one rate formula for both.
        share = vm_mips * jnp.minimum(1.0, vm_pes
                                      / jnp.maximum(n_on_vm, 1.0))
        rate = jnp.einsum("stv,sv->st", vm_onehot, share) * runf
        eta = jnp.where(running, time[:, None]
                        + rem / jnp.maximum(rate, 1e-30), _BIG)
        not_started = valid & ~running & (finish >= _BIG / 2) \
            & (start >= _BIG / 2)
        # space-shared: pending tasks only define arrival events while a PE
        # slot is free; otherwise a completion epoch admits them.
        has_slot = (task_pes - jnp.einsum("stv,sv->st", vm_onehot,
                                          n_on_vm)) > 0.5
        arr = jnp.where(not_started & (~is_space | has_slot),
                        jnp.maximum(ready, time[:, None]), _BIG)
        t_next = jnp.minimum(jnp.min(eta, axis=1), jnp.min(arr, axis=1))
        live = t_next < _BIG / 2
        tie = 1e-6 * jnp.maximum(t_next, 1.0)

        dt = jnp.where(live, t_next - time, 0.0)
        rem = jnp.where(running, rem - dt[:, None] * rate, rem)

        done_now = live[:, None] & running & (eta <= (t_next + tie)[:, None])
        finish = jnp.where(done_now, t_next[:, None], finish)
        running = running & ~done_now
        rem = jnp.where(done_now, 0.0, rem)

        maps_left = jnp.sum((valid & ~is_red
                             & (finish >= _BIG / 2)).astype(jnp.int32),
                            axis=1)
        maps_done_prev = jnp.sum((valid & ~is_red & done_now)
                                 .astype(jnp.int32), axis=1)
        phase_done = (maps_left == 0) & (maps_done_prev > 0)
        ready_next = jnp.where(phase_done[:, None] & is_red,
                               (t_next + shuffle[:, 0])[:, None], ready)

        # arrivals: time-shared starts every ready task; space-shared
        # admits the (ready, index)-first eligible tasks into the PE slots
        # left free after this epoch's completions (matching the engine,
        # reduces released this epoch compete from the next epoch on).
        eligible = live[:, None] & not_started \
            & (ready <= (t_next + tie)[:, None])
        free_after = task_pes - jnp.einsum(
            "stv,sv->st", vm_onehot,
            n_on_vm - jnp.einsum("stv,st->sv", vm_onehot,
                                 done_now.astype(jnp.float32)))
        higher_prio = (same_vm > 0.5) \
            & ((ready[:, None, :] < ready[:, :, None])
               | ((ready[:, None, :] == ready[:, :, None]) & idx_earlier))
        rank = jnp.sum((higher_prio & eligible[:, None, :])
                       .astype(jnp.float32), axis=2)
        start_now = eligible & (~is_space | (rank < free_after))
        start = jnp.where(start_now, t_next[:, None], start)
        running = running | start_now
        time = jnp.where(live, t_next, time)
        return (time, rem, running, start, finish, ready_next)

    _, _, _, start, finish, _ = jax.lax.fori_loop(0, n_epochs, epoch, state)
    start_ref[...] = start
    finish_ref[...] = finish


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def mr_schedule(task_len, task_vm, ready0, is_red, valid, shuffle,
                vm_mips, vm_pes, sched_policy=None, *, tile: int = 64,
                interpret: bool = True):
    """All args lead with the scenario dim N (padded to a tile multiple).

    task_len/ready0: (N,T) f32; task_vm: (N,T) i32; is_red/valid: (N,T) i32;
    shuffle: (N,1) f32; vm_mips/vm_pes: (N,V) f32; sched_policy: (N,1) i32
    (0 time-shared | 1 space-shared; defaults to all time-shared).
    Returns (start, finish): (N,T) f32.
    """
    N, T = task_len.shape
    V = vm_mips.shape[1]
    if sched_policy is None:
        sched_policy = jnp.zeros((N, 1), jnp.int32)
    tile = min(tile, N)
    while N % tile:
        tile //= 2
    grid = (N // tile,)

    def row(i):
        return (i, 0)

    spec_t = pl.BlockSpec((tile, T), row)
    spec_1 = pl.BlockSpec((tile, 1), row)
    spec_v = pl.BlockSpec((tile, V), row)
    out = pl.pallas_call(
        functools.partial(_kernel, T=T, V=V, n_epochs=2 * T + 2),
        grid=grid,
        in_specs=[spec_t, spec_t, spec_t, spec_t, spec_t, spec_1,
                  spec_v, spec_v, spec_1],
        out_specs=(spec_t, spec_t),
        out_shape=(jax.ShapeDtypeStruct((N, T), jnp.float32),
                   jax.ShapeDtypeStruct((N, T), jnp.float32)),
        interpret=interpret,
    )(task_len, task_vm, ready0, is_red, valid, shuffle, vm_mips, vm_pes,
      sched_policy)
    return out
