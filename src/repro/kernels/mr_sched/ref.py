"""Oracle: the XLA while-loop engine (repro.core.engine)."""
from __future__ import annotations

import jax

from repro.core import engine, sweep


def schedule_ref(batch: "engine.ScenarioArrays"):
    """Returns (start, finish) arrays for a stacked scenario batch."""
    out = jax.vmap(engine.simulate_arrays)(batch)
    return out.start, out.finish
