"""``mr_epoch``: the fused epoch megakernel (adaptive-schedule backend).

One ``pl.pallas_call`` advances a *tile* of scenario lanes through their
whole event history: rates evaluation, the one-hot reductions, fluid-state
advance, the next-event min, completions, shuffle release, and space-shared
admission are fused into a single kernel body whose per-VM/per-task state
(remaining MI, readiness, running masks, per-VM occupancy) stays resident
in VMEM across epochs — the XLA engine (``repro.core.engine``) round-trips
that state through HBM once per epoch.

Two structural upgrades over the PR-1 ``mr_schedule`` kernel:

* **Tile-level early exit** — the epoch loop is a ``lax.while_loop`` gated
  on ``any(lane unfinished)`` (plus the ``2T + 2`` safety bound), so a tile
  stops at its own realized epoch count instead of always burning the
  worst-case bound; the per-lane realized counts come back as ``n_epochs``.
* **Per-VM admission scan** — the space-shared (ready, index) admission
  rank was a ``T×T`` higher-priority matrix (O(T²) VMEM + flops per
  epoch); here admission extracts per-VM minima ``max_pes`` times
  (O(max_pes·T·V)), admitting exactly the tasks whose per-VM rank is below
  the free PE count — the ROADMAP "fold the T×T rank into a per-VM scan"
  item.

Every float-bearing step reuses the engine's exact op sequence (the one-hot
contractions are 0/1-weighted sums, so any accumulation order is exact),
which makes the kernel's schedule **bit-identical** to
``engine.simulate_arrays`` — pinned by ``tests/test_adaptive_schedule.py``,
not just approximately close.  Scope: single-job scenarios (J = 1 — what
``sweep.encode_cell`` emits), arbitrary M/R/VM mix, both sched policies per
lane (``sched_policy`` is lane data, so one tile may mix policies).

Storage subsystem (DESIGN.md §7): LOCALITY binding and the remote-fetch
penalty reach this kernel entirely through lane data — ``task_vm`` carries
the replica-aware binding and ``ready0`` carries the per-task fetch delay
(``storage.remote_fetch_delay``, applied in ``ops._derived_inputs`` with
the engine's exact f32 op sequence).  Off-replica map tasks therefore
enter the per-VM ``(ready, index)`` admission scan at their delayed ready
times and lose admission priority to data-local peers, with no kernel-side
branching — one lowering serves all five policy axes' values mixed per
lane, bit-identical to the engine (``tests/test_storage.py``).

Elasticity (DESIGN.md §8): VM lease windows are lane data too —
``vm_start``/``vm_stop`` (+ the ``spinup`` boot delay) gate admission
per VM: a pending task's eligible time is ``max(ready, lease open)``
(lease-start edges therefore join the next-event min through the
arrival candidates) and candidates whose event time lands at/past the
lease close are stranded, never defining an event again.  The
space-shared admission scan extracts per-VM minima of the lexicographic
``(priority desc, eligible time, index)`` key — the per-task
``prio`` input generalizes the classic ``(ready, index)`` rank; zero
priorities and the static-fleet window ``[0, 1e30)`` reproduce the
pre-elastic schedule bit for bit (``tests/test_elasticity.py``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BIG = 1e30
_TIME_EPS = 1e-6


def _kernel(task_len_ref, task_vm_ref, ready0_ref, is_red_ref, valid_ref,
            shuffle_ref, vm_mips_ref, vm_pes_ref, sched_ref,
            vm_start_ref, vm_stop_ref, spinup_ref, prio_ref,
            time0_ref, rem0_ref, running0_ref, start0_ref, finish0_ref,
            maps0_ref, lane_ep0_ref,
            time_ref, rem_ref, running_ref, start_ref, finish_ref,
            ready_ref, maps_ref, n_epochs_ref,
            *, T: int, V: int, max_pes: int, epoch_bound: int):
    task_len = task_len_ref[...]                 # (tile, T) f32
    task_vm = task_vm_ref[...]                   # (tile, T) i32
    is_red = is_red_ref[...] != 0                # (tile, T)
    valid = valid_ref[...] != 0
    shuffle = shuffle_ref[...]                   # (tile, 1) f32
    vm_mips = vm_mips_ref[...]                   # (tile, V)
    vm_pes = vm_pes_ref[...]                     # (tile, V)
    is_space = sched_ref[...] != 0               # (tile, 1) policy gate
    vm_start = vm_start_ref[...]                 # (tile, V) lease open
    vm_stop = vm_stop_ref[...]                   # (tile, V) lease close
    spinup = spinup_ref[...]                     # (tile, 1) boot delay
    prio = prio_ref[...]                         # (tile, T) admission prio
    tile = task_len.shape[0]

    vm_onehot = (task_vm[..., None]
                 == jax.lax.broadcasted_iota(jnp.int32,
                                             (1, 1, V), 2))  # (tile,T,V)
    onehot_b = vm_onehot
    vm_onehot = vm_onehot.astype(jnp.float32)
    task_pes = jnp.einsum("stv,sv->st", vm_onehot, vm_pes)
    idx = jax.lax.broadcasted_iota(jnp.int32, (1, T), 1)     # (1, T)

    def to_task(per_vm):
        """Gather a per-VM quantity to each task's VM (exact: one-hot)."""
        return jnp.einsum("stv,sv->st", vm_onehot, per_vm)

    def per_vm_sum(per_task):
        return jnp.einsum("stv,st->sv", vm_onehot, per_task)

    # Lease admission windows (DESIGN.md §8), gathered per task with the
    # exact f32 ops the engine's _epoch_setup uses (one-hot gathers are
    # exact; vm_stop carries the _BIG stand-in, never inf — 0 * inf would
    # NaN these einsums).  Static fleets make every use below a bitwise
    # identity with the pre-elastic kernel.
    avail_t = to_task(vm_start + spinup)         # (tile, T)
    close_t = to_task(vm_stop)                   # (tile, T)

    # carry state arrives as refs (the wrapper builds the canonical
    # initial state with the exact constants this kernel used to
    # initialize in VMEM — compacted/chunked drivers resume mid-history
    # by feeding a previous call's state back in)
    state = (
        time0_ref[...][:, 0],                            # time
        rem0_ref[...],                                   # rem
        running0_ref[...] != 0,                          # running
        start0_ref[...],                                 # start
        finish0_ref[...],                                # finish
        ready0_ref[...],                                 # ready
        maps0_ref[...][:, 0],                            # maps_left
        lane_ep0_ref[...][:, 0],                         # lane epochs
        jnp.int32(0),                                    # epochs this call
    )

    def lanes_active(finish):
        return jnp.any(valid & (finish >= _BIG / 2), axis=1)   # (tile,)

    def cond(st):
        return jnp.any(lanes_active(st[4])) & (st[8] < epoch_bound)

    def epoch(st):
        (time, rem, running, start, finish, ready, maps_left, lane_ep,
         n) = st
        active = lanes_active(finish)
        runf = running.astype(jnp.float32)
        # single rates evaluation per epoch (space-shared keeps n <= pes,
        # so the min() clamp makes this formula serve both policies)
        n_on_vm = per_vm_sum(runf)
        share = vm_mips * jnp.minimum(1.0, vm_pes
                                      / jnp.maximum(n_on_vm, 1.0))
        r = jnp.where(running, to_task(share), 0.0)
        eta = jnp.where(running,
                        time[:, None] + rem / jnp.maximum(r, 1e-30), _BIG)
        not_started = valid & ~running & (finish >= _BIG / 2) \
            & (start >= _BIG / 2)
        # lease-aware eligibility: admissible from max(ready, lease open)
        # — start edges join the next-event min through the candidates —
        # and only while the event time lands before the lease close
        # (candidates at/past it are stranded and define no event).
        elig = jnp.maximum(ready, avail_t)
        # space-shared: pending tasks only define arrival events while a
        # PE slot is free; otherwise a completion epoch admits them.
        has_slot = (task_pes - to_task(n_on_vm)) > 0.5
        cand_t = jnp.maximum(elig, time[:, None])
        arr = jnp.where(not_started & (~is_space | has_slot)
                        & (cand_t < close_t), cand_t, _BIG)
        t_next = jnp.minimum(jnp.min(eta, axis=1), jnp.min(arr, axis=1))
        live = t_next < _BIG / 2
        tie = _TIME_EPS * jnp.maximum(t_next, 1.0)

        # advance fluid state (engine op order: guard with running, not dt)
        rem = jnp.where(running, rem - (t_next[:, None] - time[:, None]) * r,
                        rem)

        # completions (all tied events fire in this one epoch)
        done_now = live[:, None] & running & (eta <= (t_next + tie)[:, None])
        finish = jnp.where(done_now, t_next[:, None], finish)
        running = running & ~done_now
        rem = jnp.where(done_now, 0.0, rem)

        # job map-phase completion -> release reduces after shuffle delay
        maps_done_now = jnp.sum((done_now & ~is_red).astype(jnp.int32),
                                axis=1)
        maps_left_new = maps_left - maps_done_now
        phase_done = (maps_left_new == 0) & (maps_left > 0)
        ready = jnp.where(is_red & phase_done[:, None],
                          (t_next + shuffle[:, 0])[:, None], ready)

        # arrivals: time-shared starts every admissible task; space-shared
        # admits the (priority desc, eligible time, index)-first waiting
        # tasks into the PE slots left free after this epoch's
        # completions.  Instead of ranking through a T×T priority matrix,
        # extract per-VM lexicographic minima max_pes times: the task
        # picked at scan step s has per-VM rank s, and is admitted iff
        # s < free slots on its VM — the same set the engine's rank
        # formulation admits.  The admission key is (prio, elig, idx);
        # all-zero priorities collapse the first stage to a no-op
        # bitwise, and a static fleet makes elig == ready.
        eligible = live[:, None] & not_started \
            & (elig <= (t_next + tie)[:, None]) \
            & (t_next[:, None] < close_t)
        free_v = vm_pes - (n_on_vm - per_vm_sum(done_now.astype(jnp.float32)))
        free_after = to_task(free_v)
        admit = jnp.zeros_like(eligible)
        remaining = eligible
        for s in range(max_pes):
            prio_m = jnp.where(remaining, prio, -_BIG)
            max_prio_v = jnp.max(
                jnp.where(onehot_b, prio_m[..., None], -_BIG), axis=1)
            top = remaining & (prio_m == to_task(max_prio_v))
            elig_m = jnp.where(top, elig, _BIG)
            min_elig_v = jnp.min(
                jnp.where(onehot_b, elig_m[..., None], _BIG), axis=1)
            cand = top & (elig_m == to_task(min_elig_v))
            idx_m = jnp.where(cand, idx, T)
            min_idx_v = jnp.min(
                jnp.where(onehot_b, idx_m[..., None], T), axis=1)
            pick = cand & (idx == jnp.einsum(
                "stv,sv->st", vm_onehot,
                min_idx_v.astype(jnp.float32)).astype(jnp.int32))
            admit = admit | (pick & (jnp.float32(s) < free_after))
            remaining = remaining & ~pick
        start_now = eligible & (~is_space | admit)
        start = jnp.where(start_now, t_next[:, None], start)
        running = running | start_now
        time = jnp.where(live, t_next, time)
        return (time, rem, running, start, finish, ready, maps_left_new,
                lane_ep + active.astype(jnp.int32), n + 1)

    st = jax.lax.while_loop(cond, epoch, state)
    time_ref[...] = st[0][:, None]
    rem_ref[...] = st[1]
    running_ref[...] = st[2].astype(jnp.int32)
    start_ref[...] = st[3]
    finish_ref[...] = st[4]
    ready_ref[...] = st[5]
    maps_ref[...] = st[6][:, None]
    n_epochs_ref[...] = st[7][:, None]


def initial_state(task_len, ready0, is_red, valid):
    """The canonical t=0 carry state, built with the exact constants the
    kernel used to initialize in VMEM (so feeding it through the state
    inputs is a bitwise no-op vs the pre-carry kernel).  Layout — every
    leaf 2-D for the BlockSpecs: ``(time (N,1) f32, rem (N,T) f32,
    running (N,T) i32, start (N,T) f32, finish (N,T) f32, ready (N,T)
    f32, maps_left (N,1) i32, n_epochs (N,1) i32)``."""
    N, T = task_len.shape
    return (jnp.zeros((N, 1), jnp.float32),
            task_len,
            jnp.zeros((N, T), jnp.int32),
            jnp.full((N, T), _BIG, jnp.float32),
            jnp.full((N, T), _BIG, jnp.float32),
            ready0,
            jnp.sum(((valid != 0) & ~(is_red != 0)).astype(jnp.int32),
                    axis=1, keepdims=True),
            jnp.zeros((N, 1), jnp.int32))


@functools.partial(jax.jit,
                   static_argnames=("tile", "interpret", "max_pes",
                                    "epoch_limit"))
def mr_epoch(task_len, task_vm, ready0, is_red, valid, shuffle,
             vm_mips, vm_pes, sched_policy=None, vm_start=None,
             vm_stop=None, spinup=None, prio=None, state=None, *,
             tile: int = 64, max_pes: int = 8, interpret: bool = True,
             epoch_limit: int | None = None):
    """All args lead with the scenario dim N (padded to a tile multiple).

    task_len/ready0: (N,T) f32; task_vm: (N,T) i32; is_red/valid: (N,T) i32;
    shuffle: (N,1) f32; vm_mips/vm_pes: (N,V) f32; sched_policy: (N,1) i32
    (0 time-shared | 1 space-shared; defaults to all time-shared).
    Elasticity lane data (DESIGN.md §8): vm_start/vm_stop: (N,V) f32 lease
    windows (stop carries the 1e30 +inf stand-in, never ``inf``); spinup:
    (N,1) f32; prio: (N,T) f32 space-shared admission priorities — the
    defaults (static fleet, zero priorities) reproduce the pre-elastic
    schedule bit for bit.

    ``state``/``epoch_limit`` make the kernel *resumable* (DESIGN.md §9):
    ``state`` is a full carry in :func:`initial_state` layout (default —
    the t=0 state; when given, the ``ready0`` argument is superseded by
    ``state[5]``) and ``epoch_limit`` caps how many event epochs this
    call advances (default — the ``2T + 2`` engine bound, i.e. run to
    completion).  The compacted driver (``ops.epoch_schedule_compact``)
    steps K-epoch chunks over gathered active lanes this way.

    ``max_pes`` must be >= the largest per-VM PE count in the batch (it
    bounds the static admission scan); ``tile`` lanes share one early-exit
    epoch loop.  Returns the advanced carry state (same 8-leaf layout).
    """
    N, T = task_len.shape
    V = vm_mips.shape[1]
    if sched_policy is None:
        sched_policy = jnp.zeros((N, 1), jnp.int32)
    if vm_start is None:
        vm_start = jnp.zeros((N, V), jnp.float32)
    if vm_stop is None:
        vm_stop = jnp.full((N, V), _BIG, jnp.float32)
    if spinup is None:
        spinup = jnp.zeros((N, 1), jnp.float32)
    if prio is None:
        prio = jnp.zeros((N, T), jnp.float32)
    if state is None:
        state = initial_state(task_len, ready0, is_red, valid)
    if epoch_limit is None:
        epoch_limit = 2 * T + 2
    tile = min(tile, N)
    while N % tile:
        tile //= 2
    grid = (N // tile,)

    def row(i):
        return (i, 0)

    spec_t = pl.BlockSpec((tile, T), row)
    spec_1 = pl.BlockSpec((tile, 1), row)
    spec_v = pl.BlockSpec((tile, V), row)
    state_specs = (spec_1, spec_t, spec_t, spec_t, spec_t, spec_t,
                   spec_1, spec_1)
    state_shapes = tuple(jax.ShapeDtypeStruct(x.shape, x.dtype)
                         for x in state)
    out = pl.pallas_call(
        functools.partial(_kernel, T=T, V=V, max_pes=max_pes,
                          epoch_bound=epoch_limit),
        grid=grid,
        in_specs=[spec_t, spec_t, spec_t, spec_t, spec_t, spec_1,
                  spec_v, spec_v, spec_1, spec_v, spec_v, spec_1, spec_t,
                  spec_1, spec_t, spec_t, spec_t, spec_t, spec_1, spec_1],
        out_specs=state_specs,
        out_shape=state_shapes,
        interpret=interpret,
    )(task_len, task_vm, state[5], is_red, valid, shuffle, vm_mips, vm_pes,
      sched_policy, vm_start, vm_stop, spinup, prio,
      state[0], state[1], state[2], state[3], state[4], state[6], state[7])
    return out
