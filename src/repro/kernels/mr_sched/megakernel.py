"""``mr_epoch``: the fused epoch megakernel (adaptive-schedule backend).

One ``pl.pallas_call`` advances a *tile* of scenario lanes through their
whole event history: rates evaluation, the one-hot reductions, fluid-state
advance, the next-event min, completions, shuffle release, and space-shared
admission are fused into a single kernel body whose per-VM/per-task state
(remaining MI, readiness, running masks, per-VM occupancy) stays resident
in VMEM across epochs — the XLA engine (``repro.core.engine``) round-trips
that state through HBM once per epoch.

Two structural upgrades over the PR-1 ``mr_schedule`` kernel:

* **Tile-level early exit** — the epoch loop is a ``lax.while_loop`` gated
  on ``any(lane unfinished)`` (plus the ``2T + 2`` safety bound), so a tile
  stops at its own realized epoch count instead of always burning the
  worst-case bound; the per-lane realized counts come back as ``n_epochs``.
* **Per-VM admission scan** — the space-shared (ready, index) admission
  rank was a ``T×T`` higher-priority matrix (O(T²) VMEM + flops per
  epoch); here admission extracts per-VM minima ``max_pes`` times
  (O(max_pes·T·V)), admitting exactly the tasks whose per-VM rank is below
  the free PE count — the ROADMAP "fold the T×T rank into a per-VM scan"
  item.

Every float-bearing step reuses the engine's exact op sequence (the one-hot
contractions are 0/1-weighted sums, so any accumulation order is exact),
which makes the kernel's schedule **bit-identical** to
``engine.simulate_arrays`` — pinned by ``tests/test_adaptive_schedule.py``,
not just approximately close.  Scope: single-job scenarios (J = 1 — what
``sweep.encode_cell`` emits), arbitrary M/R/VM mix, both sched policies per
lane (``sched_policy`` is lane data, so one tile may mix policies).

Storage subsystem (DESIGN.md §7): LOCALITY binding and the remote-fetch
penalty reach this kernel entirely through lane data — ``task_vm`` carries
the replica-aware binding and ``ready0`` carries the per-task fetch delay
(``storage.remote_fetch_delay``, applied in ``ops._derived_inputs`` with
the engine's exact f32 op sequence).  Off-replica map tasks therefore
enter the per-VM ``(ready, index)`` admission scan at their delayed ready
times and lose admission priority to data-local peers, with no kernel-side
branching — one lowering serves all five policy axes' values mixed per
lane, bit-identical to the engine (``tests/test_storage.py``).

Elasticity (DESIGN.md §8): VM lease windows are lane data too —
``vm_start``/``vm_stop`` (+ the ``spinup`` boot delay) gate admission
per VM: a pending task's eligible time is ``max(ready, lease open)``
(lease-start edges therefore join the next-event min through the
arrival candidates) and candidates whose event time lands at/past the
lease close are stranded, never defining an event again.  The
space-shared admission scan extracts per-VM minima of the lexicographic
``(priority desc, eligible time, index)`` key — the per-task
``prio`` input generalizes the classic ``(ready, index)`` rank; zero
priorities and the static-fleet window ``[0, 1e30)`` reproduce the
pre-elastic schedule bit for bit (``tests/test_elasticity.py``).

Closed-loop control (DESIGN.md §10): a static ``control`` flag threads
the engine's control dataflow through the same kernel — open-loop
lowerings carry **zero** control code.  When on, fifteen extra lane-data
refs (failure/restore instants, reserve flags, policy id + thresholds,
the precomputed failover binding ``task_vm2`` and its re-replication
fetch, plus the §11 graceful-degradation block: per-task deadlines,
deadline policy id + slack, preemption knobs) and seven extra carry
leaves (``hit``, realized ``vm_open``/``vm_close``, ``n_scale``,
``shed``, ``n_evict``, ``work_lost``) join the loop; every epoch runs
the control hook at its opening clock, switches each task's one-hot row
between its two binding slots on ``hit``, joins pending failure instants
into the next-event min, kills + re-dispatches tasks on fired VMs, and
gates admission around each VM's ``[fail, restore)`` down window — the
exact engine op sequence, so seeded-failure and autoscale grids stay
bit-identical to ``engine.simulate_arrays`` (``tests/test_control.py``).

Graceful degradation under overload (DESIGN.md §11,
``tests/test_deadlines.py``): SHED lanes drop pending tasks whose
earliest possible finish already exceeds their deadline (evaluated with
the shared ``control.earliest_finish`` f32 op sequence at both the
arrival-candidate and admission instants), BOOST lanes wrap an urgency
tier around the space-shared admission key, and preemption lets an
eligible higher-raw-priority task evict the weakest still-evictable
running task on its full VM (the §10 failure-kill op sequence driven by
a policy mask).  The T×T relations the engine uses lower here as per-VM
extrema through the same one-hot masks the admission scan uses.  The
per-lane epoch bound is additive data (``engine._lane_bound``), so
degenerate lanes keep the exact open-loop ``2T + 2`` realized counts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# THE shared f32 deadline-pressure op sequence (DESIGN.md §11) — imported
# so the kernel's SHED/BOOST predicates cannot drift from the oracle's
from repro.core.control import earliest_finish
# trace capacity math (DESIGN.md §12) shared with the engine recorder
from repro.core.telemetry import timeseries_capacity

_BIG = 1e30
_TIME_EPS = 1e-6


def _kernel(*refs, T: int, V: int, max_pes: int, epoch_bound: int,
            control: bool, trace: bool):
    (task_len_ref, task_vm_ref, ready0_ref, is_red_ref, valid_ref,
     shuffle_ref, vm_mips_ref, vm_pes_ref, sched_ref,
     vm_start_ref, vm_stop_ref, spinup_ref, prio_ref) = refs[:13]
    n_data = 13
    if control:
        (vm_valid_ref, vm_fail_ref, vm_restore_ref, vm_auto_ref,
         ctl_policy_ref, ctl_queue_ref, ctl_busy_ref, redispatch_ref,
         task_vm2_ref, refetch_ref, task_deadline_ref, dl_policy_ref,
         dl_slack_ref, preempt_ref, resume_ref) = refs[13:28]
        n_data = 28
    elif trace:
        # open-loop traces need vm_valid for the open-VM observable (the
        # control lowering already carries it as lane data)
        vm_valid_ref = refs[13]
        n_data = 14
    n_state = (14 if control else 7) + (1 if trace else 0)
    state_in = refs[n_data:n_data + n_state]
    out_refs = refs[n_data + n_state:]

    task_len = task_len_ref[...]                 # (tile, T) f32
    task_vm = task_vm_ref[...]                   # (tile, T) i32
    is_red = is_red_ref[...] != 0                # (tile, T)
    valid = valid_ref[...] != 0
    shuffle = shuffle_ref[...]                   # (tile, 1) f32
    vm_mips = vm_mips_ref[...]                   # (tile, V)
    vm_pes = vm_pes_ref[...]                     # (tile, V)
    is_space = sched_ref[...] != 0               # (tile, 1) policy gate
    vm_start = vm_start_ref[...]                 # (tile, V) lease open
    vm_stop = vm_stop_ref[...]                   # (tile, V) lease close
    spinup = spinup_ref[...]                     # (tile, 1) boot delay
    prio = prio_ref[...]                         # (tile, T) admission prio

    vm_onehot = (task_vm[..., None]
                 == jax.lax.broadcasted_iota(jnp.int32,
                                             (1, 1, V), 2))  # (tile,T,V)
    onehot_b = vm_onehot
    vm_onehot = vm_onehot.astype(jnp.float32)
    idx = jax.lax.broadcasted_iota(jnp.int32, (1, T), 1)     # (1, T)
    vidx = jax.lax.broadcasted_iota(jnp.int32, (1, V), 1)    # (1, V)
    task_pes0 = jnp.einsum("stv,sv->st", vm_onehot, vm_pes)

    if control:
        vm_valid = vm_valid_ref[...] != 0        # (tile, V)
        vm_fail = vm_fail_ref[...]               # (tile, V) f32
        vm_restore = vm_restore_ref[...]         # (tile, V) f32
        vm_auto = vm_auto_ref[...] != 0          # (tile, V) reserve flag
        pol_on = ctl_policy_ref[...][:, 0] == 1  # (tile,) AUTOSCALE
        ctl_queue = ctl_queue_ref[...][:, 0]     # (tile,)
        ctl_busy = ctl_busy_ref[...][:, 0]       # (tile,)
        redispatch = redispatch_ref[...]         # (tile, 1)
        task_vm2 = task_vm2_ref[...]             # (tile, T) failover slot
        refetch = refetch_ref[...]               # (tile, T) re-repl fetch
        task_deadline = task_deadline_ref[...]   # (tile, T) f32 (_BIG=none)
        dl_shed = dl_policy_ref[...] == 1        # (tile, 1) SHED
        dl_boost = dl_policy_ref[...] == 2       # (tile, 1) BOOST
        dl_slack = dl_slack_ref[...]             # (tile, 1) f32
        pre_onl = (preempt_ref[...] != 0) & is_space   # (tile, 1)
        res_onl = resume_ref[...] != 0           # (tile, 1)
        onehot2_b = (task_vm2[..., None]
                     == jax.lax.broadcasted_iota(jnp.int32, (1, 1, V), 2))
        # per-lane epoch bound (engine._lane_bound, additive): each
        # robustness mechanism's term is paid only by lanes whose encoded
        # data can trigger it — degenerate lanes keep the exact open-loop
        # bound (and stranded lanes' realized n_epochs stay bit-identical)
        any_fail = jnp.any(vm_valid & (vm_fail < _BIG / 2), axis=1)
        any_shed = dl_shed[:, 0] & jnp.any(
            valid & (task_deadline < _BIG / 2), axis=1)
        lane_bound = (
            jnp.int32(2 * T + 2)
            + jnp.where(any_fail, jnp.int32(2 * T + V), jnp.int32(0))
            + jnp.where(any_shed, jnp.int32(T + 1), jnp.int32(0))
            + jnp.where(preempt_ref[...][:, 0] != 0,
                        jnp.int32(2 * T), jnp.int32(0)))

    # Lease admission windows (DESIGN.md §8), gathered per task with the
    # exact f32 ops the engine's _epoch_setup uses (one-hot gathers are
    # exact; vm_stop carries the _BIG stand-in, never inf — 0 * inf would
    # NaN these einsums).  Static fleets make every use below a bitwise
    # identity with the pre-elastic kernel.  Under control these are
    # re-derived every epoch from the carried realized windows instead.
    avail_t0 = jnp.einsum("stv,sv->st", vm_onehot, vm_start + spinup)
    close_t0 = jnp.einsum("stv,sv->st", vm_onehot, vm_stop)

    # carry state arrives as refs (the wrapper builds the canonical
    # initial state with the exact constants this kernel used to
    # initialize in VMEM — compacted/chunked drivers resume mid-history
    # by feeding a previous call's state back in)
    state = (
        state_in[0][...][:, 0],                          # time
        state_in[1][...],                                # rem
        state_in[2][...] != 0,                           # running
        state_in[3][...],                                # start
        state_in[4][...],                                # finish
        ready0_ref[...],                                 # ready
        state_in[5][...][:, 0],                          # maps_left
        state_in[6][...][:, 0],                          # lane epochs
        jnp.int32(0),                                    # epochs this call
    )
    if control:
        state = state + (
            state_in[7][...] != 0,                       # hit
            state_in[8][...],                            # vm_open
            state_in[9][...],                            # vm_close
            state_in[10][...][:, 0],                     # n_scale
            state_in[11][...] != 0,                      # shed
            state_in[12][...],                           # n_evict
            state_in[13][...][:, 0],                     # work_lost
        )
    if trace:
        vm_valid_t = vm_valid_ref[...] != 0              # (tile, V)
        state = state + (state_in[-1][...],)             # ts rows (tile,C*8)

    def lanes_active(finish, lane_ep, shed=None):
        unfin = valid & (finish >= _BIG / 2)
        if control:
            # a shed task never finishes by design — it must not keep
            # its lane alive (shedding *terminates* backlogs)
            unfin &= ~shed
        act = jnp.any(unfin, axis=1)                     # (tile,)
        if control:
            act &= lane_ep < lane_bound
        return act

    def cond(st):
        act = lanes_active(st[4], st[7], st[13] if control else None)
        return jnp.any(act) & (st[8] < epoch_bound)

    def epoch(st):
        (time, rem, running, start, finish, ready, maps_left, lane_ep,
         n) = st[:9]
        active = lanes_active(finish, lane_ep,
                              st[13] if control else None)
        runf = running.astype(jnp.float32)
        if trace and not control:
            # pre-update carry snapshot: the engine's open-loop recorder
            # reads the observables off ``c.*`` before the epoch mutates
            t0, start0, finish0, ready0c = time, start, finish, ready

        # --- binding-slot switch + control hook (clock = time) ------------
        if control:
            (hit, vm_open, vm_close, n_scale, shed0, n_evict0,
             work_lost) = st[9:16]
            cur_oh_b = jnp.where(hit[..., None], onehot2_b, onehot_b)
            cur_oh = cur_oh_b.astype(jnp.float32)
        else:
            cur_oh_b, cur_oh = onehot_b, vm_onehot

        def to_task(per_vm):
            """Gather a per-VM quantity to each task's current VM
            (exact: one-hot)."""
            return jnp.einsum("stv,sv->st", cur_oh, per_vm)

        def per_vm_sum(per_task):
            return jnp.einsum("stv,st->sv", cur_oh, per_task)

        if control:
            task_pes = to_task(vm_pes)
            f_t = to_task(vm_fail)
            r_t = to_task(vm_restore)
            mips_t = to_task(vm_mips)
            # shed tasks are out of the system: refused backlog neither
            # holds a reserve open nor counts toward scaling pressure
            unfinished = valid & (finish >= _BIG / 2) & ~shed0
            # queue depth over *raw* ready times: tasks bound to unopened
            # reserves must count toward the backlog or the rule that
            # would open their VM could never trigger
            qdepth = jnp.sum((unfinished & (start >= _BIG / 2)
                              & (ready <= time[:, None]))
                             .astype(jnp.float32), axis=1)
            busy_v = per_vm_sum(runf) > 0.5
            open_v = vm_valid & (vm_open + spinup <= time[:, None]) \
                & (time[:, None] < vm_close)
            n_open = jnp.sum(open_v.astype(jnp.float32), axis=1)
            busy_frac = (jnp.sum((open_v & busy_v).astype(jnp.float32),
                                 axis=1) / jnp.maximum(n_open, 1.0))
            trigger = pol_on & (qdepth > ctl_queue) & (busy_frac >= ctl_busy)
            reserve = vm_valid & vm_auto
            unopened = reserve & (vm_open >= _BIG / 2)
            # lowest-index unopened reserve: the min of the masked index
            # key IS the argmin index (keys are the indices themselves)
            first = jnp.min(jnp.where(unopened, vidx, jnp.int32(V + 1)),
                            axis=1)
            open_mask = trigger[:, None] & unopened & (vidx == first[:, None])
            bound_unfin = per_vm_sum(unfinished.astype(jnp.float32))
            close_mask = pol_on[:, None] & reserve & (vm_open < _BIG / 2) \
                & (time[:, None] < vm_close) & (bound_unfin < 0.5)
            vm_open = jnp.where(open_mask, time[:, None], vm_open)
            vm_close = jnp.where(close_mask, time[:, None], vm_close)
            n_scale = n_scale + jnp.sum(open_mask.astype(jnp.int32), axis=1) \
                + jnp.sum(close_mask.astype(jnp.int32), axis=1)
            # lease windows re-derived from carry: exactly the hoisted
            # gathers when no reserve ever opens (one-hot sums are exact)
            avail_t = to_task(vm_open + spinup)
            close_t = to_task(vm_close)
        else:
            task_pes = task_pes0
            avail_t, close_t = avail_t0, close_t0

        # single rates evaluation per epoch (space-shared keeps n <= pes,
        # so the min() clamp makes this formula serve both policies)
        n_on_vm = per_vm_sum(runf)
        share = vm_mips * jnp.minimum(1.0, vm_pes
                                      / jnp.maximum(n_on_vm, 1.0))
        r = jnp.where(running, to_task(share), 0.0)
        eta = jnp.where(running,
                        time[:, None] + rem / jnp.maximum(r, 1e-30), _BIG)
        not_started = valid & ~running & (finish >= _BIG / 2) \
            & (start >= _BIG / 2)
        # lease-aware eligibility: admissible from max(ready, lease open)
        # — start edges join the next-event min through the candidates —
        # and only while the event time lands before the lease close
        # (candidates at/past it are stranded and define no event).
        elig = jnp.maximum(ready, avail_t)
        if control:
            # failure-window gating: any admission instant landing inside
            # the current VM's [fail, restore) down window slides to the
            # restore edge — which is how restore instants join the event
            # min (no separate restore event stream is needed)
            def gate(x):
                return jnp.where((x >= f_t) & (x < r_t), r_t, x)

            elig = gate(elig)
            cand_t = gate(jnp.maximum(elig, time[:, None]))
            # SHED admission control at the arrival-candidate instant
            # (DESIGN.md §11): a pending task whose earliest possible
            # finish already exceeds its deadline stops defining arrival
            # events.  The close_t gate keeps stranded tasks out — the
            # oracle never re-examines an arrival it could not schedule.
            # Pressure is evaluated on the *carried* rem (engine: c.rem).
            rem_c = rem
            evaluable = not_started & (elig < _BIG / 2)
            efin_c = earliest_finish(cand_t, rem_c, mips_t, xp=jnp)
            shed_c = shed0 | (dl_shed & evaluable & (cand_t < close_t)
                              & (efin_c > task_deadline))
        else:
            cand_t = jnp.maximum(elig, time[:, None])
        # space-shared: pending tasks only define arrival events while a
        # PE slot is free; otherwise a completion epoch admits them.
        has_slot = (task_pes - to_task(n_on_vm)) > 0.5
        if control:
            # preemption arrival gate (DESIGN.md §11): a pending task
            # strictly beating the weakest still-evictable running task
            # on its VM defines an arrival event even with no free slot —
            # per-VM min of evictable raw priorities instead of the
            # engine's T×T prey relation (same set: beats some evictable
            # iff beats the weakest)
            evictable = running & (n_evict0 < jnp.int32(2))
            ev_m = jnp.where(evictable, prio, _BIG)
            min_ev_v = jnp.min(
                jnp.where(cur_oh_b, ev_m[..., None], _BIG), axis=1)
            can_pre = pre_onl & (prio > to_task(min_ev_v))
            arr = jnp.where(not_started & ~shed_c
                            & (~is_space | has_slot | can_pre)
                            & (cand_t < close_t), cand_t, _BIG)
        else:
            arr = jnp.where(not_started & (~is_space | has_slot)
                            & (cand_t < close_t), cand_t, _BIG)
        t_next = jnp.minimum(jnp.min(eta, axis=1), jnp.min(arr, axis=1))
        if control:
            # pending failure instants of valid VMs are calendar events too
            fail_ev = jnp.where(vm_valid & (vm_fail > time[:, None]),
                                vm_fail, _BIG)
            t_next = jnp.minimum(t_next, jnp.min(fail_ev, axis=1))
        live = t_next < _BIG / 2
        tie = _TIME_EPS * jnp.maximum(t_next, 1.0)

        # advance fluid state (engine op order: guard with running, not dt)
        rem = jnp.where(running, rem - (t_next[:, None] - time[:, None]) * r,
                        rem)

        # completions (all tied events fire in this one epoch)
        done_now = live[:, None] & running & (eta <= (t_next + tie)[:, None])
        finish = jnp.where(done_now, t_next[:, None], finish)
        running = running & ~done_now
        rem = jnp.where(done_now, 0.0, rem)

        # job map-phase completion -> release reduces after shuffle delay
        maps_done_now = jnp.sum((done_now & ~is_red).astype(jnp.int32),
                                axis=1)
        maps_left_new = maps_left - maps_done_now
        phase_done = (maps_left_new == 0) & (maps_left > 0)
        ready = jnp.where(is_red & phase_done[:, None],
                          (t_next + shuffle[:, 0])[:, None], ready)

        # failure kills — after completions (a task finishing exactly at
        # the failure instant completes: the oracle's completions-first
        # tie order), before admissions
        start_base = start
        if control:
            fired = live[:, None] & (f_t > time[:, None]) \
                & (f_t <= t_next[:, None])
            # shed tasks are out of the system — a failure must not
            # re-dispatch (or failover-rebind) work already refused
            affected = valid & fired & (finish >= _BIG / 2) & ~shed_c
            first_hit = affected & ~hit
            lost_fail = jnp.where(affected, task_len - rem, 0.0)
            rem = jnp.where(affected, task_len, rem)
            running = running & ~affected
            start_base = jnp.where(affected, jnp.float32(_BIG), start_base)
            # re-dispatch: detection/re-queue latency from the failure
            # instant; the first hit moves to the failover slot and pays
            # the re-replication fetch, a second hit restarts in place
            ready = jnp.where(affected,
                              jnp.maximum(ready, f_t + redispatch), ready)
            ready = jnp.where(first_hit, ready + refetch, ready)
            hit = hit | first_hit

        # arrivals: time-shared starts every admissible task; space-shared
        # admits the (priority desc, eligible time, index)-first waiting
        # tasks into the PE slots left free after this epoch's
        # completions.  Instead of ranking through a T×T priority matrix,
        # extract per-VM lexicographic minima max_pes times: the task
        # picked at scan step s has per-VM rank s, and is admitted iff
        # s < free slots on its VM — the same set the engine's rank
        # formulation admits.  The admission key is (prio, elig, idx);
        # all-zero priorities collapse the first stage to a no-op
        # bitwise, and a static fleet makes elig == ready.
        eligible = live[:, None] & not_started \
            & (elig <= (t_next + tie)[:, None]) \
            & (t_next[:, None] < close_t)
        if control:
            # never admit onto a VM that is down at (or fails exactly at)
            # this epoch's instant — the killed set was computed above
            # and a same-instant admission would dodge it
            eligible &= ~((t_next[:, None] >= f_t)
                          & (t_next[:, None] < r_t))
            # SHED at the admission instant (the oracle's pop-time
            # check): queue wait grows pressure, so a task admissible
            # when it arrived may be unmeetable by the time a slot frees
            efin_t = earliest_finish(t_next[:, None], rem_c, mips_t,
                                     xp=jnp)
            shed_t = shed_c | (dl_shed & evaluable
                               & (t_next[:, None] < close_t)
                               & (efin_t > task_deadline))
            eligible &= ~shed_t
            # Priority preemption (DESIGN.md §11): on each full
            # space-shared VM the single weakest still-evictable running
            # task (lowest raw priority, latest index) loses its PE when
            # an eligible pending task strictly outranks it; further
            # victims fall in the repeated same-instant epochs the
            # arrival gate keeps scheduling.  The engine's T×T
            # beats/weaker relations lower as per-VM extrema; the kill
            # reuses the §10 failure op sequence.
            done_f = done_now.astype(jnp.float32)
            vic_cand = pre_onl & running & (n_evict0 < jnp.int32(2))
            full_t = (task_pes - to_task(n_on_vm - per_vm_sum(done_f))) \
                <= 0.5
            el_m = jnp.where(eligible, prio, -_BIG)
            max_el_v = jnp.max(
                jnp.where(cur_oh_b, el_m[..., None], -_BIG), axis=1)
            cand_e = vic_cand & full_t & (to_task(max_el_v) > prio)
            low_m = jnp.where(cand_e, prio, _BIG)
            min_low_v = jnp.min(
                jnp.where(cur_oh_b, low_m[..., None], _BIG), axis=1)
            low = cand_e & (prio == to_task(min_low_v))
            idxe_m = jnp.where(low, idx, -1)
            max_idx_v = jnp.max(
                jnp.where(cur_oh_b, idxe_m[..., None], -1), axis=1)
            evicted = low & (idx == to_task(
                max_idx_v.astype(jnp.float32)).astype(jnp.int32))
            lost_evict = jnp.where(evicted & ~res_onl,
                                   task_len - rem, 0.0)
            e_first = evicted & ~hit
            rem = jnp.where(evicted & ~res_onl, task_len, rem)
            running = running & ~evicted
            start_base = jnp.where(evicted, jnp.float32(_BIG), start_base)
            ready = jnp.where(evicted,
                              jnp.maximum(ready,
                                          t_next[:, None] + redispatch),
                              ready)
            ready = jnp.where(e_first, ready + refetch, ready)
            hit = hit | e_first
            n_evict = n_evict0 + evicted.astype(jnp.int32)
            work_lost = work_lost + jnp.sum(lost_fail, axis=1) \
                + jnp.sum(lost_evict, axis=1)
            free_v = vm_pes - (n_on_vm - per_vm_sum(done_f)
                               - per_vm_sum(evicted.astype(jnp.float32)))
            # BOOST urgency tier (DESIGN.md §11): urgent pending tasks
            # outrank every non-urgent task; ties inside a tier keep the
            # §8 (priority, eligible, index) key.  All-false urgency
            # collapses the extra scan stage to a no-op bitwise.
            urg = (dl_boost & evaluable
                   & (efin_t + dl_slack >= task_deadline)
                   ).astype(jnp.float32)
        else:
            free_v = vm_pes - (n_on_vm
                               - per_vm_sum(done_now.astype(jnp.float32)))
        free_after = to_task(free_v)
        admit = jnp.zeros_like(eligible)
        remaining = eligible
        for s in range(max_pes):
            if control:
                urg_m = jnp.where(remaining, urg, -_BIG)
                max_urg_v = jnp.max(
                    jnp.where(cur_oh_b, urg_m[..., None], -_BIG), axis=1)
                tier = remaining & (urg_m == to_task(max_urg_v))
            else:
                tier = remaining
            prio_m = jnp.where(tier, prio, -_BIG)
            max_prio_v = jnp.max(
                jnp.where(cur_oh_b, prio_m[..., None], -_BIG), axis=1)
            top = tier & (prio_m == to_task(max_prio_v))
            elig_m = jnp.where(top, elig, _BIG)
            min_elig_v = jnp.min(
                jnp.where(cur_oh_b, elig_m[..., None], _BIG), axis=1)
            cand = top & (elig_m == to_task(min_elig_v))
            idx_m = jnp.where(cand, idx, T)
            min_idx_v = jnp.min(
                jnp.where(cur_oh_b, idx_m[..., None], T), axis=1)
            pick = cand & (idx == jnp.einsum(
                "stv,sv->st", cur_oh,
                min_idx_v.astype(jnp.float32)).astype(jnp.int32))
            admit = admit | (pick & (jnp.float32(s) < free_after))
            remaining = remaining & ~pick
        start_now = eligible & (~is_space | admit)
        start = jnp.where(start_now, t_next[:, None], start_base)
        running = running | start_now
        time = jnp.where(live, t_next, time)
        new = (time, rem, running, start, finish, ready, maps_left_new,
               lane_ep + active.astype(jnp.int32), n + 1)
        if control:
            # persist the shed set; reduces of a job with a shed map can
            # never become ready (J = 1 lanes: any shed map dooms the
            # lane's reduces) — marking these orphans ends their lane
            # instead of spinning it to the epoch bound
            map_shed_any = jnp.sum((shed_t & ~is_red).astype(jnp.float32),
                                   axis=1) > 0.5
            shed = shed_t | (valid & is_red & map_shed_any[:, None]
                             & (finish >= _BIG / 2) & ~running)
            new = new + (hit, vm_open, vm_close, n_scale, shed, n_evict,
                         work_lost)
        if trace:
            # --- trace recorder (DESIGN.md §12): observe, never act -------
            # One time-series row per realized epoch, the engine's exact
            # f32 op sequence and one-hot add — bitwise in interpret mode
            # (tests/test_telemetry.py).  The event log stays engine/refsim
            # scope to bound kernel churn.
            actf = active.astype(jnp.float32)
            if control:
                new_shed = shed & ~shed0
                n_fail = jnp.sum(affected.astype(jnp.float32), axis=1)
                n_shed = jnp.sum(new_shed.astype(jnp.float32), axis=1)
                n_ev = jnp.sum(evicted.astype(jnp.float32), axis=1)
                q_d, b_f, n_o = qdepth, busy_frac, n_open
            else:
                # the control hook's observables over the static lease
                # windows, evaluated on the pre-update carry
                unfin_t = valid & (finish0 >= _BIG / 2)
                q_d = jnp.sum((unfin_t & (start0 >= _BIG / 2)
                               & (ready0c <= t0[:, None]))
                              .astype(jnp.float32), axis=1)
                busy_v = per_vm_sum(runf) > 0.5
                open_v = vm_valid_t & (vm_start + spinup <= t0[:, None]) \
                    & (t0[:, None] < vm_stop)
                n_o = jnp.sum(open_v.astype(jnp.float32), axis=1)
                b_f = (jnp.sum((open_v & busy_v).astype(jnp.float32),
                               axis=1) / jnp.maximum(n_o, 1.0))
                n_fail = n_shed = n_ev = jnp.zeros_like(actf)
            ts = st[-1]
            C = ts.shape[1] // 8
            row = (jax.lax.broadcasted_iota(jnp.int32, (1, C), 1)
                   == lane_ep[:, None]).astype(jnp.float32) * actf[:, None]
            vals = jnp.stack([time, q_d, b_f, n_o, actf,
                              n_fail, n_shed, n_ev], axis=-1)
            ts = (ts.reshape(ts.shape[0], C, 8)
                  + row[:, :, None] * vals[:, None, :]
                  ).reshape(ts.shape[0], C * 8)
            new = new + (ts,)
        return new

    st = jax.lax.while_loop(cond, epoch, state)
    out_refs[0][...] = st[0][:, None]
    out_refs[1][...] = st[1]
    out_refs[2][...] = st[2].astype(jnp.int32)
    out_refs[3][...] = st[3]
    out_refs[4][...] = st[4]
    out_refs[5][...] = st[5]
    out_refs[6][...] = st[6][:, None]
    out_refs[7][...] = st[7][:, None]
    if control:
        out_refs[8][...] = st[9].astype(jnp.int32)
        out_refs[9][...] = st[10]
        out_refs[10][...] = st[11]
        out_refs[11][...] = st[12][:, None]
        out_refs[12][...] = st[13].astype(jnp.int32)
        out_refs[13][...] = st[14]
        out_refs[14][...] = st[15][:, None]
    if trace:
        out_refs[-1][...] = st[-1]


def initial_state(task_len, ready0, is_red, valid, vm_start=None,
                  vm_stop=None, vm_auto=None, trace_capacity=None):
    """The canonical t=0 carry state, built with the exact constants the
    kernel used to initialize in VMEM (so feeding it through the state
    inputs is a bitwise no-op vs the pre-carry kernel).  Layout — every
    leaf 2-D for the BlockSpecs: ``(time (N,1) f32, rem (N,T) f32,
    running (N,T) i32, start (N,T) f32, finish (N,T) f32, ready (N,T)
    f32, maps_left (N,1) i32, n_epochs (N,1) i32)``.

    Passing ``vm_auto`` (with ``vm_start``/``vm_stop``) appends the seven
    control leaves (DESIGN.md §10–11): ``hit (N,T) i32, vm_open (N,V)
    f32, vm_close (N,V) f32, n_scale (N,1) i32, shed (N,T) i32, n_evict
    (N,T) i32, work_lost (N,1) f32`` — reserve VMs start with no realized
    lease (``vm_open = _BIG``) until the control rule opens one, exactly
    the engine's ``_epoch_setup`` initialization.

    ``trace_capacity`` (DESIGN.md §12) appends the per-epoch time-series
    leaf ``ts (N, C*8) f32`` at the end — ``C`` rows of the 8-column
    ``telemetry.TS_COLUMNS`` layout, flattened 2-D for the BlockSpecs."""
    N, T = task_len.shape
    base = (jnp.zeros((N, 1), jnp.float32),
            task_len,
            jnp.zeros((N, T), jnp.int32),
            jnp.full((N, T), _BIG, jnp.float32),
            jnp.full((N, T), _BIG, jnp.float32),
            ready0,
            jnp.sum(((valid != 0) & ~(is_red != 0)).astype(jnp.int32),
                    axis=1, keepdims=True),
            jnp.zeros((N, 1), jnp.int32))
    if vm_auto is not None:
        base = base + (
            jnp.zeros((N, T), jnp.int32),
            jnp.where(vm_auto != 0, jnp.float32(_BIG),
                      vm_start.astype(jnp.float32)),
            vm_stop.astype(jnp.float32),
            jnp.zeros((N, 1), jnp.int32),
            jnp.zeros((N, T), jnp.int32),
            jnp.zeros((N, T), jnp.int32),
            jnp.zeros((N, 1), jnp.float32))
    if trace_capacity is not None:
        base = base + (jnp.zeros((N, int(trace_capacity) * 8),
                                 jnp.float32),)
    return base


def _mr_epoch_impl(task_len, task_vm, ready0, is_red, valid, shuffle,
                   vm_mips, vm_pes, sched_policy=None, vm_start=None,
                   vm_stop=None, spinup=None, prio=None, vm_valid=None,
                   vm_fail=None, vm_restore=None, vm_auto=None,
                   ctl_policy=None, ctl_queue=None, ctl_busy=None,
                   redispatch=None, task_vm2=None, refetch=None,
                   task_deadline=None, dl_policy=None, dl_slack=None,
                   preempt=None, preempt_resume=None, state=None,
                   *, tile: int = 64, max_pes: int = 8,
                   interpret: bool = True, epoch_limit: int | None = None,
                   control: bool = False, trace: bool = False,
                   block_lanes: int | None = None):
    """All args lead with the scenario dim N (padded to a tile multiple).

    task_len/ready0: (N,T) f32; task_vm: (N,T) i32; is_red/valid: (N,T) i32;
    shuffle: (N,1) f32; vm_mips/vm_pes: (N,V) f32; sched_policy: (N,1) i32
    (0 time-shared | 1 space-shared; defaults to all time-shared).
    Elasticity lane data (DESIGN.md §8): vm_start/vm_stop: (N,V) f32 lease
    windows (stop carries the 1e30 +inf stand-in, never ``inf``); spinup:
    (N,1) f32; prio: (N,T) f32 space-shared admission priorities — the
    defaults (static fleet, zero priorities) reproduce the pre-elastic
    schedule bit for bit.

    Control lane data (DESIGN.md §10, required iff the static ``control``
    flag is on): vm_valid/vm_auto: (N,V) i32; vm_fail/vm_restore: (N,V)
    f32 seeded failure/restore instants (_BIG = never); ctl_policy: (N,1)
    i32 policy id; ctl_queue/ctl_busy/redispatch: (N,1) f32 thresholds +
    re-dispatch latency; task_vm2: (N,T) i32 failover binding; refetch:
    (N,T) f32 re-replication fetch toward it.  Graceful degradation
    (DESIGN.md §11, also control-gated): task_deadline: (N,T) f32
    (``_BIG`` = none); dl_policy: (N,1) i32 (NONE/SHED/BOOST);
    dl_slack: (N,1) f32 BOOST window; preempt/preempt_resume: (N,1) i32
    knobs.  ``control=False`` lowerings carry none of this — the
    open-loop kernel is byte-for-byte the pre-control one.

    ``state``/``epoch_limit`` make the kernel *resumable* (DESIGN.md §9):
    ``state`` is a full carry in :func:`initial_state` layout (default —
    the t=0 state; when given, the ``ready0`` argument is superseded by
    ``state[5]``) and ``epoch_limit`` caps how many event epochs this
    call advances (default — the engine bound: ``2T + 2`` open-loop, the
    additive worst case ``7T + V + 3`` under control, i.e. run to
    completion; per-lane realized counts still honor the data-dependent
    ``engine._lane_bound``).  The compacted driver
    (``ops.epoch_schedule_compact``) steps K-epoch chunks over gathered
    active lanes this way.

    ``max_pes`` must be >= the largest per-VM PE count in the batch (it
    bounds the static admission scan); ``tile`` lanes share one early-exit
    epoch loop.  Returns the advanced carry state (same 8-leaf layout;
    15 leaves under control).  ``ready0`` may be ``None`` when ``state``
    is given (the resume path never reads it) — required so the compacted
    driver can donate the state pytree without also holding a live alias
    of its ready leaf in the argument list.

    ``block_lanes`` (static) re-tiles each ``tile``-lane macro tile
    across a second, minor grid dimension of ``tile // block_lanes``
    steps of ``block_lanes`` lanes each.  On real TPU hardware the minor
    grid dimension iterates sequentially per core, so Pallas's pipeline
    emitter double-buffers the HBM→VMEM input streams across consecutive
    blocks — the next block's operands DMA in while the current block's
    event loop runs (the ``flash_attention`` kernel's mechanism).  Lanes
    are independent, so the multi-tile lowering is bitwise-equal to the
    single-tile one (asserted in interpret mode); ``None`` keeps the
    original one-dimensional grid and compiled-shape cache keys.

    ``trace=True`` (static, DESIGN.md §12) appends the per-epoch
    time-series leaf ``ts (N, C*8) f32`` to the carry — one
    ``telemetry.TS_COLUMNS`` row per realized epoch, written by the
    engine recorder's exact one-hot add, so the rows are **bitwise** the
    engine's in interpret mode.  Open-loop traces additionally require
    ``vm_valid`` (the open-VM observable); the event log stays
    engine/refsim scope.
    """
    N, T = task_len.shape
    V = vm_mips.shape[1]
    if sched_policy is None:
        sched_policy = jnp.zeros((N, 1), jnp.int32)
    if vm_start is None:
        vm_start = jnp.zeros((N, V), jnp.float32)
    if vm_stop is None:
        vm_stop = jnp.full((N, V), _BIG, jnp.float32)
    if spinup is None:
        spinup = jnp.zeros((N, 1), jnp.float32)
    if prio is None:
        prio = jnp.zeros((N, T), jnp.float32)
    ctl = (vm_valid, vm_fail, vm_restore, vm_auto, ctl_policy, ctl_queue,
           ctl_busy, redispatch, task_vm2, refetch, task_deadline,
           dl_policy, dl_slack, preempt, preempt_resume)
    if control and any(x is None for x in ctl):
        raise ValueError("mr_epoch: control=True requires all fifteen "
                         "control lane-data arrays (vm_valid .. "
                         "preempt_resume)")
    if trace and vm_valid is None:
        raise ValueError("mr_epoch: trace=True requires vm_valid (the "
                         "open-VM observable needs the real-VM mask)")
    if state is None:
        if ready0 is None:
            raise ValueError("mr_epoch: ready0 is required when no resume "
                             "state is given (it seeds initial_state)")
        state = initial_state(
            task_len, ready0, is_red, valid,
            vm_start=vm_start, vm_stop=vm_stop,
            vm_auto=vm_auto if control else None,
            trace_capacity=(timeseries_capacity(T, V, control)
                            if trace else None))
    if epoch_limit is None:
        epoch_limit = 7 * T + V + 3 if control else 2 * T + 2
    tile = min(tile, N)
    while N % tile:
        tile //= 2
    block = tile
    if block_lanes is not None:
        # minor lane-tile grid dim: pow2 halving mirrors the tile
        # adjustment so any (tile, block_lanes) request lowers cleanly
        block = max(1, min(int(block_lanes), tile))
        while tile % block:
            block //= 2
    nsub = tile // block
    if block_lanes is None:
        grid = (N // tile,)

        def row(i):
            return (i, 0)
    else:
        # (macro tile, sub-block) grid: the minor dim is sequential on
        # TPU, giving Pallas's pipeline emitter the double-buffering
        # window described in the docstring
        grid = (N // tile, nsub)

        def row(i, j):
            return (i * nsub + j, 0)

    spec_t = pl.BlockSpec((block, T), row)
    spec_1 = pl.BlockSpec((block, 1), row)
    spec_v = pl.BlockSpec((block, V), row)
    data = [task_len, task_vm, state[5], is_red, valid, shuffle,
            vm_mips, vm_pes, sched_policy, vm_start, vm_stop, spinup, prio]
    data_specs = [spec_t, spec_t, spec_t, spec_t, spec_t, spec_1,
                  spec_v, spec_v, spec_1, spec_v, spec_v, spec_1, spec_t]
    if control:
        data += [vm_valid, vm_fail, vm_restore, vm_auto, ctl_policy,
                 ctl_queue, ctl_busy, redispatch, task_vm2, refetch,
                 task_deadline, dl_policy, dl_slack, preempt,
                 preempt_resume]
        data_specs += [spec_v, spec_v, spec_v, spec_v, spec_1, spec_1,
                       spec_1, spec_1, spec_t, spec_t, spec_t, spec_1,
                       spec_1, spec_1, spec_1]
    elif trace:
        data += [vm_valid]
        data_specs += [spec_v]
    state_in = [state[0], state[1], state[2], state[3], state[4],
                state[6], state[7]]
    state_in_specs = [spec_1, spec_t, spec_t, spec_t, spec_t, spec_1,
                      spec_1]
    state_specs = (spec_1, spec_t, spec_t, spec_t, spec_t, spec_t,
                   spec_1, spec_1)
    if control:
        state_in += [state[8], state[9], state[10], state[11], state[12],
                     state[13], state[14]]
        state_in_specs += [spec_t, spec_v, spec_v, spec_1, spec_t,
                           spec_t, spec_1]
        state_specs = state_specs + (spec_t, spec_v, spec_v, spec_1,
                                     spec_t, spec_t, spec_1)
    if trace:
        spec_ts = pl.BlockSpec((block, state[-1].shape[1]), row)
        state_in += [state[-1]]
        state_in_specs += [spec_ts]
        state_specs = state_specs + (spec_ts,)
    state_shapes = tuple(jax.ShapeDtypeStruct(x.shape, x.dtype)
                         for x in state)
    out = pl.pallas_call(
        functools.partial(_kernel, T=T, V=V, max_pes=max_pes,
                          epoch_bound=epoch_limit, control=control,
                          trace=trace),
        grid=grid,
        in_specs=data_specs + state_in_specs,
        out_specs=state_specs,
        out_shape=state_shapes,
        interpret=interpret,
    )(*data, *state_in)
    return out


_MR_STATIC = ("tile", "interpret", "max_pes", "epoch_limit", "control",
              "trace", "block_lanes")

mr_epoch = jax.jit(_mr_epoch_impl, static_argnames=_MR_STATIC)
# Resume-path variant that donates the ``state`` carry pytree: the
# output leaves match the input state's shapes exactly, so XLA reuses
# the buffers in place instead of copying the full carry every K-epoch
# chunk.  Callers (``ops.epoch_schedule_compact``) must pass
# ``ready0=None`` and never re-read a donated state object.
mr_epoch_donated = jax.jit(_mr_epoch_impl, static_argnames=_MR_STATIC,
                           donate_argnames="state")
