"""Wrappers: ScenarioArrays (J=1) -> kernel inputs -> schedules.

The derived per-task quantities (task lengths, stage-in readiness,
shuffle delays) are computed in plain jnp — cheap, O(N·T) — and the
event-loop hot path runs in a Pallas kernel:

* :func:`schedule` — the PR-1 ``mr_schedule`` kernel (static ``2T + 2``
  epoch bound, T×T admission rank), returns ``(start, finish)``;
* :func:`epoch_schedule` — the fused ``mr_epoch`` megakernel (tile-level
  early exit + per-VM admission scan), returns a full
  :class:`~repro.core.engine.SimOutput` so the sweep metrics layers can
  consume it directly (``SweepPlan.run(backend="pallas")``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import network, storage
from repro.core.control import failover_targets
from repro.core.engine import (ScenarioArrays, SimOutput, _take_lanes,
                               _put_lanes, _put_lanes_donated)
from repro.core.telemetry import timeseries_capacity
from repro.core.util import pow2_pad, validate_pow2_floor

from .kernel import mr_schedule
from .megakernel import _BIG, initial_state, mr_epoch, mr_epoch_donated


def _derived_inputs(batch: ScenarioArrays):
    """The engine's exact derived-quantity op sequence, J=1 layout."""
    nm = batch.job_n_maps.astype(jnp.float32)[:, 0]        # (N,)
    nr = batch.job_n_reduces.astype(jnp.float32)[:, 0]
    stage_in = network.transfer_delay(batch.kappa_in, batch.job_data[:, 0],
                                      nm, batch.net_bw, batch.net_enabled)
    shuffle = network.transfer_delay(batch.kappa_shuffle,
                                     batch.job_data[:, 0], nm,
                                     batch.net_bw, batch.net_enabled)
    map_len = batch.job_length[:, 0] / nm
    red_len = batch.job_reduce_factor[:, 0] * batch.job_length[:, 0] / nr
    task_len = jnp.where(batch.task_is_reduce, red_len[:, None],
                         map_len[:, None]) * batch.task_mult
    task_len = jnp.where(batch.task_valid, task_len, 0.0)
    # storage remote-fetch delay (DESIGN.md §7): same broadcastable op
    # sequence as engine._epoch_setup, so off-replica map tasks enter the
    # kernel's (ready, index) admission scan at identical f32 ready times
    fetch = storage.remote_fetch_delay(
        batch.block_vm, batch.block_size, batch.task_vm,
        batch.kappa_in[:, None], batch.net_bw[:, None],
        batch.net_enabled[:, None], xp=jnp)
    ready0 = jnp.where(
        batch.task_valid & ~batch.task_is_reduce,
        (batch.job_submit[:, 0] + stage_in)[:, None] + fetch, 1e30)
    return task_len, ready0, shuffle


def _control_derived(batch: ScenarioArrays):
    """The engine's control-mode derived inputs (DESIGN.md §10): each
    task's precomputed failover binding slot and the re-replication fetch
    it pays toward that VM — the exact op sequences ``_epoch_setup`` runs
    per scenario, vmapped over the batch (integer logic + the shared
    broadcastable f32 fetch, so the results are bit-identical)."""
    task_vm2 = jax.vmap(
        lambda tv, vv, va, bv: failover_targets(tv, vv, va, bv, xp=jnp)
    )(batch.task_vm, batch.vm_valid, batch.vm_auto, batch.block_vm)
    refetch = storage.remote_fetch_delay(
        batch.block_vm, batch.block_size, task_vm2,
        batch.kappa_in[:, None], batch.net_bw[:, None],
        batch.net_enabled[:, None], xp=jnp)
    return task_vm2, refetch


def schedule(batch: ScenarioArrays, *, tile: int = 64,
             interpret: bool | None = None):
    """batch: stacked single-job scenarios (leading dim N)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    task_len, ready0, shuffle = _derived_inputs(batch)
    return mr_schedule(
        task_len.astype(jnp.float32), batch.task_vm.astype(jnp.int32),
        ready0.astype(jnp.float32),
        batch.task_is_reduce.astype(jnp.int32),
        batch.task_valid.astype(jnp.int32),
        shuffle.astype(jnp.float32)[:, None],
        batch.vm_mips.astype(jnp.float32),
        batch.vm_pes.astype(jnp.float32),
        batch.sched_policy.astype(jnp.int32)[:, None],
        tile=tile, interpret=interpret)


def _control_lane_data(batch: ScenarioArrays, pad, task_vm2, refetch):
    """The fifteen control lane-data arrays, padded, in ``mr_epoch``'s
    positional order (the §11 graceful-degradation block rides at the
    end so earlier indices — e.g. ``lanes[15]`` = vm_auto in the compact
    driver — stay stable).  Pad lanes zero-fill — their ``vm_valid`` is
    all zero, so they encode no failure events, a NONE policy (both
    control and deadline), no preemption, and the open-loop 2T+2 lane
    bound (zero task_deadline rows are inert: pad lanes hold no valid
    tasks)."""
    return (pad(batch.vm_valid.astype(jnp.int32)),
            pad(batch.vm_fail.astype(jnp.float32)),
            pad(batch.vm_restore.astype(jnp.float32)),
            pad(batch.vm_auto.astype(jnp.int32)),
            pad(batch.control_policy.astype(jnp.int32)[:, None]),
            pad(batch.ctl_queue.astype(jnp.float32)[:, None]),
            pad(batch.ctl_busy.astype(jnp.float32)[:, None]),
            pad(batch.redispatch_delay.astype(jnp.float32)[:, None]),
            pad(task_vm2.astype(jnp.int32)),
            pad(refetch.astype(jnp.float32)),
            pad(batch.task_deadline.astype(jnp.float32)),
            pad(batch.deadline_policy.astype(jnp.int32)[:, None]),
            pad(batch.deadline_slack.astype(jnp.float32)[:, None]),
            pad(batch.preempt.astype(jnp.int32)[:, None]),
            pad(batch.preempt_resume.astype(jnp.int32)[:, None]))


def epoch_schedule(batch: ScenarioArrays, *, tile: int = 64,
                   max_pes: int | None = None,
                   interpret: bool | None = None,
                   control: bool = False, trace: bool = False,
                   block_lanes: int | None = None):
    """Run the fused ``mr_epoch`` megakernel over a stacked J=1 batch.

    ``max_pes`` bounds the static per-VM admission scan and must cover the
    largest PE count in the batch; when ``vm_pes`` is concrete it is
    derived automatically, under a trace it defaults to 8 (pass it
    explicitly for bigger VMs — ``SweepPlan.run`` does).  The batch is
    padded up to a ``tile`` multiple with empty lanes (zero valid tasks,
    so they exit immediately) and trimmed back.

    ``control=True`` (static — host-decided from column presence, see
    ``sweep._CONTROL_PARAMS``) threads the closed-loop lane data through
    the kernel (DESIGN.md §10); degenerate control data reproduces the
    open-loop schedule bit for bit.

    ``trace=True`` (static, DESIGN.md §12) additionally returns the
    per-epoch time-series rows ``(N, C, 8)`` in ``telemetry.TS_COLUMNS``
    layout — bitwise the engine recorder's in interpret mode:
    ``(SimOutput, ts)`` instead of ``SimOutput``.

    ``block_lanes`` re-tiles each macro tile across a minor grid
    dimension (double-buffered HBM→VMEM streaming on real TPUs, bitwise
    in interpret mode — see ``mr_epoch``).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if max_pes is None:
        if isinstance(batch.vm_pes, jax.core.Tracer):
            max_pes = 8
        else:
            max_pes = max(int(np.ceil(float(jnp.max(batch.vm_pes)))), 1)
    task_len, ready0, shuffle = _derived_inputs(batch)
    N = task_len.shape[0]
    n_pad = (-N) % min(tile, max(N, 1))

    def pad(x):
        widths = ((0, n_pad),) + ((0, 0),) * (x.ndim - 1)
        return jnp.pad(x, widths)

    ctl = ()
    if control:
        ctl = _control_lane_data(batch, pad, *_control_derived(batch))
    elif trace:
        # open-loop traces need the real-VM mask — positionally the next
        # mr_epoch arg after prio is vm_valid
        ctl = (pad(batch.vm_valid.astype(jnp.int32)),)
    st = mr_epoch(
        pad(task_len.astype(jnp.float32)),
        pad(batch.task_vm.astype(jnp.int32)),
        pad(ready0.astype(jnp.float32)),
        pad(batch.task_is_reduce.astype(jnp.int32)),
        pad(batch.task_valid.astype(jnp.int32)),
        pad(shuffle.astype(jnp.float32)[:, None]),
        pad(batch.vm_mips.astype(jnp.float32)),
        pad(batch.vm_pes.astype(jnp.float32)),
        pad(batch.sched_policy.astype(jnp.int32)[:, None]),
        # elasticity lane data (DESIGN.md §8) — pad lanes hold no valid
        # tasks, so their zero lease windows never define events
        pad(batch.vm_start.astype(jnp.float32)),
        pad(batch.vm_stop.astype(jnp.float32)),
        pad(batch.spinup_delay.astype(jnp.float32)[:, None]),
        pad(batch.task_prio.astype(jnp.float32)),
        *ctl,
        tile=tile, max_pes=max_pes, interpret=interpret, control=control,
        trace=trace, block_lanes=block_lanes)
    out = _sim_output_of_state(batch, st, N, control=control)
    if trace:
        C = st[-1].shape[1] // 8
        return out, st[-1][:N].reshape(N, C, 8)
    return out


def _sim_output_of_state(batch: ScenarioArrays, st, N: int, *,
                         control: bool = False) -> SimOutput:
    """Trim a (padded) mr_epoch carry state back to ``N`` lanes and shape
    it into the engine's :class:`SimOutput` (exact op sequence —
    including the engine's ``_sim_output`` control fields: open-loop
    states report the encoded scenario as the realized control outputs,
    control states read the seven extra carry leaves; ``task_vm2`` is the
    failover binding control *would* use in either lowering)."""
    start, finish, ready = st[3][:N], st[4][:N], st[5][:N]
    n_epochs = st[7][:N, 0]
    exec_time = jnp.where(batch.task_valid, finish - start, 0.0)
    task_vm2, _ = _control_derived(batch)
    if control:
        hit = st[8][:N] != 0
        vm_open, vm_close = st[9][:N], st[10][:N]
        n_scale = st[11][:N, 0]
        shed = st[12][:N] != 0
        n_evict = st[13][:N]
        work_lost = st[14][:N, 0]
    else:
        hit = jnp.zeros_like(batch.task_valid)
        vm_open = jnp.asarray(batch.vm_start, jnp.float32)
        vm_close = jnp.asarray(batch.vm_stop, jnp.float32)
        n_scale = jnp.zeros(N, jnp.int32)
        shed = jnp.zeros_like(batch.task_valid)
        n_evict = jnp.zeros(batch.task_valid.shape, jnp.int32)
        work_lost = jnp.zeros(N, jnp.float32)
    # mirrors engine._sim_output: shed tasks are out of the makespan
    finish_time = jnp.max(jnp.where(batch.task_valid & ~shed, finish, 0.0),
                          axis=1)
    return SimOutput(start=start, finish=finish, ready=ready,
                     exec_time=exec_time, n_epochs=n_epochs,
                     finish_time=finish_time, hit=hit, task_vm2=task_vm2,
                     vm_open=vm_open, vm_close=vm_close, n_scale=n_scale,
                     shed=shed, n_evict=n_evict, work_lost=work_lost)


@jax.jit
def _state_activity(valid, finish, shed):
    """On-device activity reduction for the Pallas compact loop: the
    still-active lane count (ONE scalar crosses the host boundary per
    round) and the stable active-first permutation (pulled only on
    rounds that compact).  ``shed`` is the control carry's shed leaf or
    ``None`` open-loop (a static pytree difference, like the engine's
    ``control`` flag)."""
    unfin = (valid != 0) & (finish >= _BIG / 2)
    if shed is not None:
        # shed tasks never finish by design — they must not keep their
        # lane in the gather (engine._has_unfinished)
        unfin &= shed == 0
    act = jnp.any(unfin, axis=1)
    return jnp.sum(act, dtype=jnp.int32), jnp.argsort(~act)


def epoch_schedule_compact(batch: ScenarioArrays, *, k="auto",
                           tile: int = 64, max_pes: int | None = None,
                           interpret: bool | None = None, floor: int = 8,
                           cost_model=None, control: bool = False,
                           trace: bool = False, stats: dict | None = None,
                           donate: bool = True,
                           block_lanes: int | None = None):
    """Sparse active-lane compaction over the ``mr_epoch`` megakernel
    (DESIGN.md §9) — the Pallas twin of
    ``engine.simulate_batch_arrays_compact``.

    A host loop steps the batch in ``k``-epoch chunks through the
    *resumable* kernel (``state`` in/out, static ``epoch_limit``).  After
    each chunk the still-active lanes are gathered front-first into a
    pow2-padded compacted batch — re-tiled automatically, since the
    compacted count is a power of two the kernel's tile divisibility
    reduction never degrades — and the advanced carry scatters back into
    the dense lane store.  Dropped lanes are finished, and the epoch body
    is idempotent for finished lanes, so the result is **bitwise
    identical** to the dense path, per-lane ``n_epochs`` included.

    ``k="auto"`` derives the chunk size from the measured cost model.
    Returns ``(SimOutput, realized_epochs)`` with realized the batch max
    of the per-lane counts (the same reduction the dense pallas sweep
    path exposes).

    ``control=True`` composes the closed loop with compaction
    (DESIGN.md §10): killed-then-restored lanes stay in the host-side
    active set (their tasks are unfinished), so a failure that re-opens
    work after a lane looked nearly done simply keeps the lane in the
    gather — the epoch body stays idempotent for finished lanes and the
    result stays bitwise identical to the dense control path.  The host
    bound widens to the control epoch bound; the kernel's per-lane bound
    keeps degenerate lanes' realized counts at the open-loop ``2T + 2``.

    ``trace=True`` (DESIGN.md §12): the time-series leaf rides the
    gather/scatter like any other carry leaf, so the rows stay bitwise
    the dense traced path's; returns ``(SimOutput, realized, ts)``.

    ``stats`` (a dict, mutated in place) collects host-loop counters
    with the engine compact driver's keys — ``syncs`` (full permutation
    device→host pulls, paid only on rounds that actually compact),
    ``scalar_syncs`` (the per-round still-active scalar pulls),
    ``compactions`` (gather/scatter re-tiles) and ``dispatches`` (kernel
    chunk launches) — feeding the sweep
    :class:`~repro.core.telemetry.RunReport`.

    ``donate=True`` steps chunks through the state-donating kernel jit
    (``mr_epoch_donated``) and the donating store-scatter, so the carry
    updates in place instead of copying every chunk (the engine lean
    loop's store-merge invariant, see
    ``engine._compact_loop_lean``).
    """
    if stats is None:
        stats = {}
    stats.setdefault("syncs", 0)
    stats.setdefault("scalar_syncs", 0)
    stats.setdefault("compactions", 0)
    stats.setdefault("dispatches", 0)
    validate_pow2_floor(floor)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if max_pes is None:
        max_pes = max(int(np.ceil(float(jnp.max(batch.vm_pes)))), 1)
    N, T = batch.task_vm.shape
    V = batch.vm_mips.shape[1]
    # host budget = the batch-wide worst case of the additive per-lane
    # bound (engine.simulate_batch_arrays_compact's exact host rule);
    # per-lane counts stay exact through the kernel's lane_bound
    bound = 2 * T + 2
    if control:
        if bool(np.any(np.asarray(batch.vm_valid)
                       & (np.asarray(batch.vm_fail) < _BIG / 2))):
            bound += 2 * T + V
        if bool(np.any((np.asarray(batch.deadline_policy) == 1)
                       & np.any(np.asarray(batch.task_valid)
                                & (np.asarray(batch.task_deadline)
                                   < _BIG / 2), axis=1))):
            bound += T + 1
        if bool(np.any(np.asarray(batch.preempt) != 0)):
            bound += 2 * T
    if k == "auto":
        from repro.core import costmodel as costmodel_mod
        cm = cost_model or costmodel_mod.default_cost_model()
        k = cm.compact_interval(N, T)
    k = int(k)
    if k < 1:
        raise ValueError(f"epoch_schedule_compact: k must be >= 1, got {k}")
    task_len, ready0, shuffle = _derived_inputs(batch)
    n_pad = (-N) % min(tile, max(N, 1))

    def pad(x):     # pad lanes hold no valid tasks -> inactive from t=0
        widths = ((0, n_pad),) + ((0, 0),) * (x.ndim - 1)
        return jnp.pad(x, widths)

    lanes = (pad(task_len.astype(jnp.float32)),
             pad(batch.task_vm.astype(jnp.int32)),
             pad(batch.task_is_reduce.astype(jnp.int32)),
             pad(batch.task_valid.astype(jnp.int32)),
             pad(shuffle.astype(jnp.float32)[:, None]),
             pad(batch.vm_mips.astype(jnp.float32)),
             pad(batch.vm_pes.astype(jnp.float32)),
             pad(batch.sched_policy.astype(jnp.int32)[:, None]),
             pad(batch.vm_start.astype(jnp.float32)),
             pad(batch.vm_stop.astype(jnp.float32)),
             pad(batch.spinup_delay.astype(jnp.float32)[:, None]),
             pad(batch.task_prio.astype(jnp.float32)))
    if control:
        lanes = lanes + _control_lane_data(batch, pad,
                                           *_control_derived(batch))
    elif trace:
        # vm_valid joins the lane data (and the gather) — positionally
        # the next mr_epoch arg after prio
        lanes = lanes + (pad(batch.vm_valid.astype(jnp.int32)),)
    cur_state = initial_state(lanes[0], pad(ready0.astype(jnp.float32)),
                              lanes[2], lanes[3],
                              vm_start=lanes[8], vm_stop=lanes[9],
                              vm_auto=lanes[15] if control else None,
                              trace_capacity=(timeseries_capacity(
                                  T, V, control) if trace else None))
    # ``store`` is None until the first compaction (before that,
    # ``cur_state`` IS the dense store in original lane order) — the
    # engine lean loop's store-merge invariant, which is what makes
    # donating ``cur_state`` into each chunk safe: no N-sized alias of
    # the donated carry ever exists on the host side.  The freshness
    # flags guard the other aliasing hazard: ``initial_state`` forwards
    # some lane arrays as state leaves unchanged (state[1] IS task_len),
    # and donating a buffer that also rides in the same call's lane
    # operands is an XLA error — so only carries/stores produced by a
    # compute op inside this loop are ever donated.
    store = None
    state_fresh = store_fresh = False
    cur_idx = np.arange(N + n_pad)
    cur_lanes = lanes
    n_act_dev, order_dev = _state_activity(
        cur_lanes[3], cur_state[4], cur_state[12] if control else None)
    n_act = int(n_act_dev)
    stats["scalar_syncs"] += 1
    total = 0
    while total < bound:
        if n_act == 0:
            break
        pad_n = pow2_pad(n_act, cap=len(cur_idx), floor=floor)
        if pad_n < len(cur_idx):
            # active lanes first; the pow2 padding is filled with
            # finished lanes, which step idempotently — the
            # device-computed order crosses the host boundary here and
            # only here
            order = np.asarray(order_dev)[:pad_n]
            stats["syncs"] += 1
            if store is None:
                store, store_fresh = cur_state, state_fresh
            else:
                store = (_put_lanes_donated if donate and store_fresh
                         else _put_lanes)(store, jnp.asarray(cur_idx),
                                          cur_state)
                store_fresh = True
            cur_idx = cur_idx[order]
            take = jnp.asarray(cur_idx)
            cur_lanes = _take_lanes(lanes, take)
            cur_state = _take_lanes(store, take)
            state_fresh = True
            stats["compactions"] += 1
        limit = min(k, bound - total)
        stats["dispatches"] += 1
        step = mr_epoch_donated if donate and state_fresh else mr_epoch
        cur_state = step(*cur_lanes[:2], None, *cur_lanes[2:],
                         state=cur_state, tile=tile, max_pes=max_pes,
                         interpret=interpret, epoch_limit=limit,
                         control=control, trace=trace,
                         block_lanes=block_lanes)
        state_fresh = True
        total += limit
        n_act_dev, order_dev = _state_activity(
            cur_lanes[3], cur_state[4], cur_state[12] if control else None)
        n_act = int(n_act_dev)
        stats["scalar_syncs"] += 1
    if store is None:
        store = cur_state
    else:
        store = (_put_lanes_donated if donate and store_fresh
                 else _put_lanes)(store, jnp.asarray(cur_idx), cur_state)
    out = _sim_output_of_state(batch, store, N, control=control)
    if trace:
        C = store[-1].shape[1] // 8
        return out, jnp.max(out.n_epochs), store[-1][:N].reshape(N, C, 8)
    return out, jnp.max(out.n_epochs)
