"""Wrapper: ScenarioArrays (J=1) -> kernel inputs -> (start, finish).

The derived per-task quantities (task lengths, stage-in readiness,
shuffle delays) are computed in plain jnp — cheap, O(N·T) — and the
event-loop hot path runs in the Pallas kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import network
from repro.core.engine import ScenarioArrays

from .kernel import mr_schedule


def schedule(batch: ScenarioArrays, *, tile: int = 64,
             interpret: bool | None = None):
    """batch: stacked single-job scenarios (leading dim N)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    nm = batch.job_n_maps.astype(jnp.float32)[:, 0]        # (N,)
    nr = batch.job_n_reduces.astype(jnp.float32)[:, 0]
    stage_in = network.transfer_delay(batch.kappa_in, batch.job_data[:, 0],
                                      nm, batch.net_bw, batch.net_enabled)
    shuffle = network.transfer_delay(batch.kappa_shuffle,
                                     batch.job_data[:, 0], nm,
                                     batch.net_bw, batch.net_enabled)
    map_len = batch.job_length[:, 0] / nm
    red_len = batch.job_reduce_factor[:, 0] * batch.job_length[:, 0] / nr
    task_len = jnp.where(batch.task_is_reduce, red_len[:, None],
                         map_len[:, None]) * batch.task_mult
    task_len = jnp.where(batch.task_valid, task_len, 0.0)
    ready0 = jnp.where(batch.task_valid & ~batch.task_is_reduce,
                       (batch.job_submit[:, 0] + stage_in)[:, None], 1e30)
    return mr_schedule(
        task_len.astype(jnp.float32), batch.task_vm.astype(jnp.int32),
        ready0.astype(jnp.float32),
        batch.task_is_reduce.astype(jnp.int32),
        batch.task_valid.astype(jnp.int32),
        shuffle.astype(jnp.float32)[:, None],
        batch.vm_mips.astype(jnp.float32),
        batch.vm_pes.astype(jnp.float32),
        batch.sched_policy.astype(jnp.int32)[:, None],
        tile=tile, interpret=interpret)
