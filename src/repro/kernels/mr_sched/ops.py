"""Wrappers: ScenarioArrays (J=1) -> kernel inputs -> schedules.

The derived per-task quantities (task lengths, stage-in readiness,
shuffle delays) are computed in plain jnp — cheap, O(N·T) — and the
event-loop hot path runs in a Pallas kernel:

* :func:`schedule` — the PR-1 ``mr_schedule`` kernel (static ``2T + 2``
  epoch bound, T×T admission rank), returns ``(start, finish)``;
* :func:`epoch_schedule` — the fused ``mr_epoch`` megakernel (tile-level
  early exit + per-VM admission scan), returns a full
  :class:`~repro.core.engine.SimOutput` so the sweep metrics layers can
  consume it directly (``SweepPlan.run(backend="pallas")``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import network, storage
from repro.core.engine import ScenarioArrays, SimOutput

from .kernel import mr_schedule
from .megakernel import mr_epoch


def _derived_inputs(batch: ScenarioArrays):
    """The engine's exact derived-quantity op sequence, J=1 layout."""
    nm = batch.job_n_maps.astype(jnp.float32)[:, 0]        # (N,)
    nr = batch.job_n_reduces.astype(jnp.float32)[:, 0]
    stage_in = network.transfer_delay(batch.kappa_in, batch.job_data[:, 0],
                                      nm, batch.net_bw, batch.net_enabled)
    shuffle = network.transfer_delay(batch.kappa_shuffle,
                                     batch.job_data[:, 0], nm,
                                     batch.net_bw, batch.net_enabled)
    map_len = batch.job_length[:, 0] / nm
    red_len = batch.job_reduce_factor[:, 0] * batch.job_length[:, 0] / nr
    task_len = jnp.where(batch.task_is_reduce, red_len[:, None],
                         map_len[:, None]) * batch.task_mult
    task_len = jnp.where(batch.task_valid, task_len, 0.0)
    # storage remote-fetch delay (DESIGN.md §7): same broadcastable op
    # sequence as engine._epoch_setup, so off-replica map tasks enter the
    # kernel's (ready, index) admission scan at identical f32 ready times
    fetch = storage.remote_fetch_delay(
        batch.block_vm, batch.block_size, batch.task_vm,
        batch.kappa_in[:, None], batch.net_bw[:, None],
        batch.net_enabled[:, None], xp=jnp)
    ready0 = jnp.where(
        batch.task_valid & ~batch.task_is_reduce,
        (batch.job_submit[:, 0] + stage_in)[:, None] + fetch, 1e30)
    return task_len, ready0, shuffle


def schedule(batch: ScenarioArrays, *, tile: int = 64,
             interpret: bool | None = None):
    """batch: stacked single-job scenarios (leading dim N)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    task_len, ready0, shuffle = _derived_inputs(batch)
    return mr_schedule(
        task_len.astype(jnp.float32), batch.task_vm.astype(jnp.int32),
        ready0.astype(jnp.float32),
        batch.task_is_reduce.astype(jnp.int32),
        batch.task_valid.astype(jnp.int32),
        shuffle.astype(jnp.float32)[:, None],
        batch.vm_mips.astype(jnp.float32),
        batch.vm_pes.astype(jnp.float32),
        batch.sched_policy.astype(jnp.int32)[:, None],
        tile=tile, interpret=interpret)


def epoch_schedule(batch: ScenarioArrays, *, tile: int = 64,
                   max_pes: int | None = None,
                   interpret: bool | None = None) -> SimOutput:
    """Run the fused ``mr_epoch`` megakernel over a stacked J=1 batch.

    ``max_pes`` bounds the static per-VM admission scan and must cover the
    largest PE count in the batch; when ``vm_pes`` is concrete it is
    derived automatically, under a trace it defaults to 8 (pass it
    explicitly for bigger VMs — ``SweepPlan.run`` does).  The batch is
    padded up to a ``tile`` multiple with empty lanes (zero valid tasks,
    so they exit immediately) and trimmed back.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if max_pes is None:
        if isinstance(batch.vm_pes, jax.core.Tracer):
            max_pes = 8
        else:
            max_pes = max(int(np.ceil(float(jnp.max(batch.vm_pes)))), 1)
    task_len, ready0, shuffle = _derived_inputs(batch)
    N = task_len.shape[0]
    n_pad = (-N) % min(tile, max(N, 1))

    def pad(x):
        widths = ((0, n_pad),) + ((0, 0),) * (x.ndim - 1)
        return jnp.pad(x, widths)

    start, finish, ready, n_epochs = mr_epoch(
        pad(task_len.astype(jnp.float32)),
        pad(batch.task_vm.astype(jnp.int32)),
        pad(ready0.astype(jnp.float32)),
        pad(batch.task_is_reduce.astype(jnp.int32)),
        pad(batch.task_valid.astype(jnp.int32)),
        pad(shuffle.astype(jnp.float32)[:, None]),
        pad(batch.vm_mips.astype(jnp.float32)),
        pad(batch.vm_pes.astype(jnp.float32)),
        pad(batch.sched_policy.astype(jnp.int32)[:, None]),
        # elasticity lane data (DESIGN.md §8) — pad lanes hold no valid
        # tasks, so their zero lease windows never define events
        pad(batch.vm_start.astype(jnp.float32)),
        pad(batch.vm_stop.astype(jnp.float32)),
        pad(batch.spinup_delay.astype(jnp.float32)[:, None]),
        pad(batch.task_prio.astype(jnp.float32)),
        tile=tile, max_pes=max_pes, interpret=interpret)
    start, finish, ready, n_epochs = (x[:N] for x in
                                      (start, finish, ready, n_epochs))
    exec_time = jnp.where(batch.task_valid, finish - start, 0.0)
    finish_time = jnp.max(jnp.where(batch.task_valid, finish, 0.0), axis=1)
    return SimOutput(start=start, finish=finish, ready=ready,
                     exec_time=exec_time, n_epochs=n_epochs,
                     finish_time=finish_time)
