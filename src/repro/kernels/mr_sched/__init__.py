from . import megakernel, ops, ref
from .ops import epoch_schedule, schedule
