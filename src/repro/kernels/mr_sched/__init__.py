from . import ops, ref
from .ops import schedule
