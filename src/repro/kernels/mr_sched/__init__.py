from . import megakernel, ops, ref
from .ops import epoch_schedule, epoch_schedule_compact, schedule
