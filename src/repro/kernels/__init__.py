"""Pallas TPU kernels (validated in interpret mode on CPU):

* flash_attention — tiled online-softmax GQA attention (causal/window)
* rwkv6           — VMEM-resident WKV6 recurrence, time-block streamed
* mr_sched        — batched IOTSim event loop (the paper's hot path)
"""
