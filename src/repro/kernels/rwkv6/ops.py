"""Jit'd wrapper: model layout (B,T,H,hs) <-> kernel layout (B,H,T,hs)."""
from __future__ import annotations

import jax

from .kernel import wkv6_bhts


def wkv6(r, k, v, w, u, *, block_t: int = 64,
         interpret: bool | None = None):
    """r/k/v/w: (B, T, H, hs) (model layout); u: (H, hs)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    tr = lambda x: x.transpose(0, 2, 1, 3)
    y = wkv6_bhts(tr(r), tr(k), tr(v), tr(w), u, block_t=block_t,
                  interpret=interpret)
    return y.transpose(0, 2, 1, 3)
