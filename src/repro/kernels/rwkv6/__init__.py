from . import ops, ref
from .ops import wkv6
