"""WKV6 recurrence kernel: VMEM-resident state, time-block streaming.

The RWKV6 recurrence
    S_t = diag(w_t) S_{t-1} + k_tᵀ v_t ,   y_t = r_t (S_{t-1} + diag(u) k_tᵀ v_t)
is O(1)-state but strictly sequential in time.  The jnp reference
(``repro.models.ssm._wkv_scan``) round-trips the (hs × hs) state through
HBM every step; on TPU that recurrence is purely memory-bound.  This
kernel keeps the state in a VMEM scratch tile across the whole sequence
and streams (r, k, v, w) in time blocks:

* grid = (batch, heads, T / block_t), time axis minor (sequential), so the
  state scratch persists across time blocks;
* per block, one VMEM-resident fori over block_t steps of rank-1 updates —
  HBM traffic drops from O(T · hs²) to O(T · hs) (the factor-hs win that
  makes the ``long_500k`` decode shape stream-bound instead of
  state-bound);
* head_size 64 keeps the (64, 64) state on one 8×128 VREG tile boundary.

Adaptation note (DESIGN.md): the official CUDA kernel exploits warp-level
shuffles for the rank-1 update; TPU has no warp analogue — the VMEM
scratch + VPU vector update is the TPU-idiomatic equivalent.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_ref, *, bt: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    u = u_ref[0].astype(jnp.float32)                     # (hs,)

    def step(t, _):
        # size-1 slices, not int indices: interpret-mode discharge rejects
        # raw python ints in pl.load/pl.store index tuples
        idx = (pl.ds(0, 1), pl.ds(0, 1), pl.ds(t, 1), slice(None))
        r_t = pl.load(r_ref, idx)[0, 0, 0].astype(jnp.float32)   # (hs,)
        k_t = pl.load(k_ref, idx)[0, 0, 0].astype(jnp.float32)
        v_t = pl.load(v_ref, idx)[0, 0, 0].astype(jnp.float32)
        w_t = pl.load(w_ref, idx)[0, 0, 0].astype(jnp.float32)
        kv = k_t[:, None] * v_t[None, :]                 # (hs, hs)
        s = s_ref[...]
        y = jnp.sum(r_t[:, None] * (s + u[:, None] * kv), axis=0)
        pl.store(y_ref, idx, y.astype(y_ref.dtype)[None, None, None])
        s_ref[...] = w_t[:, None] * s + kv
        return 0

    jax.lax.fori_loop(0, bt, step, 0)


@functools.partial(jax.jit,
                   static_argnames=("block_t", "interpret"))
def wkv6_bhts(r, k, v, w, u, *, block_t: int = 64, interpret: bool = True):
    """r/k/v/w: (B, H, T, hs); u: (H, hs) -> y: (B, H, T, hs)."""
    B, H, T, hs = r.shape
    bt = min(block_t, T)
    while T % bt:
        bt //= 2
    nt = T // bt
    spec = pl.BlockSpec((1, 1, bt, hs), lambda b, h, ti: (b, h, ti, 0))
    return pl.pallas_call(
        functools.partial(_kernel, bt=bt),
        grid=(B, H, nt),
        in_specs=[spec, spec, spec, spec,
                  pl.BlockSpec((1, hs), lambda b, h, ti: (h, 0))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((B, H, T, hs), r.dtype),
        scratch_shapes=[pltpu.VMEM((hs, hs), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
