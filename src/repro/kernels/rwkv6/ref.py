"""Pure-jnp oracle: sequential WKV6 scan (same math as repro.models.ssm)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, w, u):
    """r/k/v/w: (B, H, T, hs); u: (H, hs) -> y: (B, H, T, hs)."""
    uf = u.astype(jnp.float32)

    def step(S, inp):
        r_t, k_t, v_t, w_t = (i.astype(jnp.float32) for i in inp)
        kv = k_t[..., None] * v_t[..., None, :]           # (B,H,hs,hs)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, S + uf[..., None] * kv)
        S = w_t[..., None] * S + kv
        return S, y

    B, H, T, hs = r.shape
    s0 = jnp.zeros((B, H, hs, hs), jnp.float32)
    xs = tuple(jnp.moveaxis(a, 2, 0) for a in (r, k, v, w))
    _, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 2).astype(r.dtype)
