"""Flash attention TPU kernel: tiled online-softmax with VMEM accumulators.

Grid = (batch, q_heads, num_q_blocks, num_kv_blocks); the kv-block axis is
minor (sequential on a TensorCore), so the (m, l, acc) accumulators live in
VMEM scratch and persist across kv steps — the canonical TPU flash
schedule.  GQA is handled in the k/v index maps (q-head h reads kv-head
h // group); causal and sliding-window masking skip fully-masked kv blocks
(``pl.when`` guards, so skipped blocks cost no MXU work).

Block sizes default to (512, 512): q, k, v, acc tiles at head_dim 128 are
512·128·(2+2+2+4) B ≈ 640 KiB — comfortably inside the ~16 MiB VMEM with
double buffering.  All matmul dims are multiples of the 128-lane MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int | None,
            bq: int, bk: int, nk: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * bq
    k_start = ki * bk
    # block-level visibility: any (t, s) with t >= s (causal) and
    # t - s < window can be live in this tile
    live = True
    if causal:
        live = jnp.asarray(q_start + bq - 1 >= k_start)
    if window is not None:
        live = jnp.logical_and(live,
                               jnp.asarray(k_start + bk - 1
                                           > q_start - window))

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, dh)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, dh)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        ok = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            ok &= qpos >= kpos
        if window is not None:
            ok &= qpos - kpos < window
        s = jnp.where(ok, s, _NEG)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + jnp.sum(p, axis=1)
        m_ref[...] = m_new
        v = v_ref[0, 0].astype(jnp.float32)            # (bk, dh)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0, :, :] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                              "interpret"))
def flash_attention_bhsd(q, k, v, *, causal: bool = True,
                         window: int | None = None, block_q: int = 512,
                         block_k: int = 512, interpret: bool = True):
    """q: (B, Hq, S, Dh); k/v: (B, Hkv, T, Dh) -> (B, Hq, S, Dh)."""
    B, Hq, S, Dh = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    G = Hq // Hkv
    bq, bk = min(block_q, S), min(block_k, T)
    assert S % bq == 0 and T % bk == 0, (S, T, bq, bk)
    nq, nk = S // bq, T // bk
    scale = Dh ** -0.5

    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               window=window, bq=bq, bk=bk, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, Dh), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, Dh),
                         lambda b, h, qi, ki, g=G: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, Dh),
                         lambda b, h, qi, ki, g=G: (b, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, Dh),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, S, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),       # running max m
            pltpu.VMEM((bq,), jnp.float32),       # running denom l
            pltpu.VMEM((bq, Dh), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
