"""Pure-jnp oracle for the flash-attention kernel (materializes S×T)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

_NEG = -1e30


def attention_ref(q, k, v, *, causal: bool = True,
                  window: int | None = None):
    """q: (B, Hq, S, Dh); k/v: (B, Hkv, T, Dh) -> (B, Hq, S, Dh)."""
    B, Hq, S, Dh = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, S, Dh)
    s = jnp.einsum("bhgsd,bhtd->bhgst", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * Dh ** -0.5
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    ok = jnp.ones((S, T), bool)
    if causal:
        ok &= qpos >= kpos
    if window is not None:
        ok &= qpos - kpos < window
    s = jnp.where(ok, s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgst,bhtd->bhgsd", p, v.astype(jnp.float32))
    return out.reshape(B, Hq, S, Dh).astype(q.dtype)
