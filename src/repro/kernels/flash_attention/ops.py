"""Jit'd public wrapper: model-layout (B,S,H,Dh) <-> kernel layout, block
sizing, and the interpret-on-CPU / compiled-on-TPU switch."""
from __future__ import annotations

import jax

from .kernel import flash_attention_bhsd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal: bool = True,
                    window: int | None = None, block_q: int = 512,
                    block_k: int = 512, interpret: bool | None = None):
    """q: (B, S, Hq, Dh); k/v: (B, T, Hkv, Dh) — model layout."""
    if interpret is None:
        interpret = not _on_tpu()
    S, T = q.shape[1], k.shape[1]
    bq = min(block_q, S)
    bk = min(block_k, T)
    # shrink to divisors (assigned shapes are powers of two; this guards
    # odd test shapes)
    while S % bq:
        bq //= 2
    while T % bk:
        bk //= 2
    out = flash_attention_bhsd(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=causal, window=window,
        block_q=max(bq, 1), block_k=max(bk, 1), interpret=interpret)
    return out.transpose(0, 2, 1, 3)
