from . import ops, ref
from .ops import flash_attention
