"""Assigned input shapes and their abstract input specs.

LM transformer shapes are ``seq_len × global_batch``:

* ``train_4k``     — seq 4096,    batch 256 → lowers ``train_step``;
* ``prefill_32k``  — seq 32768,   batch 32  → lowers the prefill forward;
* ``decode_32k``   — seq 32768,   batch 128 → lowers ``serve_step`` (one
  new token against a seq_len KV cache / recurrent state);
* ``long_500k``    — seq 524288,  batch 1   → ``serve_step``; only for
  sub-quadratic archs (SSM / hybrid / sliding-window).

``input_specs`` returns ShapeDtypeStruct stand-ins — weak-type correct,
shardable, no device allocation (the dry-run contract).  Frontend-stubbed
archs ([audio]/[vlm]) get ``(B, S, d_model)`` embedding inputs.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models import ArchConfig
from repro.models.model import init_decode_state


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def cell_supported(cfg: ArchConfig, shape: str) -> tuple[bool, str]:
    """Assignment rules: which (arch × shape) cells are runnable."""
    s = SHAPES[shape]
    if s.kind == "decode" and not cfg.has_decode:
        return False, "encoder-only: no decode step"
    if shape == "long_500k" and not cfg.subquadratic:
        return False, "pure full attention: 500k decode needs sub-quadratic"
    return True, ""


def supported_shapes(cfg: ArchConfig) -> list[str]:
    return [s for s in SHAPES if cell_supported(cfg, s)[0]]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def decode_cache_len(cfg: ArchConfig, seq_len: int) -> int:
    """Sliding-window archs cap the decode cache at the window size."""
    if cfg.window is not None:
        return min(cfg.window, seq_len)
    return seq_len


def input_specs(cfg: ArchConfig, shape: str) -> dict:
    """Abstract inputs for the step function this shape lowers.

    train:   {"batch": {"inputs", "labels"}}
    prefill: {"inputs"}
    decode:  {"tokens", "state", "t"}   (state = KV caches / SSM states)
    """
    s = SHAPES[shape]
    B, S = s.global_batch, s.seq_len
    if cfg.embedding_inputs:
        inputs = _sds((B, S, cfg.d_model), jnp.dtype(cfg.dtype))
    else:
        inputs = _sds((B, S), jnp.int32)
    if s.kind == "train":
        return {"batch": {"inputs": inputs,
                          "labels": _sds((B, S), jnp.int32)}}
    if s.kind == "prefill":
        return {"inputs": inputs}
    # decode: state built abstractly (eval_shape — no allocation)
    cache_len = decode_cache_len(cfg, S)
    state = jax.eval_shape(lambda: init_decode_state(cfg, B, cache_len))
    return {"tokens": _sds((B,), jnp.int32), "state": state,
            "t": _sds((), jnp.int32)}
