"""llama4-scout-17b-a16e — [moe] 16 experts top-1, early fusion (modality
frontend out of scope for the LM shapes). 40 heads does NOT divide the
16-way model axis: the sharding rules fall back to head_dim sharding
(DESIGN.md §5). [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.models import ArchConfig, MoESpec

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab=202048,
    moe=MoESpec(n_experts=16, top_k=1, every=1),
    rope_theta=500_000.0, norm="rmsnorm", act="swiglu",
)
