"""mixtral-8x7b — [moe] 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]"""
from repro.models import ArchConfig, MoESpec

CONFIG = ArchConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=32000,
    window=4096,                          # SWA -> sub-quadratic long ctx
    moe=MoESpec(n_experts=8, top_k=2, every=1),
    rope_theta=1_000_000.0, norm="rmsnorm", act="swiglu",
)
