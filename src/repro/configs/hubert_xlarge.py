"""hubert-xlarge — [audio] encoder-only transformer backbone; the conv
feature-extractor frontend is a STUB (input_specs provides precomputed
frame embeddings). [arXiv:2106.07447; unverified]"""
from repro.models import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge", family="encoder",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16,
    d_ff=5120, vocab=504,
    causal=False, norm="layernorm", act="gelu",
    embedding_inputs=True,
    vocab_pad_to=128,         # 504 -> 512 (model-axis divisibility)
)
