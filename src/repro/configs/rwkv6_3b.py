"""rwkv6-3b (Finch) — [ssm] attention-free, data-dependent decay linear
attention. [arXiv:2404.05892; hf]"""
from repro.models import ArchConfig, RWKVSpec

CONFIG = ArchConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=0, n_kv_heads=0,
    d_ff=8960, vocab=65536,
    rwkv=RWKVSpec(head_size=64, decay_lora=64, mix_lora=32),
    norm="layernorm",
)
