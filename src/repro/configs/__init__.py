"""Architecture registry: the 10 assigned architectures + paper presets.

``get(name)`` / ``--arch <id>`` accepts the hyphenated public ids.
"""
from __future__ import annotations

from repro.models import ArchConfig

from . import (hubert_xlarge, jamba_v0_1_52b, llama4_scout_17b_a16e,
               minitron_8b, mixtral_8x7b, pixtral_12b, rwkv6_3b,
               stablelm_1_6b, stablelm_12b, yi_6b)
from .shapes import (SHAPES, ShapeSpec, cell_supported, decode_cache_len,
                     input_specs, supported_shapes)

_MODULES = (yi_6b, stablelm_1_6b, minitron_8b, stablelm_12b, hubert_xlarge,
            pixtral_12b, jamba_v0_1_52b, mixtral_8x7b,
            llama4_scout_17b_a16e, rwkv6_3b)

REGISTRY: dict[str, ArchConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}


def get(name: str) -> ArchConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]


def arch_names() -> list[str]:
    return list(REGISTRY)


def all_cells() -> list[tuple[str, str]]:
    """Every supported (arch, shape) cell per the assignment rules."""
    return [(a, s) for a in REGISTRY for s in SHAPES
            if cell_supported(REGISTRY[a], s)[0]]


__all__ = ["REGISTRY", "get", "arch_names", "all_cells", "SHAPES",
           "ShapeSpec", "cell_supported", "decode_cache_len", "input_specs",
           "supported_shapes"]
