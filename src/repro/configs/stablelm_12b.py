"""stablelm-12b — dense decoder with GQA. [hf:stabilityai/stablelm-2-12b; hf]"""
from repro.models import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
    d_ff=13824, vocab=100352,
    rope_theta=10_000.0, norm="layernorm", act="swiglu",
)
