"""pixtral-12b — [vlm] mistral-nemo decoder backbone; the pixtral-ViT
frontend is a STUB (input_specs provides precomputed patch embeddings).
[hf:mistralai/Pixtral-12B-2409; unverified]"""
from repro.models import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=131072,
    rope_theta=1_000_000.0, norm="rmsnorm", act="swiglu",
    embedding_inputs=True,
)
