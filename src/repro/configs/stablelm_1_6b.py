"""stablelm-1.6b — dense decoder, MHA (kv == heads).
[hf:stabilityai/stablelm-2-1_6b; unverified]"""
from repro.models import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-1.6b", family="dense",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=5632, vocab=100352,
    rope_theta=10_000.0, norm="layernorm", act="swiglu",
)
