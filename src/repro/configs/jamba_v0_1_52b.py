"""jamba-v0.1-52b — [hybrid] Mamba + attention 1:7 interleave, MoE 16e
top-2 every other layer. [arXiv:2403.19887; hf]"""
from repro.models import ArchConfig, MambaSpec, MoESpec

CONFIG = ArchConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=65536,
    attn_every=8,                         # 1 attention : 7 mamba
    moe=MoESpec(n_experts=16, top_k=2, every=2),
    mamba=MambaSpec(d_state=16, d_conv=4, expand=2),
    rope_theta=10_000.0, norm="rmsnorm", act="swiglu",
)
