"""Scenario configuration for IOTSim-JAX.

Mirrors the paper's independent variables (§5.2): datacentre configuration
(Table I), VM configuration (Table II), VM number, job configuration
(Table III), and MR combination.  A :class:`Scenario` bundles one complete
simulation input; ``ScenarioBatch`` (see ``sweep.py``) stacks many of them
into arrays for the vectorized engine.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from dataclasses import dataclass, field
from typing import Sequence

from .storage import Placement, StorageSpec, as_placement  # noqa: F401
#   (re-exported: Scenario carries a StorageSpec; DESIGN.md §7)
from .elasticity import (ArrivalProcess, ElasticitySpec,  # noqa: F401
                         as_arrival_process)
#   (re-exported: Scenario carries an ElasticitySpec; DESIGN.md §8)
from .control import (ControlPolicy, ControlSpec,  # noqa: F401
                      DeadlinePolicy, as_control_policy,
                      as_deadline_policy)
#   (re-exported: Scenario carries a ControlSpec; DESIGN.md §10)
from .telemetry import TraceSpec  # noqa: F401
#   (re-exported: the trace request rides next to the scenario specs —
#    config is the one-stop import for experiment setup; DESIGN.md §12)


# ---------------------------------------------------------------------------
# Scheduling & binding policies (DESIGN.md §3)
# ---------------------------------------------------------------------------

class SchedPolicy(enum.IntEnum):
    """Per-VM cloudlet scheduling discipline (CloudSim's scheduler family).

    TIME_SHARED  — CloudletSchedulerTimeShared: all assigned cloudlets run
        concurrently; ``n`` 1-PE cloudlets on a VM with ``pes`` PEs at
        ``mips`` each progress at ``mips * min(1, pes / n)`` (fluid
        processor sharing).
    SPACE_SHARED — CloudletSchedulerSpaceShared: at most ``pes`` cloudlets
        run concurrently, each pinned to a dedicated PE at full ``mips``;
        the rest wait in a per-VM FIFO queue ordered by (ready time,
        task id).

    Values are stable wire constants: they are stored as i32 scalars in
    :class:`~repro.core.engine.ScenarioArrays`, so batches may mix policies
    under ``vmap`` without retracing.
    """
    TIME_SHARED = 0
    SPACE_SHARED = 1


class BindingPolicy(enum.IntEnum):
    """Broker task→VM binding strategy (DatacenterBroker extension point).

    ROUND_ROBIN  — CloudSim's default: one rolling VM pointer across all
        submissions (task ``k`` → VM ``k mod V``).
    LEAST_LOADED — greedy: each task (in submission order) goes to the VM
        with the smallest accumulated ``assigned_MI / (mips * pes)`` load
        estimate (full-VM capacity, so multi-PE VMs are not undervalued);
        ties break to the lowest VM index.  The load accumulator is float32
        in every layer so the oracle and the engine pick identical VMs.
    PACKED       — locality-style packing (cf. Locality Sim, PAPERS.md):
        tasks fill PE *slots* in VM order — task ``k`` lands on the VM
        owning slot ``k mod total_pes`` where slots are laid out
        ``[vm0]*pes0 ++ [vm1]*pes1 ++ …`` — so consecutive tasks of a job
        (which share input splits) co-locate until a VM's PEs are full.
    LOCALITY     — data-local binding over the storage subsystem
        (DESIGN.md §7): a map task binds to the least-loaded VM *among
        the replica holders* of its input block (same f32 load estimate
        and tie-breaking as LEAST_LOADED); reduces, block-less tasks and
        disabled storage fall back to all VMs, where the rule degenerates
        to LEAST_LOADED bit for bit.  Any policy binding a map task off
        its replica set pays the remote-fetch delay
        (``storage.remote_fetch_delay``) before the task becomes ready —
        LOCALITY avoids it by construction.

    Binding is resolved at *encoding* time into the per-task ``task_vm``
    field (the broker binds before execution, as CloudSim does); the policy
    id rides along in ``ScenarioArrays`` for provenance.
    """
    ROUND_ROBIN = 0
    LEAST_LOADED = 1
    PACKED = 2
    LOCALITY = 3


def base_task_lengths_f32(length_mi, n_maps, n_reduces, reduce_factor):
    """The f32 op sequence every layer's binding-load estimate shares:

        map_len    = L / M
        reduce_len = rf * L / R

    with all operands float32 and each op rounding to float32.  Pure
    arithmetic, so it serves ``np.float32`` scalars (the oracle, host
    encoding) and traced f32 jnp arrays (``encode_cell``) identically.
    Keep it in ONE place: LEAST_LOADED resolves argmin ties bit-for-bit
    identically across refsim / ``from_scenario`` / ``encode_cell`` only
    while every layer uses this exact sequence (DESIGN.md §3.3).
    Returns ``(map_len, reduce_len)``.
    """
    return length_mi / n_maps, reduce_factor * length_mi / n_reduces


# ---------------------------------------------------------------------------
# Specs (paper §5.2, Tables I–III)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class VMSpec:
    """One virtual machine (paper Table II).

    ``mips`` is per-PE, as in CloudSim.  A 1-PE cloudlet running alone gets
    ``mips``; with ``n`` concurrent cloudlets on the VM it gets
    ``mips * min(1, pes / n)`` (CloudletSchedulerTimeShared fluid semantics,
    see DESIGN.md §2.1).

    ``lease_start``/``lease_stop`` are the VM's pay-as-you-go lease window
    (DESIGN.md §8): the VM admits tasks only in
    ``[lease_start + spinup_delay, lease_stop)`` and is billed for its
    realized lease rounded up to the scenario's billing granularity.  The
    defaults — leased at 0, never torn down — reproduce the pre-elastic
    static fleet bit for bit.

    ``autoscale=True`` marks the VM as a *reserve* (DESIGN.md §10): its
    lease only materializes when the scenario's control policy opens it
    (it admits nothing and bills nothing until then), and an opened
    reserve is closed again once it has no unfinished bound tasks.
    """
    name: str = "small"
    mips: float = 250.0
    pes: int = 1
    ram_mb: int = 512
    bw_mbps: float = 1000.0
    image_size_mb: int = 10_000
    cost_per_sec: float = 1.0
    lease_start: float = 0.0
    lease_stop: float = math.inf
    autoscale: bool = False


@dataclass(frozen=True)
class DatacenterSpec:
    """Physical datacentre capacity (paper Table I)."""
    pes: int = 500
    ram_mb: int = 20_480
    storage_mb: int = 1_000_000
    bw_mbps: float = 1000.0
    mips: float = 1000.0


@dataclass(frozen=True)
class JobSpec:
    """One MapReduce job (paper Table III + §5.2.5 MR combination).

    ``length_mi`` is the total map work in MI; each of the ``n_maps`` map
    tasks gets ``length_mi / n_maps``.  Each of the ``n_reduces`` reduce
    tasks gets ``reduce_factor * length_mi / n_reduces`` (β, DESIGN.md §2.1).
    """
    name: str = "small"
    length_mi: float = 362_880.0
    data_mb: float = 200_000.0
    n_maps: int = 1
    n_reduces: int = 1
    submit_time: float = 0.0
    reduce_factor: float = 0.5
    # Per-task multiplicative length noise (straggler modelling, beyond-paper).
    # 1.0 == deterministic paper behaviour.
    straggler_scale: float = 1.0
    # Space-shared admission priority (DESIGN.md §8): among waiting tasks on
    # one VM, higher priority is admitted first; ties fall back to the
    # classic (ready time, task index) order.  0.0 everywhere reproduces the
    # pre-priority rank bit for bit.
    priority: float = 0.0
    # Completion deadline in simulated seconds (DESIGN.md §11): every task
    # of the job inherits it.  ``inf`` (the default, encoded as the engine's
    # _BIG sentinel) means no decision window — deadline machinery is a
    # bitwise no-op and only the miss metrics see it.
    deadline: float = math.inf


@dataclass(frozen=True)
class NetworkSpec:
    """Stage-in + shuffle delay model (DESIGN.md §2.1).

    ``DelayTime(job) = (kappa_in + kappa_shuffle) * S / ((M + 1) * BW)``;
    kappa values are calibrated so the paper's Table IV is reproduced
    exactly (kappa_in + kappa_shuffle = 21.25 for S=200000, BW=1000 gives
    4250/(M+1)).
    """
    enabled: bool = True
    bw_mbps: float = 1000.0
    kappa_in: float = 17.0
    kappa_shuffle: float = 4.25
    cost_per_unit: float = 1.0


@dataclass(frozen=True)
class Scenario:
    """One complete simulation input (one CloudSim "run")."""
    vms: Sequence[VMSpec] = field(default_factory=lambda: (VM_SMALL,) * 3)
    jobs: Sequence[JobSpec] = field(default_factory=lambda: (JOB_SMALL,))
    datacenter: DatacenterSpec = field(default_factory=DatacenterSpec)
    network: NetworkSpec = field(default_factory=NetworkSpec)
    storage: StorageSpec = field(default_factory=StorageSpec)
    elasticity: ElasticitySpec = field(default_factory=ElasticitySpec)
    control: ControlSpec = field(default_factory=ControlSpec)
    sched_policy: SchedPolicy = SchedPolicy.TIME_SHARED
    binding_policy: BindingPolicy = BindingPolicy.ROUND_ROBIN

    def total_tasks(self) -> int:
        return sum(j.n_maps + j.n_reduces for j in self.jobs)

    def replace(self, **kw) -> "Scenario":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Paper presets
# ---------------------------------------------------------------------------

VM_SMALL = VMSpec("small", mips=250.0, pes=1, ram_mb=512,
                  image_size_mb=10_000, cost_per_sec=1.0)
VM_MEDIUM = VMSpec("medium", mips=500.0, pes=2, ram_mb=1024,
                   image_size_mb=20_000, cost_per_sec=2.0)
VM_LARGE = VMSpec("large", mips=1000.0, pes=4, ram_mb=2048,
                  image_size_mb=40_000, cost_per_sec=4.0)
VM_TYPES = {"small": VM_SMALL, "medium": VM_MEDIUM, "large": VM_LARGE}

JOB_SMALL = JobSpec("small", length_mi=362_880.0, data_mb=200_000.0)
JOB_MEDIUM = JobSpec("medium", length_mi=725_760.0, data_mb=400_000.0)
JOB_BIG = JobSpec("big", length_mi=1_451_520.0, data_mb=800_000.0)
JOB_TYPES = {"small": JOB_SMALL, "medium": JOB_MEDIUM, "big": JOB_BIG}


def as_vm_spec(v) -> VMSpec:
    """Coerce a Table-II type name or :class:`VMSpec` to a spec (the value
    form sweep axes and plan base arguments accept)."""
    if isinstance(v, str):
        try:
            return VM_TYPES[v]
        except KeyError:
            raise ValueError(f"unknown VM type {v!r}; "
                             f"known: {list(VM_TYPES)}") from None
    if isinstance(v, VMSpec):
        return v
    raise TypeError(f"expected VMSpec or VM type name, got {type(v).__name__}")


def as_job_spec(v) -> JobSpec:
    """Coerce a Table-III type name or :class:`JobSpec` to a spec."""
    if isinstance(v, str):
        try:
            return JOB_TYPES[v]
        except KeyError:
            raise ValueError(f"unknown job type {v!r}; "
                             f"known: {list(JOB_TYPES)}") from None
    if isinstance(v, JobSpec):
        return v
    raise TypeError(
        f"expected JobSpec or job type name, got {type(v).__name__}")


def paper_scenario(*, job: str = "small", vm: str = "small", n_vms: int = 3,
                   n_maps: int = 1, n_reduces: int = 1,
                   network_delay: bool = True,
                   sched_policy: SchedPolicy = SchedPolicy.TIME_SHARED,
                   binding_policy: BindingPolicy = BindingPolicy.ROUND_ROBIN,
                   ) -> Scenario:
    """The paper's §5 experimental cell: one job, homogeneous VMs."""
    j = dataclasses.replace(JOB_TYPES[job], n_maps=n_maps, n_reduces=n_reduces)
    return Scenario(vms=(VM_TYPES[vm],) * n_vms, jobs=(j,),
                    network=NetworkSpec(enabled=network_delay),
                    sched_policy=sched_policy, binding_policy=binding_policy)
