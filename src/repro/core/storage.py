"""Data-locality storage subsystem: block placement as device-side data.

IOTSim's premise is that IoT big-data jobs are dominated by moving sensor
data into and between cloud VMs before MapReduce processing — yet binding
policies that ignore *where* a task's input lives treat that data as free.
Following Locality Sim (PAPERS.md), this module models an HDFS-style block
store:

* each job's dataset is split into fixed-size **blocks**
  (``ceil(data_mb / block_size_mb)``, the last block holding the
  remainder); map task ``m`` reads block ``m mod n_blocks``;
* every block is replicated ``replication``-fold onto **distinct** VMs by
  a *seeded, counter-based placement function* — no RNG state, just an
  integer hash of ``(seed, job, block)`` — with a ``UNIFORM`` variant
  (hashed start VM) and a ``SKEWED`` hot-spot variant (quadratic bias
  toward low VM indices, modelling a few storage-heavy nodes);
* placement is **encoded into** :class:`~repro.core.engine.ScenarioArrays`
  as per-task ``block_vm`` / ``block_size`` arrays, so replication factor,
  block size and placement skew are sweepable data like every other
  scenario parameter;
* a map task bound to a VM holding a replica of its block reads locally;
  bound anywhere else it first pays a **remote-fetch delay**
  ``kappa_in * block_mb / BW`` through the shared
  :func:`~repro.core.network.transfer_delay` formula (the ``M = 0``
  point-to-point case) before becoming ready.

On top of the store, ``BindingPolicy.LOCALITY`` binds each task to the
least-loaded VM *among the replica holders* of its input block (falling
back to all VMs for reduces, block-less tasks, or a disabled store).  Its
load estimate and tie-breaking are exactly LEAST_LOADED's, so with
``replication == num_vms`` (every block everywhere) LOCALITY is
**bit-identical** to LEAST_LOADED — the degenerate-parity property pinned
in ``tests/test_storage.py``.

Cross-layer determinism (DESIGN.md §7): every function here is written
against a module handle ``xp`` that may be ``numpy`` (the sequential
oracle and host-side ``from_scenario``) or ``jax.numpy`` (the traced
``encode_cell`` under ``vmap``).  The hash runs in uint32 (wraps
identically in both), the skew transform in float32 (same IEEE ops), so
host- and device-encoded placements agree **bit for bit**.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass

import numpy as np


class Placement(enum.IntEnum):
    """Block-placement variant (stable wire constants — i32 sweep data).

    UNIFORM — replica-set start VM is a uniform hash of (seed, job, block):
        load spreads evenly, the HDFS default-rack idealization.
    SKEWED  — hot-spot placement: the start VM is quadratically biased
        toward low VM indices (``floor(u² · V)`` for a hashed uniform
        ``u``), modelling a few storage-heavy nodes that accumulate most
        blocks — the regime where locality-blind binding pays the most
        remote fetches and LOCALITY binding risks load imbalance.
    """
    UNIFORM = 0
    SKEWED = 1


def as_placement(v) -> Placement:
    """Coerce a name (``"uniform"``/``"skewed"``), int, or member."""
    if isinstance(v, str):
        try:
            return Placement[v.upper()]
        except KeyError:
            raise ValueError(
                f"unknown placement {v!r}; "
                f"known: {[p.name.lower() for p in Placement]}") from None
    return Placement(v)


@dataclass(frozen=True)
class StorageSpec:
    """The scenario-level storage model (disabled by default: zero blocks,
    zero fetch delays — pre-storage scenarios are reproduced bit for bit).

    ``block_size_mb`` is the HDFS-style fixed block size; at the paper's
    200 GB Small dataset the 2048 MB default yields ~98 blocks.
    ``replication`` is clipped to the VM count at placement time (a block
    cannot have two replicas on one VM).
    """
    enabled: bool = False
    block_size_mb: float = 2048.0
    replication: int = 3
    placement: Placement = Placement.UNIFORM
    seed: int = 0


# ---------------------------------------------------------------------------
# Seeded counter-based placement (xp-generic: numpy == jax.numpy, bit for bit)
# ---------------------------------------------------------------------------

_M1 = np.uint32(0x7FEB352D)     # lowbias32 (Walker) avalanche constants
_M2 = np.uint32(0x846CA68B)
_C1 = np.uint32(0x9E3779B9)     # distinct odd mix-in constants per input
_C2 = np.uint32(0x85EBCA6B)
_C3 = np.uint32(0xC2B2AE35)
_INV24 = np.float32(1.0 / (1 << 24))


def _mix32(h):
    """lowbias32-style avalanche; uint32 in, uint32 out, wraps in both
    numpy array ops and jnp (operands must be arrays, not numpy scalars —
    scalar overflow warns in numpy, array overflow wraps silently)."""
    h = (h ^ (h >> 16)) * _M1
    h = (h ^ (h >> 15)) * _M2
    return h ^ (h >> 16)


def map_block_placement(xp, map_idx, job_idx, *, seed, placement,
                        replication, block_size_mb, job_data, n_vms,
                        pad_vms: int):
    """Replica VMs + block size for each map task of a job.

    ``map_idx``/``job_idx`` are i32 arrays ``[K]`` (map index within its
    job, job index); the scalars ``seed``/``placement``/``replication``/
    ``n_vms`` (i32-like) and ``block_size_mb``/``job_data`` (f32-like) may
    be traced.  Returns ``(block_vm, block_mb)``:

    * ``block_vm`` — i32 ``[K, pad_vms]``: the VMs holding a replica of
      the task's input block in replica-slot order, ``-1`` for slots
      beyond the effective replication ``min(max(replication, 1), n_vms)``
      (slot ``r`` holds VM ``(start + r) mod n_vms`` — consecutive VMs
      from the hashed start, so replicas are always distinct and
      ``replication == n_vms`` places every block on every VM);
    * ``block_mb`` — f32 ``[K]``: the block's size in MB (the last block
      of a dataset carries the remainder).

    Pure arithmetic on its operands — ``xp`` is ``numpy`` or ``jax.numpy``
    and the two produce bit-identical outputs (uint32 wrap-around hash,
    float32 skew transform).
    """
    i32, f32, u32 = np.int32, np.float32, np.uint32
    if isinstance(seed, int):
        # numpy 2 raises OverflowError converting out-of-range Python ints
        # to uint32 while array columns wrap silently — normalize here so
        # the host (Python-int) and device (i32-column) seed domains agree
        seed = seed % (1 << 32)
    map_idx = xp.asarray(map_idx, i32)
    n_vms_i = xp.asarray(n_vms, i32)

    # dataset -> fixed-size blocks; map m reads block m mod n_blocks
    bs = xp.maximum(xp.asarray(block_size_mb, f32), f32(1e-6))
    data = xp.asarray(job_data, f32)
    n_blocks = xp.maximum(xp.ceil(data / bs), f32(1.0)).astype(i32)
    block = map_idx % n_blocks
    last_mb = data - (n_blocks - 1).astype(f32) * bs
    block_mb = xp.where(block == n_blocks - 1, last_mb, bs)

    # seeded start VM per (seed, job, block)
    h = _mix32(xp.asarray(block, u32) * _C1
               + xp.asarray(job_idx, u32) * _C2
               + xp.asarray(seed, u32) * _C3)
    start_uni = (h % xp.asarray(xp.maximum(n_vms_i, 1), u32)).astype(i32)
    u01 = (h >> u32(8)).astype(f32) * _INV24          # [0, 1) in f32
    n_vms_f = n_vms_i.astype(f32)
    start_skew = xp.minimum((u01 * u01 * n_vms_f).astype(i32),
                            xp.maximum(n_vms_i - 1, 0))
    start = xp.where(xp.asarray(placement, i32) == int(Placement.SKEWED),
                     start_skew, start_uni)

    # replica slot r -> VM (start + r) mod n_vms, distinct for r < n_vms
    eff_repl = xp.clip(xp.asarray(replication, i32), 1, n_vms_i)
    r = xp.arange(pad_vms, dtype=i32)
    vm = (start[:, None] + r[None, :]) % xp.maximum(n_vms_i, 1)
    block_vm = xp.where(r[None, :] < eff_repl, vm, i32(-1))
    return block_vm, block_mb


def scenario_placement(scenario, pad_vms: int):
    """Realize a whole :class:`Scenario`'s block placement, host-side.

    Returns ``(block_vm, block_mb)`` over the canonical task order (per
    job: maps, then reduces) — ``i32[n_tasks, pad_vms]`` / ``f32[n_tasks]``
    with ``-1``/``0`` rows for reduces (and everything, when the store is
    disabled).  The one shared realization both host encoders consume
    (``engine.from_scenario`` and ``refsim.IoTSimBroker``), so the oracle
    and the engine cannot drift as the placement model grows.
    ``scenario`` is duck-typed (``config`` imports this module).
    """
    st = scenario.storage
    n_tasks = scenario.total_tasks()
    block_vm = np.full((n_tasks, pad_vms), -1, np.int32)
    block_mb = np.zeros(n_tasks, np.float32)
    if not st.enabled:
        return block_vm, block_mb
    k = 0
    for ji, job in enumerate(scenario.jobs):
        bvm, bmb = map_block_placement(
            np, np.arange(job.n_maps, dtype=np.int32),
            np.full(job.n_maps, ji, np.int32),
            seed=st.seed, placement=int(st.placement),
            replication=st.replication,
            block_size_mb=np.float32(st.block_size_mb),
            job_data=np.float32(job.data_mb),
            n_vms=len(scenario.vms), pad_vms=pad_vms)
        block_vm[k:k + job.n_maps] = bvm
        block_mb[k:k + job.n_maps] = bmb
        k += job.n_maps + job.n_reduces
    return block_vm, block_mb


# ---------------------------------------------------------------------------
# Derived quantities every layer shares
# ---------------------------------------------------------------------------

def locality_candidates(xp, block_vm, vm_valid):
    """Binding candidate mask ``bool[T, V]`` for LOCALITY.

    A task whose ``block_vm`` row names at least one replica may only bind
    to replica holders; tasks without a block (reduces, padding, disabled
    storage) fall back to every valid VM — which makes LOCALITY degenerate
    to LEAST_LOADED's exact argmin sequence there.
    """
    ids = xp.arange(vm_valid.shape[0], dtype=np.int32)
    holds = (block_vm[:, :, None] == ids[None, None, :]).any(axis=1)
    has_block = (block_vm >= 0).any(axis=1)
    return xp.where(has_block[:, None], holds, vm_valid[None, :])


def is_local(block_vm, task_vm):
    """``bool[..., T]``: the bound VM holds a replica of the task's block.
    Elementwise over any leading batch shape (``-1`` slots never match a
    bound VM, which is always ``>= 0``)."""
    return (block_vm == task_vm[..., None]).any(axis=-1)


def has_block(block_vm):
    """``bool[..., T]``: the task reads a placed input block at all."""
    return (block_vm >= 0).any(axis=-1)


def remote_fetch_delay(block_vm, block_size, task_vm, kappa_in, net_bw,
                       net_enabled, xp=None):
    """Per-task remote-fetch delay added to map readiness (0 when local).

    The fetch is a point-to-point storage read, so it reuses the shared
    kappa formula at its ``M = 0`` point:
    ``transfer_delay(kappa_in, block_mb, 0, BW) = kappa_in * block_mb / BW``
    — one op sequence for the oracle (f64 floats), the engine (per-lane
    f32) and the batched kernel wrapper (broadcast f32), so the layers
    cannot drift.  ``kappa_in``/``net_bw``/``net_enabled`` must broadcast
    against ``block_size``'s shape.
    """
    from . import network           # late: network has no jnp dependency
    if xp is None:
        import jax.numpy as xp      # noqa: F811 — default device path
    fetch = network.transfer_delay(kappa_in, block_size, 0.0, net_bw,
                                   net_enabled)
    remote = has_block(block_vm) & ~is_local(block_vm, task_vm)
    return xp.where(remote, fetch, 0.0)
