"""Bridge between the LM training/serving stack and the simulator.

IOTSim's purpose is *analysing big-data applications on clouds before
deploying them*.  The 2026 workload is pod-scale model training, so this
module converts a compiled training step's cost model (FLOPs / HBM bytes /
collective bytes, as extracted by ``benchmarks/roofline.py`` from the
multi-pod dry-run) into simulator scenarios:

* one *map task* per device per step (compute),
* the *shuffle* delay models the step's collective phase,
* VM MIPS ≡ chip FLOP/s, so straggling chips are straggler multipliers,
* node failures + checkpoint restarts enter as job interruptions.

This is the paper's MapReduce↔cloud methodology applied to its modern
workload (DESIGN.md §5): map = sharded compute, shuffle = collectives,
reduce = the optimizer update.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import numpy as np

from .config import (BindingPolicy, JobSpec, NetworkSpec, Scenario,
                     SchedPolicy, StorageSpec, VMSpec)


# TPU v5e (the assignment's hardware constants).
@dataclass(frozen=True)
class ChipSpec:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12        # bf16 FLOP/s
    hbm_bw: float = 819e9             # bytes/s
    link_bw: float = 50e9             # bytes/s per ICI link


@dataclass(frozen=True)
class StepCost:
    """Per-device cost of one compiled step (from the dry-run artifacts)."""
    flops: float                      # HLO FLOPs / device
    hbm_bytes: float                  # HLO bytes accessed / device
    collective_bytes: float           # summed collective operand bytes / device

    def roofline_terms(self, chip: ChipSpec) -> dict[str, float]:
        return {
            "compute_s": self.flops / chip.peak_flops,
            "memory_s": self.hbm_bytes / chip.hbm_bw,
            "collective_s": self.collective_bytes / chip.link_bw,
        }

    def step_seconds(self, chip: ChipSpec) -> float:
        """Max-of-terms roofline step time (no overlap pessimism knob)."""
        return max(self.roofline_terms(chip).values())


def step_scenario(cost: StepCost, chip: ChipSpec, n_devices: int, *,
                  straggler_sigma: float = 0.0, seed: int = 0,
                  sched_policy: SchedPolicy = SchedPolicy.TIME_SHARED,
                  binding_policy: BindingPolicy = BindingPolicy.ROUND_ROBIN,
                  storage: StorageSpec | None = None,
                  ) -> tuple[Scenario, np.ndarray | None]:
    """One training step as an IOTSim scenario.

    Device compute becomes M = n_devices map tasks of length = per-device
    FLOPs on VMs of MIPS = effective FLOP/s (bounded by the memory-roofline
    term); the collective phase becomes the shuffle delay.  Straggler
    multipliers (lognormal, σ = ``straggler_sigma``) model slow chips.
    ``sched_policy=SPACE_SHARED`` models gang-scheduled exclusive chips
    (the realistic TPU regime — one step-shard per core, no oversubscribe);
    ``binding_policy`` picks the shard→chip placement strategy.

    ``storage`` (DESIGN.md §7) attaches the block store to the step: data
    shards become placed input blocks, so
    ``binding_policy=BindingPolicy.LOCALITY`` models shard-local dispatch
    (each step-shard runs on a chip already holding its data-parallel
    shard) while locality-blind policies pay
    ``storage.remote_fetch_delay`` per off-host shard read — the
    input-pipeline analogue of HDFS rack awareness.
    """
    terms = cost.roofline_terms(chip)
    eff_rate = cost.flops / max(terms["compute_s"], terms["memory_s"])
    vm = VMSpec(name=chip.name, mips=eff_rate, pes=1, cost_per_sec=0.0)
    # Calibrate the shuffle delay to the collective term:
    #   shuffle = kappa_shuffle * S / ((M+1) * BW)  ==  collective_s
    net = NetworkSpec(enabled=True, bw_mbps=1.0, kappa_in=0.0,
                      kappa_shuffle=1.0,
                      cost_per_unit=0.0)
    data = terms["collective_s"] * (n_devices + 1)
    job = JobSpec(name="train-step", length_mi=cost.flops * n_devices,
                  data_mb=data, n_maps=n_devices, n_reduces=1,
                  reduce_factor=1e-6)
    mult = None
    if straggler_sigma > 0.0:
        rng = np.random.default_rng(seed)
        mult = np.ones(n_devices + 1)
        mult[:n_devices] = rng.lognormal(0.0, straggler_sigma, n_devices)
    return Scenario(vms=(vm,) * n_devices, jobs=(job,), network=net,
                    storage=storage if storage is not None else StorageSpec(),
                    sched_policy=sched_policy,
                    binding_policy=binding_policy), mult


def simulate_training(cost: StepCost, chip: ChipSpec, *, n_devices: int,
                      n_steps: int, straggler_sigma: float = 0.0,
                      mtbf_hours: float = 0.0, checkpoint_every: int = 100,
                      checkpoint_secs: float = 30.0, restart_secs: float = 120.0,
                      seed: int = 0) -> dict[str, float]:
    """Predict a run's makespan under stragglers + failures + checkpoints.

    Hybrid: per-step makespan from the DES engine (stragglers change the
    processor-sharing critical path); failure/restart overhead composed
    analytically on top (Poisson failures at cluster MTBF/n_devices, each
    costing ``restart_secs`` + recomputation since the last checkpoint).
    """
    from . import refsim
    sc, mult = step_scenario(cost, chip, n_devices,
                             straggler_sigma=straggler_sigma, seed=seed)
    res = refsim.simulate(sc, None if mult is None else list(mult))
    step_s = res.job().makespan
    ideal_s = cost.step_seconds(chip)          # roofline (perfect overlap)
    terms = cost.roofline_terms(chip)
    # the simulator's own no-straggler step: serial compute then shuffle
    base_s = max(terms["compute_s"], terms["memory_s"]) \
        + terms["collective_s"]

    ckpt_overhead = checkpoint_secs * (n_steps / max(checkpoint_every, 1))
    total = step_s * n_steps + ckpt_overhead
    failures = 0.0
    if mtbf_hours > 0.0:
        rate = n_devices / (mtbf_hours * 3600.0)     # cluster failure rate
        failures = rate * total
        # each failure: restart + half a checkpoint interval of lost work
        total += failures * (restart_secs
                             + 0.5 * checkpoint_every * step_s)
    return {
        "step_seconds": step_s,
        "ideal_step_seconds": ideal_s,
        "straggler_slowdown": step_s / base_s if base_s else float("nan"),
        "expected_failures": failures,
        "total_hours": total / 3600.0,
        "goodput": (ideal_s * n_steps) / total if total else float("nan"),
    }
