"""Speculative execution (beyond-paper): Hadoop-style backup tasks.

The paper models deterministic task lengths; real MapReduce clusters
straggle, and Hadoop's remedy — launch a backup copy of a slow task, take
whichever finishes first — is the canonical mitigation (Dean &
Ghemawat §3.6).  This module extends the reference simulator with:

* per-task straggler multipliers (lognormal),
* a speculation policy: when a map task's *projected* finish exceeds
  ``threshold ×`` the median projected finish of its phase, a backup is
  bound to the least-loaded VM; the task completes at min(original,
  backup).

This powers ``benchmarks/speculative_execution.py`` (makespan and cost
with/without speculation vs straggler severity) — the study the IOTSim
methodology enables but the paper left as future work.
"""
from __future__ import annotations

import math

import numpy as np

from .config import BindingPolicy, Scenario, SchedPolicy
from .network import shuffle_delay, stage_in_delay


def straggler_multipliers(scenario: Scenario, sigma: float,
                          seed: int = 0) -> list[float]:
    rng = np.random.default_rng(seed)
    return list(rng.lognormal(0.0, sigma, scenario.total_tasks()))


def simulate_speculative(scenario: Scenario, multipliers: list[float], *,
                         threshold: float = 1.5,
                         max_backups: int | None = None) -> dict:
    """Fluid time-shared simulation with one speculation round.

    Exact for the paper's single-job cells (all maps ready together);
    reduces to the reference result when multipliers are all 1.0.
    Returns per-phase times + totals with and without speculation.
    """
    if len(scenario.jobs) != 1:
        raise ValueError(
            f"simulate_speculative: scenario has {len(scenario.jobs)} jobs; "
            "the fluid model covers single-job cells only")
    # this analytic model hardcodes time-shared sharing + round-robin
    # binding; reject other policies rather than silently mis-simulating
    if (scenario.sched_policy != SchedPolicy.TIME_SHARED
            or scenario.binding_policy != BindingPolicy.ROUND_ROBIN):
        raise ValueError(
            "simulate_speculative models TIME_SHARED + ROUND_ROBIN only "
            f"(got {scenario.sched_policy.name}, "
            f"{scenario.binding_policy.name})")
    if len(multipliers) != scenario.total_tasks():
        raise ValueError(
            f"simulate_speculative: {len(multipliers)} multipliers for "
            f"{scenario.total_tasks()} tasks — one per task required")
    job = scenario.jobs[0]
    vms = scenario.vms
    V = len(vms)
    M, R = job.n_maps, job.n_reduces
    net = scenario.network
    t_ready = job.submit_time + stage_in_delay(job, net)

    base_len = job.length_mi / M
    lens = np.array([base_len * multipliers[i] for i in range(M)])
    vm_of = np.arange(M) % V

    def phase_finish(lens, vm_of, start):
        """Fluid processor sharing on each VM until every task completes."""
        finish = np.zeros(len(lens))
        for v in range(V):
            ids = np.where(vm_of == v)[0]
            if len(ids) == 0:
                continue
            rem = lens[ids].astype(float).copy()
            t = start
            rate_cap = vms[v].mips
            pes = vms[v].pes
            order = np.argsort(rem)
            done = np.zeros(len(ids), bool)
            while not done.all():
                n = (~done).sum()
                rate = rate_cap * min(1.0, pes / n)
                nxt = rem[~done].min()
                dt = nxt / rate
                rem[~done] -= nxt
                t += dt
                newly = (~done) & (rem <= 1e-9)
                finish[ids[newly]] = t
                done |= newly
        return finish

    # --- no speculation -------------------------------------------------
    fin_plain = phase_finish(lens, vm_of, t_ready)
    map_end_plain = fin_plain.max()

    # --- one speculation round ------------------------------------------
    # projected finishes under equal sharing; back up tasks projected
    # beyond threshold x median
    proj = phase_finish(lens, vm_of, t_ready)
    med = np.median(proj)
    suspects = np.where(proj > threshold * med)[0]
    if max_backups is not None:
        suspects = suspects[np.argsort(-proj[suspects])][:max_backups]
    if len(suspects):
        # backups start when detected (at the median finish time, i.e.
        # when healthy tasks complete) on the least-loaded VMs, and run
        # the task's *base* length (the slowness was machine-local)
        detect = med
        load = np.bincount(vm_of, minlength=V).astype(float)
        b_vm, b_len, b_start = [], [], []
        for s in suspects:
            v = int(np.argmin(load))
            load[v] += 1
            b_vm.append(v)
            b_len.append(base_len)
            b_start.append(detect)
        # approximate: backups run on their VM sharing with any original
        # tasks still resident; originals keep running
        fin_backup = np.array([
            b_start[i] + b_len[i] / (vms[b_vm[i]].mips
                                     * min(1.0, vms[b_vm[i]].pes
                                           / (1 + (load[b_vm[i]] - 1 > 0))))
            for i in range(len(suspects))])
        fin_spec = fin_plain.copy()
        fin_spec[suspects] = np.minimum(fin_plain[suspects], fin_backup)
        map_end_spec = fin_spec.max()
        backup_work = sum(b_len)
    else:
        map_end_spec = map_end_plain
        backup_work = 0.0

    sh = shuffle_delay(job, net)
    red_len = job.reduce_factor * job.length_mi / R
    red_time = red_len / vms[0].mips
    mk_plain = map_end_plain + sh + red_time
    mk_spec = map_end_spec + sh + red_time
    cost_rate = vms[0].cost_per_sec
    work_plain = lens.sum() + red_len * R
    work_spec = work_plain + backup_work
    return {
        "makespan_plain": mk_plain,
        "makespan_spec": mk_spec,
        "speedup": mk_plain / mk_spec,
        "n_backups": int(len(suspects)),
        "extra_work_frac": backup_work / work_plain,
        "cost_plain": work_plain / vms[0].mips * cost_rate,
        "cost_spec": work_spec / vms[0].mips * cost_rate,
    }
