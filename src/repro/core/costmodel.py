"""Measured execution-cost model for the adaptive schedule (DESIGN.md §9).

The bucket-merge heuristic and the compaction interval used to be static
magic numbers (``min_cells = max(256, N // 4)``; check-every-epoch).  Both
decisions trade the same two measured quantities against each other:

* ``dispatch_us`` — the fixed overhead of one fused bucket dispatch
  (trace-cache lookup, argument staging, XLA call, readback).  Paying it
  once more is the *cost* of splitting a bucket or of a compaction
  round's gather/step/scatter chain.
* ``epoch_lane_us`` — the marginal cost of advancing one lane one event
  epoch per task slot (the epoch body is branch-free, so this is
  activity-independent).  Saving lane-epochs is the *benefit* of both a
  smaller-padded bucket and a compacted batch.
* ``sync_us`` — the cost of one blocking scalar device→host pull.  The
  dispatch-lean compact loop (DESIGN.md §13) pays exactly one of these
  per round (the fused ``[n_step, n_active]`` pair) instead of a full
  ``bool[N]`` mask transfer, so the round overhead it balances against
  wasted tail epochs is ``sync_us + dispatch_us`` — measured, not the
  retired ``ROUND_DISPATCHES`` guess.

All are measured once per device with a tiny seeded micro-benchmark
(min-of-reps: these feed scheduling decisions, so the noise floor is the
right statistic) and persisted to a small JSON cache keyed by device, so
every later process skips the measurement.  A pinned calibration file
makes every scoring decision deterministic (``tests/test_compaction.py``).

The scoring formulas live on :class:`CostModel` so the bucket scheduler
(``sweep._bucket_groups``), the compacted-stepping drivers
(``engine.simulate_batch_arrays_compact``, ``kernels.mr_sched.ops``) and
the ROADMAP item-2 request coalescer all price work with the same two
coefficients.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import time
from functools import partial

import numpy as np

ENV_PATH = "REPRO_COSTMODEL_PATH"
_DEFAULT_PATH = pathlib.Path.home() / ".cache" / "repro-iotsim" / \
    "costmodel.json"

# Persisted-cache schema version.  The cache file is
# ``{"schema": N, "models": {device: {coefficients...}}}``; bump this
# whenever the coefficient semantics change (e.g. a new measurement
# protocol) so stale caches are invalidated instead of silently feeding
# garbage coefficients into the schedulers.  Pre-schema files (a bare
# ``{device: {...}}`` mapping) fail the check and are re-measured.
# v2: adds the measured ``sync_us`` scalar-pull coefficient (the
# dispatch-lean compact loop prices rounds as sync + dispatch, replacing
# the fixed ROUND_DISPATCHES multiplier), so v1 caches re-measure.
SCHEMA_VERSION = 2

# Conservative CPU-ish coefficients used when measurement is disabled or
# fails (e.g. a sandboxed FS): chosen to reproduce the retired static
# heuristic's behaviour on the benchmark grids within a few percent.
_FALLBACK_DISPATCH_US = 1500.0
_FALLBACK_EPOCH_LANE_US = 0.030
_FALLBACK_SYNC_US = 250.0

# Clamp bounds for the auto compaction interval K*.  Named constants so
# re-derivations of the interval formula cannot silently change the
# clamp (regression-tested): K=1 is the check-every-epoch floor the
# pre-cost-model driver used; 64 caps the wasted-tail exposure of a
# degenerate calibration (a huge measured dispatch cost must not make
# the driver effectively never compact).
COMPACT_INTERVAL_MIN = 1
COMPACT_INTERVAL_MAX = 64

_CACHE: dict[str, "CostModel"] = {}


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Measured coefficients + the scoring rules built on them."""
    dispatch_us: float       # fixed overhead of one fused dispatch
    epoch_lane_us: float     # us per (lane x epoch x task-slot)
    # Cost of one blocking scalar device->host pull (the compact loop's
    # per-round [n_step, n_active] readback).  Defaulted so pinned
    # hand-constructed calibrations predating the split keep working.
    sync_us: float = _FALLBACK_SYNC_US
    device: str = "unknown"
    # Where the coefficients came from — "measured" (fresh micro-bench
    # this process), "cache" (persisted JSON hit), "fallback" (built-in
    # conservative constants), or "static" (hand-constructed, e.g. the
    # pinned test calibrations).  Surfaced through ``RunReport`` and the
    # BENCH meta so a recorded number can be traced to its calibration.
    # compare=False: provenance, not a coefficient — a save/load
    # round-trip must stay ``==`` to what was saved.
    source: str = dataclasses.field(default="static", compare=False)

    # -- derived scoring -------------------------------------------------
    @staticmethod
    def est_epochs(pad_t) -> np.ndarray:
        """Expected realized epochs for lanes padded to ``pad_t`` tasks.

        Tail-heavy (space-shared) lanes admit roughly one task per event
        epoch, so realized counts scale ~linearly with the task count —
        ``t + 2`` is half the engine's hard ``2t + 2`` bound and matches
        the recorded ``realized_epochs`` trajectory within ~2x across the
        BENCH_sweep rows, which is accurate enough to rank partitions."""
        return np.asarray(pad_t, np.float64) + 2.0

    def cell_cost_us(self, pad_t) -> np.ndarray:
        """Marginal simulation cost of ONE lane padded to ``pad_t`` tasks
        (dispatch overhead excluded — that is per bucket, not per lane)."""
        t = np.asarray(pad_t, np.float64)
        return self.epoch_lane_us * t * self.est_epochs(t)

    def bucket_cost_us(self, n_cells, pad_t) -> float:
        """Modelled cost of running ``n_cells`` lanes as one bucket."""
        return float(self.dispatch_us
                     + np.asarray(n_cells, np.float64)
                     * self.cell_cost_us(pad_t))

    def split_gain_us(self, n_cells, pad_t, cap_t) -> float:
        """Saving from running ``n_cells`` lanes in their own ``pad_t``
        bucket instead of merged up into a ``cap_t``-padded one — before
        subtracting the extra ``dispatch_us`` the split costs.  A split
        pays iff this exceeds ``dispatch_us``."""
        return float(np.asarray(n_cells, np.float64)
                     * (self.cell_cost_us(cap_t) - self.cell_cost_us(pad_t)))

    def compact_interval(self, n_lanes: int, pad_t: int) -> int:
        """Auto compaction interval K (epochs between active-lane checks).

        A dispatch-lean round (DESIGN.md §13) costs ``sync_us`` (the
        blocking ``[n_step, n_active]`` scalar pull) plus ``dispatch_us``
        (the chunk-step launch), paid ``1/K`` per epoch; the full
        gather/scatter chain is only paid on rounds that actually shrink
        the batch, so it does not belong in the steady-state round price
        (the retired ``ROUND_DISPATCHES = 6`` multiplier priced every
        round as if it compacted).  Checking late wastes work only on
        lanes that retire *mid-chunk* — on a tail-heavy grid lanes retire
        at roughly ``n / (2t + 2)`` per epoch (the batch drains over its
        epoch bound), and each such lane wastes on average ``K/2`` epochs
        of ``t``-wide stepping.  Balancing ``(sync + dispatch) / K``
        against ``K * epoch_lane * t * n / (2t + 2) / 2`` gives the root
        below; clamped to [:data:`COMPACT_INTERVAL_MIN`,
        :data:`COMPACT_INTERVAL_MAX`] so degenerate calibrations stay
        usable."""
        retire_rate = max(n_lanes, 1) / (2.0 * max(pad_t, 1) + 2.0)
        per_epoch = max(self.epoch_lane_us * max(pad_t, 1) * retire_rate,
                        1e-9)
        k = np.sqrt(2.0 * (self.sync_us + self.dispatch_us) / per_epoch)
        return int(np.clip(round(k), COMPACT_INTERVAL_MIN,
                           COMPACT_INTERVAL_MAX))

    def to_json(self) -> dict:
        return {"dispatch_us": self.dispatch_us,
                "epoch_lane_us": self.epoch_lane_us,
                "sync_us": self.sync_us}


def fallback_cost_model(device: str = "fallback") -> CostModel:
    return CostModel(dispatch_us=_FALLBACK_DISPATCH_US,
                     epoch_lane_us=_FALLBACK_EPOCH_LANE_US,
                     sync_us=_FALLBACK_SYNC_US, device=device,
                     source="fallback")


def device_key() -> str:
    import jax
    return f"{jax.default_backend()}:{jax.devices()[0].device_kind}"


# ---------------------------------------------------------------------------
# Measurement (once per device, persisted)
# ---------------------------------------------------------------------------

def _probe_batch(n: int, n_maps: int):
    """``n`` copies of one encoded scenario (numpy stack — host-side)."""
    import dataclasses as dc

    from . import engine
    from .config import JOB_SMALL, VM_SMALL, Scenario
    sc = Scenario(vms=(VM_SMALL,),
                  jobs=(dc.replace(JOB_SMALL, n_maps=n_maps),))
    arrs = engine.from_scenario(sc)
    return engine.ScenarioArrays(
        *(np.broadcast_to(np.asarray(x)[None],
                          (n,) + np.shape(np.asarray(x))).copy()
          for x in arrs))


def measure(reps: int = 5) -> CostModel:
    """Time the two coefficients on this device (min-of-reps noise floor).

    The epoch body is branch-free — its cost is independent of lane
    activity — so a fixed-trip ``fori_loop`` over the vmapped
    ``engine._epoch_step`` measures exactly the per-epoch work the
    bucketed/compacted schedules trade off, and the k-slope cancels the
    dispatch overhead out of ``epoch_lane_us`` while the small-batch
    intercept isolates it for ``dispatch_us``."""
    import jax

    from . import engine

    @partial(jax.jit, static_argnames="k")
    def run_epochs(batch, k: int):
        # the full per-bucket pipeline minus encode — setup, k fixed
        # epochs, output + metrics staging — so the intercept reflects
        # what one more *fused bucket dispatch* really costs (argument
        # staging and metric readback dominate it on small hosts, not
        # the bare XLA call)
        inv, c0 = jax.vmap(engine._epoch_setup)(batch)

        def body(_, c):
            return jax.vmap(engine._epoch_step)(batch, inv, c)

        c = jax.lax.fori_loop(0, k, body, c0)
        out = jax.vmap(engine._sim_output)(batch, c)
        return (jax.vmap(engine.job_metrics)(batch, out),
                jax.vmap(engine.scenario_metrics)(batch, out))

    def floor_us(batch, k):
        jax.block_until_ready(run_epochs(batch, k))    # compile
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(run_epochs(batch, k))
            best = min(best, time.perf_counter() - t0)
        return best * 1e6

    import jax.numpy as jnp

    @jax.jit
    def scalar_probe(i):
        # a fresh device scalar per rep (the +i defeats constant folding
        # across calls), shaped like the compact loop's fused
        # [n_step, n_active] readback
        return jnp.sum(jnp.arange(256, dtype=jnp.int32)) + i

    def sync_floor_us():
        # time ONLY the blocking device->host pull of a *ready* scalar:
        # the per-round overhead the lean loop pays is the readback
        # round-trip, not the compute the pull may happen to wait on
        best = float("inf")
        for r in range(max(reps, 3) * 3):
            s = scalar_probe(jnp.int32(r))
            jax.block_until_ready(s)
            t0 = time.perf_counter()
            int(s)
            best = min(best, time.perf_counter() - t0)
        return best * 1e6

    small = _probe_batch(8, n_maps=7)                  # T = 8
    big = _probe_batch(64, n_maps=15)                  # T = 16
    t_small_1, t_small_9 = floor_us(small, 1), floor_us(small, 9)
    t_big_4, t_big_36 = floor_us(big, 4), floor_us(big, 36)
    slope_small = max((t_small_9 - t_small_1) / 8.0, 0.0)
    dispatch = max(t_small_1 - slope_small, 1.0)
    epoch_lane = max((t_big_36 - t_big_4) / 32.0, 1e-6) / (64 * 16)
    sync = max(sync_floor_us(), 0.01)
    return CostModel(dispatch_us=round(dispatch, 2),
                     epoch_lane_us=round(epoch_lane, 6),
                     sync_us=round(sync, 2),
                     device=device_key(), source="measured")


# ---------------------------------------------------------------------------
# Persistence
# ---------------------------------------------------------------------------

def _parse_cache(data) -> dict:
    """Validate the cache schema and return the device→entry mapping.
    Raises ``ValueError`` on any stale/foreign format (missing or
    mismatched ``schema``, pre-schema bare mappings) so callers
    re-measure instead of consuming drifted coefficients."""
    if not isinstance(data, dict) or data.get("schema") != SCHEMA_VERSION:
        raise ValueError(
            "costmodel cache: stale or unknown schema "
            f"(found {data.get('schema') if isinstance(data, dict) else data!r}, "
            f"expected {SCHEMA_VERSION}) — cache will be re-measured")
    models = data.get("models")
    if not isinstance(models, dict):
        raise ValueError("costmodel cache: missing 'models' mapping")
    return models


def load_cost_model(path, device: str | None = None) -> CostModel:
    """Load one device's calibration from a JSON cache file.  With
    ``device=None`` and a single-entry file, that entry is returned —
    the pinned-calibration form the determinism tests use.  A cache
    whose ``schema`` field is missing or mismatched raises ``ValueError``
    (stale-cache invalidation; ``default_cost_model`` then re-measures)."""
    models = _parse_cache(json.loads(pathlib.Path(path).read_text()))
    if device is None:
        if len(models) != 1:
            raise ValueError(
                f"load_cost_model: {path} holds calibrations for "
                f"{sorted(models)}; pass device= to pick one")
        device = next(iter(models))
    if device not in models:
        raise KeyError(
            f"load_cost_model: no calibration for device {device!r} in "
            f"{path} (have {sorted(models)})")
    entry = models[device]
    return CostModel(dispatch_us=float(entry["dispatch_us"]),
                     epoch_lane_us=float(entry["epoch_lane_us"]),
                     sync_us=float(entry["sync_us"]),
                     device=device, source="cache")


def save_cost_model(model: CostModel, path) -> None:
    """Merge one device's calibration into the cache file, stamping the
    current :data:`SCHEMA_VERSION`.  Entries from an unreadable or
    stale-schema file are discarded — never carried forward."""
    path = pathlib.Path(path)
    models = {}
    if path.exists():
        try:
            models = _parse_cache(json.loads(path.read_text()))
        except (OSError, ValueError):
            models = {}
    models[model.device] = model.to_json()
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps({"schema": SCHEMA_VERSION, "models": models},
                               indent=2) + "\n")


def default_cost_model(path=None, *, allow_measure: bool = True) -> CostModel:
    """The process-wide cost model: cached in memory, then in the JSON
    file at ``path`` (default ``$REPRO_COSTMODEL_PATH`` or
    ``~/.cache/repro-iotsim/costmodel.json``), then measured.  Never
    raises — an unwritable cache or failed measurement falls back to the
    conservative built-in coefficients."""
    key = device_key()
    if key in _CACHE:
        return _CACHE[key]
    path = pathlib.Path(path or os.environ.get(ENV_PATH, _DEFAULT_PATH))
    model = None
    if path.exists():
        try:
            model = load_cost_model(path, device=key)
        except (OSError, ValueError, KeyError):
            model = None
    if model is None and allow_measure:
        try:
            model = measure()
        except Exception:                      # pragma: no cover - env
            model = None
        if model is not None:
            try:
                save_cost_model(model, path)
            except OSError:                    # pragma: no cover - env
                pass
    if model is None:
        model = fallback_cost_model(key)
    _CACHE[key] = model
    return model
