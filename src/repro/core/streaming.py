"""Stream-computing layer (the paper's stated future work, §6).

Models a Storm-style topology: sources emit tuples at fixed rates into a
DAG of operators; each operator has a per-tuple service cost (MI) and
runs on a VM with bounded processing rate.  Fluid/queueing semantics:

* operator throughput = min(input rate, service rate),
* queue growth = input − throughput (unstable operators grow unbounded),
* end-to-end latency = queueing (steady-state, via utilization) +
  service along the critical path.

Vectorized over topologies like the batch engine — one ``vmap`` sweeps
operator placements/parallelism, answering the same provisioning
questions §5 answers for MapReduce.  Intentionally fluid-level (not
per-tuple DES): that is the right granularity for capacity analysis, and
it keeps the state fixed-shape for TPU execution.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Topology(NamedTuple):
    """Feed-forward operator DAG, topologically ordered.

    adj[i, j] = fraction of operator i's output routed to operator j
    (row sums ≤ 1).  Sources have ``source_rate > 0`` tuples/s.
    """
    adj: jax.Array            # f32[O, O]
    source_rate: jax.Array    # f32[O]
    service_mi: jax.Array     # f32[O] MI per tuple
    parallelism: jax.Array    # f32[O] replicas of the operator
    vm_mips: jax.Array        # f32[O] MIPS per replica


def analyze(topo: Topology) -> dict:
    """Steady-state rates, utilizations, stability and latency."""
    O = topo.adj.shape[0]
    svc_rate = topo.parallelism * topo.vm_mips / jnp.maximum(
        topo.service_mi, 1e-9)                      # tuples/s capacity

    def propagate(i, rates):
        inflow = topo.source_rate[i] + rates @ topo.adj[:, i]
        out = jnp.minimum(inflow, svc_rate[i])
        return rates.at[i].set(out)

    rates = jax.lax.fori_loop(0, O, propagate,
                              jnp.zeros(O, jnp.float32))
    inflow = topo.source_rate + rates @ topo.adj
    util = inflow / jnp.maximum(svc_rate, 1e-9)
    stable = util <= 1.0 + 1e-6
    # M/M/1-style queueing delay per op (capped for near-saturated ops)
    wait = jnp.where(util < 0.999,
                     util / jnp.maximum(svc_rate * (1.0 - util), 1e-9),
                     jnp.inf)
    service = topo.service_mi / (topo.vm_mips)
    # end-to-end latency: longest path in the DAG of (wait + service)
    node_cost = wait + service

    def longest(i, dist):
        best = jnp.max(jnp.where(topo.adj[:, i] > 0, dist, 0.0))
        return dist.at[i].set(best + node_cost[i])

    dist = jax.lax.fori_loop(0, O, longest, jnp.zeros(O, jnp.float32))
    return {
        "throughput": rates,
        "utilization": util,
        "stable": jnp.all(stable),
        "latency_s": jnp.max(dist),
        "bottleneck": jnp.argmax(util),
    }


analyze_batch = jax.jit(jax.vmap(analyze))


def smart_city_topology(*, cam_rate=2000.0, sensor_rate=5000.0,
                        parallelism=(1, 2, 2, 1, 1)) -> Topology:
    """5-op demo: [cam src, sensor src, detect, aggregate, alert]."""
    adj = jnp.zeros((5, 5), jnp.float32)
    adj = adj.at[0, 2].set(1.0)       # cams -> detect
    adj = adj.at[1, 3].set(1.0)       # sensors -> aggregate
    adj = adj.at[2, 3].set(0.2)       # detections -> aggregate
    adj = adj.at[3, 4].set(0.05)      # aggregates -> alert
    return Topology(
        adj=adj,
        source_rate=jnp.array([cam_rate, sensor_rate, 0, 0, 0],
                              jnp.float32),
        service_mi=jnp.array([0.01, 0.005, 0.8, 0.1, 0.5], jnp.float32),
        parallelism=jnp.asarray(parallelism, jnp.float32),
        vm_mips=jnp.full((5,), 1000.0, jnp.float32),
    )
