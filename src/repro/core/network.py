"""Network + storage delay model (paper §4.2.3, §5.3.5, §5.3.7).

The paper reports two delays for a MapReduce job in the "Network Delay" case:

* **stage-in** — JobTracker fetches the job's data from storage (HDFS) before
  maps can start;
* **shuffle** — each reducer reads the mappers' intermediate output before
  the reduce task can start.

``DelayTime = st_m(nm) + st_r(nr) - ft_m(nm)`` (paper §5.3.5).  Under
time-shared scheduling every map starts as soon as staged-in, so
``DelayTime = stage_in + shuffle`` and the paper's Table IV pins the total:
``DelayTime(M) = kappa * S / ((M + 1) * BW)`` with ``kappa = 21.25``
(2125 = 21.25 * 200000 / (2 * 1000) for M1R1 Small job).  See DESIGN.md §2.1
for the calibration argument.  The split between kappa_in and kappa_shuffle
is not observable from the paper's tables; we use 17 / 4.25.
"""
from __future__ import annotations

from .config import JobSpec, NetworkSpec


def transfer_delay(kappa, data_mb, n_maps, bw_mbps, enabled=1.0):
    """The single kappa formula both simulators share (DESIGN.md §2.1):

        delay = enabled * kappa * S / ((M + 1) * BW)

    Pure arithmetic on its operands, so it works identically for Python
    floats (the sequential oracle) and traced ``jnp`` arrays (the
    vectorized engine and the Pallas kernel wrapper) — the two layers
    cannot drift.  ``enabled`` is 0/1; when 0 the result must be exactly
    0.0 even if ``bw_mbps`` is 0 (disabled networks often leave bw unset),
    so the denominator is padded by ``1 - enabled`` — a no-op when enabled,
    branch-free when traced.
    """
    return (enabled * kappa * data_mb
            / ((n_maps + 1.0) * (bw_mbps + (1.0 - enabled))))


def stage_in_delay(job: JobSpec, net: NetworkSpec) -> float:
    """Delay between job submission and its map tasks becoming ready."""
    return transfer_delay(net.kappa_in, job.data_mb, job.n_maps,
                          net.bw_mbps, 1.0 if net.enabled else 0.0)


def shuffle_delay(job: JobSpec, net: NetworkSpec) -> float:
    """Delay between the last map finishing and reduces becoming ready."""
    return transfer_delay(net.kappa_shuffle, job.data_mb, job.n_maps,
                          net.bw_mbps, 1.0 if net.enabled else 0.0)


def delay_time(job: JobSpec, net: NetworkSpec) -> float:
    """Paper §5.3.5 Delay Time (st_m(nm) + st_r(nr) - ft_m(nm))."""
    return stage_in_delay(job, net) + shuffle_delay(job, net)


def network_cost(job: JobSpec, net: NetworkSpec) -> float:
    """Paper §5.3.7: NetworkCost = DelayTime x NetworkCostPerUnit."""
    return delay_time(job, net) * net.cost_per_unit
