"""Massive scenario sweeps: vmap over scenarios, pjit over the pod mesh.

CloudSim/IOTSim runs one scenario per JVM process; every figure in the paper
is a parameter sweep re-run by hand.  Here a sweep is one ``vmap`` of the
vectorized engine over a stacked :class:`ScenarioArrays` batch, sharded over
every mesh axis — a pod simulates millions of datacentre scenarios in one
``pjit`` call.  This is the headline TPU adaptation of the paper's technique
(DESIGN.md §2) and the subject of ``benchmarks/sweep_throughput.py``.

The declarative experiment API (DESIGN.md §4):

* :func:`axis` — one labeled sweep dimension over any ``Scenario``-level
  parameter (MR combination, VM count, per-VM mips/pes/cost vectors,
  policies, network knobs, VM/job presets);
* :func:`zip_` / :func:`product` — compose axes into a :class:`SweepPlan`
  (zipped axes advance together as one dimension; product axes span the
  full cartesian grid);
* :meth:`SweepPlan.run` — compile the plan into one device-side
  :class:`ScenarioArrays` batch and execute it (plain vmap, pod-sharded
  over a ``mesh``, or host-memory-``chunk``-ed), returning a labeled
  :class:`SweepResult` with ``select(**coords)`` / ``to_dict()`` lookup.

Lower-level builders (the compile targets — still public):

* :func:`stack_scenarios` — host-side: encode arbitrary ``Scenario`` objects
  (heterogeneous jobs/VMs) and stack with common padding;
* :func:`encode_cell` / :func:`grid_arrays` — device-side: build experiment
  cells (homogeneous *or* per-VM-heterogeneous) directly from traced
  parameters, entirely in jnp, so huge grids never materialize on the host.

``paper_grid`` / ``policy_grid`` are kept one release longer as thin shims
over :class:`SweepPlan` (see the DESIGN.md §4 migration note).
"""
from __future__ import annotations

import dataclasses
import enum
import inspect
from functools import lru_cache, partial
from typing import Any, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .config import (JOB_SMALL, VM_SMALL, BindingPolicy, Scenario,
                     SchedPolicy, as_job_spec, as_vm_spec,
                     base_task_lengths_f32)
from .engine import (JobMetrics, ScenarioArrays, ScenarioMetrics, bind_tasks,
                     from_scenario, job_metrics, scenario_metrics,
                     simulate_arrays)


# ---------------------------------------------------------------------------
# Host-side batch builder
# ---------------------------------------------------------------------------

def stack_scenarios(scenarios: Sequence[Scenario]) -> ScenarioArrays:
    """Encode + stack scenarios with shared padding (leading batch dim)."""
    T = max(s.total_tasks() for s in scenarios)
    J = max(len(s.jobs) for s in scenarios)
    V = max(len(s.vms) for s in scenarios)
    encoded = [from_scenario(s, pad_tasks=T, pad_jobs=J, pad_vms=V)
               for s in scenarios]
    return ScenarioArrays(*(np.stack([np.asarray(getattr(e, f))
                                      for e in encoded])
                            for f in ScenarioArrays._fields))


# ---------------------------------------------------------------------------
# Device-side cell encoder (paper §5 experiment cells + heterogeneous VMs)
# ---------------------------------------------------------------------------

def encode_cell(n_maps, n_reduces, n_vms, vm_mips, vm_pes, vm_cost,
                job_length, job_data, *, pad_tasks: int, pad_vms: int,
                reduce_factor=0.5, net_enabled=1.0, net_bw=1000.0,
                kappa_in=17.0, kappa_shuffle=4.25, net_cost_per_unit=1.0,
                task_mult=None, sched_policy=0,
                binding_policy=0) -> ScenarioArrays:
    """One paper cell as traced arrays — homogeneous or per-VM heterogeneous.

    ``vm_mips`` / ``vm_pes`` / ``vm_cost`` are **per-VM vectors** of length
    ``pad_vms`` (entries past ``n_vms`` are ignored); plain scalars are
    broadcast, reproducing the original homogeneous cells bit for bit.  With
    distinct per-VM values, LEAST_LOADED/PACKED binding differentiates inside
    device-side grids just as it does for host-encoded scenarios.

    All parameters may be traced — ``vmap`` this over parameter grids;
    ``sched_policy``/``binding_policy`` are plain i32 scalars, so one grid
    may mix policies (Group 5).  ``pad_tasks``/``pad_vms`` are static
    paddings (>= max M+R / max V).
    """
    f32 = partial(jnp.asarray, dtype=jnp.float32)
    i32 = partial(jnp.asarray, dtype=jnp.int32)
    t = jnp.arange(pad_tasks)
    n_maps, n_reduces, n_vms = i32(n_maps), i32(n_reduces), i32(n_vms)
    n_tasks = n_maps + n_reduces
    is_red = t >= n_maps
    valid = t < n_tasks
    if task_mult is None:
        task_mult = jnp.ones(pad_tasks, jnp.float32)
    vm_valid = jnp.arange(pad_vms) < n_vms
    vm_mips_a = jnp.where(vm_valid,
                          jnp.broadcast_to(f32(vm_mips), (pad_vms,)), 1.0)
    vm_pes_a = jnp.where(vm_valid,
                         jnp.broadcast_to(f32(vm_pes), (pad_vms,)), 1.0)
    vm_cost_a = jnp.where(vm_valid,
                          jnp.broadcast_to(f32(vm_cost), (pad_vms,)), 0.0)
    map_len, red_len = base_task_lengths_f32(
        f32(job_length), n_maps.astype(jnp.float32),
        n_reduces.astype(jnp.float32), f32(reduce_factor))
    base_len = jnp.where(is_red, red_len, map_len)
    return ScenarioArrays(
        task_job=jnp.zeros(pad_tasks, jnp.int32),
        task_is_reduce=is_red & valid,
        task_vm=bind_tasks(binding_policy, valid, base_len, vm_mips_a,
                           vm_pes_a, vm_valid),
        task_valid=valid,
        task_mult=task_mult,
        job_length=f32(job_length)[None],
        job_data=f32(job_data)[None],
        job_n_maps=n_maps[None],
        job_n_reduces=n_reduces[None],
        job_submit=jnp.zeros(1, jnp.float32),
        job_reduce_factor=f32(reduce_factor)[None],
        job_valid=jnp.ones(1, bool),
        vm_mips=vm_mips_a,
        vm_pes=vm_pes_a,
        vm_cost=vm_cost_a,
        vm_valid=vm_valid,
        net_enabled=f32(net_enabled), net_bw=f32(net_bw),
        kappa_in=f32(kappa_in), kappa_shuffle=f32(kappa_shuffle),
        net_cost_per_unit=f32(net_cost_per_unit),
        sched_policy=i32(sched_policy),
        binding_policy=i32(binding_policy),
    )


# encode_cell parameters an axis/grid may target (pads are static).
_CELL_PARAMS = tuple(p for p in inspect.signature(encode_cell).parameters
                     if p not in ("pad_tasks", "pad_vms"))
_INT_PARAMS = frozenset(
    {"n_maps", "n_reduces", "n_vms", "sched_policy", "binding_policy"})
_PER_VM = frozenset({"vm_mips", "vm_pes", "vm_cost"})


def grid_arrays(params: dict[str, np.ndarray], *, pad_tasks: int,
                pad_vms: int) -> ScenarioArrays:
    """vmap :func:`encode_cell` over equal-length parameter arrays.

    Each value is ``[N]`` (one scalar per cell) or ``[N, pad_vms]``
    (per-VM vectors for ``vm_mips``/``vm_pes``/``vm_cost``) /
    ``[N, pad_tasks]`` (``task_mult``).  Keys and leading lengths are
    validated up front — a mismatched key used to surface as an opaque
    vmap shape error deep inside the encoder.
    """
    names = list(params)
    if not names:
        raise ValueError("grid_arrays: empty parameter dict")
    unknown = [n for n in names if n not in _CELL_PARAMS]
    if unknown:
        raise ValueError(
            f"grid_arrays: unknown encode_cell parameter(s) {unknown}; "
            f"valid: {list(_CELL_PARAMS)}")
    sizes = {}
    for n in names:
        shape = np.shape(params[n])
        if len(shape) == 0:
            raise ValueError(
                f"grid_arrays: parameter {n!r} must be an array with a "
                "leading grid dimension (got a scalar)")
        if len(shape) == 2:
            if n in _PER_VM:
                want, pad = "pad_vms", pad_vms
            elif n == "task_mult":
                want, pad = "pad_tasks", pad_tasks
            else:
                raise ValueError(
                    f"grid_arrays: parameter {n!r} takes one scalar per "
                    f"cell, got 2-D shape {shape}")
            if shape[1] != pad:
                raise ValueError(
                    f"grid_arrays: {n!r} has trailing width {shape[1]}, "
                    f"expected {want}={pad}")
        elif len(shape) > 2:
            raise ValueError(
                f"grid_arrays: parameter {n!r} has {len(shape)} dims; "
                "at most [N, width] is supported")
        sizes[n] = shape[0]
    n0 = sizes[names[0]]
    bad = [f"{n} has length {sizes[n]}" for n in names if sizes[n] != n0]
    if bad:
        raise ValueError(
            "grid_arrays: parameter arrays must share one leading grid "
            f"length; {names[0]!r} has length {n0} but " + ", ".join(bad))
    encoder = _grid_encoder(tuple(names), pad_tasks, pad_vms)
    return encoder(*(jnp.asarray(params[n]) for n in names))


@lru_cache(maxsize=None)
def _grid_encoder(names: tuple[str, ...], pad_tasks: int, pad_vms: int):
    """One jitted vmapped encode_cell per (param set, padding) signature —
    repeated ``SweepPlan.run()`` calls re-encode at compiled speed instead
    of dispatching the encoder op by op."""
    def one(*xs):
        return encode_cell(**dict(zip(names, xs)), pad_tasks=pad_tasks,
                           pad_vms=pad_vms)
    return jax.jit(jax.vmap(one))


# ---------------------------------------------------------------------------
# Declarative sweep plans (DESIGN.md §4)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Axis:
    """One labeled sweep dimension.

    ``names`` are the coordinate names addressable in
    :meth:`SweepResult.select` (more than one after :func:`zip_`);
    ``labels`` holds one tuple of coordinate values per point (aligned with
    ``names``); ``columns`` maps encode_cell parameters to ``[n, ...]``
    encoded value columns.  Build through :func:`axis`, compose with
    :func:`zip_` / :func:`product`.
    """
    names: tuple[str, ...]
    labels: tuple[tuple[Any, ...], ...]
    columns: Mapping[str, np.ndarray]

    def __len__(self) -> int:
        return len(self.labels)


def axis(name: str, values: Sequence[Any]) -> Axis:
    """One sweep dimension: ``name`` + the values it takes.

    ``name`` is either a raw :func:`encode_cell` parameter (``n_maps``,
    ``n_vms``, ``vm_mips`` …, values scalars — or per-VM vectors for the
    ``vm_*`` parameters) or a convenience spec axis:

    * ``"vm"``/``"vm_type"`` — values are ``VMSpec`` or Table-II type names;
      expands to homogeneous ``vm_mips``/``vm_pes``/``vm_cost``;
    * ``"vms"`` — values are *sequences* of VMSpec/type names (one cluster
      per point, may differ in size): per-VM heterogeneous cells, expands
      to ``n_vms`` + per-VM ``vm_mips``/``vm_pes``/``vm_cost`` vectors;
    * ``"job"``/``"job_type"`` — ``JobSpec`` or Table-III names; expands to
      ``job_length``/``job_data``/``reduce_factor`` (MR combination stays
      a separate ``n_maps``/``n_reduces`` axis, as in the paper);
    * ``"sched_policy"``/``"binding_policy"`` — enum members or ints;
    * ``"network_delay"`` — bools, expands to ``net_enabled``.
    """
    values = list(values)
    if not values:
        raise ValueError(f"axis {name!r}: empty value list")
    f32 = partial(np.asarray, dtype=np.float32)
    if name in ("vm", "vm_type"):
        specs = [as_vm_spec(v) for v in values]
        return Axis((name,), tuple((s.name,) for s in specs), {
            "vm_mips": f32([s.mips for s in specs]),
            "vm_pes": f32([float(s.pes) for s in specs]),
            "vm_cost": f32([s.cost_per_sec for s in specs]),
        })
    if name == "vms":
        clusters = [tuple(as_vm_spec(v) for v in vs) for vs in values]
        if any(not c for c in clusters):
            raise ValueError("axis 'vms': every point needs >= 1 VM")
        V = max(len(c) for c in clusters)

        def col(get):
            out = np.zeros((len(clusters), V), np.float32)
            for i, c in enumerate(clusters):
                out[i, :len(c)] = [get(s) for s in c]
            return out

        return Axis((name,),
                    tuple((tuple(s.name for s in c),) for c in clusters), {
            "n_vms": np.asarray([len(c) for c in clusters], np.int32),
            "vm_mips": col(lambda s: s.mips),
            "vm_pes": col(lambda s: float(s.pes)),
            "vm_cost": col(lambda s: s.cost_per_sec),
        })
    if name in ("job", "job_type"):
        specs = [as_job_spec(v) for v in values]
        return Axis((name,), tuple((s.name,) for s in specs), {
            "job_length": f32([s.length_mi for s in specs]),
            "job_data": f32([s.data_mb for s in specs]),
            "reduce_factor": f32([s.reduce_factor for s in specs]),
        })
    if name == "network_delay":
        labels = tuple((bool(v),) for v in values)
        return Axis((name,), labels,
                    {"net_enabled": f32([1.0 if v else 0.0 for v in values])})
    if name == "sched_policy":
        members = [SchedPolicy(v) for v in values]
        return Axis((name,), tuple((m,) for m in members),
                    {name: np.asarray(members, np.int32)})
    if name == "binding_policy":
        members = [BindingPolicy(v) for v in values]
        return Axis((name,), tuple((m,) for m in members),
                    {name: np.asarray(members, np.int32)})
    if name not in _CELL_PARAMS:
        raise ValueError(
            f"axis {name!r}: not an encode_cell parameter or spec axis; "
            f"valid: {list(_CELL_PARAMS)} + ['vm', 'vm_type', 'vms', 'job', "
            "'job_type', 'network_delay']")
    if any(np.ndim(v) > 0 for v in values):        # per-VM / per-task vectors
        if name not in _PER_VM and name != "task_mult":
            raise ValueError(
                f"axis {name!r}: vector values only make sense for the "
                f"per-VM parameters {sorted(_PER_VM)} or 'task_mult'; "
                f"{name!r} takes one scalar per cell")
        if not all(np.ndim(v) == 1 for v in values):
            raise ValueError(
                f"axis {name!r}: vector values must all be 1-D with one "
                "shared length (use the 'vms' axis for ragged clusters)")
        widths = {int(np.shape(v)[0]) for v in values}
        if len(widths) != 1:
            raise ValueError(
                f"axis {name!r}: vector values must share one length, got "
                f"{sorted(widths)} (use the 'vms' axis for ragged clusters)")
        return Axis((name,), tuple((tuple(np.asarray(v).tolist()),)
                                   for v in values),
                    {name: np.stack([f32(v) for v in values])})
    dtype = np.int32 if name in _INT_PARAMS else np.float32
    return Axis((name,), tuple((v,) for v in values),
                {name: np.asarray(values, dtype)})


def zip_(*axes: Axis) -> Axis:
    """Fuse equal-length axes into one dimension that advances together
    (e.g. co-varying ``n_maps`` with ``job_length``), like Python ``zip``."""
    if not axes:
        raise ValueError("zip_: need at least one axis")
    lens = {"x".join(a.names): len(a) for a in axes}
    if len(set(lens.values())) != 1:
        raise ValueError(f"zip_: axes must share one length; got {lens}")
    columns: dict[str, np.ndarray] = {}
    for a in axes:
        for cname, col in a.columns.items():
            if cname in columns:
                raise ValueError(
                    f"zip_: parameter {cname!r} set by more than one axis")
            columns[cname] = col
    names = tuple(n for a in axes for n in a.names)
    if len(set(names)) != len(names):
        raise ValueError(f"zip_: duplicate coordinate names in {names}")
    labels = tuple(tuple(part for a in axes for part in a.labels[i])
                   for i in range(len(axes[0])))
    return Axis(names, labels, columns)


def product(*dims: Axis, **base: Any) -> "SweepPlan":
    """Cartesian :class:`SweepPlan` over ``dims`` (row-major: the last axis
    varies fastest).  ``base`` pins non-swept parameters for every cell —
    any :func:`axis` name with a single value (``vm_type="medium"``,
    ``network_delay=False``, ``vms=("medium", "small")``, ``n_maps=12`` …).
    """
    return SweepPlan(dims=tuple(dims), base=dict(base))


# Paper defaults for parameters no axis/base sets: the §5 baseline cell
# (3 small VMs, one small M1R1 job) — same defaults as config.paper_scenario.
_DEFAULTS: dict[str, float] = dict(
    n_maps=1, n_reduces=1, n_vms=3,
    vm_mips=VM_SMALL.mips, vm_pes=float(VM_SMALL.pes),
    vm_cost=VM_SMALL.cost_per_sec,
    job_length=JOB_SMALL.length_mi, job_data=JOB_SMALL.data_mb,
)


@dataclasses.dataclass(frozen=True)
class SweepPlan:
    """A declarative experiment plan: labeled axes × pinned base parameters.

    Compiles to one device-side :class:`ScenarioArrays` batch
    (:meth:`arrays`) and executes through :meth:`run`, which returns a
    labeled :class:`SweepResult`.  ``pad_tasks``/``pad_vms`` override the
    inferred paddings (e.g. to share one lowering across several plans).
    """
    dims: tuple[Axis, ...]
    base: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    pad_tasks: int | None = None
    pad_vms: int | None = None

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(len(d) for d in self.dims)

    @property
    def size(self) -> int:
        return int(np.prod(self.shape, dtype=np.int64)) if self.dims else 1

    def replace(self, **kw) -> "SweepPlan":
        return dataclasses.replace(self, **kw)

    def _compiled(self) -> tuple[dict[str, np.ndarray], int, int]:
        """Flatten axes + base + defaults into N-cell parameter columns."""
        shape, N = self.shape, self.size
        cols: dict[str, np.ndarray] = {}
        owner: dict[str, str] = {}
        for k, dim in enumerate(self.dims):
            outer = int(np.prod(shape[:k], dtype=np.int64))
            inner = int(np.prod(shape[k + 1:], dtype=np.int64))
            idx = np.tile(np.repeat(np.arange(shape[k]), inner), outer)
            src = "axis " + "×".join(dim.names)
            for cname, col in dim.columns.items():
                if cname in cols:
                    raise ValueError(
                        f"SweepPlan: parameter {cname!r} set by both "
                        f"{owner[cname]} and {src}")
                cols[cname] = np.asarray(col)[idx]
                owner[cname] = src
        for bname, value in self.base.items():
            for cname, col in axis(bname, [value]).columns.items():
                if cname in cols:
                    raise ValueError(
                        f"SweepPlan: parameter {cname!r} set by both "
                        f"{owner[cname]} and base argument {bname!r}")
                c = np.asarray(col)
                cols[cname] = np.broadcast_to(c[0], (N,) + c.shape[1:])
                owner[cname] = f"base argument {bname!r}"
        for cname, default in _DEFAULTS.items():
            if cname not in cols:
                dtype = np.int32 if cname in _INT_PARAMS else np.float32
                cols[cname] = np.full(N, default, dtype)
        n_tasks = int((cols["n_maps"].astype(np.int64)
                       + cols["n_reduces"].astype(np.int64)).max())
        pad_tasks = self.pad_tasks if self.pad_tasks is not None else n_tasks
        v_needed = max(int(cols["n_vms"].max()),
                       *(c.shape[1] for n, c in cols.items()
                         if n in _PER_VM and c.ndim == 2), 1)
        pad_vms = self.pad_vms if self.pad_vms is not None else v_needed
        if pad_tasks < n_tasks or pad_vms < v_needed:
            raise ValueError(
                f"SweepPlan: padding too small — need pad_tasks>={n_tasks} "
                f"(got {pad_tasks}), pad_vms>={v_needed} (got {pad_vms})")
        n_vms_max = int(cols["n_vms"].max())
        for cname in _PER_VM:
            c = cols[cname]
            if c.ndim != 2:
                continue
            if c.shape[1] < n_vms_max:
                raise ValueError(
                    f"SweepPlan: per-VM column {cname!r} has width "
                    f"{c.shape[1]} but some cell has n_vms={n_vms_max}; "
                    "give every VM vector >= n_vms entries (or use the "
                    "'vms' axis, which sets n_vms itself)")
            if c.shape[1] < pad_vms:
                cols[cname] = np.pad(c, ((0, 0), (0, pad_vms - c.shape[1])))
        if "task_mult" in cols and cols["task_mult"].shape[1] != pad_tasks:
            tm = cols["task_mult"]
            if tm.shape[1] > pad_tasks:
                raise ValueError(
                    f"SweepPlan: task_mult width {tm.shape[1]} exceeds "
                    f"pad_tasks={pad_tasks}")
            cols["task_mult"] = np.pad(
                tm, ((0, 0), (0, pad_tasks - tm.shape[1])),
                constant_values=1.0)
        return cols, pad_tasks, pad_vms

    def params(self) -> dict[str, np.ndarray]:
        """The flattened ``grid_arrays`` parameter columns (host numpy)."""
        return self._compiled()[0]

    def arrays(self) -> ScenarioArrays:
        """Compile to one device-side batch (leading dim = flattened grid)."""
        cols, pad_tasks, pad_vms = self._compiled()
        return grid_arrays(cols, pad_tasks=pad_tasks, pad_vms=pad_vms)

    def run(self, mesh: jax.sharding.Mesh | None = None,
            chunk: int | None = None) -> "SweepResult":
        """Execute the plan and return a labeled :class:`SweepResult`.

        * default — one jitted vmap over the whole batch;
        * ``mesh`` — scenarios sharded over every mesh axis (the pod path;
          the grid is padded up to a device-count multiple and trimmed);
        * ``chunk`` — at most ``chunk`` cells encoded + simulated per call
          (one shared lowering; results accumulate in host memory), for
          grids larger than device memory.
        """
        if mesh is not None and chunk is not None:
            raise ValueError("run: pass mesh or chunk, not both")
        cols, pad_tasks, pad_vms = self._compiled()
        N = self.size
        if mesh is not None:
            n_dev = int(mesh.devices.size)
            full = -(-N // n_dev) * n_dev
            batch = grid_arrays(_pad_cells(cols, full),
                                pad_tasks=pad_tasks, pad_vms=pad_vms)
            jm, sm = _simulate_full_sharded(batch, mesh)
        elif chunk is not None:
            if chunk < 1:
                raise ValueError(f"run: chunk must be >= 1, got {chunk}")
            parts = []
            for lo in range(0, N, chunk):
                part = {k: v[lo:lo + chunk] for k, v in cols.items()}
                batch = grid_arrays(_pad_cells(part, chunk),
                                    pad_tasks=pad_tasks, pad_vms=pad_vms)
                parts.append(jax.tree.map(np.asarray, _simulate_full(batch)))
            jm, sm = jax.tree.map(lambda *xs: np.concatenate(xs), *parts)
        else:
            jm, sm = _simulate_full(
                grid_arrays(cols, pad_tasks=pad_tasks, pad_vms=pad_vms))
        jm = jax.tree.map(lambda x: np.asarray(x)[:N], jm)
        sm = jax.tree.map(lambda x: np.asarray(x)[:N], sm)
        n_jobs = jm.makespan.shape[-1]
        metrics: dict[str, np.ndarray] = {}
        for f in JobMetrics._fields:
            a = getattr(jm, f)
            metrics[f] = a.reshape(self.shape if n_jobs == 1
                                   else self.shape + (n_jobs,))
        for f in ScenarioMetrics._fields:
            metrics[f] = getattr(sm, f).reshape(self.shape)
        return SweepResult(axis_names=tuple(d.names for d in self.dims),
                           axis_labels=tuple(d.labels for d in self.dims),
                           metrics=metrics, n_jobs=n_jobs)


def _pad_cells(cols: dict[str, np.ndarray], n: int) -> dict[str, np.ndarray]:
    """Pad parameter columns to ``n`` cells by repeating the last cell."""
    have = len(next(iter(cols.values())))
    if have == n:
        return cols
    return {k: np.concatenate([v, np.repeat(v[-1:], n - have, axis=0)])
            for k, v in cols.items()}


def _match_label(label, want) -> bool:
    if label is want:
        return True
    if isinstance(label, enum.Enum) and isinstance(want, str):
        return label.name == want
    try:
        return bool(label == want)
    except (TypeError, ValueError):
        return False


@dataclasses.dataclass(frozen=True)
class SweepResult:
    """Labeled sweep output: axis coordinates + named metric arrays.

    ``metrics[name]`` has the plan's grid shape (per-job metrics gain a
    trailing job dim when a cell holds more than one job).  Per-job metrics
    are the paper's §5.3 dependent variables (:class:`JobMetrics` fields,
    including ``completion``); per-scenario extras are ``finish_time``,
    ``utilization`` and ``n_epochs`` (:class:`ScenarioMetrics`).
    """
    axis_names: tuple[tuple[str, ...], ...]
    axis_labels: tuple[tuple[tuple[Any, ...], ...], ...]
    metrics: Mapping[str, np.ndarray]
    n_jobs: int = 1

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(len(labs) for labs in self.axis_labels)

    @property
    def metric_names(self) -> tuple[str, ...]:
        return tuple(self.metrics)

    def __getitem__(self, name: str) -> np.ndarray:
        try:
            return self.metrics[name]
        except KeyError:
            raise KeyError(f"no metric {name!r}; "
                           f"available: {list(self.metrics)}") from None

    def coord(self, index: Sequence[int]) -> dict[str, Any]:
        """Axis coordinates of one grid point (e.g. from unravel_index)."""
        out: dict[str, Any] = {}
        for d, (names, labs) in enumerate(zip(self.axis_names,
                                              self.axis_labels)):
            out.update(zip(names, labs[int(index[d])]))
        return out

    def select(self, **coords: Any) -> "SweepResult":
        """Slice by axis-coordinate labels (``select(n_maps=4,
        vm_type="medium")``).  Coordinates matching exactly one point drop
        their dimension; several matches keep a filtered dimension.  Zipped
        dimensions are addressed through any of their component names —
        several components of one zipped dimension constrain it jointly."""
        names = list(self.axis_names)
        labels = list(self.axis_labels)
        metrics = dict(self.metrics)
        by_dim: dict[int, dict[str, Any]] = {}
        for key, want in coords.items():
            for d, ns in enumerate(names):
                if key in ns:
                    by_dim.setdefault(d, {})[key] = want
                    break
            else:
                raise KeyError(
                    f"select: no axis {key!r}; axes: "
                    f"{[n for ns in names for n in ns]}")
        for d in sorted(by_dim, reverse=True):   # right-to-left: stable axes
            wants = by_dim[d]
            comp = {k: names[d].index(k) for k in wants}
            hits = [i for i, lab in enumerate(labels[d])
                    if all(_match_label(lab[comp[k]], w)
                           for k, w in wants.items())]
            if not hits:
                raise KeyError(
                    f"select: {wants} not on the axis "
                    f"{'×'.join(names[d])}; labels: {list(labels[d])}")
            if len(hits) == 1:
                metrics = {k: v.take(hits[0], axis=d)
                           for k, v in metrics.items()}
                del names[d], labels[d]
            else:
                metrics = {k: v.take(hits, axis=d) for k, v in metrics.items()}
                labels[d] = tuple(labels[d][i] for i in hits)
        return SweepResult(tuple(names), tuple(labels), metrics, self.n_jobs)

    def to_dict(self) -> dict[str, Any]:
        """Metrics as plain ``{name: ndarray}`` (0-d arrays as scalars)."""
        return {k: (v.item() if np.ndim(v) == 0 else np.asarray(v))
                for k, v in self.metrics.items()}

    def __repr__(self) -> str:
        ax = ", ".join(f"{'×'.join(ns)}[{len(labs)}]"
                       for ns, labs in zip(self.axis_names, self.axis_labels))
        return (f"SweepResult(axes=({ax}), n_jobs={self.n_jobs}, "
                f"metrics={list(self.metrics)})")


# ---------------------------------------------------------------------------
# Batched simulation entry points
# ---------------------------------------------------------------------------

def _one_full(sc: ScenarioArrays) -> tuple[JobMetrics, ScenarioMetrics]:
    out = simulate_arrays(sc)
    return job_metrics(sc, out), scenario_metrics(sc, out)


@jax.jit
def _simulate_full(batch: ScenarioArrays):
    """vmap engine + per-job and per-scenario metrics (the ``run()`` body)."""
    return jax.vmap(_one_full)(batch)


@lru_cache(maxsize=None)
def _sharded_runner(mesh: jax.sharding.Mesh):
    """One jitted sharded simulate per mesh — repeated ``run(mesh=…)`` calls
    reuse the compilation instead of retracing through a fresh lambda."""
    sharding = jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec(mesh.axis_names))
    return jax.jit(jax.vmap(_one_full), in_shardings=sharding,
                   out_shardings=sharding)


def _simulate_full_sharded(batch: ScenarioArrays, mesh: jax.sharding.Mesh):
    return _sharded_runner(mesh)(batch)


@jax.jit
def simulate_batch(batch: ScenarioArrays) -> JobMetrics:
    """vmap the engine + metrics over a leading scenario dim."""
    def one(sc):
        return job_metrics(sc, simulate_arrays(sc))
    return jax.vmap(one)(batch)


def simulate_batch_sharded(batch: ScenarioArrays,
                           mesh: jax.sharding.Mesh) -> JobMetrics:
    """The pod-scale path: scenarios sharded over every mesh axis.

    The engine is embarrassingly parallel across scenarios, so the batch dim
    is sharded over the flattened mesh; no collectives are emitted (verified
    in the dry-run — this workload is the compute-roofline end of the
    simulator story).
    """
    spec = jax.sharding.PartitionSpec(mesh.axis_names)
    sharding = jax.sharding.NamedSharding(mesh, spec)
    fn = jax.jit(
        lambda b: jax.vmap(lambda s: job_metrics(s, simulate_arrays(s)))(b),
        in_shardings=(jax.tree.map(lambda _: sharding, batch),),
        out_shardings=sharding)
    return fn(batch)


# ---------------------------------------------------------------------------
# Legacy grid builders — thin SweepPlan shims, kept one release longer
# ---------------------------------------------------------------------------

def paper_grid(m_range=range(1, 21), vm_numbers=(3,), vm_types=("small",),
               job_types=("small",), network_delay=True,
               sched_policy=SchedPolicy.TIME_SHARED,
               binding_policy=BindingPolicy.ROUND_ROBIN) -> ScenarioArrays:
    """Cartesian paper grid (Groups 1–4) as a device-side batch.

    Deprecated shim: build the equivalent :class:`SweepPlan` directly (see
    DESIGN.md §4); this keeps the PR-1 call sites working one release
    longer.  Cell order is unchanged (row-major, ``job_types`` fastest).
    """
    plan = product(
        axis("n_maps", m_range),
        axis("n_vms", vm_numbers),
        axis("vm_type", vm_types),
        axis("job_type", job_types),
        network_delay=network_delay,
        sched_policy=sched_policy,
        binding_policy=binding_policy,
    )
    return plan.arrays()


def policy_grid(m_range=range(1, 21), n_vms=3, vm_type="small",
                job_type="small", network_delay=True) -> tuple[
                    ScenarioArrays, list[tuple[SchedPolicy, BindingPolicy]]]:
    """Group 5 (beyond-paper): the paper's Group-1 sweep crossed with every
    (sched_policy × binding_policy) combination — one mixed-policy batch,
    one lowering.  Returns the batch plus the per-block policy labels
    (block i covers rows [i*len(m_range), (i+1)*len(m_range))).

    Deprecated shim over :class:`SweepPlan` (DESIGN.md §4) — the plan's
    labeled ``select(sched_policy=…, binding_policy=…)`` replaces the
    per-block row bookkeeping.
    """
    plan = product(
        axis("sched_policy", list(SchedPolicy)),
        axis("binding_policy", list(BindingPolicy)),
        axis("n_maps", m_range),
        n_vms=n_vms, vm_type=vm_type, job_type=job_type,
        network_delay=network_delay,
    )
    combos = [(sp, bp) for sp in SchedPolicy for bp in BindingPolicy]
    return plan.arrays(), combos
