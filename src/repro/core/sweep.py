"""Massive scenario sweeps: vmap over scenarios, pjit over the pod mesh.

CloudSim/IOTSim runs one scenario per JVM process; every figure in the paper
is a parameter sweep re-run by hand.  Here a sweep is one ``vmap`` of the
vectorized engine over a stacked :class:`ScenarioArrays` batch, sharded over
every mesh axis — a pod simulates millions of datacentre scenarios in one
``pjit`` call.  This is the headline TPU adaptation of the paper's technique
(DESIGN.md §2) and the subject of ``benchmarks/sweep_throughput.py``.

The declarative experiment API (DESIGN.md §4):

* :func:`axis` — one labeled sweep dimension over any ``Scenario``-level
  parameter (MR combination, VM count, per-VM mips/pes/cost vectors,
  policies, network knobs, VM/job presets);
* :func:`zip_` / :func:`product` — compose axes into a :class:`SweepPlan`
  (zipped axes advance together as one dimension; product axes span the
  full cartesian grid);
* :meth:`SweepPlan.run` — compile the plan into device-side
  :class:`ScenarioArrays` batches and execute them (plain vmap, pod-sharded
  over a ``mesh``, or host-memory-``chunk``-ed), returning a labeled
  :class:`SweepResult` with ``select(**coords)`` / ``to_dict()`` lookup.

``run()`` executes an *adaptive schedule* (DESIGN.md §6): cells are grouped
into a small set of padded-shape buckets (heterogeneous grids stop paying
for the grid-wide max (T, V) padding), each bucket runs the batch-level
early-exit engine (``engine.simulate_batch_arrays`` — one shared epoch loop
that stops at the batch's realized epoch count), and the realized count is
exposed as the ``realized_epochs`` metric.  ``bucket=False`` restores the
single max-shape batch; results are bit-identical either way.

Lower-level builders (the compile targets — still public):

* :func:`stack_scenarios` — host-side: encode arbitrary ``Scenario`` objects
  (heterogeneous jobs/VMs) and stack with common padding;
* :func:`encode_cell` / :func:`grid_arrays` — device-side: build experiment
  cells (homogeneous *or* per-VM-heterogeneous) directly from traced
  parameters, entirely in jnp, so huge grids never materialize on the host.
"""
from __future__ import annotations

import dataclasses
import enum
import inspect
import time
from functools import lru_cache, partial
from typing import Any, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import costmodel as costmodel_mod
from . import elasticity as elasticity_mod
from . import storage as storage_mod
from . import telemetry
from .config import (JOB_SMALL, VM_SMALL, BindingPolicy, Scenario,
                     SchedPolicy, as_job_spec, as_vm_spec,
                     base_task_lengths_f32)
from .control import ControlPolicy, as_control_policy
from .control import DeadlinePolicy, as_deadline_policy
from .control import failure_times as _failure_times
from .elasticity import ElasticitySpec, as_arrival_process
from .engine import (_BIG, JobMetrics, ScenarioArrays, ScenarioMetrics,
                     bind_tasks, from_scenario, job_metrics,
                     scenario_metrics, simulate_arrays,
                     simulate_batch_arrays, simulate_batch_arrays_compact)
from .util import pow2_pad, pow2_pads
from .storage import Placement, StorageSpec, as_placement

_DEFAULT_STORAGE = StorageSpec()    # encode_cell defaults == Scenario's
_DEFAULT_ELASTICITY = ElasticitySpec()


# ---------------------------------------------------------------------------
# Host-side batch builder
# ---------------------------------------------------------------------------

def stack_scenarios(scenarios: Sequence[Scenario]) -> ScenarioArrays:
    """Encode + stack scenarios with shared padding (leading batch dim)."""
    T = max(s.total_tasks() for s in scenarios)
    J = max(len(s.jobs) for s in scenarios)
    V = max(len(s.vms) for s in scenarios)
    encoded = [from_scenario(s, pad_tasks=T, pad_jobs=J, pad_vms=V)
               for s in scenarios]
    return ScenarioArrays(*(np.stack([np.asarray(getattr(e, f))
                                      for e in encoded])
                            for f in ScenarioArrays._fields))


# ---------------------------------------------------------------------------
# Device-side cell encoder (paper §5 experiment cells + heterogeneous VMs)
# ---------------------------------------------------------------------------

def encode_cell(n_maps, n_reduces, n_vms, vm_mips, vm_pes, vm_cost,
                job_length, job_data, *, pad_tasks: int, pad_vms: int,
                reduce_factor=0.5, net_enabled=1.0, net_bw=1000.0,
                kappa_in=17.0, kappa_shuffle=4.25, net_cost_per_unit=1.0,
                task_mult=None, sched_policy=0, binding_policy=0,
                storage_enabled=0.0,
                block_size_mb=_DEFAULT_STORAGE.block_size_mb,
                replication=_DEFAULT_STORAGE.replication,
                placement=int(_DEFAULT_STORAGE.placement),
                storage_seed=_DEFAULT_STORAGE.seed,
                job_submit=0.0, vm_start=0.0, vm_stop=_BIG,
                spinup_delay=_DEFAULT_ELASTICITY.spinup_delay,
                billing_granularity=_DEFAULT_ELASTICITY.billing_granularity,
                task_prio=None, vm_fail=_BIG, vm_restore=_BIG, vm_auto=0.0,
                control_policy=0, ctl_queue=0.0, ctl_busy=0.0,
                redispatch_delay=0.0, task_deadline=None,
                deadline_policy=0, deadline_slack=0.0, preempt=0,
                preempt_resume=0) -> ScenarioArrays:
    """One paper cell as traced arrays — homogeneous or per-VM heterogeneous.

    ``vm_mips`` / ``vm_pes`` / ``vm_cost`` are **per-VM vectors** of length
    ``pad_vms`` (entries past ``n_vms`` are ignored); plain scalars are
    broadcast, reproducing the original homogeneous cells bit for bit.  With
    distinct per-VM values, LEAST_LOADED/PACKED binding differentiates inside
    device-side grids just as it does for host-encoded scenarios.

    The storage model (DESIGN.md §7) is realized device-side when
    ``storage_enabled`` is on: the seeded block placement
    (``storage.map_block_placement`` — the same uint32/f32 op sequence the
    host encoder runs, bit for bit) becomes per-task ``block_vm`` /
    ``block_size`` data, LOCALITY binding draws its candidate mask from
    it, and every policy's off-replica map tasks pick up the remote-fetch
    delay inside the engine.  A *statically* disabled store (the plain
    Python default) skips the placement math entirely, so pre-storage
    grids pay nothing.

    Elasticity (DESIGN.md §8): ``vm_start``/``vm_stop`` are per-VM lease
    windows (scalars broadcast; ``vm_stop`` clamps to the engine's ``_BIG``
    +inf stand-in), ``spinup_delay`` delays admission past the lease
    start, ``billing_granularity`` sets the pay-as-you-go charge unit, and
    ``job_submit`` is the cell's job arrival instant (an arrival-process
    draw under :func:`arrivals`).  ``task_prio`` is a per-task priority
    vector (``pad_tasks`` wide, like ``task_mult``).  The defaults — lease
    ``[0, inf)``, no spinup, zero priorities — reproduce the static-fleet
    encoding bit for bit.

    Closed-loop control (DESIGN.md §10): ``vm_fail``/``vm_restore`` are
    per-VM failure/restore instants (scalars broadcast; ``_BIG`` = never —
    draw them host-side with :func:`repro.core.control.failure_times` or
    the :func:`failures` axis so every layer shares one f32 stream),
    ``vm_auto`` marks reserve VMs (0/1 per VM), ``control_policy`` is the
    i32 :class:`~repro.core.control.ControlPolicy` id, and
    ``ctl_queue``/``ctl_busy``/``redispatch_delay`` are the f32 autoscale
    thresholds and broker re-dispatch latency.  The defaults encode the
    open-loop scenario bit for bit — and the sweep runners only take the
    control-enabled engine path when one of these columns is present in
    the plan at all.

    Graceful degradation (DESIGN.md §11): ``task_deadline`` is a per-task
    completion-deadline vector (``pad_tasks`` wide, like ``task_mult``;
    ``_BIG`` = none, the default), ``deadline_policy`` is the i32
    :class:`~repro.core.control.DeadlinePolicy` id, ``deadline_slack``
    widens the BOOST urgency window, and ``preempt``/``preempt_resume``
    are the 0/1 priority-preemption knobs (pair them with a ``task_prio``
    column — preemption acts on raw priorities).  These ride the same
    control path gate; the defaults reproduce the §10 encoding bit for
    bit.

    All parameters may be traced — ``vmap`` this over parameter grids;
    ``sched_policy``/``binding_policy`` are plain i32 scalars, so one grid
    may mix policies (Group 5).  ``pad_tasks``/``pad_vms`` are static
    paddings (>= max M+R / max V).
    """
    f32 = partial(jnp.asarray, dtype=jnp.float32)
    i32 = partial(jnp.asarray, dtype=jnp.int32)
    t = jnp.arange(pad_tasks)
    n_maps, n_reduces, n_vms = i32(n_maps), i32(n_reduces), i32(n_vms)
    n_tasks = n_maps + n_reduces
    is_red = t >= n_maps
    valid = t < n_tasks
    if task_mult is None:
        task_mult = jnp.ones(pad_tasks, jnp.float32)
    if task_prio is None:
        task_prio = jnp.zeros(pad_tasks, jnp.float32)
    if task_deadline is None:
        task_deadline = jnp.full(pad_tasks, _BIG, jnp.float32)
    vm_valid = jnp.arange(pad_vms) < n_vms
    vm_mips_a = jnp.where(vm_valid,
                          jnp.broadcast_to(f32(vm_mips), (pad_vms,)), 1.0)
    vm_pes_a = jnp.where(vm_valid,
                         jnp.broadcast_to(f32(vm_pes), (pad_vms,)), 1.0)
    vm_cost_a = jnp.where(vm_valid,
                          jnp.broadcast_to(f32(vm_cost), (pad_vms,)), 0.0)
    vm_start_a = jnp.where(vm_valid,
                           jnp.broadcast_to(f32(vm_start), (pad_vms,)), 0.0)
    vm_stop_a = jnp.where(
        vm_valid,
        jnp.minimum(jnp.broadcast_to(f32(vm_stop), (pad_vms,)),
                    jnp.float32(_BIG)), jnp.float32(_BIG))
    # control arrays: padding / invalid VMs never fail and are not reserves
    vm_fail_a = jnp.where(
        vm_valid,
        jnp.minimum(jnp.broadcast_to(f32(vm_fail), (pad_vms,)),
                    jnp.float32(_BIG)), jnp.float32(_BIG))
    vm_restore_a = jnp.where(
        vm_valid,
        jnp.minimum(jnp.broadcast_to(f32(vm_restore), (pad_vms,)),
                    jnp.float32(_BIG)), jnp.float32(_BIG))
    vm_auto_a = vm_valid & (jnp.broadcast_to(f32(vm_auto), (pad_vms,)) > 0.5)
    map_len, red_len = base_task_lengths_f32(
        f32(job_length), n_maps.astype(jnp.float32),
        n_reduces.astype(jnp.float32), f32(reduce_factor))
    base_len = jnp.where(is_red, red_len, map_len)

    static_off = (not isinstance(storage_enabled, jax.core.Tracer)
                  and np.ndim(storage_enabled) == 0
                  and float(storage_enabled) == 0.0)
    if static_off:
        block_vm = jnp.full((pad_tasks, pad_vms), -1, jnp.int32)
        block_mb = jnp.zeros(pad_tasks, jnp.float32)
        cand = None     # LOCALITY falls back to the LEAST_LOADED scan
    else:
        # maps occupy task slots [0, n_maps) for the single encoded job,
        # so the slot index doubles as the map index
        rep_vm, rep_mb = storage_mod.map_block_placement(
            jnp, t, jnp.zeros(pad_tasks, jnp.int32), seed=storage_seed,
            placement=placement, replication=replication,
            block_size_mb=block_size_mb, job_data=job_data, n_vms=n_vms,
            pad_vms=pad_vms)
        on = f32(storage_enabled) > 0.5
        is_map = valid & ~is_red
        block_vm = jnp.where(on & is_map[:, None], rep_vm, -1)
        block_mb = jnp.where(on & is_map, rep_mb, 0.0)
        cand = storage_mod.locality_candidates(jnp, block_vm, vm_valid)
    return ScenarioArrays(
        task_job=jnp.zeros(pad_tasks, jnp.int32),
        task_is_reduce=is_red & valid,
        task_vm=bind_tasks(binding_policy, valid, base_len, vm_mips_a,
                           vm_pes_a, vm_valid, locality_cand=cand),
        task_valid=valid,
        task_mult=task_mult,
        job_length=f32(job_length)[None],
        job_data=f32(job_data)[None],
        job_n_maps=n_maps[None],
        job_n_reduces=n_reduces[None],
        job_submit=f32(job_submit)[None],
        job_reduce_factor=f32(reduce_factor)[None],
        job_valid=jnp.ones(1, bool),
        vm_mips=vm_mips_a,
        vm_pes=vm_pes_a,
        vm_cost=vm_cost_a,
        vm_valid=vm_valid,
        net_enabled=f32(net_enabled), net_bw=f32(net_bw),
        kappa_in=f32(kappa_in), kappa_shuffle=f32(kappa_shuffle),
        net_cost_per_unit=f32(net_cost_per_unit),
        sched_policy=i32(sched_policy),
        binding_policy=i32(binding_policy),
        block_vm=block_vm,
        block_size=block_mb,
        storage_enabled=f32(storage_enabled),
        vm_start=vm_start_a,
        vm_stop=vm_stop_a,
        spinup_delay=f32(spinup_delay),
        bill_gran=f32(billing_granularity),
        task_prio=jnp.asarray(task_prio, jnp.float32),
        vm_fail=vm_fail_a,
        vm_restore=vm_restore_a,
        vm_auto=vm_auto_a,
        control_policy=i32(control_policy),
        ctl_queue=f32(ctl_queue),
        ctl_busy=f32(ctl_busy),
        redispatch_delay=f32(redispatch_delay),
        task_deadline=jnp.minimum(
            jnp.asarray(task_deadline, jnp.float32), jnp.float32(_BIG)),
        deadline_policy=i32(deadline_policy),
        deadline_slack=f32(deadline_slack),
        preempt=i32(preempt),
        preempt_resume=i32(preempt_resume),
    )


# encode_cell parameters an axis/grid may target (pads are static).
_CELL_PARAMS = tuple(p for p in inspect.signature(encode_cell).parameters
                     if p not in ("pad_tasks", "pad_vms"))
_INT_PARAMS = frozenset(
    {"n_maps", "n_reduces", "n_vms", "sched_policy", "binding_policy",
     "replication", "placement", "storage_seed", "control_policy",
     "deadline_policy", "preempt", "preempt_resume"})
_PER_VM = frozenset({"vm_mips", "vm_pes", "vm_cost", "vm_start", "vm_stop",
                     "vm_fail", "vm_restore", "vm_auto"})
_PER_TASK = frozenset({"task_mult", "task_prio", "task_deadline"})
# storage knobs that are dead weight unless storage_enabled is set
_STORAGE_KNOBS = frozenset(
    {"block_size_mb", "replication", "placement", "storage_seed"})
# columns that switch the engines onto the closed-loop control path
# (DESIGN.md §10) — a plan without any of them never pays for control
_CONTROL_PARAMS = frozenset(
    {"vm_fail", "vm_restore", "vm_auto", "control_policy", "ctl_queue",
     "ctl_busy", "redispatch_delay", "task_deadline", "deadline_policy",
     "deadline_slack", "preempt", "preempt_resume"})
# per-VM pad fill: "no event" sentinels, not zero (a zero-filled failure
# column would fail every padding VM at t=0 before vm_valid masks it)
_PER_VM_FILL = {"vm_fail": _BIG, "vm_restore": _BIG}


def _validate_cell_columns(cols: Mapping[str, Any]) -> None:
    """Plan-build-time checks for the storage/placement parameter columns —
    a bad replication vector or placement id must fail here with a named
    error, not deep inside the vmapped encoder (and a silently-ignored
    storage knob must not masquerade as a swept axis).  Traced values are
    skipped (the caller is inside someone else's jit)."""
    conc = {n: np.asarray(v) for n, v in cols.items()
            if not isinstance(v, jax.core.Tracer)}
    for n in conc:
        if n in _INT_PARAMS and not np.issubdtype(conc[n].dtype, np.integer):
            raise ValueError(
                f"grid_arrays: parameter {n!r} is integer-valued; got "
                f"dtype {conc[n].dtype} (a float column here would be "
                "silently truncated per cell)")
    if "placement" in conc:
        bad = np.setdiff1d(conc["placement"], [int(p) for p in Placement])
        if bad.size:
            raise ValueError(
                f"grid_arrays: placement values {bad.tolist()} are not "
                f"Placement members {[f'{int(p)}={p.name}' for p in Placement]}")
    if "replication" in conc and (conc["replication"] < 1).any():
        raise ValueError(
            "grid_arrays: replication must be >= 1 in every cell (disable "
            "the store with storage_enabled=0 instead of replication=0)")
    if "block_size_mb" in conc and (conc["block_size_mb"] <= 0).any():
        raise ValueError(
            "grid_arrays: block_size_mb must be > 0 in every cell")
    if "billing_granularity" in conc \
            and (conc["billing_granularity"] <= 0).any():
        raise ValueError(
            "grid_arrays: billing_granularity must be > 0 in every cell")
    if "spinup_delay" in conc and (conc["spinup_delay"] < 0).any():
        raise ValueError(
            "grid_arrays: spinup_delay must be >= 0 in every cell")
    if "vm_start" in conc and (conc["vm_start"] < 0).any():
        raise ValueError(
            "grid_arrays: vm_start must be >= 0 in every cell (leases "
            "start on the simulation clock; a negative start would bill "
            "phantom lease time)")
    if "job_submit" in conc and (conc["job_submit"] < 0).any():
        raise ValueError(
            "grid_arrays: job_submit must be >= 0 in every cell (arrival "
            "instants are absolute simulation times)")
    if "control_policy" in conc:
        bad = np.setdiff1d(conc["control_policy"],
                           [int(p) for p in ControlPolicy])
        if bad.size:
            raise ValueError(
                f"grid_arrays: control_policy values {bad.tolist()} are not "
                f"ControlPolicy members "
                f"{[f'{int(p)}={p.name}' for p in ControlPolicy]}")
    if "deadline_policy" in conc:
        bad = np.setdiff1d(conc["deadline_policy"],
                           [int(p) for p in DeadlinePolicy])
        if bad.size:
            raise ValueError(
                f"grid_arrays: deadline_policy values {bad.tolist()} are not "
                f"DeadlinePolicy members "
                f"{[f'{int(p)}={p.name}' for p in DeadlinePolicy]}")
    if "task_deadline" in conc:
        dl = conc["task_deadline"].astype(np.float64)
        if not np.isfinite(dl).all():
            raise ValueError(
                "grid_arrays: task_deadline must be finite in every cell "
                "(use the _BIG sentinel, not inf/nan, for 'no deadline')")
        live = dl < _BIG / 2                      # _BIG sentinel = no deadline
        submit = conc.get("job_submit")
        sub = np.asarray(0.0 if submit is None else submit, np.float64)
        while sub.ndim < dl.ndim:
            sub = sub[..., None]
        if (live & (dl <= sub)).any():
            raise ValueError(
                "grid_arrays: task_deadline must exceed the job's submit "
                "time in every cell (a deadline at or before job_submit is "
                "unmeetable by construction — raise task_deadline or drop "
                "the axis)")
    for n in ("preempt", "preempt_resume"):
        if n in conc and (conc[n] != 0).any() and "task_prio" not in cols:
            raise ValueError(
                f"grid_arrays: {n!r} enables priority preemption but no "
                "'task_prio' column is set, so every task has equal rank "
                "and the knob would silently do nothing — add a task_prio "
                f"axis/base or drop {n!r}")
    if "deadline_slack" in conc and (conc["deadline_slack"] < 0).any():
        raise ValueError(
            "grid_arrays: deadline_slack must be >= 0 in every cell")
    if "redispatch_delay" in conc and (conc["redispatch_delay"] < 0).any():
        raise ValueError(
            "grid_arrays: redispatch_delay must be >= 0 in every cell")
    for n in ("ctl_queue", "ctl_busy"):
        if n in conc and (conc[n] < 0).any():
            raise ValueError(
                f"grid_arrays: {n} must be >= 0 in every cell")
    knobs = sorted(_STORAGE_KNOBS & set(cols))
    if knobs and "storage_enabled" not in cols:
        raise ValueError(
            f"grid_arrays: {knobs} configure the storage model but "
            "'storage_enabled' is never set, so they would silently do "
            "nothing — add axis('storage', [True]) / storage=True (or an "
            "explicit storage_enabled column)")


def grid_arrays(params: dict[str, np.ndarray], *, pad_tasks: int,
                pad_vms: int,
                static_params: Mapping[str, int] | None = None
                ) -> ScenarioArrays:
    """vmap :func:`encode_cell` over equal-length parameter arrays.

    Each value is ``[N]`` (one scalar per cell) or ``[N, pad_vms]``
    (per-VM vectors for ``vm_mips``/``vm_pes``/``vm_cost``) /
    ``[N, pad_tasks]`` (``task_mult``).  Keys and leading lengths are
    validated up front — a mismatched key used to surface as an opaque
    vmap shape error deep inside the encoder.

    ``static_params`` pins encode_cell parameters as Python compile-time
    constants instead of per-cell columns — the bucketed ``run()`` path
    uses it to bake a bucket's uniform ``binding_policy`` into the
    lowering, letting XLA dead-code-eliminate the unused binding
    strategies (the sequential LEAST_LOADED load scan dominates encode
    time when it can't be eliminated).
    """
    names = list(params)
    static = tuple(sorted((static_params or {}).items()))
    for n, _ in static:
        if n not in _CELL_PARAMS:
            raise ValueError(f"grid_arrays: unknown static parameter {n!r}")
        if n in names:
            raise ValueError(
                f"grid_arrays: parameter {n!r} passed both as a column and "
                "as a static parameter")
    if not names:
        raise ValueError("grid_arrays: empty parameter dict")
    unknown = [n for n in names if n not in _CELL_PARAMS]
    if unknown:
        raise ValueError(
            f"grid_arrays: unknown encode_cell parameter(s) {unknown}; "
            f"valid: {list(_CELL_PARAMS)}")
    sizes = {}
    for n in names:
        shape = np.shape(params[n])
        if len(shape) == 0:
            raise ValueError(
                f"grid_arrays: parameter {n!r} must be an array with a "
                "leading grid dimension (got a scalar)")
        if len(shape) == 2:
            if n in _PER_VM:
                want, pad = "pad_vms", pad_vms
            elif n in _PER_TASK:
                want, pad = "pad_tasks", pad_tasks
            else:
                raise ValueError(
                    f"grid_arrays: parameter {n!r} takes one scalar per "
                    f"cell, got 2-D shape {shape}")
            if shape[1] != pad:
                raise ValueError(
                    f"grid_arrays: {n!r} has trailing width {shape[1]}, "
                    f"expected {want}={pad}")
        elif len(shape) > 2:
            raise ValueError(
                f"grid_arrays: parameter {n!r} has {len(shape)} dims; "
                "at most [N, width] is supported")
        sizes[n] = shape[0]
    n0 = sizes[names[0]]
    bad = [f"{n} has length {sizes[n]}" for n in names if sizes[n] != n0]
    if bad:
        raise ValueError(
            "grid_arrays: parameter arrays must share one leading grid "
            f"length; {names[0]!r} has length {n0} but " + ", ".join(bad))
    _validate_cell_columns(params)
    encoder = _grid_encoder(tuple(names), pad_tasks, pad_vms, static)
    return encoder(*(jnp.asarray(params[n]) for n in names))


@lru_cache(maxsize=None)
def _grid_encoder(names: tuple[str, ...], pad_tasks: int, pad_vms: int,
                  static: tuple[tuple[str, int], ...] = ()):
    """One jitted vmapped encode_cell per (param set, padding, statics)
    signature — repeated ``SweepPlan.run()`` calls re-encode at compiled
    speed instead of dispatching the encoder op by op."""
    def one(*xs):
        kw = dict(zip(names, xs))
        kw.update(static)
        return encode_cell(**kw, pad_tasks=pad_tasks, pad_vms=pad_vms)
    return jax.jit(jax.vmap(one))


# ---------------------------------------------------------------------------
# Declarative sweep plans (DESIGN.md §4)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Axis:
    """One labeled sweep dimension.

    ``names`` are the coordinate names addressable in
    :meth:`SweepResult.select` (more than one after :func:`zip_`);
    ``labels`` holds one tuple of coordinate values per point (aligned with
    ``names``); ``columns`` maps encode_cell parameters to ``[n, ...]``
    encoded value columns.  Build through :func:`axis`, compose with
    :func:`zip_` / :func:`product`.
    """
    names: tuple[str, ...]
    labels: tuple[tuple[Any, ...], ...]
    columns: Mapping[str, np.ndarray]

    def __len__(self) -> int:
        return len(self.labels)


def axis(name: str, values: Sequence[Any]) -> Axis:
    """One sweep dimension: ``name`` + the values it takes.

    ``name`` is either a raw :func:`encode_cell` parameter (``n_maps``,
    ``n_vms``, ``vm_mips`` …, values scalars — or per-VM vectors for the
    ``vm_*`` parameters) or a convenience spec axis:

    * ``"vm"``/``"vm_type"`` — values are ``VMSpec`` or Table-II type names;
      expands to homogeneous ``vm_mips``/``vm_pes``/``vm_cost``;
    * ``"vms"`` — values are *sequences* of VMSpec/type names (one cluster
      per point, may differ in size): per-VM heterogeneous cells, expands
      to ``n_vms`` + per-VM ``vm_mips``/``vm_pes``/``vm_cost`` vectors;
    * ``"job"``/``"job_type"`` — ``JobSpec`` or Table-III names; expands to
      ``job_length``/``job_data``/``reduce_factor`` (MR combination stays
      a separate ``n_maps``/``n_reduces`` axis, as in the paper);
    * ``"sched_policy"``/``"binding_policy"`` — enum members or ints;
    * ``"network_delay"`` — bools, expands to ``net_enabled``;
    * ``"storage"`` — bools, expands to ``storage_enabled`` (the block
      store, DESIGN.md §7; combine with the raw ``replication`` /
      ``block_size_mb`` / ``storage_seed`` parameters);
    * ``"placement"`` — :class:`~repro.core.storage.Placement` members,
      ints, or the names ``"uniform"`` / ``"skewed"``;
    * ``"control_policy"`` — :class:`~repro.core.control.ControlPolicy`
      members, ints, or the names ``"none"`` / ``"autoscale"`` (the
      closed-loop control hook, DESIGN.md §10; combine with the raw
      ``ctl_queue``/``ctl_busy`` threshold parameters and per-VM
      ``vm_auto`` reserve markers, and with :func:`failures` streams).
    """
    values = list(values)
    if not values:
        raise ValueError(f"axis {name!r}: empty value list")
    f32 = partial(np.asarray, dtype=np.float32)
    if name in ("vm", "vm_type"):
        specs = [as_vm_spec(v) for v in values]
        return Axis((name,), tuple((s.name,) for s in specs), {
            "vm_mips": f32([s.mips for s in specs]),
            "vm_pes": f32([float(s.pes) for s in specs]),
            "vm_cost": f32([s.cost_per_sec for s in specs]),
        })
    if name == "vms":
        clusters = [tuple(as_vm_spec(v) for v in vs) for vs in values]
        if any(not c for c in clusters):
            raise ValueError("axis 'vms': every point needs >= 1 VM")
        V = max(len(c) for c in clusters)

        def col(get):
            out = np.zeros((len(clusters), V), np.float32)
            for i, c in enumerate(clusters):
                out[i, :len(c)] = [get(s) for s in c]
            return out

        return Axis((name,),
                    tuple((tuple(s.name for s in c),) for c in clusters), {
            "n_vms": np.asarray([len(c) for c in clusters], np.int32),
            "vm_mips": col(lambda s: s.mips),
            "vm_pes": col(lambda s: float(s.pes)),
            "vm_cost": col(lambda s: s.cost_per_sec),
        })
    if name in ("job", "job_type"):
        specs = [as_job_spec(v) for v in values]
        return Axis((name,), tuple((s.name,) for s in specs), {
            "job_length": f32([s.length_mi for s in specs]),
            "job_data": f32([s.data_mb for s in specs]),
            "reduce_factor": f32([s.reduce_factor for s in specs]),
        })
    if name == "network_delay":
        labels = tuple((bool(v),) for v in values)
        return Axis((name,), labels,
                    {"net_enabled": f32([1.0 if v else 0.0 for v in values])})
    if name == "storage":
        labels = tuple((bool(v),) for v in values)
        return Axis((name,), labels, {
            "storage_enabled": f32([1.0 if v else 0.0 for v in values])})
    if name == "placement":
        members = [as_placement(v) for v in values]
        return Axis((name,), tuple((m,) for m in members),
                    {name: np.asarray(members, np.int32)})
    if name == "sched_policy":
        members = [SchedPolicy(v) for v in values]
        return Axis((name,), tuple((m,) for m in members),
                    {name: np.asarray(members, np.int32)})
    if name == "binding_policy":
        members = [BindingPolicy(v) for v in values]
        return Axis((name,), tuple((m,) for m in members),
                    {name: np.asarray(members, np.int32)})
    if name == "control_policy":
        members = [as_control_policy(v) for v in values]
        return Axis((name,), tuple((m,) for m in members),
                    {name: np.asarray(members, np.int32)})
    if name == "deadline_policy":
        members = [as_deadline_policy(v) for v in values]
        return Axis((name,), tuple((m,) for m in members),
                    {name: np.asarray(members, np.int32)})
    if name not in _CELL_PARAMS:
        raise ValueError(
            f"axis {name!r}: not an encode_cell parameter or spec axis; "
            f"valid: {list(_CELL_PARAMS)} + ['vm', 'vm_type', 'vms', 'job', "
            "'job_type', 'network_delay', 'storage', 'placement', "
            "'control_policy', 'deadline_policy']")
    if any(np.ndim(v) > 0 for v in values):        # per-VM / per-task vectors
        if name not in _PER_VM and name not in _PER_TASK:
            raise ValueError(
                f"axis {name!r}: vector values only make sense for the "
                f"per-VM parameters {sorted(_PER_VM)} or the per-task "
                f"parameters {sorted(_PER_TASK)}; "
                f"{name!r} takes one scalar per cell")
        if not all(np.ndim(v) == 1 for v in values):
            raise ValueError(
                f"axis {name!r}: vector values must all be 1-D with one "
                "shared length (use the 'vms' axis for ragged clusters)")
        widths = {int(np.shape(v)[0]) for v in values}
        if len(widths) != 1:
            raise ValueError(
                f"axis {name!r}: vector values must share one length, got "
                f"{sorted(widths)} (use the 'vms' axis for ragged clusters)")
        return Axis((name,), tuple((tuple(np.asarray(v).tolist()),)
                                   for v in values),
                    {name: np.stack([f32(v) for v in values])})
    dtype = np.int32 if name in _INT_PARAMS else np.float32
    return Axis((name,), tuple((v,) for v in values),
                {name: np.asarray(values, dtype)})


def zip_(*axes: Axis) -> Axis:
    """Fuse equal-length axes into one dimension that advances together
    (e.g. co-varying ``n_maps`` with ``job_length``), like Python ``zip``."""
    if not axes:
        raise ValueError("zip_: need at least one axis")
    lens = {"x".join(a.names): len(a) for a in axes}
    if len(set(lens.values())) != 1:
        raise ValueError(f"zip_: axes must share one length; got {lens}")
    columns: dict[str, np.ndarray] = {}
    for a in axes:
        for cname, col in a.columns.items():
            if cname in columns:
                raise ValueError(
                    f"zip_: parameter {cname!r} set by more than one axis")
            columns[cname] = col
    names = tuple(n for a in axes for n in a.names)
    if len(set(names)) != len(names):
        raise ValueError(f"zip_: duplicate coordinate names in {names}")
    labels = tuple(tuple(part for a in axes for part in a.labels[i])
                   for i in range(len(axes[0])))
    return Axis(names, labels, columns)


def arrivals(n: int, *, rate, process="poisson", seed: int = 0,
             burst: int = 4) -> Axis:
    """An arrival-stream dimension (DESIGN.md §8): ``n`` seeded draws from
    an inter-arrival process become ``job_submit`` instants — each grid
    point simulates one arrival of the stream against the leased fleet, so
    offered load is a grid axis like any other parameter.

    ``rate`` is arrivals per simulated second; pass a *sequence* of rates
    to sweep offered load (the axis flattens rates × arrivals into one
    labeled dimension, ``select(arrival_rate=...)`` filters it).
    ``process`` is an :class:`~repro.core.elasticity.ArrivalProcess`
    member or name (``"poisson"`` | ``"uniform"`` | ``"burst"``); draws
    reuse the storage subsystem's counter-hash idiom, so streams are
    reproducible pure arithmetic of ``(seed, k)``.
    """
    proc = as_arrival_process(process)
    rates = list(rate) if np.ndim(rate) > 0 else [rate]
    if not rates:
        raise ValueError("arrivals: empty rate list")
    times = [elasticity_mod.arrival_times(n, rate=float(r), process=proc,
                                          seed=seed, burst=burst)
             for r in rates]
    col = np.concatenate(times).astype(np.float32)
    if np.ndim(rate) > 0:
        labels = tuple((float(r), k) for r in rates for k in range(n))
        return Axis(("arrival_rate", "arrival"), labels,
                    {"job_submit": col})
    return Axis(("arrival",), tuple((k,) for k in range(n)),
                {"job_submit": col})


def failures(n: int, *, rate, n_vms: int, seed: int = 0,
             repair_delay: float = np.inf) -> Axis:
    """A failure-stream dimension (DESIGN.md §10): ``n`` seeded draws of
    per-VM failure/restore instants become ``vm_fail``/``vm_restore``
    columns — each grid point injects one realization of the VM fault
    process, so fault exposure is a grid axis like any other parameter.

    ``rate`` is per-VM failures per simulated second; pass a *sequence*
    of rates to sweep fault intensity (the axis flattens rates × draws
    into one labeled dimension, ``select(failure_rate=...)`` filters it).
    Draw ``k`` of the stream uses seed ``seed + k`` of
    :func:`repro.core.control.failure_times` — the counter-hash idiom the
    host encoder shares, so a sweep cell and the equivalent
    ``Scenario(control=ControlSpec(...))`` encode bit-identical streams.
    ``n_vms`` fixes the stream width (pin the grid's ``n_vms`` to match).
    """
    rates = list(rate) if np.ndim(rate) > 0 else [rate]
    if not rates:
        raise ValueError("failures: empty rate list")
    cols_f, cols_r = [], []
    for r in rates:
        for k in range(n):
            f, rr = _failure_times(n_vms, rate=float(r), seed=seed + k,
                                   repair_delay=float(repair_delay))
            cols_f.append(f)
            cols_r.append(rr)
    col_f = np.stack(cols_f).astype(np.float32)
    col_r = np.stack(cols_r).astype(np.float32)
    if np.ndim(rate) > 0:
        labels = tuple((float(r), k) for r in rates for k in range(n))
        return Axis(("failure_rate", "failure"), labels,
                    {"vm_fail": col_f, "vm_restore": col_r})
    return Axis(("failure",), tuple((k,) for k in range(n)),
                {"vm_fail": col_f, "vm_restore": col_r})


def product(*dims: Axis, **base: Any) -> "SweepPlan":
    """Cartesian :class:`SweepPlan` over ``dims`` (row-major: the last axis
    varies fastest).  ``base`` pins non-swept parameters for every cell —
    any :func:`axis` name with a single value (``vm_type="medium"``,
    ``network_delay=False``, ``vms=("medium", "small")``, ``n_maps=12`` …).
    """
    return SweepPlan(dims=tuple(dims), base=dict(base))


# Paper defaults for parameters no axis/base sets: the §5 baseline cell
# (3 small VMs, one small M1R1 job) — same defaults as config.paper_scenario.
_DEFAULTS: dict[str, float] = dict(
    n_maps=1, n_reduces=1, n_vms=3,
    vm_mips=VM_SMALL.mips, vm_pes=float(VM_SMALL.pes),
    vm_cost=VM_SMALL.cost_per_sec,
    job_length=JOB_SMALL.length_mi, job_data=JOB_SMALL.data_mb,
)


@dataclasses.dataclass(frozen=True)
class SweepPlan:
    """A declarative experiment plan: labeled axes × pinned base parameters.

    Compiles to one device-side :class:`ScenarioArrays` batch
    (:meth:`arrays`) and executes through :meth:`run`, which returns a
    labeled :class:`SweepResult`.  ``pad_tasks``/``pad_vms`` override the
    inferred paddings (e.g. to share one lowering across several plans).
    """
    dims: tuple[Axis, ...]
    base: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    pad_tasks: int | None = None
    pad_vms: int | None = None

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(len(d) for d in self.dims)

    @property
    def size(self) -> int:
        return int(np.prod(self.shape, dtype=np.int64)) if self.dims else 1

    def replace(self, **kw) -> "SweepPlan":
        return dataclasses.replace(self, **kw)

    def arrivals(self, n: int, *, rate, process="poisson", seed: int = 0,
                 burst: int = 4) -> "SweepPlan":
        """Append an arrival-stream dimension (see module-level
        :func:`arrivals`): ``plan.arrivals(64, rate=0.01)`` simulates each
        existing grid point against 64 seeded Poisson arrival instants,
        with ``job_submit`` populated per cell."""
        dim = arrivals(n, rate=rate, process=process, seed=seed, burst=burst)
        return self.replace(dims=self.dims + (dim,))

    def failures(self, n: int, *, rate, n_vms: int, seed: int = 0,
                 repair_delay: float = np.inf) -> "SweepPlan":
        """Append a failure-stream dimension (see module-level
        :func:`failures`): ``plan.failures(16, rate=1e-3, n_vms=4)``
        simulates each existing grid point against 16 seeded realizations
        of the VM fault process, with ``vm_fail``/``vm_restore`` populated
        per cell."""
        dim = failures(n, rate=rate, n_vms=n_vms, seed=seed,
                       repair_delay=repair_delay)
        return self.replace(dims=self.dims + (dim,))

    def _compiled(self) -> tuple[dict[str, np.ndarray], int, int]:
        """Flatten axes + base + defaults into N-cell parameter columns."""
        shape, N = self.shape, self.size
        cols: dict[str, np.ndarray] = {}
        owner: dict[str, str] = {}
        for k, dim in enumerate(self.dims):
            outer = int(np.prod(shape[:k], dtype=np.int64))
            inner = int(np.prod(shape[k + 1:], dtype=np.int64))
            idx = np.tile(np.repeat(np.arange(shape[k]), inner), outer)
            src = "axis " + "×".join(dim.names)
            for cname, col in dim.columns.items():
                if cname in cols:
                    raise ValueError(
                        f"SweepPlan: parameter {cname!r} set by both "
                        f"{owner[cname]} and {src}")
                cols[cname] = np.asarray(col)[idx]
                owner[cname] = src
        for bname, value in self.base.items():
            for cname, col in axis(bname, [value]).columns.items():
                if cname in cols:
                    raise ValueError(
                        f"SweepPlan: parameter {cname!r} set by both "
                        f"{owner[cname]} and base argument {bname!r}")
                c = np.asarray(col)
                cols[cname] = np.broadcast_to(c[0], (N,) + c.shape[1:])
                owner[cname] = f"base argument {bname!r}"
        for cname, default in _DEFAULTS.items():
            if cname not in cols:
                dtype = np.int32 if cname in _INT_PARAMS else np.float32
                cols[cname] = np.full(N, default, dtype)
        n_tasks = int((cols["n_maps"].astype(np.int64)
                       + cols["n_reduces"].astype(np.int64)).max())
        pad_tasks = self.pad_tasks if self.pad_tasks is not None else n_tasks
        v_needed = max(int(cols["n_vms"].max()),
                       *(c.shape[1] for n, c in cols.items()
                         if n in _PER_VM and c.ndim == 2), 1)
        pad_vms = self.pad_vms if self.pad_vms is not None else v_needed
        if pad_tasks < n_tasks or pad_vms < v_needed:
            raise ValueError(
                f"SweepPlan: padding too small — need pad_tasks>={n_tasks} "
                f"(got {pad_tasks}), pad_vms>={v_needed} (got {pad_vms})")
        n_vms_max = int(cols["n_vms"].max())
        for cname in _PER_VM:
            c = cols.get(cname)     # vm_start/vm_stop default off-column
            if c is None or c.ndim != 2:
                continue
            if c.shape[1] < n_vms_max:
                raise ValueError(
                    f"SweepPlan: per-VM column {cname!r} has width "
                    f"{c.shape[1]} but some cell has n_vms={n_vms_max}; "
                    "give every VM vector >= n_vms entries (or use the "
                    "'vms' axis, which sets n_vms itself)")
            if c.shape[1] < pad_vms:
                cols[cname] = np.pad(
                    c, ((0, 0), (0, pad_vms - c.shape[1])),
                    constant_values=_PER_VM_FILL.get(cname, 0.0))
        for cname, fill in (("task_mult", 1.0), ("task_prio", 0.0),
                            ("task_deadline", _BIG)):
            if cname in cols and cols[cname].ndim == 2 \
                    and cols[cname].shape[1] != pad_tasks:
                tm = cols[cname]
                if tm.shape[1] > pad_tasks:
                    raise ValueError(
                        f"SweepPlan: {cname} width {tm.shape[1]} exceeds "
                        f"pad_tasks={pad_tasks}")
                cols[cname] = np.pad(
                    tm, ((0, 0), (0, pad_tasks - tm.shape[1])),
                    constant_values=fill)
        # storage/placement columns fail here, at plan build, with a named
        # error — the fused bucket runner would otherwise trace them
        # straight into the vmapped encoder
        _validate_cell_columns(cols)
        return cols, pad_tasks, pad_vms

    def params(self) -> dict[str, np.ndarray]:
        """The flattened ``grid_arrays`` parameter columns (host numpy)."""
        return self._compiled()[0]

    def arrays(self) -> ScenarioArrays:
        """Compile to one device-side batch (leading dim = flattened grid)."""
        cols, pad_tasks, pad_vms = self._compiled()
        return grid_arrays(cols, pad_tasks=pad_tasks, pad_vms=pad_vms)

    def run(self, mesh: jax.sharding.Mesh | None = None,
            chunk: int | None = None, *, bucket: object = "auto",
            backend: str = "xla", stream_to=None, compact: object = None,
            cost_model: "costmodel_mod.CostModel | None" = None,
            report: bool = False):
        """Execute the plan and return a labeled :class:`SweepResult`.

        Execution modes (combine with bucketing orthogonally):

        * default — one jitted vmap per shape bucket;
        * ``mesh`` — scenarios sharded over every mesh axis (the pod path;
          each bucket is padded up to a device-count multiple and trimmed);
        * ``chunk`` — at most ``chunk`` cells encoded + simulated per call
          (one shared lowering per bucket; results accumulate in host
          memory), for grids larger than device memory.

        ``bucket`` controls the adaptive schedule (DESIGN.md §6):
        ``"auto"`` (default) groups cells into power-of-two padded-shape
        buckets keyed on (task count, VM count, binding policy), so
        heterogeneous grids stop simulating phantom tasks at the grid-wide
        max padding; ``False`` runs the whole grid as one max-shape batch.
        Plan-level ``pad_tasks``/``pad_vms`` overrides act as bucket caps.
        Metric values are bit-identical either way (padding only adds
        exact-zero/identity lanes); only ``realized_epochs`` — the number
        of event epochs the executed batch actually ran, the new
        observability metric — reflects the schedule that produced it.

        ``backend`` selects the engine: ``"xla"`` (default) is
        :func:`engine.simulate_batch_arrays`; ``"pallas"`` runs the fused
        ``mr_epoch`` megakernel (``kernels/mr_sched``) with per-VM/task
        state resident in VMEM across epochs (interpret mode off-TPU;
        single-device only — combine with ``chunk``, not ``mesh``).

        ``stream_to`` (with ``chunk``) streams results to disk instead of
        accumulating them: each ``chunk``-cell slice of the grid is
        simulated and its long-form :meth:`SweepResult.to_table` rows
        appended to one parquet file, so million-cell grids never hold
        their metrics in host memory.  Returns a :class:`StreamedSweep`
        summary rather than a :class:`SweepResult` (the ROADMAP
        columnar-export item's second slice; needs the optional
        ``pyarrow`` dependency).

        ``compact`` turns on sparse active-lane compaction (DESIGN.md §9):
        every K epochs the still-active lanes are gathered into a
        pow2-padded compacted batch, stepped, and scattered back, so a
        tail-heavy bucket whose last 40 lanes are still running steps 64
        lanes instead of 2048.  ``compact="auto"`` (or ``True``) derives K
        from the measured cost model; an int pins K.  Results are
        bit-identical to the dense path — ``_epoch_step`` is idempotent
        for finished lanes — including per-lane ``n_epochs`` and the
        bucket's ``realized_epochs``.  Composes with ``bucket``/``chunk``
        (compaction runs per bucket resp. per chunk) and with
        ``backend="pallas"`` (the megakernel re-tiles the compacted
        batch).  The ``mesh`` path ignores ``compact``: it shards
        *per-lane* epoch loops with no cross-lane batch coupling, so
        there is no dense tail to compact away.  ``cost_model`` overrides
        the per-device measured calibration (pin one for deterministic
        scheduling decisions across hosts).

        ``report=True`` (DESIGN.md §12) additionally returns a
        :class:`~repro.core.telemetry.RunReport` — ``(result, report)``
        — recording what the adaptive schedule actually did: one
        :class:`~repro.core.telemetry.BucketReport` per dispatched
        bucket (cells, padded shape, statics, the cost-model split gain
        that justified it, dispatch/compaction-sync counts, wall time),
        fused-runner/encoder compile-cache hit+miss deltas, the resolved
        cost-model coefficients with their calibration ``source``, and
        run provenance.  Purely observational: the executed schedule and
        every metric value are unchanged.  Composes with every mode
        (streaming returns ``(StreamedSweep, RunReport)``; each streamed
        chunk re-buckets, so its report holds one entry per bucket *per
        chunk*).
        """
        if mesh is not None and chunk is not None:
            raise ValueError("run: pass mesh or chunk, not both")
        if chunk is not None and chunk < 1:
            raise ValueError(f"run: chunk must be >= 1, got {chunk}")
        if backend not in ("xla", "pallas"):
            raise ValueError(
                f"run: backend must be 'xla' or 'pallas', got {backend!r}")
        if backend == "pallas" and mesh is not None:
            raise ValueError(
                "run: backend='pallas' is single-device (use chunk=, "
                "not mesh=)")
        compact = _check_compact(compact)
        buckets: list | None = None
        if report:
            # resolve the calibration up front so the schedule and the
            # report price with the *same* coefficients
            cost_model = cost_model or costmodel_mod.default_cost_model()
            t0 = time.perf_counter()
            ci0, ei0 = _cache_infos()
            buckets = []
        if stream_to is not None:
            if chunk is None:
                raise ValueError(
                    "run: stream_to= needs chunk= (the streamed write "
                    "appends one chunk of cells at a time)")
            streamed = self._run_streaming(stream_to, chunk, bucket,
                                           backend, compact, cost_model,
                                           buckets)
            if buckets is None:
                return streamed
            return streamed, _finish_report(buckets, self.size, backend,
                                            compact, cost_model, ci0, ei0,
                                            t0)
        cols, pad_tasks, pad_vms = self._compiled()
        metrics, n_jobs = _execute_grid(cols, self.size, pad_tasks, pad_vms,
                                        bucket, mesh, chunk, backend,
                                        compact, cost_model, report=buckets)
        shaped = {
            name: (m.reshape(self.shape) if m.ndim == 1 or n_jobs == 1
                   else m.reshape(self.shape + (n_jobs,)))
            for name, m in metrics.items()}
        result = SweepResult(axis_names=tuple(d.names for d in self.dims),
                             axis_labels=tuple(d.labels for d in self.dims),
                             metrics=shaped, n_jobs=n_jobs)
        if buckets is None:
            return result
        return result, _finish_report(buckets, self.size, backend, compact,
                                      cost_model, ci0, ei0, t0)

    def _run_streaming(self, path, chunk: int, bucket, backend,
                       compact=None, cost=None,
                       report=None) -> "StreamedSweep":
        """Chunked execute + parquet append (see :meth:`run`)."""
        try:
            import pyarrow as pa
            import pyarrow.parquet as pq
        except ImportError as e:                  # pragma: no cover - env
            raise ImportError(
                "run(stream_to=...) requires the optional pyarrow "
                "dependency (pip install pyarrow); without it use "
                "run(chunk=...) and to_table()") from e
        cols, pad_tasks, pad_vms = self._compiled()
        N, shape = self.size, self.shape
        axis_names = tuple(d.names for d in self.dims)
        axis_labels = tuple(d.labels for d in self.dims)
        writer, n_rows, n_chunks = None, 0, 0
        try:
            for lo in range(0, N, chunk):
                hi = min(lo + chunk, N)
                sub = {k: v[lo:hi] for k, v in cols.items()}
                metrics, n_jobs = _execute_grid(
                    sub, hi - lo, pad_tasks, pad_vms, bucket, None, None,
                    backend, compact, cost, report=report)
                table = pa.table(_long_form_columns(
                    axis_names, axis_labels, shape, metrics, n_jobs,
                    lo, hi))
                # run provenance rides in the file-level schema metadata
                # (DESIGN.md §12) — pyarrow schema equality ignores
                # metadata, so later chunks append without re-stamping
                table = table.replace_schema_metadata(
                    {**(table.schema.metadata or {}),
                     **telemetry.parquet_metadata()})
                if writer is None:
                    writer = pq.ParquetWriter(path, table.schema)
                writer.write_table(table)
                n_rows += table.num_rows
                n_chunks += 1
        finally:
            if writer is not None:
                writer.close()
        return StreamedSweep(path=str(path), n_cells=N, n_rows=n_rows,
                             n_chunks=n_chunks)


def _check_compact(compact):
    """Normalize the ``compact`` knob: None/False off, True -> 'auto',
    'auto' or a positive int interval pass through."""
    if compact is None or compact is False:
        return None
    if compact is True:
        return "auto"
    if compact == "auto" or (isinstance(compact, int) and compact >= 1):
        return compact
    raise ValueError(
        f"run: compact must be None, False, True, 'auto', or an int "
        f">= 1; got {compact!r}")


def _cache_infos():
    """Hit/miss counters of the two lru caches the adaptive schedule
    leans on (deltas around a run feed :class:`telemetry.RunReport`)."""
    return _fused_runner.cache_info(), _grid_encoder.cache_info()


def _finish_report(buckets, n_cells: int, backend, compact, cost,
                   ci0, ei0, t0) -> "telemetry.RunReport":
    """Assemble the :class:`telemetry.RunReport` for one ``run()``."""
    ci1, ei1 = _cache_infos()
    return telemetry.RunReport(
        n_cells=n_cells, n_buckets=len(buckets), backend=backend,
        compact=compact, buckets=buckets,
        compile_cache_hits=ci1.hits - ci0.hits,
        compile_cache_misses=ci1.misses - ci0.misses,
        encoder_cache_hits=ei1.hits - ei0.hits,
        encoder_cache_misses=ei1.misses - ei0.misses,
        compaction_syncs=sum(b.compact_syncs for b in buckets),
        scalar_syncs=sum(b.compact_scalar_syncs for b in buckets),
        dispatches=sum(b.dispatches for b in buckets),
        cost_model={"dispatch_us": cost.dispatch_us,
                    "epoch_lane_us": cost.epoch_lane_us,
                    "sync_us": cost.sync_us,
                    "device": cost.device, "source": cost.source},
        device=costmodel_mod.device_key(),
        provenance=dict(telemetry.provenance()),
        wall_s=time.perf_counter() - t0)


def _execute_grid(cols: dict[str, np.ndarray], N: int, pad_tasks: int,
                  pad_vms: int, bucket, mesh, chunk, backend,
                  compact=None, cost=None, report: list | None = None
                  ) -> tuple[dict[str, np.ndarray], int]:
    """Bucket + simulate ``N`` flattened cells; returns ``(metrics,
    n_jobs)`` with per-job metric columns shaped ``[N, n_jobs]`` and
    per-scenario columns ``[N]`` (callers reshape to grid/table form).
    ``report`` (a list, appended in place) collects one
    :class:`telemetry.BucketReport` per dispatched bucket."""
    if (compact is not None or report is not None) and cost is None:
        cost = costmodel_mod.default_cost_model()
    groups = _bucket_groups(cols, pad_tasks, pad_vms, bucket, cost)
    parts = []
    for idx, gcols, statics, tb, vb in groups:
        stats = {"dispatches": 0, "syncs": 0, "scalar_syncs": 0,
                 "compactions": 0}
        w0 = time.perf_counter()
        parts.append((idx, *_run_cells(gcols, len(idx), tb, vb, statics,
                                       mesh, chunk, backend, compact, cost,
                                       stats=stats)))
        if report is not None:
            report.append(telemetry.BucketReport(
                cells=len(idx), pad_tasks=tb, pad_vms=vb, backend=backend,
                control=bool(_CONTROL_PARAMS
                             & (set(gcols) | set(statics or {}))),
                statics=dict(statics or {}),
                # the modelled lane-epoch saving vs running these cells
                # at the grid cap — the quantity _bucket_groups weighed
                # against dispatch_us (None: bucket already at the cap)
                split_gain_us=(cost.split_gain_us(len(idx), tb, pad_tasks)
                               if tb < pad_tasks else None),
                dispatches=stats["dispatches"],
                compact_syncs=stats["syncs"],
                compact_scalar_syncs=stats["scalar_syncs"],
                wall_s=time.perf_counter() - w0))
    n_jobs = int(parts[0][1].makespan.shape[-1])
    metrics: dict[str, np.ndarray] = {}
    for f in JobMetrics._fields:
        out = np.empty((N, n_jobs),
                       np.asarray(getattr(parts[0][1], f)).dtype)
        for idx, jm, _, _ in parts:
            out[idx] = np.asarray(getattr(jm, f))
        metrics[f] = out
    for f in ScenarioMetrics._fields:
        out = np.empty(N, np.asarray(getattr(parts[0][2], f)).dtype)
        for idx, _, sm, _ in parts:
            out[idx] = np.asarray(getattr(sm, f))
        metrics[f] = out
    realized = np.empty(N, np.int32)
    for idx, _, _, rz in parts:
        realized[idx] = rz
    metrics["realized_epochs"] = realized
    return metrics, n_jobs


@dataclasses.dataclass(frozen=True)
class StreamedSweep:
    """Summary of a ``run(chunk=..., stream_to=...)`` streamed export:
    the grid's metrics live in the parquet file at ``path`` (long-form
    ``to_table`` columns), not in host memory."""
    path: str
    n_cells: int
    n_rows: int
    n_chunks: int


def _pad_cells(cols: dict[str, np.ndarray], n: int) -> dict[str, np.ndarray]:
    """Pad parameter columns to ``n`` cells by repeating the last cell."""
    have = len(next(iter(cols.values())))
    if have == n:
        return cols
    return {k: np.concatenate([v, np.repeat(v[-1:], n - have, axis=0)])
            for k, v in cols.items()}


# ---------------------------------------------------------------------------
# Adaptive execution schedule: shape buckets + per-bucket execution
# ---------------------------------------------------------------------------

# Per-cell padded sizes: smallest of {floor, 2·floor, 4·floor, …, cap}
# that fits.  Power-of-two rounding keeps the set of compiled shapes small
# and stable across differently-composed grids (compile-cache friendly);
# ``cap`` is the grid-wide max (or the plan's explicit pad override).
# Vectorized in core.util — the measured-cost scorer calls it on every
# candidate partition, which made the old per-unique-value loop hot.
_bucket_pads = pow2_pads
_pow2_pad = pow2_pad


def _bucket_groups(cols: dict[str, np.ndarray], pad_tasks: int, pad_vms: int,
                   bucket, cost: "costmodel_mod.CostModel | None" = None
                   ) -> list[tuple[np.ndarray, dict[str, np.ndarray],
                                   dict[str, int] | None, int, int]]:
    """Partition grid cells into padded-shape buckets.

    Returns ``[(cell_indices, columns, static_params, pad_tasks, pad_vms)]``
    with indices ascending inside every bucket (so scattering results back
    by index reproduces the unbucketed cell order exactly).  The schedule
    (DESIGN.md §6, scored since §9 by the measured cost model):

    * **policy split** — when the grid mixes ``sched_policy`` /
      ``binding_policy`` values *and* every combination can amortize a
      dispatch (``N >= combos × 64``), cells split per combination and
      the uniform values become *static* encoder parameters — inside the
      fused bucket runner they are trace constants, so XLA eliminates the
      policy branches (admission ranking for time-shared buckets, the
      sequential LEAST_LOADED scan for non-LL buckets) the bucket cannot
      take, and each combination exits at its *own* realized epoch count
      (time-shared cells stop subsidizing space-shared serialization).
      A policy column that is uniform across the whole grid (e.g.
      base-pinned) is static without any split;
    * **task padding** — ``n_maps + n_reduces`` rounded up to a power of
      two (stable shapes across differently-composed grids), then
      ascending-size runs stand alone exactly when the *measured* cost
      model says the split pays: the lane-epoch work the run saves by
      running at its own padding instead of the grid cap
      (``cost.split_gain_us``) must exceed the one extra fused dispatch
      the split costs (``cost.dispatch_us``).  This replaces the old
      static ``min_cells = max(256, N // 4)`` magic number — on a fast
      device dispatches are cheap and grids shatter into more, tighter
      buckets; on a slow-dispatch host small runs merge upward;
    * **VM padding** — each bucket's ``n_vms`` max rounded up likewise
      (per-VM / per-task vector columns are sliced to the bucket width;
      entries past a cell's ``n_vms``/task count are ignored by
      ``encode_cell``, so slicing cannot change results).
    """
    N = len(next(iter(cols.values())))
    all_idx = np.arange(N)
    if bucket is False or bucket is None or N <= 1:
        return [(all_idx, cols, None, pad_tasks, pad_vms)]
    if bucket is not True and bucket != "auto":
        raise ValueError(
            f"run: bucket must be 'auto', True, or False; got {bucket!r}")
    cost = cost or costmodel_mod.default_cost_model()
    need_t = (cols["n_maps"].astype(np.int64)
              + cols["n_reduces"].astype(np.int64))
    need_v = cols["n_vms"].astype(np.int64)
    tb = _bucket_pads(need_t, pad_tasks)

    policy_cols = [p for p in ("sched_policy", "binding_policy")
                   if p in cols]
    # grid-uniform policy columns are *always* static (no split needed —
    # the whole grid shares the value, e.g. a base-pinned policy)
    uniform_pols = {p: int(cols[p][0]) for p in policy_cols
                    if len(np.unique(cols[p])) == 1}
    policy_names = [p for p in policy_cols if p not in uniform_pols]
    if policy_names:
        combo_key = np.stack([cols[p].astype(np.int64)
                              for p in policy_names], axis=1)
        combos, combo_id = np.unique(combo_key, axis=0, return_inverse=True)
        # policy split pays for itself far sooner than shape splits: each
        # combo exits at its own realized epoch count (time-shared combos
        # stop subsidizing space-shared serialization) and the statics DCE
        # the other policy's machinery — so it only needs each combo to
        # amortize one dispatch, not a full shape bucket
        if N < len(combos) * 64:            # too fragmented to specialize
            policy_names, combo_id = [], np.zeros(N, np.int64)
    else:
        combo_id = np.zeros(N, np.int64)

    merged: list[np.ndarray] = []
    for c in np.unique(combo_id):
        cidx = all_idx[combo_id == c]
        sizes = tb[cidx]
        pend: list[np.ndarray] = []
        done_here: list[np.ndarray] = []
        for t in np.unique(sizes):          # ascending shape runs
            pend.append(cidx[sizes == t])
            # stand alone exactly when the modelled lane-epoch saving of
            # running these cells at pad t instead of the grid cap buys
            # back the extra dispatch the split costs (near-max-shape
            # runs never qualify: the gain tends to zero as t -> cap)
            n_pend = sum(map(len, pend))
            if cost.split_gain_us(n_pend, int(t), pad_tasks) \
                    >= cost.dispatch_us:
                done_here.append(np.sort(np.concatenate(pend)))
                pend = []
        if pend:                            # tail that never paid alone
            tail = np.concatenate(pend)
            if done_here:
                # merging the tail down pulls the previous bucket's cells
                # UP to the tail's padding — keep the previous bucket
                # separate iff its own split gain vs the tail pad still
                # beats a dispatch
                prev = done_here[-1]
                t_prev = int(tb[prev].max())
                t_tail = int(tb[tail].max())
                if cost.split_gain_us(len(prev), t_prev, t_tail) \
                        < cost.dispatch_us:
                    tail = np.concatenate([done_here.pop(), tail])
            done_here.append(np.sort(tail))
        merged.extend(done_here)

    groups = []
    for idx in merged:
        t = _pow2_pad(int(need_t[idx].max()), pad_tasks)
        vb = _pow2_pad(int(need_v[idx].max()), pad_vms)
        statics = dict(uniform_pols)
        statics.update({p: int(cols[p][idx[0]]) for p in policy_names})
        gcols = {}
        for cname, cvals in cols.items():
            if cname in statics:
                continue
            cv = cvals[idx]
            if cv.ndim == 2:
                cv = cv[:, :t] if cname in _PER_TASK else cv[:, :vb]
            gcols[cname] = cv
        groups.append((idx, gcols, statics or None, t, vb))
    return groups


@lru_cache(maxsize=None)
def _fused_runner(names: tuple[str, ...], pad_tasks: int, pad_vms: int,
                  statics: tuple[tuple[str, int], ...], backend: str,
                  max_pes: int = 0, control: bool = False):
    """encode + simulate + metrics as ONE jitted callable per bucket
    signature.  A single dispatch per bucket (the bucketed schedule's fixed
    cost is dominated by per-call overhead on small hosts), and — the key
    effect — ``statics`` and encode_cell's scalar defaults become trace
    constants *inside the engine*, so XLA folds the per-bucket policy
    branches instead of carrying both policies' machinery at runtime."""
    static_kw = dict(statics)

    def run(*xs):
        def one(*cell):
            kw = dict(zip(names, cell))
            kw.update(static_kw)
            return encode_cell(**kw, pad_tasks=pad_tasks, pad_vms=pad_vms)

        batch = jax.vmap(one)(*xs)
        if backend == "pallas":
            from repro.kernels.mr_sched import \
                epoch_schedule  # lazy: ref.py cycle
            out = epoch_schedule(batch, max_pes=max_pes, control=control)
            realized = jnp.max(out.n_epochs)
        else:
            out, realized = simulate_batch_arrays(batch, control=control)
        return (jax.vmap(job_metrics)(batch, out),
                jax.vmap(scenario_metrics)(batch, out), realized)

    return jax.jit(run)


@jax.jit
def _metrics_batch(batch, out):
    """Fused metrics pass for the compacted path (its epoch stepping is
    host-driven, so metrics dispatch separately from simulation)."""
    return (jax.vmap(job_metrics)(batch, out),
            jax.vmap(scenario_metrics)(batch, out))


def _run_compact(cols: dict[str, np.ndarray], pad_tasks: int, pad_vms: int,
                 statics: dict[str, int] | None, backend: str, k, cost,
                 max_pes: int, control: bool = False,
                 stats: dict | None = None):
    """One compacted-stepping execution of a cell slice (DESIGN.md §9):
    jitted encode -> host-driven compacted epoch stepping -> jitted
    metrics.  Encode and metrics stay fused and signature-cached exactly
    like the dense runner; only the epoch loop leaves jit, because
    compaction needs host control flow over the active-lane count (XLA
    shapes are static)."""
    batch = grid_arrays(cols, pad_tasks=pad_tasks, pad_vms=pad_vms,
                        static_params=statics)
    if backend == "pallas":
        from repro.kernels.mr_sched import \
            epoch_schedule_compact  # lazy: ref.py cycle
        out, realized = epoch_schedule_compact(batch, k=k, max_pes=max_pes,
                                               cost_model=cost,
                                               control=control, stats=stats)
    else:
        out, realized = simulate_batch_arrays_compact(batch, k=k,
                                                      cost_model=cost,
                                                      control=control,
                                                      stats=stats)
    jm, sm = _metrics_batch(batch, out)
    return jm, sm, int(realized)


def _run_cells(cols: dict[str, np.ndarray], n: int, pad_tasks: int,
               pad_vms: int, statics: dict[str, int] | None,
               mesh, chunk, backend, compact=None, cost=None,
               stats: dict | None = None) -> tuple[
                   JobMetrics, ScenarioMetrics, np.ndarray]:
    """Encode + simulate one bucket's cells; returns host-side
    ``(JobMetrics, ScenarioMetrics, realized_epochs[n])``.  ``stats``
    (a dict, mutated in place) counts device ``dispatches`` plus the
    compact drivers' host ``syncs``/``compactions``."""
    if stats is None:
        stats = {}
    stats.setdefault("dispatches", 0)
    # the control path is keyed on column *presence* (host-decidable even
    # for traced columns — engine._control_active is not, under trace):
    # a plan that never names a control parameter pays zero control cost
    control = bool(_CONTROL_PARAMS & (set(cols) | set(statics or {})))
    if mesh is not None:
        # pod path: per-lane epoch loops (no per-epoch any() collective,
        # hence no dense tail for `compact` to trim — it is ignored here)
        n_dev = int(mesh.devices.size)
        full = -(-n // n_dev) * n_dev
        batch = grid_arrays(_pad_cells(cols, full), pad_tasks=pad_tasks,
                            pad_vms=pad_vms, static_params=statics)
        jm, sm = _simulate_full_sharded(batch, mesh, control)
        stats["dispatches"] += 1
        jm = jax.tree.map(lambda x: np.asarray(x)[:n], jm)
        sm = jax.tree.map(lambda x: np.asarray(x)[:n], sm)
        realized = np.full(n, int(np.max(sm.n_epochs)), np.int32)
        return jm, sm, realized
    max_pes = (max(int(np.ceil(float(np.max(cols["vm_pes"])))), 1)
               if backend == "pallas" else 0)
    if compact is not None:
        if chunk is not None:
            parts, realized = [], np.empty(n, np.int32)
            for lo in range(0, n, chunk):
                part = _pad_cells(
                    {k: v[lo:lo + chunk] for k, v in cols.items()},
                    min(chunk, n))
                take = min(chunk, n - lo)
                jm, sm, rz = _run_compact(part, pad_tasks, pad_vms, statics,
                                          backend, compact, cost, max_pes,
                                          control, stats)
                parts.append(jax.tree.map(lambda x: np.asarray(x)[:take],
                                          (jm, sm)))
                realized[lo:lo + take] = rz
            jm, sm = jax.tree.map(lambda *xs: np.concatenate(xs), *parts)
            return jm, sm, realized
        jm, sm, rz = _run_compact(cols, pad_tasks, pad_vms, statics,
                                  backend, compact, cost, max_pes, control,
                                  stats)
        jm = jax.tree.map(np.asarray, jm)
        sm = jax.tree.map(np.asarray, sm)
        return jm, sm, np.full(n, rz, np.int32)
    names = tuple(sorted(cols))
    runner = _fused_runner(names, pad_tasks, pad_vms,
                           tuple(sorted((statics or {}).items())),
                           backend, max_pes, control)
    if chunk is not None:
        parts, realized = [], np.empty(n, np.int32)
        for lo in range(0, n, chunk):
            part = _pad_cells({k: v[lo:lo + chunk] for k, v in cols.items()},
                              min(chunk, n))
            take = min(chunk, n - lo)
            jm, sm, rz = runner(*(jnp.asarray(part[k]) for k in names))
            stats["dispatches"] += 1
            parts.append(jax.tree.map(lambda x: np.asarray(x)[:take],
                                      (jm, sm)))
            realized[lo:lo + take] = int(rz)
        jm, sm = jax.tree.map(lambda *xs: np.concatenate(xs), *parts)
        return jm, sm, realized
    jm, sm, rz = runner(*(jnp.asarray(cols[k]) for k in names))
    stats["dispatches"] += 1
    jm = jax.tree.map(np.asarray, jm)
    sm = jax.tree.map(np.asarray, sm)
    return jm, sm, np.full(n, int(rz), np.int32)


def _plain_label(v):
    """One coordinate label as a column-friendly scalar (enum -> name,
    nested sequences -> string)."""
    if isinstance(v, enum.Enum):
        return v.name
    if isinstance(v, (tuple, list, np.ndarray)):
        return ",".join(str(_plain_label(x)) for x in np.asarray(v).tolist())
    return v


def _long_form_columns(axis_names, axis_labels, shape, flat_metrics,
                       n_jobs, lo, hi) -> dict[str, np.ndarray]:
    """Long-form rows for the flat grid cells ``[lo, hi)`` — the ONE
    row encoding behind :meth:`SweepResult.to_table` (whole grid) and
    the streamed parquet writer (one chunk at a time), so the two
    export paths cannot drift.  ``flat_metrics`` maps metric names to
    ``[n, n_jobs]`` (per-job) or ``[n]`` (per-scenario) columns for the
    slice; axis coordinates expand through :func:`_plain_label`, cells
    with several jobs gain a ``job`` index column.
    """
    n = hi - lo
    flat = np.arange(lo, hi)
    cols: dict[str, np.ndarray] = {}
    for d, (names, labs) in enumerate(zip(axis_names, axis_labels)):
        inner = int(np.prod(shape[d + 1:], dtype=np.int64))
        di = (flat // inner) % shape[d]
        for ci, cname in enumerate(names):
            vals = np.asarray([_plain_label(lab[ci]) for lab in labs])
            cols[cname] = np.repeat(vals[di], n_jobs)
    if n_jobs > 1:
        cols["job"] = np.tile(np.arange(n_jobs), n)
    for mname, m in flat_metrics.items():
        cols[mname] = (m.reshape(n * n_jobs) if m.ndim == 2
                       else np.repeat(m, n_jobs))
    return cols


def _match_label(label, want) -> bool:
    if label is want:
        return True
    if isinstance(label, enum.Enum) and isinstance(want, str):
        return label.name == want
    try:
        return bool(label == want)
    except (TypeError, ValueError):
        return False


@dataclasses.dataclass(frozen=True)
class SweepResult:
    """Labeled sweep output: axis coordinates + named metric arrays.

    ``metrics[name]`` has the plan's grid shape (per-job metrics gain a
    trailing job dim when a cell holds more than one job).  Per-job metrics
    are the paper's §5.3 dependent variables (:class:`JobMetrics` fields,
    including ``completion``); per-scenario extras are ``finish_time``,
    ``utilization`` and ``n_epochs`` (:class:`ScenarioMetrics`).
    """
    axis_names: tuple[tuple[str, ...], ...]
    axis_labels: tuple[tuple[tuple[Any, ...], ...], ...]
    metrics: Mapping[str, np.ndarray]
    n_jobs: int = 1

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(len(labs) for labs in self.axis_labels)

    @property
    def metric_names(self) -> tuple[str, ...]:
        return tuple(self.metrics)

    def __getitem__(self, name: str) -> np.ndarray:
        try:
            return self.metrics[name]
        except KeyError:
            raise KeyError(f"no metric {name!r}; "
                           f"available: {list(self.metrics)}") from None

    def coord(self, index: Sequence[int]) -> dict[str, Any]:
        """Axis coordinates of one grid point (e.g. from unravel_index)."""
        out: dict[str, Any] = {}
        for d, (names, labs) in enumerate(zip(self.axis_names,
                                              self.axis_labels)):
            out.update(zip(names, labs[int(index[d])]))
        return out

    def select(self, **coords: Any) -> "SweepResult":
        """Slice by axis-coordinate labels (``select(n_maps=4,
        vm_type="medium")``).  Coordinates matching exactly one point drop
        their dimension; several matches keep a filtered dimension.  Zipped
        dimensions are addressed through any of their component names —
        several components of one zipped dimension constrain it jointly."""
        names = list(self.axis_names)
        labels = list(self.axis_labels)
        metrics = dict(self.metrics)
        by_dim: dict[int, dict[str, Any]] = {}
        for key, want in coords.items():
            for d, ns in enumerate(names):
                if key in ns:
                    by_dim.setdefault(d, {})[key] = want
                    break
            else:
                raise KeyError(
                    f"select: no axis {key!r}; axes: "
                    f"{[n for ns in names for n in ns]}")
        for d in sorted(by_dim, reverse=True):   # right-to-left: stable axes
            wants = by_dim[d]
            comp = {k: names[d].index(k) for k in wants}
            hits = [i for i, lab in enumerate(labels[d])
                    if all(_match_label(lab[comp[k]], w)
                           for k, w in wants.items())]
            if not hits:
                raise KeyError(
                    f"select: {wants} not on the axis "
                    f"{'×'.join(names[d])}; labels: {list(labels[d])}")
            if len(hits) == 1:
                metrics = {k: v.take(hits[0], axis=d)
                           for k, v in metrics.items()}
                del names[d], labels[d]
            else:
                metrics = {k: v.take(hits, axis=d) for k, v in metrics.items()}
                labels[d] = tuple(labels[d][i] for i in hits)
        return SweepResult(tuple(names), tuple(labels), metrics, self.n_jobs)

    def to_dict(self) -> dict[str, Any]:
        """Metrics as plain ``{name: ndarray}`` (0-d arrays as scalars)."""
        return {k: (v.item() if np.ndim(v) == 0 else np.asarray(v))
                for k, v in self.metrics.items()}

    def to_table(self) -> dict[str, np.ndarray]:
        """Columnar (long-form) export: equal-length numpy columns, one
        row per grid cell — times ``n_jobs`` (plus a ``job`` index column)
        when cells hold several jobs.  Axis coordinates come first in
        row-major grid order, metric columns follow.  Enum labels export
        as their names and tuple labels (``vms`` clusters, per-VM vectors)
        as strings, so every column is numeric/bool/string — directly
        consumable by pandas/pyarrow (:meth:`to_parquet`); the first slice
        of the ROADMAP columnar-export item."""
        shape = self.shape
        N = int(np.prod(shape, dtype=np.int64)) if shape else 1
        nj = self.n_jobs
        flat = {}
        for mname, m in self.metrics.items():
            arr = np.asarray(m)
            flat[mname] = (arr.reshape(N, nj)        # trailing per-job dim
                           if arr.ndim == len(shape) + 1
                           else arr.reshape(N))      # per-scenario metric
        return _long_form_columns(self.axis_names, self.axis_labels, shape,
                                  flat, nj, 0, N)

    def to_parquet(self, path) -> None:
        """Write :meth:`to_table` to a parquet file, stamping run
        provenance (repro/jax versions, device, git sha) into the schema
        metadata (DESIGN.md §12).  Needs the *optional* ``pyarrow``
        dependency — import-guarded so the simulator core never depends
        on it."""
        try:
            import pyarrow as pa
            import pyarrow.parquet as pq
        except ImportError as e:                  # pragma: no cover - env
            raise ImportError(
                "SweepResult.to_parquet requires the optional pyarrow "
                "dependency (pip install pyarrow); to_table() returns the "
                "same columns as plain numpy") from e
        table = pa.table(dict(self.to_table()))
        table = table.replace_schema_metadata(
            {**(table.schema.metadata or {}),
             **telemetry.parquet_metadata()})
        pq.write_table(table, path)

    def __repr__(self) -> str:
        ax = ", ".join(f"{'×'.join(ns)}[{len(labs)}]"
                       for ns, labs in zip(self.axis_names, self.axis_labels))
        return (f"SweepResult(axes=({ax}), n_jobs={self.n_jobs}, "
                f"metrics={list(self.metrics)})")


# ---------------------------------------------------------------------------
# Batched simulation entry points
# ---------------------------------------------------------------------------

def _one_full(sc: ScenarioArrays,
              control: bool = False) -> tuple[JobMetrics, ScenarioMetrics]:
    out = simulate_arrays(sc, control=control)
    return job_metrics(sc, out), scenario_metrics(sc, out)


@lru_cache(maxsize=None)
def _sharded_runner(mesh: jax.sharding.Mesh, control: bool = False):
    """One jitted sharded simulate per mesh — repeated ``run(mesh=…)`` calls
    reuse the compilation instead of retracing through a fresh lambda."""
    sharding = jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec(mesh.axis_names))
    return jax.jit(jax.vmap(partial(_one_full, control=control)),
                   in_shardings=sharding, out_shardings=sharding)


def _simulate_full_sharded(batch: ScenarioArrays, mesh: jax.sharding.Mesh,
                           control: bool = False):
    return _sharded_runner(mesh, control)(batch)


@partial(jax.jit, static_argnames="control")
def _simulate_batch_jit(batch: ScenarioArrays,
                        control: bool = False) -> JobMetrics:
    def one(sc):
        return job_metrics(sc, simulate_arrays(sc, control=control))
    return jax.vmap(one)(batch)


def simulate_batch(batch: ScenarioArrays) -> JobMetrics:
    """vmap the engine + metrics over a leading scenario dim."""
    from .engine import _control_active
    return _simulate_batch_jit(batch, control=_control_active(batch))


def simulate_batch_sharded(batch: ScenarioArrays,
                           mesh: jax.sharding.Mesh) -> JobMetrics:
    """The pod-scale path: scenarios sharded over every mesh axis.

    The engine is embarrassingly parallel across scenarios, so the batch dim
    is sharded over the flattened mesh; no collectives are emitted (verified
    in the dry-run — this workload is the compute-roofline end of the
    simulator story).
    """
    from .engine import _control_active
    control = _control_active(batch)
    spec = jax.sharding.PartitionSpec(mesh.axis_names)
    sharding = jax.sharding.NamedSharding(mesh, spec)
    fn = jax.jit(
        lambda b: jax.vmap(lambda s: job_metrics(
            s, simulate_arrays(s, control=control)))(b),
        in_shardings=(jax.tree.map(lambda _: sharding, batch),),
        out_shardings=sharding)
    return fn(batch)

