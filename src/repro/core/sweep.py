"""Massive scenario sweeps: vmap over scenarios, pjit over the pod mesh.

CloudSim/IOTSim runs one scenario per JVM process; every figure in the paper
is a parameter sweep re-run by hand.  Here a sweep is one ``vmap`` of the
vectorized engine over a stacked :class:`ScenarioArrays` batch, sharded over
every mesh axis — a pod simulates millions of datacentre scenarios in one
``pjit`` call.  This is the headline TPU adaptation of the paper's technique
(DESIGN.md §2) and the subject of ``benchmarks/sweep_throughput.py``.

Two batch builders:

* :func:`stack_scenarios` — host-side: encode arbitrary ``Scenario`` objects
  (heterogeneous jobs/VMs) and stack with common padding;
* :func:`encode_cell` / :func:`grid_arrays` — device-side: build the paper's
  homogeneous experiment cells directly from scalar parameters, entirely in
  jnp, so huge grids never materialize on the host.
"""
from __future__ import annotations

from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .config import (BindingPolicy, Scenario, SchedPolicy,
                     base_task_lengths_f32)
from .engine import (JobMetrics, ScenarioArrays, bind_tasks, from_scenario,
                     job_metrics, simulate_arrays)


# ---------------------------------------------------------------------------
# Host-side batch builder
# ---------------------------------------------------------------------------

def stack_scenarios(scenarios: Sequence[Scenario]) -> ScenarioArrays:
    """Encode + stack scenarios with shared padding (leading batch dim)."""
    T = max(s.total_tasks() for s in scenarios)
    J = max(len(s.jobs) for s in scenarios)
    V = max(len(s.vms) for s in scenarios)
    encoded = [from_scenario(s, pad_tasks=T, pad_jobs=J, pad_vms=V)
               for s in scenarios]
    return ScenarioArrays(*(np.stack([np.asarray(getattr(e, f))
                                      for e in encoded])
                            for f in ScenarioArrays._fields))


# ---------------------------------------------------------------------------
# Device-side cell encoder (paper §5 experiment cells)
# ---------------------------------------------------------------------------

def encode_cell(n_maps, n_reduces, n_vms, vm_mips, vm_pes, vm_cost,
                job_length, job_data, *, pad_tasks: int, pad_vms: int,
                reduce_factor=0.5, net_enabled=1.0, net_bw=1000.0,
                kappa_in=17.0, kappa_shuffle=4.25, net_cost_per_unit=1.0,
                task_mult=None, sched_policy=0,
                binding_policy=0) -> ScenarioArrays:
    """One homogeneous paper cell as traced arrays.

    All scalar args may be traced — ``vmap`` this over parameter grids;
    ``sched_policy``/``binding_policy`` are plain i32 scalars, so one grid
    may mix policies (Group 5).  ``pad_tasks``/``pad_vms`` are static
    paddings (>= max M+R / max V).
    """
    f32 = partial(jnp.asarray, dtype=jnp.float32)
    i32 = partial(jnp.asarray, dtype=jnp.int32)
    t = jnp.arange(pad_tasks)
    n_maps, n_reduces, n_vms = i32(n_maps), i32(n_reduces), i32(n_vms)
    n_tasks = n_maps + n_reduces
    is_red = t >= n_maps
    valid = t < n_tasks
    if task_mult is None:
        task_mult = jnp.ones(pad_tasks, jnp.float32)
    vm_valid = jnp.arange(pad_vms) < n_vms
    vm_mips_a = jnp.where(vm_valid, f32(vm_mips), 1.0)
    vm_pes_a = jnp.where(vm_valid, f32(vm_pes), 1.0)
    map_len, red_len = base_task_lengths_f32(
        f32(job_length), n_maps.astype(jnp.float32),
        n_reduces.astype(jnp.float32), f32(reduce_factor))
    base_len = jnp.where(is_red, red_len, map_len)
    return ScenarioArrays(
        task_job=jnp.zeros(pad_tasks, jnp.int32),
        task_is_reduce=is_red & valid,
        task_vm=bind_tasks(binding_policy, valid, base_len, vm_mips_a,
                           vm_pes_a, vm_valid),
        task_valid=valid,
        task_mult=task_mult,
        job_length=f32(job_length)[None],
        job_data=f32(job_data)[None],
        job_n_maps=n_maps[None],
        job_n_reduces=n_reduces[None],
        job_submit=jnp.zeros(1, jnp.float32),
        job_reduce_factor=f32(reduce_factor)[None],
        job_valid=jnp.ones(1, bool),
        vm_mips=vm_mips_a,
        vm_pes=vm_pes_a,
        vm_cost=jnp.where(vm_valid, f32(vm_cost), 0.0),
        vm_valid=vm_valid,
        net_enabled=f32(net_enabled), net_bw=f32(net_bw),
        kappa_in=f32(kappa_in), kappa_shuffle=f32(kappa_shuffle),
        net_cost_per_unit=f32(net_cost_per_unit),
        sched_policy=i32(sched_policy),
        binding_policy=i32(binding_policy),
    )


def grid_arrays(params: dict[str, np.ndarray], *, pad_tasks: int,
                pad_vms: int) -> ScenarioArrays:
    """vmap :func:`encode_cell` over equal-length 1-D parameter arrays."""
    names = list(params)
    vals = [jnp.asarray(params[n]) for n in names]

    def one(*xs):
        return encode_cell(**dict(zip(names, xs)), pad_tasks=pad_tasks,
                           pad_vms=pad_vms)

    return jax.vmap(one)(*vals)


# ---------------------------------------------------------------------------
# Batched simulation entry points
# ---------------------------------------------------------------------------

@jax.jit
def simulate_batch(batch: ScenarioArrays) -> JobMetrics:
    """vmap the engine + metrics over a leading scenario dim."""
    def one(sc):
        return job_metrics(sc, simulate_arrays(sc))
    return jax.vmap(one)(batch)


def simulate_batch_sharded(batch: ScenarioArrays,
                           mesh: jax.sharding.Mesh) -> JobMetrics:
    """The pod-scale path: scenarios sharded over every mesh axis.

    The engine is embarrassingly parallel across scenarios, so the batch dim
    is sharded over the flattened mesh; no collectives are emitted (verified
    in the dry-run — this workload is the compute-roofline end of the
    simulator story).
    """
    spec = jax.sharding.PartitionSpec(mesh.axis_names)
    sharding = jax.sharding.NamedSharding(mesh, spec)
    fn = jax.jit(
        lambda b: jax.vmap(lambda s: job_metrics(s, simulate_arrays(s)))(b),
        in_shardings=(jax.tree.map(lambda _: sharding, batch),),
        out_shardings=sharding)
    return fn(batch)


def paper_grid(m_range=range(1, 21), vm_numbers=(3,), vm_types=("small",),
               job_types=("small",), network_delay=True,
               sched_policy=SchedPolicy.TIME_SHARED,
               binding_policy=BindingPolicy.ROUND_ROBIN) -> ScenarioArrays:
    """Cartesian paper grid (Groups 1–4) as a device-side batch."""
    from .config import JOB_TYPES, VM_TYPES
    cells = [(m, v, VM_TYPES[vt], JOB_TYPES[jt])
             for m in m_range for v in vm_numbers
             for vt in vm_types for jt in job_types]
    params = dict(
        n_maps=np.array([c[0] for c in cells], np.int32),
        n_reduces=np.ones(len(cells), np.int32),
        n_vms=np.array([c[1] for c in cells], np.int32),
        vm_mips=np.array([c[2].mips for c in cells], np.float32),
        vm_pes=np.array([float(c[2].pes) for c in cells], np.float32),
        vm_cost=np.array([c[2].cost_per_sec for c in cells], np.float32),
        job_length=np.array([c[3].length_mi for c in cells], np.float32),
        job_data=np.array([c[3].data_mb for c in cells], np.float32),
        net_enabled=np.full(len(cells), 1.0 if network_delay else 0.0,
                            np.float32),
        sched_policy=np.full(len(cells), int(sched_policy), np.int32),
        binding_policy=np.full(len(cells), int(binding_policy), np.int32),
    )
    pad_tasks = max(m_range) + 1
    pad_vms = max(vm_numbers)
    return grid_arrays(params, pad_tasks=pad_tasks, pad_vms=pad_vms)


def policy_grid(m_range=range(1, 21), n_vms=3, vm_type="small",
                job_type="small", network_delay=True) -> tuple[
                    ScenarioArrays, list[tuple[SchedPolicy, BindingPolicy]]]:
    """Group 5 (beyond-paper): the paper's Group-1 sweep crossed with every
    (sched_policy × binding_policy) combination — one mixed-policy batch,
    one lowering.  Returns the batch plus the per-block policy labels
    (block i covers rows [i*len(m_range), (i+1)*len(m_range))).
    """
    from .config import JOB_TYPES, VM_TYPES
    combos = [(sp, bp) for sp in SchedPolicy for bp in BindingPolicy]
    cells = [(m, sp, bp) for sp, bp in combos for m in m_range]
    vm, job = VM_TYPES[vm_type], JOB_TYPES[job_type]
    n = len(cells)
    params = dict(
        n_maps=np.array([c[0] for c in cells], np.int32),
        n_reduces=np.ones(n, np.int32),
        n_vms=np.full(n, n_vms, np.int32),
        vm_mips=np.full(n, vm.mips, np.float32),
        vm_pes=np.full(n, float(vm.pes), np.float32),
        vm_cost=np.full(n, vm.cost_per_sec, np.float32),
        job_length=np.full(n, job.length_mi, np.float32),
        job_data=np.full(n, job.data_mb, np.float32),
        net_enabled=np.full(n, 1.0 if network_delay else 0.0, np.float32),
        sched_policy=np.array([int(c[1]) for c in cells], np.int32),
        binding_policy=np.array([int(c[2]) for c in cells], np.int32),
    )
    batch = grid_arrays(params, pad_tasks=max(m_range) + 1, pad_vms=n_vms)
    return batch, combos
