"""Vectorized discrete-event engine (the TPU-native IOTSim core).

The sequential CloudSim event loop (``refsim.py``) is re-expressed as a
fixed-shape state machine advanced by ``jax.lax.while_loop``: each iteration
processes one *event epoch* — it advances the processor-sharing fluid state
to the earliest next completion/arrival and fires every event at that
instant.  Rates only change at events, so the fluid dynamics are exact (this
is not time-stepping).

Because every per-scenario state is a fixed-shape array bundle
(:class:`ScenarioArrays`), the whole simulation is ``vmap``-able over
scenarios and ``pjit``-able over a pod mesh — one lowering simulates millions
of IOTSim scenarios in parallel (see ``sweep.py``).  This is the
hardware-adaptation of the paper's sequential Java architecture (DESIGN.md
§2).

Semantics are tested to match ``refsim.py`` exactly
(``tests/test_engine_vs_refsim.py``).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import elasticity, network, storage
from .config import (BindingPolicy, Scenario, SchedPolicy,
                     base_task_lengths_f32)
from .control import (ControlPolicy, DeadlinePolicy, earliest_finish,
                      failover_targets, scenario_control)
from .telemetry import (EV_FINISH, EV_KILL, EV_PREEMPT, EV_SCALE_CLOSE,
                        EV_SCALE_OPEN, EV_SHED, EV_START, TraceBuffers,
                        event_capacity, timeseries_capacity)
from .util import pow2_pad, validate_pow2_floor

_BIG = 1e30          # stand-in for +inf that survives arithmetic
_TIME_EPS = 1e-6     # relative tie window for simultaneous events


# ---------------------------------------------------------------------------
# Array-of-structs scenario encoding
# ---------------------------------------------------------------------------

class ScenarioArrays(NamedTuple):
    """One scenario as fixed-shape arrays (all leaves vmappable).

    Shapes: T = padded task count, J = padded job count, V = padded VM count.
    Task structure (which job, map/reduce, VM binding) is *data*, so sweeps
    may vary MR combination, job sizes, VM speeds … under ``vmap`` without
    re-tracing.
    """
    # tasks
    task_job: jax.Array        # i32[T] job index
    task_is_reduce: jax.Array  # bool[T]
    task_vm: jax.Array         # i32[T] policy-resolved VM binding
    task_valid: jax.Array      # bool[T]
    task_mult: jax.Array       # f32[T] straggler length multiplier
    # jobs
    job_length: jax.Array      # f32[J] MI
    job_data: jax.Array        # f32[J] MB
    job_n_maps: jax.Array      # i32[J]
    job_n_reduces: jax.Array   # i32[J]
    job_submit: jax.Array      # f32[J]
    job_reduce_factor: jax.Array  # f32[J]
    job_valid: jax.Array       # bool[J]
    # vms
    vm_mips: jax.Array         # f32[V]
    vm_pes: jax.Array          # f32[V]
    vm_cost: jax.Array         # f32[V]
    vm_valid: jax.Array        # bool[V]
    # network (scalars)
    net_enabled: jax.Array     # f32 (0/1)
    net_bw: jax.Array          # f32
    kappa_in: jax.Array        # f32
    kappa_shuffle: jax.Array   # f32
    net_cost_per_unit: jax.Array  # f32
    # policies (i32 scalars — data, not trace constants: one lowering serves
    # batches mixing policies under vmap; see config.SchedPolicy)
    sched_policy: jax.Array    # i32 (0 time-shared | 1 space-shared)
    binding_policy: jax.Array  # i32 (0 RR | 1 least-loaded | 2 packed |
    #                            3 locality); already resolved into task_vm,
    #                            kept as provenance alongside the binding
    # storage (DESIGN.md §7): realized block placement as per-task data —
    # replication / block size / placement skew are sweepable like any
    # other parameter because only their *realization* reaches the engine
    block_vm: jax.Array        # i32[T, V] replica VMs of the task's input
    #                            block in replica-slot order; -1 = no slot
    #                            (reduces, padding, storage disabled)
    block_size: jax.Array      # f32[T] input-block size in MB (0 = none)
    storage_enabled: jax.Array  # f32 (0/1) provenance gate
    # elasticity (DESIGN.md §8): per-VM lease windows + pay-as-you-go knobs.
    # The degenerate static fleet is vm_start=0 / vm_stop=_BIG everywhere —
    # every availability op below is a bitwise identity there.
    vm_start: jax.Array        # f32[V] lease start (billing runs from here)
    vm_stop: jax.Array         # f32[V] lease stop; _BIG = never torn down
    spinup_delay: jax.Array    # f32 scalar — admission opens at start+spinup
    bill_gran: jax.Array       # f32 scalar — billing granularity (seconds)
    task_prio: jax.Array       # f32[T] space-shared admission priority
    #                            (higher admitted first; 0 = legacy rank)
    # closed-loop control (DESIGN.md §10): seeded failure streams realized
    # as per-VM instants (control.failure_times — host f64, cast once) and
    # the autoscale rule's inputs, all device-side sweepable data.  The
    # degenerate fill (_BIG fails, no reserves, NONE policy) is detected
    # host-side (_control_active) and skips the control code entirely.
    vm_fail: jax.Array         # f32[V] failure instant; _BIG = never fails
    vm_restore: jax.Array      # f32[V] restore instant; _BIG = never
    vm_auto: jax.Array         # bool[V] autoscale reserve (lease
    #                            materializes only when control opens it)
    control_policy: jax.Array  # i32 (0 NONE | 1 AUTOSCALE)
    ctl_queue: jax.Array       # f32 scalar — scale up while queue depth
    #                            (ready, unstarted tasks) exceeds this
    ctl_busy: jax.Array        # f32 scalar — … and the open fleet's busy
    #                            fraction is at least this
    redispatch_delay: jax.Array  # f32 scalar — failure-detection +
    #                              re-queue latency added on task kill
    # graceful degradation (DESIGN.md §11): per-task decision windows and
    # the overload-policy knobs.  The degenerate fill (deadline _BIG,
    # NONE policy, preemption off) is a bitwise identity with §10.
    task_deadline: jax.Array   # f32[T] completion deadline; _BIG = none
    deadline_policy: jax.Array  # i32 (0 NONE | 1 SHED | 2 BOOST)
    deadline_slack: jax.Array  # f32 scalar — BOOST urgency window
    preempt: jax.Array         # i32 (0/1) — priority preemption on
    preempt_resume: jax.Array  # i32 (0/1) — evicted tasks keep progress


class SimOutput(NamedTuple):
    """Raw per-task schedule + bookkeeping, all f32/i32 arrays."""
    start: jax.Array     # f32[T]
    finish: jax.Array    # f32[T]
    ready: jax.Array     # f32[T]
    exec_time: jax.Array  # f32[T]
    n_epochs: jax.Array  # i32 — event epochs executed (bench metric)
    finish_time: jax.Array  # f32 — last completion
    # closed-loop control results (degenerate fills reproduce the encoded
    # scenario: hit all-false, vm_open/vm_close the static lease window)
    hit: jax.Array       # bool[T] task was killed by a VM failure at
    #                      least once (now bound to its failover VM)
    task_vm2: jax.Array  # i32[T] failover binding (== task_vm when
    #                      control is off; current VM = hit ? vm2 : vm)
    vm_open: jax.Array   # f32[V] realized lease open (_BIG = never)
    vm_close: jax.Array  # f32[V] realized lease close (_BIG = never)
    n_scale: jax.Array   # i32 — autoscale open+close events executed
    # graceful degradation (DESIGN.md §11; exact zero fills when off)
    shed: jax.Array      # bool[T] task shed by deadline admission control
    #                      (never started, deadline unmeetable — includes
    #                      reduces orphaned by a shed map of their job)
    n_evict: jax.Array   # i32[T] times the task was preempted (<= 2)
    work_lost: jax.Array  # f32 — MI of progress discarded by failure
    #                       kills + non-resume preemptions


class JobMetrics(NamedTuple):
    """Paper §5.3 dependent variables, per job (padded J)."""
    avg_exec: jax.Array
    max_exec: jax.Array
    min_exec: jax.Array
    makespan: jax.Array
    delay_time: jax.Array
    vm_cost: jax.Array
    network_cost: jax.Array
    map_avg_exec: jax.Array
    reduce_avg_exec: jax.Array
    completion: jax.Array      # wall-clock last-reduce finish (0 for padding)


class ScenarioMetrics(NamedTuple):
    """Per-scenario (not per-job) dependent variables for sweep results."""
    finish_time: jax.Array   # f32 — wall-clock end of the scenario
    utilization: jax.Array   # f32 — delivered MI / (cluster capacity × time)
    n_epochs: jax.Array      # i32 — event epochs executed (bench metric)
    locality_fraction: jax.Array  # f32 — data-local maps / maps with a
    #                               placed input block (0 if storage off)
    transfer_bytes: jax.Array  # f32 — remote-fetched block bytes (decimal
    #                            MB × 1e6; 0 under LOCALITY's ideal case)
    billed_cost: jax.Array   # f32 — pay-as-you-go fleet cost: per-VM
    #                          realized lease, ceil'd to the billing
    #                          granularity, × cost_per_sec (DESIGN.md §8)
    vm_busy_fraction: jax.Array  # f32 — delivered MI / leased MI capacity
    #                              (capacity-weighted busy share of the
    #                              fleet's realized leases)
    queue_wait: jax.Array    # f32 — mean start − ready over started tasks
    #                          (slot + lease-availability + spinup waits)
    # closed-loop control metrics (DESIGN.md §10; 0 in open-loop runs)
    failures_injected: jax.Array   # f32 — valid-VM failures fired within
    #                                the scenario's wall-clock span
    tasks_redispatched: jax.Array  # f32 — tasks killed + re-dispatched
    scale_events: jax.Array        # f32 — autoscale lease opens + closes
    recovered_fraction: jax.Array  # f32 — re-dispatched tasks that still
    #                                completed / re-dispatched (0 if none)
    # SLO metrics layer (DESIGN.md §11; exact zeros without deadlines)
    deadline_miss_fraction: jax.Array  # f32 — finite-deadline tasks that
    #                                    finished late or never / all
    #                                    finite-deadline tasks
    shed_tasks: jax.Array          # f32 — tasks shed by admission control
    preemptions: jax.Array         # f32 — priority evictions executed
    wasted_work_frac: jax.Array    # f32 — (discarded progress + late
    #                                completions' MI) / (delivered MI +
    #                                discarded progress)
    p99_slack: jax.Array           # f32 — nearest-rank p99 of
    #                                finish − deadline over *completed*
    #                                finite-deadline tasks (<= 0 is good)


def task_lengths(sc: ScenarioArrays) -> jax.Array:
    """Effective per-task lengths in MI (straggler multiplier applied).

    The exact op sequence ``simulate_arrays`` integrates, factored out so
    metrics layers (utilization) account the same work the engine runs.
    """
    n_maps_f = sc.job_n_maps.astype(jnp.float32)
    n_red_f = sc.job_n_reduces.astype(jnp.float32)
    map_len = sc.job_length / n_maps_f
    red_len = sc.job_reduce_factor * sc.job_length / n_red_f
    task_len = jnp.where(sc.task_is_reduce, red_len[sc.task_job],
                         map_len[sc.task_job]) * sc.task_mult
    return jnp.where(sc.task_valid, task_len, 0.0)


def bind_tasks(binding_policy, task_valid, task_len, vm_mips, vm_pes,
               vm_valid, locality_cand=None) -> jax.Array:
    """Resolve the broker's task→VM binding as data (DESIGN.md §3.2).

    ``binding_policy`` may be a traced i32 scalar, so a vmapped batch can
    mix :class:`~repro.core.config.BindingPolicy` values without retracing;
    all four strategies are computed and selected branch-free.  ``task_len``
    is the *base* (pre-straggler-multiplier) length — the broker binds
    before execution, so multipliers must not influence placement.  The
    LEAST_LOADED estimate is ``assigned_MI / (mips * pes)`` (full-VM
    capacity, so multi-PE VMs are not undervalued) accumulated in float32,
    matching the oracle's bookkeeping bit for bit so both layers pick
    identical VMs.

    ``locality_cand`` is LOCALITY's ``bool[T, V]`` candidate mask
    (``storage.locality_candidates``: replica holders for tasks with an
    input block, all valid VMs otherwise).  ``None`` — no storage model —
    makes LOCALITY bind exactly as LEAST_LOADED (same scan, all-true
    mask), which is also what an all-true mask produces bit for bit.
    """
    task_valid = jnp.asarray(task_valid, bool)
    task_len = jnp.asarray(task_len, jnp.float32)
    vm_mips = jnp.asarray(vm_mips, jnp.float32)
    vm_valid = jnp.asarray(vm_valid, bool)
    T = task_valid.shape[0]
    bp = jnp.asarray(binding_policy, jnp.int32)
    validi = task_valid.astype(jnp.int32)
    counter = jnp.cumsum(validi) - validi          # submission-order index
    n_vms = jnp.maximum(jnp.sum(vm_valid.astype(jnp.int32)), 1)
    rr = counter % n_vms

    # PACKED: fill PE slots [vm0]*pes0 ++ [vm1]*pes1 ++ … cyclically.
    pes_i = jnp.where(vm_valid, jnp.asarray(vm_pes, jnp.int32), 0)
    total_pes = jnp.maximum(jnp.sum(pes_i), 1)
    slot = counter % total_pes
    cum_pes = jnp.cumsum(pes_i)
    packed = jnp.sum((slot[:, None] >= cum_pes[None, :]).astype(jnp.int32),
                     axis=1)

    # LEAST_LOADED: greedy argmin over f32 load estimate (MI / mips).
    load0 = jnp.where(vm_valid, 0.0, jnp.float32(_BIG))

    vm_pes_f = jnp.asarray(vm_pes, jnp.float32)

    vm_iota = jnp.arange(vm_mips.shape[0])

    def ll_step(i, carry):
        load, out = carry
        v = jnp.argmin(load).astype(jnp.int32)
        add = jnp.where(task_valid[i],
                        task_len[i] / (vm_mips[v] * vm_pes_f[v]), 0.0)
        # one-hot add instead of load.at[v].add: under vmap the scatter
        # serializes on CPU and dominated mixed-binding encode time; adding
        # 0.0 to untouched lanes is bit-identical (loads are never -0.0)
        return (load + jnp.where(vm_iota == v, add, 0.0),
                out.at[i].set(v))

    _, ll = jax.lax.fori_loop(0, T, ll_step,
                              (load0, jnp.zeros(T, jnp.int32)))

    # LOCALITY: the same greedy f32 scan, argmin restricted per task to its
    # candidate mask.  Masking with _BIG reproduces load0's invalid-VM fill,
    # so an all-true row replays LEAST_LOADED's argmin sequence bit for bit
    # (the degenerate-parity property: replication == n_vms, reduces, or a
    # disabled store).  A separate fori_loop, not a branch inside ll_step:
    # under a *static* binding_policy (the bucketed sweep path) XLA DCEs
    # whichever scan the bucket cannot take.
    if locality_cand is None:
        loc = ll
    else:
        cand = jnp.asarray(locality_cand, bool)

        def loc_step(i, carry):
            load, out = carry
            v = jnp.argmin(jnp.where(cand[i], load, jnp.float32(_BIG))
                           ).astype(jnp.int32)
            add = jnp.where(task_valid[i],
                            task_len[i] / (vm_mips[v] * vm_pes_f[v]), 0.0)
            return (load + jnp.where(vm_iota == v, add, 0.0),
                    out.at[i].set(v))

        _, loc = jax.lax.fori_loop(0, T, loc_step,
                                   (load0, jnp.zeros(T, jnp.int32)))

    vm = jnp.select([bp == BindingPolicy.ROUND_ROBIN,
                     bp == BindingPolicy.LEAST_LOADED,
                     bp == BindingPolicy.PACKED], [rr, ll, packed], loc)
    return jnp.where(task_valid, vm, 0).astype(jnp.int32)


def from_scenario(sc: Scenario, *, pad_tasks: int | None = None,
                  pad_jobs: int | None = None,
                  pad_vms: int | None = None) -> ScenarioArrays:
    """Encode one :class:`Scenario` into padded arrays (numpy, host-side)."""
    T = pad_tasks or sc.total_tasks()
    J = pad_jobs or len(sc.jobs)
    V = pad_vms or len(sc.vms)
    if T < sc.total_tasks() or J < len(sc.jobs) or V < len(sc.vms):
        raise ValueError(
            f"from_scenario: padding too small — need pad_tasks>="
            f"{sc.total_tasks()} (got {T}), pad_jobs>={len(sc.jobs)} "
            f"(got {J}), pad_vms>={len(sc.vms)} (got {V})")

    f32 = np.float32
    t_job = np.zeros(T, np.int32)
    t_red = np.zeros(T, bool)
    t_val = np.zeros(T, bool)
    t_prio = np.zeros(T, f32)
    # Binding-load base lengths via the one shared f32 op sequence
    # (config.base_task_lengths_f32) so every layer resolves LEAST_LOADED
    # argmin ties identically.
    t_len = np.zeros(T, f32)
    t_dl = np.full(T, _BIG, f32)
    k = 0
    for ji, job in enumerate(sc.jobs):
        map_l, red_l = base_task_lengths_f32(
            f32(job.length_mi), f32(job.n_maps), f32(job.n_reduces),
            f32(job.reduce_factor))
        for phase, n in ((False, job.n_maps), (True, job.n_reduces)):
            for _ in range(n):
                t_job[k], t_red[k], t_val[k] = ji, phase, True
                t_len[k] = red_l if phase else map_l
                t_prio[k] = job.priority
                t_dl[k] = f32(min(job.deadline, _BIG))
                k += 1

    vm_mips = _padf([v.mips for v in sc.vms], V, fill=1.0)
    vm_pes = _padf([v.pes for v in sc.vms], V, fill=1.0)
    vm_valid = np.arange(V) < len(sc.vms)

    # Storage model (DESIGN.md §7): realized block placement, host-side.
    # Disabled -> all-(-1)/0 arrays, and every policy binds exactly as
    # before (the candidate mask degenerates to vm_valid).
    block_vm = np.full((T, V), -1, np.int32)
    block_mb = np.zeros(T, f32)
    bvm, bmb = storage.scenario_placement(sc, V)
    block_vm[:len(bvm)] = bvm
    block_mb[:len(bmb)] = bmb

    # Closed-loop control (DESIGN.md §10): realized failure/restore
    # streams + reserve flags via the one shared helper the oracle uses.
    vm_fail, vm_restore, vm_auto = scenario_control(sc, V)

    if sc.binding_policy in (BindingPolicy.LEAST_LOADED,
                             BindingPolicy.LOCALITY):
        # f32-sensitive: go through the one shared jnp implementation
        cand = (storage.locality_candidates(np, block_vm, vm_valid)
                if sc.binding_policy == BindingPolicy.LOCALITY else None)
        t_vm = np.asarray(bind_tasks(int(sc.binding_policy), t_val, t_len,
                                     vm_mips, vm_pes, vm_valid,
                                     locality_cand=cand), np.int32)
    else:
        # integer-exact fast paths — skip a JAX dispatch (+ per-padding
        # compile) per encoded scenario on the host path; equality with
        # bind_tasks is pinned by the encode_cell round-trip test
        counter = np.cumsum(t_val) - t_val      # submission-order index
        if sc.binding_policy == BindingPolicy.PACKED:
            slots = np.repeat(np.arange(len(sc.vms)),
                              [int(v.pes) for v in sc.vms])
            t_vm = slots[counter % len(slots)]
        else:                                   # ROUND_ROBIN
            t_vm = counter % len(sc.vms)
        t_vm = np.where(t_val, t_vm, 0).astype(np.int32)
    return ScenarioArrays(
        task_job=t_job, task_is_reduce=t_red, task_vm=t_vm, task_valid=t_val,
        task_mult=np.ones(T, f32),
        job_length=_padf([j.length_mi for j in sc.jobs], J),
        job_data=_padf([j.data_mb for j in sc.jobs], J),
        job_n_maps=_padi([j.n_maps for j in sc.jobs], J),
        job_n_reduces=_padi([j.n_reduces for j in sc.jobs], J),
        job_submit=_padf([j.submit_time for j in sc.jobs], J),
        job_reduce_factor=_padf([j.reduce_factor for j in sc.jobs], J),
        job_valid=np.arange(J) < len(sc.jobs),
        vm_mips=vm_mips,
        vm_pes=vm_pes,
        vm_cost=_padf([v.cost_per_sec for v in sc.vms], V),
        vm_valid=vm_valid,
        net_enabled=f32(1.0 if sc.network.enabled else 0.0),
        net_bw=f32(sc.network.bw_mbps),
        kappa_in=f32(sc.network.kappa_in),
        kappa_shuffle=f32(sc.network.kappa_shuffle),
        net_cost_per_unit=f32(sc.network.cost_per_unit),
        sched_policy=np.int32(sc.sched_policy),
        binding_policy=np.int32(sc.binding_policy),
        block_vm=block_vm,
        block_size=block_mb,
        storage_enabled=f32(1.0 if sc.storage.enabled else 0.0),
        vm_start=_padf([v.lease_start for v in sc.vms], V),
        vm_stop=_padf([elasticity.encode_lease_stop(v.lease_stop)
                       for v in sc.vms], V, fill=_BIG),
        spinup_delay=f32(sc.elasticity.spinup_delay),
        bill_gran=f32(sc.elasticity.billing_granularity),
        task_prio=t_prio,
        vm_fail=vm_fail,
        vm_restore=vm_restore,
        vm_auto=vm_auto,
        control_policy=np.int32(sc.control.policy),
        ctl_queue=f32(sc.control.queue_threshold),
        ctl_busy=f32(sc.control.busy_threshold),
        redispatch_delay=f32(sc.control.redispatch_delay),
        task_deadline=t_dl,
        deadline_policy=np.int32(sc.control.deadline_policy),
        deadline_slack=f32(sc.control.deadline_slack),
        preempt=np.int32(bool(sc.control.preempt)),
        preempt_resume=np.int32(bool(sc.control.preempt_resume)),
    )


def _padf(xs, n, fill=0.0):
    out = np.full(n, fill, np.float32)
    out[:len(xs)] = xs
    return out


def _padi(xs, n):
    out = np.ones(n, np.int32)
    out[:len(xs)] = xs
    return out


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------

class _Carry(NamedTuple):
    """Per-scenario event-loop state advanced one epoch at a time.

    The trailing control leaves are ``None`` (empty pytree — zero cost)
    whenever the static ``control`` flag is off; the open-loop carry is
    unchanged byte for byte.
    """
    time: jax.Array
    rem: jax.Array        # f32[T] remaining MI
    running: jax.Array    # bool[T]
    start: jax.Array      # f32[T]
    finish: jax.Array     # f32[T]
    ready: jax.Array      # f32[T]
    maps_left: jax.Array  # i32[J]
    epoch: jax.Array      # i32 — realized event epochs for *this* lane
    hit: jax.Array | None = None       # bool[T] killed at least once
    vm_open: jax.Array | None = None   # f32[V] realized lease open
    vm_close: jax.Array | None = None  # f32[V] realized lease close
    n_scale: jax.Array | None = None   # i32 autoscale events so far
    shed: jax.Array | None = None      # bool[T] deadline-shed so far
    n_evict: jax.Array | None = None   # i32[T] preemptions per task
    work_lost: jax.Array | None = None  # f32 discarded progress (MI)
    # trace recorder leaves (DESIGN.md §12): ``None`` unless the static
    # ``trace`` flag is on — an observe-only layer, never read by any
    # dynamics op, so traced schedules stay bitwise-identical
    ts: jax.Array | None = None        # f32[C, 8] per-epoch time series
    ev_t: jax.Array | None = None      # f32[E] event timestamps
    ev_kind: jax.Array | None = None   # i32[E] event kinds (-1 empty)
    ev_task: jax.Array | None = None   # i32[E] task id (-1 scale events)
    ev_vm: jax.Array | None = None     # i32[E] VM id
    ev_n: jax.Array | None = None      # i32 events attempted (cursor)


class _EpochInv(NamedTuple):
    """Loop-invariant derived arrays shared by every epoch of one lane.

    Control leaves (``None`` unless the static ``control`` flag is on):
    the failover binding slot and its derived gathers, plus the per-task
    failure/restore instants of both binding slots.
    """
    shuffle: jax.Array     # f32[J]
    task_pes: jax.Array    # f32[T]
    vm_onehot: jax.Array   # f32[T, V]
    job_onehot: jax.Array  # f32[T, J]
    same_vm: jax.Array     # bool[T, T]
    idx_earlier: jax.Array  # bool[T, T]
    is_space: jax.Array    # bool scalar
    avail_t: jax.Array     # f32[T] bound VM's admission-open time
    #                        (lease start + spinup; 0 for a static fleet)
    close_t: jax.Array     # f32[T] bound VM's lease stop (_BIG = never)
    task_len: jax.Array | None = None    # f32[T] full length (kill reset)
    task_vm2: jax.Array | None = None    # i32[T] failover binding
    vm_onehot2: jax.Array | None = None  # f32[T, V]
    task_pes2: jax.Array | None = None   # f32[T]
    refetch: jax.Array | None = None     # f32[T] re-replication fetch to
    #                                      the failover VM (0 if it holds
    #                                      a replica / no block)
    fail1: jax.Array | None = None       # f32[T] vm_fail[task_vm]
    rest1: jax.Array | None = None       # f32[T] vm_restore[task_vm]
    fail2: jax.Array | None = None       # f32[T] vm_fail[task_vm2]
    rest2: jax.Array | None = None       # f32[T] vm_restore[task_vm2]


def _epoch_setup(sc: ScenarioArrays, *, control: bool = False,
                 trace: tuple[int, int] | None = None
                 ) -> tuple[_EpochInv, _Carry]:
    """Derived quantities + initial carry for one encoded scenario.

    ``trace`` is the static ``(timeseries_rows, event_rows)`` capacity
    pair (DESIGN.md §12) — ``None`` keeps the trace leaves empty pytrees.
    """
    T = sc.task_job.shape[0]
    J = sc.job_length.shape[0]
    V = sc.vm_mips.shape[0]

    # --- derived per-task/per-job quantities (traced: sweepable) ----------
    n_maps_f = sc.job_n_maps.astype(jnp.float32)
    stage_in = network.transfer_delay(sc.kappa_in, sc.job_data, n_maps_f,
                                      sc.net_bw, sc.net_enabled)
    shuffle = network.transfer_delay(sc.kappa_shuffle, sc.job_data, n_maps_f,
                                     sc.net_bw, sc.net_enabled)
    task_len = task_lengths(sc)

    # Maps ready at submit + stage-in (+ the storage remote-fetch delay
    # when the bound VM holds no replica of the task's input block —
    # exactly 0.0 for local tasks and storage-less scenarios, so the
    # pre-storage op sequence is reproduced bit for bit); reduces unknown
    # until maps complete.
    fetch = storage.remote_fetch_delay(sc.block_vm, sc.block_size,
                                       sc.task_vm, sc.kappa_in, sc.net_bw,
                                       sc.net_enabled, xp=jnp)
    ready0 = jnp.where(
        sc.task_valid & ~sc.task_is_reduce,
        (sc.job_submit + stage_in)[sc.task_job] + fetch, _BIG)

    is_map = sc.task_valid & ~sc.task_is_reduce
    maps_left0 = jax.ops.segment_sum(is_map.astype(jnp.int32), sc.task_job,
                                     num_segments=J)

    is_space = sc.sched_policy == SchedPolicy.SPACE_SHARED
    task_pes = sc.vm_pes[sc.task_vm]
    # One-hot encodings of the task->VM / task->job maps, hoisted out of the
    # loop: per-epoch reductions become small dense matmuls instead of
    # scatters (segment_sum), which XLA:CPU serializes — this halves the
    # sweep benchmark's time per call.  The sums are exact (0/1 operands),
    # so results are bit-identical to the scatter formulation.
    vm_onehot = (sc.task_vm[:, None] == jnp.arange(V)[None, :]
                 ).astype(jnp.float32)
    job_onehot = (sc.task_job[:, None] == jnp.arange(J)[None, :]
                  ).astype(jnp.float32)
    # Loop-invariant pieces of the space-shared admission priority.
    idx = jnp.arange(T)
    same_vm = sc.task_vm[:, None] == sc.task_vm[None, :]
    idx_earlier = idx[None, :] < idx[:, None]

    # Lease windows as per-task gathers (DESIGN.md §8): admission on VM v
    # opens at vm_start[v] + spinup and closes at vm_stop[v].  For the
    # static fleet (start 0, stop _BIG) every use below is a bitwise
    # identity: max(ready, 0) == ready for the non-negative ready times and
    # the close comparison is always true for live events.
    avail_t = (sc.vm_start + sc.spinup_delay)[sc.task_vm]
    close_t = sc.vm_stop[sc.task_vm]

    inv = _EpochInv(shuffle=shuffle, task_pes=task_pes, vm_onehot=vm_onehot,
                    job_onehot=job_onehot, same_vm=same_vm,
                    idx_earlier=idx_earlier, is_space=is_space,
                    avail_t=avail_t, close_t=close_t)
    c0 = _Carry(time=jnp.float32(0.0), rem=task_len,
                running=jnp.zeros(T, bool),
                start=jnp.full(T, _BIG, jnp.float32),
                finish=jnp.full(T, _BIG, jnp.float32),
                ready=ready0, maps_left=maps_left0,
                epoch=jnp.int32(0))
    if control:
        # Failover binding slot (DESIGN.md §10): a killed task's second —
        # and final — VM, precomputed so the epoch body stays a fixed
        # dataflow: the only dynamic binding state is the bool ``hit``
        # switch between the two slots.  Re-replication rides the PR-4
        # block store: moving off the replica set pays the shared
        # remote-fetch delay toward the new VM.
        task_vm2 = failover_targets(sc.task_vm, sc.vm_valid, sc.vm_auto,
                                    sc.block_vm, xp=jnp)
        refetch = storage.remote_fetch_delay(sc.block_vm, sc.block_size,
                                             task_vm2, sc.kappa_in,
                                             sc.net_bw, sc.net_enabled,
                                             xp=jnp)
        inv = inv._replace(
            task_len=task_len,
            task_vm2=task_vm2,
            vm_onehot2=(task_vm2[:, None] == jnp.arange(V)[None, :]
                        ).astype(jnp.float32),
            task_pes2=sc.vm_pes[task_vm2],
            refetch=refetch,
            fail1=sc.vm_fail[sc.task_vm], rest1=sc.vm_restore[sc.task_vm],
            fail2=sc.vm_fail[task_vm2], rest2=sc.vm_restore[task_vm2])
        # Reserve VMs have no lease until the control rule opens one; the
        # non-reserve fleet's realized open is just its encoded start.
        c0 = c0._replace(
            hit=jnp.zeros(T, bool),
            vm_open=jnp.where(sc.vm_auto, jnp.float32(_BIG), sc.vm_start),
            vm_close=jnp.asarray(sc.vm_stop, jnp.float32),
            n_scale=jnp.int32(0),
            shed=jnp.zeros(T, bool),
            n_evict=jnp.zeros(T, jnp.int32),
            work_lost=jnp.float32(0.0))
    if trace is not None:
        ts_cap, ev_cap = trace
        c0 = c0._replace(
            ts=jnp.zeros((ts_cap, 8), jnp.float32),
            ev_t=jnp.zeros(ev_cap, jnp.float32),
            ev_kind=jnp.full(ev_cap, -1, jnp.int32),
            ev_task=jnp.full(ev_cap, -1, jnp.int32),
            ev_vm=jnp.full(ev_cap, -1, jnp.int32),
            ev_n=jnp.int32(0))
    return inv, c0


def _has_unfinished(sc: ScenarioArrays, c: _Carry) -> jax.Array:
    unfin = sc.task_valid & (c.finish >= _BIG / 2)
    if c.shed is not None:
        # a shed task never finishes by design — it must not keep its
        # lane alive (shedding *terminates* otherwise-unbounded backlogs)
        unfin &= ~c.shed
    return jnp.any(unfin)


def _lane_bound(sc: ScenarioArrays) -> jax.Array:
    """Per-lane epoch bound (i32, data-dependent under control).

    Open-loop, every live epoch fires a start or a completion: ``2T + 2``.
    Each robustness mechanism widens the bound *additively*, and each
    term is paid only by lanes whose encoded data can trigger it — so
    degenerate lanes keep the exact open-loop bound (and stranded lanes'
    realized ``n_epochs`` stay bit-identical):

    * failures — a task restarts at most twice (its bound VM and its
      failover VM each fail at most once): +``2T`` starts + ``V``
      failure instants;
    * deadline shedding — marking epochs piggyback on live events, but
      ``+T + 1`` margins the tail where the last events only shed;
    * preemption — at most two evictions per task: +``2T`` restarts
      (eviction epochs coincide with the challenger's start)."""
    T = sc.task_job.shape[0]
    V = sc.vm_mips.shape[0]
    any_fail = jnp.any(sc.vm_valid & (sc.vm_fail < _BIG / 2))
    any_shed = (sc.deadline_policy == jnp.int32(DeadlinePolicy.SHED)) \
        & jnp.any(sc.task_valid & (sc.task_deadline < _BIG / 2))
    pre_on = sc.preempt != 0
    return (jnp.int32(2 * T + 2)
            + jnp.where(any_fail, jnp.int32(2 * T + V), jnp.int32(0))
            + jnp.where(any_shed, jnp.int32(T + 1), jnp.int32(0))
            + jnp.where(pre_on, jnp.int32(2 * T), jnp.int32(0)))


def _lane_active(sc: ScenarioArrays, c: _Carry, *,
                 control: bool = False) -> jax.Array:
    """A lane still takes epochs: unfinished work below its epoch bound.
    Open-loop drivers bound epochs globally (the per-lane bound is the
    static ``2T + 2``), so the extra term is control-only."""
    act = _has_unfinished(sc, c)
    if control:
        act &= c.epoch < _lane_bound(sc)
    return act


def _epoch_step(sc: ScenarioArrays, inv: _EpochInv, c: _Carry, *,
                control: bool = False, trace: bool = False) -> _Carry:
    """Advance one event epoch.  Idempotent for finished lanes (every
    update is gated on ``live``/``running``), so a vmapped batch may keep
    stepping a lane past its last event without changing its state — the
    property the batched early-exit driver relies on.  Leaves ``epoch``
    untouched; the drivers count realized epochs.

    ``control=True`` (a static flag — open-loop lowerings carry zero
    control code) threads the closed loop through the same dataflow:

    * the *control hook* runs first, at the epoch's opening clock
      ``c.time`` (i.e. observing the state all previous events left
      behind): AUTOSCALE compares the observed queue depth and open-fleet
      busy fraction against the encoded thresholds, opens one reserve VM
      per epoch while both exceed, and closes idle opened reserves;
    * every per-task gather switches between the two binding slots on the
      ``hit`` mask (one-hot matmuls stay exact 0/1 sums);
    * failure instants of valid VMs join the next-event min; at a firing
      instant every unfinished task on the failing VM is killed and
      re-dispatched (first hit: to the failover slot + re-replication
      fetch; second: restart in place after restore), and eligibility is
      gated around each VM's ``[fail, restore)`` down window.

    With degenerate control data (no failures, no reserves, NONE policy)
    every control op is a ``where`` over an all-false mask or a gate that
    never matches — the open-loop schedule is reproduced bit for bit
    (pinned in tests/test_control.py)."""
    # --- binding-slot switch + control hook (clock = c.time) --------------
    if control:
        cur_oh = jnp.where(c.hit[:, None], inv.vm_onehot2, inv.vm_onehot)
        task_pes = jnp.where(c.hit, inv.task_pes2, inv.task_pes)
        f_t = jnp.where(c.hit, inv.fail2, inv.fail1)
        r_t = jnp.where(c.hit, inv.rest2, inv.rest1)
        cur_vm = jnp.where(c.hit, inv.task_vm2, sc.task_vm)
        same_vm = cur_vm[:, None] == cur_vm[None, :]

        V = sc.vm_mips.shape[0]
        pol_on = sc.control_policy == jnp.int32(ControlPolicy.AUTOSCALE)
        # shed tasks are out of the system: refused backlog neither holds
        # a reserve open nor counts toward scaling pressure (all-true
        # ~shed under NONE — bitwise identity with the §10 hook)
        unfinished = sc.task_valid & (c.finish >= _BIG / 2) & ~c.shed
        # queue depth over *raw* ready times: tasks bound to unopened
        # reserves must count toward the backlog or the rule that would
        # open their VM could never trigger
        qdepth = jnp.sum((unfinished & (c.start >= _BIG / 2)
                          & (c.ready <= c.time)).astype(jnp.float32))
        busy_v = (c.running.astype(jnp.float32) @ cur_oh) > 0.5
        open_v = sc.vm_valid & (c.vm_open + sc.spinup_delay <= c.time) \
            & (c.time < c.vm_close)
        n_open = jnp.sum(open_v.astype(jnp.float32))
        busy_frac = (jnp.sum((open_v & busy_v).astype(jnp.float32))
                     / jnp.maximum(n_open, 1.0))
        trigger = pol_on & (qdepth > sc.ctl_queue) \
            & (busy_frac >= sc.ctl_busy)
        reserve = sc.vm_valid & sc.vm_auto
        unopened = reserve & (c.vm_open >= _BIG / 2)
        vidx = jnp.arange(V, dtype=jnp.int32)
        first = jnp.argmin(jnp.where(unopened, vidx, jnp.int32(V + 1)))
        open_mask = trigger & unopened & (vidx == first)
        bound_unfin = unfinished.astype(jnp.float32) @ cur_oh
        close_mask = pol_on & reserve & (c.vm_open < _BIG / 2) \
            & (c.time < c.vm_close) & (bound_unfin < 0.5)
        vm_open = jnp.where(open_mask, c.time, c.vm_open)
        vm_close = jnp.where(close_mask, c.time, c.vm_close)
        n_scale = c.n_scale + jnp.sum(open_mask.astype(jnp.int32)) \
            + jnp.sum(close_mask.astype(jnp.int32))
        # lease windows re-derived from carry: exactly the setup gathers
        # when no reserve ever opens (one-hot sums are exact)
        avail_t = cur_oh @ (vm_open + sc.spinup_delay)
        close_t = cur_oh @ vm_close
        # graceful-degradation policy masks (DESIGN.md §11) — i32/bool
        # *data*, so one lowering serves batches mixing NONE/SHED/BOOST
        # lanes; every op they gate is a bitwise no-op when all-false
        mips_t = cur_oh @ sc.vm_mips
        dl_shed = sc.deadline_policy == jnp.int32(DeadlinePolicy.SHED)
        dl_boost = sc.deadline_policy == jnp.int32(DeadlinePolicy.BOOST)
        pre_on = (sc.preempt != 0) & inv.is_space
        res_on = sc.preempt_resume != 0
        prio = sc.task_prio
    else:
        cur_oh, task_pes, same_vm = inv.vm_onehot, inv.task_pes, inv.same_vm
        avail_t, close_t = inv.avail_t, inv.close_t

    # single rates evaluation per epoch (space-shared keeps n <= pes, so
    # the min() clamp makes this formula serve both policies)
    def vm_counts(running):
        return running.astype(jnp.float32) @ cur_oh

    n_on_vm = vm_counts(c.running)
    share = sc.vm_mips * jnp.minimum(1.0, sc.vm_pes
                                     / jnp.maximum(n_on_vm, 1.0))
    r = jnp.where(c.running, cur_oh @ share, 0.0)

    eta = jnp.where(c.running, c.time + c.rem / jnp.maximum(r, 1e-30),
                    _BIG)
    not_started = sc.task_valid & ~c.running & (c.finish >= _BIG / 2) \
        & (c.start >= _BIG / 2)
    # Lease-aware eligibility (DESIGN.md §8): a pending task becomes
    # admissible at max(ready, lease avail) — so lease-start edges join
    # the next-event min through the arrival candidates below — and only
    # while its event time lands strictly before the VM's lease stop.  A
    # candidate whose time falls at/past the close never defines an event
    # again (stranded); the static fleet reproduces the old ops bitwise.
    elig = jnp.maximum(c.ready, avail_t)
    if control:
        # failure-window gating: any admission instant landing inside the
        # current VM's [fail, restore) down window slides to the restore
        # edge — which is how restore instants join the event min (no
        # separate restore event stream is needed)
        def gate(x):
            return jnp.where((x >= f_t) & (x < r_t), r_t, x)

        elig = gate(elig)
        cand_t = gate(jnp.maximum(elig, c.time))
        # SHED admission control at the arrival-candidate instant
        # (DESIGN.md §11): a pending task whose earliest possible finish
        # already exceeds its deadline stops defining arrival events.
        # The close_t gate keeps stranded tasks out — the oracle never
        # re-examines an arrival it could not schedule.
        evaluable = not_started & (elig < _BIG / 2)
        efin_c = earliest_finish(cand_t, c.rem, mips_t, xp=jnp)
        shed_c = c.shed | (dl_shed & evaluable & (cand_t < close_t)
                           & (efin_c > sc.task_deadline))
    else:
        cand_t = jnp.maximum(elig, c.time)
    # Space-shared: a pending task only defines an arrival event while
    # its VM has a free PE slot; otherwise a completion epoch admits it.
    has_slot = (task_pes - cur_oh @ n_on_vm) > 0.5
    if control:
        # preemption arrival gate (DESIGN.md §11): a pending task whose
        # raw priority strictly beats a running, still-evictable task on
        # its VM defines an arrival event even with no free slot — the
        # eviction below frees one at that instant.  Raw priority only
        # (not the BOOST urgency tier): the gate and the eviction rule
        # must agree or a same-instant arrival event could repeat with
        # no state change.
        evictable = c.running & (c.n_evict < jnp.int32(2))
        prey = same_vm & evictable[None, :] \
            & (prio[:, None] > prio[None, :])
        can_pre = pre_on & jnp.any(prey, axis=1)
        arr = jnp.where(not_started & ~shed_c
                        & (~inv.is_space | has_slot | can_pre)
                        & (cand_t < close_t), cand_t, _BIG)
    else:
        arr = jnp.where(not_started & (~inv.is_space | has_slot)
                        & (cand_t < close_t), cand_t, _BIG)
    t_next = jnp.minimum(jnp.min(eta), jnp.min(arr))
    if control:
        # pending failure instants of valid VMs are calendar events too
        fail_ev = jnp.where(sc.vm_valid & (sc.vm_fail > c.time),
                            sc.vm_fail, _BIG)
        t_next = jnp.minimum(t_next, jnp.min(fail_ev))
    live = t_next < _BIG / 2
    tie = _TIME_EPS * jnp.maximum(t_next, 1.0)

    # advance fluid state
    rem = jnp.where(c.running, c.rem - (t_next - c.time) * r, c.rem)

    # completions (all tied events fire in this one epoch)
    done_now = live & c.running & (eta <= t_next + tie)
    finish = jnp.where(done_now, t_next, c.finish)
    running = c.running & ~done_now
    rem = jnp.where(done_now, 0.0, rem)

    # job map-phase completion -> release reduces after shuffle delay
    maps_done_now = ((done_now & ~sc.task_is_reduce)
                     .astype(jnp.float32) @ inv.job_onehot).astype(jnp.int32)
    maps_left = c.maps_left - maps_done_now
    phase_done = (maps_left == 0) & (c.maps_left > 0)
    red_ready = jnp.where(phase_done, t_next + inv.shuffle, _BIG)
    ready = jnp.where(
        sc.task_is_reduce & phase_done[sc.task_job],
        red_ready[sc.task_job], c.ready)

    # failure kills — after completions (a task finishing exactly at the
    # failure instant completes: the oracle's completions-first tie
    # order), before admissions
    start_base = c.start
    if control:
        fired = live & (f_t > c.time) & (f_t <= t_next)
        # shed tasks are out of the system — a failure must not
        # re-dispatch (or failover-rebind) work that was already refused
        affected = sc.task_valid & fired & (finish >= _BIG / 2) & ~shed_c
        first_hit = affected & ~c.hit
        lost_fail = jnp.where(affected, inv.task_len - rem, 0.0)
        rem = jnp.where(affected, inv.task_len, rem)
        running = running & ~affected
        start_base = jnp.where(affected, jnp.float32(_BIG), start_base)
        # re-dispatch: detection/re-queue latency from the failure
        # instant; the first hit moves to the failover slot and pays the
        # re-replication fetch, a second hit restarts in place (its
        # eligibility then slides to the failover VM's restore edge)
        ready = jnp.where(affected,
                          jnp.maximum(ready, f_t + sc.redispatch_delay),
                          ready)
        ready = jnp.where(first_hit, ready + inv.refetch, ready)
        hit = c.hit | first_hit

    # arrivals: time-shared starts every admissible task immediately;
    # space-shared admits the (priority desc, eligible time, index)-first
    # waiting tasks into the PE slots left free after this epoch's
    # completions.  The admission key is the *eligible* time (ready
    # joined with the lease-open edge) and the whole rank is gated on the
    # lease still being open at t_next; all-zero priorities and a static
    # fleet reduce every term to the classic (ready, index) rank bitwise.
    eligible = live & not_started & (elig <= t_next + tie) \
        & (t_next < close_t)
    key = elig
    prio = sc.task_prio
    if control:
        # never admit onto a VM that is down at (or fails exactly at)
        # this epoch's instant — the killed set was computed above and a
        # same-instant admission would dodge it
        eligible &= ~((t_next >= f_t) & (t_next < r_t))
        # SHED at the admission instant (the oracle's pop-time check):
        # queue wait grows pressure, so a task admissible when it
        # arrived may be unmeetable by the time a PE slot frees
        efin_t = earliest_finish(t_next, c.rem, mips_t, xp=jnp)
        shed_t = shed_c | (dl_shed & evaluable & (t_next < close_t)
                           & (efin_t > sc.task_deadline))
        eligible &= ~shed_t
        # Priority preemption (DESIGN.md §11): on each full space-shared
        # VM, the single weakest still-evictable running task (lowest
        # raw priority, latest index) loses its PE when an eligible
        # pending task strictly outranks it; further victims fall in the
        # repeated same-instant epochs the arrival gate above keeps
        # scheduling.  The kill reuses the §10 failure op sequence:
        # progress reset (kept under preempt_resume), re-dispatch
        # latency on readiness, first hit moves to the failover slot and
        # pays the re-replication fetch.
        vic_cand = pre_on & running & (c.n_evict < jnp.int32(2))
        full = (task_pes - cur_oh @ (n_on_vm - vm_counts(done_now))) \
            <= 0.5
        beats = same_vm & vic_cand[:, None] & eligible[None, :] \
            & (prio[None, :] > prio[:, None])
        cand_e = vic_cand & full & jnp.any(beats, axis=1)
        weaker = same_vm & cand_e[None, :] & (
            (prio[None, :] < prio[:, None])
            | ((prio[None, :] == prio[:, None]) & inv.idx_earlier.T))
        evicted = cand_e & ~jnp.any(weaker, axis=1)
        lost_evict = jnp.where(evicted & ~res_on,
                               inv.task_len - rem, 0.0)
        e_first = evicted & ~hit
        rem = jnp.where(evicted & ~res_on, inv.task_len, rem)
        running = running & ~evicted
        start_base = jnp.where(evicted, jnp.float32(_BIG), start_base)
        ready = jnp.where(evicted,
                          jnp.maximum(ready,
                                      t_next + sc.redispatch_delay),
                          ready)
        ready = jnp.where(e_first, ready + inv.refetch, ready)
        hit = hit | e_first
        n_evict = c.n_evict + evicted.astype(jnp.int32)
        work_lost = c.work_lost + jnp.sum(lost_fail) \
            + jnp.sum(lost_evict)
        free_after = task_pes - cur_oh @ (n_on_vm - vm_counts(done_now)
                                          - vm_counts(evicted))
        # BOOST urgency tier (DESIGN.md §11): a pending task whose
        # earliest finish is within deadline_slack of its deadline
        # outranks every non-urgent task; ties inside a tier keep the §8
        # (priority, eligible, index) key.  All-false urgency (NONE/SHED
        # lanes, _BIG deadlines) collapses to the §8 rank bitwise.
        urg = (dl_boost & evaluable
               & (efin_t + sc.deadline_slack >= sc.task_deadline)
               ).astype(jnp.float32)
        higher_prio = same_vm & (
            (urg[None, :] > urg[:, None])
            | ((urg[None, :] == urg[:, None])
               & ((prio[None, :] > prio[:, None])
                  | ((prio[None, :] == prio[:, None])
                     & ((key[None, :] < key[:, None])
                        | ((key[None, :] == key[:, None])
                           & inv.idx_earlier))))))
    else:
        free_after = task_pes - cur_oh @ (n_on_vm - vm_counts(done_now))
        higher_prio = same_vm & (
            (prio[None, :] > prio[:, None])
            | ((prio[None, :] == prio[:, None])
               & ((key[None, :] < key[:, None])
                  | ((key[None, :] == key[:, None]) & inv.idx_earlier))))
    rank = jnp.sum((higher_prio & eligible[None, :])
                   .astype(jnp.float32), axis=1)
    start_now = eligible & (~inv.is_space | (rank < free_after))
    start = jnp.where(start_now, t_next, start_base)
    running = running | start_now

    time = jnp.where(live, t_next, c.time)
    extra = {}
    if control:
        # persist the shed set; reduces of a job with a shed map can
        # never become ready (the map phase cannot complete) — marking
        # these orphans ends their lane instead of spinning it to the
        # epoch bound
        map_shed = (shed_t & ~sc.task_is_reduce).astype(jnp.float32)
        job_dead = (map_shed @ inv.job_onehot) > 0.5
        shed = shed_t | (sc.task_valid & sc.task_is_reduce
                         & job_dead[sc.task_job]
                         & (finish >= _BIG / 2) & ~running)
        extra = dict(hit=hit, vm_open=vm_open, vm_close=vm_close,
                     n_scale=n_scale, shed=shed, n_evict=n_evict,
                     work_lost=work_lost)
    if trace:
        # --- trace recorder (DESIGN.md §12): observe, never act -----------
        # Gated on the same per-lane activity predicate the drivers count
        # epochs with, so traces from the per-lane while_loop, the batched
        # driver (which keeps stepping inactive lanes) and the compacted
        # driver are bitwise-identical.
        act = _lane_active(sc, c, control=control)
        actf = act.astype(jnp.float32)
        T = sc.task_job.shape[0]
        if control:
            new_shed = shed & ~c.shed
            n_fail = jnp.sum(affected.astype(jnp.float32))
            n_shed = jnp.sum(new_shed.astype(jnp.float32))
            n_ev = jnp.sum(evicted.astype(jnp.float32))
        else:
            # open-loop lanes compute the control hook's observables here,
            # with the identical op sequence over the static lease windows
            unfin_t = sc.task_valid & (c.finish >= _BIG / 2)
            qdepth = jnp.sum((unfin_t & (c.start >= _BIG / 2)
                              & (c.ready <= c.time)).astype(jnp.float32))
            busy_v = (c.running.astype(jnp.float32) @ cur_oh) > 0.5
            open_v = sc.vm_valid \
                & (sc.vm_start + sc.spinup_delay <= c.time) \
                & (c.time < sc.vm_stop)
            n_open = jnp.sum(open_v.astype(jnp.float32))
            busy_frac = (jnp.sum((open_v & busy_v).astype(jnp.float32))
                         / jnp.maximum(n_open, 1.0))
            n_fail = n_shed = n_ev = jnp.float32(0.0)
        # One time-series row per realized epoch, set by a one-hot add:
        # the row index is this lane's own epoch counter, which advances
        # exactly when ``act`` holds, so each row is written once (an
        # index past capacity would write nothing — the capacity equals
        # the lane's epoch bound, so it never overflows).
        row = (jnp.arange(c.ts.shape[0]) == c.epoch
               ).astype(jnp.float32) * actf
        vals = jnp.stack([time, qdepth, busy_frac, n_open, actf,
                          n_fail, n_shed, n_ev])
        ts = c.ts + row[:, None] * vals[None, :]
        # Bounded event log: every event firing this epoch, in canonical
        # in-epoch order (scale decisions at the opening clock, then
        # completions / kills / evictions / starts / sheds), written by
        # one-hot scatter at cursor positions.  Events past capacity fall
        # off the one-hot and are counted by the cursor (dropped_events).
        tvec = jnp.full(T, t_next, jnp.float32)
        tidx = jnp.arange(T, dtype=jnp.int32)

        def kvec(kind, n):
            return jnp.full(n, kind, jnp.int32)

        if control:
            V = sc.vm_mips.shape[0]
            vvec = jnp.arange(V, dtype=jnp.int32)
            novm = jnp.full(V, -1, jnp.int32)
            scale_t = jnp.full(V, c.time, jnp.float32)
            cur_vm_i = cur_vm.astype(jnp.int32)
            m = jnp.concatenate([open_mask, close_mask, done_now, affected,
                                 evicted, start_now, new_shed])
            # kills stamp the failure instant; sheds the epoch's clock
            # (their detection is epoch-quantized — see DESIGN.md §12.3)
            e_t = jnp.concatenate([scale_t, scale_t, tvec, f_t, tvec, tvec,
                                   jnp.full(T, time, jnp.float32)])
            e_kind = jnp.concatenate([kvec(EV_SCALE_OPEN, V),
                                      kvec(EV_SCALE_CLOSE, V),
                                      kvec(EV_FINISH, T), kvec(EV_KILL, T),
                                      kvec(EV_PREEMPT, T),
                                      kvec(EV_START, T), kvec(EV_SHED, T)])
            e_task = jnp.concatenate([novm, novm, tidx, tidx, tidx, tidx,
                                      tidx])
            e_vm = jnp.concatenate([vvec, vvec, cur_vm_i, cur_vm_i,
                                    cur_vm_i, cur_vm_i, cur_vm_i])
        else:
            m = jnp.concatenate([done_now, start_now])
            e_t = jnp.concatenate([tvec, tvec])
            e_kind = jnp.concatenate([kvec(EV_FINISH, T), kvec(EV_START, T)])
            e_task = jnp.concatenate([tidx, tidx])
            e_vm = jnp.concatenate([sc.task_vm, sc.task_vm]
                                   ).astype(jnp.int32)
        m = m & act
        mf = m.astype(jnp.float32)
        E = c.ev_t.shape[0]
        pos = c.ev_n + (jnp.cumsum(mf) - mf).astype(jnp.int32)
        slot = (pos[:, None] == jnp.arange(E, dtype=jnp.int32)[None, :]) \
            & m[:, None]
        written = jnp.any(slot, axis=0)

        def pick_f(v):
            return jnp.sum(jnp.where(slot, v[:, None], 0.0), axis=0)

        def pick_i(v):
            return jnp.sum(jnp.where(slot, v[:, None], 0), axis=0)

        extra.update(
            ts=ts,
            ev_t=jnp.where(written, pick_f(e_t), c.ev_t),
            ev_kind=jnp.where(written, pick_i(e_kind), c.ev_kind),
            ev_task=jnp.where(written, pick_i(e_task), c.ev_task),
            ev_vm=jnp.where(written, pick_i(e_vm), c.ev_vm),
            ev_n=c.ev_n + jnp.sum(mf).astype(jnp.int32))
    return _Carry(time, rem, running, start, finish, ready,
                  maps_left, c.epoch, **extra)


def _sim_output(sc: ScenarioArrays, cf: _Carry) -> SimOutput:
    exec_time = jnp.where(sc.task_valid, cf.finish - cf.start, 0.0)
    # both lowerings report the failover binding control *would* use, so
    # the field is bitwise-comparable across open-loop and control runs
    task_vm2 = failover_targets(sc.task_vm, sc.vm_valid, sc.vm_auto,
                                sc.block_vm, xp=jnp)
    if cf.hit is None:
        # open-loop: the realized control outputs are the encoded scenario
        hit = jnp.zeros_like(sc.task_valid)
        vm_open = jnp.asarray(sc.vm_start, jnp.float32)
        vm_close = jnp.asarray(sc.vm_stop, jnp.float32)
        n_scale = jnp.int32(0)
        shed = jnp.zeros_like(sc.task_valid)
        n_evict = jnp.zeros(sc.task_valid.shape[0], jnp.int32)
        work_lost = jnp.float32(0.0)
    else:
        hit, vm_open, vm_close = cf.hit, cf.vm_open, cf.vm_close
        n_scale = cf.n_scale
        shed, n_evict, work_lost = cf.shed, cf.n_evict, cf.work_lost
    # shed tasks never finish (finish == _BIG): the makespan is over the
    # work the system kept — all-false ~shed is the pre-§11 op sequence
    return SimOutput(start=cf.start, finish=cf.finish, ready=cf.ready,
                     exec_time=exec_time, n_epochs=cf.epoch,
                     finish_time=jnp.max(jnp.where(sc.task_valid & ~shed,
                                                   cf.finish, 0.0)),
                     hit=hit, task_vm2=task_vm2, vm_open=vm_open,
                     vm_close=vm_close, n_scale=n_scale,
                     shed=shed, n_evict=n_evict, work_lost=work_lost)


def _control_active(sc: ScenarioArrays) -> bool:
    """Host-side detection of control inputs in an encoded scenario (or
    stacked batch).  Under a trace the data is unreadable — report active
    (the control path with degenerate data is a bitwise identity, just
    not free); batch drivers that know better pass ``control=`` instead.
    """
    try:
        vf = np.asarray(sc.vm_fail)
        vv = np.asarray(sc.vm_valid)
        va = np.asarray(sc.vm_auto)
        cp = np.asarray(sc.control_policy)
        dp = np.asarray(sc.deadline_policy)
        pe = np.asarray(sc.preempt)
    except Exception:                     # traced values
        return True
    return bool((vv & (vf < _BIG / 2)).any() or (vv & va).any()
                or (cp != 0).any() or (dp != 0).any() or (pe != 0).any())


def _trace_caps(T: int, V: int, control: bool, trace: bool,
                trace_events: int | None) -> tuple[int, int] | None:
    """Static trace capacities (DESIGN.md §12.2), or ``None`` when off."""
    if not trace:
        return None
    ev = (int(trace_events) if trace_events is not None
          else event_capacity(T, V, control))
    return (timeseries_capacity(T, V, control), ev)


def _trace_of(cf: _Carry) -> TraceBuffers:
    return TraceBuffers(ts=cf.ts, ev_t=cf.ev_t, ev_kind=cf.ev_kind,
                        ev_task=cf.ev_task, ev_vm=cf.ev_vm, ev_n=cf.ev_n)


def simulate_arrays(sc: ScenarioArrays, *, control: bool | None = None,
                    trace: bool = False,
                    trace_events: int | None = None):
    """Run one encoded scenario.  Pure function of arrays: jit/vmap-friendly.

    Both scheduling policies run branch-free inside the one while_loop body:

    * TIME_SHARED — every ready task runs; the fluid share
      ``mips * min(1, pes / n)`` throttles crowded VMs.
    * SPACE_SHARED — the admission gate keeps at most ``pes`` tasks running
      per VM (so the same share formula degenerates to full ``mips``), and
      pending tasks are admitted in (ready time, task index) priority order
      as slots free up.

    Every live epoch fires at least one start or completion (arrival events
    are only scheduled when a PE slot is free), so ``2T + 2`` epochs bound
    the loop (``_lane_bound`` widens this only for lanes that encode VM
    failures); rates are evaluated exactly once per epoch.  Batches should
    prefer :func:`simulate_batch_arrays`, which shares one epoch loop across
    all lanes and stops at the batch's realized epoch count.

    ``trace=True`` (DESIGN.md §12) returns ``(SimOutput, TraceBuffers)``
    — the schedule is bitwise-identical to the untraced run.
    """
    if control is None:
        control = _control_active(sc)
    tr = _trace_caps(sc.task_job.shape[0], sc.vm_mips.shape[0], control,
                     trace, trace_events)
    inv, c0 = _epoch_setup(sc, control=control, trace=tr)
    bound = _lane_bound(sc) if control \
        else jnp.int32(2 * sc.task_job.shape[0] + 2)

    def cond(c: _Carry):
        return _has_unfinished(sc, c) & (c.epoch < bound)

    def body(c: _Carry):
        return _epoch_step(sc, inv, c, control=control,
                           trace=tr is not None
                           )._replace(epoch=c.epoch + 1)

    cf = jax.lax.while_loop(cond, body, c0)
    out = _sim_output(sc, cf)
    if tr is not None:
        return out, _trace_of(cf)
    return out


def simulate_batch_arrays(
        batch: ScenarioArrays, *, control: bool | None = None,
        trace: bool = False, trace_events: int | None = None):
    """Run a stacked batch with one shared epoch loop (batch early exit).

    Instead of vmapping the per-lane ``while_loop`` (whose batching rule
    masks every carry leaf with a per-lane ``select`` each iteration), the
    epoch loop lives *outside* the vmap: an outer ``while_loop`` advances a
    vmapped epoch body while ``any(lane active)``, so the batch stops at its
    own realized epoch count instead of the static ``2T + 2`` worst-case
    bound.  :func:`_epoch_step` is idempotent for finished lanes, so no
    masking is needed and every lane's result is bit-identical to
    ``jax.vmap(simulate_arrays)`` (pinned in the parity suite).

    Returns ``(SimOutput, realized_epochs)`` where ``realized_epochs`` is
    the i32 scalar number of epoch iterations the batch actually executed
    (== the max per-lane ``n_epochs``); ``(SimOutput, realized_epochs,
    TraceBuffers)`` under ``trace=True``.
    """
    if control is None:
        control = _control_active(batch)
    T = batch.task_job.shape[1]
    V = batch.vm_mips.shape[1]
    tr = _trace_caps(T, V, control, trace, trace_events)
    # under control the per-lane bound is data-dependent (_lane_bound,
    # folded into each lane's activity); the global count only needs the
    # static worst case (all additive widenings active at once)
    bound = jnp.int32(7 * T + V + 3 if control else 2 * T + 2)
    inv, c0 = jax.vmap(partial(_epoch_setup, control=control,
                               trace=tr))(batch)

    def lanes_active(c: _Carry) -> jax.Array:
        return jax.vmap(partial(_lane_active, control=control))(batch, c)

    # per-lane activity rides in the carry, so each epoch pays exactly one
    # O(N·T) activity scan (cond and body are separate XLA computations and
    # could not share a recomputed one)
    def cond(state):
        _, active, n = state
        return jnp.any(active) & (n < bound)

    def body(state):
        c, active, n = state
        c2 = jax.vmap(partial(_epoch_step, control=control,
                              trace=tr is not None))(batch, inv, c)
        # per-lane realized epochs: only lanes that still had work count
        # this iteration (matches the per-lane while_loop's count exactly)
        c2 = c2._replace(epoch=c.epoch + active.astype(jnp.int32))
        return c2, lanes_active(c2), n + 1

    cf, _, realized = jax.lax.while_loop(
        cond, body, (c0, lanes_active(c0), jnp.int32(0)))
    out = jax.vmap(_sim_output)(batch, cf)
    if tr is not None:
        return out, realized, _trace_of(cf)
    return out, realized


# ---------------------------------------------------------------------------
# Sparse/compacted epoch stepping (DESIGN.md §9)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("control", "trace"))
def _setup_batch(batch: ScenarioArrays, control: bool = False,
                 trace: tuple[int, int] | None = None):
    return jax.vmap(partial(_epoch_setup, control=control,
                            trace=trace))(batch)


@partial(jax.jit, static_argnames="control")
def _active_batch(batch: ScenarioArrays, c: _Carry, control: bool = False):
    return jax.vmap(partial(_lane_active, control=control))(batch, c)


_output_batch = jax.jit(jax.vmap(_sim_output))


def _step_epoch_chunk_impl(batch: ScenarioArrays, inv: _EpochInv,
                           carry: _Carry, active: jax.Array,
                           remaining: jax.Array, k: int,
                           control: bool = False, trace: bool = False):
    """Advance the batch up to ``k`` epochs (early-exiting on
    ``any(active)`` and the dynamic ``remaining`` budget) — the one
    compiled stepper both the dense-resume and compacted shapes share.

    Returns ``(carry, active, counts, order)`` where ``counts`` is the
    fused ``i32[2] = [epochs_executed, n_still_active]`` pair — the ONLY
    value the dispatch-lean host loop pulls per round — and ``order`` is
    the on-device active-first permutation (``argsort`` of ``~active``;
    jnp argsort is stable, so it reproduces the host-side
    ``concatenate([nonzero(act), nonzero(~act)])`` order bit for bit).
    The host pulls ``order`` only on rounds that actually compact.
    Identical epoch-body ops to :func:`simulate_batch_arrays`, so
    chaining chunks reproduces the single while_loop bit for bit."""
    def cond(state):
        _, act, i = state
        return jnp.any(act) & (i < jnp.minimum(jnp.int32(k), remaining))

    def body(state):
        c, act, i = state
        c2 = jax.vmap(partial(_epoch_step, control=control,
                              trace=trace))(batch, inv, c)
        c2 = c2._replace(epoch=c.epoch + act.astype(jnp.int32))
        return (c2,
                jax.vmap(partial(_lane_active, control=control))(batch, c2),
                i + 1)

    carry, act, i = jax.lax.while_loop(cond, body,
                                       (carry, active, jnp.int32(0)))
    counts = jnp.stack([i, jnp.sum(act, dtype=jnp.int32)])
    return carry, act, counts, jnp.argsort(~act)


_step_epoch_chunk = jax.jit(_step_epoch_chunk_impl,
                            static_argnames=("k", "control", "trace"))
# Donating variant (the train/trainer.py idiom): the carry pytree and
# activity mask buffers are reused in place across rounds instead of
# copied per chunk.  Safe because the host loop never re-reads a carry
# it has stepped past (see _compact_loop_lean's store-merge invariant).
_step_epoch_chunk_donated = jax.jit(_step_epoch_chunk_impl,
                                    static_argnames=("k", "control",
                                                     "trace"),
                                    donate_argnums=(2, 3))


@partial(jax.jit, static_argnames="control")
def _activity_batch(batch: ScenarioArrays, c: _Carry,
                    control: bool = False):
    """Initial-round twin of the stepper's activity reduction: the lane
    mask plus the on-device still-active count and active-first order,
    so round zero also costs one scalar pull, not a ``bool[N]`` mask."""
    act = jax.vmap(partial(_lane_active, control=control))(batch, c)
    return act, jnp.sum(act, dtype=jnp.int32), jnp.argsort(~act)


@jax.jit
def _take_lanes(tree, idx: jax.Array):
    """Gather a lane subset of any stacked pytree (exact: pure indexing)."""
    return jax.tree.map(lambda x: x[idx], tree)


def _put_lanes_impl(store, idx: jax.Array, sub):
    """Scatter a lane subset back into the dense store (distinct indices,
    so the write order cannot matter)."""
    return jax.tree.map(lambda s, x: s.at[idx].set(x), store, sub)


_put_lanes = jax.jit(_put_lanes_impl)
# Donates only the store (arg 0): its output leaves match the input
# shapes exactly so XLA reuses the buffers; ``sub`` is the pad-sized
# working carry whose shapes differ, and donating unusable buffers just
# trips jax's donation warning.
_put_lanes_donated = jax.jit(_put_lanes_impl, donate_argnums=(0,))


def simulate_batch_arrays_compact(
        batch: ScenarioArrays, *, k: int | str = "auto",
        floor: int = 8, cost_model=None, control: bool | None = None,
        trace: bool = False, trace_events: int | None = None,
        stats: dict | None = None, donate: bool = True,
        legacy: bool = False):
    """:func:`simulate_batch_arrays` with sparse active-lane compaction.

    Tail-heavy batches (mixed-policy / elastic grids) realize 20+ epochs
    while most lanes finish within ~5 — yet the dense driver keeps
    stepping every lane through the long tail because the epoch body is
    branch-free.  This host-driven variant checks the per-lane activity
    mask every ``k`` epochs; when the still-active count (pow2-padded,
    ``floor`` minimum) drops below the current working-set size, the
    active lanes are gathered into a compacted batch, the same compiled
    epoch chunk advances only those, and final carries scatter back by
    original lane index.  A b2048 batch whose tail is 40 active lanes
    then steps 64 lanes per epoch, not 2048.

    **Bitwise identical** to the dense driver: the vmapped epoch body is
    a per-lane function (gather/scatter cannot change any lane's
    arithmetic), finished lanes are idempotent under further stepping
    (so freezing them early changes nothing), and stranded lanes stay
    active until the shared ``2T + 2`` bound exactly as the dense loop
    keeps stepping them.  ``realized_epochs`` is preserved too: a global
    epoch executes iff some lane is active, in both drivers.

    ``k="auto"`` derives the interval from the measured cost model
    (``costmodel.default_cost_model().compact_interval`` — balancing the
    per-check dispatch against the work wasted stepping lanes that
    finished mid-chunk).  Host control flow means this entry point is
    NOT jit-able — it *contains* jitted chunks; callers inside jit use
    the dense driver.

    The trace leaves ride the carry through the gather/scatter like any
    other leaf, so traced compacted runs are bitwise-identical to the
    dense driver's.  ``stats`` (a dict, mutated in place) collects host
    telemetry for :class:`~repro.core.telemetry.RunReport`: ``syncs``
    (full mask/permutation device→host pulls — paid only on rounds that
    actually compact), ``scalar_syncs`` (the per-round fused
    ``[n_step, n_active]`` scalar pulls), ``compactions`` (gather
    rounds) and ``dispatches`` (chunk-stepper launches).

    ``donate=True`` routes rounds through the buffer-donating stepper /
    store-scatter jits (carries update in place instead of copying every
    chunk); ``legacy=True`` runs the pre-dispatch-lean host loop — one
    full ``bool[N]`` mask pull per round, host-side ordering, no
    donation — kept as the honest benchmark comparator and the
    reference semantics for the lean loop's tests.
    """
    if control is None:
        control = _control_active(batch)
    N, T = batch.task_job.shape[:2]
    bound = 2 * T + 2
    if control:
        # lanes widen their own epoch bound (_lane_bound, additive per
        # mechanism); the host budget only needs the batch-wide worst
        # case — per-lane counts stay exact through the activity mask
        if bool(np.any(np.asarray(batch.vm_valid)
                       & (np.asarray(batch.vm_fail) < _BIG / 2))):
            bound += 2 * T + batch.vm_mips.shape[1]
        if bool(np.any((np.asarray(batch.deadline_policy)
                        == int(DeadlinePolicy.SHED))
                       & np.any(np.asarray(batch.task_valid)
                                & (np.asarray(batch.task_deadline)
                                   < _BIG / 2), axis=1))):
            bound += T + 1
        if bool(np.any(np.asarray(batch.preempt) != 0)):
            bound += 2 * T
    if k == "auto":
        from . import costmodel as costmodel_mod
        cm = cost_model or costmodel_mod.default_cost_model()
        k = cm.compact_interval(N, T)
    k = int(k)
    if k < 1:
        raise ValueError(f"simulate_batch_arrays_compact: k must be >= 1 "
                         f"or 'auto', got {k}")
    validate_pow2_floor(floor)
    tr = _trace_caps(T, batch.vm_mips.shape[1], control, trace,
                     trace_events)
    if stats is None:
        stats = {}
    stats.setdefault("syncs", 0)
    stats.setdefault("scalar_syncs", 0)
    stats.setdefault("compactions", 0)
    stats.setdefault("dispatches", 0)
    inv, c0 = _setup_batch(batch, control=control, trace=tr)
    loop = _compact_loop_legacy if legacy else _compact_loop_lean
    return loop(batch, inv, c0, bound=bound, k=k, floor=floor,
                control=control, tr=tr, stats=stats, donate=donate)


def _compact_loop_lean(batch: ScenarioArrays, inv, c0, *, bound: int,
                       k: int, floor: int, control: bool, tr, stats: dict,
                       donate: bool):
    """Dispatch-lean host loop (DESIGN.md §13): one fused scalar pull per
    round; the full active-first permutation crosses the host boundary
    only on rounds that actually compact; carries are donated in place.

    Store-merge invariant (what makes donation safe): ``carry_store`` is
    ``None`` until the first compaction — before that, ``cur_carry`` IS
    the full batch in original lane order, so there is no N-sized copy
    aliasing ``c0`` for the donating stepper to invalidate.  Afterwards
    the store holds exactly the lanes *outside* ``cur_idx`` (plus stale
    copies of lanes inside it, which every merge overwrites), and the
    host never re-reads a carry object after passing it to a donating
    jit — each round rebinds ``cur_carry`` to the stepper's output, and
    the final ``_output_batch``/``_trace_of`` reads only the merged
    result, never a donated argument."""
    N = batch.task_job.shape[0]
    cur_batch, cur_inv, cur_carry = batch, inv, c0
    cur_active, n_act_dev, order_dev = _activity_batch(batch, c0,
                                                       control=control)
    n_act = int(n_act_dev)
    stats["scalar_syncs"] += 1
    carry_store = None
    # freshness flags: ``_epoch_setup``/``initial_state``-style jits can
    # forward an input array unchanged, so the t=0 carry may alias batch
    # leaves — donating a buffer that also rides in the same call's
    # operands is an XLA error.  Only carries/stores produced by a
    # compute op inside this loop (gather or stepper output) are donated.
    carry_fresh = store_fresh = False
    cur_idx = np.arange(N)
    realized = 0
    while realized < bound:
        if n_act == 0:
            break
        pad = pow2_pad(n_act, cap=len(cur_idx), floor=floor)
        if pad < len(cur_idx):
            # retire the working set into the dense store, then gather the
            # active lanes (pow2-padded with finished lanes, which step
            # idempotently) into a compacted view of the original batch —
            # the device-computed order crosses the host boundary here
            # and only here
            order = np.asarray(order_dev)[:pad]
            stats["syncs"] += 1
            if carry_store is None:
                carry_store, store_fresh = cur_carry, carry_fresh
            else:
                carry_store = (_put_lanes_donated if donate and store_fresh
                               else _put_lanes)(carry_store,
                                                jnp.asarray(cur_idx),
                                                cur_carry)
                store_fresh = True
            cur_idx = cur_idx[order]
            take = jnp.asarray(cur_idx)
            cur_batch = _take_lanes(batch, take)
            cur_inv = _take_lanes(inv, take)
            cur_carry = _take_lanes(carry_store, take)
            carry_fresh = True
            cur_active = _active_batch(cur_batch, cur_carry,
                                       control=control)
            stats["compactions"] += 1
        step = (_step_epoch_chunk_donated if donate and carry_fresh
                else _step_epoch_chunk)
        cur_carry, cur_active, counts, order_dev = step(
            cur_batch, cur_inv, cur_carry, cur_active,
            jnp.int32(bound - realized), k, control=control,
            trace=tr is not None)
        carry_fresh = True
        stats["dispatches"] += 1
        n_step, n_act = (int(v) for v in np.asarray(counts))
        stats["scalar_syncs"] += 1
        realized += n_step
    if carry_store is None:
        final = cur_carry
    else:
        final = (_put_lanes_donated if donate and store_fresh
                 else _put_lanes)(carry_store, jnp.asarray(cur_idx),
                                  cur_carry)
    out = _output_batch(batch, final), jnp.int32(realized)
    if tr is not None:
        return out + (_trace_of(final),)
    return out


def _compact_loop_legacy(batch: ScenarioArrays, inv, c0, *, bound: int,
                         k: int, floor: int, control: bool, tr,
                         stats: dict, donate: bool):
    """The pre-dispatch-lean host loop, verbatim: a full ``bool[N]`` mask
    pull + host-side ordering every round, no donation.  Kept as the
    honest A/B comparator for the recorded compaction benches and as the
    reference the lean loop's bitwise tests pin against."""
    del donate                     # the legacy loop never donated
    N = batch.task_job.shape[0]
    carry_store = c0
    cur_batch, cur_inv, cur_carry = batch, inv, c0
    cur_active = _active_batch(batch, c0, control=control)
    cur_idx = np.arange(N)
    realized = 0
    while realized < bound:
        act_np = np.asarray(cur_active)
        stats["syncs"] += 1
        n_act = int(act_np.sum())
        if n_act == 0:
            break
        pad = pow2_pad(n_act, cap=len(cur_idx), floor=floor)
        if pad < len(cur_idx):
            carry_store = _put_lanes(carry_store, jnp.asarray(cur_idx),
                                     cur_carry)
            order = np.concatenate([np.nonzero(act_np)[0],
                                    np.nonzero(~act_np)[0]])[:pad]
            cur_idx = cur_idx[order]
            take = jnp.asarray(cur_idx)
            cur_batch = _take_lanes(batch, take)
            cur_inv = _take_lanes(inv, take)
            cur_carry = _take_lanes(carry_store, take)
            cur_active = _active_batch(cur_batch, cur_carry,
                                       control=control)
            stats["compactions"] += 1
        cur_carry, cur_active, counts, _ = _step_epoch_chunk(
            cur_batch, cur_inv, cur_carry, cur_active,
            jnp.int32(bound - realized), k, control=control,
            trace=tr is not None)
        stats["dispatches"] += 1
        n_step = int(counts[0])
        stats["scalar_syncs"] += 1
        realized += n_step
    carry_store = _put_lanes(carry_store, jnp.asarray(cur_idx), cur_carry)
    out = _output_batch(batch, carry_store), jnp.int32(realized)
    if tr is not None:
        return out + (_trace_of(carry_store),)
    return out


# ---------------------------------------------------------------------------
# Dependent variables (paper §5.3) as JAX ops
# ---------------------------------------------------------------------------

def job_metrics(sc: ScenarioArrays, out: SimOutput) -> JobMetrics:
    J = sc.job_length.shape[0]
    is_map = sc.task_valid & ~sc.task_is_reduce
    is_red = sc.task_valid & sc.task_is_reduce
    # Segment reductions as one-hot contractions / masked maxima instead of
    # jax.ops.segment_* scatters: vmapped scatters serialize on XLA:CPU and
    # dominated the sweep's per-call time (they cost more than the event
    # loop itself).  XLA:CPU accumulates both a dot's contraction dim and a
    # scatter-add in task-index order, so the sums are bit-identical
    # (pinned in the adaptive-schedule parity suite); maxima are exact in
    # any order.
    job_onehot = (sc.task_job[:, None] == jnp.arange(J)[None, :]
                  ).astype(jnp.float32)

    def seg_sum(x, m):
        return jnp.where(m, x, 0.0) @ job_onehot

    def seg_max(x, m):
        # two-level identity mirrors segment_max exactly: a job whose
        # tasks are all masked out maxes the -_BIG fill values, while a
        # job no task maps to at all (padded J rows) stays at the true
        # max identity, -inf
        return jnp.max(jnp.where(job_onehot > 0.5,
                                 jnp.where(m, x, -_BIG)[:, None],
                                 -jnp.inf), axis=0)

    def seg_min(x, m):
        return -seg_max(-x, m)

    nm = jnp.maximum(seg_sum(jnp.ones_like(out.exec_time), is_map), 1.0)
    nr = jnp.maximum(seg_sum(jnp.ones_like(out.exec_time), is_red), 1.0)
    m_avg = seg_sum(out.exec_time, is_map) / nm
    r_avg = seg_sum(out.exec_time, is_red) / nr
    m_max, r_max = seg_max(out.exec_time, is_map), seg_max(out.exec_time, is_red)
    m_min, r_min = seg_min(out.exec_time, is_map), seg_min(out.exec_time, is_red)

    last_map_fin = seg_max(out.finish, is_map)
    last_red_fin = seg_max(out.finish, is_red)
    last_map_st = seg_max(out.start, is_map)
    last_red_st = seg_max(out.start, is_red)
    delay = last_map_st + last_red_st - last_map_fin

    # cost accrues on the task's *current* VM (the failover slot once a
    # failure re-dispatched it; == task_vm bitwise in open-loop runs)
    cur_vm = jnp.where(out.hit, out.task_vm2, sc.task_vm)
    cost_rate = sc.vm_cost[cur_vm]
    vm_cost = seg_sum(out.exec_time * cost_rate, is_map | is_red)

    return JobMetrics(
        avg_exec=m_avg + r_avg,
        max_exec=m_max + r_max,
        min_exec=m_min + r_min,
        makespan=last_red_fin - sc.job_submit,
        delay_time=delay,
        vm_cost=vm_cost,
        network_cost=delay * sc.net_cost_per_unit * sc.net_enabled,
        map_avg_exec=m_avg,
        reduce_avg_exec=r_avg,
        completion=jnp.where(sc.job_valid, last_red_fin, 0.0),
    )


def scenario_metrics(sc: ScenarioArrays, out: SimOutput) -> ScenarioMetrics:
    """Whole-scenario dependent variables (sweep-result companions to the
    per-job :class:`JobMetrics`).  Utilization is the fraction of the
    cluster's MI capacity delivered over the scenario's wall-clock span —
    every valid task completes, so delivered MI is just the summed task
    lengths."""
    total_mi = jnp.sum(task_lengths(sc))
    capacity = jnp.sum(jnp.where(sc.vm_valid, sc.vm_mips * sc.vm_pes, 0.0))
    util = total_mi / jnp.maximum(capacity * out.finish_time, 1e-30)
    # Transfer-aware storage metrics (DESIGN.md §7): pure functions of the
    # encoded placement + binding (the broker binds before execution, so
    # locality is decided at encode time, not by the event loop).
    blocked = storage.has_block(sc.block_vm) & sc.task_valid
    local = blocked & storage.is_local(sc.block_vm, sc.task_vm)
    n_blocked = jnp.sum(blocked.astype(jnp.float32))
    loc_frac = (jnp.sum(local.astype(jnp.float32))
                / jnp.maximum(n_blocked, 1.0))
    xfer = jnp.sum(jnp.where(blocked & ~local, sc.block_size, 0.0)) * 1e6
    # Pay-as-you-go accounting (DESIGN.md §8).  Billing runs over each
    # VM's *realized* lease (elasticity.billed_lease: open-ended leases
    # end with the workload, finite leases bill their declared window
    # extended by any admitted work still draining), rounded up to the
    # billing granularity.  Stranded tasks (finish at the _BIG stand-in)
    # are excluded from delivered work and wait times.  The only [T, V]
    # intermediates are one bool one-hot + one masked-max: for a
    # statically open-ended fleet XLA folds ``sc.vm_stop`` to the _BIG
    # constant and DCEs the whole busy_end chain.
    V = sc.vm_mips.shape[0]
    # Billing runs over the *realized* windows the control loop left
    # behind (SimOutput.vm_open/vm_close == the encoded vm_start/vm_stop
    # in open-loop runs, so the pre-control op sequence is bitwise): a
    # never-opened reserve (open at _BIG) clamps to zero billed seconds,
    # an opened-never-closed lease ends with the workload.  Task→VM
    # attribution uses the current binding slot (failover once hit).
    cur_vm = jnp.where(out.hit, out.task_vm2, sc.task_vm)
    vm_onehot_b = cur_vm[:, None] == jnp.arange(V)[None, :]
    ran = sc.task_valid & (out.finish < _BIG / 2)
    fin_ran = jnp.where(ran, out.finish, 0.0)
    busy_end = jnp.max(jnp.where(vm_onehot_b, fin_ran[:, None], 0.0),
                       axis=0)
    billed_t = elasticity.billed_lease(out.vm_open, out.vm_close, busy_end,
                                       out.finish_time, sc.bill_gran, xp=jnp)
    billed = jnp.sum(jnp.where(sc.vm_valid, billed_t * sc.vm_cost, 0.0))
    lease_end = jnp.where(out.vm_close >= _BIG / 2, out.finish_time,
                          jnp.maximum(out.vm_close, busy_end))
    lease_dur = jnp.maximum(lease_end - out.vm_open, 0.0)
    delivered = jnp.sum(jnp.where(ran, task_lengths(sc), 0.0))
    leased_cap = jnp.sum(jnp.where(sc.vm_valid,
                                   sc.vm_mips * sc.vm_pes * lease_dur, 0.0))
    busy_frac = delivered / jnp.maximum(leased_cap, 1e-30)
    started = sc.task_valid & (out.start < _BIG / 2)
    q_wait = jnp.sum(jnp.where(started, out.start - out.ready, 0.0)) \
        / jnp.maximum(jnp.sum(started.astype(jnp.float32)), 1.0)
    # closed-loop control metrics (DESIGN.md §10; exact zeros open-loop)
    fail_fired = sc.vm_valid & (sc.vm_fail < _BIG / 2) \
        & (sc.vm_fail <= out.finish_time)
    n_failures = jnp.sum(fail_fired.astype(jnp.float32))
    hit_tasks = sc.task_valid & out.hit
    n_hit = jnp.sum(hit_tasks.astype(jnp.float32))
    n_recovered = jnp.sum((hit_tasks & ran).astype(jnp.float32))
    recovered = n_recovered / jnp.maximum(n_hit, 1.0)
    # SLO metrics layer (DESIGN.md §11): pure functions of the encoded
    # deadlines and the realized schedule, so they accumulate even under
    # DeadlinePolicy.NONE (observe without acting); all exact zeros when
    # no finite deadline / preemption is encoded.
    fin_dl = sc.task_valid & (sc.task_deadline < _BIG / 2)
    n_dl = jnp.sum(fin_dl.astype(jnp.float32))
    missed = fin_dl & ((out.finish >= _BIG / 2)
                       | (out.finish > sc.task_deadline))
    miss_frac = jnp.sum(missed.astype(jnp.float32)) / jnp.maximum(n_dl, 1.0)
    shed_tasks = jnp.sum((sc.task_valid & out.shed).astype(jnp.float32))
    preemptions = jnp.sum(out.n_evict).astype(jnp.float32)
    late = fin_dl & ran & (out.finish > sc.task_deadline)
    wasted = out.work_lost + jnp.sum(jnp.where(late, task_lengths(sc), 0.0))
    wasted_frac = wasted / jnp.maximum(delivered + out.work_lost, 1e-30)
    # nearest-rank p99 over completed finite-deadline tasks: members sort
    # below the _BIG fill, so index ceil(0.99 n) - 1 lands on a member
    comp_dl = fin_dl & ran
    n_comp = jnp.sum(comp_dl.astype(jnp.float32))
    slack_sorted = jnp.sort(jnp.where(comp_dl,
                                      out.finish - sc.task_deadline,
                                      jnp.float32(_BIG)))
    p_idx = jnp.clip(jnp.ceil(0.99 * n_comp).astype(jnp.int32) - 1,
                     0, slack_sorted.shape[0] - 1)
    p99 = jnp.where(n_comp > 0.5, slack_sorted[p_idx], 0.0)
    return ScenarioMetrics(finish_time=out.finish_time, utilization=util,
                           n_epochs=out.n_epochs,
                           locality_fraction=loc_frac, transfer_bytes=xfer,
                           billed_cost=billed, vm_busy_fraction=busy_frac,
                           queue_wait=q_wait,
                           failures_injected=n_failures,
                           tasks_redispatched=n_hit,
                           scale_events=out.n_scale.astype(jnp.float32),
                           recovered_fraction=recovered,
                           deadline_miss_fraction=miss_frac,
                           shed_tasks=shed_tasks,
                           preemptions=preemptions,
                           wasted_work_frac=wasted_frac,
                           p99_slack=p99)


@partial(jax.jit, static_argnames="control")
def _simulate_jit(arrs: ScenarioArrays, control: bool = False) -> JobMetrics:
    return job_metrics(arrs, simulate_arrays(arrs, control=control))


def simulate(sc: Scenario) -> JobMetrics:
    """Convenience single-scenario entry point (returns device arrays)."""
    arrs = from_scenario(sc)
    return _simulate_jit(arrs, control=_control_active(arrs))
