"""Vectorized discrete-event engine (the TPU-native IOTSim core).

The sequential CloudSim event loop (``refsim.py``) is re-expressed as a
fixed-shape state machine advanced by ``jax.lax.while_loop``: each iteration
processes one *event epoch* — it advances the processor-sharing fluid state
to the earliest next completion/arrival and fires every event at that
instant.  Rates only change at events, so the fluid dynamics are exact (this
is not time-stepping).

Because every per-scenario state is a fixed-shape array bundle
(:class:`ScenarioArrays`), the whole simulation is ``vmap``-able over
scenarios and ``pjit``-able over a pod mesh — one lowering simulates millions
of IOTSim scenarios in parallel (see ``sweep.py``).  This is the
hardware-adaptation of the paper's sequential Java architecture (DESIGN.md
§2).

Semantics are tested to match ``refsim.py`` exactly
(``tests/test_engine_vs_refsim.py``).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import Scenario

_BIG = 1e30          # stand-in for +inf that survives arithmetic
_TIME_EPS = 1e-6     # relative tie window for simultaneous events


# ---------------------------------------------------------------------------
# Array-of-structs scenario encoding
# ---------------------------------------------------------------------------

class ScenarioArrays(NamedTuple):
    """One scenario as fixed-shape arrays (all leaves vmappable).

    Shapes: T = padded task count, J = padded job count, V = padded VM count.
    Task structure (which job, map/reduce, VM binding) is *data*, so sweeps
    may vary MR combination, job sizes, VM speeds … under ``vmap`` without
    re-tracing.
    """
    # tasks
    task_job: jax.Array        # i32[T] job index
    task_is_reduce: jax.Array  # bool[T]
    task_vm: jax.Array         # i32[T] round-robin VM binding
    task_valid: jax.Array      # bool[T]
    task_mult: jax.Array       # f32[T] straggler length multiplier
    # jobs
    job_length: jax.Array      # f32[J] MI
    job_data: jax.Array        # f32[J] MB
    job_n_maps: jax.Array      # i32[J]
    job_n_reduces: jax.Array   # i32[J]
    job_submit: jax.Array      # f32[J]
    job_reduce_factor: jax.Array  # f32[J]
    job_valid: jax.Array       # bool[J]
    # vms
    vm_mips: jax.Array         # f32[V]
    vm_pes: jax.Array          # f32[V]
    vm_cost: jax.Array         # f32[V]
    vm_valid: jax.Array        # bool[V]
    # network (scalars)
    net_enabled: jax.Array     # f32 (0/1)
    net_bw: jax.Array          # f32
    kappa_in: jax.Array        # f32
    kappa_shuffle: jax.Array   # f32
    net_cost_per_unit: jax.Array  # f32


class SimOutput(NamedTuple):
    """Raw per-task schedule + bookkeeping, all f32/i32 arrays."""
    start: jax.Array     # f32[T]
    finish: jax.Array    # f32[T]
    ready: jax.Array     # f32[T]
    exec_time: jax.Array  # f32[T]
    n_epochs: jax.Array  # i32 — event epochs executed (bench metric)
    finish_time: jax.Array  # f32 — last completion


class JobMetrics(NamedTuple):
    """Paper §5.3 dependent variables, per job (padded J)."""
    avg_exec: jax.Array
    max_exec: jax.Array
    min_exec: jax.Array
    makespan: jax.Array
    delay_time: jax.Array
    vm_cost: jax.Array
    network_cost: jax.Array
    map_avg_exec: jax.Array
    reduce_avg_exec: jax.Array


def from_scenario(sc: Scenario, *, pad_tasks: int | None = None,
                  pad_jobs: int | None = None,
                  pad_vms: int | None = None) -> ScenarioArrays:
    """Encode one :class:`Scenario` into padded arrays (numpy, host-side)."""
    T = pad_tasks or sc.total_tasks()
    J = pad_jobs or len(sc.jobs)
    V = pad_vms or len(sc.vms)
    assert T >= sc.total_tasks() and J >= len(sc.jobs) and V >= len(sc.vms)

    t_job = np.zeros(T, np.int32)
    t_red = np.zeros(T, bool)
    t_vm = np.zeros(T, np.int32)
    t_val = np.zeros(T, bool)
    k = 0
    rr = 0
    for ji, job in enumerate(sc.jobs):
        for phase, n in ((False, job.n_maps), (True, job.n_reduces)):
            for _ in range(n):
                t_job[k], t_red[k], t_val[k] = ji, phase, True
                t_vm[k] = rr % len(sc.vms)
                rr += 1
                k += 1

    f32 = np.float32
    return ScenarioArrays(
        task_job=t_job, task_is_reduce=t_red, task_vm=t_vm, task_valid=t_val,
        task_mult=np.ones(T, f32),
        job_length=_padf([j.length_mi for j in sc.jobs], J),
        job_data=_padf([j.data_mb for j in sc.jobs], J),
        job_n_maps=_padi([j.n_maps for j in sc.jobs], J),
        job_n_reduces=_padi([j.n_reduces for j in sc.jobs], J),
        job_submit=_padf([j.submit_time for j in sc.jobs], J),
        job_reduce_factor=_padf([j.reduce_factor for j in sc.jobs], J),
        job_valid=np.arange(J) < len(sc.jobs),
        vm_mips=_padf([v.mips for v in sc.vms], V, fill=1.0),
        vm_pes=_padf([v.pes for v in sc.vms], V, fill=1.0),
        vm_cost=_padf([v.cost_per_sec for v in sc.vms], V),
        vm_valid=np.arange(V) < len(sc.vms),
        net_enabled=f32(1.0 if sc.network.enabled else 0.0),
        net_bw=f32(sc.network.bw_mbps),
        kappa_in=f32(sc.network.kappa_in),
        kappa_shuffle=f32(sc.network.kappa_shuffle),
        net_cost_per_unit=f32(sc.network.cost_per_unit),
    )


def _padf(xs, n, fill=0.0):
    out = np.full(n, fill, np.float32)
    out[:len(xs)] = xs
    return out


def _padi(xs, n):
    out = np.ones(n, np.int32)
    out[:len(xs)] = xs
    return out


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------

def simulate_arrays(sc: ScenarioArrays) -> SimOutput:
    """Run one encoded scenario.  Pure function of arrays: jit/vmap-friendly."""
    T = sc.task_job.shape[0]
    J = sc.job_length.shape[0]
    V = sc.vm_mips.shape[0]

    # --- derived per-task/per-job quantities (traced: sweepable) ----------
    n_maps_f = sc.job_n_maps.astype(jnp.float32)
    n_red_f = sc.job_n_reduces.astype(jnp.float32)
    stage_in = (sc.net_enabled * sc.kappa_in * sc.job_data
                / ((n_maps_f + 1.0) * sc.net_bw))
    shuffle = (sc.net_enabled * sc.kappa_shuffle * sc.job_data
               / ((n_maps_f + 1.0) * sc.net_bw))
    map_len = sc.job_length / n_maps_f
    red_len = sc.job_reduce_factor * sc.job_length / n_red_f
    task_len = jnp.where(sc.task_is_reduce, red_len[sc.task_job],
                         map_len[sc.task_job]) * sc.task_mult
    task_len = jnp.where(sc.task_valid, task_len, 0.0)

    # Maps ready at submit + stage-in; reduces unknown until maps complete.
    ready0 = jnp.where(
        sc.task_valid & ~sc.task_is_reduce,
        (sc.job_submit + stage_in)[sc.task_job], _BIG)

    is_map = sc.task_valid & ~sc.task_is_reduce
    maps_left0 = jax.ops.segment_sum(is_map.astype(jnp.int32), sc.task_job,
                                     num_segments=J)

    class Carry(NamedTuple):
        time: jax.Array
        rem: jax.Array        # f32[T] remaining MI
        running: jax.Array    # bool[T]
        start: jax.Array      # f32[T]
        finish: jax.Array     # f32[T]
        ready: jax.Array      # f32[T]
        maps_left: jax.Array  # i32[J]
        epoch: jax.Array      # i32

    c0 = Carry(time=jnp.float32(0.0), rem=task_len,
               running=jnp.zeros(T, bool),
               start=jnp.full(T, _BIG, jnp.float32),
               finish=jnp.full(T, _BIG, jnp.float32),
               ready=ready0, maps_left=maps_left0,
               epoch=jnp.int32(0))

    def rates(running):
        n_on_vm = jax.ops.segment_sum(running.astype(jnp.float32),
                                      sc.task_vm, num_segments=V)
        share = sc.vm_mips * jnp.minimum(1.0, sc.vm_pes
                                         / jnp.maximum(n_on_vm, 1.0))
        return jnp.where(running, share[sc.task_vm], 0.0)

    def cond(c: Carry):
        unfinished = sc.task_valid & (c.finish >= _BIG / 2)
        return jnp.any(unfinished) & (c.epoch < 4 * T + 8)

    def body(c: Carry):
        r = rates(c.running)
        eta = jnp.where(c.running, c.time + c.rem / jnp.maximum(r, 1e-30),
                        _BIG)
        not_started = sc.task_valid & ~c.running & (c.finish >= _BIG / 2) \
            & (c.start >= _BIG / 2)
        arr = jnp.where(not_started, c.ready, _BIG)
        t_next = jnp.minimum(jnp.min(eta), jnp.min(arr))
        live = t_next < _BIG / 2
        tie = _TIME_EPS * jnp.maximum(t_next, 1.0)

        # advance fluid state
        rem = jnp.where(c.running, c.rem - (t_next - c.time) * r, c.rem)

        # completions
        done_now = live & c.running & (eta <= t_next + tie)
        finish = jnp.where(done_now, t_next, c.finish)
        running = c.running & ~done_now
        rem = jnp.where(done_now, 0.0, rem)

        # job map-phase completion -> release reduces after shuffle delay
        maps_done_now = jax.ops.segment_sum(
            (done_now & ~sc.task_is_reduce).astype(jnp.int32),
            sc.task_job, num_segments=J)
        maps_left = c.maps_left - maps_done_now
        phase_done = (maps_left == 0) & (c.maps_left > 0)
        red_ready = jnp.where(phase_done, t_next + shuffle, _BIG)
        ready = jnp.where(
            sc.task_is_reduce & phase_done[sc.task_job],
            red_ready[sc.task_job], c.ready)

        # arrivals (time-shared: start immediately when ready)
        start_now = live & not_started & (c.ready <= t_next + tie)
        start = jnp.where(start_now, t_next, c.start)
        running = running | start_now

        time = jnp.where(live, t_next, c.time)
        return Carry(time, rem, running, start, finish, ready,
                     maps_left, c.epoch + 1)

    cf = jax.lax.while_loop(cond, body, c0)
    exec_time = jnp.where(sc.task_valid, cf.finish - cf.start, 0.0)
    return SimOutput(start=cf.start, finish=cf.finish, ready=cf.ready,
                     exec_time=exec_time, n_epochs=cf.epoch,
                     finish_time=jnp.max(jnp.where(sc.task_valid, cf.finish,
                                                   0.0)))


# ---------------------------------------------------------------------------
# Dependent variables (paper §5.3) as JAX ops
# ---------------------------------------------------------------------------

def job_metrics(sc: ScenarioArrays, out: SimOutput) -> JobMetrics:
    J = sc.job_length.shape[0]
    is_map = sc.task_valid & ~sc.task_is_reduce
    is_red = sc.task_valid & sc.task_is_reduce

    def seg_sum(x, m):
        return jax.ops.segment_sum(jnp.where(m, x, 0.0), sc.task_job,
                                   num_segments=J)

    def seg_max(x, m):
        return jax.ops.segment_max(jnp.where(m, x, -_BIG), sc.task_job,
                                   num_segments=J)

    def seg_min(x, m):
        return -seg_max(-x, m)

    nm = jnp.maximum(seg_sum(jnp.ones_like(out.exec_time), is_map), 1.0)
    nr = jnp.maximum(seg_sum(jnp.ones_like(out.exec_time), is_red), 1.0)
    m_avg = seg_sum(out.exec_time, is_map) / nm
    r_avg = seg_sum(out.exec_time, is_red) / nr
    m_max, r_max = seg_max(out.exec_time, is_map), seg_max(out.exec_time, is_red)
    m_min, r_min = seg_min(out.exec_time, is_map), seg_min(out.exec_time, is_red)

    last_map_fin = seg_max(out.finish, is_map)
    last_red_fin = seg_max(out.finish, is_red)
    last_map_st = seg_max(out.start, is_map)
    last_red_st = seg_max(out.start, is_red)
    delay = last_map_st + last_red_st - last_map_fin

    cost_rate = sc.vm_cost[sc.task_vm]
    vm_cost = seg_sum(out.exec_time * cost_rate, is_map | is_red)

    return JobMetrics(
        avg_exec=m_avg + r_avg,
        max_exec=m_max + r_max,
        min_exec=m_min + r_min,
        makespan=last_red_fin - sc.job_submit,
        delay_time=delay,
        vm_cost=vm_cost,
        network_cost=delay * sc.net_cost_per_unit * sc.net_enabled,
        map_avg_exec=m_avg,
        reduce_avg_exec=r_avg,
    )


@jax.jit
def _simulate_jit(arrs: ScenarioArrays) -> JobMetrics:
    return job_metrics(arrs, simulate_arrays(arrs))


def simulate(sc: Scenario) -> JobMetrics:
    """Convenience single-scenario entry point (returns device arrays)."""
    return _simulate_jit(from_scenario(sc))
