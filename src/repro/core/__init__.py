"""IOTSim-JAX core: the paper's contribution, vectorized for TPU.

Public API:

* configs — :class:`~repro.core.config.Scenario` and the paper's Table I–III
  presets (:func:`~repro.core.config.paper_scenario`);
* :func:`~repro.core.refsim.simulate` — sequential paper-faithful oracle;
* :func:`~repro.core.engine.simulate` — vectorized JAX engine (single cell);
* :mod:`~repro.core.sweep` — vmapped / mesh-sharded scenario sweeps;
* :mod:`~repro.core.workload` — LM-training-step → scenario bridge
  (stragglers, failures, checkpoint goodput).
"""
from . import (control, elasticity, engine, network, refsim, storage, sweep,
               telemetry, workload)
from .config import (JOB_BIG, JOB_MEDIUM, JOB_SMALL, JOB_TYPES, VM_LARGE,
                     VM_MEDIUM, VM_SMALL, VM_TYPES, BindingPolicy,
                     DatacenterSpec, JobSpec, NetworkSpec, Scenario,
                     SchedPolicy, VMSpec, paper_scenario)
from .control import ControlPolicy, ControlSpec, DeadlinePolicy
from .elasticity import ArrivalProcess, ElasticitySpec
from .engine import JobMetrics, ScenarioArrays, ScenarioMetrics, SimOutput
from .storage import Placement, StorageSpec
from .sweep import Axis, StreamedSweep, SweepPlan, SweepResult
from .telemetry import RunReport, TraceResult, TraceSpec, trace_scenario
from .workload import ChipSpec, StepCost

__all__ = [
    "control", "elasticity", "engine", "network", "refsim", "storage",
    "sweep", "telemetry", "workload",
    "Scenario", "VMSpec", "JobSpec", "NetworkSpec", "DatacenterSpec",
    "StorageSpec", "Placement", "SchedPolicy", "BindingPolicy",
    "ElasticitySpec", "ArrivalProcess", "ControlSpec", "ControlPolicy",
    "DeadlinePolicy",
    "VM_SMALL", "VM_MEDIUM", "VM_LARGE", "VM_TYPES",
    "JOB_SMALL", "JOB_MEDIUM", "JOB_BIG", "JOB_TYPES",
    "paper_scenario", "JobMetrics", "ScenarioArrays", "ScenarioMetrics",
    "SimOutput", "Axis", "SweepPlan", "SweepResult", "StreamedSweep",
    "TraceSpec", "TraceResult", "RunReport", "trace_scenario",
    "ChipSpec", "StepCost",
]

from . import speculative, streaming  # noqa: E402  (beyond-paper layers)
