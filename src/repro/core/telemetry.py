"""Trace & telemetry layer (DESIGN.md §12).

Three surfaces, all opt-in (the plain path pays zero ops):

* **In-loop trace recorder** — the engine's epoch loop carries, under the
  static ``trace`` flag, a fixed-capacity per-lane time-series buffer (one
  row per realized epoch: clock, queue depth, busy fraction, open VM
  count, activity, failures/sheds/preemptions this epoch) plus a bounded
  event log of ``(t, kind, task, vm)`` rows written by one-hot scatter.
  Capacities derive from the per-lane epoch bounds (DESIGN.md §10.4), and
  an explicit :attr:`TraceBuffers.dropped_events` counter makes event-log
  overflow loud instead of silent.  The same leaves ride the §9
  compaction gather/scatter like any other carry leaf, and the Pallas
  ``mr_epoch`` twin writes the identical time-series rows (bitwise in
  interpret mode; the event log stays engine/refsim scope).

* **Export** — :class:`TraceResult` turns the device buffers into a
  long-form per-epoch table (``to_table``/``to_parquet``) and a
  Chrome/Perfetto trace-event JSON (``to_chrome_trace``: per-VM tracks of
  task spans, instant events for kill/redispatch/shed/preempt/scale) —
  load the file at ``chrome://tracing`` or https://ui.perfetto.dev.

* **Sweep-runtime telemetry** — :class:`RunReport`
  (``SweepPlan.run(report=True)``): bucket decisions with cost-model
  split gains, compile-cache hits/misses, compaction sync counts,
  per-bucket dispatch counts and wall time, plus device/backend/
  cost-calibration meta and the run-provenance stamp every exported
  artifact carries.

The refsim oracle records the same events host-side (``SimResult.events``)
so the trace itself is testable: the engine's event log reduced by kind
must match the oracle's counts (and, for all kinds but SHED whose
detection instants are epoch-quantized, timestamps).
"""
from __future__ import annotations

import dataclasses
import functools
import json
import pathlib
import subprocess
from typing import NamedTuple

import numpy as np

# ---------------------------------------------------------------------------
# Event kinds (shared by engine trace rows and the refsim mirror)
# ---------------------------------------------------------------------------

EV_START = 0        # a task takes a PE and begins (or resumes) executing
EV_FINISH = 1       # a task completes
EV_KILL = 2         # a VM failure kills an unfinished bound task
EV_PREEMPT = 3      # priority preemption evicts a running task
EV_SHED = 4         # deadline admission control refuses a task
EV_SCALE_OPEN = 5   # the autoscale hook opens a reserve lease
EV_SCALE_CLOSE = 6  # the autoscale hook closes a drained reserve

EVENT_NAMES = {
    EV_START: "start",
    EV_FINISH: "finish",
    EV_KILL: "kill",
    EV_PREEMPT: "preempt",
    EV_SHED: "shed",
    EV_SCALE_OPEN: "scale_open",
    EV_SCALE_CLOSE: "scale_close",
}

# Per-epoch time-series row layout (one f32 row per realized epoch).
TS_COLUMNS = ("time", "queue_depth", "busy_fraction", "open_vms",
              "active", "failures", "sheds", "preemptions")
N_TS_COLS = len(TS_COLUMNS)


# ---------------------------------------------------------------------------
# Capacity math (DESIGN.md §12.2)
# ---------------------------------------------------------------------------

def timeseries_capacity(n_tasks: int, n_vms: int, control: bool) -> int:
    """Rows the per-epoch time series needs: the per-lane epoch bound.

    Matches the drivers' loop bounds exactly (DESIGN.md §10.4): ``2T + 2``
    open-loop, the ``7T + V + 3`` batch worst case under control — a lane
    can never realize more epochs, so no time-series row is ever dropped.
    """
    t, v = int(n_tasks), int(n_vms)
    return 7 * t + v + 3 if control else 2 * t + 2


def event_capacity(n_tasks: int, n_vms: int, control: bool) -> int:
    """Default event-log capacity: the per-lane worst-case event count.

    Open-loop a task produces exactly one START and one FINISH.  Under
    control each task is killed at most twice (one failure per slot: the
    first hit moves it to the failover slot, whose own window fires at
    most once), preempted at most twice (the ``n_evict < 2`` gate), so it
    starts at most ``1 + kills + evictions = 5`` times, finishes at most
    once and sheds at most once — 11 rows per task — and each VM opens
    and closes at most once — 2 rows per VM.
    """
    t, v = int(n_tasks), int(n_vms)
    return 11 * t + 2 * v if control else 2 * t


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """Trace-capacity overrides (``None`` → the derived worst case).

    ``events`` deliberately admits undersized buffers: overflow drops the
    newest rows and counts them in ``dropped_events`` — earlier rows are
    never corrupted (the one-hot write falls off the end of the buffer).
    """
    events: int | None = None


# ---------------------------------------------------------------------------
# Device-side result buffers
# ---------------------------------------------------------------------------

class TraceBuffers(NamedTuple):
    """Raw trace arrays as the drivers return them (device or host).

    Shapes are per-lane (``ts: [C, 8]``, ``ev_*: [E]``, ``ev_n: []``) from
    ``simulate_arrays`` and lane-stacked (leading batch axis) from the
    batched/compacted drivers.  ``ev_n`` counts every event *attempted*,
    so ``dropped_events = max(0, ev_n - E)`` is exact.
    """
    ts: object          # f32 per-epoch time series, TS_COLUMNS layout
    ev_t: object        # f32 event timestamps
    ev_kind: object     # i32 event kinds (-1 = empty slot)
    ev_task: object     # i32 task id (-1 for scale events)
    ev_vm: object       # i32 VM id
    ev_n: object        # i32 events attempted (write cursor)

    @property
    def dropped_events(self):
        cap = np.shape(self.ev_t)[-1]
        return np.maximum(np.asarray(self.ev_n) - cap, 0)


# ---------------------------------------------------------------------------
# Host-side result wrapper + exports
# ---------------------------------------------------------------------------

class TraceResult:
    """Host-side view over :class:`TraceBuffers` with export surfaces."""

    def __init__(self, buffers: TraceBuffers, label: str = "trace"):
        ts = np.asarray(buffers.ts, np.float32)
        if ts.ndim == 2:                       # single lane -> batch of one
            ts = ts[None]
            ev = [np.asarray(x)[None] for x in buffers[1:5]]
            ev_n = np.asarray(buffers.ev_n).reshape(1)
        else:
            ev = [np.asarray(x) for x in buffers[1:5]]
            ev_n = np.asarray(buffers.ev_n).reshape(-1)
        self.ts = ts
        self.ev_t, self.ev_kind, self.ev_task, self.ev_vm = ev
        self.ev_n = ev_n
        self.label = label

    @property
    def n_lanes(self) -> int:
        return self.ts.shape[0]

    @property
    def event_capacity(self) -> int:
        return self.ev_t.shape[-1]

    @property
    def dropped_events(self) -> np.ndarray:
        """Per-lane count of events that overflowed the log (0 = none)."""
        return np.maximum(self.ev_n - self.event_capacity, 0)

    # ---- tabular exports -------------------------------------------------

    def to_table(self) -> dict[str, np.ndarray]:
        """Long-form per-epoch time series: one row per realized epoch."""
        lane_idx, epoch_idx = np.nonzero(self.ts[:, :, 4] > 0.0)
        rows = self.ts[lane_idx, epoch_idx]
        out = {"lane": lane_idx.astype(np.int32),
               "epoch": epoch_idx.astype(np.int32)}
        for ci, name in enumerate(TS_COLUMNS):
            out[name] = rows[:, ci]
        return out

    def to_parquet(self, path) -> None:
        import pyarrow as pa
        import pyarrow.parquet as pq
        table = pa.table(self.to_table())
        table = table.replace_schema_metadata(
            {**(table.schema.metadata or {}), **parquet_metadata()})
        pq.write_table(table, path)

    def events(self) -> dict[str, np.ndarray]:
        """Event-log rows as columns, empty slots stripped."""
        lane_idx, slot = np.nonzero(self.ev_kind >= 0)
        return {"lane": lane_idx.astype(np.int32),
                "t": self.ev_t[lane_idx, slot],
                "kind": self.ev_kind[lane_idx, slot],
                "task": self.ev_task[lane_idx, slot],
                "vm": self.ev_vm[lane_idx, slot]}

    def counts_by_kind(self, lane: int | None = None) -> dict[str, int]:
        kinds = self.ev_kind if lane is None else self.ev_kind[lane]
        return {name: int(np.sum(kinds == k))
                for k, name in EVENT_NAMES.items()}

    # ---- Chrome / Perfetto export ---------------------------------------

    def to_chrome_trace(self, path=None) -> dict:
        """Chrome trace-event JSON: per-VM tracks of task spans plus
        instant events for kill/redispatch/shed/preempt/scale.

        One complete-event span (``ph: "X"``) per realized task execution
        — a START paired with the FINISH/KILL/PREEMPT that ends it (a
        still-running START at trace end closes at the last event time,
        flagged ``outcome: "unterminated"``).  ``pid`` is the lane,
        ``tid`` the VM track; timestamps are sim-seconds scaled to µs.
        """
        events: list[dict] = []
        us = 1e6
        for lane in range(self.n_lanes):
            valid = self.ev_kind[lane] >= 0
            t_all = self.ev_t[lane][valid]
            k_all = self.ev_kind[lane][valid]
            task_all = self.ev_task[lane][valid]
            vm_all = self.ev_vm[lane][valid]
            open_spans: dict[int, tuple[float, int]] = {}
            interrupted: set[int] = set()
            tracks: set[int] = set()
            last_t = float(t_all[-1]) if t_all.size else 0.0

            def span(task, t0, vm, t1, outcome):
                events.append({
                    "name": f"task {task}", "cat": "task", "ph": "X",
                    "pid": lane, "tid": int(vm),
                    "ts": t0 * us, "dur": max(t1 - t0, 0.0) * us,
                    "args": {"task": int(task), "outcome": outcome}})

            def instant(name, t, vm, task):
                events.append({
                    "name": name, "cat": "event", "ph": "i", "s": "t",
                    "pid": lane, "tid": int(vm), "ts": float(t) * us,
                    "args": {"task": int(task)}})

            for t, k, task, vm in zip(t_all, k_all, task_all, vm_all):
                t, k, task, vm = float(t), int(k), int(task), int(vm)
                tracks.add(vm)
                if k == EV_START:
                    open_spans[task] = (t, vm)
                    if task in interrupted:
                        instant("redispatch", t, vm, task)
                elif k in (EV_FINISH, EV_KILL, EV_PREEMPT):
                    if task in open_spans:
                        t0, vm0 = open_spans.pop(task)
                        span(task, t0, vm0, t,
                             EVENT_NAMES[k] if k != EV_FINISH else "ok")
                    if k == EV_KILL:
                        interrupted.add(task)
                        instant("kill", t, vm, task)
                    elif k == EV_PREEMPT:
                        interrupted.add(task)
                        instant("preempt", t, vm, task)
                elif k == EV_SHED:
                    instant("shed", t, vm, task)
                elif k in (EV_SCALE_OPEN, EV_SCALE_CLOSE):
                    instant(EVENT_NAMES[k], t, vm, task)
            for task, (t0, vm0) in sorted(open_spans.items()):
                span(task, t0, vm0, last_t, "unterminated")
            events.append({"name": "process_name", "ph": "M", "pid": lane,
                           "args": {"name": f"lane {lane}"}})
            for vm in sorted(tracks):
                events.append({"name": "thread_name", "ph": "M",
                               "pid": lane, "tid": int(vm),
                               "args": {"name": f"vm {vm}"}})
        doc = {"traceEvents": events, "displayTimeUnit": "ms",
               "otherData": {**provenance(), "label": self.label,
                             "dropped_events":
                                 int(self.dropped_events.sum())}}
        if path is not None:
            pathlib.Path(path).write_text(json.dumps(doc))
        return doc


# ---------------------------------------------------------------------------
# Run provenance (satellite: self-describing artifacts)
# ---------------------------------------------------------------------------

def _git_sha() -> str | None:
    try:
        root = pathlib.Path(__file__).resolve().parents[3]
        out = subprocess.run(["git", "rev-parse", "HEAD"], cwd=root,
                             capture_output=True, text=True, timeout=5)
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else None
    except Exception:
        return None


@functools.lru_cache(maxsize=1)
def provenance() -> dict:
    """Run-provenance stamp: embedded in parquet metadata, BENCH rows,
    Chrome traces and RunReports so exported artifacts are
    self-describing."""
    import jax

    import repro
    try:
        device_kind = jax.devices()[0].device_kind
    except Exception:
        device_kind = "unknown"
    return {
        "repro_version": getattr(repro, "__version__", "0"),
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_kind": device_kind,
        "git_sha": _git_sha(),
    }


def parquet_metadata() -> dict[bytes, bytes]:
    """Provenance as parquet schema metadata (bytes->bytes)."""
    return {b"repro_provenance": json.dumps(provenance()).encode()}


# ---------------------------------------------------------------------------
# Sweep-runtime telemetry (SweepPlan.run(report=True))
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BucketReport:
    """One shape/static bucket the sweep coalescer dispatched."""
    cells: int                       # grid cells routed to this bucket
    pad_tasks: int                   # padded task-axis shape
    pad_vms: int                     # padded VM-axis shape
    backend: str                     # "xla" | "pallas"
    control: bool                    # closed-loop lowering active
    statics: dict                    # static params pinned for the bucket
    split_gain_us: float | None      # cost-model gain that justified the
    #                                  split (None: base shape bucket)
    dispatches: int = 0              # device dispatches issued
    compact_syncs: int = 0           # full mask/permutation pulls (paid
    #                                  only on rounds that compact)
    compact_scalar_syncs: int = 0    # per-round fused scalar pulls
    wall_s: float = 0.0              # wall time executing this bucket


@dataclasses.dataclass
class RunReport:
    """Sweep-runtime telemetry returned by ``SweepPlan.run(report=True)``."""
    n_cells: int
    n_buckets: int
    backend: str
    compact: object                  # the run's compact request (None/int/"auto")
    buckets: list[BucketReport]
    compile_cache_hits: int          # fused-runner lru hits during the run
    compile_cache_misses: int        # fused-runner lru misses (compiles)
    encoder_cache_hits: int          # grid-encoder lru hits during the run
    encoder_cache_misses: int
    compaction_syncs: int            # total full mask/permutation pulls
    scalar_syncs: int                # total per-round scalar pulls
    dispatches: int                  # total device dispatches
    cost_model: dict                 # measured coefficients + provenance
    #                                  {dispatch_us, epoch_lane_us, sync_us,
    #                                   device,
    #                                   source: measured|cache|fallback|...}
    device: str
    provenance: dict
    wall_s: float

    def to_json(self, indent: int | None = None) -> str:
        return json.dumps(dataclasses.asdict(self), indent=indent,
                          default=str)


# ---------------------------------------------------------------------------
# Convenience: trace one scenario end to end
# ---------------------------------------------------------------------------

def trace_scenario(scenario, spec: TraceSpec | None = None,
                   label: str = "trace"):
    """Run one :class:`~repro.core.config.Scenario` through the vectorized
    engine with tracing on; returns ``(SimOutput, TraceResult)``."""
    from . import engine
    arrs = engine.from_scenario(scenario)
    out, buffers = engine.simulate_arrays(
        arrs, trace=True,
        trace_events=None if spec is None else spec.events)
    return out, TraceResult(jax_tree_to_numpy(buffers), label=label)


def jax_tree_to_numpy(buffers: TraceBuffers) -> TraceBuffers:
    return TraceBuffers(*(np.asarray(x) for x in buffers))
