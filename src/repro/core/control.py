"""Closed-loop control subsystem: seeded faults + reactive autoscaling.

Everything before this module is *open-loop*: schedules, leases and block
placements are fully decided before ``while_loop`` step zero.  IOTSim's
cloud tier exists precisely because IoT big-data workloads are bursty and
failure-prone (paper §3) — the infrastructure must *react*.  This module
closes the loop with two mechanisms, both encoded as device-side data so
they stay sweepable and branch-free (DESIGN.md §10):

* **Seeded VM failure/restore injection** — each VM ``v`` draws one
  failure instant ``F_v`` from a counter-hash exponential stream (the
  same lowbias32 idiom as block placement and arrivals) and restores at
  ``R_v = F_v + repair_delay``.  At ``F_v`` every unfinished task whose
  *current* VM is ``v`` is killed and re-dispatched: the first hit moves
  the task to its precomputed failover VM (replica holders of its input
  block preferred — re-replication rides the PR-4 block store via the
  shared remote-fetch delay), a second hit restarts it in place after the
  restore.  Failure times are drawn host-side in f64 and cast to f32
  once, exactly like ``elasticity.arrival_times`` (``np.log`` and XLA's
  f32 log differ in ULPs — the stream must be one artifact every layer
  consumes).

* **A per-epoch control hook** — :class:`ControlPolicy` rides in
  :class:`~repro.core.engine.ScenarioArrays` as an i32 policy id (like
  Sched/Binding policies).  ``AUTOSCALE`` observes the queue depth (ready
  but unstarted tasks) and the busy fraction of the open fleet at the top
  of every epoch and opens reserve VMs (``VMSpec.autoscale=True`` — their
  lease materializes only when the controller opens it) one per epoch
  while both thresholds are exceeded, closing any opened reserve that has
  no unfinished bound tasks left.  Thresholds are f32 scalars in the
  arrays — sweepable data, not trace constants.

The degenerate configuration (no failures, ``ControlPolicy.NONE``, no
reserve VMs) is a *bitwise identity*: every control op reduces to a
``where`` over an all-false mask, and the engine skips the control code
entirely (a static flag) when the encoded arrays show no control inputs.
"""
from __future__ import annotations

import enum
import math
from dataclasses import dataclass

import numpy as np

from .storage import _C1, _C3, _INV24, _mix32

_BIG = 1e30       # must match engine._BIG (control cannot import engine)


class ControlPolicy(enum.IntEnum):
    """Per-epoch control rule (stable wire constants — i32 sweep data).

    NONE      — open-loop: the encoded lease windows are final.
    AUTOSCALE — reactive scaling: while the observed queue depth exceeds
        ``queue_threshold`` AND the open fleet's busy fraction is at
        least ``busy_threshold``, open one reserve VM per epoch (lowest
        index first); close opened reserves with no unfinished bound
        tasks.
    """
    NONE = 0
    AUTOSCALE = 1


def as_control_policy(v) -> ControlPolicy:
    """Coerce a name (``"none"``/``"autoscale"``), int, or member."""
    if isinstance(v, str):
        try:
            return ControlPolicy[v.upper()]
        except KeyError:
            raise ValueError(
                f"unknown control policy {v!r}; known: "
                f"{[p.name.lower() for p in ControlPolicy]}") from None
    return ControlPolicy(v)


class DeadlinePolicy(enum.IntEnum):
    """Per-task deadline rule (stable wire constants — i32 sweep data;
    DESIGN.md §11).

    NONE  — deadlines are recorded but never acted on (miss metrics still
        accumulate).
    SHED  — admission control: a pending task whose *earliest possible*
        finish (now + remaining work at the bound VM's full per-PE rate)
        already exceeds its deadline is shed — never started, marked
        missed — instead of occupying capacity on work that cannot meet
        its decision window.
    BOOST — priority escalation: a pending task whose earliest possible
        finish is within ``deadline_slack`` of its deadline becomes
        *urgent* and outranks every non-urgent task in the space-shared
        admission order (ties inside a tier keep the §8
        (priority, eligible, index) key).  Nothing is shed.
    """
    NONE = 0
    SHED = 1
    BOOST = 2


def as_deadline_policy(v) -> DeadlinePolicy:
    """Coerce a name (``"none"``/``"shed"``/``"boost"``), int, or member."""
    if isinstance(v, str):
        try:
            return DeadlinePolicy[v.upper()]
        except KeyError:
            raise ValueError(
                f"unknown deadline policy {v!r}; known: "
                f"{[p.name.lower() for p in DeadlinePolicy]}") from None
    return DeadlinePolicy(v)


def earliest_finish(now, rem, mips, xp=np):
    """The shared f32 earliest-finish estimate (DESIGN.md §11).

    ``earliest_finish(...) > deadline`` decides SHED (shed iff true with
    zero slack) and ``earliest_finish(...) + slack >= deadline`` decides
    BOOST urgency.  One op sequence — division then add, every operand
    f32 — shared by the oracle (np.float32 scalars) and the
    engine/kernel (traced f32), so tier membership can never drift
    between layers.  ``deadline=_BIG`` is an exact identity: ``1e30``
    absorbs any finite addend in f32, leaving the compares false.
    """
    return now + rem / xp.maximum(mips, xp.float32(1e-30))


@dataclass(frozen=True)
class ControlSpec:
    """Scenario-level closed-loop control model (disabled by default:
    zero failure rate and ``NONE`` policy reproduce the open-loop
    schedules bit for bit).

    ``failure_rate`` is per-VM failures per simulated second (exponential
    first-failure time; 0 disables injection).  ``repair_delay`` is the
    downtime until the VM admits work again (``inf`` = never restores).
    ``redispatch_delay`` models the broker's failure-detection + re-queue
    latency added to a killed task's ready time.  The autoscale
    thresholds gate the reactive rule: scale up while
    ``queue_depth > queue_threshold`` and
    ``busy_fraction >= busy_threshold``.

    The graceful-degradation knobs (DESIGN.md §11): ``deadline_policy``
    governs what the per-epoch hook does with per-task deadlines
    (``JobSpec.deadline``); ``deadline_slack`` widens the BOOST urgency
    window; ``preempt`` lets an urgent/higher-priority ready task evict a
    running lower-priority task on its space-shared VM (the PR-7
    failure-kill op sequence driven by a policy mask), and
    ``preempt_resume`` keeps the victim's partial progress instead of
    resetting it.  All defaults off: the degenerate configuration is a
    bitwise identity with the §10 closed loop.
    """
    policy: ControlPolicy = ControlPolicy.NONE
    failure_rate: float = 0.0
    failure_seed: int = 0
    repair_delay: float = math.inf
    redispatch_delay: float = 0.0
    queue_threshold: float = 0.0
    busy_threshold: float = 0.0
    deadline_policy: DeadlinePolicy = DeadlinePolicy.NONE
    deadline_slack: float = 0.0
    preempt: bool = False
    preempt_resume: bool = False


def failure_times(n_vms: int, *, rate: float, seed: int = 0,
                  repair_delay: float = math.inf
                  ) -> tuple[np.ndarray, np.ndarray]:
    """Per-VM failure/restore instants ``(F, R)`` (f32 arrays, host-side).

    Seeded and counter-based: VM ``v`` hashes ``(seed, v)`` through the
    storage layer's lowbias32 avalanche and inverts an exponential —
    ``F_v = -log1p(-u_v) / rate`` — so the stream is reproducible pure
    arithmetic (same idiom as block placement and arrivals) and rate
    scales it exactly: doubling ``rate`` exactly halves every failure
    time before the single f64→f32 cast.  ``rate <= 0`` yields the _BIG
    never-fires sentinel everywhere; so does an infinite repair for R.
    """
    if n_vms < 1:
        raise ValueError(f"failure_times: need n_vms >= 1, got {n_vms}")
    v = np.arange(int(n_vms), dtype=np.uint32)
    seed_mix = np.uint32((int(seed) % (1 << 32)) * int(_C3) % (1 << 32))
    h = _mix32(v * _C1 + seed_mix)
    u = (h >> np.uint32(8)).astype(np.float64) * float(_INV24)  # [0, 1)
    if not rate > 0.0:
        fail = np.full(n_vms, _BIG, np.float64)
    else:
        fail = -np.log1p(-u) / float(rate)
    rest = np.where(fail >= _BIG / 2, _BIG,
                    np.minimum(fail + float(repair_delay), _BIG))
    return fail.astype(np.float32), rest.astype(np.float32)


def failover_targets(task_vm, vm_valid, vm_auto, block_vm, xp=np):
    """Per-task failover VM (i32[T]) — the second binding slot.

    A killed task re-dispatches to the first VM cyclically after its
    bound VM that is (in preference order) a valid non-reserve replica
    holder of its input block, else any valid non-reserve VM, else any
    valid VM, else the bound VM itself.  Pure function of the encoded
    scenario (xp-generic: numpy for the oracle, jnp under trace), so the
    oracle and every engine layer resolve identical targets bit for bit.
    """
    task_vm = xp.asarray(task_vm)
    vm_valid = xp.asarray(vm_valid, bool)
    vm_auto = xp.asarray(vm_auto, bool)
    V = vm_valid.shape[0]
    vmr = xp.arange(V, dtype=xp.int32)[None, :]                   # [1, V]
    # cyclic preference: distance from bound-VM+1 (the bound VM is last)
    order = (vmr - task_vm[:, None].astype(xp.int32) - 1) % V     # [T, V]
    holds = xp.any(block_vm[:, :, None] == vmr[:, None, :], axis=1)
    valid = vm_valid[None, :]
    reserve = vm_auto[None, :]

    def pick(mask):
        key = xp.where(mask, order, V + 1)
        best = xp.argmin(key, axis=1).astype(xp.int32)
        ok = xp.min(key, axis=1) <= V
        return best, ok

    t1, ok1 = pick(valid & ~reserve & holds)
    t2, ok2 = pick(valid & ~reserve)
    t3, ok3 = pick(valid)
    out = xp.where(ok1, t1, xp.where(ok2, t2,
                   xp.where(ok3, t3, task_vm.astype(xp.int32))))
    return out.astype(xp.int32)


def scenario_control(scenario, pad_vms: int):
    """Realize one scenario's control model as padded per-VM arrays —
    ``(vm_fail, vm_restore, vm_auto)`` — the exact artifact both the
    oracle and the array encoders consume (one shared helper: the layers
    cannot drift).  Padding VMs never fail and are never reserves.
    """
    spec = scenario.control
    n = len(scenario.vms)
    vm_fail = np.full(pad_vms, _BIG, np.float32)
    vm_restore = np.full(pad_vms, _BIG, np.float32)
    vm_auto = np.zeros(pad_vms, bool)
    if spec.failure_rate > 0.0:
        f, r = failure_times(n, rate=spec.failure_rate,
                             seed=spec.failure_seed,
                             repair_delay=spec.repair_delay)
        vm_fail[:n], vm_restore[:n] = f, r
    vm_auto[:n] = [bool(getattr(v, "autoscale", False))
                   for v in scenario.vms]
    return vm_fail, vm_restore, vm_auto
