"""Sequential discrete-event reference simulator (the paper-faithful oracle).

This module mirrors IOTSim's entity structure (paper Figures 5–7) directly:

* :class:`IoTSimBroker`  — accepts multiple cloudlet lists and executes them
  *sequentially* (reduce list of a job only after its map list), the paper's
  §4.5 extension to CloudSim's single-list broker;
* :class:`JobTracker`    — splits a job into ``MapCloudlet``/``ReduceCloudlet``
  tasks, tracks map completion, triggers the shuffle and the reduce launch;
* :class:`TaskTracker`   — binds tasks to VMs (round-robin, as CloudSim's
  DatacenterBroker does) and reports status;
* the datacentre executes cloudlets under **time-shared** scheduling
  (CloudletSchedulerTimeShared): ``n`` concurrent 1-PE cloudlets on a VM with
  ``pes`` PEs at ``mips`` each run at ``mips * min(1, pes / n)``.

The event loop is a classic heapq calendar; processor-sharing completions are
computed lazily between calendar events (rates only change at arrivals and
completions, so the fluid dynamics are exact, not time-stepped).

This implementation is deliberately *sequential and simple*: it is the oracle
the vectorized JAX engine (``engine.py``) is tested against, and the
"paper-faithful baseline" row of EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import heapq
import itertools
import math
from dataclasses import dataclass, field

from . import network
from .config import Scenario

_EPS = 1e-9


# ---------------------------------------------------------------------------
# Task records
# ---------------------------------------------------------------------------

@dataclass
class Task:
    """One MapCloudlet or ReduceCloudlet instance."""
    job: int
    index: int                 # index within its job's phase
    is_reduce: bool
    length_mi: float           # work in MI
    vm: int = -1               # bound VM (round-robin at creation)
    ready: float = math.inf    # time the task may start (stage-in/shuffle done)
    start: float = math.inf
    finish: float = math.inf
    remaining: float = 0.0     # MI left (engine state)

    @property
    def exec_time(self) -> float:
        return self.finish - self.start


@dataclass
class JobResult:
    """Per-job dependent variables (paper §5.3).

    ``map_avg_exec`` / ``reduce_avg_exec`` split the paper's Average
    Execution Time into its two addends: the paper's Fig 9 percentages
    (≈40%/≈50%) are reproduced by the *map-phase* average (see
    EXPERIMENTS.md §Paper-validation).
    """
    avg_exec: float
    max_exec: float
    min_exec: float
    makespan: float
    delay_time: float
    vm_cost: float
    network_cost: float
    map_avg_exec: float = 0.0
    reduce_avg_exec: float = 0.0


@dataclass
class SimResult:
    tasks: list[Task]
    jobs: list[JobResult]
    finish_time: float
    n_events: int = 0

    def job(self, j: int = 0) -> JobResult:
        return self.jobs[j]


# ---------------------------------------------------------------------------
# Entities
# ---------------------------------------------------------------------------

class TaskTracker:
    """Binds tasks to VMs round-robin and tracks per-VM active sets."""

    def __init__(self, n_vms: int):
        self.n_vms = n_vms
        self._rr = 0
        self.active: list[set[int]] = [set() for _ in range(n_vms)]

    def bind(self, task: Task) -> None:
        task.vm = self._rr % self.n_vms
        self._rr += 1

    def launch(self, tid: int, task: Task) -> None:
        self.active[task.vm].add(tid)

    def complete(self, tid: int, task: Task) -> None:
        self.active[task.vm].discard(tid)


class JobTracker:
    """Splits jobs, watches map completion, triggers shuffle + reduce."""

    def __init__(self, scenario: Scenario):
        self.scenario = scenario
        self.maps_left = [j.n_maps for j in scenario.jobs]
        self.tasks: list[Task] = []
        self.map_ids: list[list[int]] = []
        self.reduce_ids: list[list[int]] = []
        for ji, job in enumerate(scenario.jobs):
            m_ids, r_ids = [], []
            for mi in range(job.n_maps):
                m_ids.append(len(self.tasks))
                self.tasks.append(Task(ji, mi, False,
                                       job.length_mi / job.n_maps))
            for ri in range(job.n_reduces):
                r_ids.append(len(self.tasks))
                self.tasks.append(Task(
                    ji, ri, True,
                    job.reduce_factor * job.length_mi / job.n_reduces))
            self.map_ids.append(m_ids)
            self.reduce_ids.append(r_ids)

    def map_finished(self, task: Task, now: float) -> float | None:
        """Returns the reduce-ready time if this was the job's last map."""
        self.maps_left[task.job] -= 1
        if self.maps_left[task.job] == 0:
            job = self.scenario.jobs[task.job]
            return now + network.shuffle_delay(job, self.scenario.network)
        return None


class IoTSimBroker:
    """Drives the simulation: sequential cloudlet lists per job (paper §4.5)."""

    def __init__(self, scenario: Scenario,
                 length_multipliers: list[float] | None = None):
        self.scenario = scenario
        self.jt = JobTracker(scenario)
        self.tt = TaskTracker(len(scenario.vms))
        # Bind every task round-robin in submission order: per job, the map
        # list is submitted first, then (later, after maps) the reduce list;
        # CloudSim's broker keeps one rolling VM pointer across submissions.
        for t in self.jt.tasks:
            self.tt.bind(t)
        if length_multipliers is not None:
            assert len(length_multipliers) == len(self.jt.tasks)
            for t, m in zip(self.jt.tasks, length_multipliers):
                t.length_mi *= m

    # ---- event-driven run ------------------------------------------------

    def run(self) -> SimResult:
        sc = self.scenario
        tasks = self.jt.tasks
        vms = sc.vms
        calendar: list[tuple[float, int, int]] = []   # (time, seq, task_id)
        seq = itertools.count()

        # Map tasks become ready at submit + stage-in delay.
        for ji, job in enumerate(sc.jobs):
            ready = job.submit_time + network.stage_in_delay(job, sc.network)
            for tid in self.jt.map_ids[ji]:
                tasks[tid].ready = ready
                heapq.heappush(calendar, (ready, next(seq), tid))

        for t in tasks:
            t.remaining = t.length_mi

        running: set[int] = set()
        now = 0.0
        n_events = 0

        def rate(tid: int) -> float:
            t = tasks[tid]
            n = len(self.tt.active[t.vm])
            vm = vms[t.vm]
            return vm.mips * min(1.0, vm.pes / n)

        while calendar or running:
            n_events += 1
            # Next completion under current processor-sharing rates.
            t_comp, comp_ids = math.inf, []
            for tid in running:
                eta = now + tasks[tid].remaining / rate(tid)
                if eta < t_comp - _EPS:
                    t_comp, comp_ids = eta, [tid]
                elif eta <= t_comp + _EPS:
                    comp_ids.append(tid)
            t_evt = calendar[0][0] if calendar else math.inf
            t_next = min(t_comp, t_evt)

            # Advance fluid state.
            for tid in running:
                tasks[tid].remaining -= (t_next - now) * rate(tid)
            now = t_next

            if t_comp <= t_evt:            # completions fire first
                for tid in comp_ids:
                    task = tasks[tid]
                    task.remaining = 0.0
                    task.finish = now
                    running.discard(tid)
                    self.tt.complete(tid, task)
                    if not task.is_reduce:
                        r_ready = self.jt.map_finished(task, now)
                        if r_ready is not None:
                            for rid in self.jt.reduce_ids[task.job]:
                                tasks[rid].ready = r_ready
                                heapq.heappush(calendar,
                                               (r_ready, next(seq), rid))
            else:                          # arrivals: task(s) become ready
                while calendar and calendar[0][0] <= now + _EPS:
                    _, _, tid = heapq.heappop(calendar)
                    task = tasks[tid]
                    task.start = now      # time-shared: starts immediately
                    self.tt.launch(tid, task)
                    running.add(tid)

        return SimResult(tasks=tasks, jobs=self._job_metrics(tasks),
                         finish_time=now, n_events=n_events)

    # ---- dependent variables (paper §5.3) ---------------------------------

    def _job_metrics(self, tasks: list[Task]) -> list[JobResult]:
        sc = self.scenario
        out = []
        for ji, job in enumerate(sc.jobs):
            maps = [tasks[i] for i in self.jt.map_ids[ji]]
            reds = [tasks[i] for i in self.jt.reduce_ids[ji]]
            met = (sum(t.exec_time for t in maps) / len(maps),
                   max(t.exec_time for t in maps),
                   min(t.exec_time for t in maps))
            ret = (sum(t.exec_time for t in reds) / len(reds),
                   max(t.exec_time for t in reds),
                   min(t.exec_time for t in reds))
            last_map = max(maps, key=lambda t: t.finish)
            last_red = max(reds, key=lambda t: t.finish)
            delay = (max(t.start for t in maps) + max(t.start for t in reds)
                     - last_map.finish)
            vm_cost = sum(t.exec_time * sc.vms[t.vm].cost_per_sec
                          for t in maps + reds)
            out.append(JobResult(
                avg_exec=met[0] + ret[0],
                max_exec=met[1] + ret[1],
                min_exec=met[2] + ret[2],
                makespan=last_red.finish - job.submit_time,
                delay_time=delay,
                vm_cost=vm_cost,
                network_cost=delay * sc.network.cost_per_unit
                if sc.network.enabled else 0.0,
                map_avg_exec=met[0],
                reduce_avg_exec=ret[0],
            ))
        return out


def simulate(scenario: Scenario,
             length_multipliers: list[float] | None = None) -> SimResult:
    """Run one scenario through the sequential reference simulator."""
    return IoTSimBroker(scenario, length_multipliers).run()
