"""Sequential discrete-event reference simulator (the paper-faithful oracle).

This module mirrors IOTSim's entity structure (paper Figures 5–7) directly:

* :class:`IoTSimBroker`  — accepts multiple cloudlet lists and executes them
  *sequentially* (reduce list of a job only after its map list), the paper's
  §4.5 extension to CloudSim's single-list broker;
* :class:`JobTracker`    — splits a job into ``MapCloudlet``/``ReduceCloudlet``
  tasks, tracks map completion, triggers the shuffle and the reduce launch;
* :class:`TaskTracker`   — binds tasks to VMs per the scenario's
  :class:`~repro.core.config.BindingPolicy` (round-robin as CloudSim's
  DatacenterBroker does, least-loaded, or locality-style packing) and
  manages per-VM execution slots;
* the datacentre executes cloudlets under the scenario's
  :class:`~repro.core.config.SchedPolicy`: **time-shared**
  (CloudletSchedulerTimeShared — ``n`` concurrent 1-PE cloudlets on a VM
  with ``pes`` PEs at ``mips`` each run at ``mips * min(1, pes / n)``) or
  **space-shared** (CloudletSchedulerSpaceShared — at most ``pes`` run at
  full ``mips``; the rest wait in a per-VM (ready, id)-ordered queue).

The event loop is a classic heapq calendar; processor-sharing completions are
computed lazily between calendar events (rates only change at arrivals and
completions, so the fluid dynamics are exact, not time-stepped).

This implementation is deliberately *sequential and simple*: it is the oracle
the vectorized JAX engine (``engine.py``) is tested against, and the
"paper-faithful baseline" row of EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import heapq
import itertools
import math
from dataclasses import dataclass, field

import numpy as np

from . import control, elasticity, network, storage, telemetry
from .config import (BindingPolicy, Scenario, SchedPolicy,
                     base_task_lengths_f32)
# the engine's masked-argmin fill: LOCALITY's candidate masking must use
# the exact value engine.bind_tasks uses or the two layers' f32 argmin
# sequences could diverge on a (pathological) load that reaches the fill
from .engine import _BIG

_EPS = 1e-9


# ---------------------------------------------------------------------------
# Task records
# ---------------------------------------------------------------------------

@dataclass
class Task:
    """One MapCloudlet or ReduceCloudlet instance."""
    job: int
    index: int                 # index within its job's phase
    is_reduce: bool
    length_mi: float           # work in MI
    vm: int = -1               # bound VM (round-robin at creation)
    ready: float = math.inf    # time the task may start (stage-in/shuffle done)
    start: float = math.inf
    finish: float = math.inf
    remaining: float = 0.0     # MI left (engine state)
    priority: float = 0.0      # space-shared admission priority (job-level)
    deadline: float = math.inf  # completion deadline (DESIGN.md §11),
    #                             f32-encoded like the engine's column
    shed: bool = False         # refused by deadline admission control
    n_evict: int = 0           # times preempted (capped at 2)

    @property
    def exec_time(self) -> float:
        return self.finish - self.start


@dataclass
class JobResult:
    """Per-job dependent variables (paper §5.3).

    ``map_avg_exec`` / ``reduce_avg_exec`` split the paper's Average
    Execution Time into its two addends: the paper's Fig 9 percentages
    (≈40%/≈50%) are reproduced by the *map-phase* average (see
    EXPERIMENTS.md §Paper-validation).
    """
    avg_exec: float
    max_exec: float
    min_exec: float
    makespan: float
    delay_time: float
    vm_cost: float
    network_cost: float
    map_avg_exec: float = 0.0
    reduce_avg_exec: float = 0.0


@dataclass
class SimResult:
    tasks: list[Task]
    jobs: list[JobResult]
    finish_time: float
    n_events: int = 0
    # closed-loop control counters (DESIGN.md §10; zero open-loop)
    failures_injected: int = 0
    tasks_redispatched: int = 0
    scale_events: int = 0
    recovered_fraction: float = 0.0
    # graceful-degradation counters (DESIGN.md §11; zero without
    # deadlines/preemption — parity-pinned against the engine's SLO layer)
    shed_tasks: int = 0
    preemptions: int = 0
    # event mirror (DESIGN.md §12): ``(t, kind, task, vm)`` rows in
    # simulation order, kinds from ``telemetry.EVENT_NAMES`` — the
    # engine's device-side event log must reduce to exactly these
    # counts (and timestamps, SHED excepted) per kind
    events: list = field(default_factory=list)

    def job(self, j: int = 0) -> JobResult:
        return self.jobs[j]


# ---------------------------------------------------------------------------
# Entities
# ---------------------------------------------------------------------------

class TaskTracker:
    """Binds tasks to VMs per the broker's binding policy and manages the
    per-VM execution state: active sets (both policies) and, under
    SPACE_SHARED, the (priority desc, eligible time, id)-ordered wait
    queues for the PE slots.  ``avail``/``close`` are the per-VM lease
    admission windows (DESIGN.md §8): tasks are admitted only at times
    ``t`` with ``avail[vm] <= t < close[vm]``.
    """

    def __init__(self, vms, sched_policy=SchedPolicy.TIME_SHARED,
                 binding_policy=BindingPolicy.ROUND_ROBIN,
                 avail=None, close=None):
        self.vms = tuple(vms)
        self.n_vms = len(self.vms)
        self.sched = SchedPolicy(sched_policy)
        self.binding = BindingPolicy(binding_policy)
        self.avail = (np.zeros(self.n_vms) if avail is None
                      else np.asarray(avail, float))
        self.close = (np.full(self.n_vms, math.inf) if close is None
                      else np.asarray(close, float))
        self._rr = 0
        # least-loaded bookkeeping: float32 on purpose — the vectorized
        # engine accumulates in f32, and both layers must pick the same VM
        self._load = np.zeros(self.n_vms, np.float32)
        # packed slots: [vm0]*pes0 ++ [vm1]*pes1 ++ ...
        self._slots = [vi for vi, vm in enumerate(self.vms)
                       for _ in range(int(vm.pes))]
        self.active: list[set[int]] = [set() for _ in range(self.n_vms)]
        self.queue: list[list[tuple[float, float, int]]] = \
            [[] for _ in range(self.n_vms)]

    def bind(self, task: Task, base_len: np.float32,
             cand: np.ndarray | None = None) -> None:
        """``base_len`` is the pre-multiplier task length computed with the
        f32 op sequence shared by every layer (see engine.bind_tasks);
        ``cand`` is LOCALITY's candidate-VM mask (replica holders of the
        task's input block; ``None`` — all VMs — degenerates the rule to
        LEAST_LOADED's exact argmin sequence)."""
        if self.binding in (BindingPolicy.LEAST_LOADED,
                            BindingPolicy.LOCALITY):
            masked = self._load
            if self.binding == BindingPolicy.LOCALITY and cand is not None:
                masked = np.where(cand, self._load, np.float32(_BIG))
            vm = int(np.argmin(masked))
            self._load[vm] += base_len / (np.float32(self.vms[vm].mips)
                                          * np.float32(self.vms[vm].pes))
        elif self.binding == BindingPolicy.PACKED:
            vm = self._slots[self._rr % len(self._slots)]
        else:
            vm = self._rr % self.n_vms
        task.vm = vm
        self._rr += 1

    def launch(self, tid: int, task: Task) -> None:
        self.active[task.vm].add(tid)

    def complete(self, tid: int, task: Task) -> None:
        self.active[task.vm].discard(tid)

    # ---- SPACE_SHARED slot management ------------------------------------

    def has_free_slot(self, vm: int) -> bool:
        return len(self.active[vm]) < int(self.vms[vm].pes)

    def eligible_at(self, task: Task) -> float:
        """Earliest admissible instant: data readiness joined with the
        bound VM's lease-open edge (the lease start *is* a calendar
        event — arrival events are scheduled at this time)."""
        return max(task.ready, self.avail[task.vm])

    def is_open(self, vm: int, t: float) -> bool:
        """The lease admits new tasks at ``t`` (strictly before close)."""
        return t < self.close[vm]

    def enqueue(self, tid: int, task: Task) -> None:
        heapq.heappush(self.queue[task.vm],
                       (-task.priority, self.eligible_at(task), tid))

    def admit(self, vm: int, now: float) -> int | None:
        """Pop the highest-priority queued task if a PE slot is free and
        the lease is still open; a closed lease strands its queue."""
        if self.queue[vm] and self.has_free_slot(vm) \
                and self.is_open(vm, now):
            return heapq.heappop(self.queue[vm])[2]
        return None


class JobTracker:
    """Splits jobs, watches map completion, triggers shuffle + reduce."""

    def __init__(self, scenario: Scenario):
        self.scenario = scenario
        self.maps_left = [j.n_maps for j in scenario.jobs]
        self.tasks: list[Task] = []
        self.map_ids: list[list[int]] = []
        self.reduce_ids: list[list[int]] = []
        for ji, job in enumerate(scenario.jobs):
            m_ids, r_ids = [], []
            # deadline encoded exactly like the engine's f32 column
            dl = float(np.float32(min(job.deadline, _BIG)))
            for mi in range(job.n_maps):
                m_ids.append(len(self.tasks))
                self.tasks.append(Task(ji, mi, False,
                                       job.length_mi / job.n_maps,
                                       priority=job.priority, deadline=dl))
            for ri in range(job.n_reduces):
                r_ids.append(len(self.tasks))
                self.tasks.append(Task(
                    ji, ri, True,
                    job.reduce_factor * job.length_mi / job.n_reduces,
                    priority=job.priority, deadline=dl))
            self.map_ids.append(m_ids)
            self.reduce_ids.append(r_ids)

    def map_finished(self, task: Task, now: float) -> float | None:
        """Returns the reduce-ready time if this was the job's last map."""
        self.maps_left[task.job] -= 1
        if self.maps_left[task.job] == 0:
            job = self.scenario.jobs[task.job]
            return now + network.shuffle_delay(job, self.scenario.network)
        return None


class IoTSimBroker:
    """Drives the simulation: sequential cloudlet lists per job (paper §4.5)."""

    def __init__(self, scenario: Scenario,
                 length_multipliers: list[float] | None = None):
        self.scenario = scenario
        self.jt = JobTracker(scenario)
        # Lease admission windows (DESIGN.md §8): avail = start + spinup,
        # close = stop — the same realized quantities the array encoders
        # carry as vm_start/vm_stop/spinup_delay.
        avail, close = elasticity.scenario_windows(scenario)
        self.tt = TaskTracker(scenario.vms, scenario.sched_policy,
                              scenario.binding_policy,
                              avail=avail, close=close)
        # Storage subsystem (DESIGN.md §7): the same realized placement
        # the array encoders consume (one shared helper — the layers
        # cannot drift), reshaped into per-task candidate masks.
        n_tasks = len(self.jt.tasks)
        n_vms = len(scenario.vms)
        self._cand: list[np.ndarray | None] = [None] * n_tasks
        bvm, self._block_mb = storage.scenario_placement(scenario, n_vms)
        for tid in range(n_tasks):
            holders = bvm[tid][bvm[tid] >= 0]
            if holders.size:
                mask = np.zeros(n_vms, bool)
                mask[holders] = True
                self._cand[tid] = mask
        # Bind every task in submission order: per job, the map list is
        # submitted first, then (later, after maps) the reduce list;
        # CloudSim's broker keeps one rolling VM pointer across submissions.
        # Base lengths for the load estimate use the shared f32 op sequence
        # (not the f64 task lengths) so binding matches the engine exactly.
        f32 = np.float32
        for tid, t in enumerate(self.jt.tasks):
            job = scenario.jobs[t.job]
            map_l, red_l = base_task_lengths_f32(
                f32(job.length_mi), f32(job.n_maps), f32(job.n_reduces),
                f32(job.reduce_factor))
            self.tt.bind(t, red_l if t.is_reduce else map_l,
                         cand=self._cand[tid])
        if length_multipliers is not None:
            if len(length_multipliers) != len(self.jt.tasks):
                raise ValueError(
                    f"length_multipliers: expected one entry per task "
                    f"({len(self.jt.tasks)}), got {len(length_multipliers)}"
                    f" — the multiplier list must match the scenario's "
                    f"task count (maps then reduces, per job)")
            for t, m in zip(self.jt.tasks, length_multipliers):
                t.length_mi *= m
        # Closed-loop control (DESIGN.md §10): the same realized failure
        # streams / reserve markers the array encoders consume, plus the
        # shared failover-target resolution against the block store and
        # the shared remote-fetch delay a moved task pays on its new VM.
        self._ctl = scenario.control
        self._policy = control.ControlPolicy(self._ctl.policy)
        vm_fail, vm_restore, vm_auto = control.scenario_control(
            scenario, n_vms)
        self._vm_fail = vm_fail.astype(np.float64)
        self._vm_restore = vm_restore.astype(np.float64)
        self._vm_auto = vm_auto
        task_vm = np.asarray([t.vm for t in self.jt.tasks], np.int32)
        self._task_vm2 = control.failover_targets(
            task_vm, np.ones(n_vms, bool), vm_auto, bvm, xp=np)
        self._refetch2 = np.asarray(storage.remote_fetch_delay(
            bvm, self._block_mb, self._task_vm2,
            np.float32(scenario.network.kappa_in),
            np.float32(scenario.network.bw_mbps),
            np.float32(1.0 if scenario.network.enabled else 0.0),
            xp=np), np.float64)
        # reserve VMs admit nothing until the control hook opens them
        self.tt.avail = np.where(vm_auto, math.inf, self.tt.avail)
        self._opened: set[int] = set()
        self._n_scale = 0
        # graceful degradation (DESIGN.md §11)
        self._dlpol = control.DeadlinePolicy(self._ctl.deadline_policy)
        self._dl_slack = np.float32(self._ctl.deadline_slack)
        self._preempt = bool(self._ctl.preempt)
        self._resume = bool(self._ctl.preempt_resume)
        self._n_preempt = 0

    # ---- event-driven run ------------------------------------------------

    def run(self) -> SimResult:
        sc = self.scenario
        tasks = self.jt.tasks
        vms = sc.vms
        # (time, seq, task_id, generation): the generation stamp makes
        # events *revocable* — a control action (failure re-dispatch,
        # reserve open) bumps the task's generation and re-pushes, so the
        # superseded calendar entry is skipped at pop time
        calendar: list[tuple[float, int, int, int]] = []
        events: list[tuple[float, int, int, int]] = []
        seq = itertools.count()
        gen = [0] * len(tasks)
        hit = [False] * len(tasks)

        def gate(x: float, vm: int) -> float:
            """The engine's failure-window gate: an instant inside the
            VM's down window [F, R) is deferred to the restore edge."""
            f, r = self._vm_fail[vm], self._vm_restore[vm]
            return r if f <= x < r else x

        def shed_at(tid: int, at: float) -> bool:
            """The engine's SHED predicate (DESIGN.md §11), same shared
            f32 op sequence: earliest possible finish at the bound VM's
            full per-PE rate already past the deadline."""
            task = tasks[tid]
            if self._dlpol != control.DeadlinePolicy.SHED \
                    or task.deadline >= _BIG / 2:
                return False
            efin = control.earliest_finish(
                np.float32(at), np.float32(task.remaining),
                np.float32(vms[task.vm].mips))
            return bool(efin > np.float32(task.deadline))

        def mark_shed(tid: int, at: float) -> None:
            """Shed once: orphan-reduce marking can re-touch a task
            already shed by admission control — only the first refusal
            is an event (the engine's ``new_shed`` edge mask)."""
            task = tasks[tid]
            if not task.shed:
                task.shed = True
                events.append((at, telemetry.EV_SHED, tid, task.vm))

        def urgent(tid: int) -> bool:
            """The engine's BOOST urgency predicate, evaluated at the
            current clock (pop time — urgency grows as slack shrinks)."""
            task = tasks[tid]
            if self._dlpol != control.DeadlinePolicy.BOOST \
                    or task.deadline >= _BIG / 2:
                return False
            efin = control.earliest_finish(
                np.float32(now), np.float32(task.remaining),
                np.float32(vms[task.vm].mips))
            return bool(efin + self._dl_slack >= np.float32(task.deadline))

        def push_arrival(tid: int) -> None:
            task = tasks[tid]
            if task.shed:
                return
            elig = gate(self.tt.eligible_at(task), task.vm)
            if not self.tt.is_open(task.vm, elig):
                return
            if shed_at(tid, elig):     # push-time admission control
                mark_shed(tid, elig)
                return
            heapq.heappush(calendar, (elig, next(seq), tid, gen[tid]))

        # Map tasks become ready at submit + stage-in delay (+ the storage
        # remote-fetch delay when bound off the input block's replica set).
        # The *arrival event* lands at the eligible time — readiness joined
        # with the bound VM's lease-open edge, so lease starts are calendar
        # events — and is never scheduled at all when it would fall at or
        # past the lease close (the task is stranded: finish stays inf).
        for ji, job in enumerate(sc.jobs):
            ready = job.submit_time + network.stage_in_delay(job, sc.network)
            for tid in self.jt.map_ids[ji]:
                cand = self._cand[tid]
                fetch = 0.0
                if cand is not None and not cand[tasks[tid].vm]:
                    fetch = network.transfer_delay(
                        sc.network.kappa_in, float(self._block_mb[tid]),
                        0.0, sc.network.bw_mbps,
                        1.0 if sc.network.enabled else 0.0)
                tasks[tid].ready = ready + fetch
                push_arrival(tid)

        for t in tasks:
            t.remaining = t.length_mi

        running: set[int] = set()
        now = 0.0
        n_events = 0
        space = self.tt.sched == SchedPolicy.SPACE_SHARED
        fail_pending = [v for v in range(self.tt.n_vms)
                        if self._vm_fail[v] < _BIG / 2]

        def rates() -> dict[int, float]:
            """Per-running-task rates — computed once per event epoch.

            Under SPACE_SHARED the slot gate keeps ``n <= pes``, so every
            running task owns a full PE at ``mips``; the time-shared fluid
            share degenerates to the same value, hence one formula.
            """
            out = {}
            for tid in running:
                t = tasks[tid]
                n = len(self.tt.active[t.vm])
                vm = vms[t.vm]
                out[tid] = vm.mips * min(1.0, vm.pes / n)
            return out

        def start_task(tid: int) -> None:
            task = tasks[tid]
            task.start = now
            self.tt.launch(tid, task)
            running.add(tid)
            events.append((now, telemetry.EV_START, tid, task.vm))

        def admit(vm: int) -> int | None:
            """Deadline-aware admission (DESIGN.md §11): pops the
            admission-order head, discarding queued tasks whose decision
            window closed while they waited (the engine's pop-time SHED
            check).  Under BOOST the heap key is stale — urgency is a
            function of the clock — so the head is a linear scan by
            (urgent desc, priority desc, eligible, id); with no BOOST
            lanes this is exactly ``TaskTracker.admit``."""
            q = self.tt.queue[vm]
            while q and self.tt.has_free_slot(vm) \
                    and self.tt.is_open(vm, now):
                if self._dlpol == control.DeadlinePolicy.BOOST:
                    i = min(range(len(q)),
                            key=lambda j: (not urgent(q[j][2]),) + q[j])
                    tid = q.pop(i)[2]
                else:
                    tid = heapq.heappop(q)[2]
                if shed_at(tid, now):
                    mark_shed(tid, now)
                    continue
                return tid
            return None

        def evict(tid: int) -> None:
            """Preempt a running task — the §10 failure-kill op
            sequence driven by the policy mask: progress reset (kept
            under preempt_resume), re-dispatch latency, first hit moves
            to the failover slot and pays the re-replication fetch."""
            task = tasks[tid]
            events.append((now, telemetry.EV_PREEMPT, tid, task.vm))
            task.n_evict += 1
            self._n_preempt += 1
            running.discard(tid)
            self.tt.complete(tid, task)
            if not self._resume:
                task.remaining = task.length_mi
            task.start = math.inf
            task.ready = max(task.ready, now + self._ctl.redispatch_delay)
            if not hit[tid]:
                hit[tid] = True
                task.vm = int(self._task_vm2[tid])
                task.ready += float(self._refetch2[tid])
            gen[tid] += 1
            if task.ready < math.inf:
                push_arrival(tid)

        def preempt_pass() -> None:
            """The engine's per-epoch eviction rule, event-wise: on each
            full space-shared VM, while a queued (non-shed) task's raw
            priority strictly beats the weakest still-evictable running
            task (lowest priority, latest index), that victim loses its
            PE and the admission-order head takes it.  Runs after every
            event batch — the running set only changes at events."""
            if not self._preempt or not space:
                return
            for vm in range(self.tt.n_vms):
                while self.tt.queue[vm] and self.tt.is_open(vm, now) \
                        and not self.tt.has_free_slot(vm):
                    vics = [t for t in self.tt.active[vm]
                            if tasks[t].n_evict < 2]
                    if not vics:
                        break
                    v = min(vics, key=lambda t: (tasks[t].priority, -t))
                    if not any(tasks[e[2]].priority > tasks[v].priority
                               and not shed_at(e[2], now)
                               for e in self.tt.queue[vm]):
                        break
                    evict(v)
                    qid = admit(vm)
                    if qid is None:
                        break
                    start_task(qid)

        def control_hook() -> None:
            """The engine's per-epoch control rule, event-wise: evaluated
            at the top of every loop iteration at the current clock (the
            engine evaluates at ``c.time`` before stepping to the next
            event), opening one reserve per evaluation while both
            thresholds are exceeded and closing drained opened reserves.
            ``NONE`` makes this a no-op — the open-loop path is
            untouched."""
            if self._policy != control.ControlPolicy.AUTOSCALE:
                return
            # close opened reserves with no unfinished bound tasks
            # (shed tasks are out of the system: refused backlog neither
            # holds a reserve open nor counts toward scaling pressure)
            for v in sorted(self._opened):
                if now < self.tt.close[v] and not any(
                        t.finish == math.inf and not t.shed and t.vm == v
                        for t in tasks):
                    self.tt.close[v] = now
                    self._n_scale += 1
                    events.append((now, telemetry.EV_SCALE_CLOSE, -1, v))
            qdepth = sum(1 for t in tasks
                         if t.finish == math.inf and t.start == math.inf
                         and not t.shed and t.ready <= now)
            open_vms = [v for v in range(self.tt.n_vms)
                        if self.tt.avail[v] <= now < self.tt.close[v]]
            busy = sum(1 for v in open_vms if self.tt.active[v])
            busy_frac = busy / max(len(open_vms), 1)
            if qdepth > self._ctl.queue_threshold \
                    and busy_frac >= self._ctl.busy_threshold:
                unopened = [v for v in range(self.tt.n_vms)
                            if self._vm_auto[v] and v not in self._opened]
                if unopened:
                    v = unopened[0]        # lowest index first, one/epoch
                    self._opened.add(v)
                    self.tt.avail[v] = now + sc.elasticity.spinup_delay
                    self._n_scale += 1
                    events.append((now, telemetry.EV_SCALE_OPEN, -1, v))
                    # the lease edge re-arms pending arrivals bound here
                    for tid, t in enumerate(tasks):
                        if t.finish == math.inf and t.start == math.inf \
                                and t.vm == v and t.ready < math.inf:
                            gen[tid] += 1
                            push_arrival(tid)

        def fire_failure(v: int) -> None:
            """Kill + re-dispatch every unfinished task whose *current*
            VM is ``v`` (running, queued, or still pending — the engine's
            ``affected`` mask): work restarts from scratch, readiness is
            pushed past the broker's detection latency, and the first hit
            moves the task to its precomputed failover VM, paying the
            shared remote-fetch delay to re-replicate its input block."""
            tf = self._vm_fail[v]
            rd = self._ctl.redispatch_delay
            self.tt.queue[v].clear()
            for tid, task in enumerate(tasks):
                if task.finish < math.inf or task.shed or task.vm != v:
                    continue
                events.append((tf, telemetry.EV_KILL, tid, v))
                if tid in running:
                    running.discard(tid)
                    self.tt.complete(tid, task)
                task.remaining = task.length_mi
                task.start = math.inf
                task.ready = max(task.ready, tf + rd)
                if not hit[tid]:
                    hit[tid] = True
                    task.vm = int(self._task_vm2[tid])
                    task.ready += float(self._refetch2[tid])
                gen[tid] += 1
                if task.ready < math.inf:
                    push_arrival(tid)

        while calendar or running:
            n_events += 1
            control_hook()
            r = rates()
            # Next completion under current processor-sharing rates.
            t_comp, comp_ids = math.inf, []
            for tid in running:
                eta = now + tasks[tid].remaining / r[tid]
                if eta < t_comp - _EPS:
                    t_comp, comp_ids = eta, [tid]
                elif eta <= t_comp + _EPS:
                    comp_ids.append(tid)
            t_evt = calendar[0][0] if calendar else math.inf
            t_fail = min((self._vm_fail[v] for v in fail_pending),
                         default=math.inf)
            t_next = min(t_comp, t_evt, t_fail)

            # Advance fluid state.
            for tid in running:
                tasks[tid].remaining -= (t_next - now) * r[tid]
            now = t_next

            if t_comp <= min(t_evt, t_fail):   # completions win all ties
                for tid in comp_ids:
                    task = tasks[tid]
                    task.remaining = 0.0
                    task.finish = now
                    events.append((now, telemetry.EV_FINISH, tid, task.vm))
                    running.discard(tid)
                    self.tt.complete(tid, task)
                    if not task.is_reduce:
                        r_ready = self.jt.map_finished(task, now)
                        if r_ready is not None:
                            for rid in self.jt.reduce_ids[task.job]:
                                tasks[rid].ready = r_ready
                                push_arrival(rid)
                    # freed PE slot -> admit the next queued task (only
                    # while the VM's lease is still open)
                    if space:
                        qid = admit(task.vm)
                        if qid is not None:
                            start_task(qid)
            elif t_fail <= t_evt:          # failures next: kills beat
                for v in [v for v in fail_pending    # same-instant starts
                          if self._vm_fail[v] <= now + _EPS]:
                    fail_pending.remove(v)
                    fire_failure(v)
            else:                          # arrivals: task(s) become ready
                # Space-shared arrivals pool through the per-VM wait queue
                # even when a slot is free: simultaneous arrivals must be
                # admitted in (priority desc, eligible, id) order — the
                # engine ranks all tied-eligible tasks in one epoch — not
                # in calendar pop order.
                arrived_vms = set()
                while calendar and calendar[0][0] <= now + _EPS:
                    _, _, tid, g = heapq.heappop(calendar)
                    task = tasks[tid]
                    if g != gen[tid] or task.shed or task.start < math.inf \
                            or task.finish < math.inf:
                        continue           # superseded by a control action
                    if space:
                        self.tt.enqueue(tid, task)
                        arrived_vms.add(task.vm)
                    else:
                        if shed_at(tid, now):
                            mark_shed(tid, now)
                        else:
                            start_task(tid)
                for vm in arrived_vms:
                    while (qid := admit(vm)) is not None:
                        start_task(qid)
            # preemption runs after every event batch at the current
            # clock — exactly the engine's in-epoch eviction instant
            preempt_pass()

        # Closed-form tail sheds (the engine keeps evaluating pending
        # tasks each epoch; the calendar stops producing pop-time checks
        # once no slot ever frees again): any schedulable never-started
        # task whose window closed by the final clock is shed, and
        # reduces of a job with a shed map can never be released.
        if self._dlpol == control.DeadlinePolicy.SHED:
            for tid, task in enumerate(tasks):
                if task.shed or task.start < math.inf \
                        or task.finish < math.inf:
                    continue
                at = gate(max(self.tt.eligible_at(task), now), task.vm)
                if self.tt.is_open(task.vm, at) and shed_at(tid, at):
                    mark_shed(tid, at)
            for ji in range(len(sc.jobs)):
                if any(tasks[t].shed for t in self.jt.map_ids[ji]):
                    for rid in self.jt.reduce_ids[ji]:
                        if tasks[rid].finish == math.inf:
                            mark_shed(rid, now)

        n_hit = sum(hit)
        n_rec = sum(1 for tid, h in enumerate(hit)
                    if h and tasks[tid].finish < math.inf)
        # makespan over the work the system kept: a shed task's arrival
        # can be the calendar's last event, but it completes nothing —
        # the engine's max-finish op sequence never sees it (and the
        # injected-failure census clocks against the same horizon)
        fin_t = max((t.finish for t in tasks if t.finish < math.inf),
                    default=0.0)
        injected = int(np.sum((self._vm_fail < _BIG / 2)
                              & (self._vm_fail <= fin_t)))
        return SimResult(tasks=tasks, jobs=self._job_metrics(tasks),
                         finish_time=fin_t, n_events=n_events,
                         failures_injected=injected,
                         tasks_redispatched=n_hit,
                         scale_events=self._n_scale,
                         recovered_fraction=n_rec / max(n_hit, 1),
                         shed_tasks=sum(1 for t in tasks if t.shed),
                         preemptions=self._n_preempt,
                         events=events)

    # ---- dependent variables (paper §5.3) ---------------------------------

    def _job_metrics(self, tasks: list[Task]) -> list[JobResult]:
        sc = self.scenario
        out = []
        for ji, job in enumerate(sc.jobs):
            maps = [tasks[i] for i in self.jt.map_ids[ji]]
            reds = [tasks[i] for i in self.jt.reduce_ids[ji]]
            met = (sum(t.exec_time for t in maps) / len(maps),
                   max(t.exec_time for t in maps),
                   min(t.exec_time for t in maps))
            ret = (sum(t.exec_time for t in reds) / len(reds),
                   max(t.exec_time for t in reds),
                   min(t.exec_time for t in reds))
            last_map = max(maps, key=lambda t: t.finish)
            last_red = max(reds, key=lambda t: t.finish)
            delay = (max(t.start for t in maps) + max(t.start for t in reds)
                     - last_map.finish)
            vm_cost = sum(t.exec_time * sc.vms[t.vm].cost_per_sec
                          for t in maps + reds)
            out.append(JobResult(
                avg_exec=met[0] + ret[0],
                max_exec=met[1] + ret[1],
                min_exec=met[2] + ret[2],
                makespan=last_red.finish - job.submit_time,
                delay_time=delay,
                vm_cost=vm_cost,
                network_cost=delay * sc.network.cost_per_unit
                if sc.network.enabled else 0.0,
                map_avg_exec=met[0],
                reduce_avg_exec=ret[0],
            ))
        return out


def simulate(scenario: Scenario,
             length_multipliers: list[float] | None = None) -> SimResult:
    """Run one scenario through the sequential reference simulator."""
    return IoTSimBroker(scenario, length_multipliers).run()
