"""Small shared numeric utilities (host-side, numpy).

``pow2_pad``/``pow2_pads`` are the one shape-rounding rule every layer
of the adaptive schedule uses — bucket task/VM paddings (``sweep``),
compacted active-lane counts (``engine.simulate_batch_arrays_compact``,
``kernels.mr_sched.ops``), and the cost model's candidate partitions
(``costmodel``).  Hoisted here because the measured-cost bucket scorer
evaluates many candidate partitions per plan, which made the original
per-unique-value Python loop a hot spot.
"""
from __future__ import annotations

import numpy as np

# floor * 2**j ladder, precomputed far past any realistic padding; the
# table form makes the vectorized rounding exact (no float log2 edge
# cases at exact powers of two)
_MAX_DOUBLINGS = 50


def validate_pow2_floor(floor: int) -> int:
    """Reject nonsensical padding floors with ``ValueError``.

    The ``floor * 2**j`` ladder only makes sense for a positive
    power-of-two floor: zero/negative floors collapse the table to
    garbage (every pad rounds to 0) and a non-pow2 floor silently
    produces pads like 24 that defeat the compile-cache-friendly shape
    set the rounding exists to guarantee.  Every entry point that
    accepts a ``floor=`` kwarg funnels through here so the failure is
    loud at the call site, not downstream in a shape mismatch."""
    f = int(floor)
    if f < 1 or (f & (f - 1)) != 0:
        raise ValueError(
            f"pow2 padding floor must be a positive power of two, got "
            f"{floor!r}")
    return f


def pow2_pads(need, cap: int, floor: int = 4) -> np.ndarray:
    """Vectorized :func:`pow2_pad`: smallest ``floor * 2**j >= need``
    elementwise, clamped to ``cap``.  ``need`` may be any integer array;
    entries ``<= floor`` round to ``floor``, entries past ``cap`` clamp
    to ``cap`` (the grid-wide max or an explicit pad override)."""
    floor = validate_pow2_floor(floor)
    need = np.asarray(need, np.int64)
    table = floor * (np.int64(1) << np.arange(_MAX_DOUBLINGS, dtype=np.int64))
    idx = np.searchsorted(table, np.maximum(need, 1), side="left")
    return np.minimum(table[np.minimum(idx, _MAX_DOUBLINGS - 1)],
                      np.int64(cap))


def pow2_pad(need: int, cap: int, floor: int = 4) -> int:
    """Smallest of ``{floor, 2*floor, 4*floor, ...}`` that fits ``need``,
    clamped to ``cap``.  Power-of-two rounding keeps the set of compiled
    shapes small and stable across differently-composed grids/batches
    (compile-cache friendly)."""
    return int(pow2_pads(np.asarray([need]), cap, floor)[0])
