"""Cloud elasticity: VM lease windows, arrival processes, pay-as-you-go.

IOTSim's pitch is evaluating IoT big-data workloads on *pay-as-you-go*
cloud infrastructure — yet a static fleet (every VM exists for all time
and costs nothing) reduces the cloud to a fixed cluster.  This module
holds the three primitives that make fleet dynamics *data* (DESIGN.md
§8), threaded through all four execution layers like policies (§3) and
storage (§7) before it:

* **Lease windows** — every VM carries ``[lease_start, lease_stop)``
  plus a cluster-wide ``spinup_delay``: the VM accepts task admissions
  only inside ``[lease_start + spinup_delay, lease_stop)``.  Admission
  gating — not preemption: a task admitted before the lease closes runs
  to completion (the cloud does not kill your in-flight work when the
  lease lapses; it stops accepting new work).  A pending task whose
  eligible time falls at or past its VM's close is *stranded*: it never
  starts (``finish`` stays at the +inf stand-in) — the simulator's
  analogue of submitting against a torn-down fleet.

* **Arrival processes** — seeded inter-arrival generation built on the
  storage subsystem's counter-hash idiom (`storage._mix32`): no RNG
  state, just uint32 avalanche of ``(seed, k)``, so arrival streams are
  pure arithmetic on sweepable scalars and bit-reproducible between the
  host planner and any future device-side generation.

* **Pay-as-you-go billing** — the realized lease of each VM, rounded
  *up* to the provider's billing granularity, priced at the VM's
  ``cost_per_sec``.  The shared formula lives here so the engine's
  ``billed_cost`` metric and the tests' refsim cross-checks cannot
  drift.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass

import numpy as np

from .storage import _C1, _C3, _mix32

_BIG = 1e30     # the engine's +inf stand-in (survives f32 arithmetic)


@dataclass(frozen=True)
class ElasticitySpec:
    """Scenario-level elasticity knobs (the per-VM lease window itself
    lives on :class:`~repro.core.config.VMSpec`).

    ``spinup_delay`` models VM boot/image-provisioning time: a leased VM
    accepts admissions only from ``lease_start + spinup_delay`` (billing
    still runs from ``lease_start`` — you pay while the image boots).
    ``billing_granularity`` is the provider's charge unit in seconds
    (per-second billing = 1.0, per-hour = 3600.0); realized lease time
    is rounded up to a multiple of it.
    """
    spinup_delay: float = 0.0
    billing_granularity: float = 1.0


class ArrivalProcess(enum.IntEnum):
    """Inter-arrival process family (stable wire constants).

    POISSON — exponential gaps ``-ln(1 - u) / rate`` (memoryless M/·/·
        offered load, the queueing-theory default).
    UNIFORM — gaps ``2 u / rate`` (same mean ``1/rate``, bounded).
    BURST   — ``burst`` arrivals land together, bursts spaced
        ``burst / rate`` apart (same mean rate, maximally clumped —
        the IoT sensor-flush pattern).
    """
    POISSON = 0
    UNIFORM = 1
    BURST = 2


def as_arrival_process(v) -> ArrivalProcess:
    """Coerce a name (``"poisson"``/``"uniform"``/``"burst"``), int, or
    member."""
    if isinstance(v, str):
        try:
            return ArrivalProcess[v.upper()]
        except KeyError:
            raise ValueError(
                f"unknown arrival process {v!r}; known: "
                f"{[p.name.lower() for p in ArrivalProcess]}") from None
    return ArrivalProcess(v)


_INV24 = np.float32(1.0 / (1 << 24))


def arrival_times(n: int, *, rate: float, process=ArrivalProcess.POISSON,
                  seed: int = 0, burst: int = 4) -> np.ndarray:
    """``n`` absolute arrival instants (f32, ascending, first gap counts).

    Seeded and counter-based — draw ``k`` hashes ``(seed, k)`` through
    the storage layer's lowbias32 avalanche, so streams are reproducible
    pure arithmetic (same idiom as block placement, DESIGN.md §7.1) and
    two plans with the same ``(n, rate, process, seed)`` see the same
    offered load.  ``rate`` is arrivals per simulated second; gaps are
    cumulative-summed in float64 then cast once to f32, so long streams
    do not accumulate rounding.
    """
    if n < 1:
        raise ValueError(f"arrival_times: need n >= 1, got {n}")
    if not rate > 0.0:
        raise ValueError(f"arrival_times: rate must be > 0, got {rate}")
    process = as_arrival_process(process)
    k = np.arange(n, dtype=np.uint32)
    # seed term mixed in Python-int space: scalar uint32 overflow warns in
    # numpy while array ops wrap silently (same dance as storage._mix32)
    seed_mix = np.uint32((int(seed) % (1 << 32)) * int(_C3) % (1 << 32))
    h = _mix32(k * _C1 + seed_mix)
    u = (h >> np.uint32(8)).astype(np.float64) * float(_INV24)  # [0, 1)
    if process == ArrivalProcess.POISSON:
        gaps = -np.log1p(-u) / rate
    elif process == ArrivalProcess.UNIFORM:
        gaps = 2.0 * u / rate
    else:                                   # BURST
        if burst < 1:
            raise ValueError(f"arrival_times: burst must be >= 1, "
                             f"got {burst}")
        gaps = np.where(k % np.uint32(burst) == 0, burst / rate, 0.0)
    return np.cumsum(gaps).astype(np.float32)


def billed_lease(vm_start, vm_stop, busy_end, finish_time, granularity,
                 xp=np):
    """Per-VM billed seconds under pay-as-you-go (xp-generic: numpy for
    the oracle-side checks, jnp inside ``engine.scenario_metrics``).

    The *realized* lease runs from ``vm_start`` to:

    * ``finish_time`` (the scenario's wall-clock end) when the lease is
      open-ended (``vm_stop`` at/above the +inf stand-in — the broker
      releases surviving VMs when the workload drains), or
    * ``max(vm_stop, busy_end)`` for a finite lease — you pay to your
      declared teardown time even if the VM idles (including a lease
      scheduled entirely after the workload drains: the window was
      committed, so it bills), and past it while admitted work is still
      draining (admission gating never kills in-flight tasks, so
      neither does billing).

    Realized time is clamped at 0 — this only triggers for *open-ended*
    leases whose start falls beyond the scenario's end — and rounded up
    to ``granularity``.  Pure arithmetic — callers multiply by per-VM
    cost rates and mask invalid VMs.
    """
    end = xp.where(vm_stop >= _BIG / 2, finish_time,
                   xp.maximum(vm_stop, busy_end))
    dur = xp.maximum(end - vm_start, 0.0)
    g = xp.maximum(granularity, 1e-9)
    return xp.ceil(dur / g) * g


def encode_lease_stop(stop) -> float:
    """User-facing ``math.inf`` lease stops, clamped to the engine's
    arithmetic-safe +inf stand-in (``inf`` would NaN the kernel's
    one-hot gathers: ``0 * inf``)."""
    return float(min(stop, _BIG)) if stop is not None else _BIG


def scenario_windows(scenario):
    """``(avail, close)`` per VM (f64 numpy) for the sequential oracle:
    admission opens at ``lease_start + spinup_delay``, closes at
    ``lease_stop``.  The f32-sensitive layers encode the same quantities
    through :func:`~repro.core.engine.from_scenario`."""
    el = scenario.elasticity
    avail = np.array([v.lease_start + el.spinup_delay
                      for v in scenario.vms])
    close = np.array([encode_lease_stop(v.lease_stop)
                      for v in scenario.vms])
    return avail, close
