"""Post-compilation HLO introspection: collective inventory + byte counts.

``cost_analysis()`` does not report collective traffic, so we parse the
optimized HLO text: every ``all-gather`` / ``all-reduce`` /
``reduce-scatter`` / ``all-to-all`` / ``collective-permute`` instruction,
summing *operand* bytes (the assignment's definition) and also recording
result bytes + replica-group size so the roofline can apply per-algorithm
wire multipliers (ring all-reduce moves 2·(k−1)/k · bytes, etc.).

Instructions inside ``while`` bodies (scan-over-layers) appear once; the
roofline extractor corrects trip counts by depth-variant differencing
(EXPERIMENTS.md §Roofline methodology).
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(?:\()?([a-z0-9]+)\[([\d,]*)\]")
_COLL = re.compile(
    r"=\s*(?:\(.*?\)|[a-z0-9]+\[[\d,]*\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_stats(hlo_text: str) -> dict:
    """Returns {op_kind: {"count", "operand_bytes", "result_bytes",
    "wire_bytes"}} summed over all collective instructions."""
    sizes: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _INSTR.match(line)
        if m:
            sizes[m.group(1)] = _shape_bytes(m.group(2), m.group(3))

    out: dict[str, dict] = defaultdict(
        lambda: {"count": 0, "operand_bytes": 0, "result_bytes": 0,
                 "wire_bytes": 0.0})
    for line in hlo_text.splitlines():
        cm = _COLL.search(line)
        if not cm:
            continue
        kind = cm.group(1)
        im = _INSTR.match(line)
        result_b = _shape_bytes(im.group(2), im.group(3)) if im else 0
        # operands: %names inside the first (...) after the opcode
        args = line[cm.end():line.find(")", cm.end())]
        operand_b = 0
        for name in re.findall(r"%?([\w.\-]+)", args):
            operand_b += sizes.get(name, 0)
        k = _group_size(line)
        rec = out[kind]
        rec["count"] += 1
        rec["operand_bytes"] += operand_b
        rec["result_bytes"] += result_b
        rec["wire_bytes"] += _wire_bytes(kind, operand_b, result_b, k)
    return dict(out)


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def _wire_bytes(kind: str, operand_b: int, result_b: int, k: int) -> float:
    """Per-device wire traffic under ring/bidirectional algorithms."""
    if kind == "collective-permute":     # point-to-point: no replica groups
        return float(operand_b)
    if k <= 1:
        return 0.0
    f = (k - 1) / k
    if kind == "all-gather":
        return f * result_b            # each device receives result minus own
    if kind == "all-reduce":
        return 2.0 * f * operand_b     # reduce-scatter + all-gather
    if kind == "reduce-scatter":
        return f * operand_b
    if kind == "all-to-all":
        return f * operand_b
    if kind == "collective-permute":
        return float(operand_b)
    return float(operand_b)


def totals(stats: dict) -> dict:
    return {
        "collective_count": sum(r["count"] for r in stats.values()),
        "collective_operand_bytes": sum(r["operand_bytes"]
                                        for r in stats.values()),
        "collective_wire_bytes": sum(r["wire_bytes"]
                                     for r in stats.values()),
    }
