"""Production mesh definitions.

A *function*, not a module-level constant: importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax
import; smoke tests and benches see the default single device).

Topology: TPU v5e pods, 256 chips each.

* single-pod:  (data=16, model=16)           — 256 chips
* multi-pod:   (pod=2, data=16, model=16)    — 512 chips, the "pod" axis
  carries pure data parallelism across the inter-pod (DCN) boundary.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> jax.sharding.Mesh:
    """Whatever devices exist locally, as a 1-D 'data' mesh (examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))
