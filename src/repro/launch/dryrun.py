import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay first: jax locks the device count on first
init, and the production meshes need 512 placeholder host devices.  Do
not set that flag anywhere global — smoke tests and benches see 1 device.

Per cell this driver:

1. builds abstract inputs (``configs.input_specs`` — ShapeDtypeStruct,
   no allocation) and resolves shardings (``repro.sharding.rules``);
2. lowers + compiles the cell's step function:
     train_*   → loss + grad + AdamW update (params/opt donated),
     prefill_* → prefill forward (logits + materialized KV/SSM state),
     decode_*  → one-token serve_step against the full-length state;
3. prints ``compiled.memory_analysis()`` (proves it fits) and
   ``cost_analysis()`` (FLOPs/bytes for §Roofline), parses the optimized
   HLO for collective traffic (``hlo_stats``);
4. appends everything to a JSON results file (incremental: re-runs skip
   completed cells) that ``benchmarks/roofline.py`` consumes.

``--variants`` additionally lowers depth-reduced variants (1 period / 0
periods) of each cell on the single-pod mesh: XLA counts a scanned layer
body once, so §Roofline derives F(L) = F_full + (periods−1)·(F(1)−F(0)).

Usage:
  python -m repro.launch.dryrun --all --variants --out dryrun.json
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k --multi-pod
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro import configs
from repro.launch import hlo_stats
from repro.launch.mesh import make_production_mesh
from repro.models import abstract_model, loss_fn, model_axes
from repro.models.model import decode_step, prefill
from repro.models.stacks import _pattern_period
from repro.sharding import rules
from repro.train import optimizer


# Perf toggles (see EXPERIMENTS.md §Perf). Baseline numbers in
# dryrun_baseline.json were taken with everything False.
PERF = {
    "bf16_params": True,     # bf16 compute-params: halve weight-gather wire
    "kv_seq_shard": True,    # flash-decoding cache layout
    "serve_no_fsdp": True,   # serving weights not data-sharded
    "fsdp2": False,          # train: pure-FSDP weights, no activation TP
}


def _cast_params(params):
    if not PERF["bf16_params"]:
        return params
    return jax.tree.map(
        lambda p: p.astype(jnp.bfloat16)
        if p.dtype == jnp.float32 else p, params)


def _serve_weight_rules(cfg, global_batch: int = 1 << 30):
    """Serving weights: replicating over `data` kills the per-step weight
    all-gathers — but only when it fits and amortizes.  Keep FSDP when
    (a) the batch doesn't occupy the data axis (long_500k: streaming the
    replicated weights per token costs more than gathering shards), or
    (b) the arch is MoE (total expert params de-replicated over data are
    what keeps 50-100B-total models inside 16 GiB; only top-k experts
    activate per token, so gathers stay proportional to *active* use)."""
    if not PERF["serve_no_fsdp"] or global_batch < 16 or cfg.moe is not None:
        return rules.WEIGHT_RULES
    r = dict(rules.WEIGHT_RULES)
    r.pop("embed", None)     # no optimizer in serving: replicate over data
    r.pop("embed2", None)
    return r


def _param_shardings(mesh, cfg, *, serve: bool = False,
                     global_batch: int = 1 << 30):
    sds = abstract_model(cfg)
    if serve and PERF["bf16_params"]:
        # serving keeps weights in bf16 (no optimizer): reading the f32
        # master + converting per step costs 3x the HBM traffic
        sds = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, jnp.bfloat16)
            if a.dtype == jnp.float32 else a, sds)
    rl = _serve_weight_rules(cfg, global_batch) if serve else (
        rules.WEIGHT_RULES_FSDP2 if PERF["fsdp2"] else rules.WEIGHT_RULES)
    return sds, rules.tree_shardings(mesh, model_axes(cfg), sds, rules=rl)





def _batch_axes_for(mesh):
    """Under FSDP2 the batch is data-parallel over every mesh axis."""
    if PERF["fsdp2"]:
        return tuple(mesh.axis_names)
    return rules.batch_axes(mesh)


def _batch_shardings(mesh, batch_sds):
    ba = _batch_axes_for(mesh)

    def spec(x):
        if x.shape and x.shape[0] % _prod(mesh, ba) == 0:
            return NamedSharding(mesh, PartitionSpec(ba))
        return NamedSharding(mesh, PartitionSpec())

    return jax.tree.map(spec, batch_sds)


def _prod(mesh, axes):
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def microbatches(cfg, spec, batch_shards: int = 16) -> int:
    """Gradient-accumulation depth per train step (memory knob: jamba's
    heterogeneous 8-block period holds the most live state).  Capped so
    each microbatch stays divisible by the (pod x data) shard extent —
    an indivisible microbatch would silently replicate activations."""
    if spec.kind != "train":
        return 1
    if cfg.family == "hybrid":
        n = 16
    elif cfg.moe is not None:
        n = 4
    else:
        n = 2
    return max(1, min(n, spec.global_batch // batch_shards))


def build_cell(cfg, shape_name: str, mesh):
    """Returns (fn, args_sds, in_shardings, donate) for one cell."""
    spec = configs.SHAPES[shape_name]
    ins = configs.input_specs(cfg, shape_name)

    if spec.kind == "train":
        params_sds, psh = _param_shardings(mesh, cfg)
        opt_sds = jax.eval_shape(optimizer.init, params_sds)
        osh = optimizer.OptState(
            step=NamedSharding(mesh, PartitionSpec()),
            m=jax.tree.map(lambda s: s, psh), v=jax.tree.map(lambda s: s, psh))
        bsh = _batch_shardings(mesh, ins["batch"])
        opt_cfg = optimizer.OptConfig(total_steps=10_000)
        n_micro = microbatches(cfg, spec, _prod(mesh, _batch_axes_for(mesh)))
        mb_ba = _batch_axes_for(mesh)

        act_rules = rules.ACT_RULES_FSDP2 if PERF["fsdp2"] else None

        def train_step(params, opt_state, batch):
            # scanned gradient accumulation (MaxText-style): activation
            # memory is bounded at one microbatch.  XLA counts the scan
            # body once — §Roofline multiplies the measured terms by
            # n_micro (the optimizer outside is negligible).
            with rules.mesh_ctx(mesh, act_rules):
                mbs = jax.tree.map(
                    lambda a: a.reshape(n_micro, a.shape[0] // n_micro,
                                        *a.shape[1:]), batch)
                mbs = jax.tree.map(
                    lambda a: jax.lax.with_sharding_constraint(
                        a, NamedSharding(mesh, PartitionSpec(
                            None, mb_ba, *[None] * (a.ndim - 2)))), mbs)

                params_c = _cast_params(params)

                def micro_step(carry, mb):
                    loss_acc, grads_acc = carry
                    li, gi = jax.value_and_grad(
                        lambda p: loss_fn(p, cfg, mb,
                                          attn_impl="chunked"))(params_c)
                    grads_acc = jax.tree.map(
                        lambda a, g: a + g.astype(jnp.float32),
                        grads_acc, gi)
                    return (loss_acc + li, grads_acc), None

                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (loss, grads), _ = jax.lax.scan(
                    micro_step, (jnp.float32(0.0), zeros), mbs)
                scale = 1.0 / n_micro
                grads = jax.tree.map(lambda g: g * scale, grads)
                params, opt_state, _ = optimizer.update(
                    opt_cfg, grads, opt_state, params)
            return params, opt_state, loss * scale

        return (train_step, (params_sds, opt_sds, ins["batch"]),
                (psh, osh, bsh), (0, 1))

    if spec.kind == "prefill":
        params_sds, psh = _param_shardings(mesh, cfg, serve=True,
                                           global_batch=spec.global_batch)
        bsh = _batch_shardings(mesh, ins["inputs"])
        cache_len = configs.decode_cache_len(cfg, spec.seq_len)

        def prefill_step(params, inputs):
            with rules.mesh_ctx(mesh):
                return prefill(_cast_params(params), cfg, inputs,
                               cache_len, attn_impl="chunked")

        return prefill_step, (params_sds, ins["inputs"]), (psh, bsh), ()

    # decode
    params_sds, psh = _param_shardings(mesh, cfg, serve=True,
                                       global_batch=spec.global_batch)
    st_sds = ins["state"]
    st_rules = rules.STATE_RULES if PERF["kv_seq_shard"] else rules.ACT_RULES
    st_sh = rules.tree_shardings(mesh, rules.state_axes(st_sds), st_sds,
                                 rules=st_rules)
    tok_sh = _batch_shardings(mesh, ins["tokens"])
    t_sh = NamedSharding(mesh, PartitionSpec())

    def serve_step(params, tokens, state, t):
        with rules.mesh_ctx(mesh):
            return decode_step(_cast_params(params), cfg, tokens, state, t)

    return (serve_step,
            (params_sds, ins["tokens"], st_sds, ins["t"]),
            (psh, tok_sh, st_sh, t_sh), (2,))


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             cfg_override=None, tag: str = "") -> dict:
    cfg = cfg_override or configs.get(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    fn, args, in_sh, donate = build_cell(cfg, shape_name, mesh)
    t0 = time.perf_counter()
    jitted = jax.jit(fn, in_shardings=in_sh, donate_argnums=donate)
    lowered = jitted.lower(*args)
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    colls = hlo_stats.collective_stats(txt)
    period = _pattern_period(cfg) if cfg.n_layers else []
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "tag": tag,
        "n_layers": cfg.n_layers,
        "period_len": len(period) or 1,
        "n_periods": (cfg.n_layers // len(period)) if period else 0,
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "code_bytes": ma.generated_code_size_in_bytes,
        },
        "collectives": colls,
        **hlo_stats.totals(colls),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
    }
    return rec


def depth_variants(cfg):
    """(tag, cfg) for the roofline depth correction: 1 period and 0."""
    period = len(_pattern_period(cfg))
    return [("L1", cfg.replace(n_layers=period)),
            ("L0", cfg.replace(n_layers=0))]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--variants", action="store_true",
                    help="also lower 1-period/0-period variants (roofline)")
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    cells: list[tuple[str, str]] = (
        configs.all_cells() if args.all else [(args.arch, args.shape)])
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    done: dict[str, dict] = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            done = json.load(f)

    failures = []
    for arch, shape in cells:
        for mp in meshes:
            jobs = [("full", None)]
            if args.variants and not mp:
                jobs += [(t, c) for t, c in
                         depth_variants(configs.get(arch))]
            for tag, cfg_over in jobs:
                key = f"{arch}|{shape}|{'2x16x16' if mp else '16x16'}|{tag}"
                if key in done:
                    continue
                print(f"[dryrun] {key} ...", flush=True)
                try:
                    rec = run_cell(arch, shape, multi_pod=mp,
                                   cfg_override=cfg_over, tag=tag)
                except Exception as e:  # noqa: BLE001 — report, keep going
                    traceback.print_exc()
                    failures.append((key, str(e)))
                    continue
                if not args.quiet:
                    print(f"  flops={rec['flops']:.3e} "
                          f"bytes={rec['bytes_accessed']:.3e} "
                          f"coll_wire={rec['collective_wire_bytes']:.3e} "
                          f"temp={rec['memory']['temp_bytes']/2**30:.2f}GiB "
                          f"compile={rec['compile_s']}s", flush=True)
                done[key] = rec
                with open(args.out, "w") as f:
                    json.dump(done, f, indent=1)

    print(f"[dryrun] completed {len(done)} records -> {args.out}")
    if failures:
        print("[dryrun] FAILURES:")
        for k, e in failures:
            print("  ", k, e)
        sys.exit(1)


if __name__ == "__main__":
    main()
