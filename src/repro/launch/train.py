"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs the fault-tolerant training loop on whatever devices the host
exposes (1-D data mesh), with reduced or full configs:

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --reduced \\
        --steps 50 --ckpt-dir /tmp/ck

Full configs on a real TPU pod use the same entry point — the sharding
rules, checkpointing and failure recovery are identical; only the mesh
and the config size change.  (The no-hardware validation path for full
configs is ``repro.launch.dryrun``.)
"""
from __future__ import annotations

import argparse

import jax

from repro import configs
from repro.train import OptConfig, TrainConfig, train


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.arch_names())
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-sized config of the same family")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = (cfg.reduced(n_layers=4, attn_every=4)
               if cfg.family == "hybrid" else cfg.reduced())
        cfg = cfg.replace(dtype="float32")
    print(f"arch={cfg.name} family={cfg.family} layers={cfg.n_layers} "
          f"d_model={cfg.d_model} devices={len(jax.devices())}")

    tc = TrainConfig(steps=args.steps, seed=args.seed, seq_len=args.seq_len,
                     global_batch=args.global_batch,
                     opt=OptConfig(lr=args.lr, warmup_steps=10),
                     ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)
    h = train(cfg, tc)
    print(f"steps={args.steps} resumed_at={h['resumed_at']} "
          f"restarts={h['restarts']} "
          f"loss {h['loss'][0]:.4f} -> {h['final_loss']:.4f}")


if __name__ == "__main__":
    main()
