"""Sharding rules: logical axes → mesh axes with divisibility fallback."""
from .rules import (ACT_RULES, WEIGHT_RULES, batch_axes, mesh_ctx,
                    set_mesh_ctx, shard_act, spec_for, state_axes,
                    tree_shardings)

__all__ = ["ACT_RULES", "WEIGHT_RULES", "batch_axes", "mesh_ctx",
           "set_mesh_ctx", "shard_act", "spec_for", "state_axes",
           "tree_shardings"]
