"""Divisibility-aware logical-axis sharding rules (FSDP × TP × SP).

Every tensor (params, activations, decode states) carries *logical* axis
names; this module resolves them to mesh axes:

* weights: ``embed → data`` (FSDP: ZeRO-sharded storage, gathered at use),
  ``mlp/inner/heads/vocab → model`` (tensor parallel), with ``head_dim`` as
  the fallback when a head count doesn't divide the model axis (llama4's
  40 heads on a 16-way axis);
* activations: ``batch → (pod, data)``, ``seq → model`` between blocks
  (sequence parallelism — the residual stream is the dominant live
  activation under remat, see DESIGN.md §5);
* decode states: KV caches shard batch × (kv_heads | head_dim | seq).

Resolution is *greedy by priority with divisibility checks*: each
candidate (dim, mesh_axis) pair gets a priority; we sort and assign,
skipping any pair whose dim size isn't divisible by the mesh axis or
whose mesh axis / tensor dim is already taken.  Tensors that fit no rule
stay replicated.  This is what guarantees ``.lower().compile()`` succeeds
for every (arch × shape × mesh) cell — sharding never fails, it degrades.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

# (mesh_axis, priority) candidates per logical axis; lower = stronger.
# "batch" expands to the (pod, data) super-axis at resolution time.
WEIGHT_RULES: dict[str, list[tuple[str, int]]] = {
    "vocab": [("model", 0)],
    "mlp": [("model", 1)],
    "inner": [("model", 1)],
    "heads": [("model", 2)],
    "kv_heads": [("model", 3)],
    "head_dim": [("model", 4)],
    "experts": [("model", 5)],          # engaged only if mlp/heads missed
    "embed": [("data", 6)],             # FSDP storage shard
    "embed2": [("data", 7)],
}

# decode/prefill state rules: cache *sequence* sharding beats head_dim —
# a head_dim-sharded cache forces an all-gather of the whole cache per
# step (the QK^T contraction is over head_dim); a seq-sharded cache only
# crosses shards in the tiny softmax reductions (flash-decoding layout).
STATE_RULES: dict[str, list[tuple[str, int]]] = {
    "batch": [("__batch__", 0)],
    "seq": [("model", 1)],
    "kv_heads": [("model", 2)],
    "head_dim": [("model", 3)],
    "heads": [("model", 2)],
    "inner": [("model", 2)],
    "embed": [("model", 9)],
}

# pure-FSDP training variant (§Perf): weights sharded over BOTH axes and
# gathered whole at use; activations batch-sharded only. Trades weight
# gathers (O(params)) for the TP activation gathers + dx all-reduces
# (O(tokens·d_model) per layer) — wins when tokens/device >> d_ff.
WEIGHT_RULES_FSDP2: dict[str, list[tuple[str, int]]] = {
    "embed": [(("data", "model"), 0)],
    "mlp": [(("data", "model"), 1)],
    "inner": [(("data", "model"), 1)],
    "vocab": [(("data", "model"), 2)],
    "experts": [(("data", "model"), 3)],
}

ACT_RULES_FSDP2: dict[str, list[tuple[str, int]]] = {
    "batch": [("__all__", 0)],     # DP over every mesh axis: the model
    "vocab": [("model", 1)],       # axis must not sit idle for compute
}

ACT_RULES: dict[str, list[tuple[str, int]]] = {
    "batch": [("__batch__", 0)],        # (pod, data) super-axis
    "heads": [("model", 1)],
    "kv_heads": [("model", 2)],
    "head_dim": [("model", 3)],
    "vocab": [("model", 1)],
    "mlp": [("model", 4)],
    "inner": [("model", 4)],
    "seq": [("model", 8)],              # SP: last resort for states,
    "embed": [("model", 9)],            # boundary constraint for resid
}


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh: Mesh, axis) -> int:
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def spec_for(mesh: Mesh, shape: tuple, axes: tuple,
             rules: dict[str, list[tuple[str, int]]]) -> PartitionSpec:
    """Resolve one tensor's logical axes to a PartitionSpec."""
    assert len(shape) == len(axes), (shape, axes)
    cands = []
    for dim, name in enumerate(axes):
        if name is None:
            continue
        for mesh_axis, prio in rules.get(name, []):
            if mesh_axis == "__batch__":
                real = batch_axes(mesh)
            elif mesh_axis == "__all__":
                real = tuple(mesh.axis_names)
            else:
                real = mesh_axis
            if isinstance(real, str) and real not in mesh.axis_names:
                continue
            if not real:
                continue
            if isinstance(real, tuple) and len(real) == 1:
                real = real[0]      # 1-tuple != bare axis in PartitionSpec
            cands.append((prio, dim, real))
    cands.sort(key=lambda c: c[0])
    assignment: dict[int, object] = {}
    used: set[str] = set()
    for prio, dim, real in cands:
        flat = set(real) if isinstance(real, tuple) else {real}
        if dim in assignment or (flat & used):
            continue
        if shape[dim] % _axis_size(mesh, real) != 0:
            continue
        assignment[dim] = real
        used |= flat
    return PartitionSpec(*(assignment.get(d) for d in range(len(shape))))


def tree_shardings(mesh: Mesh, axes_tree, shape_tree, *,
                   rules=None):
    """NamedSharding pytree for (axes_tree, shape_tree) pairs."""
    rules = rules or WEIGHT_RULES
    flat_axes, treedef = jax.tree.flatten(
        axes_tree, is_leaf=lambda x: isinstance(x, tuple))
    flat_shapes = treedef.flatten_up_to(shape_tree)
    out = []
    for ax, sd in zip(flat_axes, flat_shapes):
        out.append(NamedSharding(
            mesh, spec_for(mesh, tuple(sd.shape), ax, rules)))
    return treedef.unflatten(out)


# ---------------------------------------------------------------------------
# Activation-constraint context (used inside model code; no-op off-mesh)
# ---------------------------------------------------------------------------

_CTX: dict | None = None


def set_mesh_ctx(mesh: Mesh | None, rules=None):
    global _CTX
    _CTX = None if mesh is None else {"mesh": mesh,
                                      "rules": rules or ACT_RULES}


class mesh_ctx:
    """``with mesh_ctx(mesh): ...`` enables activation constraints."""

    def __init__(self, mesh, rules=None):
        self.mesh, self.rules = mesh, rules

    def __enter__(self):
        self._prev = _CTX
        set_mesh_ctx(self.mesh, self.rules)

    def __exit__(self, *exc):
        global _CTX
        _CTX = self._prev


def shard_act(x, axes: tuple):
    """Constrain an activation to its logical-axis sharding (no-op when no
    mesh context is active — single-device tests never see collectives)."""
    if _CTX is None:
        return x
    spec = spec_for(_CTX["mesh"], tuple(x.shape), axes, _CTX["rules"])
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_CTX["mesh"], spec))


# ---------------------------------------------------------------------------
# Decode-state logical axes (path-pattern based)
# ---------------------------------------------------------------------------

_STATE_PATTERNS = [
    # (suffix key name, rank) -> logical axes
    ("k", 4, ("batch", "seq", "kv_heads", "head_dim")),
    ("v", 4, ("batch", "seq", "kv_heads", "head_dim")),
    ("slot_pos", 1, ("seq",)),
    ("h", 3, ("batch", "inner", "state")),
    ("conv", 3, ("batch", None, "inner")),
    ("s", 4, ("batch", "heads", "head_dim", None)),
    ("x_tmix", 2, ("batch", "embed")),
    ("x_cmix", 2, ("batch", "embed")),
    ("mlp", 2, ("batch", "embed")),      # cmix token-shift state
]


def state_axes(state_tree):
    """Logical axes for a decode-state pytree (leading 'layers' dim added
    for the stacked-period dimension)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(state_tree)
    out = []
    for path, leaf in flat:
        key = None
        for p in reversed(path):
            if hasattr(p, "key"):
                key = p.key
                break
        rank = leaf.ndim
        match = None
        for name, r, ax in _STATE_PATTERNS:
            if key == name and rank == r + 1:      # +1: stacked periods
                match = ("layers",) + ax
                break
            if key == name and rank == r:
                match = ax
                break
        if match is None:
            match = (None,) * rank
        out.append(match)
    return treedef.unflatten(out)
