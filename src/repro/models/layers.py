"""Shared layers + the parameter-declaration convention.

Every block declares its parameters as a nested dict of :class:`P`
``(shape, logical_axes, init)`` entries.  From one declaration tree we
derive (a) randomly initialized params, (b) abstract ``ShapeDtypeStruct``
params for the no-allocation dry-run, and (c) the logical-axis tree the
sharding rules consume (``repro.sharding``).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.sharding.rules import shard_act

from .config import ArchConfig


class P(NamedTuple):
    shape: tuple
    axes: tuple                      # logical axis names, len == len(shape)
    init: str = "normal"             # normal | zeros | ones | scaled


def init_params(key, decls, dtype=jnp.float32):
    leaves, treedef = jax.tree.flatten(decls, is_leaf=lambda x: isinstance(x, P))
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, p in zip(keys, leaves):
        if p.init == "zeros":
            out.append(jnp.zeros(p.shape, dtype))
        elif p.init == "ones":
            out.append(jnp.ones(p.shape, dtype))
        elif p.init == "arange_log":
            # mamba A_log: log(1..d_state) broadcast over leading dims
            row = jnp.log(jnp.arange(1, p.shape[-1] + 1, dtype=dtype))
            out.append(jnp.broadcast_to(row, p.shape).astype(dtype))
        else:
            scale = 0.02 if p.init == "normal" else 0.02 / math.sqrt(2.0)
            out.append(scale * jax.random.normal(k, p.shape, dtype))
    return jax.tree.unflatten(treedef, out)


def abstract_params(decls, dtype=jnp.float32):
    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, dtype), decls,
        is_leaf=lambda x: isinstance(x, P))


def param_axes(decls):
    return jax.tree.map(lambda p: p.axes, decls,
                        is_leaf=lambda x: isinstance(x, P))


def stack_decls(decls, n: int, axis_name: str = "layers"):
    """Prepend a stacking dim (for scan-over-layers parameter stacking)."""
    return jax.tree.map(
        lambda p: P((n,) + p.shape, (axis_name,) + p.axes, p.init), decls,
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_decls(cfg: ArchConfig) -> dict:
    d = {"scale": P((cfg.d_model,), ("embed",), "ones")}
    if cfg.norm == "layernorm":
        d["bias"] = P((cfg.d_model,), ("embed",), "zeros")
    return d


def apply_norm(p, x, cfg: ArchConfig, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = ((xf - mu) * jax.lax.rsqrt(var + eps)
             * p["scale"].astype(jnp.float32)
             + p["bias"].astype(jnp.float32))
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(cfg: ArchConfig, positions):
    """positions: i32[...]; returns (cos, sin) with trailing head_dim/2."""
    half = cfg.head_dim // 2
    inv = 1.0 / (cfg.rope_theta
                 ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (..., n_heads, head_dim); cos/sin broadcast over heads."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    cos = cos[..., None, :]
    sin = sin[..., None, :]
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x1 * sin + x2 * cos], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeLU)
# ---------------------------------------------------------------------------

def mlp_decls(cfg: ArchConfig) -> dict:
    if cfg.act == "swiglu":
        return {
            "w_gate": P((cfg.d_model, cfg.d_ff), ("embed", "mlp")),
            "w_up": P((cfg.d_model, cfg.d_ff), ("embed", "mlp")),
            "w_down": P((cfg.d_ff, cfg.d_model), ("mlp", "embed"), "scaled"),
        }
    return {
        "w_up": P((cfg.d_model, cfg.d_ff), ("embed", "mlp")),
        "b_up": P((cfg.d_ff,), ("mlp",), "zeros"),
        "w_down": P((cfg.d_ff, cfg.d_model), ("mlp", "embed"), "scaled"),
        "b_down": P((cfg.d_model,), ("embed",), "zeros"),
    }


def apply_mlp(p, x, cfg: ArchConfig):
    if cfg.act == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"].astype(x.dtype)) \
            * (x @ p["w_up"].astype(x.dtype))
        h = shard_act(h, ("batch", "seq", "mlp"))
        return h @ p["w_down"].astype(x.dtype)
    h = jax.nn.gelu(x @ p["w_up"].astype(x.dtype)
                    + p["b_up"].astype(x.dtype))
    h = shard_act(h, ("batch", "seq", "mlp"))
    return h @ p["w_down"].astype(x.dtype) + p["b_down"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------

def embed_decls(cfg: ArchConfig) -> dict:
    d = {"embedding": P((cfg.padded_vocab, cfg.d_model), ("vocab", "embed"))}
    if not cfg.tie_embeddings:
        d["head"] = P((cfg.d_model, cfg.padded_vocab), ("embed", "vocab"))
    return d


def embed_tokens(p, tokens, cfg: ArchConfig):
    return p["embedding"].astype(jnp.dtype(cfg.dtype))[tokens]


def lm_head(p, x, cfg: ArchConfig):
    w = (p["embedding"].T if cfg.tie_embeddings else p["head"])
    return x @ w.astype(x.dtype)
