"""Architecture configuration for the LM workload substrate.

One :class:`ArchConfig` describes every assigned architecture family:
dense decoder (llama-style GQA), encoder-only (hubert), VLM backbone
(pixtral), MoE (mixtral / llama4-scout), hybrid Mamba+attention+MoE (jamba)
and attention-free SSM (rwkv6).  Family-specific blocks are selected by
``block_pattern()``.

Modality frontends ([audio]/[vlm]) are STUBS by assignment: ``input_specs``
provides precomputed frame/patch embeddings, the backbone here is the
transformer itself.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Literal

Family = Literal["dense", "encoder", "vlm", "moe", "hybrid", "ssm"]


@dataclass(frozen=True)
class MoESpec:
    n_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.25
    every: int = 1            # MoE replaces the MLP every `every` layers
    router_jitter: float = 0.0


@dataclass(frozen=True)
class MambaSpec:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0          # 0 -> d_model // 16


@dataclass(frozen=True)
class RWKVSpec:
    head_size: int = 64
    decay_lora: int = 64      # rank of the data-dependent decay LoRA
    mix_lora: int = 32        # rank of the token-shift mix LoRA


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int              # 0 for attention-free archs
    n_kv_heads: int
    d_ff: int
    vocab: int
    # attention details
    rope_theta: float = 10_000.0
    window: int | None = None          # sliding-window attention (mixtral)
    attn_every: int = 1                # hybrid: attention layer period (jamba: 8)
    causal: bool = True                # False for encoder-only
    # family specs
    moe: MoESpec | None = None
    mamba: MambaSpec | None = None
    rwkv: RWKVSpec | None = None
    # numerics / structure
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    act: Literal["swiglu", "gelu"] = "swiglu"
    tie_embeddings: bool = False
    vocab_pad_to: int = 128            # pad vocab for sharding (Megatron-style)
    dtype: str = "bfloat16"            # activation/compute dtype
    param_dtype: str = "float32"
    kv_dtype: str | None = None        # decode KV cache dtype (serving
                                       # memory knob; None -> dtype)
    # frontend stub ([audio]/[vlm]): inputs are embeddings, not token ids
    embedding_inputs: bool = False

    @property
    def head_dim(self) -> int:
        if self.n_heads == 0:
            return 0
        return self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return (self.vocab + p - 1) // p * p

    @property
    def has_decode(self) -> bool:
        return self.family != "encoder"

    @property
    def subquadratic(self) -> bool:
        """Can run the 500k-token long-context decode shape."""
        return (self.family in ("ssm", "hybrid")
                or self.window is not None)

    def block_pattern(self) -> list[dict]:
        """Per-layer block description: mixer kind + mlp kind."""
        out = []
        for i in range(self.n_layers):
            if self.family == "ssm":
                mixer = "rwkv"
            elif self.family == "hybrid":
                # jamba: 1 attention layer per attn_every (at the middle
                # slot of each period, per the paper's 1:7 interleave)
                mixer = ("attn" if i % self.attn_every
                         == self.attn_every // 2 else "mamba")
            else:
                mixer = "attn"
            if self.moe is not None and i % self.moe.every == (
                    self.moe.every - 1):
                mlp = "moe"
            elif self.family == "ssm":
                mlp = "rwkv_cmix"
            else:
                mlp = "mlp"
            out.append({"mixer": mixer, "mlp": mlp})
        return out

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self, *, n_layers: int = 2, d_model: int = 64,
                n_heads: int | None = None, d_ff: int = 128,
                vocab: int = 256, **kw) -> "ArchConfig":
        """Smoke-test-sized config of the same family (CPU-runnable)."""
        if n_heads is None:
            n_heads = 0 if self.n_heads == 0 else 4
        kv = 0 if self.n_kv_heads == 0 else min(self.n_kv_heads, max(n_heads // 2, 1))
        changes: dict = dict(
            name=self.name + "-reduced", n_layers=n_layers, d_model=d_model,
            n_heads=n_heads, n_kv_heads=kv, d_ff=d_ff, vocab=vocab,
            vocab_pad_to=8)
        if self.moe is not None:
            changes["moe"] = dataclasses.replace(
                self.moe, n_experts=min(self.moe.n_experts, 4))
        if self.mamba is not None:
            changes["mamba"] = dataclasses.replace(
                self.mamba, d_state=8, d_conv=4, expand=2, dt_rank=8)
        if self.rwkv is not None:
            changes["rwkv"] = dataclasses.replace(
                self.rwkv, head_size=16, decay_lora=8, mix_lora=8)
        if self.attn_every > 1:
            changes["attn_every"] = min(self.attn_every, max(n_layers, 2))
        if self.window is not None:
            changes["window"] = kw.pop("window", 32)
        changes.update(kw)
        return self.replace(**changes)
