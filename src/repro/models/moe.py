"""Mixture-of-Experts layer: top-k routing with sort-based capacity dispatch.

The dispatch is gather/scatter (no dispatch-einsum), so HLO FLOPs stay
proportional to *active* expert compute — the classic one-hot dispatch
tensor costs O(tokens · experts · capacity · d_model) matmul FLOPs, which
for mixtral-size configs is a ~40% FLOP tax; sort-based dispatch avoids it
(see EXPERIMENTS.md §Perf for the measured difference).

Default parallelism keeps experts replicated with tensor-parallel ``d_ff``
(dispatch stays device-local).  Expert-parallel all-to-all over the
``model`` axis is the MapReduce-shaped alternative (map = route, shuffle =
all-to-all, reduce = combine) explored in the hillclimb.

``apply_moe_dense`` is the oracle: loops experts densely with no capacity
(used by unit tests and as the ref for the dispatch equivalence property).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding.rules import shard_act

from .config import ArchConfig
from .layers import P


def moe_decls(cfg: ArchConfig) -> dict:
    E = cfg.moe.n_experts
    return {
        "router": P((cfg.d_model, E), ("embed", "experts")),
        "w_gate": P((E, cfg.d_model, cfg.d_ff), ("experts", "embed", "mlp")),
        "w_up": P((E, cfg.d_model, cfg.d_ff), ("experts", "embed", "mlp")),
        "w_down": P((E, cfg.d_ff, cfg.d_model), ("experts", "mlp", "embed"),
                    "scaled"),
    }


def _route(p, xf, cfg: ArchConfig):
    """Router: top-k gates (renormalized softmax). xf: (N, D) -> (N,k)x2."""
    logits = (xf.astype(jnp.float32)
              @ p["router"].astype(jnp.float32))            # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.moe.top_k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    return gates, idx


def _expert_ffn(p, xg, cfg: ArchConfig):
    """Grouped SwiGLU over expert buckets. xg: (E, C, D) -> (E, C, D)."""
    dt = xg.dtype
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xg, p["w_gate"].astype(dt))) \
        * jnp.einsum("ecd,edf->ecf", xg, p["w_up"].astype(dt))
    h = shard_act(h, ("experts", None, "mlp"))
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(dt))


def _dispatch_groups(batch: int) -> int:
    """Dispatch-group count = the mesh's (pod × data) extent when a mesh
    context is active (sort/gather/scatter then stay shard-local — a
    global argsort would force GSPMD to all-gather the token
    activations), else 1."""
    from repro.sharding import rules as _r
    if _r._CTX is None:
        return 1
    mesh = _r._CTX["mesh"]
    g = 1
    for a in _r.batch_axes(mesh):
        g *= mesh.shape[a]
    while batch % g:
        g //= 2
    return max(g, 1)


def _expert_ffn_grouped(p, xg, cfg: ArchConfig):
    """Grouped SwiGLU. xg: (G, E, C, D) -> (G, E, C, D); mlp dim TP."""
    dt = xg.dtype
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xg,
                               p["w_gate"].astype(dt))) \
        * jnp.einsum("gecd,edf->gecf", xg, p["w_up"].astype(dt))
    h = shard_act(h, ("batch", "experts", "capacity", "mlp"))
    return jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(dt))


def apply_moe(p, x, cfg: ArchConfig):
    """Group-local sort-based capacity dispatch. x: (B, S, D).

    Tokens are dispatched independently within contiguous batch groups
    aligned to the data-parallel shards (capacity is per group — the
    standard expert-parallel grouping), with an explicit sharding
    constraint on every dispatch intermediate so sort/gather/scatter
    stay shard-local under GSPMD.  With no mesh context this reduces to
    one global group.
    """
    B, S, D = x.shape
    E, k = cfg.moe.n_experts, cfg.moe.top_k
    G = _dispatch_groups(B)
    N = (B * S) // G                                          # per group
    grp = lambda a, ax: shard_act(a, ("batch",) + ax)         # G leads
    xf = grp(x.reshape(G, N, D), (None, None))
    gates, idx = _route(p, xf.reshape(G * N, D), cfg)
    gates = grp(gates.reshape(G, N, k), (None, None))
    idx = grp(idx.reshape(G, N, k), (None, None))

    C = int(cfg.moe.capacity_factor * N * k / E + 0.999)
    C = max(8, -(-C // 8) * 8)                                # mult of 8
    C = min(C, N)

    flat_e = idx.reshape(G, N * k)
    order = grp(jnp.argsort(flat_e, axis=1, stable=True), (None,))
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    tok = order // k                                          # (G, N*k)
    # rank within expert bucket = position - bucket start, where
    # start[e] = #assignments routed to experts < e (exclusive cumsum)
    counts = jnp.sum(jax.nn.one_hot(sorted_e, E, dtype=jnp.int32), axis=1)
    start = jnp.cumsum(counts, axis=1) - counts
    rank = (jnp.arange(N * k)[None, :]
            - jnp.take_along_axis(start, sorted_e, axis=1))
    keep = rank < C
    slot = jnp.where(keep, sorted_e * C + rank, E * C)        # E*C = drop

    gi = jnp.arange(G)[:, None]
    # gather tokens into (G, E, C, D) buckets (zero row absorbs drops)
    buf_tok = jnp.full((G, E * C + 1), N, jnp.int32) \
        .at[gi, slot].set(tok.astype(jnp.int32), mode="drop")
    xpad = jnp.concatenate([xf, jnp.zeros((G, 1, D), xf.dtype)], axis=1)
    xg = jnp.take_along_axis(
        xpad, buf_tok[:, :E * C, None], axis=1).reshape(G, E, C, D)
    # EP: expert buckets sharded over the model axis (capacity dim when
    # E doesn't divide it) — keeps per-device bucket arrays O(1/model)
    xg = grp(xg, ("experts", "capacity", None))

    yg = _expert_ffn_grouped(p, xg, cfg)
    yg = grp(yg, ("experts", "capacity", None)).reshape(G, E * C, D)

    # combine: scatter-add gate-weighted expert outputs back to tokens
    g_sorted = jnp.take_along_axis(gates.reshape(G, N * k), order,
                                   axis=1).astype(x.dtype)
    contrib = jnp.where(
        keep[..., None],
        jnp.take_along_axis(yg, jnp.minimum(slot, E * C - 1)[..., None],
                            axis=1) * g_sorted[..., None], 0.0)
    contrib = grp(contrib, ("capacity", None))
    out = jnp.zeros((G, N, D), x.dtype).at[gi, tok].add(contrib)
    return grp(out, (None, None)).reshape(B, S, D)


def apply_moe_dense(p, x, cfg: ArchConfig):
    """Oracle: dense per-expert compute, no capacity drop. O(E) FLOPs."""
    B, S, D = x.shape
    xf = x.reshape(B * S, D)
    gates, idx = _route(p, xf, cfg)
    E = cfg.moe.n_experts
    out = jnp.zeros_like(xf)
    for e in range(E):
        pe = {k2: v[e] for k2, v in p.items() if k2 != "router"}
        dt = xf.dtype
        h = jax.nn.silu(xf @ pe["w_gate"].astype(dt)) \
            * (xf @ pe["w_up"].astype(dt))
        ye = h @ pe["w_down"].astype(dt)
        w = jnp.sum(jnp.where(idx == e, gates, 0.0), axis=-1).astype(dt)
        out += w[:, None] * ye
    return out.reshape(B, S, D)
