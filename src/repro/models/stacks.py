"""Layer stacks: period-aware scan-over-layers with rematerialization.

Homogeneous archs scan one block; heterogeneous archs (jamba's
mamba/attention 1:7 interleave with MoE every other layer) repeat a
*period* of sub-blocks — the block pattern's smallest repeating unit —
and scan over periods.  Parameters are stacked on a leading ``layers``
axis (never sharded), so the HLO contains one period regardless of depth:
compile times stay flat and the roofline extractor applies the documented
depth correction.

``unroll=True`` disables the scan (used by depth-variant lowerings in the
roofline methodology and by tiny smoke configs where scan overhead
dominates).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.sharding.rules import shard_act

from . import attention, moe, ssm
from .config import ArchConfig
from .layers import (P, apply_mlp, apply_norm, mlp_decls, norm_decls,
                     stack_decls)


def _pattern_period(cfg: ArchConfig) -> list[dict]:
    pat = cfg.block_pattern()
    for p in range(1, len(pat) + 1):
        if len(pat) % p == 0 and pat == pat[:p] * (len(pat) // p):
            return pat[:p]
    return pat


MIXER_DECLS = {"attn": attention.attn_decls, "mamba": ssm.mamba_decls,
               "rwkv": ssm.rwkv_tmix_decls}
MLP_DECLS = {"mlp": mlp_decls, "moe": moe.moe_decls,
             "rwkv_cmix": ssm.rwkv_cmix_decls}


def sub_block_decls(cfg: ArchConfig, entry: dict) -> dict:
    return {
        "norm1": norm_decls(cfg),
        "mixer": MIXER_DECLS[entry["mixer"]](cfg),
        "norm2": norm_decls(cfg),
        "mlp": MLP_DECLS[entry["mlp"]](cfg),
    }


def stack_param_decls(cfg: ArchConfig) -> dict:
    """{"sub{i}": decls} stacked over n_layers/period periods."""
    period = _pattern_period(cfg)
    if not period:                       # 0-layer roofline variant
        return {}
    n_periods = cfg.n_layers // len(period)
    return {
        f"sub{i}": stack_decls(sub_block_decls(cfg, e), n_periods)
        for i, e in enumerate(period)
    }


def _apply_sub_block(p, x, cfg: ArchConfig, entry: dict, positions,
                     attn_impl: str):
    # constraint on the *bf16* norm output anchors GSPMD's SP->TP gather
    # on the cast tensor (it otherwise gathers the f32 norm internals at
    # 2x wire cost — §Perf B4)
    h = shard_act(apply_norm(p["norm1"], x, cfg),
                  ("batch", "seq", "embed"))
    if entry["mixer"] == "attn":
        out = attention.apply_attention(p["mixer"], h, cfg, positions,
                                        impl=attn_impl)
    elif entry["mixer"] == "mamba":
        out = ssm.apply_mamba(p["mixer"], h, cfg)
    else:
        out = ssm.apply_rwkv_tmix(p["mixer"], h, cfg)
    x = x + out
    h = apply_norm(p["norm2"], x, cfg)
    if entry["mlp"] == "mlp":
        out = apply_mlp(p["mlp"], h, cfg)
    elif entry["mlp"] == "moe":
        out = moe.apply_moe(p["mlp"], h, cfg)
    else:
        out = ssm.apply_rwkv_cmix(p["mlp"], h, cfg)
    x = x + out
    return x


def apply_stack(params: dict, x, cfg: ArchConfig, positions=None, *,
                attn_impl: str = "auto", unroll: bool = False,
                remat: bool = True):
    """Full-sequence forward through all layers.  x: (B,S,D)."""
    period = _pattern_period(cfg)
    if not period:                       # 0-layer roofline variant
        return shard_act(x, ("batch", "seq", "embed"))
    n_periods = cfg.n_layers // len(period)

    # heterogeneous periods (jamba: 8 sub-blocks) additionally checkpoint
    # each sub-block: the rematted backward then keeps ONE sub-block's
    # internals live instead of the whole period's (4 MoE + 7 mamba
    # buffers at once is hundreds of GiB at the assigned sizes)
    nested = remat and len(period) > 1

    def one_period(x, pparams):
        x = shard_act(x, ("batch", "seq", "embed"))   # SP residual stream
        for i, entry in enumerate(period):
            fn = functools.partial(_apply_sub_block, cfg=cfg, entry=entry,
                                   positions=positions, attn_impl=attn_impl)
            if nested:
                fn = jax.checkpoint(fn)
            x = fn(pparams[f"sub{i}"], x)
        return x

    if remat:
        one_period = jax.checkpoint(one_period)

    if unroll:
        for li in range(n_periods):
            x = one_period(x, jax.tree.map(lambda a: a[li], params))
        return x

    def body(x, pparams):
        return one_period(x, pparams), None

    x, _ = jax.lax.scan(body, x, params)
    return x


# ---------------------------------------------------------------------------
# Decode: per-layer recurrent state threading
# ---------------------------------------------------------------------------

def init_stack_state(cfg: ArchConfig, batch: int, cache_len: int) -> dict:
    """Stacked per-period decode states (KV caches / SSM states)."""
    period = _pattern_period(cfg)
    if not period:
        return {}
    n_periods = cfg.n_layers // len(period)

    def stacked(make):
        leaves = make()
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_periods,) + a.shape).copy(),
            leaves)

    state = {}
    for i, entry in enumerate(period):
        sub = {}
        if entry["mixer"] == "attn":
            sub["mixer"] = stacked(functools.partial(
                attention.init_kv_cache, cfg, batch, cache_len))
        elif entry["mixer"] == "mamba":
            sub["mixer"] = stacked(functools.partial(
                ssm.init_mamba_state, cfg, batch))
        else:
            sub["mixer"] = stacked(functools.partial(
                ssm.init_rwkv_state, cfg, batch))
        if entry["mlp"] == "rwkv_cmix":
            sub["mlp"] = stacked(
                lambda: jnp.zeros((batch, cfg.d_model), jnp.dtype(cfg.dtype)))
        state[f"sub{i}"] = sub
    return state


def _prefill_sub_block(p, x, cfg: ArchConfig, entry: dict, cache_len: int,
                       attn_impl: str):
    h = apply_norm(p["norm1"], x, cfg)
    new = {}
    if entry["mixer"] == "attn":
        out, new["mixer"] = attention.prefill_attention(
            p["mixer"], h, cfg, cache_len, impl=attn_impl)
    elif entry["mixer"] == "mamba":
        out, new["mixer"] = ssm.apply_mamba(p["mixer"], h, cfg,
                                            return_state=True)
    else:
        out, new["mixer"] = ssm.apply_rwkv_tmix(p["mixer"], h, cfg,
                                                return_state=True)
    x = x + out
    h = apply_norm(p["norm2"], x, cfg)
    if entry["mlp"] == "mlp":
        x = x + apply_mlp(p["mlp"], h, cfg)
    elif entry["mlp"] == "moe":
        x = x + moe.apply_moe(p["mlp"], h, cfg)
    else:
        # cmix token-shift decode state = last token of the cmix input h
        new["mlp"] = h[:, -1]
        x = x + ssm.apply_rwkv_cmix(p["mlp"], h, cfg)
    return x, new


def prefill_stack(params: dict, x, cfg: ArchConfig, cache_len: int, *,
                  attn_impl: str = "auto"):
    """Full-sequence forward that also returns stacked decode states."""
    period = _pattern_period(cfg)
    if not period:
        return x, {}

    def body(x, pparams):
        new_st = {}
        for i, entry in enumerate(period):
            x, new_st[f"sub{i}"] = _prefill_sub_block(
                pparams[f"sub{i}"], x, cfg, entry, cache_len, attn_impl)
        return x, new_st

    x, states = jax.lax.scan(body, x, params)
    return x, states


def _step_sub_block(p, x, st, cfg: ArchConfig, entry: dict, t):
    h = apply_norm(p["norm1"], x, cfg)
    new = {}
    if entry["mixer"] == "attn":
        out, new["mixer"] = attention.decode_attention(p["mixer"], h,
                                                       st["mixer"], cfg, t)
    elif entry["mixer"] == "mamba":
        out, new["mixer"] = ssm.mamba_step(p["mixer"], h, st["mixer"], cfg)
    else:
        out, new["mixer"] = ssm.rwkv_tmix_step(p["mixer"], h, st["mixer"],
                                               cfg)
    x = x + out
    h = apply_norm(p["norm2"], x, cfg)
    if entry["mlp"] == "mlp":
        x = x + apply_mlp(p["mlp"], h, cfg)
    elif entry["mlp"] == "moe":
        x = x + moe.apply_moe(p["mlp"], h, cfg)
    else:
        out, new["mlp"] = ssm.rwkv_cmix_step(p["mlp"], h, st["mlp"], cfg)
        x = x + out
    return x, new


def step_stack(params: dict, x, state: dict, cfg: ArchConfig, t):
    """One-token decode through all layers.  x: (B,1,D); t: position."""
    period = _pattern_period(cfg)
    if not period:
        return x, {}

    def body(x, scanned):
        pparams, st = scanned
        new_st = {}
        for i, entry in enumerate(period):
            x, new_st[f"sub{i}"] = _step_sub_block(
                pparams[f"sub{i}"], x, st[f"sub{i}"], cfg, entry, t)
        return x, new_st

    x, new_state = jax.lax.scan(body, x, (params, state))
    return x, new_state
