"""Grouped-query attention: training/prefill forward + KV-cache decode.

Shapes follow the logical-axis convention: q/k/v projections are kept 3-D
``(embed, heads, head_dim)`` so the sharding rules may shard either the
``heads`` or the ``head_dim`` axis (the latter rescues archs whose head
count does not divide the model-parallel axis, e.g. llama4-scout's 40
heads on a 16-way mesh).

The jnp path below is the reference; ``use_flash=True`` routes the core
softmax(QKᵀ)V through the Pallas flash-attention kernel
(``repro.kernels.flash_attention``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding.rules import shard_act

from .config import ArchConfig
from .layers import P, apply_rope, rope_freqs

_NEG = -1e30
# chunked-attention tile sizes (module-level so perf experiments can sweep)
BLOCK_Q = 512
BLOCK_K = 1024


def attn_decls(cfg: ArchConfig) -> dict:
    dh = cfg.head_dim
    return {
        "wq": P((cfg.d_model, cfg.n_heads, dh), ("embed", "heads", "head_dim")),
        "wk": P((cfg.d_model, cfg.n_kv_heads, dh),
                ("embed", "kv_heads", "head_dim")),
        "wv": P((cfg.d_model, cfg.n_kv_heads, dh),
                ("embed", "kv_heads", "head_dim")),
        "wo": P((cfg.n_heads, dh, cfg.d_model),
                ("heads", "head_dim", "embed"), "scaled"),
    }


def _qkv(p, x, cfg: ArchConfig, positions):
    q = shard_act(jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype)),
                  ("batch", "seq", "heads", "head_dim"))
    k = shard_act(jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype)),
                  ("batch", "seq", "kv_heads", "head_dim"))
    v = shard_act(jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype)),
                  ("batch", "seq", "kv_heads", "head_dim"))
    cos, sin = rope_freqs(cfg, positions)
    return apply_rope(q, cos, sin), apply_rope(k, cos, sin), v


def _gqa_scores_mask(cfg: ArchConfig, q_pos, k_pos):
    """mask[(...,) S, T] — True where attendable."""
    ok = jnp.ones((q_pos.shape[-1], k_pos.shape[-1]), bool)
    if cfg.causal:
        ok &= q_pos[:, None] >= k_pos[None, :]
    if cfg.window is not None:
        ok &= q_pos[:, None] - k_pos[None, :] < cfg.window
    return ok


def sdpa(cfg: ArchConfig, q, k, v, mask):
    """Reference scaled-dot-product attention with GQA grouping.

    q: (B,S,Hq,Dh)  k,v: (B,T,Hkv,Dh)  mask: (S,T) or (B,S,T).
    """
    B, S, Hq, Dh = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, S, Hkv, G, Dh)
    # preferred_element_type keeps operands bf16 (a converted-f32 operand
    # would be gathered at 2x wire cost under GSPMD)
    scores = jnp.einsum("bshgk,bthk->bhgst", qg, k,
                        preferred_element_type=jnp.float32)
    scores *= Dh ** -0.5
    if mask.ndim == 2:
        mask = mask[None]
    scores = jnp.where(mask[:, None, None], scores, _NEG)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgst,bthk->bshgk", w, v)
    return out.reshape(B, S, Hq, Dh)


def chunked_sdpa(cfg: ArchConfig, q, k, v, *, block_q: int | None = None,
                 block_k: int | None = None):
    """Flash-style online-softmax attention in pure jnp (nested scans over
    q/kv blocks).  Never materializes the S×T score matrix — this is what
    makes the 4k-train / 32k-prefill shapes fit HBM in the compiled
    dry-run; the Pallas kernel is the TPU-native version of the same
    schedule with explicit VMEM tiling.

    Assumes contiguous positions 0..S-1 (training/prefill).  FLOPs inside
    the block scans are counted once by XLA cost analysis — the roofline
    extractor adds the analytic attention term (EXPERIMENTS.md §Roofline).
    """
    B, S, Hq, Dh = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    bq = min(block_q or BLOCK_Q, S)
    bk = min(block_k or BLOCK_K, T)
    nq, nk = S // bq, T // bk
    assert S % bq == 0 and T % bk == 0, (S, T, bq, bk)
    scale = Dh ** -0.5

    qr = q.reshape(B, nq, bq, Hkv, G, Dh).transpose(1, 0, 3, 4, 2, 5)
    kr = k.reshape(B, nk, bk, Hkv, Dh).transpose(1, 0, 3, 2, 4)
    vr = v.reshape(B, nk, bk, Hkv, Dh).transpose(1, 0, 3, 2, 4)

    def q_block(_, qi_qb):
        qi, qb = qi_qb                       # qb: (B,Hkv,G,bq,Dh)
        qpos = qi * bq + jnp.arange(bq)

        def kv_block(carry, ki_kb):
            m, l, acc = carry
            ki, kb, vb = ki_kb               # kb/vb: (B,Hkv,bk,Dh)
            kpos = ki * bk + jnp.arange(bk)
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            ok = jnp.ones((bq, bk), bool)
            if cfg.causal:
                ok &= qpos[:, None] >= kpos[None, :]
            if cfg.window is not None:
                ok &= qpos[:, None] - kpos[None, :] < cfg.window
            s = jnp.where(ok, s, _NEG)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p.astype(vb.dtype), vb
            ).astype(jnp.float32)
            return (m_new, l, acc), None

        init = (jnp.full((B, Hkv, G, bq), -jnp.inf, jnp.float32),
                jnp.zeros((B, Hkv, G, bq), jnp.float32),
                jnp.zeros((B, Hkv, G, bq, Dh), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(kv_block), init, (jnp.arange(nk), kr, vr))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.astype(q.dtype)     # (B,Hkv,G,bq,Dh)

    _, blocks = jax.lax.scan(q_block, None, (jnp.arange(nq), qr))
    out = blocks.transpose(1, 0, 4, 2, 3, 5).reshape(B, S, Hq, Dh)
    return out


def _core_attention(cfg: ArchConfig, q, k, v, positions, impl: str):
    if impl == "auto":
        impl = "chunked" if q.shape[1] >= 2048 else "dense"
    if impl == "flash":
        from repro.kernels.flash_attention import ops as fa_ops
        return fa_ops.flash_attention(q, k, v, causal=cfg.causal,
                                      window=cfg.window)
    if impl == "chunked":
        return chunked_sdpa(cfg, q, k, v)
    mask = _gqa_scores_mask(cfg, positions[0], positions[0])
    return sdpa(cfg, q, k, v, mask)


def apply_attention(p, x, cfg: ArchConfig, positions=None, *,
                    impl: str = "auto"):
    """Full-sequence path (training / prefill). x: (B,S,D)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q, k, v = _qkv(p, x, cfg, positions)
    out = _core_attention(cfg, q, k, v, positions, impl)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


def prefill_attention(p, x, cfg: ArchConfig, cache_len: int, *,
                      impl: str = "auto"):
    """Full-sequence forward that also materializes the KV cache.

    With ``cache_len < S`` (sliding-window long-context serving) only the
    last ``cache_len`` positions are kept, ring-buffer addressed so a
    subsequent :func:`decode_attention` continues seamlessly.
    """
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]
    q, k, v = _qkv(p, x, cfg, positions)
    out = _core_attention(cfg, q, k, v, positions, impl)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))

    keep = min(cache_len, S)
    kpos = jnp.arange(S - keep, S)
    slots = jnp.mod(kpos, cache_len)
    cache = init_kv_cache(cfg, B, cache_len)
    cache["k"] = cache["k"].at[:, slots].set(
        k[:, -keep:].astype(cache["k"].dtype))
    cache["v"] = cache["v"].at[:, slots].set(
        v[:, -keep:].astype(cache["v"].dtype))
    cache["slot_pos"] = cache["slot_pos"].at[slots].set(
        kpos.astype(jnp.int32))
    return y, cache


# ---------------------------------------------------------------------------
# KV cache decode
# ---------------------------------------------------------------------------

def init_kv_cache(cfg: ArchConfig, batch: int, cache_len: int,
                  dtype=None) -> dict:
    """Ring-buffer KV cache.  ``slot_pos`` holds each slot's absolute
    position (-1 = empty); with sliding-window archs ``cache_len`` may be
    just the window size (the 500k-decode trick for mixtral)."""
    dtype = dtype or jnp.dtype(cfg.kv_dtype or cfg.dtype)
    kv = (batch, cache_len, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(kv, dtype),
        "v": jnp.zeros(kv, dtype),
        "slot_pos": jnp.full((cache_len,), -1, jnp.int32),
    }


def decode_attention(p, x, cache, cfg: ArchConfig, t):
    """One-token decode step.  x: (B,1,D); t: scalar absolute position.

    Returns (out (B,1,D), updated cache).  Batch-uniform position (our
    serving shapes decode in lockstep).
    """
    B = x.shape[0]
    Sc = cache["k"].shape[1]
    pos = jnp.full((B, 1), t, jnp.int32)
    q, k, v = _qkv(p, x, cfg, pos)
    slot = jnp.mod(t, Sc)
    cache = dict(cache)
    kv_dt = cache["k"].dtype
    cache["k"] = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(kv_dt), slot, 1)
    cache["v"] = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(kv_dt), slot, 1)
    cache["slot_pos"] = jax.lax.dynamic_update_slice_in_dim(
        cache["slot_pos"], jnp.full((1,), t, jnp.int32), slot, 0)

    kpos = cache["slot_pos"]
    ok = (kpos >= 0) & (kpos <= t)
    if cfg.window is not None:
        ok &= (t - kpos) < cfg.window
    mask = ok[None, None, :]                      # (1, S=1, T)
    out = sdpa(cfg, q, cache["k"].astype(q.dtype),
               cache["v"].astype(q.dtype), mask.astype(bool))
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype)), cache
