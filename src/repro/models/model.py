"""Top-level model: embedding → stack → head, loss, prefill/decode.

Works for every assigned family; frontend-stubbed archs
(``cfg.embedding_inputs``: hubert frames, pixtral patches) take
``(B, S, d_model)`` embeddings instead of token ids, per the assignment
("the modality frontend is a STUB — input_specs() provides precomputed
frame/patch embeddings").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding.rules import shard_act

from . import stacks
from .config import ArchConfig
from .layers import (abstract_params, apply_norm, embed_decls, embed_tokens,
                     init_params, lm_head, norm_decls, param_axes)


def model_decls(cfg: ArchConfig) -> dict:
    return {
        "embed": embed_decls(cfg),
        "stack": stacks.stack_param_decls(cfg),
        "final_norm": norm_decls(cfg),
    }


def init_model(key, cfg: ArchConfig):
    return init_params(key, model_decls(cfg), jnp.dtype(cfg.param_dtype))


def abstract_model(cfg: ArchConfig):
    """ShapeDtypeStruct param tree — the no-allocation dry-run input."""
    return abstract_params(model_decls(cfg), jnp.dtype(cfg.param_dtype))


def model_axes(cfg: ArchConfig):
    """Logical-axis tree mirroring the params (for sharding rules)."""
    return param_axes(model_decls(cfg))


def forward(params, cfg: ArchConfig, inputs, *, attn_impl: str = "auto",
            unroll: bool = False, remat: bool = True):
    """Logits for a full sequence.  inputs: (B,S) int32 or (B,S,D) embeds."""
    dt = jnp.dtype(cfg.dtype)
    if cfg.embedding_inputs:
        x = inputs.astype(dt)
    else:
        x = embed_tokens(params["embed"], inputs, cfg)
    x = shard_act(x, ("batch", "seq", "embed"))
    x = stacks.apply_stack(params["stack"], x, cfg, attn_impl=attn_impl,
                           unroll=unroll, remat=remat)
    x = apply_norm(params["final_norm"], x, cfg)
    return shard_act(lm_head(params["embed"], x, cfg),
                     ("batch", "seq", "vocab"))


def forward_hidden(params, cfg: ArchConfig, inputs, *,
                   attn_impl: str = "auto", unroll: bool = False,
                   remat: bool = True):
    """Final-normed hidden states (B,S,D) — the LM head is applied by the
    caller (``loss_fn`` fuses it into chunked cross-entropy)."""
    dt = jnp.dtype(cfg.dtype)
    if cfg.embedding_inputs:
        x = inputs.astype(dt)
    else:
        x = embed_tokens(params["embed"], inputs, cfg)
    x = shard_act(x, ("batch", "seq", "embed"))
    x = stacks.apply_stack(params["stack"], x, cfg, attn_impl=attn_impl,
                           unroll=unroll, remat=remat)
    return apply_norm(params["final_norm"], x, cfg)


def _xent_chunk(params, cfg: ArchConfig, xc, lc):
    """Σ nll over one sequence chunk.  xc: (B,ck,D); lc: (B,ck)."""
    logits = shard_act(lm_head(params["embed"], xc, cfg),
                       ("batch", "seq", "vocab")).astype(jnp.float32)
    mask = lc >= 0
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, jnp.maximum(lc, 0)[..., None],
                             axis=-1)[..., 0]
    return -jnp.sum(jnp.where(mask, ll, 0.0)), jnp.sum(mask)


def loss_fn(params, cfg: ArchConfig, batch, *, attn_impl: str = "auto",
            unroll: bool = False, remat: bool = True,
            xent_chunk: int = 512):
    """Mean next-token (or masked-label) cross-entropy.  batch:
    {"inputs": ids or embeds, "labels": (B,S) int32, -1 = unlabelled}.

    The LM head + softmax runs in rematerialized sequence chunks: full
    (B, S, vocab) fp32 logits at the assigned sizes are tens of GiB per
    device; chunking bounds the live set at (B, chunk, vocab).
    """
    x = forward_hidden(params, cfg, batch["inputs"], attn_impl=attn_impl,
                       unroll=unroll, remat=remat)
    labels = batch["labels"]
    B, S = labels.shape
    ck = xent_chunk
    if S > ck and S % ck == 0:
        nc = S // ck
        xcs = jnp.moveaxis(x.reshape(B, nc, ck, x.shape[-1]), 1, 0)
        lcs = jnp.moveaxis(labels.reshape(B, nc, ck), 1, 0)

        def body(carry, xl):
            nll, n = jax.checkpoint(
                lambda xc, lc: _xent_chunk(params, cfg, xc, lc))(*xl)
            return (carry[0] + nll, carry[1] + n), None

        (nll, n), _ = jax.lax.scan(
            body, (jnp.float32(0.0), jnp.int32(0)), (xcs, lcs))
    else:
        nll, n = _xent_chunk(params, cfg, x, labels)
    return nll / jnp.maximum(n, 1)


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_decode_state(cfg: ArchConfig, batch: int, cache_len: int):
    return stacks.init_stack_state(cfg, batch, cache_len)


def prefill(params, cfg: ArchConfig, inputs, cache_len: int, *,
            attn_impl: str = "auto"):
    """Returns (last-position logits, decode state)."""
    dt = jnp.dtype(cfg.dtype)
    if cfg.embedding_inputs:
        x = inputs.astype(dt)
    else:
        x = embed_tokens(params["embed"], inputs, cfg)
    x, state = stacks.prefill_stack(params["stack"], x, cfg, cache_len,
                                    attn_impl=attn_impl)
    x = apply_norm(params["final_norm"], x, cfg)
    return lm_head(params["embed"], x[:, -1:], cfg)[:, 0], state


def decode_step(params, cfg: ArchConfig, tokens, state, t):
    """One decode step.  tokens: (B,) int32; t: scalar position of them.

    Returns (logits (B, vocab), new state).  This is the function the
    ``decode_*`` / ``long_*`` dry-run shapes lower (``serve_step``).
    """
    x = embed_tokens(params["embed"], tokens[:, None], cfg)
    x, state = stacks.step_stack(params["stack"], x, state, cfg, t)
    x = apply_norm(params["final_norm"], x, cfg)
    return lm_head(params["embed"], x, cfg)[:, 0], state
