"""State-space / linear-recurrence mixers: Mamba (jamba) and RWKV6 (finch).

Both are implemented as exact sequential recurrences via ``lax.scan`` over
time — the semantic reference.  The recurrences are O(1)-state, which is
what makes the ``long_500k`` decode shape runnable for these families.
The chunked matmul formulation of RWKV6 (TPU-friendly, MXU-aligned) lives
in ``repro.kernels.rwkv6`` with this scan as its oracle.

FLOP accounting note (EXPERIMENTS.md §Roofline): the projections — the
dominant FLOPs — sit *outside* the time scan and are counted by XLA's
cost analysis; the elementwise recurrence inside the scan is counted once
per trip, so the roofline extractor adds the analytic correction
(< 1% of layer FLOPs for both families at the assigned sizes).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding.rules import shard_act

from .config import ArchConfig
from .layers import P


# ---------------------------------------------------------------------------
# Mamba (selective SSM)
# ---------------------------------------------------------------------------

def _mamba_dims(cfg: ArchConfig):
    m = cfg.mamba
    d_inner = m.expand * cfg.d_model
    dt_rank = m.dt_rank or max(cfg.d_model // 16, 1)
    return d_inner, dt_rank, m.d_state, m.d_conv


def mamba_decls(cfg: ArchConfig) -> dict:
    di, dtr, ds, dc = _mamba_dims(cfg)
    return {
        "in_proj": P((cfg.d_model, 2 * di), ("embed", "inner")),
        "conv_w": P((dc, di), ("conv", "inner")),
        "conv_b": P((di,), ("inner",), "zeros"),
        "x_proj": P((di, dtr + 2 * ds), ("inner", "proj")),
        "dt_w": P((dtr, di), ("proj", "inner")),
        "dt_b": P((di,), ("inner",), "zeros"),
        "a_log": P((di, ds), ("inner", "state"), "arange_log"),
        "d_skip": P((di,), ("inner",), "ones"),
        "out_proj": P((di, cfg.d_model), ("inner", "embed"), "scaled"),
    }


def _mamba_pre(p, x, cfg: ArchConfig, conv_state=None):
    """Shared projections. x: (B,S,D). Returns (xin, z, dt, Bc, Cc, conv_tail)."""
    di, dtr, ds, dc = _mamba_dims(cfg)
    dt_ = x.dtype
    xz = x @ p["in_proj"].astype(dt_)
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = shard_act(xin, ("batch", "seq", "inner"))
    z = shard_act(z, ("batch", "seq", "inner"))
    # causal depthwise conv over time
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], dc - 1, di), dt_)
    else:
        pad = conv_state.astype(dt_)
    xin_p = jnp.concatenate([pad, xin], axis=1)
    conv_tail = xin_p[:, -(dc - 1):, :]
    w = p["conv_w"].astype(dt_)
    xin = sum(xin_p[:, i:i + xin.shape[1], :] * w[i] for i in range(dc))
    xin = jax.nn.silu(xin + p["conv_b"].astype(dt_))

    xp = xin @ p["x_proj"].astype(dt_)
    dt_low, Bc, Cc = jnp.split(xp, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(dt_low @ p["dt_w"].astype(dt_)
                         + p["dt_b"].astype(dt_)).astype(jnp.float32)
    dt = shard_act(dt, ("batch", "seq", "inner"))
    return xin, z, dt, Bc.astype(jnp.float32), Cc.astype(jnp.float32), conv_tail


def _mamba_scan(p, xin, dt, Bc, Cc, h0, *, chunk: int = 256):
    """h_t = exp(dt A) h + dt x B ; y_t = h C + D x. Carries h (B,di,ds).

    Time-chunked with per-chunk rematerialization: a flat reverse-mode
    scan would save the (B, di, ds) carry for *every* step (hundreds of
    GiB at the assigned sizes); checkpointing per chunk keeps only
    chunk-boundary carries and recomputes inside — the standard
    sqrt-remat trade for long recurrences.
    """
    A = -jnp.exp(p["a_log"].astype(jnp.float32))        # (di, ds)

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp                        # (B,di),(B,di),(B,ds)
        dA = jnp.exp(dt_t[..., None] * A)                # (B,di,ds)
        h = h * dA + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bds,bs->bd", h, c_t)
        return h, y

    xs = (jnp.moveaxis(xin.astype(jnp.float32), 1, 0),
          jnp.moveaxis(dt, 1, 0), jnp.moveaxis(Bc, 1, 0),
          jnp.moveaxis(Cc, 1, 0))
    S = xs[0].shape[0]
    if S > chunk and S % chunk == 0:
        xs = jax.tree.map(
            lambda a: a.reshape(S // chunk, chunk, *a.shape[1:]), xs)

        def chunk_body(h, xc):
            h = shard_act(h, ("batch", "inner", "state"))
            return jax.lax.scan(step, h, xc)

        h, ys = jax.lax.scan(jax.checkpoint(chunk_body), h0, xs)
        ys = ys.reshape(S, *ys.shape[2:])
    else:
        h, ys = jax.lax.scan(step, h0, xs)
    return h, jnp.moveaxis(ys, 0, 1)                     # (B,S,di)


def apply_mamba(p, x, cfg: ArchConfig, *, return_state: bool = False):
    """Training / prefill path. x: (B,S,D)."""
    di, _, ds, _ = _mamba_dims(cfg)
    xin, z, dt, Bc, Cc, conv_tail = _mamba_pre(p, x, cfg)
    h0 = jnp.zeros((x.shape[0], di, ds), jnp.float32)
    h, y = _mamba_scan(p, xin, dt, Bc, Cc, h0)
    y = (y.astype(x.dtype) + p["d_skip"].astype(x.dtype) * xin) \
        * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(x.dtype)
    if return_state:
        return out, {"h": h, "conv": conv_tail}
    return out


def init_mamba_state(cfg: ArchConfig, batch: int) -> dict:
    di, _, ds, dc = _mamba_dims(cfg)
    return {"h": jnp.zeros((batch, di, ds), jnp.float32),
            "conv": jnp.zeros((batch, dc - 1, di), jnp.dtype(cfg.dtype))}


def mamba_step(p, x, state, cfg: ArchConfig):
    """One-token decode. x: (B,1,D)."""
    xin, z, dt, Bc, Cc, conv_tail = _mamba_pre(p, x, cfg,
                                               conv_state=state["conv"])
    h, y = _mamba_scan(p, xin, dt, Bc, Cc, state["h"])
    y = (y.astype(x.dtype) + p["d_skip"].astype(x.dtype) * xin) \
        * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(x.dtype)
    return out, {"h": h, "conv": conv_tail}


# ---------------------------------------------------------------------------
# RWKV6 (finch): data-dependent decay linear attention
# ---------------------------------------------------------------------------

def _rwkv_dims(cfg: ArchConfig):
    hs = cfg.rwkv.head_size
    return cfg.d_model // hs, hs


def rwkv_tmix_decls(cfg: ArchConfig) -> dict:
    D = cfg.d_model
    H, hs = _rwkv_dims(cfg)
    r = cfg.rwkv
    return {
        "mu": P((5, D), ("five", "embed")),               # r,k,v,w,g shifts
        "mix_down": P((D, 5 * r.mix_lora), ("embed", "lora")),
        "mix_up": P((5, r.mix_lora, D), ("five", "lora", "embed")),
        "wr": P((D, H * hs), ("embed", "inner")),
        "wk": P((D, H * hs), ("embed", "inner")),
        "wv": P((D, H * hs), ("embed", "inner")),
        "wg": P((D, H * hs), ("embed", "inner")),
        "w0": P((H * hs,), ("inner",), "zeros"),
        "decay_down": P((D, r.decay_lora), ("embed", "lora")),
        "decay_up": P((r.decay_lora, H * hs), ("lora", "inner")),
        "u": P((H, hs), ("heads", "head_dim")),
        "ln_scale": P((H * hs,), ("inner",), "ones"),
        "ln_bias": P((H * hs,), ("inner",), "zeros"),
        "wo": P((H * hs, D), ("inner", "embed"), "scaled"),
    }


def _tmix_proj(p, x, x_prev, cfg: ArchConfig):
    """Token-shift mixing + projections. x: (B,S,D); x_prev: shifted x."""
    dt_ = x.dtype
    dx = x_prev - x
    # data-dependent mixing (LoRA over the 5 streams)
    lo = jnp.tanh((x + dx * p["mu"][4].astype(dt_))        # g-stream mix seed
                  @ p["mix_down"].astype(dt_))
    B, S = x.shape[:2]
    lo = lo.reshape(B, S, 5, cfg.rwkv.mix_lora)
    dyn = jnp.einsum("bsfl,fld->bsfd", lo, p["mix_up"].astype(dt_))
    mixed = x[:, :, None, :] + dx[:, :, None, :] \
        * (p["mu"].astype(dt_) + dyn)                      # (B,S,5,D)
    xr, xk, xv, xw, xg = (mixed[:, :, i] for i in range(5))
    H, hs = _rwkv_dims(cfg)
    shp = (B, S, H, hs)
    r = shard_act((xr @ p["wr"].astype(dt_)).reshape(shp),
                  ("batch", "seq", "heads", "head_dim"))
    k = shard_act((xk @ p["wk"].astype(dt_)).reshape(shp),
                  ("batch", "seq", "heads", "head_dim"))
    v = shard_act((xv @ p["wv"].astype(dt_)).reshape(shp),
                  ("batch", "seq", "heads", "head_dim"))
    g = jax.nn.silu(xg @ p["wg"].astype(dt_))
    # data-dependent decay in (0,1): w = exp(-exp(w0 + lora(xw)))
    wlog = p["w0"].astype(jnp.float32) + (
        jnp.tanh(xw @ p["decay_down"].astype(dt_)).astype(jnp.float32)
        @ p["decay_up"].astype(jnp.float32))
    w = jnp.exp(-jnp.exp(wlog)).reshape(shp)
    return r, k, v, g, w


def _wkv_scan(p, r, k, v, w, s0, *, chunk: int = 256):
    """S_t = diag(w_t) S + kᵀv ; y_t = r·(S + diag(u) kᵀv). s0: (B,H,hs,hs).

    Time-chunked + per-chunk remat for the same backward-memory reason as
    ``_mamba_scan``.  The Pallas kernel (repro.kernels.rwkv6) is the
    VMEM-resident production path; this is the semantic reference.
    """
    u = p["u"].astype(jnp.float32)

    def step(S, inp):
        r_t, k_t, v_t, w_t = (i.astype(jnp.float32) for i in inp)
        kv = k_t[..., None] * v_t[..., None, :]            # (B,H,hs,hs)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, S + u[..., None] * kv)
        S = w_t[..., None] * S + kv
        return S, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, w))
    T = xs[0].shape[0]
    if T > chunk and T % chunk == 0:
        xs = jax.tree.map(
            lambda a: a.reshape(T // chunk, chunk, *a.shape[1:]), xs)

        def chunk_body(S, xc):
            S = shard_act(S, ("batch", "heads", "head_dim", None))
            return jax.lax.scan(step, S, xc)

        S, ys = jax.lax.scan(jax.checkpoint(chunk_body), s0, xs)
        ys = ys.reshape(T, *ys.shape[2:])
    else:
        S, ys = jax.lax.scan(step, s0, xs)
    return S, jnp.moveaxis(ys, 0, 1)                       # (B,S,H,hs)


def _tmix_out(p, y, g, cfg: ArchConfig):
    """Per-head group-norm, gate, output projection."""
    B, S, H, hs = y.shape
    mu = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.var(y, axis=-1, keepdims=True)
    y = ((y - mu) * jax.lax.rsqrt(var + 1e-5)).reshape(B, S, H * hs)
    y = y * p["ln_scale"].astype(jnp.float32) \
        + p["ln_bias"].astype(jnp.float32)
    y = y.astype(g.dtype) * g
    return y @ p["wo"].astype(g.dtype)


def apply_rwkv_tmix(p, x, cfg: ArchConfig, *, return_state: bool = False):
    B, S, D = x.shape
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    r, k, v, g, w = _tmix_proj(p, x, x_prev, cfg)
    H, hs = _rwkv_dims(cfg)
    s0 = jnp.zeros((B, H, hs, hs), jnp.float32)
    s, y = _wkv_scan(p, r, k, v, w, s0)
    out = _tmix_out(p, y, g, cfg)
    if return_state:
        return out, {"s": s, "x_tmix": x[:, -1]}
    return out


def rwkv_cmix_decls(cfg: ArchConfig) -> dict:
    D = cfg.d_model
    return {
        "mu_k": P((D,), ("embed",)),
        "mu_r": P((D,), ("embed",)),
        "wk": P((D, cfg.d_ff), ("embed", "mlp")),
        "wv": P((cfg.d_ff, D), ("mlp", "embed"), "scaled"),
        "wr": P((D, D), ("embed", "embed2")),
    }


def apply_rwkv_cmix(p, x, cfg: ArchConfig, x_prev=None):
    dt_ = x.dtype
    if x_prev is None:
        x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    dx = x_prev - x
    xk = x + dx * p["mu_k"].astype(dt_)
    xr = x + dx * p["mu_r"].astype(dt_)
    k = jnp.square(jax.nn.relu(xk @ p["wk"].astype(dt_)))
    return jax.nn.sigmoid(xr @ p["wr"].astype(dt_)) * (k @ p["wv"].astype(dt_))


def init_rwkv_state(cfg: ArchConfig, batch: int) -> dict:
    H, hs = _rwkv_dims(cfg)
    D = cfg.d_model
    dt_ = jnp.dtype(cfg.dtype)
    return {"s": jnp.zeros((batch, H, hs, hs), jnp.float32),
            "x_tmix": jnp.zeros((batch, D), dt_),
            "x_cmix": jnp.zeros((batch, D), dt_)}


def rwkv_tmix_step(p, x, state, cfg: ArchConfig):
    """One-token decode. x: (B,1,D)."""
    x_prev = state["x_tmix"][:, None, :]
    r, k, v, g, w = _tmix_proj(p, x, x_prev, cfg)
    S, y = _wkv_scan(p, r, k, v, w, state["s"])
    out = _tmix_out(p, y, g, cfg)
    return out, {"s": S, "x_tmix": x[:, 0]}


def rwkv_cmix_step(p, x, state_x, cfg: ArchConfig):
    out = apply_rwkv_cmix(p, x, cfg, x_prev=state_x[:, None, :])
    return out, x[:, 0]
