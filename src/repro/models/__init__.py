"""LM workload substrate: composable model definitions for all assigned
architecture families (dense GQA, encoder-only, VLM backbone, MoE, hybrid
Mamba+attention, RWKV6)."""
from . import attention, layers, moe, ssm, stacks
from .config import ArchConfig, Family, MambaSpec, MoESpec, RWKVSpec
from .model import (abstract_model, decode_step, forward, init_decode_state,
                    init_model, loss_fn, model_axes, model_decls, prefill)

__all__ = [
    "attention", "layers", "moe", "ssm", "stacks",
    "ArchConfig", "Family", "MoESpec", "MambaSpec", "RWKVSpec",
    "abstract_model", "decode_step", "forward", "init_decode_state",
    "init_model", "loss_fn", "model_axes", "model_decls", "prefill",
]
