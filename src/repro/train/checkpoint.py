"""Sharded, atomic, elastically-restorable checkpoints.

Layout (one directory per step)::

    <root>/step_000420.tmp/        # written first
        manifest.json              # treedef, shapes, dtypes, step, meta
        leaf_00000.npy ...         # one file per pytree leaf
    <root>/step_000420/            # atomic rename == commit

Rename-commit means a crash mid-save never corrupts the latest checkpoint
(restore only ever sees committed directories); this is the property the
kill-and-restore fault-tolerance test exercises.

Elastic restore: leaves are stored as *global* arrays with their logical
path, so a restore may apply a different mesh/sharding than the save
(``device_put`` with the new sharding) — tested by
``tests/test_checkpoint.py::test_elastic_resharding``.

At real pod scale each host would write only its addressable shards
(``path + shard_idx``); the manifest format already records per-leaf
shapes/dtypes so that layout is a drop-in extension.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(root: str, step: int, tree, *, meta: dict | None = None,
         keep: int = 3) -> str:
    """Atomically persist a pytree.  Returns the committed directory."""
    os.makedirs(root, exist_ok=True)
    name = f"step_{step:08d}"
    tmp = os.path.join(root, name + ".tmp")
    final = os.path.join(root, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves, treedef = _flatten(tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "paths": [str(p) for p, _ in
                  jax.tree_util.tree_flatten_with_path(tree)[0]],
        "leaves": [{"file": f"leaf_{i:05d}.npy",
                    "shape": list(np.shape(x)),
                    "dtype": str(np.asarray(x).dtype)}
                   for i, x in enumerate(leaves)],
        "meta": meta or {},
    }
    for i, x in enumerate(leaves):
        np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), np.asarray(x))
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # commit
    _retain(root, keep)
    return final


def _retain(root: str, keep: int):
    steps = sorted(all_steps(root))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(root, f"step_{s:08d}"), ignore_errors=True)


def all_steps(root: str) -> list[int]:
    if not os.path.isdir(root):
        return []
    out = []
    for d in os.listdir(root):
        if d.startswith("step_") and not d.endswith(".tmp") \
                and os.path.exists(os.path.join(root, d, "manifest.json")):
            out.append(int(d.split("_")[1]))
    return sorted(out)


def latest_step(root: str) -> int | None:
    steps = all_steps(root)
    return steps[-1] if steps else None


def restore(root: str, like, *, step: int | None = None,
            shardings=None) -> tuple[int, object, dict]:
    """Restore into the structure of ``like`` (values ignored).

    ``shardings``: optional pytree of NamedSharding matching ``like`` —
    the *elastic* path: the saved global arrays are placed onto whatever
    mesh the restoring job runs (may differ from the saving job's).
    """
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints under {root}")
    d = os.path.join(root, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    _, treedef = _flatten(like)
    leaves = [np.load(os.path.join(d, rec["file"]))
              for rec in manifest["leaves"]]
    if shardings is not None:
        flat_sh = treedef.flatten_up_to(shardings)
        leaves = [jax.device_put(x, s) for x, s in zip(leaves, flat_sh)]
    tree = treedef.unflatten(leaves)
    return step, tree, manifest.get("meta", {})
