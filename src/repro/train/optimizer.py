"""AdamW with warmup-cosine schedule and global-norm clipping.

Built from scratch (no optax in this environment).  The optimizer state is
a pytree mirroring the params, so the sharding rules apply to it unchanged
(ZeRO-style: m/v inherit each param's sharding — the FSDP axis shards
optimizer state for free).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


class OptState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


def init(params) -> OptState:
    z = jax.tree.map(jnp.zeros_like, params)
    return OptState(step=jnp.zeros((), jnp.int32), m=z,
                    v=jax.tree.map(jnp.zeros_like, params))


def schedule(cfg: OptConfig, step):
    """Linear warmup → cosine decay to min_lr_frac·lr."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(cfg: OptConfig, grads, state: OptState, params):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        step_p = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_p).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(step, new_m, new_v), metrics
