"""Gradient compression with error feedback (collective-bound lever).

Int8 block-quantized gradients cut data-parallel all-reduce traffic 4×
(fp32) / 2× (bf16); the residual of each quantization is carried into the
next step (error feedback, Seide et al. / Karimireddy et al.), which is
what keeps convergence intact.  In the SPMD program the all-reduce is
implicit — compression is applied to the gradient *as it would enter the
wire*: quantize → (all-reduce) → dequantize, so the measured §Perf effect
on the collective roofline term is the real 4× operand-byte reduction.

Off by default; tested for convergence parity in
``tests/test_extensions.py``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: dict            # pytree like grads (fp32)


def init_state(params) -> EFState:
    return EFState(jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def _quantize_block(g, block: int = 256):
    """Symmetric int8 with per-block scales. g: any shape, fp32."""
    flat = g.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % block
    flat = jnp.pad(flat, (0, pad)).reshape(-1, block)
    scale = jnp.max(jnp.abs(flat), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(flat / jnp.maximum(scale, 1e-30)),
                 -127, 127).astype(jnp.int8)
    return q, scale, n


def _dequantize(q, scale, n, shape):
    return (q.astype(jnp.float32) * scale).reshape(-1)[:n].reshape(shape)


def compress_grads(grads, ef: EFState, *, block: int = 256):
    """Returns (dequantized grads as seen post-all-reduce, new EF state).

    The int8 payload is what crosses the wire; the fp32 view returned here
    is bit-identical to dequantize(all-reduce(quantize(...))) under
    deterministic summation, so optimizer semantics are exact.
    """
    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q, scale, n = _quantize_block(gf, block)
        deq = _dequantize(q, scale, n, gf.shape)
        return deq, gf - deq                 # error feedback residual

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(ef.residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    new_g = tdef.unflatten([o[0] for o in outs])
    new_r = tdef.unflatten([o[1] for o in outs])
    return new_g, EFState(new_r)


def wire_bytes(params) -> dict:
    """Uncompressed vs int8 wire bytes for one gradient all-reduce."""
    n = sum(p.size for p in jax.tree.leaves(params))
    return {"fp32": 4 * n, "int8": n + 4 * (n // 256 + 1)}
