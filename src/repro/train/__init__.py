"""Training substrate: from-scratch AdamW, deterministic data pipeline,
atomic sharded checkpoints, fault-tolerant training loop."""
from . import checkpoint, data, optimizer, trainer
from .optimizer import OptConfig, OptState
from .trainer import NodeFailure, TrainConfig, make_train_step, train

__all__ = ["checkpoint", "data", "optimizer", "trainer", "OptConfig",
           "OptState", "NodeFailure", "TrainConfig", "make_train_step",
           "train"]
