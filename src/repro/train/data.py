"""Deterministic synthetic data pipeline.

Stateless-by-construction: the batch for global step ``s`` is a pure
function of ``(seed, s)`` via ``fold_in``, so

* resume-after-restart is exact (no iterator state to checkpoint beyond
  the step counter),
* each data-parallel shard draws its own fold (host ``h`` reads only its
  slice — the multi-host pattern, degenerate on 1 host),
* property tests can replay any step.

The token distribution is Zipfian with a Markov "document" structure —
enough statistical texture for loss curves to be meaningful, with no
external datasets (everything offline).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2


def _zipf_logits(cfg: DataConfig):
    ranks = jnp.arange(1, cfg.vocab + 1, dtype=jnp.float32)
    return -cfg.zipf_a * jnp.log(ranks)


def batch_at(cfg: DataConfig, step, *, shard: int = 0, n_shards: int = 1):
    """Batch for a global step (this shard's slice).  jit-able."""
    assert cfg.global_batch % n_shards == 0
    b = cfg.global_batch // n_shards
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step), shard)
    k1, k2 = jax.random.split(key)
    base = jax.random.categorical(
        k1, _zipf_logits(cfg), shape=(b, cfg.seq_len + 1))
    # Markov structure: with p=0.5 repeat-shifted previous token (gives
    # learnable bigram statistics, so tiny-model loss visibly drops)
    rep = jax.random.bernoulli(k2, 0.5, (b, cfg.seq_len + 1))
    prev = jnp.roll(base, 1, axis=1)
    toks = jnp.where(rep, (prev + 1) % cfg.vocab, base)
    return {"inputs": toks[:, :-1].astype(jnp.int32),
            "labels": toks[:, 1:].astype(jnp.int32)}


def embedding_batch_at(cfg: DataConfig, d_model: int, step, *,
                       shard: int = 0, n_shards: int = 1,
                       dtype=jnp.bfloat16):
    """Frontend-stub variant: (B,S,D) embeddings + class labels."""
    b = cfg.global_batch // n_shards
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step), shard + 977)
    k1, k2 = jax.random.split(key)
    emb = jax.random.normal(k1, (b, cfg.seq_len, d_model), jnp.float32)
    labels = jax.random.randint(k2, (b, cfg.seq_len), 0, cfg.vocab)
    return {"inputs": emb.astype(dtype), "labels": labels.astype(jnp.int32)}
