"""Training loop: jitted step, checkpoint/restart, straggler watchdog,
failure recovery.

Fault-tolerance contract (exercised by ``tests/test_trainer.py``):

* every ``ckpt_every`` steps the full (params, opt, data-step) state is
  committed atomically (``checkpoint.py``);
* a step that raises (injected failure / real node loss) triggers restore
  of the last committed state and replay — because the data pipeline is a
  pure function of the step counter, replay is bit-exact;
* a step-walltime watchdog tracks a robust median and flags stragglers
  (at pod scale the flag feeds the re-slotting policy; here it is
  surfaced in metrics and tested with an injected slow step).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable

import jax
import numpy as np

from repro.models import ArchConfig, init_model, loss_fn

from . import checkpoint, data, optimizer


class NodeFailure(RuntimeError):
    """Raised (by the runtime or an injected fault hook) when a step loses
    a node; the loop restores the last committed checkpoint and replays."""


@dataclass
class TrainConfig:
    steps: int = 100
    seed: int = 0
    seq_len: int = 128
    global_batch: int = 8
    opt: optimizer.OptConfig = field(default_factory=optimizer.OptConfig)
    ckpt_dir: str | None = None
    ckpt_every: int = 50
    log_every: int = 10
    straggler_factor: float = 3.0     # step > factor x median -> flagged
    max_restarts: int = 3


def make_train_step(cfg: ArchConfig, opt_cfg: optimizer.OptConfig, *,
                    attn_impl: str = "auto", unroll: bool = False,
                    donate: bool = True):
    """The jitted (params, opt_state, batch) -> (params, opt_state, metrics)."""

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch, attn_impl=attn_impl,
                              unroll=unroll))(params)
        params, opt_state, m = optimizer.update(opt_cfg, grads, opt_state,
                                                params)
        m["loss"] = loss
        return params, opt_state, m

    kw = {"donate_argnums": (0, 1)} if donate else {}
    return jax.jit(step, **kw)


def train(cfg: ArchConfig, tc: TrainConfig, *,
          fault_hook: Callable[[int], None] | None = None,
          resume: bool = True) -> dict:
    """Run the loop.  ``fault_hook(step)`` may raise to simulate node loss
    (the loop restores the last checkpoint and replays)."""
    dcfg = data.DataConfig(vocab=cfg.vocab, seq_len=tc.seq_len,
                           global_batch=tc.global_batch, seed=tc.seed)
    opt_cfg = tc.opt.replace(total_steps=tc.steps)
    step_fn = make_train_step(cfg, opt_cfg)
    batch_fn = jax.jit(lambda s: (
        data.embedding_batch_at(dcfg, cfg.d_model, s, dtype=jax.numpy.dtype(
            cfg.dtype)) if cfg.embedding_inputs else data.batch_at(dcfg, s)))

    params = init_model(jax.random.PRNGKey(tc.seed), cfg)
    opt_state = optimizer.init(params)
    start = 0
    if resume and tc.ckpt_dir and checkpoint.latest_step(tc.ckpt_dir) is not None:
        start, (params, opt_state), _ = checkpoint.restore(
            tc.ckpt_dir, (params, opt_state))

    history = {"loss": [], "grad_norm": [], "straggler_steps": [],
               "restarts": 0, "resumed_at": start}
    times: list[float] = []
    s = start
    restarts = 0
    while s < tc.steps:
        t0 = time.perf_counter()
        try:
            if fault_hook is not None:
                fault_hook(s)
            batch = batch_fn(s)
            params, opt_state, m = step_fn(params, opt_state, batch)
            loss = float(m["loss"])
        except NodeFailure:
            restarts += 1
            if restarts > tc.max_restarts or not tc.ckpt_dir:
                raise
            s, (params, opt_state), _ = checkpoint.restore(
                tc.ckpt_dir, (params, opt_state))
            history["restarts"] = restarts
            continue
        dt = time.perf_counter() - t0
        times.append(dt)
        med = float(np.median(times[-50:]))
        if len(times) > 5 and dt > tc.straggler_factor * med:
            history["straggler_steps"].append(s)
        history["loss"].append(loss)
        history["grad_norm"].append(float(m["grad_norm"]))
        s += 1
        if tc.ckpt_dir and (s % tc.ckpt_every == 0 or s == tc.steps):
            checkpoint.save(tc.ckpt_dir, s, (params, opt_state),
                            meta={"loss": loss})
    history["final_loss"] = history["loss"][-1] if history["loss"] else None
    return history
