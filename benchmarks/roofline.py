"""Roofline extraction: dryrun_results.json -> per-cell three-term table.

Methodology (see EXPERIMENTS.md §Roofline):

* The compiled SPMD module's shapes are per-device, so ``cost_analysis()``
  FLOPs/bytes and the parsed collective bytes are *per-device* quantities.
* XLA counts a ``lax.scan`` body once, so per-cell we also compile depth
  variants (1 period, 0 periods) and correct:
      X(L) = X_full + (periods - 1) · (X(L1) - X(L0))
  for FLOPs, bytes and collective traffic (the layer scan is the only
  collective-carrying loop).
* Intra-layer scans (flash-style attention block loops, the chunked
  cross-entropy) are corrected analytically — their bodies contain no
  collectives, and the analytic terms are exact for matmul FLOPs.
* SSM time-scan recurrences (mamba/rwkv elementwise updates) are < 1 % of
  layer FLOPs at the assigned sizes and are noted, not corrected.

Terms (TPU v5e): compute = F / 197e12, memory = B / 819e9,
collective = wire_bytes / 50e9 (per-device wire bytes under ring
algorithms, one ICI link conservative).
"""
from __future__ import annotations

import json
import os
import sys

from repro import configs
from repro.configs import SHAPES
from repro.launch.dryrun import microbatches
from repro.models.stacks import _pattern_period

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
CHIPS = 256


# ---------------------------------------------------------------------------
# analytic model FLOPs (6·N·D, active params for MoE) + scan corrections
# ---------------------------------------------------------------------------

def param_counts(cfg) -> dict:
    """Total and active parameter counts from the config."""
    D, F, V = cfg.d_model, cfg.d_ff, cfg.padded_vocab
    per_layer_tot = per_layer_act = 0.0
    for entry in cfg.block_pattern():
        if entry["mixer"] == "attn":
            mix = D * cfg.head_dim * (cfg.n_heads * 2 + cfg.n_kv_heads * 2)
        elif entry["mixer"] == "mamba":
            di = cfg.mamba.expand * D
            dtr = cfg.mamba.dt_rank or max(D // 16, 1)
            ds = cfg.mamba.d_state
            mix = D * 2 * di + di * (dtr + 2 * ds) + dtr * di + 2 * di * D
        else:                                   # rwkv tmix
            mix = 5 * D * D + 2 * D * (cfg.rwkv.decay_lora
                                       + 5 * cfg.rwkv.mix_lora)
        if entry["mlp"] == "moe":
            e_tot = cfg.moe.n_experts * 3 * D * F
            e_act = cfg.moe.top_k * 3 * D * F
            mlp_tot, mlp_act = e_tot, e_act
        elif entry["mlp"] == "rwkv_cmix":
            mlp_tot = mlp_act = D * F + F * D + D * D
        else:
            n_mat = 3 if cfg.act == "swiglu" else 2
            mlp_tot = mlp_act = n_mat * D * F
        per_layer_tot += mix + mlp_tot
        per_layer_act += mix + mlp_act
    embed = V * D * (1 if cfg.tie_embeddings else 2)
    return {"total": per_layer_tot + embed,
            "active": per_layer_act + embed,
            "active_no_embed": per_layer_act,
            "head": V * D}


def model_flops(cfg, shape_name: str) -> float:
    """6·N_active·tokens (train) / 2·N_active·tokens (inference), global."""
    s = SHAPES[shape_name]
    tokens = s.global_batch * (1 if s.kind == "decode" else s.seq_len)
    n = param_counts(cfg)["active"]
    mult = 6.0 if s.kind == "train" else 2.0
    return mult * n * tokens


def _attn_layers(cfg) -> int:
    return sum(1 for e in cfg.block_pattern() if e["mixer"] == "attn")


def analytic_attention_flops(cfg, shape_name: str) -> float:
    """Exact matmul FLOPs of the blocked attention loops (global).

    QKᵀ + PV = 4·B·Hq·S·T·Dh per layer forward; the blocked schedule
    computes all block pairs (no causal skip).  Train: ×4 (fwd + remat
    recompute + backward ≈ 2×fwd).  Decode cells don't scan — no term.
    """
    s = SHAPES[shape_name]
    if s.kind == "decode" or _attn_layers(cfg) == 0:
        return 0.0
    T = s.seq_len
    f = 4.0 * s.global_batch * cfg.n_heads * s.seq_len * T * cfg.head_dim
    mult = 4.0 if s.kind == "train" else 1.0
    return f * mult * _attn_layers(cfg)


def analytic_xent_flops(cfg, shape_name: str) -> float:
    """LM-head matmul FLOPs hidden inside the chunked-xent scan (global)."""
    s = SHAPES[shape_name]
    if s.kind != "train":
        return 0.0
    f = 2.0 * s.global_batch * s.seq_len * cfg.d_model * cfg.padded_vocab
    return 4.0 * f                              # fwd + recompute + bwd


def analytic_attention_bytes(cfg, shape_name: str) -> float:
    """HBM traffic of the attention block loops (q/k/v block streams)."""
    s = SHAPES[shape_name]
    if s.kind == "decode" or _attn_layers(cfg) == 0:
        return 0.0
    B, S = s.global_batch, s.seq_len
    bq, bk = 512, 1024
    n_pairs = (S // bq) * (S // bk)
    per_pair = (bq + 2 * bk) * cfg.head_dim * B * cfg.n_heads * 2
    mult = 4.0 if s.kind == "train" else 1.0
    return n_pairs * per_pair * mult * _attn_layers(cfg)


# ---------------------------------------------------------------------------
# record assembly
# ---------------------------------------------------------------------------

def corrected_cell(results: dict, arch: str, shape: str) -> dict | None:
    key = f"{arch}|{shape}|16x16|"
    full = results.get(key + "full")
    if full is None:
        return None
    l1, l0 = results.get(key + "L1"), results.get(key + "L0")
    cfg = configs.get(arch)
    periods = full["n_periods"] or 1

    def corr(field):
        x = full[field]
        if l1 is not None and l0 is not None:
            x += (periods - 1) * (l1[field] - l0[field])
        return x

    # grad-accumulation scan: body counted once -> multiply by n_micro
    # (the optimizer update outside the scan is ~10 flops/param, < 0.1 %)
    n_micro = microbatches(cfg, SHAPES[shape])
    flops = corr("flops") * n_micro
    byts = corr("bytes_accessed") * n_micro
    wire = corr("collective_wire_bytes") * n_micro
    operand = corr("collective_operand_bytes") * n_micro
    # analytic intra-layer scan corrections (global, full batch -> /device)
    flops += (analytic_attention_flops(cfg, shape)
              + analytic_xent_flops(cfg, shape)) / CHIPS
    byts += analytic_attention_bytes(cfg, shape) / CHIPS

    mf = model_flops(cfg, shape) / CHIPS
    terms = {"compute_s": flops / PEAK_FLOPS, "memory_s": byts / HBM_BW,
             "collective_s": wire / LINK_BW}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    return {
        "arch": arch, "shape": shape,
        "flops": flops, "bytes": byts, "wire": wire,
        "collective_operand_bytes": operand,
        **terms,
        "dominant": dom.replace("_s", ""),
        "model_flops": mf,
        "useful_ratio": mf / flops if flops else 0.0,
        "roofline_frac": (mf / PEAK_FLOPS) / bound if bound else 0.0,
        "temp_gib": full["memory"]["temp_bytes"] / 2**30,
        "microbatches": microbatches(cfg, SHAPES[shape]),
    }


def all_corrected(path: str) -> list[dict]:
    with open(path) as f:
        results = json.load(f)
    out = []
    for arch, shape in configs.all_cells():
        rec = corrected_cell(results, arch, shape)
        if rec is not None:
            out.append(rec)
    return out


def render_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | "
           "dominant | MODEL/HLO | roofline frac | temp GiB |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_frac']:.2%} | {r['temp_gib']:.1f} |")
    return hdr + "\n".join(lines)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results_opt.json"
    rows = all_corrected(path)
    print(render_table(rows))
    print()
    worst = sorted(rows, key=lambda r: r["roofline_frac"])[:5]
    coll = sorted(rows, key=lambda r: -r["collective_s"] /
                  max(r["compute_s"], 1e-30))[:5]
    print("worst roofline fraction:", [(r["arch"], r["shape"]) for r in worst])
    print("most collective-bound:", [(r["arch"], r["shape"]) for r in coll])
    out_csv = os.path.join(os.path.dirname(path) or ".", "roofline.csv")
    with open(out_csv, "w") as f:
        cols = list(rows[0].keys()) if rows else []
        f.write(",".join(cols) + "\n")
        for r in rows:
            f.write(",".join(str(r[c]) for c in cols) + "\n")
    print("wrote", out_csv)


if __name__ == "__main__":
    main()
