"""Beyond-paper study: Hadoop-style speculative execution under straggler
severity sweep (uses the reference simulator extension).  Run directly:

    PYTHONPATH=src python -m benchmarks.speculative_execution
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import paper_scenario, speculative


def study(sigmas=(0.0, 0.2, 0.4, 0.6, 0.8), n_seeds=20):
    rows = []
    for sigma in sigmas:
        sc = paper_scenario(n_maps=16, n_vms=16)
        t0 = time.perf_counter()
        sp, work = [], []
        for seed in range(n_seeds):
            mult = ([1.0] * sc.total_tasks() if sigma == 0.0 else
                    speculative.straggler_multipliers(sc, sigma, seed))
            r = speculative.simulate_speculative(sc, mult, threshold=1.5)
            sp.append(r["speedup"])
            work.append(r["extra_work_frac"])
        us = (time.perf_counter() - t0) / n_seeds * 1e6
        rows.append((f"spec_exec_speedup_sigma{sigma}", us,
                     f"{np.mean(sp):.3f}x(+{np.mean(work):.1%}work)"))
    return rows


def bench_rows(sigmas=(0.0, 0.4, 0.8), n_seeds=20):
    """Rows in the ``BENCH_sweep.json`` schema (name, us, us_min, derived,
    realized_epochs, meta) so ``sweep_throughput.main`` can record the
    study next to the engine rows.  The fluid model is event-driven, not
    epoch-stepped, so ``realized_epochs`` is 0 and the meta names the
    model; ``us_min`` is the per-seed noise floor."""
    rows = []
    for sigma in sigmas:
        sc = paper_scenario(n_maps=16, n_vms=16)
        times, sp, work, nb = [], [], [], []
        for seed in range(n_seeds):
            mult = ([1.0] * sc.total_tasks() if sigma == 0.0 else
                    speculative.straggler_multipliers(sc, sigma, seed))
            t0 = time.perf_counter()
            r = speculative.simulate_speculative(sc, mult, threshold=1.5)
            times.append(time.perf_counter() - t0)
            sp.append(r["speedup"])
            work.append(r["extra_work_frac"])
            nb.append(r["n_backups"])
        rows.append((f"spec_exec_sigma{sigma}", np.mean(times) * 1e6,
                     min(times) * 1e6,
                     f"{np.mean(sp):.3f}x(+{np.mean(work):.1%}work)", 0,
                     {"model": "fluid_speculation", "sigma": sigma,
                      "n_seeds": n_seeds, "threshold": 1.5,
                      "mean_backups": round(float(np.mean(nb)), 2)}))
    return rows


def all_rows():
    return study()


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for n, us, d in all_rows():
        print(f"{n},{us:.1f},{d}")
