"""Sweep-throughput benchmark: the TPU adaptation's headline number.

CloudSim runs one scenario per process; the vectorized engine runs a whole
parameter grid per ``pjit`` call.  We measure scenarios/second on the host
CPU (single device) and — because the sweep is embarrassingly parallel with
zero collectives (verified by the dry-run) — the pod-scale figure is
devices × single-device throughput, reported as the derived column.

The measured path is the declarative API end to end:
:func:`~repro.core.sweep.zip_`-ed random axes compiled and executed by
``SweepPlan.run()`` (encode + simulate + labeled readback per call) under
the adaptive execution schedule (DESIGN.md §6 — shape buckets + batch
early exit), so each row also records the *realized* epoch count next to
the worst-case ``2T + 2`` bound the pre-adaptive engine always paid.

Mixed-policy gap: scheduling policies differ in how many event epochs a
scenario intrinsically needs (space-shared admission serializes starts), so
comparing a mixed grid's scen/s against the all-time-shared row conflates
policy mixing with policy *cost*.  The ``unifpol`` row therefore runs the
mixed grid's exact workload as six per-combination uniform plans (summed
wall time) — the relevant baseline for "what does mixing policies inside
one batch cost?".  The recorded gap is mixed vs that.

Locality rows: the ``_locality_b*`` rows re-run the workload with the
storage subsystem on (DESIGN.md §7 — skewed hot-spot placement,
replication 1–3 per lane, LOCALITY binding), timing the placement hash +
candidate-masked binding scan + fetch-delay ops the block store adds to
the encode path; each row records its placement/replication meta.

Elastic rows: the ``_elastic_b*`` rows run the workload as a dynamic
fleet (DESIGN.md §8 — Poisson job arrivals as ``job_submit``, per-VM
lease windows with spinup, priorities per lane, and *mixed* scheduling
policies: priorities and window-gated admission only bite under
space-shared queues), timing the lease-availability masking +
window-gated admission the elastic epoch loop adds.  Because the row
mixes sched policies, its honest comparator is the ``mixedpol`` row
(which pays the same policy-mixing tax, PR 3), NOT the all-time-shared
plain row — the recorded gap is ``elastic_gap_vs_mixedpol``; each row
records its arrival-rate/process/policy-mix meta.

Control rows: the ``_control_b*`` rows run the elastic workload through
the closed-loop lowering (DESIGN.md §10 — per-lane seeded VM
failure/restore streams with failover re-dispatch, plus the AUTOSCALE
per-epoch hook over a reserve-free fleet, so the hook is evaluated every
epoch but never strands work on an unopened reserve), timing the fail
event join + kill/redispatch ops + hook contraction the control loop
adds.  The workload *is* the elastic grid plus control columns, so the
honest comparator is the elastic row — timed min-of-alternating-A/B
(like the compaction pair) and recorded as ``control_gap_vs_elastic``.

Traced row: the ``_traced_b64`` row times the deadline workload at the
engine level with the in-loop trace lowering on (DESIGN.md §12 — one-hot
time-series scatter + bounded event log inside the epoch loop), min-of-
alternating-A/B against the same jitted call with tracing off, recorded
as ``trace_gap_vs_plain``.  The trace-*off* side is bitwise the plain
path (the lowering inserts no ops when off) — ``bench_smoke`` guards
that identity with a tightened budget on the plain b64 row.

``python -m benchmarks.sweep_throughput`` records the rows plus
backend/device metadata (and a small calibration figure that lets CI gate
regressions across machine speeds, see ``benchmarks.bench_smoke``) to
``BENCH_sweep.json`` at the repo root, the perf-trajectory baseline.
"""
from __future__ import annotations

import functools
import json
import multiprocessing
import pathlib
import platform
import time

import jax
import numpy as np

from repro.core import (BindingPolicy, ControlPolicy, Placement,
                        SchedPolicy, control as ctl, costmodel, elasticity,
                        engine, telemetry)
from repro.core.sweep import axis, product, zip_

EPOCH_BOUND = 2 * 21 + 2   # the pre-adaptive engine's static bound at T=21
LOC_PLACEMENT = int(Placement.SKEWED)   # locality rows' placement variant
LOC_REPLICATION = "1-3"                 # … and replication-factor range
ELASTIC_RATE = 0.002                    # elastic rows' Poisson arrival rate
TAIL_MAPS = 40                          # tailheavy rows' uniform map count
TAIL_PAD = TAIL_MAPS + 1                # … and their task padding (T=41)
CONTROL_RATE = 0.0005                   # control rows' per-VM failure rate
CONTROL_REPAIR = 600.0                  # … and repair delay (seconds)


def _random_cols(n, rng, mixed_policies=False, locality=False,
                 elastic=False, tailheavy=False, control=False,
                 deadline=False):
    cols = dict(
        n_maps=rng.integers(1, 21, n).astype(np.int32),
        n_reduces=np.ones(n, np.int32),
        n_vms=rng.integers(1, 10, n).astype(np.int32),
        vm_mips=rng.choice([250.0, 500.0, 1000.0], n).astype(np.float32),
        vm_pes=rng.choice([1.0, 2.0, 4.0], n).astype(np.float32),
        vm_cost=rng.choice([1.0, 2.0, 4.0], n).astype(np.float32),
        job_length=rng.choice([362880.0, 725760.0, 1451520.0], n
                              ).astype(np.float32),
        job_data=rng.choice([2e5, 4e5, 8e5], n).astype(np.float32),
    )
    if mixed_policies:
        cols["sched_policy"] = rng.integers(0, 2, n).astype(np.int32)
        cols["binding_policy"] = rng.integers(0, 3, n).astype(np.int32)
    if locality:
        # the storage-subsystem workload (DESIGN.md §7): block store on,
        # skewed hot-spot placement, LOCALITY bound per lane — the
        # placement hash + candidate-masked binding scan now sit on the
        # encode path this row times
        cols["binding_policy"] = np.full(
            n, int(BindingPolicy.LOCALITY), np.int32)
        cols["storage_enabled"] = np.ones(n, np.float32)
        cols["replication"] = rng.integers(1, 4, n).astype(np.int32)
        cols["placement"] = np.full(n, LOC_PLACEMENT, np.int32)
        cols["block_size_mb"] = rng.choice([8192.0, 32768.0], n
                                           ).astype(np.float32)
        cols["storage_seed"] = rng.integers(0, 1000, n).astype(np.int32)
    if elastic or control or deadline:
        # the dynamic-fleet workload (DESIGN.md §8): Poisson job arrivals
        # against per-VM lease windows with spinup and mixed priorities —
        # the availability masking + window-gated admission now sit on the
        # epoch loop this row times.  Windows are generous (open-ended or
        # arrival + 40k s) so lanes realize full schedules, not strands.
        cols["job_submit"] = elasticity.arrival_times(
            n, rate=ELASTIC_RATE, seed=n)
        start = rng.choice([0.0, 500.0, 2000.0], (n, 9)).astype(np.float32)
        cols["vm_start"] = start
        cols["vm_stop"] = np.where(rng.random((n, 9)) < 0.5, 1e30,
                                   start + cols["job_submit"][:, None]
                                   + 40000.0).astype(np.float32)
        cols["spinup_delay"] = rng.choice([0.0, 60.0], n).astype(np.float32)
        cols["task_prio"] = rng.integers(0, 3, (n, 21)).astype(np.float32)
        cols["sched_policy"] = rng.integers(0, 2, n).astype(np.int32)
    if control or deadline:
        # the closed-loop workload (DESIGN.md §10): the elastic grid plus
        # per-lane seeded failure/restore streams (one flat counter-hash
        # draw resliced per lane — same idiom, distinct instants) and the
        # AUTOSCALE hook over a reserve-free fleet: the fail event joins
        # t_next, kills re-dispatch after a detection delay, and the hook
        # contraction runs every epoch — without opened-reserve dynamics
        # that would strand time-shared lanes and benchmark stranding
        # instead of control cost
        f, r = ctl.failure_times(9 * n, rate=CONTROL_RATE, seed=n,
                                 repair_delay=CONTROL_REPAIR)
        cols["vm_fail"] = np.asarray(f, np.float32).reshape(n, 9)
        cols["vm_restore"] = np.asarray(r, np.float32).reshape(n, 9)
        cols["redispatch_delay"] = rng.choice([0.0, 30.0], n
                                              ).astype(np.float32)
        cols["control_policy"] = np.full(n, int(ControlPolicy.AUTOSCALE),
                                         np.int32)
        cols["ctl_queue"] = rng.choice([2.0, 8.0], n).astype(np.float32)
        cols["ctl_busy"] = np.full(n, 0.5, np.float32)
    if deadline:
        # the graceful-degradation workload (DESIGN.md §11): the control
        # grid plus per-task deadlines with SHED/BOOST lanes and priority
        # preemption armed — the earliest-finish admission predicate, the
        # urgency tier and the per-VM eviction scan now sit on the epoch
        # loop this row times.  Half the deadlines are the _BIG sentinel
        # (absent), the rest clear the job's submit time by construction
        # so the plan validates; slack varies so BOOST lanes fire at
        # different urgencies.
        dl = (cols["job_submit"][:, None]
              + rng.choice([3000.0, 12000.0, 48000.0], (n, 21))
              ).astype(np.float32)
        cols["task_deadline"] = np.where(rng.random((n, 21)) < 0.5,
                                         1e30, dl).astype(np.float32)
        cols["deadline_policy"] = rng.integers(1, 3, n).astype(np.int32)
        cols["deadline_slack"] = rng.choice([0.0, 120.0], n
                                            ).astype(np.float32)
        cols["preempt"] = np.ones(n, np.int32)
        cols["preempt_resume"] = rng.integers(0, 2, n).astype(np.int32)
    if tailheavy:
        # the sparse-compaction workload (DESIGN.md §9): every lane runs
        # the SAME 40-map space-shared shape — one policy combo, one
        # shape, so the static policy/shape bucketing cannot isolate the
        # tail — but ~1/8 of lanes are stragglers stuck on a single 1-PE
        # VM: 40 sequential admissions -> ~2·T realized epochs, while
        # the rest spread their maps over 12-36 PEs and retire within a
        # few epochs.  The tail is *data-dependent inside one compiled
        # bucket*, exactly the regime compaction targets: the dense
        # driver steps all lanes to the last straggler, the compacted
        # driver steps only the pow2-padded survivors.  Lane 0 is always
        # a straggler so every batch size realizes >= 20 epochs (the
        # bench_smoke gate asserts it).
        strag = rng.random(n) < 1.0 / 8.0
        strag[0] = True
        cols["n_maps"] = np.full(n, TAIL_MAPS, np.int32)
        cols["n_vms"] = np.where(strag, 1,
                                 rng.integers(6, 10, n)).astype(np.int32)
        cols["vm_pes"] = np.where(strag, 1.0,
                                  rng.choice([2.0, 4.0], n)
                                  ).astype(np.float32)
        cols["sched_policy"] = np.ones(n, np.int32)
        cols["binding_policy"] = np.zeros(n, np.int32)
    return cols


def _plan_of(cols, pad_tasks=21):
    # one zipped dimension: all columns advance together (a labeled random
    # scenario list, not a cartesian grid)
    plan = product(zip_(*(axis(k, v) for k, v in cols.items())))
    return plan.replace(pad_tasks=pad_tasks, pad_vms=9)


def _random_plan(n, rng, mixed_policies=False, locality=False,
                 elastic=False, tailheavy=False, control=False,
                 deadline=False):
    return _plan_of(_random_cols(n, rng, mixed_policies, locality, elastic,
                                 tailheavy, control, deadline),
                    pad_tasks=TAIL_PAD if tailheavy else 21)


def _time_runs(run, reps=7):
    """(mean_seconds, min_seconds, last_result) over ``reps`` timed calls.

    The mean is the trend-tracking figure; the min is the noise floor the
    CI gate (``bench_smoke``) compares against — gating a local min-of-7
    against a recorded *mean* left no headroom whenever the machine-speed
    calibration drifted between samples.  ``reps=7`` matches the gate's
    min-of-7: this host's noise is bimodal on minute timescales, and a
    recorded min-of-3 regularly missed the fast phase the min-of-15
    calibration catches, skewing the row/calibration ratio the gate
    budgets on."""
    run()                                       # compile + warm caches
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        res = run()
        times.append(time.perf_counter() - t0)
    return sum(times) / reps, min(times), res


def _time_ab(run_a, run_b, reps=7):
    """Min-of-alternating-A/B: interleave the two variants' timed calls so
    this host's bimodal slow phases hit both sides equally — timing A's
    seven reps back-to-back and then B's lets one variant land entirely in
    a fast phase and fabricate a gap.  Returns ``(mean_a, min_a, mean_b,
    min_b)`` in seconds; the mins are the noise floors the recorded
    A-vs-B gaps use."""
    run_a()                                     # compile + warm caches
    run_b()
    times_a, times_b = [], []
    for _ in range(reps):
        t0 = time.perf_counter()
        run_a()
        times_a.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        run_b()
        times_b.append(time.perf_counter() - t0)
    return (sum(times_a) / reps, min(times_a),
            sum(times_b) / reps, min(times_b))


def throughput_rows(batch_sizes=(64, 512, 2048), reps=7,
                    mixed_policies=False, locality=False, elastic=False):
    rows = []
    tag = ("_elastic" if elastic else "_locality" if locality
           else "_mixedpol" if mixed_policies else "")
    meta = None
    if locality:
        meta = {"placement": Placement(LOC_PLACEMENT).name.lower(),
                "replication": LOC_REPLICATION, "storage": True}
    elif elastic:
        meta = {"arrival": "poisson", "arrival_rate": ELASTIC_RATE,
                "leases": True, "spinup": "0|60",
                "sched_policy": "mixed"}
    for n in batch_sizes:
        # seed == batch size: every b{n} row draws the same base columns
        # regardless of which batch sizes the call sweeps, so variant rows
        # (plain / mixedpol / locality / elastic) at one n are the *same
        # workload* and their recorded gaps measure the variant, not rng
        # drift
        plan = _random_plan(n, np.random.default_rng(n), mixed_policies,
                            locality, elastic)
        dt, dt_min, res = _time_runs(plan.run, reps)
        rows.append((f"sweep_throughput{tag}_b{n}", dt * 1e6, dt_min * 1e6,
                     f"{n / dt:.0f}_scen/s",
                     int(res["realized_epochs"].max()), meta))
    return rows


def tailheavy_rows(batch_sizes=(64, 2048), reps=7):
    """Dense vs compacted execution on the tail-heavy grid (DESIGN.md §9).

    The pair of rows per batch size is timed min-of-alternating-A/B
    (:func:`_time_ab`): A is the dense bucketed ``run()``, B the same plan
    with ``compact="auto"`` — the auto interval and the bucket boundaries
    both come from the measured cost model.  The compact row's meta
    records its ``compaction_gap_vs_dense`` (min-vs-min; negative =
    compaction is faster) plus the host-chattiness census at the pinned
    ``auto_k`` — full pulls / scalar pulls / dispatches from a
    ``report=True`` replay — which ``bench_smoke`` re-derives and gates
    (the census is deterministic given the grid and the interval, unlike
    the wall times)."""
    rows = []
    for n in batch_sizes:
        plan = _random_plan(n, np.random.default_rng(n), tailheavy=True)
        res = [None]

        def run_compact(plan=plan, res=res):
            res[0] = plan.run(compact="auto")

        dt_a, min_a, dt_b, min_b = _time_ab(plan.run, run_compact, reps)
        realized = int(res[0]["realized_epochs"].max())
        k_auto = costmodel.default_cost_model().compact_interval(n, TAIL_PAD)
        # census replay at the *pinned* interval: machine-independent, so
        # a smoke run on any host can compare its own census 1:1
        _, rep = plan.run(compact=k_auto, report=True)
        tail = f"1/8_stragglers_{TAIL_MAPS}maps_1vm_spaceshared"
        rows.append((f"sweep_throughput_tailheavy_b{n}", dt_a * 1e6,
                     min_a * 1e6, f"{n / dt_a:.0f}_scen/s", realized,
                     {"tail": tail}))
        rows.append((f"sweep_throughput_tailheavy_compact_b{n}",
                     dt_b * 1e6, min_b * 1e6, f"{n / dt_b:.0f}_scen/s",
                     realized,
                     {"tail": tail,
                      "compact": "auto", "auto_k": k_auto,
                      "timing": "min_of_alternating_ab",
                      "compaction_gap_vs_dense": round(min_b / min_a - 1.0,
                                                       4),
                      "census": {"k": k_auto,
                                 "compaction_syncs": rep.compaction_syncs,
                                 "scalar_syncs": rep.scalar_syncs,
                                 "dispatches": rep.dispatches}}))
    return rows


def compact_loop_rows(batch_sizes=(64, 2048), reps=7):
    """The dispatch-lean compact loop vs the legacy per-round-sync loop
    (DESIGN.md §13) at the *engine* level.

    Both sides run :func:`engine.simulate_batch_arrays_compact` on the
    tail-heavy batch at the same measured-cost interval K; the only
    difference is the host loop: A (``legacy=True``) reproduces the
    pre-lean driver — a full activity-mask device->host pull every round,
    host-side argsort-free compaction order, no buffer donation — while B
    is the lean loop — one fused 2-scalar pull per round, the on-device
    active-first permutation materialized only on compacting rounds, and
    carries/stores donated across the stepper and scatter calls.  Timed
    min-of-alternating-A/B; the lean row's meta records
    ``lean_speedup_vs_legacy`` (min-vs-min), both sides' sync/dispatch
    census, and the cost coefficients that picked K."""
    rows = []
    cost = costmodel.default_cost_model()
    for n in batch_sizes:
        batch = _random_plan(n, np.random.default_rng(n),
                             tailheavy=True).arrays()
        k = cost.compact_interval(n, TAIL_PAD)
        realized = [0]

        def run_legacy(batch=batch, k=k):
            out, _ = engine.simulate_batch_arrays_compact(batch, k=k,
                                                          legacy=True)
            jax.block_until_ready(out)

        def run_lean(batch=batch, k=k, realized=realized):
            out, rz = engine.simulate_batch_arrays_compact(batch, k=k)
            jax.block_until_ready(out)
            realized[0] = int(rz)

        dt_a, min_a, dt_b, min_b = _time_ab(run_legacy, run_lean, reps)
        st_legacy, st_lean = {}, {}
        engine.simulate_batch_arrays_compact(batch, k=k, legacy=True,
                                             stats=st_legacy)
        engine.simulate_batch_arrays_compact(batch, k=k, stats=st_lean)
        census = {"k": k,
                  "legacy": {key: st_legacy[key] for key in
                             ("dispatches", "syncs", "scalar_syncs",
                              "compactions")},
                  "lean": {key: st_lean[key] for key in
                           ("dispatches", "syncs", "scalar_syncs",
                            "compactions")}}
        rows.append((f"sweep_throughput_compactloop_legacy_b{n}",
                     dt_a * 1e6, min_a * 1e6, f"{n / dt_a:.0f}_scen/s",
                     realized[0],
                     {"k": k, "loop": "legacy_per_round_sync",
                      "timing": "min_of_alternating_ab"}))
        rows.append((f"sweep_throughput_compactloop_lean_b{n}",
                     dt_b * 1e6, min_b * 1e6, f"{n / dt_b:.0f}_scen/s",
                     realized[0],
                     {"k": k, "loop": "lean_scalar_sync_donated",
                      "donate": True,
                      "timing": "min_of_alternating_ab",
                      "lean_speedup_vs_legacy": round(min_a / min_b, 4),
                      "census": census,
                      "cost_model": {"dispatch_us": cost.dispatch_us,
                                     "sync_us": cost.sync_us,
                                     "epoch_lane_us": cost.epoch_lane_us,
                                     "device": cost.device,
                                     "source": cost.source}}))
    return rows


def control_rows(batch_sizes=(64, 2048), reps=7):
    """Closed-loop control vs the open-loop elastic grid (DESIGN.md §10).

    The pair per batch size is timed min-of-alternating-A/B
    (:func:`_time_ab`): A is the elastic plan (same rng(n) base draw), B
    the same draw with the control columns on — seeded failure/restore
    streams, redispatch, the AUTOSCALE hook.  Only the control row is
    recorded; its meta carries ``control_gap_vs_elastic`` (min-vs-min
    against the alternated A side, so the gap measures the lowering, not
    machine drift)."""
    rows = []
    for n in batch_sizes:
        plan_a = _random_plan(n, np.random.default_rng(n), elastic=True)
        plan_b = _random_plan(n, np.random.default_rng(n), control=True)
        res = [None]

        def run_control(plan_b=plan_b, res=res):
            res[0] = plan_b.run()

        dt_a, min_a, dt_b, min_b = _time_ab(plan_a.run, run_control, reps)
        injected = int(np.asarray(res[0]["failures_injected"]).sum())
        rows.append((f"sweep_throughput_control_b{n}", dt_b * 1e6,
                     min_b * 1e6, f"{n / dt_b:.0f}_scen/s",
                     int(res[0]["realized_epochs"].max()),
                     {"failure_rate": CONTROL_RATE,
                      "repair_delay": CONTROL_REPAIR,
                      "policy": "autoscale_hook_no_reserves",
                      "failures_injected": injected,
                      "timing": "min_of_alternating_ab",
                      "control_gap_vs_elastic": round(min_b / min_a - 1.0,
                                                      4)}))
    return rows


def deadline_rows(batch_sizes=(64, 2048), reps=7):
    """Graceful degradation vs the closed-loop control grid (DESIGN.md §11).

    The pair per batch size is timed min-of-alternating-A/B
    (:func:`_time_ab`): A is the control plan (same rng(n) base draw), B
    the same draw with the deadline columns on — per-task deadlines,
    SHED/BOOST policies, priority preemption with and without
    partial-progress resume.  Only the deadline row is recorded; its meta
    carries ``deadline_gap_vs_control`` (min-vs-min against the alternated
    A side), plus the realized shed/preemption census so the row proves
    the degradation machinery actually fired."""
    rows = []
    for n in batch_sizes:
        plan_a = _random_plan(n, np.random.default_rng(n), control=True)
        plan_b = _random_plan(n, np.random.default_rng(n), deadline=True)
        res = [None]

        def run_deadline(plan_b=plan_b, res=res):
            res[0] = plan_b.run()

        dt_a, min_a, dt_b, min_b = _time_ab(plan_a.run, run_deadline, reps)
        shed = int(np.asarray(res[0]["shed_tasks"]).sum())
        pre = int(np.asarray(res[0]["preemptions"]).sum())
        rows.append((f"sweep_throughput_deadline_b{n}", dt_b * 1e6,
                     min_b * 1e6, f"{n / dt_b:.0f}_scen/s",
                     int(res[0]["realized_epochs"].max()),
                     {"policy_mix": "shed|boost", "preempt": True,
                      "shed_tasks": shed, "preemptions": pre,
                      "timing": "min_of_alternating_ab",
                      "deadline_gap_vs_control": round(min_b / min_a - 1.0,
                                                       4)}))
    return rows


def traced_rows(n=64, reps=7):
    """In-loop tracing vs the plain engine path (DESIGN.md §12).

    The pair is timed min-of-alternating-A/B at the *engine* level — the
    same jitted :func:`engine.simulate_batch_arrays` call on the deadline
    b64 batch (every subsystem lit, so all event kinds can fire) with the
    trace lowering off (A) vs on (B).  Only the traced row is recorded;
    its meta carries ``trace_gap_vs_plain`` (min-vs-min — what the one-hot
    time-series scatter + bounded event log cost *inside* the epoch loop),
    the event census from a warm traced call, and — the observability
    contract of DESIGN.md §12.4 — the run provenance and cost-model
    coefficients (with their measured/cache/fallback ``source``) that the
    report/export paths stamp.  The trace-off side is the identity the
    ``bench_smoke`` plain-path guard protects: with ``trace=False`` the
    lowering inserts no ops at all."""
    batch = _random_plan(n, np.random.default_rng(n), deadline=True).arrays()
    run_plain = jax.jit(functools.partial(
        engine.simulate_batch_arrays, control=True))
    run_traced = jax.jit(functools.partial(
        engine.simulate_batch_arrays, control=True, trace=True))
    res = [None]

    def a():
        jax.block_until_ready(run_plain(batch))

    def b(res=res):
        res[0] = jax.block_until_ready(run_traced(batch))

    dt_a, min_a, dt_b, min_b = _time_ab(a, b, reps)
    out, realized, tb = res[0]
    tr = telemetry.TraceResult(tb, label=f"traced_b{n}")
    counts = tr.counts_by_kind()
    cost = costmodel.default_cost_model()
    return [(f"sweep_throughput_traced_b{n}", dt_b * 1e6, min_b * 1e6,
             f"{n / dt_b:.0f}_scen/s", int(np.asarray(realized).max()),
             {"trace": "timeseries+events",
              "events_logged": int(sum(counts.values())),
              "dropped_events": int(tr.dropped_events.sum()),
              "timing": "min_of_alternating_ab",
              "trace_gap_vs_plain": round(min_b / min_a - 1.0, 4),
              "cost_model": {"dispatch_us": cost.dispatch_us,
                             "sync_us": cost.sync_us,
                             "epoch_lane_us": cost.epoch_lane_us,
                             "device": cost.device, "source": cost.source},
              "provenance": dict(telemetry.provenance())})]


def unifpol_rows(n=2048, reps=7):
    """The mixed grid's workload as six per-policy-combo uniform plans.

    Policy-uniform sub-batches are the fair reference for the mixed row:
    each combo pays only its own realized epoch count, exactly what a user
    running six separate uniform sweeps would see.  Summed wall time over
    the same 2048 scenarios -> directly comparable scen/s.
    """
    # same rng(n) draw as the mixedpol b{n} row -> identical grid
    cols = _random_cols(n, np.random.default_rng(n), mixed_policies=True)
    plans = []
    for sp in SchedPolicy:
        for bp in BindingPolicy:
            pick = np.nonzero((cols["sched_policy"] == int(sp))
                              & (cols["binding_policy"] == int(bp)))[0]
            if len(pick) == 0:      # small n may leave a combo unpopulated
                continue
            sub = {k: v[pick] for k, v in cols.items()
                   if k not in ("sched_policy", "binding_policy")}
            plans.append(_plan_of(sub).replace(
                base=dict(sched_policy=sp, binding_policy=bp)))

    realized = [0]

    def run_all():
        out = [p.run() for p in plans]
        realized[0] = max(int(r["realized_epochs"].max()) for r in out)
        return out

    dt, dt_min, _ = _time_runs(run_all, reps)
    return [(f"sweep_throughput_unifpol_b{n}", dt * 1e6, dt_min * 1e6,
             f"{n / dt:.0f}_scen/s", realized[0], None)]


def calibration_us(reps=15):
    """A fixed miniature sweep (b16 `run()`, min over reps — the noise
    floor, since this feeds a pass/fail gate) timed on this machine and
    stored with the baseline, so CI smoke runs can scale the regression
    gate by relative machine speed.  Deliberately the same code path as
    the gated workload — dispatch + encode + epoch loop + readback — so
    the ratio tracks the real cost profile, which a pure-compute matmul
    calibration would not (the b64 row is dispatch-dominated)."""
    plan = _random_plan(16, np.random.default_rng(123))
    plan.run()                                     # compile + warm caches
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        plan.run()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def all_rows():
    # mixed-policy row: same grid with random (sched, binding) per scenario —
    # policy diversity is data, so one adaptive schedule serves all scenarios
    # within the batch; the unifpol row is its uniform-execution reference.
    # locality rows: the same workload with the block store on (skewed
    # placement, LOCALITY binding) — what the storage subsystem costs.
    # elastic rows: the same workload as a dynamic fleet (arrivals, lease
    # windows, priorities) — what the elasticity subsystem costs.
    # tailheavy rows: one compiled shape whose 1/8 straggler lanes run
    # ~2T epochs while the rest retire early — dense vs compact="auto"
    # timed alternating-A/B (what sparse compaction buys on the
    # data-dependent tail it targets).
    return (throughput_rows()
            + throughput_rows(batch_sizes=(2048,), mixed_policies=True)
            + unifpol_rows()
            + throughput_rows(batch_sizes=(64, 2048), locality=True)
            + throughput_rows(batch_sizes=(64, 2048), elastic=True)
            + tailheavy_rows()
            + compact_loop_rows()
            + control_rows()
            + deadline_rows()
            + traced_rows())


def main() -> None:
    rows = all_rows()
    by_name = {r[0]: r for r in rows}
    mixed = by_name["sweep_throughput_mixedpol_b2048"][1]
    unif = by_name["sweep_throughput_unifpol_b2048"][1]
    plain = by_name["sweep_throughput_b2048"][1]
    loc = by_name["sweep_throughput_locality_b2048"][1]
    # elastic mixes sched policies (priorities/window admission need
    # space-shared lanes), so its comparator is the mixedpol row — the
    # plain all-time-shared row would mostly measure the policy-mixing
    # tax PR 3 already quantifies, not elasticity
    ela = by_name["sweep_throughput_elastic_b2048"][1]
    # compaction gap: noise-floor min vs min on the alternating-A/B pair
    th_dense = by_name["sweep_throughput_tailheavy_b2048"][2]
    th_comp = by_name["sweep_throughput_tailheavy_compact_b2048"][2]
    # lean-loop gain: the engine-level legacy-vs-lean A/B pair (§13)
    lean_speedup = by_name["sweep_throughput_compactloop_lean_b2048"][5][
        "lean_speedup_vs_legacy"]
    # control gap: already min-vs-min from its own alternating-A/B pair
    ctl_gap = by_name["sweep_throughput_control_b2048"][5][
        "control_gap_vs_elastic"]
    # deadline gap: ditto, against the control comparator (DESIGN.md §11)
    dl_gap = by_name["sweep_throughput_deadline_b2048"][5][
        "deadline_gap_vs_control"]
    # trace gap: min-of-A/B at the engine level (DESIGN.md §12) — the cost
    # of turning the in-loop trace lowering ON; the OFF side is bitwise the
    # plain path and is guarded separately by bench_smoke
    tr_meta = by_name["sweep_throughput_traced_b64"][5]
    tr_gap = tr_meta["trace_gap_vs_plain"]
    # the fluid speculative-execution study rides along in the same schema
    from . import speculative_execution
    rows = rows + speculative_execution.bench_rows()
    out = pathlib.Path(__file__).resolve().parent.parent / "BENCH_sweep.json"
    payload = {
        "benchmark": "sweep_throughput (SweepPlan.run end-to-end, "
                     "adaptive schedule)",
        "meta": {
            "backend": jax.default_backend(),
            "device": jax.devices()[0].device_kind,
            "device_count": jax.device_count(),
            "cpu_count": multiprocessing.cpu_count(),
            "platform": platform.platform(),
            "epoch_bound": EPOCH_BOUND,
            "calibration_us": round(calibration_us(), 1),
            "mixedpol_gap_vs_unifpol": round(mixed / unif - 1.0, 4),
            "locality_gap_vs_plain": round(loc / plain - 1.0, 4),
            "elastic_gap_vs_mixedpol": round(ela / mixed - 1.0, 4),
            "compaction_gap_vs_dense": round(th_comp / th_dense - 1.0, 4),
            "compaction_speedup_tailheavy_b2048": round(th_dense / th_comp,
                                                        2),
            "compact_lean_speedup_vs_legacy_b2048": lean_speedup,
            "control_gap_vs_elastic": ctl_gap,
            "deadline_gap_vs_control": dl_gap,
            "trace_gap_vs_plain": tr_gap,
            # run provenance + cost-model transparency (DESIGN.md §12.4):
            # which build/device produced this baseline, and whether the
            # bucket-split coefficients were measured here or loaded
            "provenance": tr_meta["provenance"],
            "cost_model": tr_meta["cost_model"],
        },
        "rows": [{"name": n, "us_per_call": round(us, 1),
                  "us_per_call_min": round(us_min, 1), "derived": d,
                  "realized_epochs": ep,
                  **({"meta": m} if m else {})}
                 for n, us, us_min, d, ep, m in rows],
    }
    out.write_text(json.dumps(payload, indent=2) + "\n")
    for r in payload["rows"]:
        print(f"{r['name']},{r['us_per_call']},{r['derived']},"
              f"epochs={r['realized_epochs']}/{EPOCH_BOUND}")
    print(f"mixedpol vs unifpol gap: "
          f"{payload['meta']['mixedpol_gap_vs_unifpol']:+.1%}")
    print(f"locality (storage on) vs plain b2048 gap: "
          f"{payload['meta']['locality_gap_vs_plain']:+.1%}")
    print(f"elastic (dynamic fleet) vs mixedpol b2048 gap: "
          f"{payload['meta']['elastic_gap_vs_mixedpol']:+.1%}")
    print(f"compaction vs dense tailheavy b2048 (min-of-A/B): "
          f"{payload['meta']['compaction_speedup_tailheavy_b2048']:.2f}x")
    print(f"lean vs legacy compact loop b2048 (min-of-A/B): "
          f"{payload['meta']['compact_lean_speedup_vs_legacy_b2048']:.2f}x")
    print(f"control (closed-loop) vs elastic b2048 gap (min-of-A/B): "
          f"{payload['meta']['control_gap_vs_elastic']:+.1%}")
    print(f"deadline (graceful degradation) vs control b2048 gap "
          f"(min-of-A/B): {payload['meta']['deadline_gap_vs_control']:+.1%}")
    print(f"trace (in-loop telemetry) vs plain engine b64 gap "
          f"(min-of-A/B): {payload['meta']['trace_gap_vs_plain']:+.1%}")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
