"""Sweep-throughput benchmark: the TPU adaptation's headline number.

CloudSim runs one scenario per process; the vectorized engine runs a whole
parameter grid per ``pjit`` call.  We measure scenarios/second on the host
CPU (single device) and — because the sweep is embarrassingly parallel with
zero collectives (verified by the dry-run) — the pod-scale figure is
devices × single-device throughput, reported as the derived column.

The measured path is the declarative API end to end:
:func:`~repro.core.sweep.zip_`-ed random axes compiled and executed by
``SweepPlan.run()`` (encode + simulate + labeled readback per call).

``python -m benchmarks.sweep_throughput`` records the rows to
``BENCH_sweep.json`` at the repo root, the perf-trajectory baseline.
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np

from repro.core.sweep import axis, product, zip_


def _random_plan(n, rng, mixed_policies=False):
    cols = dict(
        n_maps=rng.integers(1, 21, n).astype(np.int32),
        n_reduces=np.ones(n, np.int32),
        n_vms=rng.integers(1, 10, n).astype(np.int32),
        vm_mips=rng.choice([250.0, 500.0, 1000.0], n).astype(np.float32),
        vm_pes=rng.choice([1.0, 2.0, 4.0], n).astype(np.float32),
        vm_cost=rng.choice([1.0, 2.0, 4.0], n).astype(np.float32),
        job_length=rng.choice([362880.0, 725760.0, 1451520.0], n
                              ).astype(np.float32),
        job_data=rng.choice([2e5, 4e5, 8e5], n).astype(np.float32),
    )
    if mixed_policies:
        cols["sched_policy"] = rng.integers(0, 2, n).astype(np.int32)
        cols["binding_policy"] = rng.integers(0, 3, n).astype(np.int32)
    # one zipped dimension: all columns advance together (a labeled random
    # scenario list, not a cartesian grid)
    plan = product(zip_(*(axis(k, v) for k, v in cols.items())))
    return plan.replace(pad_tasks=21, pad_vms=9)


def throughput_rows(batch_sizes=(64, 512, 2048), reps=3,
                    mixed_policies=False):
    rows = []
    rng = np.random.default_rng(0)
    tag = "_mixedpol" if mixed_policies else ""
    for n in batch_sizes:
        plan = _random_plan(n, rng, mixed_policies)
        plan.run()                                  # compile + warm caches
        t0 = time.perf_counter()
        for _ in range(reps):
            plan.run()
        dt = (time.perf_counter() - t0) / reps
        us_per_call = dt * 1e6
        scen_per_s = n / dt
        rows.append((f"sweep_throughput{tag}_b{n}", us_per_call,
                     f"{scen_per_s:.0f}_scen/s"))
    return rows


def all_rows():
    # mixed-policy row: same grid with random (sched, binding) per scenario —
    # policy diversity is data, so one lowering serves all scenarios *within*
    # the batch (this row still traces separately from the default row, whose
    # plan leaves the policy columns to encode_cell's defaults)
    return (throughput_rows()
            + throughput_rows(batch_sizes=(2048,), mixed_policies=True))


def main() -> None:
    rows = all_rows()
    out = pathlib.Path(__file__).resolve().parent.parent / "BENCH_sweep.json"
    payload = {
        "benchmark": "sweep_throughput (SweepPlan.run end-to-end)",
        "rows": [{"name": n, "us_per_call": round(us, 1), "derived": d}
                 for n, us, d in rows],
    }
    out.write_text(json.dumps(payload, indent=2) + "\n")
    for r in payload["rows"]:
        print(f"{r['name']},{r['us_per_call']},{r['derived']}")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
