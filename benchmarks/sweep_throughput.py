"""Sweep-throughput benchmark: the TPU adaptation's headline number.

CloudSim runs one scenario per process; the vectorized engine runs a whole
parameter grid per ``pjit`` call.  We measure scenarios/second on the host
CPU (single device) and — because the sweep is embarrassingly parallel with
zero collectives (verified by the dry-run) — the pod-scale figure is
devices × single-device throughput, reported as the derived column.

The measured path is the declarative API end to end:
:func:`~repro.core.sweep.zip_`-ed random axes compiled and executed by
``SweepPlan.run()`` (encode + simulate + labeled readback per call) under
the adaptive execution schedule (DESIGN.md §6 — shape buckets + batch
early exit), so each row also records the *realized* epoch count next to
the worst-case ``2T + 2`` bound the pre-adaptive engine always paid.

Mixed-policy gap: scheduling policies differ in how many event epochs a
scenario intrinsically needs (space-shared admission serializes starts), so
comparing a mixed grid's scen/s against the all-time-shared row conflates
policy mixing with policy *cost*.  The ``unifpol`` row therefore runs the
mixed grid's exact workload as six per-combination uniform plans (summed
wall time) — the relevant baseline for "what does mixing policies inside
one batch cost?".  The recorded gap is mixed vs that.

``python -m benchmarks.sweep_throughput`` records the rows plus
backend/device metadata (and a small calibration figure that lets CI gate
regressions across machine speeds, see ``benchmarks.bench_smoke``) to
``BENCH_sweep.json`` at the repo root, the perf-trajectory baseline.
"""
from __future__ import annotations

import json
import multiprocessing
import pathlib
import platform
import time

import jax
import numpy as np

from repro.core import BindingPolicy, SchedPolicy
from repro.core.sweep import axis, product, zip_

EPOCH_BOUND = 2 * 21 + 2   # the pre-adaptive engine's static bound at T=21


def _random_cols(n, rng, mixed_policies=False):
    cols = dict(
        n_maps=rng.integers(1, 21, n).astype(np.int32),
        n_reduces=np.ones(n, np.int32),
        n_vms=rng.integers(1, 10, n).astype(np.int32),
        vm_mips=rng.choice([250.0, 500.0, 1000.0], n).astype(np.float32),
        vm_pes=rng.choice([1.0, 2.0, 4.0], n).astype(np.float32),
        vm_cost=rng.choice([1.0, 2.0, 4.0], n).astype(np.float32),
        job_length=rng.choice([362880.0, 725760.0, 1451520.0], n
                              ).astype(np.float32),
        job_data=rng.choice([2e5, 4e5, 8e5], n).astype(np.float32),
    )
    if mixed_policies:
        cols["sched_policy"] = rng.integers(0, 2, n).astype(np.int32)
        cols["binding_policy"] = rng.integers(0, 3, n).astype(np.int32)
    return cols


def _plan_of(cols):
    # one zipped dimension: all columns advance together (a labeled random
    # scenario list, not a cartesian grid)
    plan = product(zip_(*(axis(k, v) for k, v in cols.items())))
    return plan.replace(pad_tasks=21, pad_vms=9)


def _random_plan(n, rng, mixed_policies=False):
    return _plan_of(_random_cols(n, rng, mixed_policies))


def _time_runs(run, reps=3):
    run()                                       # compile + warm caches
    t0 = time.perf_counter()
    for _ in range(reps):
        res = run()
    return (time.perf_counter() - t0) / reps, res


def throughput_rows(batch_sizes=(64, 512, 2048), reps=3,
                    mixed_policies=False):
    rows = []
    rng = np.random.default_rng(0)
    tag = "_mixedpol" if mixed_policies else ""
    for n in batch_sizes:
        plan = _random_plan(n, rng, mixed_policies)
        dt, res = _time_runs(plan.run, reps)
        rows.append((f"sweep_throughput{tag}_b{n}", dt * 1e6,
                     f"{n / dt:.0f}_scen/s",
                     int(res["realized_epochs"].max())))
    return rows


def unifpol_rows(n=2048, reps=3):
    """The mixed grid's workload as six per-policy-combo uniform plans.

    Policy-uniform sub-batches are the fair reference for the mixed row:
    each combo pays only its own realized epoch count, exactly what a user
    running six separate uniform sweeps would see.  Summed wall time over
    the same 2048 scenarios -> directly comparable scen/s.
    """
    # same fresh rng(0) first-draw as the mixedpol row -> identical grid
    cols = _random_cols(n, np.random.default_rng(0), mixed_policies=True)
    plans = []
    for sp in SchedPolicy:
        for bp in BindingPolicy:
            pick = np.nonzero((cols["sched_policy"] == int(sp))
                              & (cols["binding_policy"] == int(bp)))[0]
            if len(pick) == 0:      # small n may leave a combo unpopulated
                continue
            sub = {k: v[pick] for k, v in cols.items()
                   if k not in ("sched_policy", "binding_policy")}
            plans.append(_plan_of(sub).replace(
                base=dict(sched_policy=sp, binding_policy=bp)))

    realized = [0]

    def run_all():
        out = [p.run() for p in plans]
        realized[0] = max(int(r["realized_epochs"].max()) for r in out)
        return out

    dt, _ = _time_runs(run_all, reps)
    return [(f"sweep_throughput_unifpol_b{n}", dt * 1e6,
             f"{n / dt:.0f}_scen/s", realized[0])]


def calibration_us(reps=15):
    """A fixed miniature sweep (b16 `run()`, min over reps — the noise
    floor, since this feeds a pass/fail gate) timed on this machine and
    stored with the baseline, so CI smoke runs can scale the regression
    gate by relative machine speed.  Deliberately the same code path as
    the gated workload — dispatch + encode + epoch loop + readback — so
    the ratio tracks the real cost profile, which a pure-compute matmul
    calibration would not (the b64 row is dispatch-dominated)."""
    plan = _random_plan(16, np.random.default_rng(123))
    plan.run()                                     # compile + warm caches
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        plan.run()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def all_rows():
    # mixed-policy row: same grid with random (sched, binding) per scenario —
    # policy diversity is data, so one adaptive schedule serves all scenarios
    # within the batch; the unifpol row is its uniform-execution reference
    return (throughput_rows()
            + throughput_rows(batch_sizes=(2048,), mixed_policies=True)
            + unifpol_rows())


def main() -> None:
    rows = all_rows()
    by_name = {r[0]: r for r in rows}
    mixed = by_name["sweep_throughput_mixedpol_b2048"][1]
    unif = by_name["sweep_throughput_unifpol_b2048"][1]
    out = pathlib.Path(__file__).resolve().parent.parent / "BENCH_sweep.json"
    payload = {
        "benchmark": "sweep_throughput (SweepPlan.run end-to-end, "
                     "adaptive schedule)",
        "meta": {
            "backend": jax.default_backend(),
            "device": jax.devices()[0].device_kind,
            "device_count": jax.device_count(),
            "cpu_count": multiprocessing.cpu_count(),
            "platform": platform.platform(),
            "epoch_bound": EPOCH_BOUND,
            "calibration_us": round(calibration_us(), 1),
            "mixedpol_gap_vs_unifpol": round(mixed / unif - 1.0, 4),
        },
        "rows": [{"name": n, "us_per_call": round(us, 1), "derived": d,
                  "realized_epochs": ep}
                 for n, us, d, ep in rows],
    }
    out.write_text(json.dumps(payload, indent=2) + "\n")
    for r in payload["rows"]:
        print(f"{r['name']},{r['us_per_call']},{r['derived']},"
              f"epochs={r['realized_epochs']}/{EPOCH_BOUND}")
    print(f"mixedpol vs unifpol gap: "
          f"{payload['meta']['mixedpol_gap_vs_unifpol']:+.1%}")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
