"""Sweep-throughput benchmark: the TPU adaptation's headline number.

CloudSim runs one scenario per process; the vectorized engine runs a whole
parameter grid per ``pjit`` call.  We measure scenarios/second on the host
CPU (single device) and — because the sweep is embarrassingly parallel with
zero collectives (verified by the dry-run) — the pod-scale figure is
devices × single-device throughput, reported as the derived column.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import sweep


def throughput_rows(batch_sizes=(64, 512, 2048), reps=3):
    rows = []
    rng = np.random.default_rng(0)
    for n in batch_sizes:
        params = dict(
            n_maps=rng.integers(1, 21, n).astype(np.int32),
            n_reduces=np.ones(n, np.int32),
            n_vms=rng.integers(1, 10, n).astype(np.int32),
            vm_mips=rng.choice([250.0, 500.0, 1000.0], n).astype(np.float32),
            vm_pes=rng.choice([1.0, 2.0, 4.0], n).astype(np.float32),
            vm_cost=rng.choice([1.0, 2.0, 4.0], n).astype(np.float32),
            job_length=rng.choice([362880.0, 725760.0, 1451520.0], n
                                  ).astype(np.float32),
            job_data=rng.choice([2e5, 4e5, 8e5], n).astype(np.float32),
        )
        batch = sweep.grid_arrays(params, pad_tasks=21, pad_vms=9)
        out = sweep.simulate_batch(batch)
        out.makespan.block_until_ready()
        t0 = time.perf_counter()
        for _ in range(reps):
            sweep.simulate_batch(batch).makespan.block_until_ready()
        dt = (time.perf_counter() - t0) / reps
        us_per_call = dt * 1e6
        scen_per_s = n / dt
        rows.append((f"sweep_throughput_b{n}", us_per_call,
                     f"{scen_per_s:.0f}_scen/s"))
    return rows


def all_rows():
    return throughput_rows()
