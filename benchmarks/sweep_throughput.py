"""Sweep-throughput benchmark: the TPU adaptation's headline number.

CloudSim runs one scenario per process; the vectorized engine runs a whole
parameter grid per ``pjit`` call.  We measure scenarios/second on the host
CPU (single device) and — because the sweep is embarrassingly parallel with
zero collectives (verified by the dry-run) — the pod-scale figure is
devices × single-device throughput, reported as the derived column.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import sweep


def throughput_rows(batch_sizes=(64, 512, 2048), reps=3,
                    mixed_policies=False):
    rows = []
    rng = np.random.default_rng(0)
    tag = "_mixedpol" if mixed_policies else ""
    for n in batch_sizes:
        params = dict(
            n_maps=rng.integers(1, 21, n).astype(np.int32),
            n_reduces=np.ones(n, np.int32),
            n_vms=rng.integers(1, 10, n).astype(np.int32),
            vm_mips=rng.choice([250.0, 500.0, 1000.0], n).astype(np.float32),
            vm_pes=rng.choice([1.0, 2.0, 4.0], n).astype(np.float32),
            vm_cost=rng.choice([1.0, 2.0, 4.0], n).astype(np.float32),
            job_length=rng.choice([362880.0, 725760.0, 1451520.0], n
                                  ).astype(np.float32),
            job_data=rng.choice([2e5, 4e5, 8e5], n).astype(np.float32),
        )
        if mixed_policies:
            params["sched_policy"] = rng.integers(0, 2, n).astype(np.int32)
            params["binding_policy"] = rng.integers(0, 3, n).astype(np.int32)
        batch = sweep.grid_arrays(params, pad_tasks=21, pad_vms=9)
        out = sweep.simulate_batch(batch)
        out.makespan.block_until_ready()
        t0 = time.perf_counter()
        for _ in range(reps):
            sweep.simulate_batch(batch).makespan.block_until_ready()
        dt = (time.perf_counter() - t0) / reps
        us_per_call = dt * 1e6
        scen_per_s = n / dt
        rows.append((f"sweep_throughput{tag}_b{n}", us_per_call,
                     f"{scen_per_s:.0f}_scen/s"))
    return rows


def all_rows():
    # mixed-policy row: same grid with random (sched, binding) per scenario —
    # policy diversity is data, so one lowering serves all scenarios *within*
    # the batch (this row still traces separately from the default row, whose
    # params dict bakes the policies in as constants)
    return (throughput_rows()
            + throughput_rows(batch_sizes=(2048,), mixed_policies=True))
