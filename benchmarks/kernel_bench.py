"""Kernel micro-benchmarks (interpret-mode timings are NOT TPU numbers —
the derived column carries the jnp-reference comparison + the structural
quantity that matters on TPU: HBM-traffic reduction / FLOP parity)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _time(fn, *args, reps=3):
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn(*args).block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def flash_rows():
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    B, S, Hq, Hkv, Dh = 1, 256, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, Dh))
    k = jax.random.normal(ks[1], (B, S, Hkv, Dh))
    v = jax.random.normal(ks[2], (B, S, Hkv, Dh))
    us = _time(lambda a, b, c: flash_attention(a, b, c, causal=True,
                                               block_q=64, block_k=64),
               q, k, v)
    tr = lambda x: x.transpose(0, 2, 1, 3)
    ref = attention_ref(tr(q), tr(k), tr(v), causal=True).transpose(0, 2, 1, 3)
    got = flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    err = float(jnp.abs(got - ref).max())
    # structural: score-matrix HBM bytes avoided per layer at 32k prefill
    avoided = 32 * 32768 * 32768 * 4 / 2**30
    return [("kernel_flash_attn_interp", us, f"err={err:.1e}"),
            ("kernel_flash_attn_32k_score_GiB_avoided", us,
             f"{avoided:.0f}")]


def wkv_rows():
    from repro.kernels.rwkv6 import wkv6
    from repro.kernels.rwkv6.ref import wkv6_ref
    B, H, T, hs = 1, 2, 128, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    r, k, v = (0.5 * jax.random.normal(ks[i], (B, T, H, hs))
               for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, hs))) * 0.5 + 0.45
    u = 0.3 * jax.random.normal(jax.random.PRNGKey(9), (H, hs))
    us = _time(lambda *a: wkv6(*a, block_t=32), r, k, v, w, u)
    tr = lambda x: x.transpose(0, 2, 1, 3)
    err = float(jnp.abs(wkv6(r, k, v, w, u, block_t=32)
                        - wkv6_ref(tr(r), tr(k), tr(v), tr(w), u)
                        .transpose(0, 2, 1, 3)).max())
    # structural: HBM state traffic, scan (O(T·hs^2)) vs kernel (O(T·hs))
    ratio = hs
    return [("kernel_wkv6_interp", us, f"err={err:.1e}"),
            ("kernel_wkv6_state_traffic_reduction", us, f"{ratio}x")]


def mr_sched_rows():
    import numpy as np

    from repro.core import sweep
    from repro.kernels.mr_sched import schedule
    from repro.kernels.mr_sched.ref import schedule_ref
    batch = sweep.paper_grid(m_range=range(1, 21))
    us_k = _time(lambda b: schedule(b, tile=8)[1], batch)
    us_r = _time(lambda b: schedule_ref(b)[1], batch)
    s_k, f_k = schedule(batch, tile=8)
    s_r, f_r = schedule_ref(batch)
    valid = np.asarray(batch.task_valid)
    err = float(np.abs(np.where(valid, np.asarray(f_k) - np.asarray(f_r),
                                0)).max())
    return [("kernel_mr_sched_interp", us_k, f"err={err:.1e}"),
            ("kernel_mr_sched_xla_engine_ref", us_r, "baseline")]


def all_rows():
    return flash_rows() + wkv_rows() + mr_sched_rows()
