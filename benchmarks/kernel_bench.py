"""Kernel micro-benchmarks (interpret-mode timings are NOT TPU numbers —
the derived column carries the jnp-reference comparison + the structural
quantity that matters on TPU: HBM-traffic reduction / FLOP parity).

``python -m benchmarks.kernel_bench`` additionally sweeps ``mr_epoch``
megakernel tile sizes and records the winners + device metadata to
``BENCH_kernel.json`` at the repo root (interpret-mode numbers rank tile
shapes by the work the schedule actually does — epoch-loop trips × lanes —
which is the quantity the TPU path tiles for; re-run on real hardware to
re-rank).
"""
from __future__ import annotations

import json
import multiprocessing
import pathlib
import platform
import time

import jax
import jax.numpy as jnp


def _time(fn, *args, reps=3):
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn(*args).block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def flash_rows():
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    B, S, Hq, Hkv, Dh = 1, 256, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, Dh))
    k = jax.random.normal(ks[1], (B, S, Hkv, Dh))
    v = jax.random.normal(ks[2], (B, S, Hkv, Dh))
    us = _time(lambda a, b, c: flash_attention(a, b, c, causal=True,
                                               block_q=64, block_k=64),
               q, k, v)
    tr = lambda x: x.transpose(0, 2, 1, 3)
    ref = attention_ref(tr(q), tr(k), tr(v), causal=True).transpose(0, 2, 1, 3)
    got = flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    err = float(jnp.abs(got - ref).max())
    # structural: score-matrix HBM bytes avoided per layer at 32k prefill
    avoided = 32 * 32768 * 32768 * 4 / 2**30
    return [("kernel_flash_attn_interp", us, f"err={err:.1e}"),
            ("kernel_flash_attn_32k_score_GiB_avoided", us,
             f"{avoided:.0f}")]


def wkv_rows():
    from repro.kernels.rwkv6 import wkv6
    from repro.kernels.rwkv6.ref import wkv6_ref
    B, H, T, hs = 1, 2, 128, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    r, k, v = (0.5 * jax.random.normal(ks[i], (B, T, H, hs))
               for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, hs))) * 0.5 + 0.45
    u = 0.3 * jax.random.normal(jax.random.PRNGKey(9), (H, hs))
    us = _time(lambda *a: wkv6(*a, block_t=32), r, k, v, w, u)
    tr = lambda x: x.transpose(0, 2, 1, 3)
    err = float(jnp.abs(wkv6(r, k, v, w, u, block_t=32)
                        - wkv6_ref(tr(r), tr(k), tr(v), tr(w), u)
                        .transpose(0, 2, 1, 3)).max())
    # structural: HBM state traffic, scan (O(T·hs^2)) vs kernel (O(T·hs))
    ratio = hs
    return [("kernel_wkv6_interp", us, f"err={err:.1e}"),
            ("kernel_wkv6_state_traffic_reduction", us, f"{ratio}x")]


def _mr_batch(m_range=range(1, 21)):
    from repro.core import sweep
    return sweep.product(sweep.axis("n_maps", m_range)).arrays()


def mr_sched_rows():
    import numpy as np

    from repro.kernels.mr_sched import epoch_schedule, schedule
    from repro.kernels.mr_sched.ref import schedule_ref
    batch = _mr_batch()
    us_k = _time(lambda b: schedule(b, tile=8)[1], batch)
    us_e = _time(lambda b: epoch_schedule(b, tile=8).finish, batch)
    us_r = _time(lambda b: schedule_ref(b)[1], batch)
    s_r, f_r = schedule_ref(batch)
    valid = np.asarray(batch.task_valid)

    def err(f_k):
        return float(np.abs(np.where(valid,
                                     np.asarray(f_k) - np.asarray(f_r),
                                     0)).max())

    return [("kernel_mr_sched_interp", us_k, f"err={err(schedule(batch, tile=8)[1]):.1e}"),
            ("kernel_mr_epoch_interp", us_e,
             f"err={err(epoch_schedule(batch, tile=8).finish):.1e}"),
            ("kernel_mr_sched_xla_engine_ref", us_r, "baseline")]


def mr_epoch_tile_rows(tiles=(8, 16, 32, 64, 128), n=256, reps=3):
    """Sweep ``mr_epoch`` tile sizes over a mixed-policy random batch.

    A bigger tile amortizes grid steps but couples more lanes to one
    early-exit predicate (the tile runs to its slowest lane); the sweep
    measures that trade-off on this backend.  Returns one row per tile
    plus a winner row.
    """
    from repro.kernels.mr_sched import epoch_schedule
    batch = _mr_tile_batch(n)
    rows, timings = [], {}
    for tile in tiles:
        us = _time(lambda b, t=tile: epoch_schedule(b, tile=t).finish,
                   batch, reps=reps)
        timings[tile] = us
        rows.append((f"kernel_mr_epoch_tile{tile}", us,
                     f"{n / us * 1e6:.0f}_scen/s"))
    best = min(timings, key=timings.get)
    rows.append(("kernel_mr_epoch_best_tile", timings[best], str(best)))
    return rows, best


def mr_epoch_block_rows(blocks=(4, 8, 16, 32), tile=32, n=256, reps=3):
    """Sweep the multi-tile ``block_lanes`` sub-blocking of ``mr_epoch``
    at a fixed lane tile (DESIGN.md §13).

    ``block_lanes=b`` splits each ``tile``-lane grid step into
    ``tile // b`` minor-dimension steps; on TPU the minor grid dimension
    is sequential, so the Pallas pipeline emitter double-buffers the
    ``b``-lane block fetches — HBM->VMEM streaming of the next block
    overlaps the current block's epoch loop.  Each candidate is asserted
    bitwise-equal to the single-tile lowering before it is timed (the
    sub-blocking must be pure pipelining, never a semantic change); the
    winner row records the block the TPU path should use at this tile.
    Interpret-mode numbers rank by work, not TPU wall time — re-run on
    real hardware to re-rank.
    """
    import numpy as np

    from repro.kernels.mr_sched import epoch_schedule
    batch = _mr_tile_batch(n)
    ref = epoch_schedule(batch, tile=tile)
    rows, timings = [], {}
    for block in blocks:
        got = epoch_schedule(batch, tile=tile, block_lanes=block)
        for f in ref._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(ref, f)), np.asarray(getattr(got, f)),
                err_msg=f"mr_epoch block_lanes={block} diverges from "
                        f"single-tile on {f}")
        us = _time(lambda b, blk=block: epoch_schedule(
            b, tile=tile, block_lanes=blk).finish, batch, reps=reps)
        timings[block] = us
        rows.append((f"kernel_mr_epoch_t{tile}_block{block}", us,
                     f"{n / us * 1e6:.0f}_scen/s"))
    best = min(timings, key=timings.get)
    rows.append(("kernel_mr_epoch_best_block_lanes", timings[best],
                 str(best)))
    return rows, best


def _mr_tile_batch(n):
    """The mixed-policy random batch the tile/block sweeps share."""
    import numpy as np

    from repro.core import sweep
    rng = np.random.default_rng(0)
    params = dict(
        n_maps=rng.integers(1, 21, n).astype(np.int32),
        n_reduces=rng.integers(1, 3, n).astype(np.int32),
        n_vms=rng.integers(1, 10, n).astype(np.int32),
        vm_mips=rng.choice([250.0, 500.0, 1000.0], n).astype(np.float32),
        vm_pes=rng.choice([1.0, 2.0, 4.0], n).astype(np.float32),
        vm_cost=np.ones(n, np.float32),
        job_length=rng.choice([362880.0, 725760.0], n).astype(np.float32),
        job_data=rng.choice([2e5, 4e5], n).astype(np.float32),
        sched_policy=rng.integers(0, 2, n).astype(np.int32),
        binding_policy=rng.integers(0, 3, n).astype(np.int32),
    )
    return sweep.grid_arrays(params, pad_tasks=23, pad_vms=9)


def mr_epoch_compact_tile_rows(tiles=(8, 16, 32, 64), n=64, reps=3):
    """Sweep ``mr_epoch`` tiles over the compacted batch shapes the sparse
    host loop actually dispatches (DESIGN.md §9).

    The workload is the tail-heavy grid's straggler residue: ``n`` lanes
    at T=41 whose 1/8 stragglers run ~2·T epochs — the pow2 shape the
    compacted driver re-tiles and re-dispatches after each gather.  The
    timing drives :func:`epoch_schedule_compact` end to end (host loop,
    gather/scatter and chunked kernel included), so the winner is the
    tile the compact path should use at this lane count.  On CPU these
    are interpret-mode numbers (rank, not TPU wall time); on a real TPU
    the ``interpret=None`` default lowers the kernel natively
    (``interpret=False``) and the same sweep re-ranks the tiles.
    """
    import numpy as np

    from repro.core import sweep
    from repro.kernels.mr_sched import epoch_schedule_compact
    rng = np.random.default_rng(1)
    strag = rng.random(n) < 1.0 / 8.0
    strag[0] = True
    params = dict(
        n_maps=np.full(n, 40, np.int32),
        n_reduces=np.ones(n, np.int32),
        n_vms=np.where(strag, 1, rng.integers(6, 10, n)).astype(np.int32),
        vm_mips=rng.choice([250.0, 500.0, 1000.0], n).astype(np.float32),
        vm_pes=np.where(strag, 1.0,
                        rng.choice([2.0, 4.0], n)).astype(np.float32),
        vm_cost=np.ones(n, np.float32),
        job_length=rng.choice([362880.0, 725760.0], n).astype(np.float32),
        job_data=rng.choice([2e5, 4e5], n).astype(np.float32),
        sched_policy=np.ones(n, np.int32),
        binding_policy=np.zeros(n, np.int32),
    )
    batch = sweep.grid_arrays(params, pad_tasks=41, pad_vms=9)
    rows, timings = [], {}
    for tile in tiles:
        def run(b, t=tile):
            out, _ = epoch_schedule_compact(b, k=8, tile=t)
            return out.finish
        us = _time(run, batch, reps=reps)
        timings[tile] = us
        rows.append((f"kernel_mr_epoch_compact_tile{tile}", us,
                     f"{n / us * 1e6:.0f}_scen/s"))
    best = min(timings, key=timings.get)
    rows.append(("kernel_mr_epoch_compact_best_tile", timings[best],
                 str(best)))
    return rows, best


def all_rows():
    return flash_rows() + wkv_rows() + mr_sched_rows()


def main() -> None:
    tile_rows, best_tile = mr_epoch_tile_rows()
    block_rows, best_block = mr_epoch_block_rows()
    compact_rows, best_tile_compact = mr_epoch_compact_tile_rows()
    rows = mr_sched_rows() + tile_rows + block_rows + compact_rows
    out = pathlib.Path(__file__).resolve().parent.parent / "BENCH_kernel.json"
    payload = {
        "benchmark": "mr_sched/mr_epoch kernel micro-benchmarks",
        "meta": {
            "backend": jax.default_backend(),
            "device": jax.devices()[0].device_kind,
            "device_count": jax.device_count(),
            "cpu_count": multiprocessing.cpu_count(),
            "platform": platform.platform(),
            "interpret": jax.default_backend() != "tpu",
            "best_tile": best_tile,
            "best_block_lanes": best_block,
            "best_tile_compact": best_tile_compact,
        },
        "rows": [{"name": n, "us_per_call": round(us, 1), "derived": d}
                 for n, us, d in rows],
    }
    out.write_text(json.dumps(payload, indent=2) + "\n")
    for r in payload["rows"]:
        print(f"{r['name']},{r['us_per_call']},{r['derived']}")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
