"""Benchmarks reproducing the paper's four experiment groups (Figs 8–11,
Table IV), one function per table/figure.  Each returns ``(name,
us_per_call, derived)`` rows: the timing is for the vectorized engine
sweep that computes the figure, ``derived`` is the figure's headline
quantity (so regressions in *either* speed or semantics are visible).

Each group is one declarative :class:`~repro.core.sweep.SweepPlan`
(DESIGN.md §4); derived quantities read out of the labeled
:class:`~repro.core.sweep.SweepResult` instead of positional rows.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import paper_scenario, refsim, sweep
from repro.core.config import BindingPolicy, SchedPolicy
from repro.core.sweep import axis, product

M_SWEEP = range(1, 21)


def _timed(plan, reps=5):
    """Time repeated ``plan.run()`` calls (steady-state, post-compile)."""
    res = plan.run()
    t0 = time.perf_counter()
    for _ in range(reps):
        res = plan.run()
    us = (time.perf_counter() - t0) / reps * 1e6
    return res, us


def group1_fig8a():
    """Fig 8a: execution time (avg/max/min) vs MR combination."""
    res, us = _timed(product(axis("n_maps", M_SWEEP)))
    avg = res["avg_exec"]
    drop = float(1 - avg[2] / avg[0])          # rapid early drop
    flatness = float((max(avg[5:]) - min(avg[5:])) / avg[0])
    return [("group1_fig8a_earlydrop", us, f"{drop:.3f}"),
            ("group1_fig8a_flatness_M6plus", us, f"{flatness:.4f}")]


def group1_fig8b():
    """Fig 8b: makespan with vs without network delay."""
    plan = product(axis("n_maps", M_SWEEP),
                   axis("network_delay", (True, False)))
    res, us = _timed(plan)
    rows = []
    for nd in (True, False):
        mk = res.select(n_maps=1, network_delay=nd)["makespan"]
        rows.append((f"group1_fig8b_makespan_M1_delay={int(nd)}", us,
                     f"{float(mk):.1f}"))
    return rows


def group2_fig9_table4():
    """Fig 9 (avg exec vs VM number) + Table IV (network cost invariance)."""
    plan = product(axis("n_maps", M_SWEEP), axis("n_vms", (3, 6, 9)))
    res, us = _timed(plan)
    base = res.select(n_vms=3)["map_avg_exec"]
    red6 = float(np.mean(1 - res.select(n_vms=6)["map_avg_exec"] / base))
    red9 = float(np.mean(1 - res.select(n_vms=9)["map_avg_exec"] / base))
    # Table IV: exact values + invariance across VM number
    tbl = np.stack([res.select(n_vms=v)["network_cost"] for v in (3, 6, 9)])
    invariant = bool(np.allclose(tbl[0], tbl[1]) and np.allclose(tbl[0], tbl[2]))
    expected = 4250.0 / (np.arange(1, 21) + 1)
    exact = bool(np.allclose(tbl[0], expected, rtol=1e-4))
    return [
        ("group2_fig9_reduction_3to6_vms", us, f"{red6:.3f}"),
        ("group2_fig9_reduction_3to9_vms", us, f"{red9:.3f}"),
        ("group2_table4_vm_invariant", us, str(invariant)),
        ("group2_table4_exact_4250_over_Mplus1", us, str(exact)),
    ]


def group3_fig10():
    """Fig 10: avg exec time vs VM configuration (paper ~60%/~80% less)."""
    plan = product(axis("n_maps", M_SWEEP),
                   axis("vm_type", ("small", "medium", "large")))
    res, us = _timed(plan)
    s = float(np.mean(res.select(vm_type="small")["avg_exec"]))
    rows = []
    for vt, claim in (("medium", 0.60), ("large", 0.80)):
        r = 1 - float(np.mean(res.select(vm_type=vt)["avg_exec"])) / s
        rows.append((f"group3_fig10_{vt}_reduction(paper~{claim})",
                     us, f"{r:.3f}"))
    return rows


def group4_fig11():
    """Fig 11: VM computation cost vs job configuration (linear)."""
    plan = product(axis("n_maps", M_SWEEP),
                   axis("job_type", ("small", "medium", "big")))
    res, us = _timed(plan)
    s = float(np.mean(res.select(job_type="small")["vm_cost"]))
    m = float(np.mean(res.select(job_type="medium")["vm_cost"]))
    b = float(np.mean(res.select(job_type="big")["vm_cost"]))
    return [("group4_fig11_medium_over_small(expect2)", us, f"{m/s:.3f}"),
            ("group4_fig11_big_over_small(expect4)", us, f"{b/s:.3f}")]


def group5_policies():
    """Group 5 (beyond-paper): scheduling x binding policy comparison.

    One mixed-policy plan (every SchedPolicy x BindingPolicy over the
    Group-1 M sweep on medium VMs), one vmapped call — the scenario family
    CloudSim expresses only by swapping scheduler classes and re-running.
    Derived: space-shared/time-shared makespan ratio at M=20 (queueing cost
    of PE exclusivity), packed/round-robin ratio under time sharing, and a
    *device-side* heterogeneous-VM cell where LEAST_LOADED's capacity
    estimate beats the rolling pointer (the closed ROADMAP item).
    """
    plan = product(axis("sched_policy", list(SchedPolicy)),
                   axis("binding_policy", list(BindingPolicy)),
                   axis("n_maps", M_SWEEP),
                   vm_type="medium")
    res, us = _timed(plan)

    def mk20(sp, bp):
        return float(res.select(sched_policy=sp, binding_policy=bp,
                                n_maps=20)["makespan"])

    ts_rr = mk20(SchedPolicy.TIME_SHARED, BindingPolicy.ROUND_ROBIN)
    ss_rr = mk20(SchedPolicy.SPACE_SHARED, BindingPolicy.ROUND_ROBIN)
    # packed vs RR under TIME sharing: on the homogeneous pes=2 cell the
    # space-shared placements are symmetric (ratio identically 1), but
    # time-shared fluid sharing *does* see the packing imbalance
    ts_pk = mk20(SchedPolicy.TIME_SHARED, BindingPolicy.PACKED)
    # binding on a *heterogeneous* cluster — now a device-side cell: per-VM
    # mips/pes/cost vectors through the same encode_cell path as the grid
    hetero = product(axis("binding_policy", list(BindingPolicy)),
                     vms=("medium",) * 2 + ("small",) * 4,
                     sched_policy=SchedPolicy.SPACE_SHARED,
                     n_maps=12, n_reduces=2, job_type="medium")
    h_res, h_us = _timed(hetero)
    ll = float(h_res.select(binding_policy=BindingPolicy.LEAST_LOADED)["makespan"])
    rr = float(h_res.select(binding_policy=BindingPolicy.ROUND_ROBIN)["makespan"])
    return [
        ("group5_makespan_space/time_M20", us, f"{ss_rr / ts_rr:.3f}"),
        ("group5_makespan_packed/rr_time_M20", us, f"{ts_pk / ts_rr:.3f}"),
        ("group5_hetero_makespan_leastloaded/rr", h_us, f"{ll / rr:.3f}"),
    ]


def refsim_baseline():
    """Paper-faithful sequential baseline speed (for §Perf before/after)."""
    scs = [paper_scenario(n_maps=m) for m in M_SWEEP]
    t0 = time.perf_counter()
    for s in scs:
        refsim.simulate(s)
    us = (time.perf_counter() - t0) / len(scs) * 1e6
    return [("refsim_sequential_us_per_scenario", us, "baseline")]


def all_rows():
    rows = []
    for fn in (group1_fig8a, group1_fig8b, group2_fig9_table4, group3_fig10,
               group4_fig11, group5_policies, refsim_baseline):
        rows += fn()
    return rows
