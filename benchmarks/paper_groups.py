"""Benchmarks reproducing the paper's four experiment groups (Figs 8–11,
Table IV), one function per table/figure.  Each returns ``(name,
us_per_call, derived)`` rows: the timing is for the vectorized engine
sweep that computes the figure, ``derived`` is the figure's headline
quantity (so regressions in *either* speed or semantics are visible).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import paper_scenario, refsim, sweep

M_SWEEP = range(1, 21)


def _timed(batch, reps=5):
    fn = sweep.simulate_batch
    out = fn(batch)
    out.makespan.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(batch)
        out.makespan.block_until_ready()
    us = (time.perf_counter() - t0) / reps * 1e6
    return out, us


def group1_fig8a():
    """Fig 8a: execution time (avg/max/min) vs MR combination."""
    batch = sweep.paper_grid(m_range=M_SWEEP)
    out, us = _timed(batch)
    avg = out.avg_exec[:, 0]
    drop = float(1 - avg[2] / avg[0])          # rapid early drop
    flatness = float((max(avg[5:]) - min(avg[5:])) / avg[0])
    return [("group1_fig8a_earlydrop", us, f"{drop:.3f}"),
            ("group1_fig8a_flatness_M6plus", us, f"{flatness:.4f}")]


def group1_fig8b():
    """Fig 8b: makespan with vs without network delay."""
    rows = []
    for nd in (True, False):
        batch = sweep.paper_grid(m_range=M_SWEEP, network_delay=nd)
        out, us = _timed(batch)
        rows.append((f"group1_fig8b_makespan_M1_delay={int(nd)}", us,
                     f"{float(out.makespan[0, 0]):.1f}"))
    return rows


def group2_fig9_table4():
    """Fig 9 (avg exec vs VM number) + Table IV (network cost invariance)."""
    outs = {}
    us_total = 0.0
    for v in (3, 6, 9):
        batch = sweep.paper_grid(m_range=M_SWEEP, vm_numbers=(v,))
        outs[v], us = _timed(batch)
        us_total += us
    red6 = float(np.mean(1 - outs[6].map_avg_exec[:, 0]
                         / outs[3].map_avg_exec[:, 0]))
    red9 = float(np.mean(1 - outs[9].map_avg_exec[:, 0]
                         / outs[3].map_avg_exec[:, 0]))
    # Table IV: exact values + invariance across VM number
    tbl = np.stack([outs[v].network_cost[:, 0] for v in (3, 6, 9)])
    invariant = bool(np.allclose(tbl[0], tbl[1]) and np.allclose(tbl[0], tbl[2]))
    expected = 4250.0 / (np.arange(1, 21) + 1)
    exact = bool(np.allclose(np.asarray(tbl[0]), expected, rtol=1e-4))
    return [
        ("group2_fig9_reduction_3to6_vms", us_total, f"{red6:.3f}"),
        ("group2_fig9_reduction_3to9_vms", us_total, f"{red9:.3f}"),
        ("group2_table4_vm_invariant", us_total, str(invariant)),
        ("group2_table4_exact_4250_over_Mplus1", us_total, str(exact)),
    ]


def group3_fig10():
    """Fig 10: avg exec time vs VM configuration (paper ~60%/~80% less)."""
    outs = {}
    us_total = 0.0
    for vt in ("small", "medium", "large"):
        batch = sweep.paper_grid(m_range=M_SWEEP, vm_types=(vt,))
        outs[vt], us = _timed(batch)
        us_total += us
    s = float(np.mean(outs["small"].avg_exec[:, 0]))
    rows = []
    for vt, claim in (("medium", 0.60), ("large", 0.80)):
        r = 1 - float(np.mean(outs[vt].avg_exec[:, 0])) / s
        rows.append((f"group3_fig10_{vt}_reduction(paper~{claim})",
                     us_total, f"{r:.3f}"))
    return rows


def group4_fig11():
    """Fig 11: VM computation cost vs job configuration (linear)."""
    outs = {}
    us_total = 0.0
    for jt in ("small", "medium", "big"):
        batch = sweep.paper_grid(m_range=M_SWEEP, job_types=(jt,))
        outs[jt], us = _timed(batch)
        us_total += us
    s = float(np.mean(outs["small"].vm_cost[:, 0]))
    m = float(np.mean(outs["medium"].vm_cost[:, 0]))
    b = float(np.mean(outs["big"].vm_cost[:, 0]))
    return [("group4_fig11_medium_over_small(expect2)", us_total, f"{m/s:.3f}"),
            ("group4_fig11_big_over_small(expect4)", us_total, f"{b/s:.3f}")]


def group5_policies():
    """Group 5 (beyond-paper): scheduling x binding policy comparison.

    One mixed-policy batch (every SchedPolicy x BindingPolicy block over the
    Group-1 M sweep on medium VMs), one vmapped call — the scenario family
    CloudSim expresses only by swapping scheduler classes and re-running.
    Derived: space-shared/time-shared makespan ratio at M=20 (queueing cost
    of PE exclusivity) and packed/round-robin ratio under space sharing.
    """
    import dataclasses

    from repro.core import JOB_MEDIUM, VM_MEDIUM, VM_SMALL, Scenario
    from repro.core.config import BindingPolicy, SchedPolicy
    batch, combos = sweep.policy_grid(m_range=M_SWEEP, n_vms=3,
                                      vm_type="medium")
    out, us = _timed(batch)
    n_m = len(M_SWEEP)
    mk = {c: np.asarray(out.makespan[i * n_m:(i + 1) * n_m, 0])
          for i, c in enumerate(combos)}
    ts_rr = mk[(SchedPolicy.TIME_SHARED, BindingPolicy.ROUND_ROBIN)]
    ss_rr = mk[(SchedPolicy.SPACE_SHARED, BindingPolicy.ROUND_ROBIN)]
    # packed vs RR under TIME sharing: on the homogeneous pes=2 cell the
    # space-shared placements are symmetric (ratio identically 1), but
    # time-shared fluid sharing *does* see the packing imbalance
    ts_pk = mk[(SchedPolicy.TIME_SHARED, BindingPolicy.PACKED)]
    # binding on a *heterogeneous* cluster (host-side stacked batch):
    # least-loaded's capacity estimate vs the rolling pointer
    job = dataclasses.replace(JOB_MEDIUM, n_maps=12, n_reduces=2)
    hetero = [Scenario(vms=(VM_MEDIUM,) * 2 + (VM_SMALL,) * 4, jobs=(job,),
                       sched_policy=SchedPolicy.SPACE_SHARED,
                       binding_policy=bp) for bp in BindingPolicy]
    h_out, h_us = _timed(sweep.stack_scenarios(hetero))
    h_mk = np.asarray(h_out.makespan[:, 0])
    return [
        ("group5_makespan_space/time_M20", us,
         f"{float(ss_rr[-1] / ts_rr[-1]):.3f}"),
        ("group5_makespan_packed/rr_time_M20", us,
         f"{float(ts_pk[-1] / ts_rr[-1]):.3f}"),
        ("group5_hetero_makespan_leastloaded/rr", h_us,
         f"{float(h_mk[1] / h_mk[0]):.3f}"),
    ]


def refsim_baseline():
    """Paper-faithful sequential baseline speed (for §Perf before/after)."""
    scs = [paper_scenario(n_maps=m) for m in M_SWEEP]
    t0 = time.perf_counter()
    for s in scs:
        refsim.simulate(s)
    us = (time.perf_counter() - t0) / len(scs) * 1e6
    return [("refsim_sequential_us_per_scenario", us, "baseline")]


def all_rows():
    rows = []
    for fn in (group1_fig8a, group1_fig8b, group2_fig9_table4, group3_fig10,
               group4_fig11, group5_policies, refsim_baseline):
        rows += fn()
    return rows
