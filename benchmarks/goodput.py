"""Goodput / fault-tolerance study: the IOTSim methodology applied to
pod-scale training (workload bridge).  Uses the dry-run's extracted cost
model when available, else representative numbers."""
from __future__ import annotations

import json
import os
import time

from repro.core import ChipSpec, StepCost, workload


def _step_cost() -> tuple[str, StepCost]:
    path = "dryrun_baseline.json"
    if os.path.exists(path):
        with open(path) as f:
            d = json.load(f)
        key = "yi-6b|train_4k|16x16|full"
        if key in d:
            r = d[key]
            return key, StepCost(flops=r["flops"],
                                 hbm_bytes=r["bytes_accessed"],
                                 collective_bytes=r["collective_wire_bytes"])
    return "synthetic", StepCost(flops=2e14, hbm_bytes=2e12,
                                 collective_bytes=3e10)


def all_rows():
    src, cost = _step_cost()
    chip = ChipSpec()
    rows = []
    t0 = time.perf_counter()
    clean = workload.simulate_training(cost, chip, n_devices=256,
                                       n_steps=10_000)
    us = (time.perf_counter() - t0) * 1e6
    rows.append((f"goodput_clean[{src}]", us, f"{clean['goodput']:.3f}"))
    strag = workload.simulate_training(cost, chip, n_devices=256,
                                       n_steps=10_000, straggler_sigma=0.1)
    rows.append(("goodput_stragglers_sigma0.1", us,
                 f"{strag['goodput']:.3f}"))
    fail = workload.simulate_training(cost, chip, n_devices=256,
                                      n_steps=10_000, straggler_sigma=0.1,
                                      mtbf_hours=200.0)
    rows.append(("goodput_stragglers+failures_mtbf200h", us,
                 f"{fail['goodput']:.3f}"))
    rows.append(("goodput_expected_failures", us,
                 f"{fail['expected_failures']:.1f}"))
    # checkpoint cadence sweep: the knob the simulator exists to answer
    best = max((workload.simulate_training(
        cost, chip, n_devices=256, n_steps=10_000, straggler_sigma=0.1,
        mtbf_hours=200.0, checkpoint_every=ck)["goodput"], ck)
        for ck in (25, 50, 100, 200, 400))
    rows.append(("goodput_best_ckpt_cadence", us,
                 f"every{best[1]}steps={best[0]:.3f}"))
    return rows
