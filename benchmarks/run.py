"""Benchmark harness entry point: ``PYTHONPATH=src python -m benchmarks.run``.

Prints ``name,us_per_call,derived`` CSV — one section per paper table/figure
(paper_groups), the sweep-throughput adaptation benchmark, the kernel
micro-benchmarks, and the workload/goodput study.  Roofline extraction for
the dry-run lives in ``benchmarks/roofline.py`` (separate entry point:
reads compiled artifacts, writes EXPERIMENTS.md tables).
"""
from __future__ import annotations

import sys


def main() -> None:
    rows = []
    from . import paper_groups
    rows += paper_groups.all_rows()
    from . import sweep_throughput
    rows += sweep_throughput.all_rows()
    try:
        from . import kernel_bench
        rows += kernel_bench.all_rows()
    except ImportError:
        pass
    try:
        from . import goodput
        rows += goodput.all_rows()
    except ImportError:
        pass
    from . import speculative_execution
    rows += speculative_execution.all_rows()

    print("name,us_per_call,derived")
    for name, us, derived, *_ in rows:
        print(f"{name},{us:.1f},{derived}")
    sys.stdout.flush()


if __name__ == "__main__":
    main()
