"""CI benchmark smoke gate: ``sweep_throughput`` at b64 on the CPU
(interpret-class) path — the plain grid, the storage-subsystem LOCALITY
grid (skewed placement, DESIGN.md §7) AND the elastic dynamic-fleet grid
(arrivals + lease windows, DESIGN.md §8) AND the tail-heavy compacted
grid (sparse active-lane compaction, DESIGN.md §9) AND the closed-loop
control grid (failure streams + autoscale hook, DESIGN.md §10) AND the
graceful-degradation grid (deadlines + preemption, DESIGN.md §11) —
failing on crash or
on a >25% throughput regression against the checked-in
``BENCH_sweep.json`` baseline rows.

Absolute wall times are not comparable across machines, so the baseline's
``calibration_us`` (a fixed jitted micro-workload timed when the baseline
was recorded, see ``sweep_throughput.calibration_us``) rescales the gate:
this machine is allowed ``baseline_us × (local_calib / baseline_calib) ×
(1 + tolerance)`` per call.  Override the tolerance with
``BENCH_SMOKE_TOL`` (fraction, default 0.25).

The plain b64 row doubles as the *trace-off identity guard* (DESIGN.md
§12): tracing is a static flag whose off state must insert zero ops, so
that row runs under a tightened budget — ``BENCH_SMOKE_PLAIN_TOL``
(fraction, default 0.10) — and any overhead the trace (or deadline)
lowering leaks into the plain path fails CI at <10% instead of hiding
inside the general 25% noise allowance.

A separate *host-chattiness* gate (DESIGN.md §13) replays the tail-heavy
b64 grid at the baseline's pinned compaction interval and requires the
sync census — full mask/permutation pulls, fused scalar pulls, device
dispatches — to match the recorded figures exactly: the census is
deterministic given the grid and the interval, so no tolerance applies.

    PYTHONPATH=src python -m benchmarks.bench_smoke
"""
from __future__ import annotations

import json
import os
import pathlib
import sys

import time

import numpy as np

from benchmarks.sweep_throughput import _random_plan, calibration_us

GATED = (          # (baseline row name, plan kwargs, run kwargs)
    # the plain row runs under the tightened BENCH_SMOKE_PLAIN_TOL budget
    # (see module docstring): it is the trace-off / deadline-off identity
    # the static-flag lowerings must keep free
    ("sweep_throughput_b64", {}, {}),
    ("sweep_throughput_locality_b64", {"locality": True}, {}),
    ("sweep_throughput_elastic_b64", {"elastic": True}, {}),
    # the sparse-compaction row (DESIGN.md §9): tail-heavy grid through
    # the compacted driver with the measured-cost auto interval — gates
    # both the compact host loop and the cost-model calibration path
    ("sweep_throughput_tailheavy_compact_b64", {"tailheavy": True},
     {"compact": "auto"}),
    # the closed-loop control row (DESIGN.md §10): the elastic grid plus
    # failure streams + the per-epoch AUTOSCALE hook — gates the control
    # lowering's epoch-loop additions
    ("sweep_throughput_control_b64", {"control": True}, {}),
    # the graceful-degradation row (DESIGN.md §11): the control grid plus
    # deadlines, SHED/BOOST and priority preemption — gates the deadline
    # lowering's epoch-loop additions.  The plain b64 row above is the
    # <10% plain-path guard for both this and the trace lowering: with
    # the columns/flag off each lowering is a static flag (None pytree
    # leaves), so any overhead leaked into the plain path shows up
    # against that row's tightened budget.
    ("sweep_throughput_deadline_b64", {"deadline": True}, {}),
)

# the tail-heavy grid must actually realize a deep tail, else the row
# gates nothing (the ISSUE's floor for a meaningful compaction workload)
MIN_TAIL_EPOCHS = 20


def _census_gate(baseline) -> bool:
    """Host-chattiness gate for the dispatch-lean compact loop (DESIGN.md
    §13): replay the tail-heavy b64 grid at the baseline's *pinned*
    compaction interval with ``report=True`` and require the sync census
    to match the recorded one exactly.  Unlike the wall-time rows, the
    census — full mask/permutation pulls, fused scalar pulls, dispatches —
    is deterministic given the grid and the interval, so any regression
    (a lowering that quietly re-adds a per-round full pull, say) fails
    crisply with no machine-speed rescaling.  Returns True on failure."""
    name = "sweep_throughput_tailheavy_compact_b64"
    base_row = next((r for r in baseline["rows"] if r["name"] == name),
                    None)
    census = (base_row or {}).get("meta", {}).get("census")
    if census is None:
        print(f"FAIL: baseline row {name!r} records no sync census — "
              "re-record with `python -m benchmarks.sweep_throughput`")
        return True
    plan = _random_plan(64, np.random.default_rng(64), tailheavy=True)
    _, rep = plan.run(compact=int(census["k"]), report=True)
    got = {"k": int(census["k"]),
           "compaction_syncs": rep.compaction_syncs,
           "scalar_syncs": rep.scalar_syncs,
           "dispatches": rep.dispatches}
    print(f"{name} census at pinned k={got['k']}: "
          f"{got['compaction_syncs']} full pulls, "
          f"{got['scalar_syncs']} scalar pulls, "
          f"{got['dispatches']} dispatches "
          f"(recorded {census['compaction_syncs']}/"
          f"{census['scalar_syncs']}/{census['dispatches']})")
    if got != dict(census):
        print("FAIL: compact-loop host chattiness drifted from the "
              f"recorded census ({got} != {dict(census)}) — the lean "
              "loop must pull full activity arrays only on compacting "
              "rounds")
        return True
    return False


def _min_of_reps(reps=7, run_kw=None, **plan_kw):
    """b64 us/call as a min over reps: the mean-of-3 the baseline records
    is fine for trend tracking, but a pass/fail gate on a shared CI runner
    needs the noise floor, not the noise."""
    run_kw = run_kw or {}
    # rng(64): the exact grid the baseline's b64 rows record (seed == n)
    plan = _random_plan(64, np.random.default_rng(64), **plan_kw)
    res = plan.run(**run_kw)                       # compile + warm caches
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        res = plan.run(**run_kw)
        best = min(best, time.perf_counter() - t0)
    return best * 1e6, int(res["realized_epochs"].max())


def main() -> int:
    base_path = (pathlib.Path(__file__).resolve().parent.parent
                 / "BENCH_sweep.json")
    baseline = json.loads(base_path.read_text())
    base_calib = float(baseline.get("meta", {}).get("calibration_us", 0.0))

    tol = float(os.environ.get("BENCH_SMOKE_TOL", "0.25"))
    # the plain-path identity budget (module docstring): <10% on the row
    # whose workload every static-flag lowering must leave untouched
    plain_tol = float(os.environ.get("BENCH_SMOKE_PLAIN_TOL", "0.10"))
    local_calib = calibration_us()
    scale = (local_calib / base_calib) if base_calib > 0 else 1.0

    failed = False
    for name, plan_kw, run_kw in GATED:
        base_row = next((r for r in baseline["rows"] if r["name"] == name),
                        None)
        if base_row is None:
            print(f"FAIL: baseline row {name!r} missing from {base_path} — "
                  "re-record it with `python -m benchmarks.sweep_throughput`")
            failed = True
            continue
        # gate noise floor against noise floor: the recorded min-of-reps
        # (mean-of-3 is the trend figure; comparing a local min against it
        # made the budget depend on which way calibration drift pointed)
        base_us = float(base_row.get("us_per_call_min",
                                     base_row["us_per_call"]))
        us, realized = _min_of_reps(run_kw=run_kw, **plan_kw)
        row_tol = plain_tol if name == "sweep_throughput_b64" else tol
        budget = base_us * scale * (1.0 + row_tol)
        print(f"{name}: {us:.1f} us/call min-of-7 "
              f"({64 / us * 1e6:.0f}_scen/s, realized epochs {realized}); "
              f"baseline {base_us:.1f} us/call, machine-speed scale "
              f"{scale:.2f}x -> budget {budget:.1f} us/call "
              f"(tolerance {row_tol:.0%})")
        if not np.isfinite(us) or us > budget:
            print("FAIL: benchmark smoke regression "
                  f"({name}: {us:.1f} > {budget:.1f} us/call)")
            failed = True
        if plan_kw.get("tailheavy") and realized < MIN_TAIL_EPOCHS:
            print(f"FAIL: tail-heavy grid realized only {realized} epochs "
                  f"(< {MIN_TAIL_EPOCHS}) — the compaction row is not "
                  "exercising a deep tail")
            failed = True
    failed |= _census_gate(baseline)
    if failed:
        return 1
    print("OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
