"""Hillclimb driver: measure one cell's corrected roofline terms under a
PERF-flag configuration (hypothesis -> change -> measure loop, §Perf).

    PYTHONPATH=src python -m benchmarks.hillclimb <arch> <shape> \
        [flag=0/1 ...] [--quick]      (--quick: full compile only, no
                                       depth variants — term deltas only
                                       approximate for scanned parts)
"""
import json
import sys


def main():
    arch, shape = sys.argv[1], sys.argv[2]
    quick = "--quick" in sys.argv
    from repro.launch import dryrun
    for a in sys.argv[3:]:
        if "=" in a:
            k, v = a.split("=")
            assert k in dryrun.PERF, k
            dryrun.PERF[k] = bool(int(v))
    print("PERF:", dryrun.PERF)

    results = {}
    jobs = [("full", None)]
    if not quick:
        jobs += dryrun.depth_variants(
            __import__("repro.configs", fromlist=["x"]).get(arch))
    for tag, cfg_over in jobs:
        rec = dryrun.run_cell(arch, shape, multi_pod=False,
                              cfg_override=cfg_over, tag=tag)
        results[f"{arch}|{shape}|16x16|{tag}"] = rec
        print(f"  [{tag}] flops={rec['flops']:.3e} "
              f"bytes={rec['bytes_accessed']:.3e} "
              f"wire={rec['collective_wire_bytes']:.3e} "
              f"temp={rec['memory']['temp_bytes']/2**30:.2f}GiB "
              f"compile={rec['compile_s']}s")

    if not quick:
        from benchmarks.roofline import corrected_cell
        r = corrected_cell(results, arch, shape)
        print(f"corrected: compute={r['compute_s']:.3e}s "
              f"memory={r['memory_s']:.3e}s "
              f"collective={r['collective_s']:.3e}s "
              f"dominant={r['dominant']} frac={r['roofline_frac']:.2%} "
              f"MODEL/HLO={r['useful_ratio']:.2f}")
        out = f"/tmp/hillclimb_{arch}_{shape}.json"
        with open(out, "a") as f:
            json.dump({"perf": dryrun.PERF, **r}, f)
            f.write("\n")


if __name__ == "__main__":
    main()
