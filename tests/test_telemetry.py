"""Device-side trace & telemetry subsystem (DESIGN.md §12).

The trace layer must be *free when off* and *exact when on*:

* **trace-off / trace-on identity** — with tracing disabled the drivers
  run the pre-§12 lowering (the flag only adds carry leaves, never ops);
  with tracing enabled the ``SimOutput`` stays bitwise identical across
  engine ↔ batched ↔ batched-compact ↔ pallas dense + compact, stranded
  lanes included, and the trace buffers themselves agree bitwise across
  every engine path (the pallas twin carries the time-series rows);
* **oracle event parity** — the refsim calendar mirrors every event the
  engine logs: per-kind counts are integer-exact and timestamps match to
  the f32 tolerance (rtol 2e-4) over seeded failure / shed / preempt /
  autoscale grids.  SHED is counts-only: the engine detects refusal at
  epoch granularity, the oracle at calendar time;
* **overflow semantics** — an undersized event log drops the *newest*
  rows, counts them in ``dropped_events``, and never corrupts earlier
  rows (the one-hot write falls off the end of the buffer);
* **exports** — ``to_chrome_trace()`` is valid trace-event JSON with one
  complete-event span per realized task execution; parquet artifacts
  carry the provenance stamp; ``run(report=True)`` returns a
  :class:`~repro.core.telemetry.RunReport` without changing any metric.
"""
import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.core import (ControlPolicy, ControlSpec, DeadlinePolicy, Scenario,
                        SchedPolicy, costmodel, engine, refsim, sweep,
                        telemetry)
from repro.core.config import (JobSpec, NetworkSpec, VM_SMALL, VMSpec,
                               paper_scenario)
from repro.core.elasticity import ElasticitySpec
from repro.core.sweep import axis, product
from repro.core.telemetry import (EV_FINISH, EV_KILL, EV_PREEMPT,
                                  EV_SCALE_CLOSE, EV_SCALE_OPEN, EV_SHED,
                                  EV_START, EVENT_NAMES, TraceResult,
                                  event_capacity, timeseries_capacity)
from repro.kernels.mr_sched import epoch_schedule, epoch_schedule_compact

_BIG = engine._BIG
SCHED_FIELDS = engine.SimOutput._fields


def _assert_same(a, b, fields, msg):
    for f in fields:
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)),
                                      err_msg=f"{msg}: {f}")


def _overload(dlpol, *, preempt=False, resume=False, slack=0.0,
              sp=SchedPolicy.SPACE_SHARED, spacing=120.0,
              deadlines=(4000.0, 4600.0, 5200.0, 5800.0, 6400.0)):
    """Five staggered jobs on two small VMs: sustained overload."""
    jobs = tuple(JobSpec(f"j{i}", length_mi=362_880.0, data_mb=200_000.0,
                         n_maps=3, n_reduces=1, submit_time=spacing * i,
                         priority=float(i % 3), deadline=deadlines[i])
                 for i in range(5))
    return Scenario(vms=(VM_SMALL,) * 2, jobs=jobs,
                    network=NetworkSpec(enabled=False), sched_policy=sp,
                    control=ControlSpec(deadline_policy=dlpol,
                                        deadline_slack=slack,
                                        preempt=preempt,
                                        preempt_resume=resume))


def _fail_scenario(seed=7, sp=SchedPolicy.SPACE_SHARED):
    sc = paper_scenario(n_maps=6, n_reduces=2, n_vms=4, sched_policy=sp)
    return sc.replace(control=ControlSpec(
        failure_rate=0.002, failure_seed=seed, repair_delay=300.0,
        redispatch_delay=5.0))


def _scale_scenario(sp=SchedPolicy.SPACE_SHARED):
    vms = (VMSpec("base", mips=250.0), VMSpec("base", mips=250.0),
           VMSpec("res", mips=250.0, autoscale=True),
           VMSpec("res", mips=250.0, autoscale=True))
    job = JobSpec("j", length_mi=362_880.0, data_mb=200_000.0,
                  n_maps=12, n_reduces=2)
    return Scenario(vms=vms, jobs=(job,), sched_policy=sp,
                    control=ControlSpec(policy=ControlPolicy.AUTOSCALE,
                                        queue_threshold=2.0,
                                        busy_threshold=0.5))


def _stranded():
    """A lane whose VM leases all close early: tasks never finish, so
    the lane realizes its full epoch bound (the hard trace-capacity
    case)."""
    base = paper_scenario(n_maps=6, n_reduces=2, n_vms=3,
                          sched_policy=SchedPolicy.SPACE_SHARED)
    return base.replace(
        vms=tuple(dataclasses.replace(v, lease_stop=500.0)
                  for v in base.vms),
        elasticity=ElasticitySpec())


def test_capacity_formulas():
    assert timeseries_capacity(10, 4, False) == 2 * 10 + 2
    assert timeseries_capacity(10, 4, True) == 7 * 10 + 4 + 3
    assert event_capacity(10, 4, False) == 2 * 10
    assert event_capacity(10, 4, True) == 11 * 10 + 2 * 4


# ---------------------------------------------------------------------------
# Bitwise identity: trace on/off, all five execution paths
# ---------------------------------------------------------------------------

def test_trace_bitwise_every_path():
    """Traced SimOutput == untraced, and the trace buffers agree bitwise
    across engine per-lane ↔ batched ↔ compact and the pallas twin's
    time series — on a mixed batch that includes failures, autoscale
    and a stranded lane."""
    batch = sweep.stack_scenarios([_fail_scenario(), _scale_scenario(),
                                   _stranded()])
    ref, _ = engine.simulate_batch_arrays(batch, control=True)
    assert (np.asarray(ref.finish[2]) >= _BIG / 2).any(), "no stranded lane"
    out, _, tb = engine.simulate_batch_arrays(batch, control=True,
                                              trace=True)
    _assert_same(ref, out, SCHED_FIELDS, "batched traced")
    # per-lane driver under vmap: outputs and buffers bitwise
    lane_out, lane_tb = jax.vmap(
        lambda sc: engine.simulate_arrays(sc, control=True, trace=True)
    )(batch)
    _assert_same(ref, lane_out, SCHED_FIELDS, "vmapped traced")
    _assert_same(tb, lane_tb, telemetry.TraceBuffers._fields,
                 "vmapped trace buffers")
    for K in (1, 4, "auto"):
        comp, _, ctb = engine.simulate_batch_arrays_compact(
            batch, k=K, control=True, trace=True)
        _assert_same(ref, comp, SCHED_FIELDS, f"compact traced k={K}")
        _assert_same(tb, ctb, telemetry.TraceBuffers._fields,
                     f"compact trace buffers k={K}")
    # pallas twin: time-series rows only, bitwise vs the engine's
    pal, ts = epoch_schedule(batch, control=True, trace=True)
    _assert_same(ref, pal, SCHED_FIELDS, "pallas dense traced")
    np.testing.assert_array_equal(np.asarray(ts), np.asarray(tb.ts),
                                  err_msg="pallas dense ts")
    palc, _, tsc = epoch_schedule_compact(batch, k=2, control=True,
                                          trace=True)
    _assert_same(ref, palc, SCHED_FIELDS, "pallas compact traced")
    np.testing.assert_array_equal(np.asarray(tsc), np.asarray(tb.ts),
                                  err_msg="pallas compact ts")
    tr = TraceResult(telemetry.jax_tree_to_numpy(tb))
    assert (tr.dropped_events == 0).all()


def test_trace_off_open_loop_identity():
    """Open-loop lowering: tracing composes without the control hook and
    stays an identity on the schedule."""
    sc = engine.from_scenario(paper_scenario(n_maps=6, n_reduces=2,
                                             n_vms=3))
    base = engine.simulate_arrays(sc, control=False)
    out, tb = engine.simulate_arrays(sc, control=False, trace=True)
    _assert_same(base, out, SCHED_FIELDS, "open-loop traced")
    tr = TraceResult(telemetry.jax_tree_to_numpy(tb))
    n = int(np.asarray(sc.task_valid).sum())
    c = tr.counts_by_kind(0)
    assert c["start"] == n and c["finish"] == n
    assert sum(c.values()) == 2 * n          # open loop: START/FINISH only


# ---------------------------------------------------------------------------
# Oracle event parity: refsim mirrors the engine's event log
# ---------------------------------------------------------------------------

_PARITY_CASES = [
    ("open-loop", lambda: paper_scenario(n_maps=6, n_reduces=2, n_vms=3),
     False),
    ("shed", lambda: _overload(DeadlinePolicy.SHED), True),
    ("preempt", lambda: _overload(DeadlinePolicy.NONE, preempt=True), True),
    ("shed-preempt", lambda: _overload(DeadlinePolicy.SHED, preempt=True,
                                       resume=True), True),
    ("failures", _fail_scenario, True),
    ("failures-ts", lambda: _fail_scenario(sp=SchedPolicy.TIME_SHARED),
     True),
    ("autoscale", _scale_scenario, True),
    ("autoscale-ts", lambda: _scale_scenario(SchedPolicy.TIME_SHARED),
     True),
]


@pytest.mark.parametrize("name,mk,control", _PARITY_CASES,
                         ids=[n for n, _, _ in _PARITY_CASES])
def test_engine_trace_matches_refsim_events(name, mk, control):
    sc = mk()
    ref = refsim.simulate(sc)
    arrs = engine.from_scenario(sc)
    out, tb = engine.simulate_arrays(arrs, control=control, trace=True)
    tr = TraceResult(telemetry.jax_tree_to_numpy(tb))
    assert int(tr.dropped_events[0]) == 0
    # per-kind counts: integer-exact
    refc: dict[int, int] = {}
    for (_, k, _, _) in ref.events:
        refc[k] = refc.get(k, 0) + 1
    eng = tr.counts_by_kind(0)
    for k, kname in EVENT_NAMES.items():
        assert eng[kname] == refc.get(k, 0), \
            f"{name}: {kname} count {eng[kname]} != refsim {refc.get(k, 0)}"
    ev = tr.events()
    # timestamps per kind to the f32 tolerance (SHED is counts-only:
    # the engine detects refusal at epoch granularity)
    for k in EVENT_NAMES:
        if k == EV_SHED:
            continue
        et = np.sort(ev["t"][ev["kind"] == k])
        rt = np.sort([t for (t, kk, _, _) in ref.events if kk == k])
        np.testing.assert_allclose(et, rt, rtol=2e-4, atol=1e-2,
                                   err_msg=f"{name}: {EVENT_NAMES[k]}")
    # (kind, task, vm) rows are the same multiset
    es = sorted((int(k), int(t), int(v))
                for k, t, v in zip(ev["kind"], ev["task"], ev["vm"])
                if k != EV_SHED)
    rs = sorted((int(k), int(t), int(v)) for (_, k, t, v) in ref.events
                if k != EV_SHED)
    assert es == rs, f"{name}: (kind,task,vm) multiset mismatch"
    # time-series: active rows time-monotone; per-epoch counters sum to
    # the oracle's totals
    ts = tr.ts[0]
    act = ts[:, 4] > 0
    assert (np.diff(ts[act, 0]) >= -1e-6).all()
    assert int(ts[:, 5].sum()) == refc.get(EV_KILL, 0)
    assert int(ts[:, 6].sum()) == refc.get(EV_SHED, 0)
    assert int(ts[:, 7].sum()) == refc.get(EV_PREEMPT, 0)


# ---------------------------------------------------------------------------
# Overflow semantics
# ---------------------------------------------------------------------------

def test_event_overflow_counts_without_corruption():
    sc = engine.from_scenario(_fail_scenario())
    base = engine.simulate_arrays(sc, control=True)
    _, full = engine.simulate_arrays(sc, control=True, trace=True)
    n_ev = int(np.asarray(full.ev_n))
    cap = 4
    assert n_ev > cap, "scenario too quiet to overflow"
    out, tiny = engine.simulate_arrays(sc, control=True, trace=True,
                                       trace_events=cap)
    _assert_same(base, out, SCHED_FIELDS, "overflowed traced")
    tr = TraceResult(telemetry.jax_tree_to_numpy(tiny))
    assert int(tr.dropped_events[0]) == n_ev - cap
    # rows that fit are exactly the first `cap` rows of the full log
    for name, f in (("t", "ev_t"), ("kind", "ev_kind"),
                    ("task", "ev_task"), ("vm", "ev_vm")):
        np.testing.assert_array_equal(
            np.asarray(getattr(tiny, f)),
            np.asarray(getattr(full, f))[:cap],
            err_msg=f"overflow corrupted earlier {name} rows")


# ---------------------------------------------------------------------------
# Exports
# ---------------------------------------------------------------------------

def test_chrome_trace_schema(tmp_path):
    sc = _fail_scenario()
    _, tr = telemetry.trace_scenario(sc, label="failures")
    path = tmp_path / "trace.json"
    tr.to_chrome_trace(path)
    doc = json.loads(path.read_text())          # valid JSON on disk
    assert set(doc) == {"traceEvents", "displayTimeUnit", "otherData"}
    spans = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    counts = tr.counts_by_kind(0)
    # one complete-event span per realized task execution: every START
    # opens exactly one span (kills close one and the redispatch START
    # opens the next)
    assert len(spans) == counts["start"]
    assert counts["kill"] > 0, "no failure ever fired"
    kills = [e for e in doc["traceEvents"]
             if e["ph"] == "i" and e["name"] == "kill"]
    redisp = [e for e in doc["traceEvents"]
              if e["ph"] == "i" and e["name"] == "redispatch"]
    assert len(kills) == counts["kill"]
    assert 0 < len(redisp) <= counts["kill"]    # restarts after kills
    for e in spans:
        assert e["dur"] >= 0.0
        assert e["args"]["outcome"] in ("ok", "kill", "preempt",
                                        "unterminated")
    assert doc["otherData"]["jax_version"]
    assert doc["otherData"]["dropped_events"] == 0


def test_timeseries_table_and_parquet(tmp_path):
    pa = pytest.importorskip("pyarrow")
    pq = pytest.importorskip("pyarrow.parquet")
    _, tr = telemetry.trace_scenario(_scale_scenario())
    tab = tr.to_table()
    n = len(tab["epoch"])
    assert n == int((tr.ts[:, :, 4] > 0).sum())
    assert set(telemetry.TS_COLUMNS) < set(tab)
    p = tmp_path / "ts.parquet"
    tr.to_parquet(p)
    meta = pq.read_schema(p).metadata
    prov = json.loads(meta[b"repro_provenance"])
    assert prov["jax_version"] and "device_kind" in prov


# ---------------------------------------------------------------------------
# Sweep-runtime telemetry: run(report=True)
# ---------------------------------------------------------------------------

_PINNED = costmodel.CostModel(dispatch_us=100.0, epoch_lane_us=0.05,
                              sync_us=40.0, device="pinned")


def test_run_report_observational():
    plan = product(axis("n_maps", [2, 3, 8, 12]), axis("n_vms", [2, 4]))
    base = plan.run(cost_model=_PINNED)
    res, rep = plan.run(cost_model=_PINNED, report=True)
    for f in base.metric_names:
        np.testing.assert_array_equal(base[f], res[f], err_msg=f)
    assert rep.n_cells == 8 and rep.n_buckets == len(rep.buckets) >= 1
    assert rep.dispatches == sum(b.dispatches for b in rep.buckets) >= 1
    assert rep.cost_model == {"dispatch_us": 100.0, "epoch_lane_us": 0.05,
                              "sync_us": 40.0, "device": "pinned",
                              "source": "static"}
    assert rep.provenance["jax_version"]
    assert rep.wall_s > 0 and all(b.wall_s > 0 for b in rep.buckets)
    # second identical run hits the fused-runner cache for every bucket
    _, rep2 = plan.run(cost_model=_PINNED, report=True)
    assert rep2.compile_cache_misses == 0
    assert rep2.compile_cache_hits >= rep2.n_buckets
    json.loads(rep.to_json())                   # serializable


def test_run_report_compact_counts_syncs():
    plan = product(axis("n_maps", [2, 4, 6, 9]), n_vms=3)
    base = plan.run(cost_model=_PINNED)
    res, rep = plan.run(cost_model=_PINNED, compact=1, report=True)
    for f in base.metric_names:
        if f == "realized_epochs":
            continue
        np.testing.assert_array_equal(base[f], res[f], err_msg=f)
    # dispatch-lean loop (DESIGN.md §13): every round pulls one fused
    # scalar pair; full mask/permutation pulls happen only on rounds that
    # actually compact — this 4-cell plan never shrinks below the pow2
    # floor, so its full-pull count is exactly zero
    assert rep.scalar_syncs > 0
    assert rep.compaction_syncs == 0
    assert rep.compact == 1
    assert all(b.compact_scalar_syncs > 0 for b in rep.buckets)
    assert all(b.compact_syncs <= b.compact_scalar_syncs
               for b in rep.buckets)


def test_run_report_cost_source_surfaces():
    """The calibration source rides into the report (fallback pinned via
    a CostModel constructed by the fallback path)."""
    cm = costmodel.fallback_cost_model("test-dev")
    _, rep = product(axis("n_maps", [2, 3]), n_vms=2).run(
        cost_model=cm, report=True)
    assert rep.cost_model["source"] == "fallback"
    assert rep.cost_model["device"] == "test-dev"


def test_sweep_parquet_provenance(tmp_path):
    pq = pytest.importorskip("pyarrow.parquet")
    plan = product(axis("n_maps", [2, 3, 4]), n_vms=2)
    res = plan.run(cost_model=_PINNED)
    p1 = tmp_path / "res.parquet"
    res.to_parquet(p1)
    assert b"repro_provenance" in pq.read_schema(p1).metadata
    p2 = tmp_path / "stream.parquet"
    streamed, rep = plan.run(chunk=2, stream_to=p2, cost_model=_PINNED,
                             report=True)
    prov = json.loads(pq.read_schema(p2).metadata[b"repro_provenance"])
    assert prov["repro_version"] and prov["jax_version"]
    assert streamed.n_rows == 3
    assert rep.n_cells == 3 and rep.dispatches >= 2   # >= one per chunk
