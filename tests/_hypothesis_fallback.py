"""Seeded stand-in for ``hypothesis`` when it is not installed.

The real library is declared in ``requirements.txt`` and used when present
(CI installs it); this shim keeps the property-test modules collectable and
meaningful on bare machines.  It implements just the strategy surface these
tests use — ``integers``, ``sampled_from``, ``booleans``, ``tuples`` — and a
``@given`` that replays ``max_examples`` deterministic draws from a
per-test seed (crc32 of the test name), so failures reproduce.
"""
from __future__ import annotations


import zlib
from types import SimpleNamespace

import numpy as np

_DEFAULT_EXAMPLES = 20


class _Strategy:
    def __init__(self, sample):
        self.sample = sample


def _integers(lo, hi):
    return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)))


def _sampled_from(xs):
    xs = list(xs)
    return _Strategy(lambda rng: xs[int(rng.integers(len(xs)))])


def _booleans():
    return _Strategy(lambda rng: bool(rng.integers(2)))


def _tuples(*ss):
    return _Strategy(lambda rng: tuple(s.sample(rng) for s in ss))


strategies = SimpleNamespace(integers=_integers, sampled_from=_sampled_from,
                             booleans=_booleans, tuples=_tuples)


def given(*ss):
    def deco(fn):
        def run():
            n = getattr(run, "_max_examples", _DEFAULT_EXAMPLES)
            rng = np.random.default_rng(zlib.crc32(fn.__name__.encode()))
            for _ in range(n):
                fn(*(s.sample(rng) for s in ss))
        # no functools.wraps: pytest must see run's zero-arg signature,
        # not the wrapped function's strategy parameters
        run.__name__ = fn.__name__
        run.__doc__ = fn.__doc__
        return run
    return deco


def settings(max_examples=_DEFAULT_EXAMPLES, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco
