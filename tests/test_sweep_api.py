"""Declarative sweep API (DESIGN.md §4): SweepPlan/SweepResult semantics,
bit-identity with the frozen PR-1 grid parameter encodings (the removed
``paper_grid``/``policy_grid`` shims' cell layout), heterogeneous-VM
device-side cells, and grid validation errors.

The ``table4``-marked tests double as the CI sweep smoke job: a tiny
``SweepPlan`` end to end on CPU, asserting bit-identity with the frozen
PR-1 grid encoding.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import (JOB_MEDIUM, VM_LARGE, VM_MEDIUM, VM_SMALL,
                        BindingPolicy, Scenario, SchedPolicy, engine,
                        paper_scenario, refsim, sweep)
from repro.core.config import JOB_TYPES, VM_TYPES
from repro.core.sweep import axis, product, zip_

ALL_POLICIES = [(sp, bp) for sp in SchedPolicy for bp in BindingPolicy]
M_RANGE = range(1, 11)


def _legacy_paper_grid_params(m_range):
    """The PR-1 ``paper_grid`` parameter encoding, frozen for comparison."""
    cells = [(m, 3, VM_TYPES["small"], JOB_TYPES["small"]) for m in m_range]
    n = len(cells)
    return dict(
        n_maps=np.array([c[0] for c in cells], np.int32),
        n_reduces=np.ones(n, np.int32),
        n_vms=np.array([c[1] for c in cells], np.int32),
        vm_mips=np.array([c[2].mips for c in cells], np.float32),
        vm_pes=np.array([float(c[2].pes) for c in cells], np.float32),
        vm_cost=np.array([c[2].cost_per_sec for c in cells], np.float32),
        job_length=np.array([c[3].length_mi for c in cells], np.float32),
        job_data=np.array([c[3].data_mb for c in cells], np.float32),
        net_enabled=np.full(n, 1.0, np.float32),
        sched_policy=np.full(n, int(SchedPolicy.TIME_SHARED), np.int32),
        binding_policy=np.full(n, int(BindingPolicy.ROUND_ROBIN), np.int32),
    )


# ---------------------------------------------------------------------------
# Table IV bit-identity: SweepPlan vs the legacy paper_grid path (CI smoke)
# ---------------------------------------------------------------------------

def test_table4_bit_identity_with_legacy_paper_grid():
    """Paper Table IV cells through SweepPlan == legacy encoding, bitwise."""
    legacy = sweep.grid_arrays(_legacy_paper_grid_params(M_RANGE),
                               pad_tasks=max(M_RANGE) + 1, pad_vms=3)
    legacy_out = sweep.simulate_batch(legacy)
    res = product(axis("n_maps", M_RANGE)).run()
    np.testing.assert_array_equal(np.asarray(legacy_out.makespan[:, 0]),
                                  res["makespan"])
    np.testing.assert_array_equal(np.asarray(legacy_out.network_cost[:, 0]),
                                  res["network_cost"])
    # the plan's own compile target matches the frozen encoding as a batch
    arrs = product(axis("n_maps", M_RANGE)).arrays()
    for f in engine.ScenarioArrays._fields:
        np.testing.assert_array_equal(np.asarray(getattr(legacy, f)),
                                      np.asarray(getattr(arrs, f)),
                                      err_msg=f"field {f}")
    # Table IV values themselves
    expected = 4250.0 / (np.arange(1, 11) + 1)
    np.testing.assert_allclose(res["network_cost"], expected, rtol=1e-4)


def test_table4_policy_cross_matches_legacy_block_layout():
    """The old ``policy_grid`` block layout (policy-major, m-minor), frozen
    as raw parameter columns, matches the SweepPlan policy cross bitwise."""
    m_range = range(1, 6)
    vm = VM_TYPES["medium"]
    job = JOB_TYPES["small"]
    combos = [(sp, bp) for sp in SchedPolicy for bp in BindingPolicy]
    cells = [(sp, bp, m) for sp, bp in combos for m in m_range]
    n = len(cells)
    legacy = sweep.grid_arrays(dict(
        n_maps=np.array([m for _, _, m in cells], np.int32),
        n_reduces=np.ones(n, np.int32),
        n_vms=np.full(n, 3, np.int32),
        vm_mips=np.full(n, vm.mips, np.float32),
        vm_pes=np.full(n, float(vm.pes), np.float32),
        vm_cost=np.full(n, vm.cost_per_sec, np.float32),
        job_length=np.full(n, job.length_mi, np.float32),
        job_data=np.full(n, job.data_mb, np.float32),
        net_enabled=np.ones(n, np.float32),
        sched_policy=np.array([sp for sp, _, _ in cells], np.int32),
        binding_policy=np.array([bp for _, bp, _ in cells], np.int32),
    ), pad_tasks=max(m_range) + 1, pad_vms=3)
    plan = product(axis("sched_policy", list(SchedPolicy)),
                   axis("binding_policy", list(BindingPolicy)),
                   axis("n_maps", m_range),
                   vm_type="medium")
    res = plan.run()
    out = sweep.simulate_batch(legacy)
    mk = np.asarray(out.makespan[:, 0]).reshape(
        len(SchedPolicy), len(BindingPolicy), len(m_range))
    np.testing.assert_array_equal(mk, res["makespan"])


# ---------------------------------------------------------------------------
# Heterogeneous-VM device-side cells (the closed ROADMAP item)
# ---------------------------------------------------------------------------

HET_VMS = (VM_SMALL, VM_MEDIUM, VM_LARGE)
HET_JOB = dataclasses.replace(JOB_MEDIUM, n_maps=7, n_reduces=2)


@pytest.mark.parametrize("sp,bp", ALL_POLICIES,
                         ids=[f"{sp.name}-{bp.name}" for sp, bp in ALL_POLICIES])
def test_hetero_encode_cell_matches_host_encoding(sp, bp):
    """Mixed small/medium/large cell via per-VM-array encode_cell must match
    from_scenario (the stack_scenarios element encoding) bit for bit."""
    sc = Scenario(vms=HET_VMS, jobs=(HET_JOB,), sched_policy=sp,
                  binding_policy=bp)
    host = engine.from_scenario(sc, pad_tasks=12, pad_vms=4)
    dev = sweep.encode_cell(
        n_maps=7, n_reduces=2, n_vms=3,
        vm_mips=np.array([v.mips for v in HET_VMS] + [0.0], np.float32),
        vm_pes=np.array([float(v.pes) for v in HET_VMS] + [0.0], np.float32),
        vm_cost=np.array([v.cost_per_sec for v in HET_VMS] + [0.0],
                         np.float32),
        job_length=HET_JOB.length_mi, job_data=HET_JOB.data_mb,
        pad_tasks=12, pad_vms=4, sched_policy=int(sp), binding_policy=int(bp))
    for f in engine.ScenarioArrays._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(host, f)), np.asarray(getattr(dev, f)),
            err_msg=f"field {f} ({sp.name}/{bp.name})")


@pytest.mark.parametrize("sp,bp", ALL_POLICIES,
                         ids=[f"{sp.name}-{bp.name}" for sp, bp in ALL_POLICIES])
def test_hetero_device_sweep_matches_oracle(sp, bp):
    """The same mixed cell simulated through a vms-axis SweepPlan matches
    stack_scenarios + the refsim oracle."""
    sc = Scenario(vms=HET_VMS, jobs=(HET_JOB,), sched_policy=sp,
                  binding_policy=bp)
    plan = product(axis("vms", [HET_VMS]),
                   sched_policy=sp, binding_policy=bp,
                   n_maps=7, n_reduces=2,
                   job_length=HET_JOB.length_mi, job_data=HET_JOB.data_mb)
    res = plan.run()
    stacked = sweep.simulate_batch(sweep.stack_scenarios([sc]))
    np.testing.assert_array_equal(res["makespan"],
                                  np.asarray(stacked.makespan[:, 0]))
    ref = refsim.simulate(sc).job()
    for f in ("avg_exec", "makespan", "vm_cost", "network_cost"):
        np.testing.assert_allclose(res[f].item(), getattr(ref, f),
                                   rtol=2e-4, atol=1e-2,
                                   err_msg=f"{f} ({sp.name}/{bp.name})")


def test_hetero_least_loaded_beats_round_robin_device_side():
    """Acceptance: a heterogeneous device-side sweep where LEAST_LOADED
    beats ROUND_ROBIN on makespan (binding differentiates inside grids)."""
    plan = product(axis("binding_policy", list(BindingPolicy)),
                   vms=("medium",) * 2 + ("small",) * 4,
                   sched_policy=SchedPolicy.SPACE_SHARED,
                   n_maps=12, n_reduces=2, job_type="medium")
    res = plan.run()
    ll = float(res.select(binding_policy=BindingPolicy.LEAST_LOADED)["makespan"])
    rr = float(res.select(binding_policy=BindingPolicy.ROUND_ROBIN)["makespan"])
    assert ll < rr, f"LEAST_LOADED {ll} !< ROUND_ROBIN {rr}"
    # and the oracle agrees with both device-side numbers
    for bp, got in ((BindingPolicy.LEAST_LOADED, ll),
                    (BindingPolicy.ROUND_ROBIN, rr)):
        sc = Scenario(vms=(VM_MEDIUM,) * 2 + (VM_SMALL,) * 4,
                      jobs=(dataclasses.replace(JOB_MEDIUM, n_maps=12,
                                                n_reduces=2),),
                      sched_policy=SchedPolicy.SPACE_SHARED,
                      binding_policy=bp)
        assert refsim.simulate(sc).job().makespan == pytest.approx(got,
                                                                   rel=2e-4)


# ---------------------------------------------------------------------------
# Plan composition, labeling, execution modes
# ---------------------------------------------------------------------------

def test_zip_and_select_composition():
    plan = product(
        zip_(axis("n_maps", (1, 2, 4)), axis("job_type",
                                             ("small", "medium", "big"))),
        axis("vm_type", ("small", "medium")),
    )
    assert plan.shape == (3, 2)
    res = plan.run()
    assert res["makespan"].shape == (3, 2)
    # selecting a zipped component drops the whole zipped dim
    one = res.select(n_maps=4, vm_type="medium")
    assert one.shape == ()
    d = one.to_dict()
    single = engine.simulate(paper_scenario(job="big", vm="medium", n_maps=4))
    assert d["makespan"] == pytest.approx(float(single.makespan[0]), rel=1e-6)
    # multi-match keeps a filtered dim; enum/str coords both resolve
    assert res.select(vm_type="small").shape == (3,)
    assert res.coord((2, 1)) == {"n_maps": 4, "job_type": "big",
                                 "vm_type": "medium"}
    # two components of one zipped dim constrain it jointly
    both = res.select(n_maps=4, job_type="big", vm_type="medium")
    assert both.to_dict()["makespan"] == d["makespan"]
    with pytest.raises(KeyError, match="not on the axis"):
        res.select(n_maps=4, job_type="small")      # inconsistent pair


def test_run_chunked_bit_identical():
    plan = product(axis("n_maps", range(1, 11)))
    res = plan.run()
    chunked = plan.run(chunk=4)          # 10 cells -> 4+4+2(padded)
    for name in res.metric_names:
        np.testing.assert_array_equal(res[name], chunked[name],
                                      err_msg=name)


def test_run_on_mesh_matches_plain():
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("pod",))
    plan = product(axis("n_maps", range(1, 8)))   # 7 cells: exercises padding
    res, sharded = plan.run(), plan.run(mesh=mesh)
    for name in res.metric_names:
        np.testing.assert_array_equal(res[name], sharded[name], err_msg=name)


def test_per_job_completion_and_utilization_metrics():
    res = product(axis("n_maps", (1, 5))).run()
    np.testing.assert_allclose(res["completion"], res["makespan"])  # submit=0
    assert (res["utilization"] > 0).all() and (res["utilization"] <= 1).all()
    # more parallelism -> better cluster utilization on the 3-VM cell
    assert res.select(n_maps=5)["utilization"] > res.select(n_maps=1)["utilization"]


def test_to_table_columnar_export():
    """ROADMAP columnar-export slice: long-form dict-of-numpy columns in
    row-major grid order, coordinate columns coherent with coord()."""
    plan = product(
        zip_(axis("n_maps", (1, 2, 4)), axis("job_type",
                                             ("small", "medium", "big"))),
        axis("binding_policy", list(BindingPolicy)[:2]),
    )
    res = plan.run()
    t = res.to_table()
    n = 3 * 2
    assert set(t) == {"n_maps", "job_type", "binding_policy",
                      *res.metric_names}
    for k, col in t.items():
        assert col.shape == (n,), k
    # row-major order: last axis fastest; enum labels export as names
    assert t["n_maps"].tolist() == [1, 1, 2, 2, 4, 4]
    assert t["binding_policy"].tolist() == ["ROUND_ROBIN", "LEAST_LOADED"] * 3
    assert t["job_type"].tolist() == ["small"] * 2 + ["medium"] * 2 + ["big"] * 2
    # values line up with select()
    k = 5      # (n_maps=4, LEAST_LOADED)
    sel = res.select(n_maps=4, binding_policy=BindingPolicy.LEAST_LOADED)
    assert t["makespan"][k] == sel["makespan"].item()
    # 0-d results export as single-row tables
    one = sel.to_table()
    assert one["makespan"].shape == (1,)


def test_to_table_multi_job_long_form():
    """Cells holding several jobs expand to one row per (cell, job) with a
    job index column; per-scenario metrics repeat across the job rows."""
    from repro.core import paper_scenario
    scs = [paper_scenario(n_maps=m) for m in (1, 3)]
    sc2 = Scenario(jobs=(scs[0].jobs[0], dataclasses.replace(
        scs[0].jobs[0], submit_time=500.0)))
    batch = sweep.stack_scenarios([sc2, sc2.replace(
        jobs=tuple(dataclasses.replace(j, n_maps=2) for j in sc2.jobs))])
    jm = sweep.simulate_batch(batch)
    out, _ = sweep.simulate_batch_arrays(batch)
    res = sweep.SweepResult(
        axis_names=(("cell",),), axis_labels=(((0,), (1,)),),
        metrics={"makespan": np.asarray(jm.makespan),
                 "finish_time": np.asarray(out.finish_time)}, n_jobs=2)
    t = res.to_table()
    assert t["job"].tolist() == [0, 1, 0, 1]
    assert t["cell"].tolist() == [0, 0, 1, 1]
    np.testing.assert_array_equal(t["makespan"],
                                  np.asarray(jm.makespan).reshape(4))
    # per-scenario metric repeats across a cell's job rows
    assert t["finish_time"][0] == t["finish_time"][1]


def test_to_parquet_import_guarded():
    res = product(axis("n_maps", (1, 2))).run()
    try:
        import pyarrow  # noqa: F401
    except ImportError:
        with pytest.raises(ImportError, match="pyarrow"):
            res.to_parquet("/tmp/_sweep_should_not_exist.parquet")
    else:
        import tempfile
        import pyarrow.parquet as pq
        with tempfile.NamedTemporaryFile(suffix=".parquet") as f:
            res.to_parquet(f.name)
            table = pq.read_table(f.name)
            assert table.num_rows == 2
            np.testing.assert_array_equal(
                np.asarray(table["makespan"]), res["makespan"])


def test_select_errors_name_unknown_keys():
    res = product(axis("n_maps", (1, 2))).run()
    with pytest.raises(KeyError, match="no axis"):
        res.select(bogus=3)
    with pytest.raises(KeyError, match="not on the axis"):
        res.select(n_maps=99)
    with pytest.raises(KeyError, match="no metric"):
        res["nope"]


# ---------------------------------------------------------------------------
# Validation: clear errors instead of opaque vmap shape failures
# ---------------------------------------------------------------------------

def test_grid_arrays_unequal_lengths_names_offender():
    params = dict(n_maps=np.arange(1, 5, dtype=np.int32),
                  n_reduces=np.ones(4, np.int32),
                  n_vms=np.full(4, 3, np.int32),
                  vm_mips=np.full(3, 250.0, np.float32),   # wrong length
                  vm_pes=np.ones(4, np.float32),
                  vm_cost=np.ones(4, np.float32),
                  job_length=np.full(4, 1e5, np.float32),
                  job_data=np.full(4, 2e5, np.float32))
    with pytest.raises(ValueError, match="vm_mips"):
        sweep.grid_arrays(params, pad_tasks=6, pad_vms=3)


def test_grid_arrays_unknown_key():
    with pytest.raises(ValueError, match="unknown.*n_mapss"):
        sweep.grid_arrays({"n_mapss": np.ones(3, np.int32)},
                          pad_tasks=4, pad_vms=3)


def test_grid_arrays_scalar_param_rejected():
    with pytest.raises(ValueError, match="leading grid dimension"):
        sweep.grid_arrays({"n_maps": np.int32(3)}, pad_tasks=4, pad_vms=3)


def test_grid_arrays_trailing_width_validated():
    base = dict(n_maps=np.full(4, 2, np.int32))
    with pytest.raises(ValueError, match="vm_mips.*pad_vms=3"):
        sweep.grid_arrays({**base, "vm_mips": np.full((4, 5), 250.0,
                                                      np.float32)},
                          pad_tasks=4, pad_vms=3)
    with pytest.raises(ValueError, match="one scalar per cell"):
        sweep.grid_arrays({**base, "job_length": np.full((4, 2), 1e5,
                                                         np.float32)},
                          pad_tasks=4, pad_vms=3)


def test_zip_length_mismatch_names_axes():
    with pytest.raises(ValueError, match="n_maps"):
        zip_(axis("n_maps", (1, 2, 3)), axis("n_vms", (3, 6)))


def test_plan_conflicting_parameter_owners():
    with pytest.raises(ValueError, match="vm_mips"):
        product(axis("vm_type", ("small",)), vm_mips=500.0).params()
    with pytest.raises(ValueError, match="n_vms"):
        product(axis("vms", [("small", "small")]),
                axis("n_vms", (1, 2))).params()


def test_axis_unknown_name_lists_valid():
    with pytest.raises(ValueError, match="not an encode_cell parameter"):
        axis("warp_factor", (1, 2))
    with pytest.raises(ValueError, match="unknown VM type"):
        axis("vm_type", ("tiny",))


def test_plan_padding_too_small():
    plan = product(axis("n_maps", (1, 30))).replace(pad_tasks=8)
    with pytest.raises(ValueError, match="pad_tasks"):
        plan.arrays()


def test_per_vm_vector_narrower_than_n_vms_rejected():
    """A 2-entry vm_mips vector with the default n_vms=3 must error, not
    silently run VM 2 at 0 MIPS (regression: zero-padding gave makespan=1e30
    with no exception)."""
    plan = product(axis("vm_mips", [np.array([500.0, 250.0])]))
    with pytest.raises(ValueError, match="vm_mips.*n_vms=3"):
        plan.params()
    # wide enough for its n_vms: fine, and extra lanes are ignored
    ok = product(axis("vm_mips", [np.array([500.0, 250.0])]), n_vms=2)
    assert ok.params()["vm_mips"].shape == (1, 2)


def test_axis_vector_values_validated():
    # vectors for a scalar-only parameter: clear error, not a deep
    # encode_cell broadcast failure
    with pytest.raises(ValueError, match="one scalar per cell"):
        axis("n_maps", [[1, 2], [3, 4]])
    # mixed scalar/vector values: the intended ValueError, not IndexError
    with pytest.raises(ValueError, match="1-D"):
        axis("vm_mips", [250.0, [250.0, 500.0]])
    with pytest.raises(ValueError, match="share one length"):
        axis("vm_mips", [[250.0], [250.0, 500.0]])


def test_sharded_runner_cached_per_mesh():
    from repro.core.sweep import _sharded_runner
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("pod",))
    assert _sharded_runner(mesh) is _sharded_runner(mesh)
