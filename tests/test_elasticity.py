"""Dynamic cloud elasticity (DESIGN.md §8): VM lease windows, arrival
processes, pay-as-you-go accounting — cross-layer parity in the repo's
usual pattern:

* **degenerate static-fleet parity** — explicit ``vm_start=0`` /
  ``vm_stop=inf`` / ``spinup=0`` / zero-priority columns must be
  *bitwise* identical to a plan that never mentions elasticity, across
  the bucketed, chunked and pallas execution modes (every availability
  op is an identity there);
* **seeded elastic grids** — lease windows, spinup, arrival instants and
  priorities as data: oracle bindings bitwise, oracle times to the
  f32-engine tolerance (rtol 2e-4), and engine ↔ batched early-exit ↔
  ``mr_epoch`` megakernel **bitwise** — including lanes with stranded
  tasks (lease closed before admission), which every array layer must
  agree on exactly;
* the acceptance property: shrinking a lease window (later start,
  longer spinup) strictly increases ``queue_wait``;
* pay-as-you-go billing: granularity ceiling, finite leases billed to
  their declared teardown, open-ended leases billed to the realized
  finish — cross-checked against the oracle through the one shared
  ``elasticity.billed_lease`` formula;
* seeded counter-based arrival processes (Poisson/uniform/burst) and
  the ``SweepPlan.arrivals`` axis;
* streaming chunked parquet export (``run(chunk=…, stream_to=…)``)
  equals the in-memory ``to_table`` rows exactly.
"""
import dataclasses
import math

import jax
import numpy as np
import pytest

from repro.core import (JOB_MEDIUM, JOB_SMALL, VM_MEDIUM, VM_SMALL,
                        ArrivalProcess, BindingPolicy, ElasticitySpec,
                        Scenario, SchedPolicy, elasticity, engine, refsim,
                        sweep)
from repro.core.sweep import arrivals, axis, product, zip_
from repro.kernels.mr_sched import epoch_schedule

_BIG = engine._BIG
REF_FIELDS = ("avg_exec", "max_exec", "min_exec", "makespan", "delay_time",
              "vm_cost", "network_cost")


# ---------------------------------------------------------------------------
# Arrival processes: seeded counter-hash streams
# ---------------------------------------------------------------------------

def test_arrival_times_deterministic_and_seeded():
    a = elasticity.arrival_times(50, rate=0.01, seed=3)
    b = elasticity.arrival_times(50, rate=0.01, seed=3)
    c = elasticity.arrival_times(50, rate=0.01, seed=4)
    np.testing.assert_array_equal(a, b)
    assert (a != c).any(), "seed must matter"
    assert (np.diff(a) >= 0).all() and (a >= 0).all()
    # a longer stream extends the same draws (counter-based, no RNG state)
    np.testing.assert_array_equal(a, elasticity.arrival_times(
        80, rate=0.01, seed=3)[:50])


@pytest.mark.parametrize("process", list(ArrivalProcess))
def test_arrival_rate_scales_offered_load(process):
    slow = elasticity.arrival_times(400, rate=0.001, process=process, seed=7)
    fast = elasticity.arrival_times(400, rate=0.01, process=process, seed=7)
    # mean inter-arrival ~= 1/rate; 10x the rate -> 10x the density
    np.testing.assert_allclose(slow[-1] / fast[-1], 10.0, rtol=1e-3)
    np.testing.assert_allclose(slow[-1] / 400, 1 / 0.001, rtol=0.2)


def test_burst_process_clumps_arrivals():
    t = elasticity.arrival_times(12, rate=0.01, process="burst", burst=4)
    # groups of 4 share one instant, instants spaced burst/rate apart
    assert (t.reshape(3, 4) == t.reshape(3, 4)[:, :1]).all()
    np.testing.assert_allclose(np.diff(t.reshape(3, 4)[:, 0]), 400.0)


def test_arrival_validation():
    with pytest.raises(ValueError, match="rate"):
        elasticity.arrival_times(5, rate=0.0)
    with pytest.raises(ValueError, match="n >= 1"):
        elasticity.arrival_times(0, rate=1.0)
    with pytest.raises(ValueError, match="unknown arrival process"):
        elasticity.arrival_times(5, rate=1.0, process="fractal")
    with pytest.raises(ValueError, match="burst"):
        elasticity.arrival_times(5, rate=1.0, process="burst", burst=0)


def test_arrivals_axis_and_plan_method():
    plan = product(axis("n_vms", (2, 3))).arrivals(6, rate=0.005, seed=2)
    assert plan.shape == (2, 6)
    sub = plan.run().select(n_vms=3, arrival=4)
    want = elasticity.arrival_times(6, rate=0.005, seed=2)[4]
    solo = product(axis("n_vms", (3,)),
                   job_submit=float(want)).run()
    assert sub["makespan"].item() == solo["makespan"].item()
    # job_submit column carries the exact stream (per n_vms grid row)
    np.testing.assert_array_equal(
        plan.params()["job_submit"].reshape(2, 6)[0],
        elasticity.arrival_times(6, rate=0.005, seed=2))


def test_arrivals_rate_sweep_one_flattened_dimension():
    dim = arrivals(5, rate=[0.001, 0.01], process="uniform", seed=9)
    assert dim.names == ("arrival_rate", "arrival")
    assert len(dim) == 10
    res = product(dim).run()
    slow = res.select(arrival_rate=0.001)
    assert slow.shape == (5,)
    # offered load is a real axis: later slow arrivals submit much later
    fast = res.select(arrival_rate=0.01)
    assert float(slow["completion"][-1]) > float(fast["completion"][-1])


# ---------------------------------------------------------------------------
# Degenerate static-fleet parity (the PR's hard bit-identity criterion)
# ---------------------------------------------------------------------------

def _policy_grid():
    return [
        zip_(axis("n_maps", (1, 7, 14, 3)), axis("n_vms", (1, 4, 6, 3))),
        axis("sched_policy", list(SchedPolicy)),
        axis("binding_policy", [BindingPolicy.ROUND_ROBIN,
                                BindingPolicy.LEAST_LOADED]),
    ]


def test_degenerate_elastic_columns_bitwise_noop():
    """vm_start=0, vm_stop=inf, spinup=0, zero priorities: all execution
    modes must reproduce the elasticity-free plan bit for bit."""
    plain = product(*_policy_grid())
    degen = product(*_policy_grid(), vm_start=0.0, vm_stop=math.inf,
                    spinup_delay=0.0, billing_granularity=1.0,
                    job_submit=0.0)
    base = plain.run()
    for tag, res in {
        "bucketed": degen.run(),
        "unbucketed": degen.run(bucket=False),
        "chunked": degen.run(chunk=7),
        "pallas": degen.run(backend="pallas"),
    }.items():
        for name in base.metric_names:
            if name == "realized_epochs":
                continue
            np.testing.assert_array_equal(base[name], res[name],
                                          err_msg=f"{name} ({tag})")


def test_degenerate_encoding_matches_from_scenario():
    """Default Scenario encoding carries the degenerate window and zero
    priorities; an explicit per-VM lease in the spec round-trips through
    both encoders bit for bit."""
    arrs = engine.from_scenario(Scenario())
    assert np.asarray(arrs.vm_start).tolist() == [0.0] * 3
    assert np.asarray(arrs.vm_stop).tolist() == [np.float32(_BIG)] * 3
    assert float(arrs.spinup_delay) == 0.0
    assert np.asarray(arrs.task_prio).tolist() == [0.0, 0.0]
    vms = (dataclasses.replace(VM_SMALL, lease_start=100.0, lease_stop=9e3),
           dataclasses.replace(VM_SMALL, lease_start=0.0),
           VM_SMALL)
    sc = Scenario(vms=vms, jobs=(dataclasses.replace(JOB_SMALL, n_maps=4),),
                  elasticity=ElasticitySpec(spinup_delay=30.0,
                                            billing_granularity=60.0))
    host = engine.from_scenario(sc, pad_tasks=5, pad_vms=4)
    dev = sweep.encode_cell(
        n_maps=4, n_reduces=1, n_vms=3, vm_mips=250.0, vm_pes=1.0,
        vm_cost=1.0, job_length=JOB_SMALL.length_mi,
        job_data=JOB_SMALL.data_mb, pad_tasks=5, pad_vms=4,
        vm_start=np.array([100.0, 0.0, 0.0, 0.0], np.float32),
        vm_stop=np.array([9e3, _BIG, _BIG, _BIG], np.float32),
        spinup_delay=30.0, billing_granularity=60.0)
    for f in engine.ScenarioArrays._fields:
        np.testing.assert_allclose(
            np.asarray(getattr(host, f), np.float32),
            np.asarray(getattr(dev, f), np.float32), err_msg=f"field {f}")


# ---------------------------------------------------------------------------
# Seeded elastic grids: refsim <-> engine <-> batched <-> mr_epoch parity
# ---------------------------------------------------------------------------

def _elastic_scenario(seed: int, sp: SchedPolicy) -> Scenario:
    """Random leased fleet exercising every elastic knob without stranding
    (stops are generous so the oracle's inf and the engine's _BIG never
    have to be compared against each other)."""
    rng = np.random.default_rng(seed)
    vms = []
    for _ in range(int(rng.integers(2, 7))):
        base = VM_SMALL if rng.random() < 0.5 else VM_MEDIUM
        start = float(rng.choice([0.0, 400.0, 1500.0]))
        stop = float(rng.choice([start + 30000.0, math.inf]))
        vms.append(dataclasses.replace(base, lease_start=start,
                                       lease_stop=stop))
    job = dataclasses.replace(
        JOB_SMALL if rng.random() < 0.5 else JOB_MEDIUM,
        n_maps=int(rng.integers(3, 13)), n_reduces=int(rng.integers(1, 3)),
        submit_time=float(rng.choice([0.0, 250.0])),
        priority=float(rng.integers(0, 3)))
    return Scenario(
        vms=tuple(vms), jobs=(job,),
        elasticity=ElasticitySpec(
            spinup_delay=float(rng.choice([0.0, 90.0])),
            billing_granularity=float(rng.choice([1.0, 3600.0]))),
        sched_policy=sp,
        binding_policy=BindingPolicy(rng.integers(0, 3)))


ELASTIC_COMBOS = [(s, sp) for s in range(4) for sp in SchedPolicy]


@pytest.mark.parametrize("seed,sp", ELASTIC_COMBOS,
                         ids=[f"s{s}-{sp.name}" for s, sp in ELASTIC_COMBOS])
def test_elastic_parity_refsim_engine_pallas(seed, sp):
    sc = _elastic_scenario(200 + seed, sp)
    ref = refsim.simulate(sc)
    assert all(t.finish < math.inf for t in ref.tasks), "generator stranded"
    arrs = engine.from_scenario(sc, pad_tasks=15, pad_vms=7)

    np.testing.assert_array_equal(
        [t.vm for t in ref.tasks],
        np.asarray(arrs.task_vm)[:sc.total_tasks()])

    got = engine._simulate_jit(arrs)
    for f in REF_FIELDS:
        np.testing.assert_allclose(
            float(getattr(got, f)[0]), getattr(ref.jobs[0], f),
            rtol=2e-4, atol=1e-2, err_msg=f"{f} (seed {seed})")
    # queue_wait: oracle wait (start - data readiness) == engine metric
    out = engine.simulate_arrays(arrs)
    sm = engine.scenario_metrics(arrs, out)
    ref_wait = np.mean([t.start - t.ready for t in ref.tasks])
    np.testing.assert_allclose(float(sm.queue_wait), ref_wait,
                               rtol=2e-4, atol=1e-2)

    # engine <-> batched early exit <-> mr_epoch megakernel: bitwise
    batch = sweep.stack_scenarios(
        [sc, sc.replace(sched_policy=SchedPolicy.TIME_SHARED)])
    lane = jax.jit(jax.vmap(engine.simulate_arrays))(batch)
    both, _ = jax.jit(engine.simulate_batch_arrays)(batch)
    kern = epoch_schedule(batch, tile=2, interpret=True)
    for f in lane._fields:
        np.testing.assert_array_equal(np.asarray(getattr(lane, f)),
                                      np.asarray(getattr(both, f)),
                                      err_msg=f"batched {f}")
        np.testing.assert_array_equal(np.asarray(getattr(lane, f)),
                                      np.asarray(getattr(kern, f)),
                                      err_msg=f"pallas {f}")


def test_elastic_mixed_grid_engine_vs_pallas_bitwise():
    """A random device-side grid mixing policies, storage AND elasticity —
    including deliberately stranding lease windows — through grid_arrays:
    batched engine == megakernel, bitwise."""
    n = 48
    rng = np.random.default_rng(23)
    params = dict(
        n_maps=rng.integers(1, 16, n).astype(np.int32),
        n_reduces=rng.integers(1, 3, n).astype(np.int32),
        n_vms=rng.integers(1, 9, n).astype(np.int32),
        vm_mips=rng.choice([250.0, 500.0], n).astype(np.float32),
        vm_pes=rng.choice([1.0, 2.0], n).astype(np.float32),
        vm_cost=np.ones(n, np.float32),
        job_length=rng.choice([362880.0, 725760.0], n).astype(np.float32),
        job_data=rng.choice([2e5, 4e5], n).astype(np.float32),
        job_submit=rng.choice([0.0, 400.0], n).astype(np.float32),
        sched_policy=rng.integers(0, 2, n).astype(np.int32),
        binding_policy=rng.integers(0, 3, n).astype(np.int32),
        spinup_delay=rng.choice([0.0, 120.0], n).astype(np.float32),
        vm_start=rng.choice([0.0, 800.0], (n, 8)).astype(np.float32),
        # some stop values close *before* some tasks become eligible:
        # stranded lanes must agree bitwise across the array layers too
        vm_stop=rng.choice([900.0, 40000.0, _BIG], (n, 8)
                           ).astype(np.float32),
        task_prio=rng.integers(0, 3, (n, 18)).astype(np.float32),
    )
    batch = sweep.grid_arrays(params, pad_tasks=18, pad_vms=8)
    eng, _ = jax.jit(engine.simulate_batch_arrays)(batch)
    out = epoch_schedule(batch, tile=8, interpret=True)
    stranded = np.asarray(batch.task_valid) & (np.asarray(eng.finish)
                                               >= _BIG / 2)
    assert stranded.any(), "grid should exercise stranding"
    for f in eng._fields:
        np.testing.assert_array_equal(np.asarray(getattr(eng, f)),
                                      np.asarray(getattr(out, f)),
                                      err_msg=f)


def test_stranded_semantics_refsim_matches_engine():
    """A lease that closes before a queued task can be admitted strands it
    in *both* simulators: the oracle leaves finish=inf, the engine leaves
    the _BIG stand-in, and the stranded sets are identical."""
    vms = (dataclasses.replace(VM_SMALL, lease_stop=900.0),
           dataclasses.replace(VM_SMALL, lease_stop=600.0))
    job = dataclasses.replace(JOB_SMALL, n_maps=6, n_reduces=1)
    sc = Scenario(vms=vms, jobs=(job,),
                  sched_policy=SchedPolicy.SPACE_SHARED)
    ref = refsim.simulate(sc)
    arrs = engine.from_scenario(sc)
    out = engine.simulate_arrays(arrs)
    ref_stranded = [t.finish == math.inf for t in ref.tasks]
    eng_stranded = (np.asarray(out.finish) >= _BIG / 2)[
        :sc.total_tasks()].tolist()
    assert ref_stranded == eng_stranded
    assert any(ref_stranded), "scenario should strand its reduce"
    # strict close: a task eligible exactly at the stop is NOT admitted
    sc0 = Scenario(vms=(dataclasses.replace(VM_SMALL, lease_stop=0.0),),
                   jobs=(JOB_SMALL,),
                   network=dataclasses.replace(sc.network, enabled=False))
    assert refsim.simulate(sc0).tasks[0].finish == math.inf
    out0 = engine.simulate_arrays(engine.from_scenario(sc0))
    assert float(np.asarray(out0.finish)[0]) >= _BIG / 2


def test_lease_start_edge_is_an_event():
    """A map ready before its VM's lease opens starts exactly at the
    lease-open edge (start + spinup) — in both simulators."""
    vms = (dataclasses.replace(VM_SMALL, lease_start=2000.0),) * 2
    sc = Scenario(vms=vms, jobs=(JOB_SMALL,),
                  elasticity=ElasticitySpec(spinup_delay=500.0))
    ref = refsim.simulate(sc)
    assert ref.tasks[0].start == 2500.0
    out = engine.simulate_arrays(engine.from_scenario(sc))
    assert float(np.asarray(out.start)[0]) == 2500.0


# ---------------------------------------------------------------------------
# Acceptance: shrinking the lease window strictly increases queue_wait
# ---------------------------------------------------------------------------

def test_shrinking_lease_strictly_increases_queue_wait():
    starts = (0.0, 600.0, 1200.0, 2400.0)
    res = product(axis("vm_start", starts),
                  n_maps=8, n_reduces=2, n_vms=4).run()
    qw = res["queue_wait"]
    assert (np.diff(qw) > 0).all(), qw
    assert qw[0] == 0.0     # time-shared static fleet: no waiting at all
    # spinup delay shrinks the window from the same edge
    res2 = product(axis("spinup_delay", (0.0, 300.0, 900.0)),
                   vm_start=600.0, n_maps=8, n_reduces=2, n_vms=4).run()
    assert (np.diff(res2["queue_wait"]) > 0).all()
    # and the wait shows up in completion too (admission really delayed)
    assert float(res["completion"][-1]) > float(res["completion"][0])


# ---------------------------------------------------------------------------
# Pay-as-you-go billing
# ---------------------------------------------------------------------------

def test_billed_cost_granularity_and_open_lease():
    res = product(axis("billing_granularity", (1.0, 3600.0)),
                  n_maps=4, n_vms=3, vm_cost=2.0).run()
    fin = float(res["finish_time"][0])
    # open-ended lease: billed to the realized finish, per VM
    np.testing.assert_allclose(res["billed_cost"][0],
                               3 * 2.0 * np.ceil(fin), rtol=1e-6)
    np.testing.assert_allclose(
        res["billed_cost"][1],
        3 * 2.0 * 3600.0 * np.ceil(fin / 3600.0), rtol=1e-6)
    # coarser granularity can only bill more
    assert res["billed_cost"][1] >= res["billed_cost"][0]


def test_billed_cost_finite_lease_bills_declared_window():
    """A finite lease bills its declared window even when the workload
    finishes early — the pay-as-you-go trade the smart_city Part-4
    right-sizing sweep optimizes."""
    res = product(axis("vm_stop", (20000.0, 50000.0)),
                  n_maps=4, n_vms=2).run()
    assert float(res["finish_time"].max()) < 20000.0
    np.testing.assert_allclose(res["billed_cost"], [2 * 20000.0,
                                                    2 * 50000.0])
    # vm_busy_fraction scales inversely with the idle lease tail
    assert res["vm_busy_fraction"][0] > res["vm_busy_fraction"][1]


def test_billed_lease_shared_formula_matches_oracle():
    sc = _elastic_scenario(321, SchedPolicy.SPACE_SHARED)
    ref = refsim.simulate(sc)
    arrs = engine.from_scenario(sc)
    sm = engine.scenario_metrics(arrs, engine.simulate_arrays(arrs))
    el = sc.elasticity
    busy_end = np.zeros(len(sc.vms))
    for t in ref.tasks:
        busy_end[t.vm] = max(busy_end[t.vm], t.finish)
    billed = elasticity.billed_lease(
        np.array([v.lease_start for v in sc.vms]),
        np.array([elasticity.encode_lease_stop(v.lease_stop)
                  for v in sc.vms]),
        busy_end, ref.finish_time, el.billing_granularity)
    want = float(np.sum(billed * [v.cost_per_sec for v in sc.vms]))
    np.testing.assert_allclose(float(sm.billed_cost), want, rtol=2e-4)


# ---------------------------------------------------------------------------
# Priority-aware admission rank (satellite: first priority slice)
# ---------------------------------------------------------------------------

def test_priority_reorders_space_shared_admission():
    """One 1-PE VM, 4 queued maps: the task_prio vector overrides the
    (ready, index) order — highest priority admitted first."""
    prio = np.zeros(5, np.float32)
    prio[3] = 2.0       # map 3 jumps the queue
    prio[2] = 1.0
    base = dict(n_maps=4, n_reduces=1, n_vms=1,
                sched_policy=SchedPolicy.SPACE_SHARED)
    plain = product(**base).run()
    boosted = product(axis("task_prio", [prio]), **base).run()
    assert float(boosted["makespan"].item()) == float(plain["makespan"])
    # the boosted cell admits map 3 before maps 0-2 finished: its exec
    # window starts first among the equal-ready maps
    b = sweep.grid_arrays(dict(task_prio=prio[None],
                               n_maps=np.array([4], np.int32),
                               n_reduces=np.array([1], np.int32),
                               n_vms=np.array([1], np.int32),
                               vm_mips=np.array([250.0], np.float32),
                               vm_pes=np.array([1.0], np.float32),
                               vm_cost=np.array([1.0], np.float32),
                               job_length=np.array([362880.0], np.float32),
                               job_data=np.array([2e5], np.float32),
                               sched_policy=np.array(
                                   [int(SchedPolicy.SPACE_SHARED)],
                                   np.int32)),
                          pad_tasks=5, pad_vms=1)
    out = engine.simulate_arrays(jax.tree.map(lambda x: x[0], b))
    starts = np.asarray(out.start)[:4]
    assert starts[3] == starts.min()
    assert starts[2] == np.sort(starts)[1]
    # oracle agrees through job-level priorities: the high-priority job's
    # tasks win the shared VM's queue although submitted second
    lo = dataclasses.replace(JOB_SMALL, n_maps=3, priority=0.0)
    hi = dataclasses.replace(JOB_SMALL, n_maps=3, priority=5.0)
    sc = Scenario(vms=(VM_SMALL,), jobs=(lo, hi),
                  sched_policy=SchedPolicy.SPACE_SHARED)
    ref = refsim.simulate(sc)
    hi_starts = [t.start for t in ref.tasks if t.job == 1 and not
                 t.is_reduce]
    lo_starts = [t.start for t in ref.tasks if t.job == 0 and not
                 t.is_reduce]
    assert max(hi_starts) < max(lo_starts)
    got = engine._simulate_jit(engine.from_scenario(sc))
    for f in REF_FIELDS:
        np.testing.assert_allclose(
            np.asarray(getattr(got, f))[:2],
            [getattr(ref.jobs[0], f), getattr(ref.jobs[1], f)],
            rtol=2e-4, atol=1e-2, err_msg=f)


def test_zero_priority_column_is_bitwise_noop():
    plan = product(axis("n_maps", (3, 9)), axis("sched_policy",
                                                list(SchedPolicy)), n_vms=2)
    withp = product(axis("n_maps", (3, 9)),
                    axis("sched_policy", list(SchedPolicy)), n_vms=2,
                    task_prio=np.zeros(10, np.float32))
    a, b = plan.run(), withp.run()
    for name in a.metric_names:
        np.testing.assert_array_equal(a[name], b[name], err_msg=name)


# ---------------------------------------------------------------------------
# Streaming chunked parquet export (satellite: ROADMAP arrow item)
# ---------------------------------------------------------------------------

def test_streaming_export_equals_in_memory_table(tmp_path):
    pytest.importorskip("pyarrow")
    import pyarrow.parquet as pq
    plan = product(axis("n_maps", (1, 5, 9)),
                   axis("vm_stop", (30000.0, math.inf)),
                   axis("sched_policy", list(SchedPolicy)),
                   n_vms=3, spinup_delay=60.0)
    path = tmp_path / "grid.parquet"
    info = plan.run(chunk=5, stream_to=path)
    assert (info.n_cells, info.n_rows) == (12, 12) and info.n_chunks == 3
    disk = pq.read_table(path)
    mem = plan.run().to_table()
    assert disk.column_names == list(mem)
    for name, col in mem.items():
        if name == "realized_epochs":   # schedule-dependent by design
            continue
        np.testing.assert_array_equal(
            np.asarray(disk[name]), np.asarray(col), err_msg=name)


def test_streaming_requires_chunk(tmp_path):
    plan = product(axis("n_maps", (1, 2)))
    with pytest.raises(ValueError, match="chunk"):
        plan.run(stream_to=tmp_path / "x.parquet")


# ---------------------------------------------------------------------------
# Plan-build validation for the elastic parameter columns
# ---------------------------------------------------------------------------

def test_elastic_param_validation():
    with pytest.raises(ValueError, match="billing_granularity"):
        product(axis("billing_granularity", (0.0,))).params()
    with pytest.raises(ValueError, match="spinup_delay"):
        product(axis("spinup_delay", (-5.0,))).params()
    with pytest.raises(ValueError, match="job_submit"):
        product(axis("job_submit", (-1.0,))).params()
    # per-VM lease vectors ride the 'vm_*' column machinery
    cols = product(axis("vm_start", [np.array([0.0, 100.0])]),
                   n_vms=2).params()
    assert cols["vm_start"].shape == (1, 2)
