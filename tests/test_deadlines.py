"""Graceful degradation under overload (DESIGN.md §11): deadline-aware
admission (SHED), urgency escalation (BOOST), priority preemption, and
the SLO metrics layer — cross-layer parity in the repo's usual pattern:

* **degenerate bitwise parity** — every §11 knob switched on but fed
  degenerate data (deadlines at the ``_BIG`` sentinel, flat priorities)
  must reproduce the pre-§11 schedule bit for bit across engine ↔
  batched ↔ batched-compact (K ∈ {1, 4, "auto"}) ↔ pallas ``mr_epoch``
  dense + compact, including stranded lanes whose realized ``n_epochs``
  must keep the exact open-loop ``2T + 2`` count under the widened
  additive epoch bound;
* **oracle event parity** — the sequential calendar oracle models shed
  and preemption event-wise: *exactly* equal shed/preemption counts and
  schedules to the f32-engine tolerance (rtol 2e-4) over a
  policy × preemption grid;
* **overload acceptance** — staggered-arrival overloads where SHED
  strictly reduces ``p99_slack`` and BOOST strictly reduces
  ``deadline_miss_fraction`` against NONE; a preemption grid where
  ``preemptions > 0`` coexists with a rank-inversion count of zero;
  tightening deadlines monotonically grows the shed count;
* **seeded overload grids** — deadline + preemption + failure columns
  through the sweep: engine ↔ compact ↔ pallas five-way **bitwise** with
  ``shed_tasks > 0`` really exercised;
* sweep-plan validation: unmeetable/non-finite deadlines and orphaned
  preemption knobs fail at plan build with errors naming the axis;
* export: the five SLO metrics ride ``to_table()`` and the streaming
  parquet writer.
"""
import dataclasses
import math

import jax
import numpy as np
import pytest

from repro.core import (ControlSpec, DeadlinePolicy, Scenario, SchedPolicy,
                        control, engine, refsim, sweep)
from repro.core.config import JobSpec, NetworkSpec, VM_SMALL, paper_scenario
from repro.core.sweep import axis, product
from repro.kernels.mr_sched import epoch_schedule, epoch_schedule_compact

_BIG = engine._BIG
SLO_METRICS = ("deadline_miss_fraction", "shed_tasks", "preemptions",
               "wasted_work_frac", "p99_slack")
SCHED_FIELDS = engine.SimOutput._fields


def _assert_same(a, b, fields, msg):
    for f in fields:
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)),
                                      err_msg=f"{msg}: {f}")


def _overload(dlpol, *, preempt=False, resume=False, slack=0.0,
              sp=SchedPolicy.SPACE_SHARED, spacing=120.0,
              deadlines=(4000.0, 4600.0, 5200.0, 5800.0, 6400.0)):
    """Five staggered jobs on two small VMs: sustained overload with
    mixed static priorities, each job carrying one deadline."""
    jobs = tuple(JobSpec(f"j{i}", length_mi=362_880.0, data_mb=200_000.0,
                         n_maps=3, n_reduces=1, submit_time=spacing * i,
                         priority=float(i % 3), deadline=deadlines[i])
                 for i in range(5))
    return Scenario(vms=(VM_SMALL,) * 2, jobs=jobs,
                    network=NetworkSpec(enabled=False), sched_policy=sp,
                    control=ControlSpec(deadline_policy=dlpol,
                                        deadline_slack=slack,
                                        preempt=preempt,
                                        preempt_resume=resume))


# ---------------------------------------------------------------------------
# Policy coercion
# ---------------------------------------------------------------------------

def test_deadline_policy_coercion():
    assert control.as_deadline_policy("shed") == DeadlinePolicy.SHED
    assert control.as_deadline_policy(2) == DeadlinePolicy.BOOST
    assert control.as_deadline_policy(DeadlinePolicy.NONE) == 0
    with pytest.raises(ValueError, match="(?i)deadline"):
        control.as_deadline_policy("evict")


# ---------------------------------------------------------------------------
# Degenerate parity: every §11 op is a where over an all-false mask
# ---------------------------------------------------------------------------

def _arm(scs, policies, preempts):
    """Arm the §11 knobs per scenario without touching the workload."""
    return [sc.replace(control=dataclasses.replace(
        sc.control, deadline_policy=pol, deadline_slack=100.0,
        preempt=pre, preempt_resume=pre))
        for sc, pol, pre in zip(scs, policies, preempts)]


def _degenerate_pair():
    """(plain, armed) stacked single-job batches: ``armed`` switches on
    SHED/BOOST + preemption + resume per lane but feeds only ``_BIG``
    deadlines and flat priorities, so every predicate is all-false.
    Includes a stranded lane (lease closes early)."""
    base = [paper_scenario(n_maps=6, n_reduces=2, n_vms=3),
            paper_scenario(n_maps=8, n_reduces=2, n_vms=4,
                           sched_policy=SchedPolicy.SPACE_SHARED)]
    from repro.core.elasticity import ElasticitySpec
    strand = base[1].replace(
        vms=tuple(dataclasses.replace(v, lease_stop=500.0)
                  for v in base[1].vms),
        elasticity=ElasticitySpec())
    scs = base + [strand]
    # preemption stays off on the stranded lane: a lane that never drains
    # realizes its full epoch *bound*, and preempt=1 widens the bound by
    # +2T as data — arming it there is observable in n_epochs by design
    armed = _arm(scs, (DeadlinePolicy.SHED, DeadlinePolicy.BOOST,
                       DeadlinePolicy.BOOST), (True, True, False))
    return sweep.stack_scenarios(scs), sweep.stack_scenarios(armed)


def test_degenerate_deadline_bitwise_every_mode():
    plain, armed = _degenerate_pair()
    ref, _ = engine.simulate_batch_arrays(plain, control=False)
    assert (np.asarray(ref.finish[2]) >= _BIG / 2).any(), "no stranded lane"
    on, _ = engine.simulate_batch_arrays(armed, control=True)
    _assert_same(ref, on, SCHED_FIELDS, "engine armed")
    lane = jax.vmap(lambda sc: engine.simulate_arrays(sc, control=True)
                    )(armed)
    _assert_same(ref, lane, SCHED_FIELDS, "vmapped simulate_arrays")
    for K in (1, 4, "auto"):
        comp, _ = engine.simulate_batch_arrays_compact(armed, k=K,
                                                       control=True)
        _assert_same(ref, comp, SCHED_FIELDS, f"engine compact k={K}")
        pal, _ = epoch_schedule_compact(armed, k=K, control=True)
        _assert_same(ref, pal, SCHED_FIELDS, f"pallas compact k={K}")
    dense = epoch_schedule(armed, control=True)
    _assert_same(ref, dense, SCHED_FIELDS, "pallas dense")
    # the widened additive bound is per-lane *data*: degenerate lanes keep
    # the exact open-loop epoch count
    T = plain.task_valid.shape[1]
    np.testing.assert_array_equal(np.asarray(on.n_epochs),
                                  np.asarray(ref.n_epochs))
    assert int(np.asarray(ref.n_epochs).max()) <= 2 * T + 2


def test_degenerate_deadline_bitwise_multi_job_staggered():
    """Multi-job staggered arrivals armed with degenerate §11 data stay an
    identity through the engine lowerings (the oracle included); the
    ``mr_epoch`` kernel models single-job lanes only and sits this one
    out."""
    plain = _overload(DeadlinePolicy.NONE, deadlines=(math.inf,) * 5)
    # flatten the priorities: preemption over equal ranks never fires (the
    # strict > gate), so arming it stays an identity on this lane too
    plain = plain.replace(jobs=tuple(
        dataclasses.replace(j, priority=0.0) for j in plain.jobs))
    armed, = _arm([plain], (DeadlinePolicy.SHED,), (True,))
    a = engine.simulate_arrays(engine.from_scenario(plain), control=False)
    b = engine.simulate_arrays(engine.from_scenario(armed), control=True)
    _assert_same(a, b, SCHED_FIELDS, "armed multi-job")
    batch = sweep.stack_scenarios([plain, armed])
    both, _ = engine.simulate_batch_arrays(batch, control=True)
    comp, _ = engine.simulate_batch_arrays_compact(batch, k=2, control=True)
    _assert_same(both, comp, SCHED_FIELDS, "compact multi-job")
    for f in ("start", "finish", "ready"):
        np.testing.assert_array_equal(np.asarray(getattr(both, f)[0]),
                                      np.asarray(getattr(both, f)[1]),
                                      err_msg=f"lane parity: {f}")
    ra, rb = refsim.simulate(plain), refsim.simulate(armed)
    assert rb.shed_tasks == 0 and rb.preemptions == 0
    assert [t.finish for t in ra.tasks] == [t.finish for t in rb.tasks]


def test_degenerate_deadline_columns_bitwise_noop_in_sweep():
    """Explicit sentinel deadline columns == a plan that never mentions
    them, through the sweep (control lowering vs open-loop one)."""
    pr = np.array([1.0, 0.0, 2.0, 0.0, 1.0, 0.0, 0.0, 2.0, 1.0], np.float32)
    plain = product(axis("n_maps", range(2, 8)), n_reduces=2, n_vms=4,
                    task_prio=pr,
                    sched_policy=SchedPolicy.SPACE_SHARED)
    armed = product(axis("n_maps", range(2, 8)), n_reduces=2, n_vms=4,
                    task_prio=pr,
                    sched_policy=SchedPolicy.SPACE_SHARED,
                    task_deadline=np.full(9, _BIG, np.float32),
                    deadline_policy="shed", deadline_slack=50.0,
                    preempt=1, preempt_resume=1)
    a, b = plain.run(), armed.run()
    for f in a.metric_names:
        np.testing.assert_array_equal(a[f], b[f], err_msg=f)
    c = armed.run(backend="pallas")
    for f in a.metric_names:
        np.testing.assert_array_equal(a[f], c[f], err_msg=f"pallas {f}")
    assert (a["shed_tasks"] == 0).all()
    assert (a["preemptions"] == 0).all()


# ---------------------------------------------------------------------------
# Oracle event parity: shed + preemption modelled event-wise
# ---------------------------------------------------------------------------

_PARITY_CASES = [
    ("none", dict(dlpol=DeadlinePolicy.NONE)),
    ("shed", dict(dlpol=DeadlinePolicy.SHED)),
    ("boost", dict(dlpol=DeadlinePolicy.BOOST, slack=100.0)),
    ("preempt", dict(dlpol=DeadlinePolicy.NONE, preempt=True)),
    ("preempt-resume", dict(dlpol=DeadlinePolicy.NONE, preempt=True,
                            resume=True)),
    ("shed-preempt", dict(dlpol=DeadlinePolicy.SHED, preempt=True,
                          resume=True)),
]


@pytest.mark.parametrize("name,kw", _PARITY_CASES,
                         ids=[n for n, _ in _PARITY_CASES])
def test_overload_refsim_matches_engine(name, kw):
    kw = dict(kw)
    sc = _overload(kw.pop("dlpol"), **kw)
    ref = refsim.simulate(sc)
    arrs = engine.from_scenario(sc)
    out = engine.simulate_arrays(arrs, control=True)
    sm = engine.scenario_metrics(arrs, out)
    n = sc.total_tasks()
    # event counts are integers: exactly equal
    shed_e = int(np.asarray(out.shed[:n]).sum())
    assert ref.shed_tasks == shed_e
    assert ref.preemptions == int(sm.preemptions)
    if name == "preempt":
        assert ref.preemptions > 0, "grid never preempted"
    # shed sets identical; kept schedules to the f32 tolerance
    ref_live = np.array([not t.shed for t in ref.tasks])
    eng_live = np.asarray(out.finish[:n]) < _BIG / 2
    np.testing.assert_array_equal(
        ref_live, np.asarray(~out.shed[:n]) if shed_e else eng_live)
    np.testing.assert_array_equal(eng_live, ref_live)
    rs = np.array([t.finish if not t.shed else np.inf for t in ref.tasks])
    es = np.asarray(out.finish[:n], np.float64)
    np.testing.assert_allclose(es[ref_live], rs[ref_live],
                               rtol=2e-4, atol=1e-2, err_msg=name)
    fin = max((t.finish for t in ref.tasks if t.finish < math.inf),
              default=0.0)
    np.testing.assert_allclose(float(sm.finish_time), fin,
                               rtol=2e-4, atol=1e-2)


# ---------------------------------------------------------------------------
# Overload acceptance: the policies actually help
# ---------------------------------------------------------------------------

def _metrics_of(sc):
    arrs = engine.from_scenario(sc)
    out = engine.simulate_arrays(arrs, control=True)
    return engine.scenario_metrics(arrs, out)


@pytest.mark.parametrize("spacing", [60.0, 120.0, 180.0])
def test_shed_strictly_reduces_p99_slack(spacing):
    none = _metrics_of(_overload(DeadlinePolicy.NONE, spacing=spacing))
    shed = _metrics_of(_overload(DeadlinePolicy.SHED, spacing=spacing))
    assert float(shed.shed_tasks) > 0, "grid never shed"
    assert float(shed.p99_slack) < float(none.p99_slack), (
        float(shed.p99_slack), float(none.p99_slack))
    # refused work is cheaper too: no late completions burning capacity
    assert float(shed.wasted_work_frac) < float(none.wasted_work_frac)


def _boost_pair(deadline, dlpol, slack=0.0):
    """A low-priority tight-deadline job stuck behind a high-priority
    batch: only urgency escalation can move it up the admission order."""
    ja = JobSpec("a", length_mi=450_000.0, data_mb=1000.0, n_maps=6,
                 n_reduces=1, submit_time=0.0, priority=5.0,
                 deadline=math.inf)
    jb = JobSpec("b", length_mi=75_000.0, data_mb=1000.0, n_maps=1,
                 n_reduces=1, submit_time=10.0, priority=0.0,
                 deadline=deadline)
    return Scenario(vms=(VM_SMALL,) * 2, jobs=(ja, jb),
                    network=NetworkSpec(enabled=False),
                    sched_policy=SchedPolicy.SPACE_SHARED,
                    control=ControlSpec(deadline_policy=dlpol,
                                        deadline_slack=slack))


@pytest.mark.parametrize("deadline", [1100.0, 1300.0])
def test_boost_strictly_reduces_miss_fraction(deadline):
    none = _metrics_of(_boost_pair(deadline, DeadlinePolicy.NONE))
    boost = _metrics_of(_boost_pair(deadline, DeadlinePolicy.BOOST,
                                    slack=500.0))
    assert float(none.deadline_miss_fraction) > 0, "grid never missed"
    assert float(boost.deadline_miss_fraction) \
        < float(none.deadline_miss_fraction)
    # BOOST only reorders admissions — nothing is refused or killed
    assert float(boost.shed_tasks) == 0
    assert float(boost.preemptions) == 0


@pytest.mark.parametrize("resume", [False, True])
@pytest.mark.parametrize("spacing", [60.0, 120.0])
def test_preemption_no_rank_inversion(resume, spacing):
    """With preemption on, no lower-priority task survives a full VM
    while a higher-priority task sits eligible and waiting — every such
    inversion is resolved by an eviction (``n_evict > 0``)."""
    sc = _overload(DeadlinePolicy.NONE, preempt=True, resume=resume,
                   spacing=spacing)
    arrs = engine.from_scenario(sc)
    out = engine.simulate_arrays(arrs, control=True)
    sm = engine.scenario_metrics(arrs, out)
    assert int(sm.preemptions) > 0, "grid never preempted"
    n = sc.total_tasks()
    prio = np.asarray(arrs.task_prio[:n])
    # evicted tasks re-dispatch onto their failover slot: rank inversions
    # are judged on the *realized* binding
    vm = np.where(np.asarray(out.hit[:n]), np.asarray(out.task_vm2[:n]),
                  np.asarray(arrs.task_vm[:n]))
    start = np.asarray(out.start[:n], np.float64)
    ready = np.asarray(out.ready[:n], np.float64)
    n_evict = np.asarray(out.n_evict[:n])
    inversions = 0
    for i in range(n):            # the waiting high-priority task
        for j in range(n):        # the running low-priority task
            if vm[i] != vm[j] or prio[i] <= prio[j]:
                continue
            if not (start[i] < math.inf and start[j] < math.inf):
                continue
            if ready[i] < start[j] - 1e-6 and start[i] > start[j] + 1e-6 \
                    and n_evict[j] == 0:
                inversions += 1
    assert inversions == 0, inversions


def test_tightening_deadlines_monotone_sheds():
    scales = [1.6, 1.2, 1.0, 0.8, 0.6]
    base = (4000.0, 4600.0, 5200.0, 5800.0, 6400.0)
    sheds = []
    for s in scales:
        sm = _metrics_of(_overload(
            DeadlinePolicy.SHED, deadlines=tuple(d * s for d in base)))
        sheds.append(int(sm.shed_tasks))
    assert sheds == sorted(sheds), sheds       # tighter -> never fewer sheds
    assert sheds[-1] > sheds[0], sheds         # and the sweep really moves


# ---------------------------------------------------------------------------
# Seeded overload grids: five-way bitwise through the sweep
# ---------------------------------------------------------------------------

def test_overload_grid_five_way_bitwise():
    dl = [np.array([400.0] * 4 + [900.0] * 4 + [1200.0] * 2, np.float32),
          np.array([250.0] * 8 + [2000.0] * 2, np.float32)]
    pr = np.array([0.0, 0.0, 1.0, 1.0, 2.0, 2.0, 0.0, 1.0, 0.0, 0.0],
                  np.float32)
    plan = (product(
        axis("task_deadline", dl),
        axis("deadline_policy", [0, 1, 2]),
        axis("preempt", [0, 1]),
        axis("sched_policy", list(SchedPolicy)),
        n_maps=8, n_reduces=2, n_vms=2, task_prio=pr, deadline_slack=100.0,
        preempt_resume=1, net_enabled=0.0, redispatch_delay=5.0)
        .failures(2, rate=0.002, n_vms=2, seed=7, repair_delay=200.0))
    te = plan.run()
    tp = plan.run(backend="pallas")
    tc1 = plan.run(compact=1)
    tc4 = plan.run(compact=4)
    tpc = plan.run(backend="pallas", compact=4)
    for f in te.metric_names:
        for name, other in (("pallas", tp), ("compact1", tc1),
                            ("compact4", tc4), ("pallas-compact", tpc)):
            np.testing.assert_array_equal(te[f], other[f],
                                          err_msg=f"{name}: {f}")
    # the acceptance grid really exercises the machinery
    assert (np.asarray(te["shed_tasks"]) > 0).any()
    assert (np.asarray(te["preemptions"]) > 0).any()
    # heavy-shed cells can end before the first failure instant — the
    # injected census clocks against the realized makespan
    assert (np.asarray(te["failures_injected"]) > 0).any()


# ---------------------------------------------------------------------------
# Sweep-plan validation: bad degradation axes fail at build, by name
# ---------------------------------------------------------------------------

def test_sweep_plan_validation_errors():
    pr = np.zeros(4, np.float32)
    with pytest.raises(ValueError, match="DeadlinePolicy"):
        axis("deadline_policy", [7])
    with pytest.raises(ValueError, match="task_deadline.*finite"):
        product(axis("task_deadline",
                     [np.array([np.inf, 100.0, 100.0, 100.0], np.float32)]),
                n_maps=2, n_reduces=2, n_vms=2,
                deadline_policy="shed").params()
    with pytest.raises(ValueError, match="task_deadline.*submit"):
        product(axis("task_deadline",
                     [np.full(4, 100.0, np.float32)]),
                n_maps=2, n_reduces=2, n_vms=2, job_submit=200.0,
                deadline_policy="shed").params()
    with pytest.raises(ValueError, match="'preempt'.*task_prio"):
        product(axis("preempt", [1]), n_maps=2, n_reduces=2,
                n_vms=2).params()
    with pytest.raises(ValueError, match="'preempt_resume'"):
        product(axis("preempt_resume", [1]), n_maps=2, n_reduces=2,
                n_vms=2).params()
    with pytest.raises(ValueError, match="deadline_slack"):
        product(axis("deadline_slack", [-1.0]), n_maps=2, n_reduces=2,
                n_vms=2, task_prio=pr).params()
    # zero knobs stay valid: preempt=0 without priorities is the identity
    product(axis("preempt", [0]), n_maps=2, n_reduces=2, n_vms=2).params()


# ---------------------------------------------------------------------------
# Export path: the five SLO metrics ride every export encoding
# ---------------------------------------------------------------------------

def test_slo_metrics_in_table_and_stream(tmp_path):
    dl = np.array([150.0] * 4 + [5000.0] * 4 + [9000.0] * 2, np.float32)
    plan = product(axis("vm_mips", [250.0, 500.0]),
                   axis("deadline_policy", ["none", "shed"]),
                   n_maps=8, n_reduces=2, n_vms=2, task_deadline=dl,
                   net_enabled=0.0)
    res = plan.run()
    tab = res.to_table()
    for m in SLO_METRICS:
        assert m in tab, sorted(tab)
    assert (np.asarray(tab["shed_tasks"]) > 0).any()
    assert (np.asarray(tab["deadline_miss_fraction"]) > 0).any()
    pa = pytest.importorskip("pyarrow")
    import pyarrow.parquet as pq
    path = tmp_path / "slo.parquet"
    plan.run(chunk=2, stream_to=path)
    disk = pq.read_table(path)
    for m in SLO_METRICS:
        np.testing.assert_array_equal(np.asarray(disk[m]),
                                      np.asarray(tab[m]), err_msg=m)
    del pa
