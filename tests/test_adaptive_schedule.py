"""Adaptive execution schedule (DESIGN.md §6): seeded bit-identity suite.

Pins the three new execution layers against the fixed-bound per-lane
engine and the ``refsim`` oracle across all 6 policy combos:

* ``engine.simulate_batch_arrays`` (batch-level early exit) must be
  **bitwise** identical to ``jax.vmap(engine.simulate_arrays)`` — the
  epoch body is idempotent for finished lanes, so sharing one epoch loop
  may not change a single ulp;
* the fused Pallas ``mr_epoch`` megakernel (per-VM admission scan, VMEM-
  resident state) must be bitwise identical to the engine in interpret
  mode — its one-hot contractions are 0/1-weighted sums, exact in any
  accumulation order;
* ``SweepPlan.run()``'s shape buckets must scatter back into the exact
  unbucketed cell order with bitwise-equal metrics (padding only adds
  exact-identity lanes), across the default, chunked, sharded and pallas
  execution modes, and must expose the realized epoch count.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import (JOB_MEDIUM, VM_MEDIUM, VM_SMALL, BindingPolicy,
                        Scenario, SchedPolicy, engine, refsim, sweep)
from repro.core.sweep import axis, product, zip_
from repro.kernels.mr_sched import epoch_schedule

ALL_POLICIES = [(sp, bp) for sp in SchedPolicy for bp in BindingPolicy]


def _random_params(n, seed, mixed_policies=True):
    rng = np.random.default_rng(seed)
    params = dict(
        n_maps=rng.integers(1, 21, n).astype(np.int32),
        n_reduces=rng.integers(1, 3, n).astype(np.int32),
        n_vms=rng.integers(1, 10, n).astype(np.int32),
        vm_mips=rng.choice([250.0, 500.0, 1000.0], n).astype(np.float32),
        vm_pes=rng.choice([1.0, 2.0, 4.0], n).astype(np.float32),
        vm_cost=rng.choice([1.0, 2.0], n).astype(np.float32),
        job_length=rng.choice([362880.0, 725760.0], n).astype(np.float32),
        job_data=rng.choice([2e5, 4e5], n).astype(np.float32),
    )
    if mixed_policies:
        params["sched_policy"] = rng.integers(0, 2, n).astype(np.int32)
        params["binding_policy"] = rng.integers(0, 3, n).astype(np.int32)
    return params


def _random_batch(n, seed, mixed_policies=True, **overrides):
    params = _random_params(n, seed, mixed_policies)
    params.update(overrides)
    return sweep.grid_arrays(params, pad_tasks=23, pad_vms=9)


# ---------------------------------------------------------------------------
# Batch-level early exit vs the per-lane fixed-bound loop
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sp,bp", ALL_POLICIES,
                         ids=[f"{sp.name}-{bp.name}"
                              for sp, bp in ALL_POLICIES])
def test_batched_early_exit_bitwise_per_policy(sp, bp):
    n = 24
    batch = _random_batch(n, seed=10 * int(sp) + int(bp),
                          mixed_policies=False,
                          sched_policy=np.full(n, int(sp), np.int32),
                          binding_policy=np.full(n, int(bp), np.int32))
    lane = jax.jit(jax.vmap(engine.simulate_arrays))(batch)
    both, realized = jax.jit(engine.simulate_batch_arrays)(batch)
    for f in lane._fields:
        np.testing.assert_array_equal(np.asarray(getattr(lane, f)),
                                      np.asarray(getattr(both, f)),
                                      err_msg=f"{f} ({sp.name}/{bp.name})")
    n_ep = np.asarray(lane.n_epochs)
    assert int(realized) == int(n_ep.max())
    assert int(realized) < 2 * 23 + 2, "no early exit realized"


def test_batched_early_exit_bitwise_mixed_batch():
    batch = _random_batch(64, seed=99)
    lane = jax.jit(jax.vmap(engine.simulate_arrays))(batch)
    both, realized = jax.jit(engine.simulate_batch_arrays)(batch)
    for f in lane._fields:
        np.testing.assert_array_equal(np.asarray(getattr(lane, f)),
                                      np.asarray(getattr(both, f)),
                                      err_msg=f)
    assert int(realized) == int(np.asarray(lane.n_epochs).max())


# ---------------------------------------------------------------------------
# mr_epoch megakernel vs the engine (bitwise) and the refsim oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tile", [8, 32])
def test_mr_epoch_bitwise_vs_engine_mixed(tile):
    batch = _random_batch(48, seed=tile)
    eng, _ = jax.jit(engine.simulate_batch_arrays)(batch)
    out = epoch_schedule(batch, tile=tile, interpret=True)
    for f in eng._fields:
        np.testing.assert_array_equal(np.asarray(getattr(eng, f)),
                                      np.asarray(getattr(out, f)),
                                      err_msg=f)


@pytest.mark.parametrize("sp,bp", ALL_POLICIES,
                         ids=[f"{sp.name}-{bp.name}"
                              for sp, bp in ALL_POLICIES])
def test_mr_epoch_bitwise_vs_engine_per_policy(sp, bp):
    n = 16
    batch = _random_batch(n, seed=40 + 10 * int(sp) + int(bp),
                          mixed_policies=False,
                          sched_policy=np.full(n, int(sp), np.int32),
                          binding_policy=np.full(n, int(bp), np.int32))
    eng, _ = jax.jit(engine.simulate_batch_arrays)(batch)
    out = epoch_schedule(batch, tile=8, interpret=True)
    for f in eng._fields:
        np.testing.assert_array_equal(np.asarray(getattr(eng, f)),
                                      np.asarray(getattr(out, f)),
                                      err_msg=f"{f} ({sp.name}/{bp.name})")


def test_mr_epoch_admission_scan_vs_refsim_oracle():
    """Space-shared multi-PE admission through the per-VM scan reproduces
    the sequential oracle on a heterogeneous cluster (slots contended)."""
    job = dataclasses.replace(JOB_MEDIUM, n_maps=11, n_reduces=3)
    sc = Scenario(vms=(VM_MEDIUM, VM_SMALL, VM_SMALL), jobs=(job,),
                  sched_policy=SchedPolicy.SPACE_SHARED,
                  binding_policy=BindingPolicy.LEAST_LOADED)
    batch = sweep.stack_scenarios([sc])
    out = epoch_schedule(batch, tile=1, interpret=True)
    ref = refsim.simulate(sc).job()
    valid = np.asarray(batch.task_valid)[0]
    fin = np.asarray(out.finish)[0][valid]
    assert float(fin.max()) == pytest.approx(
        ref.makespan + sc.jobs[0].submit_time, rel=2e-4)


# ---------------------------------------------------------------------------
# Bucketed SweepPlan.run(): bit-identity, order, realized_epochs
# ---------------------------------------------------------------------------

def _mixed_plan(n=96, seed=5):
    params = _random_params(n, seed)
    plan = product(zip_(*(axis(k, v) for k, v in params.items())))
    return plan.replace(pad_tasks=23, pad_vms=9)


def test_bucketed_run_bit_identical_all_modes():
    plan = _mixed_plan()
    base = plan.run(bucket=False)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("pod",))
    variants = {
        "bucketed": plan.run(),
        "chunked": plan.run(chunk=17),
        "bucketed+chunk": plan.run(chunk=17, bucket="auto"),
        "mesh": plan.run(mesh=mesh),
        "pallas": plan.run(bucket=False, backend="pallas"),
        "pallas+bucket": plan.run(backend="pallas"),
    }
    for tag, res in variants.items():
        for name in base.metric_names:
            if name == "realized_epochs":   # schedule-dependent by design
                continue
            np.testing.assert_array_equal(base[name], res[name],
                                          err_msg=f"{name} ({tag})")


def test_bucketing_preserves_coordinate_order():
    """A product plan whose axes force heterogeneous shapes keeps its
    row-major coordinate order under bucketing (scatter-back identity)."""
    plan = product(axis("n_maps", (1, 19, 3, 12)),
                   axis("n_vms", (1, 6)),
                   axis("binding_policy", list(BindingPolicy)))
    res_b, res_u = plan.run(), plan.run(bucket=False)
    assert res_b.shape == (4, 2, len(BindingPolicy))
    np.testing.assert_array_equal(res_b["makespan"], res_u["makespan"])
    # coordinate lookup agrees with a direct single-cell run
    one = res_b.select(n_maps=19, n_vms=6,
                       binding_policy=BindingPolicy.PACKED)
    solo = product(axis("n_maps", (19,)), n_vms=6,
                   binding_policy=BindingPolicy.PACKED).run()
    assert one["makespan"].item() == solo["makespan"].item()
    assert res_b.coord((1, 1, 2)) == {
        "n_maps": 19, "n_vms": 6,
        "binding_policy": BindingPolicy.PACKED}


def test_bucket_groups_partition_and_order():
    from repro.core.sweep import _bucket_groups
    params = _random_params(300, seed=11)
    groups = _bucket_groups(params, 23, 9, "auto")
    seen = np.concatenate([g[0] for g in groups])
    assert len(seen) == 300 and len(np.unique(seen)) == 300
    for idx, gcols, statics, tb, vb in groups:
        assert (np.diff(idx) > 0).all(), "bucket indices must ascend"
        need_t = gcols["n_maps"] + gcols["n_reduces"]
        assert int(need_t.max()) <= tb <= 23
        assert int(gcols["n_vms"].max()) <= vb <= 9
        if statics:
            for p in statics:
                assert p not in gcols


def test_realized_epochs_metric_exposed():
    plan = _mixed_plan(n=64, seed=3)
    res = plan.run()
    bound = 2 * 21 + 2
    realized = res["realized_epochs"]
    assert realized.shape == res["n_epochs"].shape
    assert (realized >= res["n_epochs"]).all()
    assert (realized < bound).all(), "early exit should beat the bound"
    # unbucketed: one batch -> one realized count == global max n_epochs
    res_u = plan.run(bucket=False)
    assert len(np.unique(res_u["realized_epochs"])) == 1
    assert int(res_u["realized_epochs"].max()) == int(res_u["n_epochs"].max())


def test_run_rejects_bad_backend_and_bucket():
    plan = product(axis("n_maps", (1, 2)))
    with pytest.raises(ValueError, match="backend"):
        plan.run(backend="cuda")
    with pytest.raises(ValueError, match="bucket"):
        plan.run(bucket=3)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("pod",))
    with pytest.raises(ValueError, match="single-device"):
        plan.run(mesh=mesh, backend="pallas")


def test_static_policy_specialization_bit_identical():
    """grid_arrays with static policies == the same policies as columns."""
    params = _random_params(40, seed=21, mixed_policies=False)
    n = 40
    for sp, bp in ALL_POLICIES:
        as_cols = dict(params,
                       sched_policy=np.full(n, int(sp), np.int32),
                       binding_policy=np.full(n, int(bp), np.int32))
        a = sweep.grid_arrays(as_cols, pad_tasks=23, pad_vms=9)
        b = sweep.grid_arrays(params, pad_tasks=23, pad_vms=9,
                              static_params={"sched_policy": int(sp),
                                             "binding_policy": int(bp)})
        for f in engine.ScenarioArrays._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(a, f)), np.asarray(getattr(b, f)),
                err_msg=f"{f} ({sp.name}/{bp.name})")
