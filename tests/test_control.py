"""Closed-loop control subsystem (DESIGN.md §10): seeded fault injection,
reactive autoscaling, re-replication — cross-layer parity in the repo's
usual pattern:

* **failure-stream determinism** — the counter-hash exponential stream is
  seeded pure arithmetic: reproducible, seed-sensitive, and *exactly*
  rate-scaled (doubling the rate halves every instant bit for bit, the
  division happening in f64 before the single f32 cast);
* **degenerate bitwise parity** — a scenario that never mentions control
  must come out bit-identical whether the static ``control`` flag is off
  or on (every control op is a ``where`` over an all-false mask), across
  engine ↔ batched ↔ batched-compact (K ∈ {1, 4, "auto"}) ↔ pallas
  ``mr_epoch`` dense + compact — including lanes with stranded tasks,
  whose realized ``n_epochs`` must keep the exact open-loop ``2T + 2``
  count under the widened control epoch bound;
* **seeded failure grids** — injected VM failures with re-dispatch and
  re-replication: oracle event-wise model to the f32-engine tolerance
  (rtol 2e-4) with *exactly* equal event counts, and engine ↔ batched ↔
  pallas **bitwise** (the acceptance grid: ``failures_injected > 0``,
  ``recovered_fraction >= 0.9``);
* **autoscale acceptance** — reactive reserve VMs under the AUTOSCALE
  policy: scale events match the oracle exactly, and shrinking the queue
  threshold strictly reduces ``queue_wait`` on an overloaded
  space-shared grid;
* export: the four control metrics ride ``to_table()`` and the streaming
  parquet writer through the shared ``_long_form_columns`` encoding.
"""
import dataclasses
import math

import jax
import numpy as np
import pytest

from repro.core import (ControlPolicy, ControlSpec, Scenario, SchedPolicy,
                        VMSpec, control, engine, refsim, sweep)
from repro.core.config import JobSpec, paper_scenario
from repro.core.sweep import axis, failures, product
from repro.kernels.mr_sched import epoch_schedule, epoch_schedule_compact

_BIG = engine._BIG
REF_FIELDS = ("avg_exec", "max_exec", "min_exec", "makespan", "delay_time",
              "vm_cost", "network_cost")
CONTROL_METRICS = ("failures_injected", "tasks_redispatched",
                   "scale_events", "recovered_fraction")


# ---------------------------------------------------------------------------
# Failure streams: seeded counter-hash exponentials
# ---------------------------------------------------------------------------

def test_failure_times_deterministic_and_seeded():
    f1, r1 = control.failure_times(32, rate=0.001, seed=5, repair_delay=60.0)
    f2, r2 = control.failure_times(32, rate=0.001, seed=5, repair_delay=60.0)
    np.testing.assert_array_equal(f1, f2)
    np.testing.assert_array_equal(r1, r2)
    f3, _ = control.failure_times(32, rate=0.001, seed=6)
    assert (f1 != f3).any(), "seed must matter"
    # counter-based: a wider fleet extends the same per-VM draws
    np.testing.assert_array_equal(
        f1, control.failure_times(48, rate=0.001, seed=5)[0][:32])
    assert (f1 > 0).all() and (r1 > f1).all()
    np.testing.assert_allclose(r1, np.minimum(f1 + np.float32(60.0), _BIG))


def test_failure_rate_scales_exactly():
    slow, _ = control.failure_times(64, rate=0.0005, seed=3)
    fast, _ = control.failure_times(64, rate=0.001, seed=3)
    # the exponential inversion divides by the rate in f64 before the one
    # f32 cast, and halving is exact in binary floating point
    np.testing.assert_array_equal(fast, slow / 2.0)


def test_failure_times_disabled_and_unrepaired():
    f, r = control.failure_times(8, rate=0.0)
    assert (f == _BIG).all() and (r == _BIG).all()
    f, r = control.failure_times(8, rate=0.01)        # repair defaults inf
    assert (f < _BIG / 2).all() and (r == _BIG).all()
    with pytest.raises(ValueError, match="n_vms"):
        control.failure_times(0, rate=0.01)


def test_failover_targets_preference_order():
    vm_valid = np.array([True, True, True, True])
    no_blocks = np.full((3, 2), -1, np.int32)
    # cyclic from bound+1, skipping nothing: 0->1, 1->2, 3->0
    out = control.failover_targets(np.array([0, 1, 3]), vm_valid,
                                   np.zeros(4, bool), no_blocks)
    np.testing.assert_array_equal(out, [1, 2, 0])
    # replica holders win over closer non-holders
    blocks = np.array([[2, 3], [2, 3], [2, 3]], np.int32)
    out = control.failover_targets(np.array([0, 1, 3]), vm_valid,
                                   np.zeros(4, bool), blocks)
    np.testing.assert_array_equal(out, [2, 2, 2])
    # reserves are skipped unless nothing else exists; lone VM falls back
    # to itself
    out = control.failover_targets(np.array([0]), np.array([True, True]),
                                   np.array([False, True]), no_blocks[:1])
    np.testing.assert_array_equal(out, [0])
    out = control.failover_targets(np.array([0]), np.array([True, False]),
                                   np.zeros(2, bool), no_blocks[:1])
    np.testing.assert_array_equal(out, [0])


# ---------------------------------------------------------------------------
# Degenerate parity: the control lowering is a bitwise identity
# ---------------------------------------------------------------------------

def _stranding_batch():
    """Open-loop scenarios incl. a lane whose lease closes before some
    tasks can start (stranded: finish stays _BIG, n_epochs hits 2T+2)."""
    scs = [paper_scenario(n_maps=6, n_reduces=2, n_vms=3),
           paper_scenario(n_maps=8, n_reduces=2, n_vms=4,
                          sched_policy=SchedPolicy.SPACE_SHARED)]
    from repro.core.elasticity import ElasticitySpec
    strand = scs[1].replace(
        vms=tuple(dataclasses.replace(v, lease_stop=500.0)
                  for v in scs[1].vms),
        elasticity=ElasticitySpec())
    return sweep.stack_scenarios(scs + [strand])


# every SimOutput field is bitwise-comparable across lowerings: both the
# open-loop and control paths report the failover binding control *would*
# use in ``task_vm2``, so the flag only changes the dynamics, never the
# reported metadata
SCHED_FIELDS = engine.SimOutput._fields


def _assert_same(a, b, fields, msg):
    for f in fields:
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)),
                                      err_msg=f"{msg}: {f}")


def test_degenerate_control_bitwise_every_mode():
    batch = _stranding_batch()
    assert not engine._control_active(batch)
    ref, _ = engine.simulate_batch_arrays(batch, control=False)
    assert (np.asarray(ref.finish[2]) >= _BIG / 2).any(), "no stranded lane"
    on, _ = engine.simulate_batch_arrays(batch, control=True)
    _assert_same(ref, on, SCHED_FIELDS, "engine control=True")
    lane = jax.vmap(lambda sc: engine.simulate_arrays(sc, control=True)
                    )(batch)
    _assert_same(ref, lane, SCHED_FIELDS, "vmapped simulate_arrays")
    for K in (1, 4, "auto"):
        comp, _ = engine.simulate_batch_arrays_compact(batch, k=K,
                                                       control=True)
        _assert_same(ref, comp, SCHED_FIELDS, f"engine compact k={K}")
        pal, _ = epoch_schedule_compact(batch, k=K, control=True)
        _assert_same(ref, pal, SCHED_FIELDS, f"pallas compact k={K}")
    dense = epoch_schedule(batch, control=True)
    _assert_same(ref, dense, SCHED_FIELDS, "pallas dense")
    # and the control-off pallas path matches the control-on one fully
    _assert_same(epoch_schedule(batch), dense, SCHED_FIELDS, "pallas off/on")


def test_degenerate_control_columns_bitwise_noop_in_sweep():
    """Explicit zeroed/disabled control columns == a plan that never
    mentions control, through the sweep (which routes the first through
    the control lowering and the second through the open-loop one)."""
    plain = product(axis("n_maps", range(2, 8)), n_reduces=2, n_vms=4)
    ctl = product(axis("n_maps", range(2, 8)), n_reduces=2, n_vms=4,
                  control_policy="none", ctl_queue=0.0, ctl_busy=0.0,
                  redispatch_delay=0.0)
    a, b = plain.run(), ctl.run()
    for f in a.metric_names:
        np.testing.assert_array_equal(a[f], b[f], err_msg=f)
    c = ctl.run(backend="pallas")
    for f in a.metric_names:
        np.testing.assert_array_equal(a[f], c[f], err_msg=f"pallas {f}")
    assert (a["failures_injected"] == 0).all()
    assert (a["scale_events"] == 0).all()


# ---------------------------------------------------------------------------
# Seeded failure grids: oracle event parity + three-way bitwise
# ---------------------------------------------------------------------------

def _failure_scenario(seed, sp=SchedPolicy.TIME_SHARED):
    sc = paper_scenario(n_maps=6, n_reduces=2, n_vms=4, sched_policy=sp)
    return sc.replace(control=ControlSpec(
        failure_rate=0.002, failure_seed=seed, repair_delay=300.0,
        redispatch_delay=5.0))


@pytest.mark.parametrize("sp", list(SchedPolicy))
@pytest.mark.parametrize("seed", [7, 11, 23])
def test_failure_refsim_matches_engine(seed, sp):
    sc = _failure_scenario(seed, sp)
    ref = refsim.simulate(sc)
    arrs = engine.from_scenario(sc)
    out = engine.simulate_arrays(arrs, control=True)
    sm = engine.scenario_metrics(arrs, out)
    # event counts are integers: exactly equal, and failures really fired
    assert int(sm.failures_injected) == ref.failures_injected > 0
    assert int(sm.tasks_redispatched) == ref.tasks_redispatched
    assert int(sm.scale_events) == ref.scale_events == 0
    np.testing.assert_allclose(float(sm.recovered_fraction),
                               ref.recovered_fraction, rtol=1e-6)
    assert ref.recovered_fraction >= 0.9
    # per-task schedule: oracle f64 vs engine f32
    n = sc.total_tasks()
    np.testing.assert_allclose(
        np.asarray(out.finish[:n]), [t.finish for t in ref.tasks],
        rtol=2e-4, atol=1e-2, err_msg=f"finish (seed {seed})")
    np.testing.assert_allclose(
        np.asarray(out.start[:n]), [t.start for t in ref.tasks],
        rtol=2e-4, atol=1e-2, err_msg=f"start (seed {seed})")
    for f in REF_FIELDS:
        got = engine._simulate_jit(engine.from_scenario(sc), control=True)
        np.testing.assert_allclose(
            float(getattr(got, f)[0]), getattr(ref.jobs[0], f),
            rtol=2e-4, atol=1e-2, err_msg=f"{f} (seed {seed})")


def test_failure_grid_three_way_bitwise():
    plan = (product(axis("vm_mips", [250.0, 500.0]),
                    axis("sched_policy", list(SchedPolicy)),
                    n_maps=6, n_reduces=2, n_vms=4, redispatch_delay=5.0)
            .failures(4, rate=0.002, n_vms=4, seed=7, repair_delay=300.0))
    te = plan.run()
    tp = plan.run(backend="pallas")
    tc = plan.run(compact=4)
    tpc = plan.run(backend="pallas", compact=4)
    for f in te.metric_names:
        for name, other in (("pallas", tp), ("compact", tc),
                            ("pallas-compact", tpc)):
            np.testing.assert_array_equal(te[f], other[f],
                                          err_msg=f"{name}: {f}")
    # the acceptance grid really exercises the machinery
    assert (np.asarray(te["failures_injected"]) > 0).all()
    assert (np.asarray(te["recovered_fraction"]) >= 0.9).all()
    assert (np.asarray(te["tasks_redispatched"]) > 0).any()


def test_failures_axis_shapes_and_rate_labels():
    dim = failures(6, rate=[0.001, 0.002], n_vms=3, seed=1,
                   repair_delay=100.0)
    assert dim.names == ("failure_rate", "failure")
    assert len(dim) == 12
    assert dim.columns["vm_fail"].shape == (12, 3)
    assert dim.columns["vm_restore"].shape == (12, 3)
    single = failures(4, rate=0.001, n_vms=3)
    assert single.names == ("failure",)
    assert (failures(2, rate=0.0, n_vms=3).columns["vm_fail"] == _BIG).all()
    with pytest.raises(ValueError, match="rate"):
        failures(4, rate=[], n_vms=3)


def test_failure_masks_compose_with_compaction_stranded_mix():
    """A grid mixing failing lanes with a stranded open-loop lane: the
    compacted drivers must re-activate killed lanes correctly AND keep
    the stranded lane's open-loop 2T+2 realized count."""
    from repro.core.elasticity import ElasticitySpec
    scs = [_failure_scenario(seed, sp)
           for seed, sp in zip([7, 11, 23, 5], list(SchedPolicy) * 2)]
    plain = paper_scenario(n_maps=8, n_reduces=2, n_vms=4,
                           sched_policy=SchedPolicy.SPACE_SHARED)
    strand = plain.replace(
        vms=tuple(dataclasses.replace(v, lease_stop=500.0)
                  for v in plain.vms),
        elasticity=ElasticitySpec())
    batch = sweep.stack_scenarios(scs + [plain, strand])
    assert engine._control_active(batch)
    T = batch.task_job.shape[1]
    ref, re = engine.simulate_batch_arrays(batch, control=True)
    assert (np.asarray(ref.finish[5]) >= _BIG / 2).any(), "lane 5 not "\
        "stranded"
    assert int(ref.n_epochs[5]) == 2 * T + 2    # open-loop bound exactly
    for K in (1, 4, "auto"):
        ce, ree = engine.simulate_batch_arrays_compact(batch, k=K,
                                                       control=True)
        cp, rep = epoch_schedule_compact(batch, k=K, control=True)
        _assert_same(ref, ce, engine.SimOutput._fields, f"engine k={K}")
        _assert_same(ref, cp, engine.SimOutput._fields, f"pallas k={K}")
        assert int(re) == int(ree) == int(rep)
    dense = epoch_schedule(batch, control=True)
    _assert_same(ref, dense, engine.SimOutput._fields, "pallas dense")


# ---------------------------------------------------------------------------
# Autoscaling: oracle parity + acceptance
# ---------------------------------------------------------------------------

def _autoscale_scenario(sp=SchedPolicy.SPACE_SHARED, queue=2.0, busy=0.5):
    vms = (VMSpec("base", mips=250.0), VMSpec("base", mips=250.0),
           VMSpec("res", mips=250.0, autoscale=True),
           VMSpec("res", mips=250.0, autoscale=True))
    job = JobSpec("j", length_mi=362_880.0, data_mb=200_000.0,
                  n_maps=12, n_reduces=2)
    return Scenario(vms=vms, jobs=(job,), sched_policy=sp,
                    control=ControlSpec(policy=ControlPolicy.AUTOSCALE,
                                        queue_threshold=queue,
                                        busy_threshold=busy))


@pytest.mark.parametrize("sp", list(SchedPolicy))
def test_autoscale_refsim_matches_engine(sp):
    sc = _autoscale_scenario(sp)
    ref = refsim.simulate(sc)
    arrs = engine.from_scenario(sc)
    out = engine.simulate_arrays(arrs, control=True)
    sm = engine.scenario_metrics(arrs, out)
    assert int(sm.scale_events) == ref.scale_events > 0
    assert int(sm.failures_injected) == ref.failures_injected == 0
    n = sc.total_tasks()
    np.testing.assert_allclose(
        np.asarray(out.finish[:n]), [t.finish for t in ref.tasks],
        rtol=2e-4, atol=1e-2)


def test_autoscale_engine_batched_pallas_bitwise():
    scs = [_autoscale_scenario(sp) for sp in SchedPolicy]
    batch = sweep.stack_scenarios(scs)
    lane = jax.vmap(lambda sc: engine.simulate_arrays(sc, control=True)
                    )(batch)
    both, _ = engine.simulate_batch_arrays(batch, control=True)
    kern = epoch_schedule(batch, tile=2, control=True)
    _assert_same(lane, both, engine.SimOutput._fields, "batched")
    _assert_same(lane, kern, engine.SimOutput._fields, "pallas")
    comp, _ = epoch_schedule_compact(batch, k=1, control=True)
    _assert_same(lane, comp, engine.SimOutput._fields, "pallas compact")
    # reserves really open and close again once drained
    assert (np.asarray(lane.n_scale) >= 2).all()
    vm_open = np.asarray(lane.vm_open)
    assert (vm_open[:, 2:4] < _BIG / 2).any(), "no reserve ever opened"


def _staggered_autoscale_scenario(queue):
    """Overloaded fleet whose queue depth *ramps* (three jobs whose input
    fetch delays stagger their ready times) — so the reactive threshold
    controls *when* the reserves open, not just whether."""
    vms = (VMSpec("base", mips=250.0), VMSpec("base", mips=250.0),
           VMSpec("res", mips=250.0, autoscale=True),
           VMSpec("res", mips=250.0, autoscale=True))
    jobs = tuple(JobSpec(f"j{i}", length_mi=362_880.0, data_mb=d,
                         n_maps=4, n_reduces=1)
                 for i, d in enumerate([50_000.0, 200_000.0, 400_000.0]))
    return Scenario(vms=vms, jobs=jobs,
                    sched_policy=SchedPolicy.SPACE_SHARED,
                    control=ControlSpec(policy=ControlPolicy.AUTOSCALE,
                                        queue_threshold=queue,
                                        busy_threshold=0.5))


def test_shrinking_queue_threshold_strictly_reduces_queue_wait():
    thresholds = [0.0, 1.0, 2.0, 3.0, 4.0]
    batch = sweep.stack_scenarios(
        [_staggered_autoscale_scenario(q) for q in thresholds])
    out, _ = engine.simulate_batch_arrays(batch, control=True)
    sm = jax.vmap(engine.scenario_metrics)(batch, out)
    qw = np.asarray(sm.queue_wait)
    assert (np.diff(qw) > 0).all(), qw          # lower threshold -> less wait
    assert (np.asarray(sm.scale_events) > 0).all()
    assert (np.asarray(out.finish) < _BIG / 2).all()  # nobody stranded


def test_autoscale_sweep_columns_engine_pallas_bitwise():
    """The sweep-encoded autoscale columns (``vm_auto`` base arg +
    ``control_policy``/threshold columns) drive the same lowering on every
    backend."""
    plan = product(axis("ctl_queue", [0.0, 4.0, 10.0]),
                   n_maps=16, n_reduces=2, n_vms=4,
                   vm_auto=np.array([0.0, 0.0, 1.0, 1.0], np.float32),
                   control_policy="autoscale", ctl_busy=0.5,
                   sched_policy=SchedPolicy.SPACE_SHARED)
    res = plan.run()
    pal = plan.run(backend="pallas")
    for f in res.metric_names:
        np.testing.assert_array_equal(res[f], pal[f], err_msg=f)
    assert (np.asarray(res["scale_events"]) > 0).all()
    assert (np.asarray(res["queue_wait"]) > 0).all()


# ---------------------------------------------------------------------------
# Export path: the four metrics ride every export encoding
# ---------------------------------------------------------------------------

def test_control_metrics_in_table_and_stream(tmp_path):
    plan = (product(axis("vm_mips", [250.0, 500.0]), n_maps=5, n_reduces=2,
                    n_vms=4, redispatch_delay=5.0)
            .failures(2, rate=0.002, n_vms=4, seed=7, repair_delay=300.0))
    res = plan.run()
    tab = res.to_table()
    for m in CONTROL_METRICS:
        assert m in tab, sorted(tab)
    assert (np.asarray(tab["failures_injected"]) > 0).all()
    pa = pytest.importorskip("pyarrow")
    import pyarrow.parquet as pq
    path = tmp_path / "ctl.parquet"
    plan.run(chunk=2, stream_to=path)
    disk = pq.read_table(path)
    for m in CONTROL_METRICS:
        np.testing.assert_array_equal(np.asarray(disk[m]),
                                      np.asarray(tab[m]), err_msg=m)
    del pa
