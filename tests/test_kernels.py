"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                     # seeded fallback, same test surface
    from _hypothesis_fallback import given, settings
    from _hypothesis_fallback import strategies as st

from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rwkv6 import wkv6
from repro.kernels.rwkv6.ref import wkv6_ref


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

FA_SHAPES = [
    # (B, S, T, Hq, Hkv, Dh, causal, window)
    (2, 128, 128, 4, 2, 32, True, None),       # GQA causal
    (1, 256, 256, 8, 8, 16, True, 64),         # MHA sliding window
    (2, 64, 64, 4, 1, 32, False, None),        # encoder (MQA)
    (1, 128, 128, 2, 2, 64, True, None),       # head_dim 64
    (1, 96, 96, 2, 1, 8, True, 32),            # non-pow2 seq
]


@pytest.mark.parametrize("shape", FA_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(shape, dtype):
    B, S, T, Hq, Hkv, Dh, causal, window = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, Dh), dtype)
    k = jax.random.normal(ks[1], (B, T, Hkv, Dh), dtype)
    v = jax.random.normal(ks[2], (B, T, Hkv, Dh), dtype)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=32)
    tr = lambda x: x.transpose(0, 2, 1, 3)
    ref = attention_ref(tr(q), tr(k), tr(v), causal=causal,
                        window=window).transpose(0, 2, 1, 3)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), atol=tol,
                               rtol=tol)


@settings(max_examples=10, deadline=None)
@given(st.sampled_from([16, 32, 64]), st.sampled_from([1, 2, 4]),
       st.sampled_from([8, 16, 32]), st.booleans())
def test_flash_attention_property(S, G, Dh, causal):
    """Random block sizes & GQA groups against the oracle."""
    Hkv = 2
    q = jax.random.normal(jax.random.PRNGKey(S), (1, S, Hkv * G, Dh))
    k = jax.random.normal(jax.random.PRNGKey(S + 1), (1, S, Hkv, Dh))
    v = jax.random.normal(jax.random.PRNGKey(S + 2), (1, S, Hkv, Dh))
    got = flash_attention(q, k, v, causal=causal, block_q=16, block_k=16)
    tr = lambda x: x.transpose(0, 2, 1, 3)
    ref = attention_ref(tr(q), tr(k), tr(v), causal=causal) \
        .transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)


def test_flash_vs_chunked_vs_dense_model_paths():
    """The three attention impls inside the model agree."""
    from repro.models import ArchConfig
    from repro.models.attention import (_gqa_scores_mask, chunked_sdpa,
                                        sdpa)
    cfg = ArchConfig(name="t", family="dense", n_layers=1, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=64, vocab=16, window=48)
    B, S, Dh = 2, 128, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, S, 4, Dh))
    k = jax.random.normal(ks[1], (B, S, 2, Dh))
    v = jax.random.normal(ks[2], (B, S, 2, Dh))
    pos = jnp.arange(S)
    dense = sdpa(cfg, q, k, v, _gqa_scores_mask(cfg, pos, pos))
    chunked = chunked_sdpa(cfg, q, k, v, block_q=32, block_k=32)
    flash = flash_attention(q, k, v, causal=True, window=48,
                            block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(chunked),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(flash),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# rwkv6
# ---------------------------------------------------------------------------

WKV_SHAPES = [
    (2, 3, 96, 16, 32),
    (1, 2, 64, 8, 64),
    (2, 1, 40, 4, 16),
    (1, 4, 128, 32, 32),
]


@pytest.mark.parametrize("shape", WKV_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv6_matches_ref(shape, dtype):
    B, H, T, hs, bt = shape
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    r, k, v = (0.5 * jax.random.normal(ks[i], (B, T, H, hs), dtype)
               for i in range(3))
    w = (jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, hs))) * 0.5
         + 0.45).astype(dtype)
    u = 0.3 * jax.random.normal(jax.random.PRNGKey(9), (H, hs), dtype)
    got = wkv6(r, k, v, w, u, block_t=bt)
    tr = lambda x: x.transpose(0, 2, 1, 3)
    ref = wkv6_ref(tr(r), tr(k), tr(v), tr(w), u).transpose(0, 2, 1, 3)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), atol=tol,
                               rtol=tol)


def test_wkv6_block_size_invariance():
    B, H, T, hs = 1, 2, 64, 8
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    r, k, v = (jax.random.normal(ks[i], (B, T, H, hs)) for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, hs))) * 0.4 + 0.5
    u = jax.random.normal(jax.random.PRNGKey(5), (H, hs)) * 0.1
    outs = [np.asarray(wkv6(r, k, v, w, u, block_t=bt))
            for bt in (8, 16, 64)]
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-5)
    np.testing.assert_allclose(outs[0], outs[2], atol=1e-5)


# ---------------------------------------------------------------------------
# mr_sched
# ---------------------------------------------------------------------------

def _random_batch(n, seed=0, mixed_policies=False):
    from repro.core import sweep
    rng = np.random.default_rng(seed)
    params = dict(
        n_maps=rng.integers(1, 21, n).astype(np.int32),
        n_reduces=rng.integers(1, 3, n).astype(np.int32),
        n_vms=rng.integers(1, 10, n).astype(np.int32),
        vm_mips=rng.choice([250.0, 500.0, 1000.0], n).astype(np.float32),
        vm_pes=rng.choice([1.0, 2.0, 4.0], n).astype(np.float32),
        vm_cost=np.ones(n, np.float32),
        job_length=rng.choice([362880.0, 725760.0], n).astype(np.float32),
        job_data=rng.choice([2e5, 4e5], n).astype(np.float32),
    )
    if mixed_policies:
        params["sched_policy"] = rng.integers(0, 2, n).astype(np.int32)
        params["binding_policy"] = rng.integers(0, 3, n).astype(np.int32)
    return sweep.grid_arrays(params, pad_tasks=23, pad_vms=9)


def _assert_schedule_matches(batch, tile):
    from repro.kernels.mr_sched import schedule
    from repro.kernels.mr_sched.ref import schedule_ref
    s_ref, f_ref = schedule_ref(batch)
    s_got, f_got = schedule(batch, tile=tile)
    valid = np.asarray(batch.task_valid)
    np.testing.assert_allclose(np.where(valid, s_got, 0),
                               np.where(valid, np.asarray(s_ref), 0),
                               rtol=1e-4, atol=1e-2)
    np.testing.assert_allclose(np.where(valid, f_got, 0),
                               np.where(valid, np.asarray(f_ref), 0),
                               rtol=1e-4, atol=1e-2)


@pytest.mark.parametrize("tile", [8, 32])
def test_mr_sched_matches_engine(tile):
    _assert_schedule_matches(_random_batch(32, seed=tile), tile)


@pytest.mark.parametrize("tile", [8, 32])
def test_mr_sched_matches_engine_mixed_policies(tile):
    """One tile mixing sched/binding policies matches the engine oracle."""
    _assert_schedule_matches(
        _random_batch(32, seed=100 + tile, mixed_policies=True), tile)


def test_mr_sched_reproduces_paper_metrics():
    """Kernel schedule -> paper Table IV numbers end to end."""
    from repro.core import sweep
    from repro.kernels.mr_sched import schedule
    batch = sweep.product(sweep.axis("n_maps", range(1, 11))).arrays()
    s, f = schedule(batch, tile=8)
    # delay time for M1R1: last map start + reduce start - last map finish
    valid = np.asarray(batch.task_valid)
    for i, m in enumerate(range(1, 11)):
        is_red = np.asarray(batch.task_is_reduce)[i] & valid[i]
        is_map = ~np.asarray(batch.task_is_reduce)[i] & valid[i]
        delay = (np.max(np.asarray(s)[i][is_map])
                 + np.max(np.asarray(s)[i][is_red])
                 - np.max(np.asarray(f)[i][is_map]))
        assert delay == pytest.approx(4250.0 / (m + 1), rel=1e-4)
