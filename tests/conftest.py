"""Shared pytest fixtures.

The suite compiles hundreds of distinct XLA executables (four engine
layers × policy/storage/elastic/control variants × compaction shapes).
On the CPU backend those live executables accumulate JIT code mappings
for the whole process lifetime, and past a threshold a later
``backend_compile`` dies with a hard SIGSEGV inside XLA — deterministic
at whichever test happens to push it over (observed at
``test_sweep_api`` once the control suite ran first).  Dropping the
compilation caches between modules bounds the live-executable set; each
module recompiles what it actually uses.
"""
import gc

import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_per_module():
    yield
    jax.clear_caches()
    gc.collect()
