"""Validation against the paper's own published numbers (§5.4, Figs 8–11,
Table IV).  These tests pin the *faithful reproduction*; EXPERIMENTS.md
§Paper-validation reports the same quantities.
"""
import numpy as np
import pytest

from repro.core import SchedPolicy, paper_scenario, refsim
from repro.core import engine

M_SWEEP = range(1, 21)


# ---------------------------------------------------------------------------
# Table IV — network cost, exact, identical across VM numbers
# ---------------------------------------------------------------------------

TABLE_IV = {
    1: 2125.0, 2: 1416.667, 3: 1062.5, 4: 850.0, 5: 708.333, 6: 607.143,
    7: 531.25, 8: 472.222, 9: 425.0, 10: 386.364, 11: 354.167, 12: 326.923,
    13: 303.571, 14: 283.333, 15: 265.625, 16: 250.0, 17: 236.111,
    18: 223.684, 19: 212.5, 20: 202.381,
}


@pytest.mark.parametrize("n_vms", [3, 6, 9])
def test_table_iv_exact(n_vms):
    for m, expected in TABLE_IV.items():
        got = refsim.simulate(paper_scenario(n_maps=m, n_vms=n_vms)) \
            .job().network_cost
        assert got == pytest.approx(expected, abs=5e-4), (m, n_vms)


def test_table_iv_engine_matches():
    for m in (1, 7, 20):
        got = float(engine.simulate(paper_scenario(n_maps=m)).network_cost[0])
        assert got == pytest.approx(TABLE_IV[m], rel=1e-4)


# ---------------------------------------------------------------------------
# Group 1 (Fig 8a/8b)
# ---------------------------------------------------------------------------

def _g1(m, **kw):
    return refsim.simulate(paper_scenario(n_maps=m, **kw)).job()


def test_group1_exec_identical_when_maps_le_vms():
    """avg == max == min while #maps <= #VMs (Fig 8a, left region)."""
    for m in (1, 2, 3):
        r = _g1(m, n_vms=3)
        assert r.avg_exec == pytest.approx(r.max_exec)
        assert r.avg_exec == pytest.approx(r.min_exec)


def test_group1_exec_decreases_then_flattens():
    """Execution time drops rapidly for M<=V then flattens (Fig 8a)."""
    vals = [_g1(m).avg_exec for m in M_SWEEP]
    assert vals[0] > vals[1] > vals[2]                 # rapid early drop
    flat = vals[5:]                                    # M>=6: flat region
    assert max(flat) - min(flat) < 0.10 * vals[0]


def test_group1_spread_narrows():
    """max-min spread narrows as MR combination grows (Fig 8a)."""
    spread = {m: _g1(m).max_exec - _g1(m).min_exec for m in (4, 20)}
    assert spread[20] < spread[4]


def test_group1_makespan_delay_vs_no_delay():
    """Makespan with network delay is larger; gap narrows with M (Fig 8b)."""
    gaps = []
    for m in (1, 5, 20):
        a = _g1(m, network_delay=True).makespan
        b = _g1(m, network_delay=False).makespan
        assert a > b
        gaps.append(a - b)
    assert gaps[0] > gaps[1] > gaps[2]
    # the gap IS the delay time: kappa * S / ((M+1) * BW)
    assert gaps[0] == pytest.approx(2125.0, abs=1e-3)


# ---------------------------------------------------------------------------
# Group 2 (Fig 9): more VMs -> less map-phase execution time
# ---------------------------------------------------------------------------

def _map_avg(n_vms, m):
    return refsim.simulate(paper_scenario(n_maps=m, n_vms=n_vms)) \
        .job().map_avg_exec


def test_group2_identical_when_maps_below_vm_number():
    for m in (1, 2, 3):
        a, b, c = (_map_avg(v, m) for v in (3, 6, 9))
        assert a == pytest.approx(b) == pytest.approx(c)


def test_group2_reduction_percentages():
    """Paper: ~40% average reduction 3->6 VMs, ~50% for 3->9 (Fig 9).

    (Averaged per-M reduction of the map-phase average execution time; see
    DESIGN.md §2.1 / EXPERIMENTS.md for why the reduce task is excluded.)
    """
    red6 = np.mean([1 - _map_avg(6, m) / _map_avg(3, m) for m in M_SWEEP])
    red9 = np.mean([1 - _map_avg(9, m) / _map_avg(3, m) for m in M_SWEEP])
    assert red6 == pytest.approx(0.40, abs=0.03)
    assert red9 == pytest.approx(0.50, abs=0.03)


def test_group2_network_cost_invariant_to_vm_number():
    """Table IV's headline: network cost identical across VM numbers."""
    for m in (1, 10, 20):
        costs = {v: refsim.simulate(paper_scenario(n_maps=m, n_vms=v))
                 .job().network_cost for v in (3, 6, 9)}
        assert len({round(c, 6) for c in costs.values()}) == 1


# ---------------------------------------------------------------------------
# Group 3 (Fig 10): VM configuration
# ---------------------------------------------------------------------------

def test_group3_vm_config_reductions():
    """Paper: Medium ~60% less, Large ~80% less average execution time."""
    def sweep_avg(vm):
        return np.mean([refsim.simulate(paper_scenario(vm=vm, n_maps=m))
                        .job().avg_exec for m in M_SWEEP])
    small, med, large = (sweep_avg(v) for v in ("small", "medium", "large"))
    assert 1 - med / small == pytest.approx(0.60, abs=0.05)   # ours: 0.58
    assert 1 - large / small == pytest.approx(0.80, abs=0.05)  # ours: 0.805


# ---------------------------------------------------------------------------
# Space-shared analytic sanity: n tasks, 1 VM, 1 PE => serial execution
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m", [2, 5, 8])
def test_space_shared_serial_on_single_pe(m):
    """With one 1-PE VM, SPACE_SHARED runs the M maps + 1 reduce strictly
    back to back: each task at full mips, the next starting the instant the
    previous finishes.  Closed form (network delay off):

        map_i  exec = L / (M * mips)         finish_i = i * L / (M * mips)
        reduce exec = 0.5 * L / mips         makespan = 1.5 * L / mips
    """
    sc = paper_scenario(n_maps=m, n_reduces=1, n_vms=1,
                        network_delay=False,
                        sched_policy=SchedPolicy.SPACE_SHARED)
    L, mips = sc.jobs[0].length_mi, sc.vms[0].mips
    res = refsim.simulate(sc)
    tasks = sorted(res.tasks, key=lambda t: t.start)
    for prev, nxt in zip(tasks, tasks[1:]):
        assert nxt.start == pytest.approx(prev.finish, abs=1e-6)
    for t in tasks[:-1]:                              # maps: full-rate slices
        assert t.exec_time == pytest.approx(L / (m * mips), rel=1e-9)
    assert tasks[-1].exec_time == pytest.approx(0.5 * L / mips, rel=1e-9)
    assert res.finish_time == pytest.approx(1.5 * L / mips, rel=1e-9)
    # the vectorized engine agrees
    got = engine.simulate(sc)
    assert float(got.makespan[0]) == pytest.approx(1.5 * L / mips, rel=1e-4)
    # time-shared on the same cell finishes the maps together, later
    ts = refsim.simulate(paper_scenario(n_maps=m, n_reduces=1, n_vms=1,
                                        network_delay=False))
    ts_maps = [t for t in ts.tasks if not t.is_reduce]
    assert min(t.finish for t in ts_maps) == \
        pytest.approx(max(t.finish for t in ts_maps), rel=1e-9)
    assert min(t.finish for t in ts_maps) >= tasks[0].finish - 1e-6


# ---------------------------------------------------------------------------
# Group 4 (Fig 11): VM computation cost linear in job length
# ---------------------------------------------------------------------------

def test_group4_cost_linear_in_job_length():
    costs = {j: refsim.simulate(paper_scenario(job=j, n_maps=10))
             .job().vm_cost for j in ("small", "medium", "big")}
    assert costs["medium"] == pytest.approx(2 * costs["small"], rel=1e-6)
    assert costs["big"] == pytest.approx(4 * costs["small"], rel=1e-6)
