"""Storage subsystem (DESIGN.md §7): block placement, LOCALITY binding,
transfer-aware metrics — cross-layer parity in the repo's usual pattern:

* placement itself is **bit-identical** between the host (numpy) and
  device (traced jnp) encoders — one xp-generic uint32/f32 op sequence;
* LOCALITY *binding decisions* (``task_vm``) are bit-identical between
  the sequential oracle and the array encoders; oracle *times* agree to
  the f32-engine tolerance (rtol 2e-4), and the engine, the batched
  early-exit engine and the Pallas ``mr_epoch`` megakernel agree
  **bitwise** with each other — across >= 6 seeded scenario combos;
* the degenerate-parity property: ``replication == num_vms`` (every
  block on every VM) makes LOCALITY bit-identical to LEAST_LOADED, its
  no-transfer fallback ranking;
* skewed-placement grids: LOCALITY's ``locality_fraction`` strictly
  exceeds ROUND_ROBIN's and its ``transfer_bytes`` is exactly 0
  (the PR acceptance criterion);
* friendly plan-build validation for the new storage parameter columns.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import (JOB_MEDIUM, JOB_SMALL, VM_MEDIUM, VM_SMALL,
                        BindingPolicy, Placement, Scenario, SchedPolicy,
                        StorageSpec, engine, refsim, storage, sweep)
from repro.core.sweep import axis, product, zip_
from repro.kernels.mr_sched import epoch_schedule

REF_FIELDS = ("avg_exec", "max_exec", "min_exec", "makespan", "delay_time",
              "vm_cost", "network_cost")


# ---------------------------------------------------------------------------
# The placement function: shared-layer bit-identity and model properties
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("placement", list(Placement))
@pytest.mark.parametrize("seed", [0, 7, 12345])
def test_placement_host_equals_device(placement, seed):
    """numpy and traced-jnp placement must agree bit for bit (the uint32
    hash wraps identically, the f32 skew transform is the same IEEE ops)."""
    import jax.numpy as jnp
    kw = dict(seed=seed, placement=int(placement), replication=2,
              block_size_mb=np.float32(4096.0), job_data=np.float32(2e5),
              n_vms=7, pad_vms=9)
    m_idx = np.arange(20, dtype=np.int32)
    j_idx = np.zeros(20, np.int32)
    h_vm, h_mb = storage.map_block_placement(np, m_idx, j_idx, **kw)
    d_vm, d_mb = jax.jit(lambda m, j: storage.map_block_placement(
        jnp, m, j, **kw))(m_idx, j_idx)
    np.testing.assert_array_equal(h_vm, np.asarray(d_vm))
    np.testing.assert_array_equal(h_mb, np.asarray(d_mb))


def test_placement_replicas_distinct_and_clipped():
    for repl in (1, 3, 5, 99):
        bvm, bmb = storage.map_block_placement(
            np, np.arange(40, dtype=np.int32), np.zeros(40, np.int32),
            seed=1, placement=0, replication=repl,
            block_size_mb=np.float32(1000.0), job_data=np.float32(2e5),
            n_vms=5, pad_vms=8)
        eff = min(max(repl, 1), 5)
        for row in bvm:
            vms = row[row >= 0]
            assert len(vms) == eff
            assert len(set(vms.tolist())) == eff, "replicas must be distinct"
            assert (vms < 5).all() and (vms >= 0).all()
        assert (bmb > 0).all()
    # replication == n_vms: every block on every VM
    bvm, _ = storage.map_block_placement(
        np, np.arange(10, dtype=np.int32), np.zeros(10, np.int32),
        seed=1, placement=1, replication=5, block_size_mb=np.float32(1e3),
        job_data=np.float32(2e5), n_vms=5, pad_vms=5)
    assert (np.sort(bvm, axis=1) == np.arange(5)).all()


def test_placement_block_sizes_cover_dataset():
    """Fixed-size blocks with a remainder tail: sizes must tile data_mb."""
    bvm, bmb = storage.map_block_placement(
        np, np.arange(6, dtype=np.int32), np.zeros(6, np.int32),
        seed=0, placement=0, replication=1,
        block_size_mb=np.float32(900.0), job_data=np.float32(5000.0),
        n_vms=3, pad_vms=3)
    # ceil(5000/900) = 6 blocks: five of 900 MB + one 500 MB tail
    assert bmb.tolist() == [900.0] * 5 + [500.0]


def test_skewed_placement_concentrates_low_vms():
    """SKEWED must put decisively more replicas on the low VM indices than
    UNIFORM does (the hot-spot model the acceptance grid relies on)."""
    counts = {}
    for plc in Placement:
        bvm, _ = storage.map_block_placement(
            np, np.arange(400, dtype=np.int32), np.zeros(400, np.int32),
            seed=3, placement=int(plc), replication=1,
            block_size_mb=np.float32(500.0), job_data=np.float32(8e5),
            n_vms=8, pad_vms=8)
        counts[plc] = np.bincount(bvm[:, 0], minlength=8)
    lo_uni = counts[Placement.UNIFORM][:3].sum()
    lo_skew = counts[Placement.SKEWED][:3].sum()
    assert lo_skew > 1.5 * lo_uni, (lo_skew, lo_uni)


def test_negative_seed_host_matches_device():
    """A negative seed must not crash the host path (numpy 2 raises
    OverflowError casting out-of-range Python ints to uint32) and must
    wrap to the same placement an i32 device column produces."""
    st = StorageSpec(enabled=True, replication=2, seed=-1)
    sc = Scenario(vms=(VM_SMALL,) * 3,
                  jobs=(dataclasses.replace(JOB_SMALL, n_maps=5),),
                  storage=st, binding_policy=BindingPolicy.LOCALITY)
    host = engine.from_scenario(sc, pad_tasks=6, pad_vms=3)
    assert [t.vm for t in refsim.simulate(sc).tasks] == \
        np.asarray(host.task_vm).tolist()
    batch = product(
        axis("storage_seed", [-1]), storage=True, replication=2,
        block_size_mb=st.block_size_mb, n_maps=5,
        binding_policy=BindingPolicy.LOCALITY).arrays()
    np.testing.assert_array_equal(np.asarray(host.block_vm),
                                  np.asarray(batch.block_vm)[0])
    np.testing.assert_array_equal(np.asarray(host.task_vm),
                                  np.asarray(batch.task_vm)[0])


def test_placement_seed_and_job_sensitivity():
    def place(seed, job):
        bvm, _ = storage.map_block_placement(
            np, np.arange(30, dtype=np.int32),
            np.full(30, job, np.int32), seed=seed, placement=0,
            replication=1, block_size_mb=np.float32(500.0),
            job_data=np.float32(2e5), n_vms=9, pad_vms=9)
        return bvm[:, 0]

    assert (place(0, 0) != place(1, 0)).any(), "seed must matter"
    assert (place(0, 0) != place(0, 1)).any(), "job index must matter"
    np.testing.assert_array_equal(place(4, 2), place(4, 2))


# ---------------------------------------------------------------------------
# LOCALITY parity: refsim <-> engine <-> batched engine <-> mr_epoch kernel
# ---------------------------------------------------------------------------

def _storage_scenario(seed: int, sp: SchedPolicy, plc: Placement,
                      bp: BindingPolicy = BindingPolicy.LOCALITY) -> Scenario:
    rng = np.random.default_rng(seed)
    vms = tuple(rng.choice([VM_SMALL, VM_MEDIUM])
                for _ in range(int(rng.integers(2, 7))))
    job = dataclasses.replace(
        rng.choice([JOB_SMALL, JOB_MEDIUM]),
        n_maps=int(rng.integers(3, 15)), n_reduces=int(rng.integers(1, 3)))
    st = StorageSpec(enabled=True,
                     block_size_mb=float(rng.choice([1024.0, 4096.0])),
                     replication=int(rng.integers(1, 4)),
                     placement=plc, seed=seed)
    return Scenario(vms=vms, jobs=(job,), storage=st,
                    sched_policy=sp, binding_policy=bp)


SIX_COMBOS = [(s, sp, plc)
              for s, (sp, plc) in enumerate(
                  [(sp, plc) for sp in SchedPolicy for plc in Placement]
                  + [(SchedPolicy.TIME_SHARED, Placement.SKEWED),
                     (SchedPolicy.SPACE_SHARED, Placement.UNIFORM)])]


@pytest.mark.parametrize("seed,sp,plc", SIX_COMBOS,
                         ids=[f"s{s}-{sp.name}-{plc.name}"
                              for s, sp, plc in SIX_COMBOS])
def test_locality_parity_refsim_engine_pallas(seed, sp, plc):
    sc = _storage_scenario(100 + seed, sp, plc)
    ref = refsim.simulate(sc)
    arrs = engine.from_scenario(sc, pad_tasks=17, pad_vms=7)

    # binding decisions: oracle == encoded arrays, bitwise (ints)
    np.testing.assert_array_equal(
        [t.vm for t in ref.tasks],
        np.asarray(arrs.task_vm)[:sc.total_tasks()])

    # oracle times vs f32 engine: the repo's standard tolerance
    got = engine._simulate_jit(arrs)
    for f in REF_FIELDS:
        np.testing.assert_allclose(
            float(getattr(got, f)[0]), getattr(ref.jobs[0], f),
            rtol=2e-4, atol=1e-2, err_msg=f"{f} (seed {seed})")

    # engine <-> batched early exit <-> mr_epoch megakernel: bitwise
    batch = sweep.stack_scenarios([sc, sc.replace(
        binding_policy=BindingPolicy.ROUND_ROBIN)])
    lane = jax.jit(jax.vmap(engine.simulate_arrays))(batch)
    both, _ = jax.jit(engine.simulate_batch_arrays)(batch)
    kern = epoch_schedule(batch, tile=2, interpret=True)
    for f in lane._fields:
        np.testing.assert_array_equal(np.asarray(getattr(lane, f)),
                                      np.asarray(getattr(both, f)),
                                      err_msg=f"batched {f}")
        np.testing.assert_array_equal(np.asarray(getattr(lane, f)),
                                      np.asarray(getattr(kern, f)),
                                      err_msg=f"pallas {f}")


def test_locality_mixed_grid_engine_vs_pallas_bitwise():
    """A random mixed grid over all four binding policies x storage params
    through grid_arrays: batched engine == megakernel, bitwise."""
    n = 48
    rng = np.random.default_rng(11)
    params = dict(
        n_maps=rng.integers(1, 19, n).astype(np.int32),
        n_reduces=rng.integers(1, 3, n).astype(np.int32),
        n_vms=rng.integers(1, 9, n).astype(np.int32),
        vm_mips=rng.choice([250.0, 500.0], n).astype(np.float32),
        vm_pes=rng.choice([1.0, 2.0], n).astype(np.float32),
        vm_cost=np.ones(n, np.float32),
        job_length=rng.choice([362880.0, 725760.0], n).astype(np.float32),
        job_data=rng.choice([2e5, 4e5], n).astype(np.float32),
        sched_policy=rng.integers(0, 2, n).astype(np.int32),
        binding_policy=rng.integers(0, 4, n).astype(np.int32),
        storage_enabled=rng.integers(0, 2, n).astype(np.float32),
        replication=rng.integers(1, 4, n).astype(np.int32),
        placement=rng.integers(0, 2, n).astype(np.int32),
        block_size_mb=rng.choice([1024.0, 8192.0], n).astype(np.float32),
        storage_seed=rng.integers(0, 100, n).astype(np.int32),
    )
    batch = sweep.grid_arrays(params, pad_tasks=20, pad_vms=8)
    eng, _ = jax.jit(engine.simulate_batch_arrays)(batch)
    out = epoch_schedule(batch, tile=8, interpret=True)
    for f in eng._fields:
        np.testing.assert_array_equal(np.asarray(getattr(eng, f)),
                                      np.asarray(getattr(out, f)),
                                      err_msg=f)


def test_degenerate_parity_full_replication_equals_least_loaded():
    """replication == num_vms puts every block on every VM: LOCALITY's
    masked argmin sees LEAST_LOADED's exact load vector, so bindings and
    every metric must be bit-identical — and nobody pays a fetch."""
    plan = product(
        axis("binding_policy", [BindingPolicy.LEAST_LOADED,
                                BindingPolicy.LOCALITY]),
        axis("n_maps", (1, 5, 12)),
        axis("placement", list(Placement)),
        storage=True, replication=3, n_vms=3, block_size_mb=2048.0)
    res = plan.run()
    ll = res.select(binding_policy=BindingPolicy.LEAST_LOADED)
    loc = res.select(binding_policy=BindingPolicy.LOCALITY)
    for name in res.metric_names:
        if name == "realized_epochs":
            continue
        np.testing.assert_array_equal(ll[name], loc[name], err_msg=name)
    assert (loc["transfer_bytes"] == 0.0).all()
    assert (loc["locality_fraction"] == 1.0).all()
    # oracle agrees: same scenario object, both policies, identical binding
    st = StorageSpec(enabled=True, replication=4, block_size_mb=2048.0)
    job = dataclasses.replace(JOB_MEDIUM, n_maps=9, n_reduces=2)
    vms = (VM_SMALL, VM_MEDIUM, VM_SMALL, VM_MEDIUM)
    binds = {}
    for bp in (BindingPolicy.LEAST_LOADED, BindingPolicy.LOCALITY):
        sc = Scenario(vms=vms, jobs=(job,), storage=st, binding_policy=bp)
        binds[bp] = [t.vm for t in refsim.simulate(sc).tasks]
    assert binds[BindingPolicy.LEAST_LOADED] == binds[BindingPolicy.LOCALITY]


def test_storage_off_is_bitwise_noop():
    """A disabled store (the default) must leave every policy's encoding
    and schedule untouched — including LOCALITY, which falls back to the
    LEAST_LOADED scan."""
    for bp in BindingPolicy:
        sc = Scenario(vms=(VM_SMALL, VM_MEDIUM, VM_SMALL),
                      jobs=(dataclasses.replace(JOB_SMALL, n_maps=7),),
                      binding_policy=bp)
        off = engine.from_scenario(sc)
        assert (np.asarray(off.block_vm) == -1).all()
        assert (np.asarray(off.block_size) == 0.0).all()
        if bp == BindingPolicy.LOCALITY:
            ll = engine.from_scenario(
                sc.replace(binding_policy=BindingPolicy.LEAST_LOADED))
            np.testing.assert_array_equal(np.asarray(off.task_vm),
                                          np.asarray(ll.task_vm))


# ---------------------------------------------------------------------------
# Transfer-aware metrics (the PR acceptance grid)
# ---------------------------------------------------------------------------

def _skewed_plan(**base):
    return product(
        axis("binding_policy", [BindingPolicy.ROUND_ROBIN,
                                BindingPolicy.LEAST_LOADED,
                                BindingPolicy.LOCALITY]),
        axis("replication", (1, 2, 3)),
        storage=True, placement="skewed", n_maps=16, n_reduces=2,
        n_vms=8, block_size_mb=8192.0, **base)


def test_locality_fraction_locality_beats_round_robin_skewed():
    res = _skewed_plan().run()
    rr = res.select(binding_policy=BindingPolicy.ROUND_ROBIN)
    loc = res.select(binding_policy=BindingPolicy.LOCALITY)
    assert (loc["locality_fraction"] > rr["locality_fraction"]).all(), (
        loc["locality_fraction"], rr["locality_fraction"])
    assert (loc["transfer_bytes"] == 0.0).all()
    assert (rr["transfer_bytes"] > 0.0).all()
    # fraction of data-local maps grows with the replication factor
    rr_lf = rr["locality_fraction"]
    assert rr_lf[0] < rr_lf[-1]


def test_remote_fetch_delays_map_readiness():
    """Under a locality-blind binding, enabling storage can only delay map
    starts (fetches add to readiness) — and the oracle sees the same
    makespan shift as the engine."""
    job = dataclasses.replace(JOB_SMALL, n_maps=8, n_reduces=1)
    base = Scenario(vms=(VM_SMALL,) * 4, jobs=(job,),
                    binding_policy=BindingPolicy.ROUND_ROBIN)
    on = base.replace(storage=StorageSpec(
        enabled=True, replication=1, block_size_mb=8192.0,
        placement=Placement.SKEWED, seed=5))
    mk_off = refsim.simulate(base).job().makespan
    mk_on = refsim.simulate(on).job().makespan
    assert mk_on > mk_off
    got_on = engine.simulate(on)
    np.testing.assert_allclose(float(got_on.makespan[0]), mk_on, rtol=2e-4)


def test_locality_vs_least_loaded_crossover_exists():
    """The motivating question ("at what replication factor does LOCALITY
    stop beating LEAST_LOADED under skewed placement?") has a real answer
    on this grid: at replication 1 the hot-spot pileup costs LOCALITY more
    than LEAST_LOADED's fetches (fetches delay maps *in parallel*), from
    replication 2 the widened replica sets restore balance while
    LEAST_LOADED keeps paying fetches, and at replication == n_vms the two
    policies converge bit for bit."""
    plan = product(
        axis("binding_policy", [BindingPolicy.LEAST_LOADED,
                                BindingPolicy.LOCALITY]),
        axis("replication", (1, 2, 4, 8)),
        storage=True, placement="skewed", n_maps=24, n_reduces=2,
        n_vms=8, block_size_mb=32768.0, job_type="small")
    res = plan.run()
    ll = res.select(binding_policy=BindingPolicy.LEAST_LOADED)["makespan"]
    loc = res.select(binding_policy=BindingPolicy.LOCALITY)["makespan"]
    assert loc[0] > ll[0], "r=1: extreme skew should cost LOCALITY"
    assert (loc[1:3] < ll[1:3]).all(), f"r=2,4: LOCALITY {loc} !< LL {ll}"
    assert loc[3] == ll[3], "full replication must converge bitwise"


# ---------------------------------------------------------------------------
# Plan-build validation for the storage parameter columns
# ---------------------------------------------------------------------------

def test_storage_knobs_without_enable_rejected():
    with pytest.raises(ValueError, match="storage_enabled"):
        product(axis("replication", (1, 2, 3))).params()
    # explicit column or the 'storage' axis both satisfy it
    assert product(axis("replication", (1, 2)), storage=True).params()[
        "replication"].tolist() == [1, 2]


def test_storage_param_range_validation():
    with pytest.raises(ValueError, match="replication must be >= 1"):
        product(axis("replication", (0, 1)), storage=True).params()
    with pytest.raises(ValueError, match="block_size_mb must be > 0"):
        product(axis("block_size_mb", (0.0,)), storage=True).params()
    with pytest.raises(ValueError, match="not.*Placement"):
        sweep.grid_arrays(dict(n_maps=np.ones(2, np.int32),
                               storage_enabled=np.ones(2, np.float32),
                               placement=np.full(2, 7, np.int32)),
                          pad_tasks=4, pad_vms=3)


def test_storage_param_dtype_validation():
    with pytest.raises(ValueError, match="replication.*integer"):
        sweep.grid_arrays(dict(n_maps=np.ones(2, np.int32),
                               storage_enabled=np.ones(2, np.float32),
                               replication=np.full(2, 1.5, np.float32)),
                          pad_tasks=4, pad_vms=3)
    with pytest.raises(ValueError, match="unknown"):
        sweep.grid_arrays(dict(replications=np.ones(2, np.int32)),
                          pad_tasks=4, pad_vms=3)


def test_placement_axis_coercion_and_select():
    res = product(axis("placement", ["uniform", "skewed"]),
                  storage=True, binding_policy=BindingPolicy.LOCALITY).run()
    one = res.select(placement="SKEWED")
    assert one.shape == ()
    with pytest.raises(ValueError, match="unknown placement"):
        axis("placement", ["diagonal"])
