"""Beyond-paper extensions: speculative execution, streaming layer,
gradient compression, workload bridge."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ChipSpec, StepCost, paper_scenario, refsim,
                        speculative, streaming, workload)
from repro.models import ArchConfig
from repro.train import OptConfig, TrainConfig, compress, train


# ---------------------------------------------------------------------------
# speculative execution
# ---------------------------------------------------------------------------

def test_speculative_noop_without_stragglers():
    sc = paper_scenario(n_maps=12, n_vms=4)
    r = speculative.simulate_speculative(sc, [1.0] * sc.total_tasks())
    ref = refsim.simulate(sc).job()
    assert r["n_backups"] == 0
    assert r["makespan_plain"] == pytest.approx(r["makespan_spec"])
    assert r["makespan_plain"] == pytest.approx(ref.makespan, rel=1e-6)


def test_speculative_beats_stragglers():
    sc = paper_scenario(n_maps=12, n_vms=12)
    mult = [1.0] * sc.total_tasks()
    mult[3] = 5.0                                 # one 5x straggler
    r = speculative.simulate_speculative(sc, mult, threshold=1.5)
    assert r["n_backups"] == 1
    assert r["speedup"] > 1.15    # rescues the straggled map phase
    assert r["extra_work_frac"] < 0.2             # at bounded extra cost


def test_speculative_lognormal_study():
    sc = paper_scenario(n_maps=16, n_vms=16)
    mult = speculative.straggler_multipliers(sc, sigma=0.6, seed=1)
    r = speculative.simulate_speculative(sc, mult)
    assert r["speedup"] >= 1.0
    assert r["cost_spec"] >= r["cost_plain"]


# ---------------------------------------------------------------------------
# streaming layer
# ---------------------------------------------------------------------------

def test_streaming_stable_topology():
    topo = streaming.smart_city_topology(parallelism=(1, 2, 4, 1, 1))
    out = streaming.analyze(topo)
    assert bool(out["stable"])
    assert np.isfinite(float(out["latency_s"]))
    # detect op sees cam_rate tuples; throughput matches inflow
    np.testing.assert_allclose(float(out["throughput"][2]), 2000.0,
                               rtol=1e-5)


def test_streaming_bottleneck_detection():
    topo = streaming.smart_city_topology(parallelism=(1, 2, 1, 1, 1))
    out = streaming.analyze(topo)
    assert int(out["bottleneck"]) == 2            # detect under-provisioned
    assert not bool(out["stable"])
    # provisioning the bottleneck restores stability
    topo2 = streaming.smart_city_topology(parallelism=(1, 2, 4, 1, 1))
    assert bool(streaming.analyze(topo2)["stable"])


def test_streaming_batch_sweep():
    topos = [streaming.smart_city_topology(parallelism=(1, 2, p, 1, 1))
             for p in (1, 2, 4, 8)]
    batch = jax.tree.map(lambda *xs: jnp.stack(xs), *topos)
    out = streaming.analyze_batch(batch)
    assert out["stable"].tolist() == [False, True, True, True]


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_compression_roundtrip_small_error():
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 1e-3}
    ef = compress.init_state(g)
    deq, ef2 = compress.compress_grads(g, ef)
    err = float(jnp.abs(deq["w"] - g["w"]).max())
    assert err < 2e-5              # <= scale/2, scale ~ max/127
    # error feedback: residual carries the rounding error
    total = deq["w"] + ef2.residual["w"]
    np.testing.assert_allclose(np.asarray(total), np.asarray(g["w"]),
                               atol=1e-8)


def test_compression_wire_savings():
    g = {"w": jnp.zeros((10000,))}
    wb = compress.wire_bytes(g)
    assert wb["fp32"] / wb["int8"] > 3.5


def test_compression_convergence_parity():
    cfg = ArchConfig(name="tiny-c", family="dense", n_layers=2, d_model=32,
                     n_heads=4, n_kv_heads=2, d_ff=64, vocab=64,
                     vocab_pad_to=8, dtype="float32")
    tc = TrainConfig(steps=25, seq_len=32, global_batch=4,
                     opt=OptConfig(lr=3e-3, warmup_steps=5))
    base = train(cfg, tc)

    # rerun the loop with compression spliced into the gradient path
    from repro.models import init_model, loss_fn
    from repro.train import data, optimizer
    dcfg = data.DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4)
    params = init_model(jax.random.PRNGKey(0), cfg)
    opt_state = optimizer.init(params)
    ef = compress.init_state(params)
    ocfg = tc.opt.replace(total_steps=25)

    @jax.jit
    def step(params, opt_state, ef, batch):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch))(params)
        grads, ef = compress.compress_grads(grads, ef)
        params, opt_state, _ = optimizer.update(ocfg, grads, opt_state,
                                                params)
        return params, opt_state, ef, loss

    losses = []
    for s in range(25):
        params, opt_state, ef, loss = step(params, opt_state, ef,
                                           data.batch_at(dcfg, s))
        losses.append(float(loss))
    # compressed run converges like the uncompressed one
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1
    assert abs(np.mean(losses[-5:]) - np.mean(base["loss"][-5:])) < 0.25


# ---------------------------------------------------------------------------
# workload bridge
# ---------------------------------------------------------------------------

def test_workload_roofline_terms():
    cost = StepCost(flops=1e14, hbm_bytes=1e12, collective_bytes=1e10)
    chip = ChipSpec()
    t = cost.roofline_terms(chip)
    assert t["compute_s"] == pytest.approx(1e14 / 197e12)
    assert t["memory_s"] == pytest.approx(1e12 / 819e9)
    assert t["collective_s"] == pytest.approx(1e10 / 50e9)


def test_workload_straggler_and_failures():
    cost = StepCost(flops=1e14, hbm_bytes=1e11, collective_bytes=1e9)
    chip = ChipSpec()
    clean = workload.simulate_training(cost, chip, n_devices=64,
                                       n_steps=100, straggler_sigma=0.0)
    assert clean["straggler_slowdown"] == pytest.approx(1.0, rel=1e-3)
    slow = workload.simulate_training(cost, chip, n_devices=64,
                                      n_steps=100, straggler_sigma=0.2,
                                      seed=3)
    assert slow["step_seconds"] > clean["step_seconds"]
    failing = workload.simulate_training(cost, chip, n_devices=64,
                                         n_steps=100, mtbf_hours=1.0)
    assert failing["expected_failures"] > 0
    assert failing["goodput"] < clean["goodput"]