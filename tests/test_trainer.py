"""Training substrate tests: convergence, checkpoint atomicity + resume
bit-exactness, kill-and-restore fault tolerance, straggler watchdog,
elastic resharding, optimizer semantics.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ArchConfig, init_model
from repro.train import (NodeFailure, OptConfig, TrainConfig, checkpoint,
                         data, optimizer, train)

TINY = ArchConfig(name="tiny", family="dense", n_layers=2, d_model=32,
                  n_heads=4, n_kv_heads=2, d_ff=64, vocab=64, vocab_pad_to=8,
                  dtype="float32")


def _tc(tmp_path=None, **kw):
    base = dict(steps=30, seq_len=32, global_batch=4,
                opt=OptConfig(lr=3e-3, warmup_steps=5, clip_norm=1.0),
                ckpt_every=10, log_every=100)
    if tmp_path is not None:
        base["ckpt_dir"] = os.path.join(str(tmp_path), "ckpt")
    base.update(kw)
    return TrainConfig(**base)


def test_loss_decreases(tmp_path):
    h = train(TINY, _tc())
    first = np.mean(h["loss"][:5])
    last = np.mean(h["loss"][-5:])
    assert last < first - 0.1, (first, last)


def test_checkpoint_resume_bit_exact(tmp_path):
    """Interrupt at 30, resume to 60 == one uninterrupted 60-step run."""
    h_full = train(TINY, _tc(steps=60))            # no ckpt dir: fresh run

    class Abort(Exception):
        pass

    def hook(s):
        if s == 30:
            raise Abort                            # hard process kill

    with pytest.raises(Abort):
        train(TINY, _tc(tmp_path, steps=60), fault_hook=hook)
    h_res = train(TINY, _tc(tmp_path, steps=60))   # restart: resumes at 30
    assert h_res["resumed_at"] == 30
    np.testing.assert_allclose(h_res["loss"], h_full["loss"][30:], rtol=1e-5)


def test_kill_and_restore(tmp_path):
    """Injected node failure at step 25 -> restore from 20 and replay."""
    fails = {"armed": True}

    def hook(s):
        if s == 25 and fails["armed"]:
            fails["armed"] = False
            raise NodeFailure("injected")

    h = train(TINY, _tc(tmp_path, steps=40), fault_hook=hook)
    assert h["restarts"] == 1
    assert len(h["loss"]) >= 40 - 20               # replayed from 20
    h_clean = train(TINY, _tc(steps=40))
    np.testing.assert_allclose(h["loss"][-5:], h_clean["loss"][-5:],
                               rtol=1e-6)          # replay is bit-exact


def test_straggler_watchdog():
    import time as _t

    def hook(s):
        if s == 20:
            _t.sleep(1.0)                          # induced straggler

    h = train(TINY, _tc(steps=25), fault_hook=hook)
    assert 20 in h["straggler_steps"]


def test_checkpoint_atomic_commit(tmp_path):
    root = str(tmp_path / "c")
    tree = {"a": jnp.arange(4.0), "b": {"c": jnp.ones((2, 2))}}
    checkpoint.save(root, 7, tree)
    # a stale .tmp dir (simulated crash) must be invisible to restore
    os.makedirs(os.path.join(root, "step_00000009.tmp"))
    assert checkpoint.latest_step(root) == 7
    step, got, _ = checkpoint.restore(root, tree)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(got["a"]), np.arange(4.0))


def test_checkpoint_retention(tmp_path):
    root = str(tmp_path / "c")
    tree = {"x": jnp.zeros(3)}
    for s in (1, 2, 3, 4, 5):
        checkpoint.save(root, s, tree, keep=2)
    assert checkpoint.all_steps(root) == [4, 5]


def test_elastic_resharding(tmp_path):
    """Save unsharded, restore onto a 4-device mesh with a new sharding."""
    import subprocess
    import sys
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, numpy as np
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.train import checkpoint
root = os.environ["CKPT_ROOT"]
tree = {"w": jnp.arange(64.0).reshape(8, 8)}
checkpoint.save(root, 1, tree)
mesh = jax.make_mesh((2, 2), ("data", "model"))
sh = {"w": NamedSharding(mesh, P("data", "model"))}
step, got, _ = checkpoint.restore(root, tree, shardings=sh)
assert step == 1
assert got["w"].sharding == sh["w"], got["w"].sharding
np.testing.assert_array_equal(np.asarray(got["w"]),
                              np.arange(64.0).reshape(8, 8))
print("ELASTIC_OK")
"""
    env = dict(os.environ, CKPT_ROOT=str(tmp_path / "e"),
               PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, cwd=os.getcwd())
    assert "ELASTIC_OK" in out.stdout, out.stderr


def test_data_determinism_and_sharding():
    dcfg = data.DataConfig(vocab=97, seq_len=16, global_batch=8)
    b1 = data.batch_at(dcfg, 5)
    b2 = data.batch_at(dcfg, 5)
    np.testing.assert_array_equal(b1["inputs"], b2["inputs"])
    b3 = data.batch_at(dcfg, 6)
    assert not np.array_equal(b1["inputs"], b3["inputs"])
    # shards partition the batch deterministically and differ pairwise
    s0 = data.batch_at(dcfg, 5, shard=0, n_shards=4)
    s1 = data.batch_at(dcfg, 5, shard=1, n_shards=4)
    assert s0["inputs"].shape == (2, 16)
    assert not np.array_equal(s0["inputs"], s1["inputs"])
    assert (b1["inputs"] < 97).all() and (b1["inputs"] >= 0).all()


def test_optimizer_semantics():
    params = {"w": jnp.ones((4,)), "b": jnp.zeros((2,))}
    grads = {"w": jnp.full((4,), 2.0), "b": jnp.ones((2,))}
    cfg = OptConfig(lr=0.1, warmup_steps=0, total_steps=100_000,
                    clip_norm=1e9, weight_decay=0.0)
    st = optimizer.init(params)
    p1, st1, m = optimizer.update(cfg, grads, st, params)
    # first AdamW step moves each coord by ~lr * sign(grad)
    np.testing.assert_allclose(np.asarray(p1["w"]),
                               1.0 - 0.1 * np.ones(4), rtol=1e-3)
    assert float(m["grad_norm"]) == pytest.approx(np.sqrt(4 * 4 + 2), rel=1e-5)
    # clipping engages
    cfg2 = OptConfig(lr=0.1, warmup_steps=0, total_steps=100_000,
                     clip_norm=0.1, weight_decay=0.0)
    p2, _, m2 = optimizer.update(cfg2, grads, st, params)
    assert np.all(np.abs(np.asarray(p2["w"]) - 1.0)
                  <= np.abs(np.asarray(p1["w"]) - 1.0) + 1e-7)


def test_schedule_shape():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                    min_lr_frac=0.1)
    lrs = [float(optimizer.schedule(cfg, jnp.asarray(s)))
           for s in (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert 0.1 < lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.1, rel=1e-3)
