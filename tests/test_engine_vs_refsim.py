"""Oracle equality: the vectorized JAX engine must reproduce the sequential
paper-faithful DES exactly, plus hypothesis property tests on simulator
invariants.
"""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (JOB_MEDIUM, JOB_SMALL, VM_MEDIUM, VM_SMALL, Scenario,
                        engine, paper_scenario, refsim, sweep)

FIELDS = ("avg_exec", "max_exec", "min_exec", "makespan", "delay_time",
          "vm_cost", "network_cost", "map_avg_exec", "reduce_avg_exec")


def assert_parity(sc: Scenario, rtol=2e-4, atol=1e-2):
    ref = refsim.simulate(sc)
    got = engine.simulate(sc)
    for ji in range(len(sc.jobs)):
        for f in FIELDS:
            np.testing.assert_allclose(
                float(getattr(got, f)[ji]), getattr(ref.jobs[ji], f),
                rtol=rtol, atol=atol, err_msg=f"job {ji} field {f}")


@pytest.mark.parametrize("m", [1, 3, 4, 7, 20])
@pytest.mark.parametrize("v", [3, 9])
def test_paper_cells(m, v):
    assert_parity(paper_scenario(n_maps=m, n_vms=v))


def test_no_network_delay():
    assert_parity(paper_scenario(n_maps=7, network_delay=False))


def test_multi_reduce():
    assert_parity(paper_scenario(n_maps=8, n_reduces=3))


def test_multi_job_heterogeneous():
    jobs = (dataclasses.replace(JOB_SMALL, n_maps=5),
            dataclasses.replace(JOB_MEDIUM, n_maps=3, n_reduces=2,
                                submit_time=500.0))
    sc = Scenario(vms=(VM_SMALL, VM_SMALL, VM_MEDIUM), jobs=jobs)
    assert_parity(sc)


def test_padding_invariance():
    """Extra task/job/VM padding must not change results."""
    sc = paper_scenario(n_maps=5)
    base = engine._simulate_jit(engine.from_scenario(sc))
    padded = engine._simulate_jit(engine.from_scenario(
        sc, pad_tasks=32, pad_jobs=4, pad_vms=8))
    for f in FIELDS:
        np.testing.assert_allclose(float(getattr(base, f)[0]),
                                   float(getattr(padded, f)[0]), rtol=1e-5)


# ---------------------------------------------------------------------------
# Property tests (hypothesis): simulator invariants
# ---------------------------------------------------------------------------

scenario_params = st.tuples(
    st.integers(1, 12),                      # n_maps
    st.integers(1, 3),                       # n_reduces
    st.integers(1, 8),                       # n_vms
    st.sampled_from(["small", "medium", "large"]),
    st.sampled_from(["small", "medium", "big"]),
    st.booleans(),                           # network delay
)


@settings(max_examples=40, deadline=None)
@given(scenario_params)
def test_property_engine_matches_oracle(p):
    m, r, v, vm, job, nd = p
    assert_parity(paper_scenario(job=job, vm=vm, n_vms=v, n_maps=m,
                                 n_reduces=r, network_delay=nd))


@settings(max_examples=30, deadline=None)
@given(scenario_params)
def test_property_invariants(p):
    """Reduce starts after every map finishes; makespan bounds; positivity."""
    m, r, v, vm, job, nd = p
    sc = paper_scenario(job=job, vm=vm, n_vms=v, n_maps=m, n_reduces=r,
                        network_delay=nd)
    res = refsim.simulate(sc)
    maps = [t for t in res.tasks if not t.is_reduce]
    reds = [t for t in res.tasks if t.is_reduce]
    last_map_finish = max(t.finish for t in maps)
    for t in reds:
        assert t.start >= last_map_finish - 1e-6      # MR dependency
    jr = res.job()
    assert jr.min_exec <= jr.avg_exec + 1e-6
    assert jr.avg_exec <= jr.max_exec + 1e-6
    assert jr.makespan >= jr.max_exec - 1e-6          # contains critical path
    assert jr.delay_time >= -1e-9
    assert jr.vm_cost > 0


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 12), st.integers(1, 6), st.integers(1, 6))
def test_property_more_vms_never_hurt(m, v1, dv):
    """Monotonicity: adding VMs never increases the makespan."""
    a = refsim.simulate(paper_scenario(n_maps=m, n_vms=v1)).job().makespan
    b = refsim.simulate(paper_scenario(n_maps=m, n_vms=v1 + dv)).job().makespan
    assert b <= a + 1e-6


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 12), st.integers(2, 8))
def test_property_network_cost_vm_invariant(m, v):
    a = refsim.simulate(paper_scenario(n_maps=m, n_vms=3)).job().network_cost
    b = refsim.simulate(paper_scenario(n_maps=m, n_vms=v)).job().network_cost
    assert a == pytest.approx(b, rel=1e-9)


# ---------------------------------------------------------------------------
# Sweep layer
# ---------------------------------------------------------------------------

def test_sweep_grid_matches_oracle():
    batch = sweep.paper_grid(m_range=range(1, 11), vm_numbers=(3, 6))
    out = sweep.simulate_batch(batch)
    i = 0
    for m in range(1, 11):
        for v in (3, 6):
            ref = refsim.simulate(paper_scenario(n_maps=m, n_vms=v)).job()
            np.testing.assert_allclose(float(out.makespan[i, 0]),
                                       ref.makespan, rtol=2e-4)
            np.testing.assert_allclose(float(out.network_cost[i, 0]),
                                       ref.network_cost, rtol=2e-4)
            i += 1


def test_stack_scenarios_matches_single():
    scs = [paper_scenario(n_maps=m) for m in (1, 4, 9)]
    out = sweep.simulate_batch(sweep.stack_scenarios(scs))
    for i, s in enumerate(scs):
        single = engine.simulate(s)
        np.testing.assert_allclose(float(out.makespan[i, 0]),
                                   float(single.makespan[0]), rtol=1e-5)
