"""Oracle equality: the vectorized JAX engine must reproduce the sequential
paper-faithful DES exactly, plus hypothesis property tests on simulator
invariants.
"""
import dataclasses

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                     # seeded fallback, same test surface
    from _hypothesis_fallback import given, settings
    from _hypothesis_fallback import strategies as st

from repro.core import (JOB_MEDIUM, JOB_SMALL, VM_MEDIUM, VM_SMALL,
                        BindingPolicy, Scenario, SchedPolicy, engine,
                        paper_scenario, refsim, sweep)

FIELDS = ("avg_exec", "max_exec", "min_exec", "makespan", "delay_time",
          "vm_cost", "network_cost", "map_avg_exec", "reduce_avg_exec")


def assert_parity(sc: Scenario, rtol=2e-4, atol=1e-2):
    ref = refsim.simulate(sc)
    got = engine.simulate(sc)
    for ji in range(len(sc.jobs)):
        for f in FIELDS:
            np.testing.assert_allclose(
                float(getattr(got, f)[ji]), getattr(ref.jobs[ji], f),
                rtol=rtol, atol=atol, err_msg=f"job {ji} field {f}")


@pytest.mark.parametrize("m", [1, 3, 4, 7, 20])
@pytest.mark.parametrize("v", [3, 9])
def test_paper_cells(m, v):
    assert_parity(paper_scenario(n_maps=m, n_vms=v))


def test_no_network_delay():
    assert_parity(paper_scenario(n_maps=7, network_delay=False))


def test_disabled_network_with_zero_bw():
    """enabled=False must yield exactly zero delay even when bw_mbps=0
    (regression: the shared transfer_delay helper divided by bw)."""
    from repro.core import NetworkSpec
    sc = paper_scenario(n_maps=4, network_delay=False).replace(
        network=NetworkSpec(enabled=False, bw_mbps=0.0))
    ref = refsim.simulate(sc)
    assert ref.job().delay_time == pytest.approx(0.0, abs=1e-9)
    got = engine.simulate(sc)
    assert np.isfinite(float(got.makespan[0]))
    assert float(got.makespan[0]) == pytest.approx(ref.job().makespan,
                                                  rel=2e-4)


def test_multi_reduce():
    assert_parity(paper_scenario(n_maps=8, n_reduces=3))


def test_multi_job_heterogeneous():
    jobs = (dataclasses.replace(JOB_SMALL, n_maps=5),
            dataclasses.replace(JOB_MEDIUM, n_maps=3, n_reduces=2,
                                submit_time=500.0))
    sc = Scenario(vms=(VM_SMALL, VM_SMALL, VM_MEDIUM), jobs=jobs)
    assert_parity(sc)


def test_padding_invariance():
    """Extra task/job/VM padding must not change results."""
    sc = paper_scenario(n_maps=5)
    base = engine._simulate_jit(engine.from_scenario(sc))
    padded = engine._simulate_jit(engine.from_scenario(
        sc, pad_tasks=32, pad_jobs=4, pad_vms=8))
    for f in FIELDS:
        np.testing.assert_allclose(float(getattr(base, f)[0]),
                                   float(getattr(padded, f)[0]), rtol=1e-5)


# ---------------------------------------------------------------------------
# Policy layer: engine must match the oracle for every policy combination
# ---------------------------------------------------------------------------

ALL_POLICIES = [(sp, bp) for sp in SchedPolicy for bp in BindingPolicy]


def _random_scenario(rng) -> Scenario:
    vms = tuple(rng.choice([VM_SMALL, VM_MEDIUM])
                for _ in range(int(rng.integers(1, 7))))
    jobs = tuple(
        dataclasses.replace(
            rng.choice([JOB_SMALL, JOB_MEDIUM]),
            n_maps=int(rng.integers(1, 9)),
            n_reduces=int(rng.integers(1, 3)),
            submit_time=float(rng.choice([0.0, 0.0, 500.0])))
        for _ in range(int(rng.integers(1, 3))))
    return Scenario(vms=vms, jobs=jobs)


def _padded_parity(sc: Scenario, rtol=1e-3, atol=1e-2, msg=""):
    """Parity on a fixed padding so the whole sweep shares one lowering."""
    ref = refsim.simulate(sc)
    arrs = engine.from_scenario(sc, pad_tasks=24, pad_jobs=2, pad_vms=9)
    got = engine._simulate_jit(arrs)
    for ji in range(len(sc.jobs)):
        for f in FIELDS:
            np.testing.assert_allclose(
                float(getattr(got, f)[ji]), getattr(ref.jobs[ji], f),
                rtol=rtol, atol=atol, err_msg=f"{msg} job {ji} field {f}")


@pytest.mark.parametrize("sp,bp", ALL_POLICIES,
                         ids=[f"{sp.name}-{bp.name}" for sp, bp in ALL_POLICIES])
def test_policy_parity_seeded_sweep(sp, bp):
    """>= 50 seeded random scenarios per (sched x binding) combination."""
    rng = np.random.default_rng(1000 * int(sp) + int(bp))
    for _ in range(50):
        sc = dataclasses.replace(_random_scenario(rng),
                                 sched_policy=sp, binding_policy=bp)
        _padded_parity(sc, msg=f"{sp.name}/{bp.name}")


def test_policy_parity_paper_cells():
    """Deterministic paper cells under every policy combination."""
    for sp, bp in ALL_POLICIES:
        for m, v in ((1, 3), (7, 3), (20, 9)):
            _padded_parity(paper_scenario(n_maps=m, n_vms=v, vm="medium",
                                          sched_policy=sp,
                                          binding_policy=bp),
                           msg=f"{sp.name}/{bp.name} M{m}V{v}")


def test_space_shared_slot_gate():
    """Space-shared never runs more than pes tasks at once on a VM."""
    sc = paper_scenario(n_maps=12, n_vms=2, vm="medium",
                        sched_policy=SchedPolicy.SPACE_SHARED)
    res = refsim.simulate(sc)
    events = sorted({t.start for t in res.tasks} |
                    {t.finish for t in res.tasks})
    for ts in events:
        mid = ts + 1e-6
        for vi, vm in enumerate(sc.vms):
            n = sum(1 for t in res.tasks
                    if t.vm == vi and t.start <= mid < t.finish)
            assert n <= vm.pes


def test_binding_policies_bind_as_specified():
    """task_vm data matches each policy's documented placement rule."""
    sc = paper_scenario(n_maps=6, n_reduces=2, n_vms=3, vm="medium")
    # ROUND_ROBIN: rolling pointer
    rr = engine.from_scenario(dataclasses.replace(
        sc, binding_policy=BindingPolicy.ROUND_ROBIN))
    np.testing.assert_array_equal(np.asarray(rr.task_vm),
                                  np.arange(8) % 3)
    # PACKED: fill pes=2 slots per VM before moving on
    pk = engine.from_scenario(dataclasses.replace(
        sc, binding_policy=BindingPolicy.PACKED))
    np.testing.assert_array_equal(np.asarray(pk.task_vm),
                                  np.array([0, 0, 1, 1, 2, 2, 0, 0]))
    # LEAST_LOADED on heterogeneous VMs prefers the high-capacity VM
    het = Scenario(vms=(VM_SMALL, VM_MEDIUM),
                   jobs=(dataclasses.replace(JOB_SMALL, n_maps=3),),
                   binding_policy=BindingPolicy.LEAST_LOADED)
    ll = engine.from_scenario(het)
    # task0 -> VM0 (tie at 0 load); the rest -> VM1: medium's capacity
    # (mips*pes = 1000) is 4x small's, so its load estimate stays lowest
    np.testing.assert_array_equal(np.asarray(ll.task_vm)[:4], [0, 1, 1, 1])
    # refsim agrees with the encoded binding
    br = refsim.IoTSimBroker(het)
    assert [t.vm for t in br.jt.tasks] == list(np.asarray(ll.task_vm)[:4])


# ---------------------------------------------------------------------------
# Property tests (hypothesis): simulator invariants
# ---------------------------------------------------------------------------

scenario_params = st.tuples(
    st.integers(1, 12),                      # n_maps
    st.integers(1, 3),                       # n_reduces
    st.integers(1, 8),                       # n_vms
    st.sampled_from(["small", "medium", "large"]),
    st.sampled_from(["small", "medium", "big"]),
    st.booleans(),                           # network delay
)


@settings(max_examples=40, deadline=None)
@given(scenario_params)
def test_property_engine_matches_oracle(p):
    m, r, v, vm, job, nd = p
    assert_parity(paper_scenario(job=job, vm=vm, n_vms=v, n_maps=m,
                                 n_reduces=r, network_delay=nd))


@settings(max_examples=30, deadline=None)
@given(scenario_params)
def test_property_invariants(p):
    """Reduce starts after every map finishes; makespan bounds; positivity."""
    m, r, v, vm, job, nd = p
    sc = paper_scenario(job=job, vm=vm, n_vms=v, n_maps=m, n_reduces=r,
                        network_delay=nd)
    res = refsim.simulate(sc)
    maps = [t for t in res.tasks if not t.is_reduce]
    reds = [t for t in res.tasks if t.is_reduce]
    last_map_finish = max(t.finish for t in maps)
    for t in reds:
        assert t.start >= last_map_finish - 1e-6      # MR dependency
    jr = res.job()
    assert jr.min_exec <= jr.avg_exec + 1e-6
    assert jr.avg_exec <= jr.max_exec + 1e-6
    assert jr.makespan >= jr.max_exec - 1e-6          # contains critical path
    assert jr.delay_time >= -1e-9
    assert jr.vm_cost > 0


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 12), st.integers(1, 6), st.integers(1, 6))
def test_property_more_vms_never_hurt(m, v1, dv):
    """Monotonicity: adding VMs never increases the makespan."""
    a = refsim.simulate(paper_scenario(n_maps=m, n_vms=v1)).job().makespan
    b = refsim.simulate(paper_scenario(n_maps=m, n_vms=v1 + dv)).job().makespan
    assert b <= a + 1e-6


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 12), st.integers(2, 8))
def test_property_network_cost_vm_invariant(m, v):
    a = refsim.simulate(paper_scenario(n_maps=m, n_vms=3)).job().network_cost
    b = refsim.simulate(paper_scenario(n_maps=m, n_vms=v)).job().network_cost
    assert a == pytest.approx(b, rel=1e-9)


# ---------------------------------------------------------------------------
# Sweep layer
# ---------------------------------------------------------------------------

def test_sweep_grid_matches_oracle():
    batch = sweep.product(sweep.axis("n_maps", range(1, 11)),
                          sweep.axis("n_vms", (3, 6))).arrays()
    out = sweep.simulate_batch(batch)
    i = 0
    for m in range(1, 11):
        for v in (3, 6):
            ref = refsim.simulate(paper_scenario(n_maps=m, n_vms=v)).job()
            np.testing.assert_allclose(float(out.makespan[i, 0]),
                                       ref.makespan, rtol=2e-4)
            np.testing.assert_allclose(float(out.network_cost[i, 0]),
                                       ref.network_cost, rtol=2e-4)
            i += 1


def test_encode_cell_roundtrips_from_scenario():
    """Device-side cell encoding == host-side encoding of the same cell."""
    for sp, bp in ALL_POLICIES:
        sc = paper_scenario(n_maps=5, n_reduces=2, n_vms=3, vm="medium",
                            sched_policy=sp, binding_policy=bp)
        host = engine.from_scenario(sc, pad_tasks=9, pad_vms=4)
        vm = sc.vms[0]
        dev = sweep.encode_cell(
            n_maps=5, n_reduces=2, n_vms=3, vm_mips=vm.mips,
            vm_pes=float(vm.pes), vm_cost=vm.cost_per_sec,
            job_length=sc.jobs[0].length_mi, job_data=sc.jobs[0].data_mb,
            pad_tasks=9, pad_vms=4, sched_policy=int(sp),
            binding_policy=int(bp))
        for f in engine.ScenarioArrays._fields:
            np.testing.assert_allclose(
                np.asarray(getattr(host, f), np.float32),
                np.asarray(getattr(dev, f), np.float32),
                err_msg=f"field {f} ({sp.name}/{bp.name})")


def test_least_loaded_binding_precision_roundtrip():
    """Huge workload-scale lengths: host- and device-side encoders must
    still bind identically (regression: f64-vs-f32 base-length drift could
    flip LEAST_LOADED argmin ties)."""
    job = dataclasses.replace(JOB_SMALL, length_mi=5.1e16, n_maps=17,
                              n_reduces=2)
    sc = Scenario(vms=(VM_SMALL, VM_MEDIUM, VM_SMALL), jobs=(job,),
                  binding_policy=BindingPolicy.LEAST_LOADED)
    host = engine.from_scenario(sc, pad_tasks=19, pad_vms=3)
    dev = sweep.encode_cell(
        n_maps=17, n_reduces=2, n_vms=3, vm_mips=250.0, vm_pes=1.0,
        vm_cost=1.0, job_length=5.1e16, job_data=job.data_mb,
        pad_tasks=19, pad_vms=3,
        binding_policy=int(BindingPolicy.LEAST_LOADED))
    # homogeneous cell for the device side; check the host self-consistency
    # against refsim and the f32 op sequence on the device side
    br = refsim.IoTSimBroker(sc)
    assert [t.vm for t in br.jt.tasks] == list(np.asarray(host.task_vm)[:19])
    hom = Scenario(vms=(VM_SMALL,) * 3, jobs=(job,),
                   binding_policy=BindingPolicy.LEAST_LOADED)
    np.testing.assert_array_equal(
        np.asarray(engine.from_scenario(hom, pad_tasks=19).task_vm),
        np.asarray(dev.task_vm))


def test_stack_scenarios_matches_single():
    scs = [paper_scenario(n_maps=m) for m in (1, 4, 9)]
    out = sweep.simulate_batch(sweep.stack_scenarios(scs))
    for i, s in enumerate(scs):
        single = engine.simulate(s)
        np.testing.assert_allclose(float(out.makespan[i, 0]),
                                   float(single.makespan[0]), rtol=1e-5)
