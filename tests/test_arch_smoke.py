"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step + one decode step on CPU; asserts output shapes and
no NaNs (the FULL configs are exercised only via the dry-run).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import (decode_step, forward, init_model, loss_fn, prefill)

ARCHS = configs.arch_names()


def _reduced(name, *, no_drop=False):
    cfg = configs.get(name)
    # jamba's period is lcm(attn_every, moe.every): keep 1 full period
    if cfg.family == "hybrid":
        cfg = cfg.reduced(n_layers=4, attn_every=4)
    else:
        cfg = cfg.reduced()
    if no_drop and cfg.moe is not None:
        # decode-vs-forward equivalence needs drop-free MoE: capacity
        # drops legitimately differ between prefill(S) and forward(S+DEC)
        import dataclasses
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  capacity_factor=8.0))
    return cfg


def _tiny_inputs(cfg, key, B=2, S=16):
    if cfg.embedding_inputs:
        return jax.random.normal(key, (B, S, cfg.d_model),
                                 jnp.dtype(cfg.dtype))
    return jax.random.randint(key, (B, S), 0, cfg.vocab)


@pytest.mark.parametrize("name", ARCHS)
def test_full_config_metadata(name):
    """The full config matches the assignment's table exactly."""
    cfg = configs.get(name)
    assert cfg.name == name
    assert cfg.n_layers >= 24 and cfg.d_model >= 1280
    if cfg.n_heads:
        assert cfg.d_model % cfg.n_heads == 0
    # registry <-> shapes coherence
    shapes = configs.supported_shapes(cfg)
    assert "train_4k" in shapes and "prefill_32k" in shapes
    if cfg.family == "encoder":
        assert "decode_32k" not in shapes
    if cfg.family in ("ssm", "hybrid") or cfg.window:
        assert "long_500k" in shapes


@pytest.mark.parametrize("name", ARCHS)
def test_smoke_train_step(name):
    """One forward + grad step on a reduced config: shapes + finite."""
    cfg = _reduced(name)
    key = jax.random.PRNGKey(0)
    params = init_model(key, cfg)
    B, S = 2, 16
    batch = {"inputs": _tiny_inputs(cfg, key, B, S),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab)}

    logits = jax.jit(lambda p: forward(p, cfg, batch["inputs"]))(params)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert not np.isnan(np.asarray(logits, np.float32)).any()

    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: loss_fn(p, cfg, batch)))(params)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat)


@pytest.mark.parametrize("name", ARCHS)
def test_smoke_decode_step(name):
    """Prefill + two decode steps match the full forward (reduced cfg)."""
    cfg = _reduced(name, no_drop=True)
    if not cfg.has_decode or cfg.embedding_inputs:
        pytest.skip("no decode path for encoder/frontend-stub smoke")
    key = jax.random.PRNGKey(1)
    params = init_model(key, cfg)
    B, S, DEC = 2, 12, 2
    toks = jax.random.randint(key, (B, S + DEC), 0, cfg.vocab)
    full = forward(params, cfg, toks, remat=False)
    cache_len = configs.decode_cache_len(cfg, S + DEC)
    lg, state = prefill(params, cfg, toks[:, :S], cache_len)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, S - 1]),
                               atol=2e-2, rtol=1e-2)
    for t in range(S, S + DEC):
        lg, state = decode_step(params, cfg, toks[:, t], state, t)
        assert lg.shape == (B, cfg.padded_vocab)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, t]),
                                   atol=2e-2, rtol=1e-2)


def test_all_cells_count():
    """32 runnable cells per the assignment skip rules (DESIGN.md §6)."""
    cells = configs.all_cells()
    assert len(cells) == 32
    assert ("hubert-xlarge", "decode_32k") not in cells
    assert ("yi-6b", "long_500k") not in cells
    assert ("mixtral-8x7b", "long_500k") in cells
    assert ("rwkv6-3b", "long_500k") in cells
    assert ("jamba-v0.1-52b", "long_500k") in cells
