"""Unit tests for the sharding resolver and the HLO collective parser —
the two pieces of pure logic the whole dry-run leans on."""
import os
import subprocess
import sys

import pytest

from repro.launch import hlo_stats
from repro.sharding import rules


# ---------------------------------------------------------------------------
# spec_for: run in a 512-device subprocess-free way (mesh building needs
# multiple devices -> use a subprocess once, parameterized inline)
# ---------------------------------------------------------------------------

_SPEC_PROG = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import jax
from repro.sharding import rules
from repro.launch.mesh import make_production_mesh

mesh = make_production_mesh()               # (data=16, model=16)
mp = make_production_mesh(multi_pod=True)   # (pod=2, data=16, model=16)
checks = []

def expect(shape, axes, want, m=mesh, rl=rules.WEIGHT_RULES):
    got = str(rules.spec_for(m, shape, axes, rl))
    checks.append((shape, axes, want, got, want == got))

# TP + FSDP basics
expect((4096, 11008), ("embed", "mlp"), "PartitionSpec('data', 'model')")
# llama4: 40 heads don't divide 16 -> head_dim fallback
expect((5120, 40, 128), ("embed", "heads", "head_dim"),
       "PartitionSpec('data', None, 'model')")
# divisible heads take the model axis, head_dim skipped (axis used)
expect((4096, 32, 128), ("embed", "heads", "head_dim"),
       "PartitionSpec('data', 'model', None)")
# hubert vocab 504 -> padded 512 divides; raw 504 would be replicated
expect((512, 1280), ("vocab", "embed"), "PartitionSpec('model', 'data')")
expect((504, 1280), ("vocab", "embed"), "PartitionSpec(None, 'data')")
# kv cache: seq beats head_dim under STATE_RULES, not under ACT_RULES
expect((128, 32768, 8, 128), ("batch", "seq", "kv_heads", "head_dim"),
       "PartitionSpec('data', 'model', None, None)", rl=rules.STATE_RULES)
expect((128, 32768, 8, 128), ("batch", "seq", "kv_heads", "head_dim"),
       "PartitionSpec('data', None, None, 'model')", rl=rules.ACT_RULES)
# batch super-axis covers pod+data on the multi-pod mesh
expect((256, 4096), ("batch", "seq"),
       "PartitionSpec(('pod', 'data'), 'model')", m=mp, rl=rules.ACT_RULES)
# indivisible batch degrades to replicated (never fails)
expect((3, 7), ("batch", "seq"), "PartitionSpec(None, None)",
       rl=rules.ACT_RULES)
# FSDP2: one dim takes both axes
expect((5120, 13824), ("embed", "mlp"),
       "PartitionSpec(('data', 'model'), None)", rl=rules.WEIGHT_RULES_FSDP2)

for shape, axes, want, got, ok in checks:
    print("OK" if ok else f"FAIL {shape} {axes}: want {want} got {got}")
"""


def test_spec_for_resolution():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", _SPEC_PROG], env=env,
                         capture_output=True, text=True, cwd=os.getcwd())
    assert out.returncode == 0, out.stderr
    lines = [ln for ln in out.stdout.splitlines() if ln.strip()]
    assert len(lines) == 10
    bad = [ln for ln in lines if ln != "OK"]
    assert not bad, bad


# ---------------------------------------------------------------------------
# hlo_stats: collective parsing on a synthetic HLO snippet
# ---------------------------------------------------------------------------

_HLO = """
HloModule test
fused {
  %x = bf16[16,4096]{1,0} parameter(0)
}
ENTRY main {
  %p0 = bf16[16,4096]{1,0} parameter(0)
  %ag = bf16[256,4096]{1,0} all-gather(%p0), replica_groups=[16,16]<=[256], dimensions={0}
  %ar = f32[8,1024]{1,0} parameter(1)
  %ar2 = f32[8,1024]{1,0} all-reduce(%ar), replica_groups={{0,1,2,3}}, to_apply=add
  %rs = bf16[2,4096]{1,0} reduce-scatter(%p0), replica_groups=[2,8]<=[16], dimensions={0}
  %cp = bf16[16,4096]{1,0} collective-permute(%p0), source_target_pairs={{0,1}}
  ROOT %t = (bf16[256,4096]{1,0}) tuple(%ag)
}
"""


def test_collective_stats_parsing():
    st = hlo_stats.collective_stats(_HLO)
    assert st["all-gather"]["count"] == 1
    # operand = the 16x4096 bf16 shard
    assert st["all-gather"]["operand_bytes"] == 16 * 4096 * 2
    assert st["all-gather"]["result_bytes"] == 256 * 4096 * 2
    # wire: (k-1)/k * result with k=16 (iota groups [16,16]<=[256])
    assert st["all-gather"]["wire_bytes"] == pytest.approx(
        15 / 16 * 256 * 4096 * 2)
    # all-reduce: k=4 from explicit groups, 2(k-1)/k * operand
    assert st["all-reduce"]["wire_bytes"] == pytest.approx(
        2 * 3 / 4 * 8 * 1024 * 4)
    # reduce-scatter: k=8, (k-1)/k * operand
    assert st["reduce-scatter"]["wire_bytes"] == pytest.approx(
        7 / 8 * 16 * 4096 * 2)
    # collective-permute: full operand crosses the wire
    assert st["collective-permute"]["wire_bytes"] == 16 * 4096 * 2
    tot = hlo_stats.totals(st)
    assert tot["collective_count"] == 4
    assert tot["collective_wire_bytes"] == pytest.approx(
        sum(r["wire_bytes"] for r in st.values()))


def test_collective_stats_empty():
    assert hlo_stats.collective_stats("ENTRY main { ROOT %c = s32[] constant(0) }") == {}


# ---------------------------------------------------------------------------
# roofline param counting vs actual model parameters
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["yi-6b", "mixtral-8x7b", "rwkv6-3b",
                                  "jamba-v0.1-52b"])
def test_param_count_matches_model(arch):
    import jax

    from benchmarks.roofline import param_counts
    from repro import configs
    from repro.models import abstract_model
    cfg = configs.get(arch)
    actual = sum(x.size for x in jax.tree.leaves(abstract_model(cfg)))
    counted = param_counts(cfg)["total"]
    # analytic count covers matmuls + embeddings (norms/biases/loras are
    # the remainder): must agree within 3 %
    assert counted == pytest.approx(actual, rel=0.03), \
        (counted / 1e9, actual / 1e9)
