"""End-to-end behaviour tests: the full pipeline the framework exists for —
paper-faithful simulation -> vectorized sweeps -> LM workload bridge ->
fault-tolerant training — exercised together.
"""
import dataclasses
import os

import numpy as np
import pytest

from repro.core import (JOB_BIG, VM_TYPES, ChipSpec, Scenario, StepCost,
                        engine, paper_scenario, refsim, sweep, workload)
from repro.models import ArchConfig
from repro.train import OptConfig, TrainConfig, train


def test_end_to_end_provisioning_decision():
    """The paper's §5 use case end to end: sweep candidate deployments,
    pick the cheapest meeting an SLA, cross-check with the oracle."""
    cells = [(vm_name, vm, n, 16) for vm_name, vm in VM_TYPES.items()
             for n in (2, 4, 8)]
    params = dict(
        n_maps=np.array([c[3] for c in cells], np.int32),
        n_reduces=np.ones(len(cells), np.int32),
        n_vms=np.array([c[2] for c in cells], np.int32),
        vm_mips=np.array([c[1].mips for c in cells], np.float32),
        vm_pes=np.array([float(c[1].pes) for c in cells], np.float32),
        vm_cost=np.array([c[1].cost_per_sec for c in cells], np.float32),
        job_length=np.full(len(cells), JOB_BIG.length_mi, np.float32),
        job_data=np.full(len(cells), JOB_BIG.data_mb, np.float32),
    )
    batch = sweep.grid_arrays(params, pad_tasks=17, pad_vms=8)
    out = sweep.simulate_batch(batch)
    makespan = np.asarray(out.makespan[:, 0])
    cost = np.asarray(out.vm_cost[:, 0])
    feasible = makespan <= 6000.0
    assert feasible.any()
    best = int(np.argmin(np.where(feasible, cost, np.inf)))

    # oracle agrees on the winning cell
    vm_name, vm, n, m = cells[best]
    ref = refsim.simulate(Scenario(
        vms=(vm,) * n,
        jobs=(dataclasses.replace(JOB_BIG, n_maps=m),))).job()
    assert ref.makespan == pytest.approx(makespan[best], rel=1e-4)
    assert ref.vm_cost == pytest.approx(cost[best], rel=1e-4)


def test_simulator_to_training_bridge():
    """Dry-run cost model -> simulator -> goodput prediction is coherent."""
    cost = StepCost(flops=5e13, hbm_bytes=5e11, collective_bytes=5e9)
    chip = ChipSpec()
    pred = workload.simulate_training(cost, chip, n_devices=128,
                                      n_steps=500, straggler_sigma=0.05,
                                      mtbf_hours=500.0)
    assert 0.0 < pred["goodput"] <= 1.0
    assert pred["step_seconds"] >= pred["ideal_step_seconds"] - 1e-9
    # more failures -> less goodput, monotone in MTBF
    worse = workload.simulate_training(cost, chip, n_devices=128,
                                       n_steps=500, straggler_sigma=0.05,
                                       mtbf_hours=50.0)
    assert worse["goodput"] < pred["goodput"]


def test_training_with_failure_and_resume(tmp_path):
    """Tiny LM survives an injected failure and reaches the clean-run loss."""
    cfg = ArchConfig(name="sys-tiny", family="dense", n_layers=2,
                     d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
                     vocab=64, vocab_pad_to=8, dtype="float32")
    tc = TrainConfig(steps=30, seq_len=32, global_batch=4,
                     opt=OptConfig(lr=3e-3, warmup_steps=5),
                     ckpt_dir=os.path.join(str(tmp_path), "ck"),
                     ckpt_every=10)
    hit = {"armed": True}

    def hook(s):
        if s == 15 and hit["armed"]:
            hit["armed"] = False
            from repro.train import NodeFailure
            raise NodeFailure("chaos")

    h = train(cfg, tc, fault_hook=hook)
    clean = train(cfg, TrainConfig(steps=30, seq_len=32, global_batch=4,
                                   opt=OptConfig(lr=3e-3, warmup_steps=5)))
    assert h["restarts"] == 1
    np.testing.assert_allclose(h["loss"][-3:], clean["loss"][-3:],
                               rtol=1e-5)


def test_engine_epoch_bound_property():
    """Every simulation terminates within the 2T+2 epoch bound."""
    for m in (1, 7, 20):
        sc = paper_scenario(n_maps=m, n_reduces=2, n_vms=5)
        out = engine._simulate_jit(engine.from_scenario(sc))
        assert np.isfinite(float(out.makespan[0]))