"""Sparse active-lane compaction + measured-cost scheduling (DESIGN.md §9).

The compacted stepping drivers — ``engine.simulate_batch_arrays_compact``
and the Pallas ``epoch_schedule_compact`` — gather still-active lanes into
a pow2-padded batch every K epochs and scatter the carry back.  Because
the epoch body is idempotent for finished lanes, dropping them from the
working set is a **bitwise** no-op; this suite pins that claim:

* compacted == dense ``simulate_batch_arrays``, every ``SimOutput`` field
  and the realized epoch count, across all 6 policy combos, a mixed
  storage grid (LOCALITY + replication/placement skew) and an elastic
  grid with stranded lanes (``finish`` stays at the 1e30 +inf stand-in),
  for K in {1, 4, "auto"};
* ``run(compact=...)`` == ``run()`` across bucketed / chunked / pallas
  execution modes, including same-mode ``realized_epochs`` parity;
* engine <-> batched <-> pallas parity under compaction;
* the shared pow2 padding util matches the retired per-unique-value loop;
* the measured cost model is deterministic given a pinned calibration
  file — equal coefficients, equal bucket partitions, equal intervals.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as hst
    HAVE_HYPOTHESIS = True
except ImportError:              # environment without hypothesis: the
    HAVE_HYPOTHESIS = False      # seeded-rng cases below still run

from repro.core import BindingPolicy, SchedPolicy, costmodel, engine, sweep
from repro.core.engine import _BIG
from repro.core.sweep import axis, product, zip_
from repro.core.util import pow2_pad, pow2_pads
from repro.kernels.mr_sched import epoch_schedule, epoch_schedule_compact

ALL_POLICIES = [(sp, bp) for sp in SchedPolicy for bp in BindingPolicy]
KS = [1, 4, "auto"]

# one pinned calibration shared by every scheduling-determinism test
PINNED = costmodel.CostModel(dispatch_us=800.0, epoch_lane_us=0.05,
                             sync_us=120.0, device="pinned")


def _random_params(n, seed, mixed_policies=True):
    rng = np.random.default_rng(seed)
    params = dict(
        n_maps=rng.integers(1, 21, n).astype(np.int32),
        n_reduces=rng.integers(1, 3, n).astype(np.int32),
        n_vms=rng.integers(1, 10, n).astype(np.int32),
        vm_mips=rng.choice([250.0, 500.0, 1000.0], n).astype(np.float32),
        vm_pes=rng.choice([1.0, 2.0, 4.0], n).astype(np.float32),
        vm_cost=rng.choice([1.0, 2.0], n).astype(np.float32),
        job_length=rng.choice([362880.0, 725760.0], n).astype(np.float32),
        job_data=rng.choice([2e5, 4e5], n).astype(np.float32),
    )
    if mixed_policies:
        params["sched_policy"] = rng.integers(0, 2, n).astype(np.int32)
        params["binding_policy"] = rng.integers(0, 3, n).astype(np.int32)
    return params


def _storage_params(n, seed):
    rng = np.random.default_rng(seed)
    params = _random_params(n, seed)
    params.update(
        binding_policy=rng.integers(0, 4, n).astype(np.int32),
        storage_enabled=rng.integers(0, 2, n).astype(np.float32),
        replication=rng.integers(1, 4, n).astype(np.int32),
        placement=rng.integers(0, 2, n).astype(np.int32),
        block_size_mb=rng.choice([1024.0, 8192.0], n).astype(np.float32),
        storage_seed=rng.integers(0, 100, n).astype(np.int32),
    )
    return params


def _elastic_params(n, seed):
    """Lease windows that close before some tasks become eligible — the
    grid must exercise stranded lanes (asserted below)."""
    rng = np.random.default_rng(seed)
    params = _random_params(n, seed)
    params.update(
        job_submit=rng.choice([0.0, 400.0], n).astype(np.float32),
        spinup_delay=rng.choice([0.0, 120.0], n).astype(np.float32),
        vm_start=rng.choice([0.0, 800.0], (n, 9)).astype(np.float32),
        vm_stop=rng.choice([900.0, 40000.0, _BIG], (n, 9)
                           ).astype(np.float32),
        task_prio=rng.integers(0, 3, (n, 23)).astype(np.float32),
    )
    return params


def _assert_bitwise(a, b, tag):
    for f in a._fields:
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)),
                                      err_msg=f"{f} ({tag})")


# ---------------------------------------------------------------------------
# Engine: compacted vs dense, bitwise (policies x storage x elastic)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sp,bp", ALL_POLICIES,
                         ids=[f"{sp.name}-{bp.name}"
                              for sp, bp in ALL_POLICIES])
def test_engine_compact_bitwise_per_policy(sp, bp):
    n = 24
    params = _random_params(n, seed=10 * int(sp) + int(bp),
                            mixed_policies=False)
    params["sched_policy"] = np.full(n, int(sp), np.int32)
    params["binding_policy"] = np.full(n, int(bp), np.int32)
    batch = sweep.grid_arrays(params, pad_tasks=23, pad_vms=9)
    dense, realized = jax.jit(engine.simulate_batch_arrays)(batch)
    for k in KS:
        comp, rz = engine.simulate_batch_arrays_compact(batch, k=k)
        _assert_bitwise(dense, comp, f"{sp.name}/{bp.name} k={k}")
        assert int(rz) == int(realized), (sp, bp, k)


@pytest.mark.parametrize("k", KS, ids=[f"k{k}" for k in KS])
def test_engine_compact_bitwise_storage_grid(k):
    batch = sweep.grid_arrays(_storage_params(48, seed=11),
                              pad_tasks=23, pad_vms=9)
    dense, realized = jax.jit(engine.simulate_batch_arrays)(batch)
    comp, rz = engine.simulate_batch_arrays_compact(batch, k=k)
    _assert_bitwise(dense, comp, f"storage k={k}")
    assert int(rz) == int(realized)


@pytest.mark.parametrize("k", KS, ids=[f"k{k}" for k in KS])
def test_engine_compact_bitwise_elastic_stranded(k):
    batch = sweep.grid_arrays(_elastic_params(48, seed=23),
                              pad_tasks=23, pad_vms=9)
    dense, realized = jax.jit(engine.simulate_batch_arrays)(batch)
    stranded = np.asarray(batch.task_valid) & (np.asarray(dense.finish)
                                               >= _BIG / 2)
    assert stranded.any(), "grid should exercise stranding"
    comp, rz = engine.simulate_batch_arrays_compact(batch, k=k)
    _assert_bitwise(dense, comp, f"elastic k={k}")
    assert int(rz) == int(realized)
    # stranded lanes never leave the working set, so they realize the
    # full epoch budget in both drivers
    np.testing.assert_array_equal(
        np.asarray(dense.finish) >= _BIG / 2,
        np.asarray(comp.finish) >= _BIG / 2)


def test_engine_compact_rejects_bad_k():
    batch = sweep.grid_arrays(_random_params(8, seed=1),
                              pad_tasks=23, pad_vms=9)
    with pytest.raises(ValueError, match="k"):
        engine.simulate_batch_arrays_compact(batch, k=0)


# ---------------------------------------------------------------------------
# Pallas: compacted vs dense megakernel vs engine (three-way, bitwise)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", KS, ids=[f"k{k}" for k in KS])
def test_pallas_compact_three_way_bitwise(k):
    params = _random_params(48, seed=7)
    batch = sweep.grid_arrays(params, pad_tasks=23, pad_vms=9)
    eng, _ = jax.jit(engine.simulate_batch_arrays)(batch)
    dense = epoch_schedule(batch, tile=8, interpret=True)
    comp, rz = epoch_schedule_compact(batch, k=k, tile=8, interpret=True)
    _assert_bitwise(eng, dense, "engine vs dense pallas")
    _assert_bitwise(dense, comp, f"dense vs compact pallas k={k}")
    assert int(rz) == int(np.asarray(dense.n_epochs).max())


def test_pallas_compact_elastic_stranded_bitwise():
    batch = sweep.grid_arrays(_elastic_params(32, seed=23),
                              pad_tasks=23, pad_vms=9)
    eng, _ = jax.jit(engine.simulate_batch_arrays)(batch)
    comp, _ = epoch_schedule_compact(batch, k=4, tile=8, interpret=True)
    stranded = np.asarray(batch.task_valid) & (np.asarray(eng.finish)
                                               >= _BIG / 2)
    assert stranded.any(), "grid should exercise stranding"
    _assert_bitwise(eng, comp, "engine vs compact pallas (stranded)")


# ---------------------------------------------------------------------------
# run(compact=...): bit-identity across execution modes
# ---------------------------------------------------------------------------

def _mixed_plan(n=96, seed=5):
    params = _random_params(n, seed)
    plan = product(zip_(*(axis(k, v) for k, v in params.items())))
    return plan.replace(pad_tasks=23, pad_vms=9)


def test_run_compact_bit_identical_all_modes():
    plan = _mixed_plan()
    base = plan.run(bucket=False)
    variants = {
        "compact": plan.run(compact="auto"),
        "compact-k1": plan.run(compact=1),
        "nobucket+compact": plan.run(bucket=False, compact=4),
        "chunk+compact": plan.run(chunk=17, compact=4),
        "pallas+compact": plan.run(backend="pallas", compact=4),
        "pallas+chunk+compact": plan.run(backend="pallas", chunk=17,
                                         compact="auto"),
    }
    for tag, res in variants.items():
        for name in base.metric_names:
            if name == "realized_epochs":   # schedule-dependent by design
                continue
            np.testing.assert_array_equal(base[name], res[name],
                                          err_msg=f"{name} ({tag})")


def test_run_compact_realized_parity_same_mode():
    """Same execution mode, compaction on vs off: even realized_epochs —
    the schedule-dependent metric — must agree, because a compacted
    global epoch executes iff some lane is active, exactly like dense."""
    plan = _mixed_plan(n=64, seed=3)
    for kw in (dict(bucket=False), dict(bucket=False, backend="pallas")):
        dense = plan.run(**kw)
        comp = plan.run(compact=1, **kw)
        for name in dense.metric_names:
            np.testing.assert_array_equal(dense[name], comp[name],
                                          err_msg=f"{name} ({kw})")


def test_run_compact_rejects_bad_values():
    plan = product(axis("n_maps", (1, 2)))
    with pytest.raises(ValueError, match="compact"):
        plan.run(compact=0)
    with pytest.raises(ValueError, match="compact"):
        plan.run(compact="always")


def test_run_compact_mesh_ignored():
    """The mesh path shards per-lane epoch loops (no dense tail to trim):
    compact is accepted and ignored, results unchanged."""
    plan = _mixed_plan(n=32, seed=9)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("pod",))
    base = plan.run(mesh=mesh)
    comp = plan.run(mesh=mesh, compact=4)
    for name in base.metric_names:
        np.testing.assert_array_equal(base[name], comp[name], err_msg=name)


# ---------------------------------------------------------------------------
# pow2 padding util (hoisted from sweep; vectorized)
# ---------------------------------------------------------------------------

def test_pow2_pad_matches_reference_loop():
    def ref(need, cap, floor=4):        # the retired scalar loop
        b = floor
        while b < need:
            b *= 2
        return min(b, cap)

    rng = np.random.default_rng(0)
    need = rng.integers(0, 70, 500)
    for cap in (8, 21, 23, 64, 100):
        for floor in (4, 8):
            want = np.array([ref(int(v), cap, floor) for v in need])
            np.testing.assert_array_equal(pow2_pads(need, cap, floor), want)
            for v in (0, 1, 4, 5, 8, 63, 64, 65):
                assert pow2_pad(v, cap, floor) == ref(v, cap, floor)


def test_pow2_pads_vectorized_properties():
    need = np.array([1, 3, 4, 5, 9, 40, 1000])
    pads = pow2_pads(need, cap=64, floor=4)
    assert (pads >= np.minimum(need, 64)).all()
    assert (pads <= 64).all()
    # every pad is floor * 2**j or the cap
    assert all(p == 64 or (p % 4 == 0 and (p // 4) & (p // 4 - 1) == 0)
               for p in pads.tolist())


# ---------------------------------------------------------------------------
# Cost model: pinned-calibration determinism
# ---------------------------------------------------------------------------

def test_cost_model_roundtrip_and_determinism(tmp_path):
    path = tmp_path / "costmodel.json"
    costmodel.save_cost_model(PINNED, path)
    m1 = costmodel.load_cost_model(path, device="pinned")
    m2 = costmodel.load_cost_model(path)        # single-entry form
    assert m1 == m2 == PINNED
    # file contents are plain JSON: schema version + the coefficients
    data = json.loads(path.read_text())
    assert data == {"schema": costmodel.SCHEMA_VERSION,
                    "models": {"pinned": {"dispatch_us": 800.0,
                                          "epoch_lane_us": 0.05,
                                          "sync_us": 120.0}}}


def test_cost_model_stale_schema_invalidated(tmp_path):
    """Pre-schema / mismatched caches raise on load and are discarded on
    save instead of feeding drifted coefficients to the schedulers."""
    path = tmp_path / "costmodel.json"
    # the pre-schema format: a bare device -> coefficients mapping
    path.write_text(json.dumps(
        {"old-dev": {"dispatch_us": 1.0, "epoch_lane_us": 9.9}}))
    with pytest.raises(ValueError, match="schema"):
        costmodel.load_cost_model(path, device="old-dev")
    # a future schema version is equally stale
    path.write_text(json.dumps(
        {"schema": costmodel.SCHEMA_VERSION + 1,
         "models": {"d": {"dispatch_us": 1.0, "epoch_lane_us": 1.0}}}))
    with pytest.raises(ValueError, match="schema"):
        costmodel.load_cost_model(path)
    # saving over a stale cache drops its entries entirely
    costmodel.save_cost_model(PINNED, path)
    data = json.loads(path.read_text())
    assert data["schema"] == costmodel.SCHEMA_VERSION
    assert list(data["models"]) == ["pinned"]


def test_cost_model_scoring_is_deterministic():
    params = _random_params(300, seed=11)
    g1 = sweep._bucket_groups(params, 23, 9, "auto", cost=PINNED)
    g2 = sweep._bucket_groups(params, 23, 9, "auto", cost=PINNED)
    assert len(g1) == len(g2)
    for a, b in zip(g1, g2):
        np.testing.assert_array_equal(a[0], b[0])
        assert a[2:] == b[2:]
    # intervals derive from the same two coefficients
    assert PINNED.compact_interval(2048, 21) \
        == PINNED.compact_interval(2048, 21)
    assert PINNED.compact_interval(8, 8) >= 1


def test_bucket_groups_partition_under_pinned_cost():
    """The measured-cost scorer still yields a valid ordered partition
    with correct per-bucket pads (the old suite's invariants)."""
    params = _random_params(300, seed=11)
    groups = sweep._bucket_groups(params, 23, 9, "auto", cost=PINNED)
    seen = np.concatenate([g[0] for g in groups])
    assert len(seen) == 300 and len(np.unique(seen)) == 300
    for idx, gcols, statics, tb, vb in groups:
        assert (np.diff(idx) > 0).all()
        need_t = gcols["n_maps"] + gcols["n_reduces"]
        assert int(need_t.max()) <= tb <= 23
        assert int(gcols["n_vms"].max()) <= vb <= 9


def test_bucket_split_follows_dispatch_cost():
    """Cheaper dispatch => more buckets (splits amortize sooner); a huge
    dispatch cost collapses the grid into one bucket per policy combo."""
    params = _random_params(300, seed=11, mixed_policies=False)
    cheap = costmodel.CostModel(dispatch_us=10.0, epoch_lane_us=0.05,
                                device="cheap")
    pricey = costmodel.CostModel(dispatch_us=1e9, epoch_lane_us=0.05,
                                 device="pricey")
    n_cheap = len(sweep._bucket_groups(params, 23, 9, "auto", cost=cheap))
    n_pricey = len(sweep._bucket_groups(params, 23, 9, "auto", cost=pricey))
    assert n_pricey == 1
    assert n_cheap > n_pricey


def test_run_results_independent_of_cost_model():
    """Scheduling decisions change with the calibration; results may not."""
    plan = _mixed_plan(n=96, seed=5)
    cheap = costmodel.CostModel(dispatch_us=10.0, epoch_lane_us=0.05,
                                device="cheap")
    a = plan.run(cost_model=PINNED, compact="auto")
    b = plan.run(cost_model=cheap, compact="auto")
    base = plan.run(bucket=False)
    for name in base.metric_names:
        if name == "realized_epochs":
            continue
        np.testing.assert_array_equal(base[name], a[name], err_msg=name)
        np.testing.assert_array_equal(base[name], b[name], err_msg=name)


def test_default_cost_model_prefers_pinned_file(tmp_path, monkeypatch):
    """REPRO_COSTMODEL_PATH + a pinned file skips measurement entirely."""
    path = tmp_path / "cal.json"
    key = costmodel.device_key()
    costmodel.save_cost_model(
        costmodel.CostModel(dispatch_us=123.0, epoch_lane_us=0.01,
                            device=key), path)
    monkeypatch.setenv(costmodel.ENV_PATH, str(path))
    monkeypatch.setattr(costmodel, "_CACHE", {})
    got = costmodel.default_cost_model()
    assert got.dispatch_us == 123.0 and got.epoch_lane_us == 0.01


# ---------------------------------------------------------------------------
# Floor validation (ISSUE 10): nonsensical pow2 floors fail loudly
# ---------------------------------------------------------------------------

BAD_FLOORS = [0, -1, -8, 3, 6, 12]


@pytest.mark.parametrize("floor", BAD_FLOORS)
def test_pow2_pad_rejects_bad_floor(floor):
    with pytest.raises(ValueError, match="floor"):
        pow2_pad(5, cap=64, floor=floor)
    with pytest.raises(ValueError, match="floor"):
        pow2_pads(np.array([5, 9]), cap=64, floor=floor)


@pytest.mark.parametrize("floor", [0, -4, 6])
def test_compact_drivers_reject_bad_floor(floor):
    batch = sweep.grid_arrays(_random_params(8, seed=1),
                              pad_tasks=23, pad_vms=9)
    with pytest.raises(ValueError, match="floor"):
        engine.simulate_batch_arrays_compact(batch, k=2, floor=floor)
    with pytest.raises(ValueError, match="floor"):
        epoch_schedule_compact(batch, k=2, tile=8, interpret=True,
                               floor=floor)


# ---------------------------------------------------------------------------
# Compact-interval clamp: named constants, pinned (ISSUE 10 satellite)
# ---------------------------------------------------------------------------

def test_compact_interval_clamp_constants_pinned():
    """The K* re-derivation (sync_us + dispatch_us round pricing) must not
    silently change the clamp the pre-split formula used."""
    assert costmodel.COMPACT_INTERVAL_MIN == 1
    assert costmodel.COMPACT_INTERVAL_MAX == 64
    huge = costmodel.CostModel(dispatch_us=1e12, epoch_lane_us=0.05,
                               sync_us=1e12, device="huge")
    assert huge.compact_interval(2048, 21) == costmodel.COMPACT_INTERVAL_MAX
    tiny = costmodel.CostModel(dispatch_us=1e-9, epoch_lane_us=1e9,
                               sync_us=1e-9, device="tiny")
    assert tiny.compact_interval(2048, 21) == costmodel.COMPACT_INTERVAL_MIN
    for n, t in ((8, 8), (64, 21), (2048, 23)):
        k = PINNED.compact_interval(n, t)
        assert costmodel.COMPACT_INTERVAL_MIN <= k \
            <= costmodel.COMPACT_INTERVAL_MAX


def test_compact_interval_prices_sync_plus_dispatch():
    """A round costs one scalar pull plus one chunk launch: moving cost
    between the two coefficients leaves K* unchanged."""
    a = costmodel.CostModel(dispatch_us=900.0, epoch_lane_us=0.05,
                            sync_us=100.0, device="a")
    b = costmodel.CostModel(dispatch_us=100.0, epoch_lane_us=0.05,
                            sync_us=900.0, device="b")
    for n, t in ((64, 8), (512, 21), (2048, 23)):
        assert a.compact_interval(n, t) == b.compact_interval(n, t)
    # and a pricier sync alone pushes the interval up (fewer checks)
    cheap_sync = costmodel.CostModel(dispatch_us=800.0, epoch_lane_us=0.05,
                                     sync_us=1.0, device="c")
    dear_sync = costmodel.CostModel(dispatch_us=800.0, epoch_lane_us=0.05,
                                    sync_us=80000.0, device="d")
    assert dear_sync.compact_interval(512, 21) \
        > cheap_sync.compact_interval(512, 21)


# ---------------------------------------------------------------------------
# _take_lanes/_put_lanes round-trip: permutation identity (property)
# ---------------------------------------------------------------------------

def _check_take_put_roundtrip(seed: int):
    """Gathering any lane subset and scattering it straight back is the
    identity, for arbitrary carry-shaped pytrees including ``None``
    trace/control leaves (the static-off lowerings' pytree form)."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 33))
    m = int(rng.integers(1, n + 1))
    tree = {
        "f32": jnp.asarray(rng.normal(size=(n, int(rng.integers(1, 5))))
                           .astype(np.float32)),
        "i32": (jnp.asarray(rng.integers(-5, 9, size=(n,))
                            .astype(np.int32)), None),
        "bool": jnp.asarray(rng.integers(0, 2, size=(n, 3)) != 0),
        "trace_off": None,
    }
    idx = jnp.asarray(rng.permutation(n)[:m])
    sub = engine._take_lanes(tree, idx)
    back = engine._put_lanes(tree, idx, sub)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), tree, back)
    # distinct-index scatter of gathered rows is exact, so double
    # application changes nothing either
    again = engine._put_lanes(back, idx, engine._take_lanes(back, idx))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), tree, again)


@pytest.mark.parametrize("seed", range(8))
def test_take_put_roundtrip_identity(seed):
    _check_take_put_roundtrip(seed)


if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(hst.integers(min_value=0, max_value=2**32 - 1))
    def test_take_put_roundtrip_identity_hypothesis(seed):
        _check_take_put_roundtrip(seed)


def test_take_put_roundtrip_real_carry():
    """The property on the engine's actual carry pytree (trace leaves off
    -> None leaves ride the tree.map exactly like the synthetic case)."""
    batch = sweep.grid_arrays(_elastic_params(12, seed=2),
                              pad_tasks=23, pad_vms=9)
    _, c0 = engine._setup_batch(batch)
    rng = np.random.default_rng(0)
    idx = jnp.asarray(rng.permutation(12)[:8])
    back = engine._put_lanes(c0, idx, engine._take_lanes(c0, idx))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), c0, back)


# ---------------------------------------------------------------------------
# Donation safety: no use-after-donate on any mode (ISSUE 10)
# ---------------------------------------------------------------------------

def test_engine_compact_donation_safe_and_bitwise():
    """donate=True must consume only loop-internal buffers: results match
    the donation-off and legacy loops bitwise, every output fully
    materializes, and a second run over the SAME batch arrays (shared,
    never donated) is identical — a use-after-donate anywhere raises."""
    batch = sweep.grid_arrays(_elastic_params(48, seed=23),
                              pad_tasks=23, pad_vms=9)
    lean, r1 = engine.simulate_batch_arrays_compact(batch, k=2)
    off, r2 = engine.simulate_batch_arrays_compact(batch, k=2,
                                                   donate=False)
    legacy, r3 = engine.simulate_batch_arrays_compact(batch, k=2,
                                                      legacy=True)
    again, r4 = engine.simulate_batch_arrays_compact(batch, k=2)
    _assert_bitwise(lean, off, "donate on vs off")
    _assert_bitwise(lean, legacy, "lean vs legacy loop")
    _assert_bitwise(lean, again, "repeat over shared batch")
    assert int(r1) == int(r2) == int(r3) == int(r4)


def test_engine_compact_donation_safe_traced():
    """The trace leaves ride the donated carry; the buffers the host
    finally reads must never have been donated."""
    batch = sweep.grid_arrays(_random_params(24, seed=6),
                              pad_tasks=23, pad_vms=9)
    out_a, rz_a, tr_a = engine.simulate_batch_arrays_compact(
        batch, k=2, trace=True)
    out_b, rz_b, tr_b = engine.simulate_batch_arrays_compact(
        batch, k=2, trace=True, legacy=True)
    _assert_bitwise(out_a, out_b, "traced lean vs legacy")
    assert int(rz_a) == int(rz_b)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), tr_a, tr_b)


def test_pallas_compact_donation_safe_and_bitwise():
    batch = sweep.grid_arrays(_random_params(48, seed=7),
                              pad_tasks=23, pad_vms=9)
    lean, r1 = epoch_schedule_compact(batch, k=2, tile=8, interpret=True)
    off, r2 = epoch_schedule_compact(batch, k=2, tile=8, interpret=True,
                                     donate=False)
    again, r3 = epoch_schedule_compact(batch, k=2, tile=8, interpret=True)
    _assert_bitwise(lean, off, "pallas donate on vs off")
    _assert_bitwise(lean, again, "pallas repeat over shared batch")
    assert int(r1) == int(r2) == int(r3)


def test_run_modes_survive_repeat_with_donation():
    """run() encodes grids through an lru cache, so the compact drivers
    must never donate encoder-owned arrays: every compacted mode must
    produce identical results when run twice back to back."""
    plan = _mixed_plan(n=48, seed=13)
    for kw in (dict(compact=1), dict(chunk=17, compact=2),
               dict(backend="pallas", compact=2)):
        first = plan.run(**kw)
        second = plan.run(**kw)
        for name in first.metric_names:
            np.testing.assert_array_equal(first[name], second[name],
                                          err_msg=f"{name} ({kw})")


# ---------------------------------------------------------------------------
# Host chattiness: the dispatch-lean loop's sync census (ISSUE 10)
# ---------------------------------------------------------------------------

def test_lean_loop_sync_census():
    """Acceptance: full mask/permutation pulls drop to <= the number of
    compaction rounds; every round pays exactly one fused scalar pull."""
    batch = sweep.grid_arrays(_random_params(64, seed=7),
                              pad_tasks=23, pad_vms=9)
    st = {}
    engine.simulate_batch_arrays_compact(batch, k=1, stats=st)
    assert st["compactions"] > 0, "grid must actually compact"
    assert st["syncs"] == st["compactions"]
    assert st["scalar_syncs"] == st["dispatches"] + 1
    # the legacy loop paid a full-array pull every round
    stl = {}
    engine.simulate_batch_arrays_compact(batch, k=1, stats=stl,
                                         legacy=True)
    assert stl["compactions"] == st["compactions"]
    assert stl["dispatches"] == st["dispatches"]
    assert stl["syncs"] > st["syncs"]
    assert stl["syncs"] >= stl["dispatches"]


def test_pallas_lean_loop_sync_census():
    batch = sweep.grid_arrays(_random_params(64, seed=7),
                              pad_tasks=23, pad_vms=9)
    st = {}
    epoch_schedule_compact(batch, k=1, tile=8, interpret=True, stats=st)
    assert st["compactions"] > 0
    assert st["syncs"] == st["compactions"]
    assert st["scalar_syncs"] == st["dispatches"] + 1


# ---------------------------------------------------------------------------
# Multi-tile mr_epoch: bitwise across the compact tile-sweep shapes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("block", [4, 8, 16])
def test_mr_epoch_multitile_bitwise(block):
    batch = sweep.grid_arrays(_random_params(48, seed=7),
                              pad_tasks=23, pad_vms=9)
    ref = epoch_schedule(batch, tile=16, interpret=True)
    mt = epoch_schedule(batch, tile=16, interpret=True, block_lanes=block)
    _assert_bitwise(ref, mt, f"multi-tile block={block}")


def test_pallas_compact_multitile_bitwise():
    """Compacted pow2 working sets re-tile across the minor grid dim and
    stay bitwise-equal to the engine across the tile-sweep shapes."""
    batch = sweep.grid_arrays(_random_params(48, seed=7),
                              pad_tasks=23, pad_vms=9)
    eng, _ = jax.jit(engine.simulate_batch_arrays)(batch)
    for tile, block in ((8, 4), (16, 8), (32, 8)):
        comp, rz = epoch_schedule_compact(batch, k=4, tile=tile,
                                          interpret=True,
                                          block_lanes=block)
        _assert_bitwise(eng, comp, f"compact tile={tile} block={block}")


def test_mr_epoch_multitile_elastic_stranded_bitwise():
    batch = sweep.grid_arrays(_elastic_params(32, seed=23),
                              pad_tasks=23, pad_vms=9)
    eng, _ = jax.jit(engine.simulate_batch_arrays)(batch)
    comp, _ = epoch_schedule_compact(batch, k=4, tile=8, interpret=True,
                                     block_lanes=4)
    _assert_bitwise(eng, comp, "multi-tile compact (stranded)")
