"""Pin the speculative-execution fluid model against the reference DES.

``core/speculative.py`` is an analytic extension (fluid processor sharing
plus one Hadoop-style speculation round) that bypasses the engine tower, so
nothing else anchors it to the oracle.  Two properties pin it:

* degenerate multipliers (all 1.0) reproduce the reference schedule — the
  fluid plain makespan equals ``refsim``'s, and the speculation round is a
  no-op (no suspects, no extra work, speedup exactly 1);
* malformed inputs are rejected with clear errors instead of silently
  mis-shaping — wrong multiplier count, multi-job scenarios, and policies
  the fluid model does not implement.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                     # seeded fallback, same test surface
    from _hypothesis_fallback import given, settings
    from _hypothesis_fallback import strategies as st

from repro.core import (BindingPolicy, SchedPolicy, paper_scenario, refsim,
                        speculative)

# single reduce only: the fluid model prices the reduce phase as one task
# at full VM rate, which is the reference schedule's shape only while
# reduces never share a processor
spec_params = st.tuples(
    st.integers(1, 12),                      # n_maps
    st.integers(1, 8),                       # n_vms
    st.sampled_from(["small", "medium", "large"]),
    st.sampled_from(["small", "medium", "big"]),
    st.booleans(),                           # network delay
)


@settings(max_examples=25, deadline=None)
@given(spec_params)
def test_property_degenerate_multipliers_match_refsim(p):
    m, v, vm, job, nd = p
    sc = paper_scenario(job=job, vm=vm, n_vms=v, n_maps=m, n_reduces=1,
                        network_delay=nd)
    r = speculative.simulate_speculative(sc, [1.0] * sc.total_tasks())
    ref = refsim.simulate(sc).job()
    np.testing.assert_allclose(r["makespan_plain"], ref.makespan,
                               rtol=2e-4, atol=1e-2)
    # no stragglers -> the speculation round must not fire
    assert r["n_backups"] == 0
    assert r["extra_work_frac"] == 0.0
    assert r["speedup"] == 1.0
    assert r["makespan_spec"] == r["makespan_plain"]


def test_multiplier_count_mismatch_raises():
    sc = paper_scenario(n_maps=4, n_vms=2)          # 4 maps + 1 reduce
    with pytest.raises(ValueError, match="4 multipliers for 5 tasks"):
        speculative.simulate_speculative(sc, [1.0] * 4)


def test_multi_job_rejected():
    sc = paper_scenario(n_maps=4, n_vms=2)
    two = sc.replace(jobs=list(sc.jobs) * 2)
    with pytest.raises(ValueError, match="2 jobs"):
        speculative.simulate_speculative(two, [1.0] * two.total_tasks())


def test_unsupported_policies_rejected():
    sc = paper_scenario(n_maps=4, n_vms=2)
    mult = [1.0] * sc.total_tasks()
    with pytest.raises(ValueError, match="TIME_SHARED"):
        speculative.simulate_speculative(
            sc.replace(sched_policy=SchedPolicy.SPACE_SHARED), mult)
    with pytest.raises(ValueError, match="ROUND_ROBIN"):
        speculative.simulate_speculative(
            sc.replace(binding_policy=BindingPolicy.LEAST_LOADED), mult)


def test_stragglers_never_slower_than_plain():
    """With real stragglers the speculated makespan never exceeds plain."""
    sc = paper_scenario(n_maps=16, n_vms=16)
    for seed in range(5):
        mult = speculative.straggler_multipliers(sc, 0.6, seed)
        r = speculative.simulate_speculative(sc, mult, threshold=1.5)
        assert r["makespan_spec"] <= r["makespan_plain"] + 1e-9
